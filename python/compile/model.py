"""L2 — JAX attention models (build-time only).

Forward graphs for the attention variants, calling the L1 Pallas kernel so
they lower into a single HLO module per variant. `aot.py` exports each entry
point as HLO text; the Rust runtime loads and executes them as the golden
reference for the functional dataflow executor.

Entry-point shapes are fixed at AOT time (see ENTRY_POINTS) and mirrored by
`rust/src/runtime/artifacts.rs`.
"""

import jax
import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.flat_attention import flat_attention

# Artifact shapes (keep in sync with rust/src/runtime/artifacts.rs).
MHA_SEQ = 256
MHA_DIM = 64
GQA_GROUP = 8
GQA_SP = 2
GQA_KV = 256
MLA_ROWS = 16
MLA_DC = 64
MLA_DR = 16
MLA_KV = 256


def mha_prefill(q, k, v):
    """Single-head MHA prefill block via the Pallas kernel.

    q/k/v: (MHA_SEQ, MHA_DIM) f32 → (MHA_SEQ, MHA_DIM).
    """
    return (flat_attention(q, k, v, block_q=64, block_k=64),)


def mha_reference(q, k, v):
    """Dense reference attention (no Pallas) — an independently lowered
    graph used to cross-check the kernel artifact."""
    return (ref.attention(q, k, v),)


def gqa_decode(q, k, v):
    """GQA decode for one KV group: the group's queries are concatenated
    into the effective sequence (paper §III-D).

    q: (GQA_GROUP·GQA_SP, MHA_DIM); k/v: (GQA_KV, MHA_DIM).
    """
    return (flat_attention(q, k, v, block_q=GQA_GROUP * GQA_SP, block_k=64),)


def mla_decode(q_abs, c_kv):
    """MLA weight-absorbed decode core (paper Eq. 7–8): all heads share the
    latent cache; V is the latent's first MLA_DC columns.

    q_abs: (MLA_ROWS, MLA_DC+MLA_DR); c_kv: (MLA_KV, MLA_DC+MLA_DR)
    → (MLA_ROWS, MLA_DC).
    """
    v = c_kv[:, :MLA_DC]
    return (flat_attention(q_abs, c_kv, v, block_q=MLA_ROWS, block_k=64),)


ENTRY_POINTS = {
    "mha_prefill": (
        mha_prefill,
        [(MHA_SEQ, MHA_DIM), (MHA_SEQ, MHA_DIM), (MHA_SEQ, MHA_DIM)],
    ),
    "mha_reference": (
        mha_reference,
        [(MHA_SEQ, MHA_DIM), (MHA_SEQ, MHA_DIM), (MHA_SEQ, MHA_DIM)],
    ),
    "gqa_decode": (
        gqa_decode,
        [(GQA_GROUP * GQA_SP, MHA_DIM), (GQA_KV, MHA_DIM), (GQA_KV, MHA_DIM)],
    ),
    "mla_decode": (
        mla_decode,
        [(MLA_ROWS, MLA_DC + MLA_DR), (MLA_KV, MLA_DC + MLA_DR)],
    ),
}


def lower_entry(name: str):
    """jit-lower an entry point with its fixed f32 shapes."""
    fn, shapes = ENTRY_POINTS[name]
    specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
    return jax.jit(fn).lower(*specs)
