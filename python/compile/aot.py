"""AOT export: lower every L2 entry point to HLO **text** artifacts.

Interchange is HLO text, not `.serialize()`: jax ≥ 0.5 emits HloModuleProto
with 64-bit instruction ids which the Rust side's xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage: `python -m compile.aot --out-dir ../artifacts` (from `python/`), or
just `make artifacts` at the repo root. Python never runs after this.
"""

import argparse
import pathlib

from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", help="artifact directory")
    ap.add_argument("--only", default=None, help="export a single entry point")
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    names = [args.only] if args.only else list(model.ENTRY_POINTS)
    for name in names:
        lowered = model.lower_entry(name)
        text = to_hlo_text(lowered)
        path = out_dir / f"{name}.hlo.txt"
        path.write_text(text)
        print(f"wrote {path} ({len(text)} chars)")


if __name__ == "__main__":
    main()
