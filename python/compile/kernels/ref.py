"""Pure-jnp oracles the Pallas kernel and JAX models are verified against.

These are the dense textbook formulations (paper §II-B): MHA, GQA, and MLA
in both the explicit and weight-absorbed (Eq. 7–8) forms.
"""

import jax.numpy as jnp


def attention(q, k, v, causal: bool = False):
    """softmax(q·kᵀ/√d)·v. q: (sq, d); k: (skv, d); v: (skv, dv)."""
    d = q.shape[-1]
    s = (q @ k.T) / jnp.sqrt(jnp.asarray(d, dtype=q.dtype))
    if causal:
        sq, skv = s.shape
        off = skv - sq
        mask = jnp.arange(skv)[None, :] <= (jnp.arange(sq)[:, None] + off)
        s = jnp.where(mask, s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return p @ v


def mha(q, k, v, causal: bool = False):
    """Multi-head: q/k (h, s, d), v (h, s, dv) → (h, sq, dv)."""
    return jnp.stack([attention(q[i], k[i], v[i], causal) for i in range(q.shape[0])])


def gqa(q, k, v, group: int, causal: bool = False):
    """Grouped-query attention: q (h, sq, d); k/v (h//group, skv, ·).

    Queries of a group are concatenated against their shared KV head
    (paper §III-D / Fig. 3d).
    """
    h, sq, _ = q.shape
    kv_heads = k.shape[0]
    assert h == kv_heads * group
    outs = []
    for g in range(kv_heads):
        for j in range(group):
            outs.append(attention(q[g * group + j], k[g], v[g], causal))
    return jnp.stack(outs)


def mla_explicit(x, w_dq, w_uq, w_dkv, w_uk, w_uv, causal: bool = False):
    """MLA, explicit form (Eq. 5–6): per-head Q/K/V decompressed from the
    latents. x: (s, d_model); returns (h, s, dv)."""
    c_q = x @ w_dq  # (s, q_lora)
    c_kv = x @ w_dkv  # (s, d_c)
    outs = []
    for i in range(w_uq.shape[0]):
        qi = c_q @ w_uq[i]  # (s, d)
        ki = c_kv @ w_uk[i]
        vi = c_kv @ w_uv[i]
        outs.append(attention(qi, ki, vi, causal))
    return jnp.stack(outs)


def mla_absorbed(x, w_dq, w_uq, w_dkv, w_uk, w_uv, causal: bool = False):
    """MLA after weight absorption (Eq. 7–8): scores computed in the latent
    space, shared c_kv as K and V; W^UV applied to the latent output.

    Numerically equal to `mla_explicit` up to fp error — the identity the
    paper's MQA-mode generalization rests on.
    """
    c_q = x @ w_dq
    c_kv = x @ w_dkv  # (s, d_c) — the only cached tensor
    d = w_uq.shape[-1]  # per-head dim (for the 1/√d scale)
    outs = []
    for i in range(w_uq.shape[0]):
        w_uqk = w_uq[i] @ w_uk[i].T  # (q_lora, d_c), Eq. 8
        q_abs = c_q @ w_uqk  # (s, d_c)
        s = (q_abs @ c_kv.T) / jnp.sqrt(jnp.asarray(d, dtype=x.dtype))
        if causal:
            sq, skv = s.shape
            off = skv - sq
            mask = jnp.arange(skv)[None, :] <= (jnp.arange(sq)[:, None] + off)
            s = jnp.where(mask, s, -jnp.inf)
        p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
        p = p / jnp.sum(p, axis=-1, keepdims=True)
        o_latent = p @ c_kv  # (s, d_c)
        outs.append(o_latent @ w_uv[i])  # decompress
    return jnp.stack(outs)
