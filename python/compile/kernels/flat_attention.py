"""L1 — Pallas FlatAttention kernel (build-time only).

The per-tile compute of the paper's Algorithm 2, expressed as a Pallas
kernel: a grid over output row blocks, an inner `fori_loop` over KV column
blocks, online-softmax rescaling of the running (m, l, O) statistics.

TPU hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's tile
group becomes the BlockSpec HBM↔VMEM schedule — each grid step holds one
(block_q × D) Q slice and streams (block_k × D) K/V slices through VMEM,
the exact slice shape the Fig. 10/11 strategy selects (128×128 by default).
The MXU plays RedMulE (f32-accumulated matmuls), the VPU plays Spatz
(rowmax / exp / rowsum). Group-level fabric collectives have no single-
kernel TPU analogue; they live in the L3 coordinator (and in the Rust
functional executor, which this kernel is verified against through the
AOT artifacts).

`interpret=True` everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls; real-TPU lowering is compile-only (see DESIGN.md §Perf for
the VMEM/MXU estimate).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128

NEG_INF = -1e30


def _attention_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, kv_len: int, scale: float):
    """One Q row-block against the full KV, online softmax over K blocks."""
    q = q_ref[...].astype(jnp.float32) * scale  # (bq, d)
    bq = q.shape[0]
    dv = v_ref.shape[-1]
    num_k = pl.cdiv(kv_len, block_k)

    def body(j, carry):
        m_i, l_i, acc = carry
        k_blk = pl.load(k_ref, (pl.dslice(j * block_k, block_k), slice(None))).astype(jnp.float32)
        v_blk = pl.load(v_ref, (pl.dslice(j * block_k, block_k), slice(None))).astype(jnp.float32)
        # Mask K rows beyond kv_len (when block_k does not divide kv_len).
        kpos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 1)
        s = q @ k_blk.T  # (bq, block_k)
        s = jnp.where(kpos < kv_len, s, NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=-1))  # row-wise max (line 15)
        p = jnp.exp(s - m_new[:, None])  # (line 17)
        corr = jnp.exp(m_i - m_new)  # tracking-stat rescale (line 22)
        l_new = corr * l_i + jnp.sum(p, axis=-1)  # (lines 18, 22)
        acc = acc * corr[:, None] + p @ v_blk  # (lines 23–24)
        return m_new, l_new, acc

    m0 = jnp.full((bq,), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((bq,), dtype=jnp.float32)
    acc0 = jnp.zeros((bq, dv), dtype=jnp.float32)
    _, l_f, acc = jax.lax.fori_loop(0, num_k, body, (m0, l0, acc0))
    o_ref[...] = (acc / l_f[:, None]).astype(o_ref.dtype)  # (line 28)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k"))
def flat_attention(q, k, v, block_q: int = DEFAULT_BLOCK_Q, block_k: int = DEFAULT_BLOCK_K):
    """softmax(q·kᵀ/√d)·v via the Pallas FlatAttention kernel.

    q: (sq, d); k: (skv, d); v: (skv, dv). Returns (sq, dv).
    """
    sq, d = q.shape
    kv_len, dv = v.shape
    block_q = min(block_q, sq)
    block_k = min(block_k, kv_len)
    # Pad KV to a block multiple: dynamic_slice clamps out-of-bounds starts,
    # which would silently misalign the tail block against its mask.
    pad = (-kv_len) % block_k
    if pad:
        k = jnp.pad(k, ((0, pad), (0, 0)))
        v = jnp.pad(v, ((0, pad), (0, 0)))
    kv_padded = kv_len + pad
    scale = 1.0 / (d**0.5)
    grid = (pl.cdiv(sq, block_q),)
    kernel = functools.partial(_attention_kernel, block_k=block_k, kv_len=kv_len, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i: (i, 0)),
            pl.BlockSpec((kv_padded, d), lambda i: (0, 0)),
            pl.BlockSpec((kv_padded, dv), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, dv), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((sq, dv), q.dtype),
        interpret=True,
    )(q, k, v)


def flat_attention_batched(q, k, v, block_q: int = DEFAULT_BLOCK_Q, block_k: int = DEFAULT_BLOCK_K):
    """vmap over leading (batch·heads) dimension: q (u, sq, d), k/v (u, skv, ·)."""
    fn = functools.partial(flat_attention, block_q=block_q, block_k=block_k)
    return jax.vmap(fn)(q, k, v)
