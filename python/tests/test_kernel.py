"""L1 correctness: the Pallas FlatAttention kernel against the pure-jnp
oracle, with hypothesis sweeping shapes/dtypes (the repo's core numeric
signal — everything downstream trusts this kernel)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.flat_attention import flat_attention, flat_attention_batched

jax.config.update("jax_enable_x64", False)


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype=jnp.float32).astype(dtype)


def keys(seed, n):
    return jax.random.split(jax.random.PRNGKey(seed), n)


@pytest.mark.parametrize("sq,skv,d", [(64, 64, 32), (128, 256, 64), (256, 128, 64)])
def test_kernel_matches_ref_basic(sq, skv, d):
    kq, kk, kv = keys(0, 3)
    q, k, v = rand(kq, (sq, d)), rand(kk, (skv, d)), rand(kv, (skv, d))
    out = flat_attention(q, k, v, block_q=32, block_k=32)
    expect = ref.attention(q, k, v)
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


def test_kernel_unaligned_kv():
    # kv_len not a multiple of block_k exercises the in-kernel masking.
    kq, kk, kv = keys(1, 3)
    q, k, v = rand(kq, (48, 32)), rand(kk, (100, 32)), rand(kv, (100, 32))
    out = flat_attention(q, k, v, block_q=16, block_k=32)
    expect = ref.attention(q, k, v)
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


def test_kernel_block_size_invariance():
    kq, kk, kv = keys(2, 3)
    q, k, v = rand(kq, (64, 16)), rand(kk, (128, 16)), rand(kv, (128, 16))
    outs = [
        flat_attention(q, k, v, block_q=bq, block_k=bk)
        for bq, bk in [(16, 16), (32, 64), (64, 128)]
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=2e-5, rtol=2e-5)


def test_kernel_bf16_inputs():
    kq, kk, kv = keys(3, 3)
    q = rand(kq, (32, 32), jnp.bfloat16)
    k = rand(kk, (64, 32), jnp.bfloat16)
    v = rand(kv, (64, 32), jnp.bfloat16)
    out = flat_attention(q, k, v, block_q=16, block_k=16).astype(jnp.float32)
    expect = ref.attention(q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32))
    np.testing.assert_allclose(out, expect, atol=3e-2, rtol=3e-2)


def test_batched_kernel():
    kq, kk, kv = keys(4, 3)
    q, k, v = rand(kq, (4, 32, 16)), rand(kk, (4, 48, 16)), rand(kv, (4, 48, 16))
    out = flat_attention_batched(q, k, v, block_q=16, block_k=16)
    for i in range(4):
        np.testing.assert_allclose(out[i], ref.attention(q[i], k[i], v[i]), atol=2e-5, rtol=2e-5)


@settings(max_examples=25, deadline=None)
@given(
    sq=st.sampled_from([8, 17, 32, 64, 96]),
    skv=st.sampled_from([8, 24, 64, 100, 128]),
    d=st.sampled_from([8, 16, 32, 64]),
    dv=st.sampled_from([8, 16, 32, 64]),
    block_q=st.sampled_from([8, 16, 32]),
    block_k=st.sampled_from([8, 16, 32]),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_kernel_matches_ref_hypothesis(sq, skv, d, dv, block_q, block_k, seed):
    kq, kk, kv = keys(seed, 3)
    q, k, v = rand(kq, (sq, d)), rand(kk, (skv, d)), rand(kv, (skv, dv))
    out = flat_attention(q, k, v, block_q=block_q, block_k=block_k)
    expect = ref.attention(q, k, v)
    np.testing.assert_allclose(out, expect, atol=5e-5, rtol=5e-5)


@settings(max_examples=10, deadline=None)
@given(scale=st.sampled_from([0.1, 1.0, 10.0, 100.0]), seed=st.integers(0, 999))
def test_kernel_numerically_stable_at_large_logits(scale, seed):
    # Online softmax must not overflow for large score magnitudes.
    kq, kk, kv = keys(seed, 3)
    q = rand(kq, (16, 16)) * scale
    k = rand(kk, (64, 16)) * scale
    v = rand(kv, (64, 16))
    out = flat_attention(q, k, v, block_q=16, block_k=16)
    assert bool(jnp.all(jnp.isfinite(out)))
    expect = ref.attention(q, k, v)
    np.testing.assert_allclose(out, expect, atol=1e-4, rtol=1e-3)


def test_rows_sum_property():
    # With v = identity-ish columns, output rows are convex combinations:
    # each output element must lie within [min(v), max(v)].
    kq, kk, kv = keys(5, 3)
    q, k, v = rand(kq, (32, 16)), rand(kk, (64, 16)), rand(kv, (64, 16))
    out = np.asarray(flat_attention(q, k, v, block_q=16, block_k=16))
    vmin, vmax = np.asarray(v).min(axis=0), np.asarray(v).max(axis=0)
    assert (out >= vmin - 1e-4).all() and (out <= vmax + 1e-4).all()
