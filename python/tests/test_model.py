"""L2 correctness: variant oracles (weight absorption identity, GQA
grouping) and the AOT entry points (shape checks + kernel-vs-reference)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def keys(seed, n):
    return jax.random.split(jax.random.PRNGKey(seed), n)


def test_weight_absorption_identity():
    # Paper Eq. 7–8: the absorbed MQA form equals explicit MLA exactly.
    ks = keys(0, 6)
    s, dm, ql, dc, d, dv, h = 24, 48, 32, 40, 16, 16, 4
    x = jax.random.normal(ks[0], (s, dm)) * 0.3
    w_dq = jax.random.normal(ks[1], (dm, ql)) * 0.1
    w_uq = jax.random.normal(ks[2], (h, ql, d)) * 0.1
    w_dkv = jax.random.normal(ks[3], (dm, dc)) * 0.1
    w_uk = jax.random.normal(ks[4], (h, dc, d)) * 0.1
    w_uv = jax.random.normal(ks[5], (h, dc, dv)) * 0.1
    explicit = ref.mla_explicit(x, w_dq, w_uq, w_dkv, w_uk, w_uv)
    absorbed = ref.mla_absorbed(x, w_dq, w_uq, w_dkv, w_uk, w_uv)
    # The absorbed form shares c_kv as V; outputs differ only in the V
    # decompression path — compare the explicit V path instead:
    # explicit uses v_i = c_kv @ w_uv[i]; absorbed computes (p @ c_kv) @ w_uv[i].
    # These are identical by associativity.
    np.testing.assert_allclose(absorbed, explicit, atol=1e-4, rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 999), h=st.sampled_from([2, 4]), group=st.sampled_from([1, 2]))
def test_gqa_grouping_matches_per_head(seed, h, group):
    ks = keys(seed, 3)
    kv_heads = h // group
    q = jax.random.normal(ks[0], (h, 8, 16))
    k = jax.random.normal(ks[1], (kv_heads, 24, 16))
    v = jax.random.normal(ks[2], (kv_heads, 24, 16))
    out = ref.gqa(q, k, v, group)
    for i in range(h):
        expect = ref.attention(q[i], k[i // group], v[i // group])
        np.testing.assert_allclose(out[i], expect, atol=1e-5, rtol=1e-5)


def test_mha_prefill_entry_matches_reference():
    ks = keys(1, 3)
    q = jax.random.normal(ks[0], (model.MHA_SEQ, model.MHA_DIM))
    k = jax.random.normal(ks[1], (model.MHA_SEQ, model.MHA_DIM))
    v = jax.random.normal(ks[2], (model.MHA_SEQ, model.MHA_DIM))
    (out,) = model.mha_prefill(q, k, v)
    (expect,) = model.mha_reference(q, k, v)
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


def test_gqa_decode_entry_shape_and_value():
    ks = keys(2, 3)
    rows = model.GQA_GROUP * model.GQA_SP
    q = jax.random.normal(ks[0], (rows, model.MHA_DIM))
    k = jax.random.normal(ks[1], (model.GQA_KV, model.MHA_DIM))
    v = jax.random.normal(ks[2], (model.GQA_KV, model.MHA_DIM))
    (out,) = model.gqa_decode(q, k, v)
    assert out.shape == (rows, model.MHA_DIM)
    np.testing.assert_allclose(out, ref.attention(q, k, v), atol=2e-5, rtol=2e-5)


def test_mla_decode_entry_matches_latent_attention():
    ks = keys(3, 2)
    w = model.MLA_DC + model.MLA_DR
    q_abs = jax.random.normal(ks[0], (model.MLA_ROWS, w))
    c_kv = jax.random.normal(ks[1], (model.MLA_KV, w))
    (out,) = model.mla_decode(q_abs, c_kv)
    assert out.shape == (model.MLA_ROWS, model.MLA_DC)
    expect = ref.attention(q_abs, c_kv, c_kv[:, : model.MLA_DC])
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


def test_all_entry_points_lower():
    for name in model.ENTRY_POINTS:
        lowered = model.lower_entry(name)
        assert "stablehlo" in str(lowered.compiler_ir("stablehlo"))[:200] or True
        # Must also convert to HLO text (the artifact format).
        from compile.aot import to_hlo_text

        text = to_hlo_text(lowered)
        assert "ENTRY" in text
