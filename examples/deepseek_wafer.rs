//! End-to-end driver (paper §V-C): DeepSeek-v3-671B FP8 decoding on the
//! 64-chip wafer-scale system — the repo's headline experiment.
//!
//! The full pipeline:
//!   1. Functional check of the MLA weight-absorbed attention core against
//!      the PJRT-executed JAX golden (`artifacts/mla_decode.hlo.txt`).
//!   2. Batch sweep on EP32-PP2 comparing FlatAttention vs the FlashMLA-
//!      style dataflow: system throughput vs TPOT (paper Fig. 13a).
//!   3. The Table II operating points (≤ 50 ms TPOT) vs DS-Prof / CM384.
//!
//! Run: `make artifacts && cargo run --release --example deepseek_wafer`

use anyhow::Result;

use flatattention::arch::config::SimFidelity;
use flatattention::baseline::soa::SoaSystem;
use flatattention::exec::functional;
use flatattention::exec::tensor::Mat;
use flatattention::multichip::d2d::WaferSystem;
use flatattention::multichip::parallelism::{AttentionChoice, DecodeEvaluator, ParallelismPlan};
use flatattention::multichip::wafer::{batch_sweep, best_under_tpot, ours1, ours2};
use flatattention::runtime::artifacts::{artifact_path, Artifact};
use flatattention::runtime::pjrt::HloExecutable;
use flatattention::util::SplitMix64;
use flatattention::workload::deepseek::DeepSeekConfig;

fn main() -> Result<()> {
    let ds = DeepSeekConfig::v3_671b();
    let sys = WaferSystem::paper();
    println!("# DeepSeek-v3-671B decoding on a wafer-scale multi-die system\n");
    println!(
        "model: {} ({} B params), 64 chips @ {:.0} TFLOPS FP8, {:.0} GB/s HBM, {:.0} GB/s D2D",
        ds.name,
        ds.param_count() / 1_000_000_000,
        sys.chip.peak_flops() / 1e12,
        sys.chip.hbm.total_bandwidth_bytes_per_s / 1e9,
        sys.d2d.link_bandwidth_bytes_per_s / 1e9
    );

    // --- 1. MLA functional check vs PJRT golden ---------------------------
    println!("\n## 1. MLA weight-absorbed core vs PJRT golden");
    match artifact_path(Artifact::MlaDecode) {
        Ok(path) => {
            let exe = HloExecutable::load(&path)?;
            let mut rng = SplitMix64::new(7);
            // Shapes fixed by python/compile/model.py: R=16, dc=64, dr=16, KV=256.
            let (rows, dc, dr, kv) = (16usize, 64usize, 16usize, 256usize);
            let q_abs = Mat::random(rows, dc + dr, &mut rng);
            let c_kv = Mat::random(kv, dc + dr, &mut rng);
            let golden = exe.run_f32(&[&q_abs, &c_kv], rows, dc)?;
            let v_latent = c_kv.cols_slice(0, dc);
            let local = functional::reference_attention(&q_abs, &c_kv, &v_latent, false);
            let err = local.max_abs_diff(&golden);
            println!("  PJRT (Pallas MLA kernel) vs Rust functional: max |Δ| = {err:.2e}");
            anyhow::ensure!(err < 5e-3);
        }
        Err(e) => println!("  (skipping PJRT check: {e})"),
    }

    // --- 2. Fig. 13a sweep -------------------------------------------------
    println!("\n## 2. Decode batch sweep, EP32-PP2, kv=4096 (Fig. 13a)");
    let plan = ParallelismPlan::new(32, 2);
    println!(
        "{:<14} {:>10} {:>11} {:>14} {:>14} {:>10}",
        "dataflow", "batch/chip", "TPOT (ms)", "system tok/s", "tok/s/chip", "attn util"
    );
    for choice in [AttentionChoice::Flat, AttentionChoice::FlashMla] {
        for o in batch_sweep(&sys, &ds, plan, 4096, choice, SimFidelity::Analytic) {
            println!(
                "{:<14} {:>10} {:>11.1} {:>14.0} {:>14.0} {:>9.0}%",
                choice.label(),
                o.batch_per_chip,
                o.tpot_ms,
                o.system_tokens_per_s,
                o.per_chip_tokens_per_s,
                100.0 * o.attention_utilization
            );
        }
    }

    // --- 3. Layer breakdown @ b=256 (Fig. 13b) -----------------------------
    println!("\n## 3. Decode-layer breakdown @ 256 batch/chip (Fig. 13b)");
    let mut ev = DecodeEvaluator::new(SimFidelity::Analytic);
    let flat = ev.evaluate(&sys, &ds, plan, 256, 4096, AttentionChoice::Flat);
    let mla = ev.evaluate(&sys, &ds, plan, 256, 4096, AttentionChoice::FlashMla);
    for (name, o) in [("FlatAttention", &flat), ("FlashMLA", &mla)] {
        println!(
            "  {:<14} attention {:>7.0} µs ({:>4.1}%)  gemm {:>7.0} µs  vector {:>5.1} µs  C2C {:>6.1} µs",
            name,
            o.layer.attention_s * 1e6,
            100.0 * o.layer.attention_s / o.layer.total(),
            o.layer.gemm_s * 1e6,
            o.layer.vector_s * 1e6,
            o.layer.c2c_s * 1e6
        );
    }
    println!(
        "  → FlatAttention: attention speedup {:.1}x, end-to-end layer speedup {:.1}x (paper: 4.5x, 2.1x)",
        mla.layer.attention_s / flat.layer.attention_s,
        mla.layer.total() / flat.layer.total()
    );

    // --- 4. Table II -------------------------------------------------------
    println!("\n## 4. Table II operating points (TPOT ≤ 50 ms)");
    println!("{:<22} {:>8} {:>12} {:>11}", "system", "batch", "tok/s/chip", "TPOT (ms)");
    for s in [SoaSystem::cm384(), SoaSystem::ds_prof()] {
        println!("{:<22} {:>8} {:>12.0} {:>11.1}", s.name, s.batch_per_chip, s.tokens_per_s_per_chip, s.tpot_ms);
    }
    let ds_prof = SoaSystem::ds_prof();
    for (name, sweep) in [("Ours1 (1 TB/s D2D)", ours1(SimFidelity::Analytic)), ("Ours2 (160 GB/s D2D)", ours2(SimFidelity::Analytic))] {
        if let Some(o) = best_under_tpot(&sweep, 50.0) {
            println!(
                "{:<22} {:>8} {:>12.0} {:>11.1}   ({:.1}x DS-Prof per-chip, {:.1}x TPOT reduction)",
                name,
                o.batch_per_chip,
                o.per_chip_tokens_per_s,
                o.tpot_ms,
                o.per_chip_tokens_per_s / ds_prof.tokens_per_s_per_chip,
                ds_prof.tpot_ms / o.tpot_ms
            );
        }
    }
    println!("\npaper headline: 1.9x system throughput, 1.4x TPOT reduction at 1.5x lower peak FLOPS");
    Ok(())
}
