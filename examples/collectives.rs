//! Fabric collectives demo (paper Fig. 2b / Fig. 7): row-wise multicast and
//! sum-reduction in HW / SW.Tree / SW.Seq flavours across transfer sizes on
//! the 32×32 Table I mesh.
//!
//! Run: `cargo run --release --example collectives`

use flatattention::arch::collective::{multicast, reduce, Axis, CollectiveImpl};
use flatattention::arch::config::{ChipConfig, Dtype};
use flatattention::arch::noc::{ChipResources, TileCoord};
use flatattention::sim::Graph;
use flatattention::util::fmt_bytes;

fn main() {
    let cfg = ChipConfig::table1();
    let res = ChipResources::new(&cfg);
    println!("# Fabric collectives on {} (row of 32 tiles)\n", cfg.name);

    let impls = [CollectiveImpl::Hw, CollectiveImpl::SwTree, CollectiveImpl::SwSeq];
    println!("## Row-wise multicast (cycles)");
    println!("{:>10}  {:>10} {:>10} {:>10}  {:>10} {:>10}", "size", "HW", "SW.Tree", "SW.Seq", "HW/Tree", "HW/Seq");
    for shift in [10u32, 12, 14, 16, 18, 20, 22] {
        let bytes = 1u64 << shift;
        let t: Vec<u64> = impls
            .iter()
            .map(|&imp| {
                let mut g = Graph::new(res.table.clone());
                multicast(&mut g, &res, &cfg, imp, Axis::Row, 0, 32, bytes, &[]);
                g.simulate().makespan
            })
            .collect();
        println!(
            "{:>10}  {:>10} {:>10} {:>10}  {:>9.1}x {:>9.1}x",
            fmt_bytes(bytes),
            t[0],
            t[1],
            t[2],
            t[1] as f64 / t[0] as f64,
            t[2] as f64 / t[0] as f64
        );
    }

    println!("\n## Row-wise sum reduction (cycles)");
    println!("{:>10}  {:>10} {:>10} {:>10}  {:>10} {:>10}", "size", "HW", "SW.Tree", "SW.Seq", "HW/Tree", "HW/Seq");
    for shift in [10u32, 12, 14, 16, 18, 20, 22] {
        let bytes = 1u64 << shift;
        let t: Vec<u64> = impls
            .iter()
            .map(|&imp| {
                let mut g = Graph::new(res.table.clone());
                reduce(
                    &mut g,
                    &res,
                    &cfg,
                    imp,
                    Axis::Row,
                    0,
                    32,
                    TileCoord { x: 0, y: 0 },
                    bytes,
                    Dtype::Fp16,
                    &[],
                );
                g.simulate().makespan
            })
            .collect();
        println!(
            "{:>10}  {:>10} {:>10} {:>10}  {:>9.1}x {:>9.1}x",
            fmt_bytes(bytes),
            t[0],
            t[1],
            t[2],
            t[1] as f64 / t[0] as f64,
            t[2] as f64 / t[0] as f64
        );
    }
    println!("\npaper anchors (4 MiB): multicast 5.1x / 30.7x; reduction 10.9x / 67.3x");
}
