//! Request-level serving on the wafer-scale system: continuous batching,
//! KV-cache admission and offered-load sweeps on top of the steady-state
//! decode model of `examples/deepseek_wafer.rs`.
//!
//! 1. Synthesizes three seeded traffic patterns (Poisson, bursty, diurnal).
//! 2. Sweeps offered load on the Table II EP32-PP2 configuration and prints
//!    the goodput / TTFT / TPOT curves with the saturation knee (prefill
//!    billed by the real prefill dataflow simulation).
//! 3. Compares KV admission policies on a memory-constrained wafer.
//! 4. Shows prefix-cache KV reuse and the FCFS/SJF/priority queue policies
//!    on shared-system-prompt traffic.
//!
//! Run: `cargo run --release --example serving`

use anyhow::Result;

use flatattention::metrics::fmt_pct;
use flatattention::multichip::d2d::WaferSystem;
use flatattention::multichip::parallelism::KernelCache;
use flatattention::serve::request::{generate_trace, PrefixProfile, TraceConfig, TrafficPattern};
use flatattention::serve::scheduler::{AdmissionPolicy, QueuePolicy, SchedulerConfig};
use flatattention::serve::sim::{load_sweep, saturation_knee, simulate, ServeConfig, StageTimeCache};
use flatattention::serve::KvCacheModel;
use flatattention::workload::deepseek::DeepSeekConfig;

fn main() -> Result<()> {
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let cfg = ServeConfig::default();
    let kv = KvCacheModel::new(&sys, &ds, cfg.plan, cfg.dtype);
    println!("# Serving DeepSeek-v3-671B on the 64-chip wafer (EP32-PP2)\n");
    println!(
        "per chip: {} GiB HBM − {:.1} GiB weights → {:.2} M KV tokens/column ({} columns)",
        sys.chip.hbm.capacity_bytes() >> 30,
        kv.weight_bytes_per_chip as f64 / (1u64 << 30) as f64,
        kv.column_capacity_tokens as f64 / 1e6,
        kv.columns
    );

    // --- 1+2. Offered-load sweep per traffic pattern -----------------------
    // FLATATTENTION_FAST=1 shrinks horizons/rates to smoke-test scale (CI).
    let fast = std::env::var_os("FLATATTENTION_FAST").is_some();
    let horizon = if fast { 3.0 } else { 20.0 };
    let rates: &[f64] = if fast { &[250.0, 1000.0] } else { &[250.0, 500.0, 1000.0, 2000.0, 4000.0] };
    let rates = rates.to_vec();
    let kernels = KernelCache::new();
    let stages = StageTimeCache::new();
    // Periods divide the horizon so realized load matches the offered rps.
    for pattern in [
        TrafficPattern::Poisson,
        TrafficPattern::Bursty { period_s: horizon / 4.0, duty: 0.3, burst_factor: 4.0 },
        TrafficPattern::Diurnal { period_s: horizon, trough_factor: 0.25 },
    ] {
        println!("\n## {} traffic, horizon {horizon} s", pattern.label());
        println!(
            "{:>6} {:>7} {:>8} {:>10} {:>10} {:>10} {:>11} {:>9} {:>8}",
            "rps", "done", "backlog", "TTFT p99", "TPOT p50", "TPOT p99", "tok/s", "goodput", "KV peak"
        );
        let outcomes = load_sweep(&sys, &ds, &cfg, pattern, &rates, 2026, horizon, &kernels, &stages);
        for o in &outcomes {
            println!(
                "{:>6.0} {:>7} {:>8} {:>8.0}ms {:>8.1}ms {:>8.1}ms {:>11.0} {:>9.0} {:>8}",
                o.offered_rps,
                o.completed,
                o.in_flight + o.queued,
                o.ttft_ms.p99,
                o.tpot_ms.p50,
                o.tpot_ms.p99,
                o.system_tokens_per_s,
                o.goodput_rps,
                fmt_pct(o.peak_kv_occupancy)
            );
        }
        match saturation_knee(&outcomes, cfg.slo_tpot_ms) {
            Some(rate) => println!("→ saturation knee at {rate:.0} rps (p99 TPOT crosses the {} ms SLO)", cfg.slo_tpot_ms),
            None => println!("→ no saturation inside the sweep"),
        }
    }

    // --- 3. Admission policies under memory pressure -----------------------
    let (p_rate, p_horizon) = if fast { (400.0, 3.0) } else { (1200.0, 10.0) };
    println!("\n## KV admission policies on a 24 GiB-HBM wafer, poisson {p_rate:.0} rps");
    let mut small = WaferSystem::paper();
    small.chip.hbm.capacity_gib_per_stack = 12;
    let trace = generate_trace(&TraceConfig::new(77, TrafficPattern::Poisson, p_rate, p_horizon));
    for (name, policy) in [
        ("reserve-full", AdmissionPolicy::ReserveFull),
        ("on-demand+preempt", AdmissionPolicy::OnDemandPreempt),
    ] {
        let pcfg = ServeConfig {
            scheduler: SchedulerConfig { policy, ..Default::default() },
            ..Default::default()
        };
        let (o, _) = simulate(&small, &ds, &trace, &pcfg, p_horizon, name, p_rate, &kernels, &stages);
        println!(
            "  {:<18} done {:>5}  preempt {:>5}  TPOT p99 {:>6.1} ms  goodput {:>5.0} rps  KV peak {}",
            name,
            o.completed,
            o.preemptions,
            o.tpot_ms.p99,
            o.goodput_rps,
            fmt_pct(o.peak_kv_occupancy)
        );
    }
    // --- 4. Prefix-cache KV reuse + scheduling policies --------------------
    // Agentic traffic: 70% of prompts share one of 8 seeded system prefixes
    // (~1k tokens). Reused blocks skip prefill compute AND KV admission, and
    // prefill itself is billed by the real prefill dataflow simulation, so
    // the TTFT delta below is dataflow-grounded, not a heuristic discount.
    let (x_rate, x_horizon) = if fast { (300.0, 3.0) } else { (800.0, 10.0) };
    println!("\n## Prefix-cache KV reuse + queue policies, poisson {x_rate:.0} rps, shared prompts");
    let tc = TraceConfig::new(4242, TrafficPattern::Poisson, x_rate, x_horizon)
        .with_prefixes(PrefixProfile::agentic());
    let shared_trace = generate_trace(&tc);
    for (name, queue_policy, block) in [
        ("fcfs, cache off", QueuePolicy::Fcfs, 0u32),
        ("fcfs, cache on", QueuePolicy::Fcfs, 256),
        ("sjf, cache on", QueuePolicy::Sjf, 256),
        ("priority, cache on", QueuePolicy::Priority, 256),
    ] {
        let pcfg = ServeConfig {
            scheduler: SchedulerConfig {
                queue_policy,
                prefix_block_tokens: block,
                ..Default::default()
            },
            ..Default::default()
        };
        let (o, _) = simulate(&sys, &ds, &shared_trace, &pcfg, x_horizon, name, x_rate, &kernels, &stages);
        println!(
            "  {:<20} done {:>5}  hit rate {:>6}  TTFT mean {:>6.0} ms  p99 {:>6.0} ms  goodput {:>5.0} rps",
            name,
            o.completed,
            fmt_pct(o.prefix_hit_rate()),
            o.ttft_ms.mean,
            o.ttft_ms.p99,
            o.goodput_rps
        );
    }
    println!("\nserving example OK");
    Ok(())
}
