//! Fleet-level serving across multiple wafer instances: disaggregated
//! prefill/decode pools, KV-transfer accounting and prefix-affinity routing
//! on top of the request-level simulator of `examples/serving.rs`.
//!
//! 1. Sizes the KV handoff: latent-KV layout bytes and exposed delay per
//!    migrated prompt over inter-node vs D2D-class links.
//! 2. Sweeps prefill:decode pool ratios at fixed fleet size against the
//!    colocated baseline and prints the TPOT crossover.
//! 3. Compares routing policies on shared-prompt traffic (prefix affinity
//!    concentrates family blocks on their home instance).
//!
//! Run: `cargo run --release --example cluster`

use anyhow::Result;

use flatattention::cluster::{
    simulate_cluster, tpot_crossover, ClusterConfig, ClusterOutcome, FleetMode, KvTransferModel, RoutingPolicy,
};
use flatattention::metrics::fmt_pct;
use flatattention::multichip::d2d::WaferSystem;
use flatattention::multichip::parallelism::KernelCache;
use flatattention::serve::request::{generate_trace, thin_trace, PrefixProfile, TraceConfig, TrafficPattern};
use flatattention::serve::sim::StageTimeCache;
use flatattention::util::fmt_bytes;
use flatattention::workload::deepseek::DeepSeekConfig;

fn main() -> Result<()> {
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    println!("# Fleet serving: 4× EP32-PP2 wafer instances, DeepSeek-v3-671B\n");

    // --- 1. KV handoff economics ------------------------------------------
    let inter = KvTransferModel::inter_node(&ds, flatattention::arch::config::Dtype::Fp8);
    let d2d = KvTransferModel::d2d_class(&ds, flatattention::arch::config::Dtype::Fp8);
    println!("## KV handoff (MLA latent layout, {} B/token across 61 layers)", inter.bytes_per_token);
    for ctx in [512u64, 2048, 8192] {
        println!(
            "  {ctx:>5}-token prompt: {:>9}  inter-node {:>7.2} ms exposed  d2d-class {:>6.3} ms",
            fmt_bytes(inter.bytes_for(ctx)),
            inter.exposed_seconds(ctx) * 1e3,
            d2d.exposed_seconds(ctx) * 1e3,
        );
    }

    // --- 2. Pool-ratio sweep vs colocated ---------------------------------
    // FLATATTENTION_FAST=1 shrinks horizons/rates to smoke-test scale (CI).
    let fast = std::env::var_os("FLATATTENTION_FAST").is_some();
    let horizon = if fast { 3.0 } else { 8.0 };
    let rates: &[f64] = if fast { &[125.0, 2000.0] } else { &[125.0, 1000.0, 4000.0, 8000.0] };
    let rates = rates.to_vec();
    let seed = 2026u64;
    let max_rate = rates.iter().cloned().fold(0.0f64, f64::max);
    let master = generate_trace(
        &TraceConfig::new(seed, TrafficPattern::Poisson, max_rate, horizon).with_prefixes(PrefixProfile::agentic()),
    );
    let kernels = KernelCache::new();
    let stages = StageTimeCache::new();
    let modes = [
        FleetMode::Colocated { instances: 4 },
        FleetMode::Disaggregated { prefill: 1, decode: 3 },
        FleetMode::Disaggregated { prefill: 2, decode: 2 },
        FleetMode::Disaggregated { prefill: 3, decode: 1 },
    ];
    println!("\n## Pool ratios over offered load, horizon {horizon} s");
    println!(
        "{:>14} {:>6} {:>6} {:>9} {:>9} {:>9} {:>10} {:>8} {:>9}",
        "fleet", "rps", "done", "TTFT p50", "TPOT p50", "TPOT p99", "tok/s", "goodput", "transfer"
    );
    let mut curves: Vec<Vec<ClusterOutcome>> = Vec::new();
    for mode in modes {
        let ccfg = ClusterConfig { mode, ..ClusterConfig::colocated(4, &ds) };
        let mut curve = Vec::new();
        for &rate in &rates {
            let trace = thin_trace(&master, rate / max_rate, seed ^ 0xC0FF_EE00);
            let (o, _) = simulate_cluster(&sys, &ds, &trace, &ccfg, horizon, rate, &kernels, &stages);
            assert!(o.conserves_requests());
            println!(
                "{:>14} {:>6.0} {:>6} {:>7.0}ms {:>7.1}ms {:>7.1}ms {:>10.0} {:>8.0} {:>9}",
                o.label,
                rate,
                o.completed,
                o.ttft_ms.p50,
                o.tpot_ms.p50,
                o.tpot_ms.p99,
                o.fleet_tokens_per_s,
                o.goodput_rps,
                fmt_pct(o.transfer_overhead_share),
            );
            curve.push(o);
        }
        curves.push(curve);
    }
    for (mode, curve) in modes.iter().zip(&curves).skip(1) {
        match tpot_crossover(&curves[0], curve) {
            Some(rate) => println!("→ {}: p99 TPOT beats colocated from {rate:.0} rps", mode.label()),
            None => println!("→ {}: colocated p99 TPOT never beaten in this sweep", mode.label()),
        }
    }

    // --- 3. Routing policies on shared-prompt traffic ---------------------
    // The live least-queue-depth policy reads each instance's engine
    // snapshot at the decision time — only meaningful on the interleaved
    // single-clock fleet, where all instances advance in causal order.
    let r_rate = if fast { 500.0 } else { 1000.0 };
    println!("\n## Arrival routing at {r_rate:.0} rps (70% shared prompts, colocated-4)");
    let trace = thin_trace(&master, r_rate / max_rate, seed ^ 0xC0FF_EE00);
    for policy in [
        RoutingPolicy::RoundRobin,
        RoutingPolicy::LeastOutstanding,
        RoutingPolicy::LeastQueueDepth,
        RoutingPolicy::PrefixAffinity,
    ] {
        let ccfg = ClusterConfig { routing: policy, ..ClusterConfig::colocated(4, &ds) };
        let (o, _) = simulate_cluster(&sys, &ds, &trace, &ccfg, horizon, r_rate, &kernels, &stages);
        let hits: u64 = o.instances.iter().map(|i| i.prefix_hit_tokens).sum();
        println!(
            "  {:<18} done {:>6}  TTFT mean {:>6.0} ms  prefix hits {:>10} tokens  goodput {:>5.0} rps  spills {:>4}",
            policy.label(),
            o.completed,
            o.ttft_ms.mean,
            hits,
            o.goodput_rps,
            o.router_spills,
        );
    }
    println!("\ncluster example OK");
    Ok(())
}
