//! Architecture–dataflow co-exploration (paper Appendix D): sweep the tile
//! array geometry, L1 capacity, NoC link width and HBM bandwidth around the
//! Table I design point and report FlatAttention's utilization at each —
//! the feedback loop the paper uses to select the accelerator configuration.
//!
//! Run: `cargo run --release --example design_space`

use flatattention::arch::config::{ChipConfig, Dtype, SimFidelity};
use flatattention::dataflow::{simulate_attention, AttentionDataflow};
use flatattention::metrics::fmt_pct;
use flatattention::workload::attention::AttentionShape;

fn eval(cfg: &ChipConfig) -> (f64, f64, f64) {
    let shape = AttentionShape::mha_prefill(2, 32, 128, 4096, Dtype::Fp16);
    let m = simulate_attention(cfg, &shape, AttentionDataflow::auto_flat(cfg, &shape), SimFidelity::Full);
    (m.seconds * 1e3, m.compute_utilization, m.hbm_bw_utilization)
}

fn main() {
    println!("# Architecture co-exploration around Table I (MHA prefill D=128 S=4096)\n");
    println!(
        "{:<34} {:>10} {:>8} {:>8} {:>10}",
        "configuration", "runtime", "util", "HBM BW", "peak TF"
    );

    let base = ChipConfig::table1();
    let mut rows: Vec<(String, ChipConfig)> = vec![("table1 (32x32, 384KiB, 128B/cyc)".into(), base.clone())];

    // Mesh geometry sweep at iso-peak-ish FLOPS.
    for mesh in [16u32, 24, 32] {
        let mut c = base.clone();
        c.name = format!("mesh-{mesh}x{mesh}");
        c.mesh_x = mesh;
        c.mesh_y = mesh;
        c.hbm.channels_per_stack = mesh.min(32);
        rows.push((format!("mesh {mesh}x{mesh} (same tile)"), c));
    }
    // L1 capacity sweep (tiling strategy reacts via slice selection).
    for l1 in [192u64, 384, 768] {
        let mut c = base.clone();
        c.name = format!("l1-{l1}");
        c.tile.l1_kib = l1;
        rows.push((format!("L1 {l1} KiB"), c));
    }
    // NoC link width (collective bandwidth).
    for link in [64u64, 128, 256] {
        let mut c = base.clone();
        c.name = format!("link-{link}");
        c.noc.link_bytes_per_cycle = link;
        rows.push((format!("NoC link {link} B/cyc"), c));
    }
    // HBM bandwidth.
    for bw in [1.0e12, 2.0e12, 4.0e12] {
        let mut c = base.clone();
        c.name = format!("hbm-{}", bw as u64 / 1_000_000_000);
        c.hbm.total_bandwidth_bytes_per_s = bw;
        rows.push((format!("HBM {:.0} TB/s", bw / 1e12), c));
    }

    for (label, cfg) in rows {
        let (ms, util, bw) = eval(&cfg);
        println!(
            "{:<34} {:>8.2}ms {:>8} {:>8} {:>9.0}",
            label,
            ms,
            fmt_pct(util),
            fmt_pct(bw),
            cfg.peak_flops() / 1e12
        );
    }
    println!(
        "\ntakeaway: the Table I point sits where larger groups stop paying (over-flattening)\nand HBM stops being the bottleneck — the co-design balance of paper Appendix D."
    );
}
