//! Quickstart: the whole stack on one MHA layer.
//!
//! 1. Verify the dataflow *math*: the Rust functional FlatAttention executor
//!    (Algorithm 2, slice-for-slice with group reductions) against the dense
//!    reference and against the PJRT-executed JAX/Pallas golden artifact.
//! 2. Simulate the *performance* of the same layer on the paper's Table I
//!    chip with FlashAttention-2/3 and FlatAttention dataflows.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use anyhow::Result;

use flatattention::arch::config::{ChipConfig, Dtype, SimFidelity};
use flatattention::dataflow::{simulate_attention, AttentionDataflow, FlatTiling};
use flatattention::exec::functional;
use flatattention::exec::tensor::Mat;
use flatattention::metrics::fmt_pct;
use flatattention::runtime::artifacts::{artifact_path, Artifact};
use flatattention::runtime::pjrt::HloExecutable;
use flatattention::util::SplitMix64;
use flatattention::workload::attention::AttentionShape;

fn main() -> Result<()> {
    // --- 1. numerics ------------------------------------------------------
    println!("# FlatAttention quickstart\n");
    println!("## 1. Functional verification (Algorithm 2 math)");
    let mut rng = SplitMix64::new(42);
    let (sq, skv, d) = (256usize, 256usize, 64usize);
    let q = Mat::random(sq, d, &mut rng);
    let k = Mat::random(skv, d, &mut rng);
    let v = Mat::random(skv, d, &mut rng);

    let reference = functional::reference_attention(&q, &k, &v, false);
    let tiling = FlatTiling { gx: 4, gy: 4, slice_r: 16, slice_c: 16 };
    let flat = functional::flat_attention(&q, &k, &v, &tiling);
    println!("  flat (4x4 group) vs dense reference: max |Δ| = {:.2e}", flat.max_abs_diff(&reference));
    assert!(flat.max_abs_diff(&reference) < 1e-4);

    match artifact_path(Artifact::MhaPrefill) {
        Ok(path) => {
            let exe = HloExecutable::load(&path)?;
            let golden = exe.run_f32(&[&q, &k, &v], sq, d)?;
            let err = flat.max_abs_diff(&golden);
            println!("  flat vs PJRT golden (Pallas kernel → HLO → CPU): max |Δ| = {err:.2e}");
            assert!(err < 5e-3);
        }
        Err(e) => println!("  (skipping PJRT check: {e})"),
    }

    // --- 2. performance ----------------------------------------------------
    println!("\n## 2. Performance simulation (Table I chip, MHA prefill D=128 S=4096, B=2 H=32)");
    let cfg = ChipConfig::table1();
    let shape = AttentionShape::mha_prefill(2, 32, 128, 4096, Dtype::Fp16);
    println!(
        "  chip: {} — {:.0} TFLOPS FP16, {:.0} GB/s HBM",
        cfg.name,
        cfg.peak_flops() / 1e12,
        cfg.hbm.total_bandwidth_bytes_per_s / 1e9
    );
    let mut fa3_s = 0.0;
    for df in [
        AttentionDataflow::Fa2,
        AttentionDataflow::Fa3,
        AttentionDataflow::auto_flat(&cfg, &shape),
    ] {
        let m = simulate_attention(&cfg, &shape, df, SimFidelity::Full);
        if matches!(df, AttentionDataflow::Fa3) {
            fa3_s = m.seconds;
        }
        println!(
            "  {:10}  {:>9.3} ms   util {:>6}   HBM BW {:>6}   traffic {}",
            df.label(),
            m.seconds * 1e3,
            fmt_pct(m.compute_utilization),
            fmt_pct(m.hbm_bw_utilization),
            flatattention::util::fmt_bytes(m.hbm_bytes)
        );
        if let AttentionDataflow::Flat(_) = df {
            println!("  → FlatAttention speedup over FA-3: {:.1}x (paper: 4.1x at this shape)", fa3_s / m.seconds);
        }
    }
    println!("\nquickstart OK");
    Ok(())
}
