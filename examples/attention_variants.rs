//! Attention-variant sweep (paper Fig. 12 workloads): FlatAttention with the
//! Fig. 10 tiling strategy across MHA / GQA / MLA, prefill and decode, vs
//! the GH200 SoA kernel baselines.
//!
//! Run: `cargo run --release --example attention_variants`

use flatattention::arch::config::{ChipConfig, SimFidelity};
use flatattention::baseline::gh200::{self, Bound, Gh200};
use flatattention::coordinator::experiments::fig12_shapes;
use flatattention::coordinator::report::fmt_time;
use flatattention::dataflow::{choose_tiling, simulate_attention, AttentionDataflow};
use flatattention::metrics::fmt_pct;

fn main() {
    let cfg = ChipConfig::table1_gh200_match();
    let gh = Gh200::new();
    println!("# FlatAttention across attention variants — {} vs GH200\n", cfg.name);
    println!(
        "{:<28} {:>12} {:>9} {:>12} {:>14} {:>9}",
        "shape", "ours", "ours-label", "GH200", "GH200 kernel", "speedup"
    );

    let mut speedups: Vec<f64> = Vec::new();
    for shape in fig12_shapes(false) {
        let tiling = choose_tiling(&cfg, &shape, true);
        let m = simulate_attention(&cfg, &shape, AttentionDataflow::auto_flat(&cfg, &shape), SimFidelity::Full);
        let g = gh200::attention(&gh, &shape);
        let sp = g.seconds / m.seconds;
        speedups.push(sp);
        let ours_label = if shape.is_compute_bound(&cfg) {
            format!("C:{}", fmt_pct(m.compute_utilization))
        } else {
            format!("M:{}", fmt_pct(m.hbm_bw_utilization))
        };
        let gh_label = match g.bound {
            Bound::Compute => format!("{} C:{}", g.kernel, fmt_pct(g.efficiency)),
            Bound::Memory => format!("{} M:{}", g.kernel, fmt_pct(g.efficiency)),
        };
        println!(
            "{:<28} {:>12} {:>9} {:>12} {:>14} {:>8.1}x   (group {}x{}, slice {}x{})",
            shape.label(),
            fmt_time(m.seconds),
            ours_label,
            fmt_time(g.seconds),
            gh_label,
            sp,
            tiling.gx,
            tiling.gy,
            tiling.slice_r,
            tiling.slice_c
        );
    }
    let avg = speedups.iter().sum::<f64>() / speedups.len() as f64;
    println!("\naverage speedup over GH200: {avg:.1}x (paper: 1.9x)");
}
