//! `flatattention` CLI — the L3 coordinator entry point.
//!
//! Subcommands (std-only argument parsing; the build is fully offline):
//!
//! ```text
//! flatattention list                         # list experiments
//! flatattention experiment <id> [--fast] [--cache-dir DIR]
//! flatattention all [--fast] [--cache-dir DIR]
//! flatattention simulate [options]           # simulate one attention kernel
//! flatattention serve [--fast] [--policies] [--prefix] [--cache-dir DIR]
//!                     [--policy fcfs|sjf|priority] [--rate R] [--horizon S] [--seed N]
//!                     [--trace-out F] [--series-out F] [--metrics-out F] [--threads N]
//! flatattention cluster [--fast] [--models] [--dynamic] [--cache-dir DIR]
//!                       [--routing P] [--topology degenerate|torus|fat-tree]
//!                       [--link inter-node|d2d]
//!                       [--prefill N --decode N | --instances N]
//!                       [--rate R] [--horizon S] [--seed N] [--shards N]
//!                       [--kill I@T]... [--drain I@T]... [--fault-restart S] [--random-kills N]
//!                       [--trace-out F] [--series-out F] [--metrics-out F] [--threads N]
//! flatattention report serve|cluster [serve/cluster options] [--json-out F]
//! flatattention verify [--artifacts DIR]     # functional + PJRT verification
//! ```
//!
//! `serve` drives the continuous-batching serving simulator (experiment ids
//! `serve_load` / `serve_policies` / `serve_prefix`): deterministic
//! goodput-vs-offered-load curves with TTFT/TPOT p50/p95/p99 for Poisson,
//! bursty and diurnal traffic on the Table II EP32-PP2 wafer configuration,
//! with dataflow-grounded prefill billing, prefix-cache KV reuse and
//! FCFS/SJF/priority queue policies.
//!
//! `cluster` drives the fleet layer above `serve` (experiment ids
//! `cluster_pools` / `cluster_models` / `cluster_dynamic` /
//! `cluster_failures` / `cluster_topology`): multiple wafer instances
//! interleaved on one event clock behind a cluster router (static or live
//! least-queue-depth policies), colocated or disaggregated into
//! prefill/decode pools with the MLA latent-KV handoff routed hop-by-hop
//! over an explicit inter-instance fabric (`--topology
//! degenerate|torus|fat-tree`, per-edge busy-until contention ledgers;
//! `--routing topo-aware` folds the hop count into decode placement).
//! `--kill I@T` / `--drain I@T` schedule instance faults (global engine id
//! `I`, seconds `T`, repeatable): a kill aborts at the next epoch barrier
//! and requeues stranded work through the entry router, a drain masks the
//! instance and lets residents finish. `--fault-restart S` rejoins every
//! faulted instance `S` seconds later (a killed instance first reloads its
//! weights over the shared link, billed); `--random-kills N` draws N
//! seeded kill times over the horizon from the trace seed. Faults never
//! break determinism — any shard count replays the same run byte for byte.
//!
//! `--cache-dir DIR` persists the kernel/stage-time memo caches to a JSON
//! snapshot in DIR: loaded before the run, written back after, so repeated
//! invocations never re-simulate a kernel shape (cross-process
//! memoization). Caching never changes a result — every entry is keyed by
//! its full config identity.
//!
//! `--shards N` partitions a custom fleet across the sharded
//! conservative-lookahead engine; `--threads N` pins the process-wide
//! worker budget (also honored by the parallel batch sweeps; the
//! `FLATATTENTION_THREADS` env var is the flag's equivalent). Neither ever
//! changes a result — any shard count and any thread budget are
//! bit-identical to the serial path.
//!
//! `--trace-out F` / `--series-out F` / `--metrics-out F` / `--attrib-out F`
//! export the deterministic observability layer ([`flatattention::obs`]): a
//! Chrome `trace_event` JSON (load F in <https://ui.perfetto.dev>), a
//! fixed-interval gauge series (CSV, or JSON when F ends in `.json`),
//! Prometheus text-format counters, and the `flatattention-attrib-v1`
//! performance-attribution JSON (per-kernel rooflines + per-request latency
//! waterfalls). On the custom `serve`/`cluster` paths the whole simulation
//! is instrumented; the canned experiment paths still write valid
//! (empty-trace) files carrying the real cache counters. Observability
//! never changes a result — the instrumented run's outcome is bit-identical
//! to the plain one.
//!
//! `report` runs one observed `serve` or `cluster` simulation (same option
//! tail as those subcommands) and prints the cross-layer attribution
//! profile instead of the outcome table: top kernels by simulated time with
//! roofline classification, latency-waterfall percentiles, the Fig. 9
//! dataflow anchor and — on the cluster path — the DES self-profile
//! (wall-clock, diagnostic only). `--json-out F` writes the same
//! attribution as `flatattention-attrib-v1` JSON.

use anyhow::{bail, Context, Result};

use flatattention::arch::config::{ChipConfig, Dtype, SimFidelity};
use flatattention::coordinator::cache::{self, SimCaches};
use flatattention::coordinator::cli::{ClusterArgs, LinkClass, ServeArgs};
use flatattention::coordinator::experiments;
use flatattention::dataflow::{simulate_attention, AttentionDataflow, FlatParams};
use flatattention::exec::functional;
use flatattention::exec::tensor::Mat;
use flatattention::obs::{ObsBundle, ObsConfig, ObsExports};
use flatattention::runtime::artifacts::{artifact_path, Artifact};
use flatattention::runtime::pjrt::HloExecutable;
use flatattention::util::SplitMix64;
use flatattention::workload::attention::AttentionShape;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    let flag = |name: &str| args.iter().any(|a| a == name);
    let opt = |name: &str| {
        args.iter()
            .position(|a| a == name)
            .and_then(|i| args.get(i + 1))
            .cloned()
    };

    match cmd {
        "help" | "--help" | "-h" => {
            println!("flatattention — FlatAttention reproduction (simulator + dataflows + wafer runtime)");
            println!();
            println!("usage:");
            println!("  flatattention list");
            println!("  flatattention experiment <id> [--fast] [--cache-dir DIR]");
            println!("  flatattention all [--fast] [--cache-dir DIR]");
            println!("  flatattention simulate [--dataflow fa2|fa3|flat] [--phase prefill|decode]");
            println!("                         [--seq N] [--kv N] [--heads N] [--dim N] [--batch N]");
            println!("                         [--chip table1|gh200|wafer] [--analytic]");
            println!("  flatattention serve [--fast] [--policies] [--prefix] [--cache-dir DIR]");
            println!("                      [--policy fcfs|sjf|priority] [--rate R] [--horizon S] [--seed N]");
            println!("                      [--trace-out F] [--series-out F] [--metrics-out F] [--attrib-out F] [--threads N]");
            println!("  flatattention cluster [--fast] [--models] [--dynamic] [--cache-dir DIR]");
            println!("                        [--routing round-robin|least-outstanding|least-queue-depth|prefix-affinity|topo-aware]");
            println!("                        [--topology degenerate|torus|fat-tree]");
            println!("                        [--link inter-node|d2d] [--prefill N --decode N | --instances N]");
            println!("                        [--rate R] [--horizon S] [--seed N] [--shards N]");
            println!("                        [--kill I@T]... [--drain I@T]... [--fault-restart S] [--random-kills N]");
            println!("                        [--trace-out F] [--series-out F] [--metrics-out F] [--attrib-out F] [--threads N]");
            println!("  flatattention report serve|cluster [serve/cluster options] [--json-out F]");
            println!("  flatattention verify");
            println!();
            println!("  --trace-out F    Chrome trace_event JSON (open in ui.perfetto.dev)");
            println!("  --series-out F   per-instance gauge series (CSV; JSON when F ends in .json)");
            println!("  --metrics-out F  Prometheus text-format counters");
            println!("  --attrib-out F   performance attribution: kernel rooflines + latency waterfalls (JSON)");
            println!("  --json-out F     (report) write the attribution profile as flatattention-attrib-v1 JSON");
            println!("  --shards N       shard the custom fleet's lookahead engine (bit-identical at any N)");
            println!("  --threads N      pin the worker-thread budget (= FLATATTENTION_THREADS)");
            println!("  --kill I@T       kill instance I at T s: abort at the barrier, requeue stranded work");
            println!("  --drain I@T      drain instance I at T s: mask the router, residents finish in place");
            println!("  --fault-restart S  rejoin every faulted instance S s later (kills reload weights first)");
            println!("  --random-kills N   N seeded-random kills drawn over the horizon from the trace seed");
            Ok(())
        }
        "list" => {
            for (id, desc) in experiments::list() {
                println!("{id:8} {desc}");
            }
            Ok(())
        }
        "experiment" => {
            let id = args.get(1).context("usage: flatattention experiment <id>")?;
            let (caches, cache_dir) = open_caches(opt("--cache-dir"))?;
            let rep = experiments::run_with(id, flag("--fast"), &caches)?;
            rep.print();
            persist_caches(cache_dir.as_deref(), &caches)
        }
        "all" => {
            let (caches, cache_dir) = open_caches(opt("--cache-dir"))?;
            for (id, _) in experiments::list() {
                let rep = experiments::run_with(id, flag("--fast"), &caches)?;
                rep.print();
                println!();
            }
            persist_caches(cache_dir.as_deref(), &caches)
        }
        "simulate" => {
            let chip = match opt("--chip").as_deref() {
                None | Some("table1") => ChipConfig::table1(),
                Some("gh200") => ChipConfig::table1_gh200_match(),
                Some("wafer") => ChipConfig::wafer_fp8(),
                Some(other) => bail!("unknown chip '{other}'"),
            };
            let heads: u32 = opt("--heads").map(|s| s.parse()).transpose()?.unwrap_or(32);
            let dim: u32 = opt("--dim").map(|s| s.parse()).transpose()?.unwrap_or(128);
            let batch: u32 = opt("--batch").map(|s| s.parse()).transpose()?.unwrap_or(2);
            let shape = match opt("--phase").as_deref() {
                None | Some("prefill") => {
                    let seq: u32 = opt("--seq").map(|s| s.parse()).transpose()?.unwrap_or(4096);
                    AttentionShape::mha_prefill(batch, heads, dim, seq, Dtype::Fp16)
                }
                Some("decode") => {
                    let kv: u32 = opt("--kv").map(|s| s.parse()).transpose()?.unwrap_or(4096);
                    AttentionShape::mha_decode(batch, heads, dim, kv, 1, Dtype::Fp16)
                }
                Some(other) => bail!("unknown phase '{other}'"),
            };
            let df = match opt("--dataflow").as_deref() {
                None | Some("flat") => AttentionDataflow::Flat(FlatParams::auto(&chip, &shape)),
                Some("fa2") => AttentionDataflow::Fa2,
                Some("fa3") => AttentionDataflow::Fa3,
                Some(other) => bail!("unknown dataflow '{other}'"),
            };
            let fidelity = if flag("--analytic") { SimFidelity::Analytic } else { SimFidelity::Full };
            let m = simulate_attention(&chip, &shape, df, fidelity);
            println!("chip       : {}", chip.name);
            println!("shape      : {}", shape.label());
            println!("dataflow   : {}", df.label());
            println!(
                "runtime    : {} ({} cycles)",
                flatattention::coordinator::report::fmt_time(m.seconds),
                flatattention::util::fmt_cycles(m.cycles)
            );
            println!(
                "achieved   : {:.0} TFLOPS ({:.1}% of peak)",
                m.tflops,
                100.0 * m.compute_utilization
            );
            println!(
                "HBM        : {} ({:.1}% BW)",
                flatattention::util::fmt_bytes(m.hbm_bytes),
                100.0 * m.hbm_bw_utilization
            );
            println!("NoC        : {}", flatattention::util::fmt_bytes(m.noc_bytes));
            Ok(())
        }
        "serve" => {
            // Shorthand for the serving experiments: the load sweep (or a
            // custom single sweep / the prefix-cache experiment), plus the
            // KV-policy comparison when --policies is given.
            let sargs = ServeArgs::parse(&args[1..])?;
            if let Some(n) = sargs.threads {
                flatattention::util::set_worker_threads(n);
            }
            let (caches, cache_dir) = open_caches(sargs.cache_dir.clone())?;
            let obs_cfg = sargs.obs_requested().then(ObsConfig::default);
            let mut obs_written = false;
            if sargs.prefix {
                experiments::run_with("serve_prefix", sargs.fast, &caches)?.print();
            } else if sargs.is_custom() {
                let rate = sargs.rate_rps.unwrap_or(1000.0);
                let horizon = sargs.horizon_s.unwrap_or(if sargs.fast { 4.0 } else { 10.0 });
                let (rep, exports) = experiments::serve_custom_observed(sargs.queue_policy, rate, horizon, sargs.seed, &caches, obs_cfg);
                rep.print();
                if let Some(e) = exports {
                    write_obs(&sargs.trace_out, &sargs.series_out, &sargs.metrics_out, &sargs.attrib_out, &e)?;
                    obs_written = true;
                }
            } else {
                experiments::run_with("serve_load", sargs.fast, &caches)?.print();
            }
            if sargs.policies {
                println!();
                experiments::run_with("serve_policies", sargs.fast, &caches)?.print();
            }
            if sargs.obs_requested() && !obs_written {
                // Canned experiment path: still honor the flags with valid
                // (empty-trace) files carrying the real cache counters.
                write_obs(&sargs.trace_out, &sargs.series_out, &sargs.metrics_out, &sargs.attrib_out, &fallback_exports(&caches))?;
            }
            persist_caches(cache_dir.as_deref(), &caches)
        }
        "cluster" => {
            // Shorthand for the fleet experiments: the pool-ratio sweep, the
            // multi-model comparison (--models), the static-vs-live routing
            // comparison (--dynamic), or a single custom fleet.
            let cargs = ClusterArgs::parse(&args[1..])?;
            if let Some(n) = cargs.threads {
                flatattention::util::set_worker_threads(n);
            }
            let (caches, cache_dir) = open_caches(cargs.cache_dir.clone())?;
            let obs_cfg = cargs.obs_requested().then(ObsConfig::default);
            let mut obs_written = false;
            if cargs.models {
                experiments::run_with("cluster_models", cargs.fast, &caches)?.print();
            } else if cargs.dynamic {
                experiments::run_with("cluster_dynamic", cargs.fast, &caches)?.print();
            } else if cargs.is_custom() {
                let rate = cargs.rate_rps.unwrap_or(1000.0);
                let horizon = cargs.horizon_s.unwrap_or(if cargs.fast { 4.0 } else { 10.0 });
                let faults = cargs.fault_plan(cargs.mode().instances() as usize, horizon);
                let (rep, exports) = experiments::cluster_custom_observed(
                    cargs.mode(),
                    cargs.routing,
                    cargs.topology,
                    cargs.link == LinkClass::D2dClass,
                    rate,
                    horizon,
                    cargs.seed,
                    &faults,
                    cargs.shards,
                    &caches,
                    obs_cfg,
                );
                rep.print();
                if let Some(e) = exports {
                    write_obs(&cargs.trace_out, &cargs.series_out, &cargs.metrics_out, &cargs.attrib_out, &e)?;
                    obs_written = true;
                }
            } else {
                experiments::run_with("cluster_pools", cargs.fast, &caches)?.print();
            }
            if cargs.obs_requested() && !obs_written {
                write_obs(&cargs.trace_out, &cargs.series_out, &cargs.metrics_out, &cargs.attrib_out, &fallback_exports(&caches))?;
            }
            persist_caches(cache_dir.as_deref(), &caches)
        }
        "report" => {
            // Cross-layer performance attribution: run one observed serve
            // or cluster simulation and print the profiler view (kernel
            // rooflines + latency waterfalls) instead of the outcome table.
            let target = args.get(1).map(|s| s.as_str()).unwrap_or("serve");
            // `--json-out PATH` is report-only; strip it before handing the
            // tail to the serve/cluster parsers.
            let mut tail: Vec<String> = Vec::new();
            let mut json_out: Option<String> = None;
            let mut it = args.iter().skip(2);
            while let Some(a) = it.next() {
                if a == "--json-out" {
                    json_out = Some(it.next().context("--json-out expects a value")?.clone());
                } else {
                    tail.push(a.clone());
                }
            }
            match target {
                "serve" => {
                    let sargs = ServeArgs::parse(&tail)?;
                    if let Some(n) = sargs.threads {
                        flatattention::util::set_worker_threads(n);
                    }
                    let (caches, cache_dir) = open_caches(sargs.cache_dir.clone())?;
                    let rate = sargs.rate_rps.unwrap_or(1000.0);
                    let horizon = sargs.horizon_s.unwrap_or(if sargs.fast { 4.0 } else { 10.0 });
                    let (text, json) =
                        experiments::serve_report(sargs.queue_policy, rate, horizon, sargs.seed, &caches);
                    println!("{text}");
                    write_attrib(json_out.as_deref().or(sargs.attrib_out.as_deref()), &json)?;
                    persist_caches(cache_dir.as_deref(), &caches)
                }
                "cluster" => {
                    let cargs = ClusterArgs::parse(&tail)?;
                    if let Some(n) = cargs.threads {
                        flatattention::util::set_worker_threads(n);
                    }
                    let (caches, cache_dir) = open_caches(cargs.cache_dir.clone())?;
                    let rate = cargs.rate_rps.unwrap_or(1000.0);
                    let horizon = cargs.horizon_s.unwrap_or(if cargs.fast { 4.0 } else { 10.0 });
                    let faults = cargs.fault_plan(cargs.mode().instances() as usize, horizon);
                    let (text, json) = experiments::cluster_report(
                        cargs.mode(),
                        cargs.routing,
                        cargs.topology,
                        cargs.link == LinkClass::D2dClass,
                        rate,
                        horizon,
                        cargs.seed,
                        &faults,
                        cargs.shards,
                        &caches,
                    );
                    println!("{text}");
                    write_attrib(json_out.as_deref().or(cargs.attrib_out.as_deref()), &json)?;
                    persist_caches(cache_dir.as_deref(), &caches)
                }
                other => bail!("unknown report target '{other}'; usage: flatattention report serve|cluster [options]"),
            }
        }
        "verify" => verify(),
        other => bail!("unknown command '{other}'; try `flatattention help`"),
    }
}

/// Load the on-disk caches when `--cache-dir` was given; fresh otherwise.
fn open_caches(cache_dir: Option<String>) -> Result<(SimCaches, Option<String>)> {
    match cache_dir {
        Some(dir) => {
            let caches = cache::load(std::path::Path::new(&dir))?;
            Ok((caches, Some(dir)))
        }
        None => Ok((SimCaches::fresh(), None)),
    }
}

/// Write the caches back when `--cache-dir` was given.
fn persist_caches(cache_dir: Option<&str>, caches: &SimCaches) -> Result<()> {
    match cache_dir {
        Some(dir) => cache::save(std::path::Path::new(dir), caches),
        None => Ok(()),
    }
}

/// Write whichever observability exports were requested.
fn write_obs(
    trace_out: &Option<String>,
    series_out: &Option<String>,
    metrics_out: &Option<String>,
    attrib_out: &Option<String>,
    exports: &ObsExports,
) -> Result<()> {
    if let Some(p) = trace_out {
        std::fs::write(p, &exports.trace_json).with_context(|| format!("writing trace to {p}"))?;
        println!("trace   → {p}");
    }
    if let Some(p) = series_out {
        let body = if p.ends_with(".json") { &exports.series_json } else { &exports.series_csv };
        std::fs::write(p, body).with_context(|| format!("writing series to {p}"))?;
        println!("series  → {p}");
    }
    if let Some(p) = metrics_out {
        std::fs::write(p, &exports.metrics_text).with_context(|| format!("writing metrics to {p}"))?;
        println!("metrics → {p}");
    }
    write_attrib(attrib_out.as_deref(), &exports.attrib_json)
}

/// Write the `flatattention-attrib-v1` JSON when a path was requested.
fn write_attrib(path: Option<&str>, json: &str) -> Result<()> {
    if let Some(p) = path {
        std::fs::write(p, json).with_context(|| format!("writing attribution to {p}"))?;
        println!("attrib  → {p}");
    }
    Ok(())
}

/// Counters-only exports for the canned experiment paths: a valid (empty)
/// Chrome trace and gauge series plus the real kernel/stage cache
/// counters, so the requested files always exist and always parse.
fn fallback_exports(caches: &SimCaches) -> ObsExports {
    let mut b = ObsBundle::new();
    b.counters.add("stage_cache_hits", caches.stages.hits());
    b.counters.add("stage_cache_misses", caches.stages.misses());
    b.counters.add("kernel_cache_hits", caches.kernels.hits());
    b.counters.add("kernel_cache_misses", caches.kernels.misses());
    b.exports()
}

/// Functional + PJRT verification: the Rust FlatAttention executor (the
/// dataflow math of Algorithm 2) against the PJRT-executed JAX/Pallas
/// golden artifacts.
fn verify() -> Result<()> {
    use flatattention::dataflow::FlatTiling;

    println!("[1/3] functional: FlatAttention (Algorithm 2) vs dense reference");
    let mut rng = SplitMix64::new(2026);
    let (sq, skv, d) = (256usize, 256usize, 64usize);
    let q = Mat::random(sq, d, &mut rng);
    let k = Mat::random(skv, d, &mut rng);
    let v = Mat::random(skv, d, &mut rng);
    let reference = functional::reference_attention(&q, &k, &v, false);
    let tiling = FlatTiling { gx: 4, gy: 4, slice_r: 16, slice_c: 16 };
    let flat = functional::flat_attention(&q, &k, &v, &tiling);
    let err = flat.max_abs_diff(&reference);
    println!("      max |Δ| = {err:.2e}");
    anyhow::ensure!(err < 1e-4, "functional mismatch");

    println!("[2/3] PJRT: loading MHA artifact");
    let path = artifact_path(Artifact::MhaPrefill)?;
    let exe = HloExecutable::load(&path)?;
    println!("      platform = {}", exe.platform());

    println!("[3/3] PJRT golden vs Rust functional executor");
    let golden = exe.run_f32(&[&q, &k, &v], sq, d)?;
    let err_pjrt = flat.max_abs_diff(&golden);
    println!("      max |Δ| (flat vs PJRT) = {err_pjrt:.2e}");
    anyhow::ensure!(err_pjrt < 5e-3, "PJRT mismatch: {err_pjrt}");
    println!("verify OK — kernel → JAX → HLO → PJRT → Rust dataflow all agree");
    Ok(())
}
