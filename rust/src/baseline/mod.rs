//! GH200 and SoA-system baselines.
pub mod gh200;
pub mod soa;
