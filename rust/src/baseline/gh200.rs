//! NVIDIA GH200 baseline model (paper Fig. 1b anchors, Fig. 12 comparison).
//!
//! Substitution (DESIGN.md): we do not have a GH200; the paper's own
//! benchmark data [1] is summarized by a roofline model with per-variant
//! *measured-efficiency* envelopes. Fig. 1b reports FlashAttention-3 /
//! FlashMLA running 26–64% below the roofline; the efficiency table below
//! encodes that envelope per (variant, phase, head-dim) and is the only
//! GH200-specific calibration in the repository.

use crate::workload::attention::{AttentionShape, AttentionVariant, Phase};

/// GH200 device constants (FP16 dense peak, HBM3e bandwidth).
#[derive(Debug, Clone, Copy)]
pub struct Gh200 {
    pub peak_fp16_flops: f64,
    pub peak_fp8_flops: f64,
    pub hbm_bytes_per_s: f64,
}

impl Default for Gh200 {
    fn default() -> Self {
        Self::new()
    }
}

impl Gh200 {
    pub fn new() -> Self {
        // 989 TFLOPS FP16 dense (no sparsity), 1979 TFLOPS FP8, 4 TB/s
        // (the paper's Table I match point).
        Gh200 { peak_fp16_flops: 989.0e12, peak_fp8_flops: 1979.0e12, hbm_bytes_per_s: 4.0e12 }
    }

    pub fn ridge_flops_per_byte(&self) -> f64 {
        self.peak_fp16_flops / self.hbm_bytes_per_s
    }
}

impl Default for Gh200 {
    fn default() -> Self {
        Self::new()
    }
}

/// Whether a kernel lands compute- or memory-bound on GH200.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Bound {
    Compute,
    Memory,
}

/// GH200 attention-kernel performance estimate.
#[derive(Debug, Clone, Copy)]
pub struct Gh200Attention {
    pub seconds: f64,
    pub bound: Bound,
    /// Compute utilization (compute-bound) or HBM BW utilization
    /// (memory-bound) actually achieved — the `C:x% / M:y%` labels of
    /// Fig. 12 for the GH200 bars.
    pub efficiency: f64,
    pub kernel: &'static str,
}

/// Measured-efficiency envelope for the SoA kernels on GH200, calibrated to
/// the paper's Fig. 1b (FA-3 prefill and FlashMLA decode sit 26–64% below
/// the roofline) and the Fig. 12 average speedup anchor (1.9×).
fn efficiency(shape: &AttentionShape) -> (f64, &'static str) {
    match (shape.variant, shape.phase) {
        // FlashAttention-3 prefill: better at large head dim (Fig. 1b:
        // hd64 sits much further from the roofline than hd128).
        (AttentionVariant::Mha, Phase::Prefill) | (AttentionVariant::Gqa { .. }, Phase::Prefill) => {
            let e = match shape.head_dim {
                0..=64 => 0.38,
                65..=96 => 0.48,
                _ => 0.58,
            };
            // Short sequences lose additional efficiency to scheduling.
            let s = shape.seq_q;
            let e = if s < 1024 { e * 0.8 } else if s < 2048 { e * 0.9 } else { e };
            (e, "FlashAttention-3")
        }
        // Flash decode kernels: memory-bound BW efficiency.
        (AttentionVariant::Mha, _) => (0.45, "FlashAttention-3"),
        (AttentionVariant::Mqa, _) | (AttentionVariant::Gqa { .. }, _) => (0.50, "FlashAttention-3"),
        // FlashMLA: high BW efficiency memory-bound, but weaker in the
        // compute-bound regime created by weight absorption.
        (AttentionVariant::MlaAbsorbed, Phase::Prefill) => (0.55, "FlashMLA"),
        (AttentionVariant::MlaAbsorbed, _) => {
            if shape.batch >= 32 {
                (0.48, "FlashMLA") // compute-bound regime
            } else {
                (0.60, "FlashMLA") // memory-bound regime
            }
        }
    }
}

/// Estimate the GH200 runtime of an attention kernel.
pub fn attention(gh: &Gh200, shape: &AttentionShape) -> Gh200Attention {
    let flops = shape.flops() as f64;
    let bytes = shape.ideal_io_bytes() as f64;
    let peak = if shape.dtype.bytes() == 1 { gh.peak_fp8_flops } else { gh.peak_fp16_flops };
    let t_compute = flops / peak;
    let t_memory = bytes / gh.hbm_bytes_per_s;
    let bound = if t_compute >= t_memory { Bound::Compute } else { Bound::Memory };
    let (eff, kernel) = efficiency(shape);
    let seconds = t_compute.max(t_memory) / eff;
    Gh200Attention { seconds, bound, efficiency: eff, kernel }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::config::{ChipConfig, Dtype};

    #[test]
    fn gh200_matches_table1_peak() {
        let gh = Gh200::new();
        let t1 = ChipConfig::table1_gh200_match();
        let ratio = t1.peak_flops() / gh.peak_fp16_flops;
        assert!((ratio - 1.0).abs() < 0.05, "ratio {ratio}");
        assert_eq!(t1.hbm.total_bandwidth_bytes_per_s, gh.hbm_bytes_per_s);
    }

    #[test]
    fn prefill_efficiency_within_fig1b_envelope() {
        // Fig. 1b: 26%–64% gap → efficiency in [0.36, 0.74].
        let gh = Gh200::new();
        for d in [64, 128] {
            for s in [1024, 2048, 4096, 8192] {
                let shape = AttentionShape::mha_prefill(2, 32, d, s, Dtype::Fp16);
                let a = attention(&gh, &shape);
                assert!(a.efficiency >= 0.30 && a.efficiency <= 0.74, "eff {} d{d} s{s}", a.efficiency);
            }
        }
    }

    #[test]
    fn long_prefill_is_compute_bound_decode_memory_bound() {
        let gh = Gh200::new();
        let p = attention(&gh, &AttentionShape::mha_prefill(2, 32, 128, 4096, Dtype::Fp16));
        assert_eq!(p.bound, Bound::Compute);
        let d = attention(&gh, &AttentionShape::mha_decode(16, 32, 128, 8192, 1, Dtype::Fp16));
        assert_eq!(d.bound, Bound::Memory);
    }

    #[test]
    fn flashmla_decode_uses_flashmla_kernel() {
        let gh = Gh200::new();
        let s = AttentionShape::mla_absorbed_decode(64, 128, 512, 64, 4096, 2, Dtype::Fp16);
        let a = attention(&gh, &s);
        assert_eq!(a.kernel, "FlashMLA");
        assert_eq!(a.bound, Bound::Compute); // absorbed MLA at batch 64
    }

    #[test]
    fn runtime_scales_with_sequence() {
        let gh = Gh200::new();
        let a = attention(&gh, &AttentionShape::mha_prefill(2, 32, 128, 2048, Dtype::Fp16));
        let b = attention(&gh, &AttentionShape::mha_prefill(2, 32, 128, 4096, Dtype::Fp16));
        // Causal prefill flops grow ~4×; runtime should too.
        let r = b.seconds / a.seconds;
        assert!(r > 3.0 && r < 5.0, "ratio {r}");
    }
}
