//! SoA serving-system comparison rows (paper Table II).
//!
//! These are the paper's own citations for CM384 (Huawei CloudMatrix384,
//! [36]) and DS-Prof (DeepSeek's published profile on 96×H800, [35]) —
//! published measurements encoded as constants, exactly as the paper uses
//! them as comparison anchors.

/// One Table II row.
#[derive(Debug, Clone)]
pub struct SoaSystem {
    pub name: &'static str,
    pub chips: u32,
    pub chip_desc: &'static str,
    pub interconnect: &'static str,
    pub hbm_tb_s: f64,
    pub tflops: f64,
    pub tflops_desc: &'static str,
    pub batch_per_chip: u32,
    pub kv_len: u32,
    /// Per-chip decoding throughput, tokens/s.
    pub tokens_per_s_per_chip: f64,
    /// Time per output token, ms.
    pub tpot_ms: f64,
}

impl SoaSystem {
    pub fn cm384() -> Self {
        SoaSystem {
            name: "CM384",
            chips: 384,
            chip_desc: "Ascend 910C",
            interconnect: "Multi-Plane: UBLink 382GB/s, RDMA 400Gbps",
            hbm_tb_s: 3.2,
            tflops: 1504.0,
            tflops_desc: "INT8",
            batch_per_chip: 128,
            kv_len: 4096,
            tokens_per_s_per_chip: 1943.0,
            tpot_ms: 49.4,
        }
    }

    pub fn ds_prof() -> Self {
        SoaSystem {
            name: "DS-Prof",
            chips: 96,
            chip_desc: "Nvidia H800",
            interconnect: "Multi-Plane: NV-Link 160GB/s, RDMA 400Gbps",
            hbm_tb_s: 3.6,
            tflops: 1979.0,
            tflops_desc: "FP8",
            batch_per_chip: 128,
            kv_len: 4096,
            tokens_per_s_per_chip: 2325.0,
            tpot_ms: 50.2,
        }
    }

    /// System-level throughput (tokens/s).
    pub fn system_tokens_per_s(&self) -> f64 {
        self.tokens_per_s_per_chip * self.chips as f64
    }

    /// Peak system TFLOPS.
    pub fn system_tflops(&self) -> f64 {
        self.tflops * self.chips as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_rows_as_published() {
        let cm = SoaSystem::cm384();
        assert_eq!(cm.chips, 384);
        assert!((cm.tokens_per_s_per_chip - 1943.0).abs() < 1e-9);
        let ds = SoaSystem::ds_prof();
        assert_eq!(ds.chips, 96);
        assert!((ds.tpot_ms - 50.2).abs() < 1e-9);
    }

    #[test]
    fn wafer_peak_is_1_5x_lower_than_ds_prof() {
        // The paper's headline: ours operates at 1.5× lower peak system
        // performance than DS-Prof (96×1979 vs 64×1976 TFLOPS).
        let ds = SoaSystem::ds_prof();
        let ours = 64.0 * 1976.0;
        let ratio = ds.system_tflops() / ours;
        assert!((ratio - 1.5).abs() < 0.05, "ratio {ratio}");
    }
}
