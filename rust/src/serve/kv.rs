//! Per-chip KV-cache capacity model for the wafer-scale serving simulator.
//!
//! Budget = HBM capacity − resident weights, spent on MLA latent KV. Under
//! an EP×PP plan the wafer decomposes into `ep` *columns* (one chip per
//! pipeline stage); a user's KV lives on all `pp` chips of its column, each
//! chip holding the `layers/pp` layers of its stage. Because stages hold
//! equal layer counts, any chip of the column saturates at the same user
//! token count — so admission tracks one token budget per column.
//!
//! Weights per chip: routed experts are sharded EP-ways within each stage's
//! layers; everything else (attention, shared experts, gates, dense FFN) is
//! replicated across the EP group but split across pipeline stages.

use crate::arch::config::Dtype;
use crate::multichip::d2d::WaferSystem;
use crate::multichip::parallelism::ParallelismPlan;
use crate::workload::deepseek::DeepSeekConfig;

/// Static KV capacity figures for one (system, model, plan) combination.
#[derive(Debug, Clone, Copy)]
pub struct KvCacheModel {
    /// Bytes one cached token occupies on one chip (its stage's layer share
    /// of the MLA latent + rope cache).
    pub bytes_per_token_per_chip: u64,
    /// Weight bytes resident per chip.
    pub weight_bytes_per_chip: u64,
    /// HBM bytes per chip.
    pub hbm_capacity_bytes: u64,
    /// KV token capacity of one EP column (== one chip's budget divided by
    /// its per-token share).
    pub column_capacity_tokens: u64,
    /// Number of EP columns.
    pub columns: u32,
}

impl KvCacheModel {
    pub fn new(sys: &WaferSystem, ds: &DeepSeekConfig, plan: ParallelismPlan, dtype: Dtype) -> Self {
        let layers_per_stage = (ds.layers as u64).div_ceil(plan.pp as u64);
        let bytes_per_token_per_chip =
            (ds.kv_lora_rank + ds.qk_rope_dim) as u64 * dtype.bytes() * layers_per_stage;

        let moe_layers = (ds.layers - ds.dense_layers) as u64;
        let expert_bytes_total = ds.expert_weight_bytes_per_layer(dtype) * moe_layers;
        let all_bytes = ds.param_count() * dtype.bytes();
        let rest_bytes = all_bytes.saturating_sub(expert_bytes_total);
        let weight_bytes_per_chip = expert_bytes_total / (plan.ep as u64 * plan.pp as u64)
            + rest_bytes / plan.pp as u64;

        let hbm_capacity_bytes = sys.chip.hbm.capacity_bytes();
        let kv_budget = hbm_capacity_bytes.saturating_sub(weight_bytes_per_chip);
        KvCacheModel {
            bytes_per_token_per_chip,
            weight_bytes_per_chip,
            hbm_capacity_bytes,
            column_capacity_tokens: kv_budget / bytes_per_token_per_chip.max(1),
            columns: plan.ep,
        }
    }

    /// Maximum concurrently resident users per column, if each held
    /// `tokens_per_user` KV tokens.
    pub fn users_per_column_at(&self, tokens_per_user: u64) -> u64 {
        self.column_capacity_tokens / tokens_per_user.max(1)
    }
}

/// Mutable KV occupancy of one EP column during simulation. Token counts
/// are `f64` because MTP speculative decode grows context by a fractional
/// expected amount (`tokens_per_iteration`) per iteration.
#[derive(Debug, Clone)]
pub struct KvColumn {
    pub capacity_tokens: f64,
    pub held_tokens: f64,
    /// High-water mark of `held_tokens` over the run.
    pub peak_tokens: f64,
}

impl KvColumn {
    pub fn new(capacity_tokens: u64) -> Self {
        KvColumn { capacity_tokens: capacity_tokens as f64, held_tokens: 0.0, peak_tokens: 0.0 }
    }

    pub fn free_tokens(&self) -> f64 {
        (self.capacity_tokens - self.held_tokens).max(0.0)
    }

    pub fn fits(&self, tokens: f64) -> bool {
        self.held_tokens + tokens <= self.capacity_tokens
    }

    /// Reserve `tokens`; returns false (and reserves nothing) on overflow.
    pub fn reserve(&mut self, tokens: f64) -> bool {
        if !self.fits(tokens) {
            return false;
        }
        self.held_tokens += tokens;
        self.peak_tokens = self.peak_tokens.max(self.held_tokens);
        true
    }

    pub fn release(&mut self, tokens: f64) {
        self.held_tokens = (self.held_tokens - tokens).max(0.0);
    }

    pub fn occupancy_frac(&self) -> f64 {
        if self.capacity_tokens <= 0.0 {
            0.0
        } else {
            self.held_tokens / self.capacity_tokens
        }
    }

    pub fn peak_frac(&self) -> f64 {
        if self.capacity_tokens <= 0.0 {
            0.0
        } else {
            self.peak_tokens / self.capacity_tokens
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> KvCacheModel {
        KvCacheModel::new(
            &WaferSystem::paper(),
            &DeepSeekConfig::v3_671b(),
            ParallelismPlan::new(32, 2),
            Dtype::Fp8,
        )
    }

    #[test]
    fn weights_leave_room_for_kv() {
        let m = model();
        // EP32-PP2 on the 128 GiB chip: weights well under capacity …
        assert!(m.weight_bytes_per_chip < m.hbm_capacity_bytes / 2, "weights {} GiB", m.weight_bytes_per_chip >> 30);
        // … and the leftover holds at least the Table II operating point:
        // 256 users × pp waves at kv 4096+ per chip-column.
        assert!(m.users_per_column_at(4096 + 1024) >= 512, "users {}", m.users_per_column_at(5120));
    }

    #[test]
    fn per_token_bytes_match_mla_layout() {
        let m = model();
        let ds = DeepSeekConfig::v3_671b();
        // (512 latent + 64 rope) × 1 B × ceil(61/2) layers.
        assert_eq!(m.bytes_per_token_per_chip, (512 + 64) * 31);
        assert_eq!(
            m.bytes_per_token_per_chip,
            ds.kv_cache_bytes_per_user_layer(1, Dtype::Fp8) * 31
        );
    }

    #[test]
    fn deeper_pp_shrinks_per_chip_share() {
        let sys = WaferSystem::paper();
        let ds = DeepSeekConfig::v3_671b();
        let a = KvCacheModel::new(&sys, &ds, ParallelismPlan::new(32, 2), Dtype::Fp8);
        let b = KvCacheModel::new(&sys, &ds, ParallelismPlan::new(16, 4), Dtype::Fp8);
        assert!(b.bytes_per_token_per_chip < a.bytes_per_token_per_chip);
        assert!(b.weight_bytes_per_chip < a.weight_bytes_per_chip + (1 << 30));
    }

    #[test]
    fn column_accounting_and_watermark() {
        let mut c = KvColumn::new(1000);
        assert!(c.reserve(600.0));
        assert!(!c.reserve(500.0), "overflow must be refused");
        assert!(c.reserve(400.0));
        assert!((c.occupancy_frac() - 1.0).abs() < 1e-12);
        c.release(700.0);
        assert!((c.held_tokens - 300.0).abs() < 1e-12);
        assert!((c.peak_frac() - 1.0).abs() < 1e-12, "watermark sticks at the peak");
    }
}
