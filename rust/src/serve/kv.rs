//! Per-chip KV-cache capacity model for the wafer-scale serving simulator.
//!
//! Budget = HBM capacity − resident weights, spent on MLA latent KV. Under
//! an EP×PP plan the wafer decomposes into `ep` *columns* (one chip per
//! pipeline stage); a user's KV lives on all `pp` chips of its column, each
//! chip holding the `layers/pp` layers of its stage. Because stages hold
//! equal layer counts, any chip of the column saturates at the same user
//! token count — so admission tracks one token budget per column.
//!
//! Weights per chip: routed experts are sharded EP-ways within each stage's
//! layers; everything else (attention, shared experts, gates, dense FFN) is
//! replicated across the EP group but split across pipeline stages.
//!
//! [`PrefixStore`] adds prefix-cache KV reuse on top of the per-column
//! budget: a token-block trie keyed by `(prefix_id, block_index)` holding
//! the shared leading blocks of system prompts. Hits skip both prefill
//! compute and KV admission for the shared tokens; unreferenced blocks are
//! evicted LRU-from-the-chain-tail under memory pressure.

use std::collections::HashMap;

use crate::arch::config::Dtype;
use crate::multichip::d2d::WaferSystem;
use crate::multichip::parallelism::ParallelismPlan;
use crate::workload::deepseek::DeepSeekConfig;

/// Static KV capacity figures for one (system, model, plan) combination.
#[derive(Debug, Clone, Copy)]
pub struct KvCacheModel {
    /// Bytes one cached token occupies on one chip (its stage's layer share
    /// of the MLA latent + rope cache).
    pub bytes_per_token_per_chip: u64,
    /// Weight bytes resident per chip.
    pub weight_bytes_per_chip: u64,
    /// HBM bytes per chip.
    pub hbm_capacity_bytes: u64,
    /// KV token capacity of one EP column (== one chip's budget divided by
    /// its per-token share).
    pub column_capacity_tokens: u64,
    /// Number of EP columns.
    pub columns: u32,
}

impl KvCacheModel {
    pub fn new(sys: &WaferSystem, ds: &DeepSeekConfig, plan: ParallelismPlan, dtype: Dtype) -> Self {
        Self::with_reserved(sys, ds, plan, dtype, 0)
    }

    /// [`KvCacheModel::new`] with `reserved_bytes` of per-chip HBM carved
    /// out of the KV budget before capacity is computed — the residency cost
    /// of *other* models co-served on the same instance (multi-model shared
    /// pools park every co-resident model's weights on every chip).
    pub fn with_reserved(
        sys: &WaferSystem,
        ds: &DeepSeekConfig,
        plan: ParallelismPlan,
        dtype: Dtype,
        reserved_bytes: u64,
    ) -> Self {
        let layers_per_stage = (ds.layers as u64).div_ceil(plan.pp as u64);
        let bytes_per_token_per_chip =
            (ds.kv_lora_rank + ds.qk_rope_dim) as u64 * dtype.bytes() * layers_per_stage;

        let moe_layers = (ds.layers - ds.dense_layers) as u64;
        let expert_bytes_total = ds.expert_weight_bytes_per_layer(dtype) * moe_layers;
        let all_bytes = ds.param_count() * dtype.bytes();
        let rest_bytes = all_bytes.saturating_sub(expert_bytes_total);
        let weight_bytes_per_chip = expert_bytes_total / (plan.ep as u64 * plan.pp as u64)
            + rest_bytes / plan.pp as u64;

        let hbm_capacity_bytes = sys.chip.hbm.capacity_bytes();
        let kv_budget = hbm_capacity_bytes.saturating_sub(weight_bytes_per_chip).saturating_sub(reserved_bytes);
        KvCacheModel {
            bytes_per_token_per_chip,
            weight_bytes_per_chip,
            hbm_capacity_bytes,
            column_capacity_tokens: kv_budget / bytes_per_token_per_chip.max(1),
            columns: plan.ep,
        }
    }

    /// Maximum concurrently resident users per column, if each held
    /// `tokens_per_user` KV tokens.
    pub fn users_per_column_at(&self, tokens_per_user: u64) -> u64 {
        self.column_capacity_tokens / tokens_per_user.max(1)
    }
}

/// Mutable KV occupancy of one EP column during simulation. Token counts
/// are `f64` because MTP speculative decode grows context by a fractional
/// expected amount (`tokens_per_iteration`) per iteration.
#[derive(Debug, Clone)]
pub struct KvColumn {
    pub capacity_tokens: f64,
    pub held_tokens: f64,
    /// High-water mark of `held_tokens` over the run.
    pub peak_tokens: f64,
}

impl KvColumn {
    pub fn new(capacity_tokens: u64) -> Self {
        KvColumn { capacity_tokens: capacity_tokens as f64, held_tokens: 0.0, peak_tokens: 0.0 }
    }

    pub fn free_tokens(&self) -> f64 {
        (self.capacity_tokens - self.held_tokens).max(0.0)
    }

    pub fn fits(&self, tokens: f64) -> bool {
        self.held_tokens + tokens <= self.capacity_tokens
    }

    /// Reserve `tokens`; returns false (and reserves nothing) on overflow.
    pub fn reserve(&mut self, tokens: f64) -> bool {
        if !self.fits(tokens) {
            return false;
        }
        self.held_tokens += tokens;
        self.peak_tokens = self.peak_tokens.max(self.held_tokens);
        true
    }

    pub fn release(&mut self, tokens: f64) {
        self.held_tokens = (self.held_tokens - tokens).max(0.0);
    }

    pub fn occupancy_frac(&self) -> f64 {
        if self.capacity_tokens <= 0.0 {
            0.0
        } else {
            self.held_tokens / self.capacity_tokens
        }
    }

    pub fn peak_frac(&self) -> f64 {
        if self.capacity_tokens <= 0.0 {
            0.0
        } else {
            self.peak_tokens / self.capacity_tokens
        }
    }
}

/// One resident shared block of a prompt prefix.
#[derive(Debug, Clone, Copy)]
struct PrefixBlock {
    /// Requests currently pinning this block (admitted and not yet
    /// completed/preempted). Zero-ref blocks stay resident for future hits
    /// until evicted under pressure.
    refs: u32,
    /// LRU clock stamp of the last pin/insert touching this block.
    last_use: u64,
}

/// Per-EP-column prefix-cache: a token-block trie over shared prompt
/// prefixes. The `u64` family key is whatever the scheduler's
/// [`PrefixKeying`](crate::serve::scheduler::PrefixKeying) mode supplies —
/// the exact trace-family id, or the hashed-token-block content fingerprint
/// that lets distinct families with identical seeded prefixes share blocks.
/// The path of family key `p` is the block chain `(p, 0), (p, 1), …`;
/// pins always cover a leading chain, so reference counts are non-increasing
/// along it and zero-ref blocks form a suffix — eviction from the chain tail
/// keeps the trie prefix-closed.
///
/// Token accounting: block tokens are charged to the owning [`KvColumn`] by
/// the scheduler (transferred from the inserting request's reservation), so
/// `column.held_tokens` always covers private KV *plus* shared blocks and
/// the capacity invariant needs no second ledger.
#[derive(Debug, Clone, Default)]
pub struct PrefixStore {
    /// Shareable-block granularity in tokens (0 disables the store).
    pub block_tokens: u32,
    blocks: HashMap<(u64, u32), PrefixBlock>,
    clock: u64,
    /// Tokens currently held by resident shared blocks.
    pub shared_tokens: f64,
    /// Blocks evicted under pressure so far.
    pub evictions: u64,
    /// Blocks ever inserted.
    pub inserted_blocks: u64,
}

impl PrefixStore {
    pub fn new(block_tokens: u32) -> Self {
        PrefixStore { block_tokens, ..Default::default() }
    }

    pub fn is_enabled(&self) -> bool {
        self.block_tokens > 0
    }

    /// Whole leading blocks of `prefix_tokens` that are shareable at all.
    pub fn shareable_tokens(&self, prefix_id: u64, prefix_tokens: u32) -> u32 {
        if prefix_id == 0 || !self.is_enabled() {
            return 0;
        }
        (prefix_tokens / self.block_tokens) * self.block_tokens
    }

    /// Longest resident leading chain of `prefix_id`, in tokens, capped at
    /// the shareable part of `prefix_tokens`.
    pub fn probe(&self, prefix_id: u64, prefix_tokens: u32) -> u32 {
        let shareable = self.shareable_tokens(prefix_id, prefix_tokens);
        if shareable == 0 {
            return 0;
        }
        let mut hit = 0u32;
        for b in 0..shareable / self.block_tokens {
            if self.blocks.contains_key(&(prefix_id, b)) {
                hit += 1;
            } else {
                break;
            }
        }
        hit * self.block_tokens
    }

    /// Pin the leading `tokens` (whole blocks, as returned by [`probe`]) of
    /// `prefix_id` for an admitted request. Returns the tokens *actually*
    /// pinned: blocks evicted between the caller's `probe` and this `pin`
    /// (admission pressure can run [`evict_for`] in between) truncate the
    /// pinned chain at the first missing block — pinning past a hole would
    /// break the prefix-closed trie invariant — and the caller must bill
    /// the shortfall as private KV instead of a cache hit.
    ///
    /// [`probe`]: PrefixStore::probe
    /// [`evict_for`]: PrefixStore::evict_for
    #[must_use = "blocks may have been evicted since probe; reconcile the shortfall"]
    pub fn pin(&mut self, prefix_id: u64, tokens: u32) -> u32 {
        if prefix_id == 0 || !self.is_enabled() || tokens == 0 {
            return 0;
        }
        self.clock += 1;
        let mut pinned = 0u32;
        for b in 0..tokens / self.block_tokens {
            match self.blocks.get_mut(&(prefix_id, b)) {
                Some(e) => {
                    e.refs += 1;
                    e.last_use = self.clock;
                    pinned += self.block_tokens;
                }
                None => break,
            }
        }
        pinned
    }

    /// Release the pins of a completed or preempted request that held the
    /// leading `tokens` of `prefix_id`. Blocks stay resident for reuse.
    pub fn unpin(&mut self, prefix_id: u64, tokens: u32) {
        if prefix_id == 0 || !self.is_enabled() || tokens == 0 {
            return;
        }
        for b in 0..tokens / self.block_tokens {
            if let Some(e) = self.blocks.get_mut(&(prefix_id, b)) {
                e.refs = e.refs.saturating_sub(1);
            }
        }
    }

    /// Publish the blocks covering `[from_tokens, to_tokens)` of `prefix_id`
    /// after the inserting request finished prefilling them. Blocks another
    /// request published meanwhile are pinned instead of re-inserted.
    /// Returns the tokens *newly* charged to the store — the scheduler must
    /// transfer exactly that many from the inserter's private reservation.
    pub fn insert(&mut self, prefix_id: u64, from_tokens: u32, to_tokens: u32) -> u32 {
        if prefix_id == 0 || !self.is_enabled() || to_tokens <= from_tokens {
            return 0;
        }
        self.clock += 1;
        let mut newly = 0u32;
        for b in from_tokens / self.block_tokens..to_tokens / self.block_tokens {
            match self.blocks.get_mut(&(prefix_id, b)) {
                Some(e) => {
                    e.refs += 1;
                    e.last_use = self.clock;
                }
                None => {
                    self.blocks.insert(
                        (prefix_id, b),
                        PrefixBlock { refs: 1, last_use: self.clock },
                    );
                    self.inserted_blocks += 1;
                    newly += self.block_tokens;
                }
            }
        }
        self.shared_tokens += newly as f64;
        newly
    }

    /// Evict unreferenced blocks (LRU first, always from a chain's tail so
    /// the trie stays prefix-closed) until at least `deficit` tokens are
    /// freed or nothing evictable remains. Returns the tokens freed — the
    /// caller releases them from the owning column.
    pub fn evict_for(&mut self, deficit: f64) -> f64 {
        let mut freed = 0.0f64;
        while freed < deficit {
            let victim = self
                .blocks
                .iter()
                .filter(|(&(p, b), e)| e.refs == 0 && !self.blocks.contains_key(&(p, b + 1)))
                .min_by_key(|(_, e)| e.last_use)
                .map(|(&k, _)| k);
            let Some(k) = victim else { break };
            self.blocks.remove(&k);
            self.evictions += 1;
            freed += self.block_tokens as f64;
        }
        self.shared_tokens = (self.shared_tokens - freed).max(0.0);
        freed
    }

    /// Resident shared blocks (all prefixes).
    pub fn resident_blocks(&self) -> usize {
        self.blocks.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> KvCacheModel {
        KvCacheModel::new(
            &WaferSystem::paper(),
            &DeepSeekConfig::v3_671b(),
            ParallelismPlan::new(32, 2),
            Dtype::Fp8,
        )
    }

    #[test]
    fn weights_leave_room_for_kv() {
        let m = model();
        // EP32-PP2 on the 128 GiB chip: weights well under capacity …
        assert!(m.weight_bytes_per_chip < m.hbm_capacity_bytes / 2, "weights {} GiB", m.weight_bytes_per_chip >> 30);
        // … and the leftover holds at least the Table II operating point:
        // 256 users × pp waves at kv 4096+ per chip-column.
        assert!(m.users_per_column_at(4096 + 1024) >= 512, "users {}", m.users_per_column_at(5120));
    }

    #[test]
    fn per_token_bytes_match_mla_layout() {
        let m = model();
        let ds = DeepSeekConfig::v3_671b();
        // (512 latent + 64 rope) × 1 B × ceil(61/2) layers.
        assert_eq!(m.bytes_per_token_per_chip, (512 + 64) * 31);
        assert_eq!(
            m.bytes_per_token_per_chip,
            ds.kv_cache_bytes_per_user_layer(1, Dtype::Fp8) * 31
        );
    }

    #[test]
    fn deeper_pp_shrinks_per_chip_share() {
        let sys = WaferSystem::paper();
        let ds = DeepSeekConfig::v3_671b();
        let a = KvCacheModel::new(&sys, &ds, ParallelismPlan::new(32, 2), Dtype::Fp8);
        let b = KvCacheModel::new(&sys, &ds, ParallelismPlan::new(16, 4), Dtype::Fp8);
        assert!(b.bytes_per_token_per_chip < a.bytes_per_token_per_chip);
        assert!(b.weight_bytes_per_chip < a.weight_bytes_per_chip + (1 << 30));
    }

    #[test]
    fn reserved_bytes_shrink_kv_budget() {
        let base = model();
        let reserved = KvCacheModel::with_reserved(
            &WaferSystem::paper(),
            &DeepSeekConfig::v3_671b(),
            ParallelismPlan::new(32, 2),
            Dtype::Fp8,
            8 << 30,
        );
        assert_eq!(reserved.weight_bytes_per_chip, base.weight_bytes_per_chip);
        assert!(reserved.column_capacity_tokens < base.column_capacity_tokens);
        let delta = base.column_capacity_tokens - reserved.column_capacity_tokens;
        let expect = (8u64 << 30) / base.bytes_per_token_per_chip;
        assert!(delta.abs_diff(expect) <= 1, "delta {delta} vs {expect}");
        // Reserving more than the budget degrades to zero capacity, not a panic.
        let starved = KvCacheModel::with_reserved(
            &WaferSystem::paper(),
            &DeepSeekConfig::v3_671b(),
            ParallelismPlan::new(32, 2),
            Dtype::Fp8,
            u64::MAX,
        );
        assert_eq!(starved.column_capacity_tokens, 0);
    }

    #[test]
    fn prefix_store_probe_insert_reuse() {
        let mut s = PrefixStore::new(256);
        assert_eq!(s.probe(1, 1000), 0);
        // Only whole blocks are shareable: 1000 tokens → 3 blocks (768).
        assert_eq!(s.shareable_tokens(1, 1000), 768);
        let newly = s.insert(1, 0, 768);
        assert_eq!(newly, 768);
        assert_eq!(s.resident_blocks(), 3);
        assert_eq!(s.probe(1, 1000), 768);
        // A different prefix id shares nothing.
        assert_eq!(s.probe(2, 1000), 0);
        // Re-inserting the same range charges nothing new.
        assert_eq!(s.insert(1, 0, 768), 0);
        // prefix_id 0 means "no shared prefix".
        assert_eq!(s.probe(0, 4096), 0);
        assert_eq!(s.insert(0, 0, 4096), 0);
    }

    #[test]
    fn prefix_store_eviction_respects_pins_and_chain_order() {
        let mut s = PrefixStore::new(256);
        s.insert(1, 0, 512); // blocks (1,0),(1,1) — pinned by inserter
        s.insert(2, 0, 256); // block (2,0) — pinned by inserter
        // Nothing evictable while every block is pinned.
        assert_eq!(s.evict_for(256.0), 0.0);
        s.unpin(1, 512);
        s.unpin(2, 256);
        // Pin prefix 1 again (a hit): prefix 2 is now the LRU zero-ref.
        assert_eq!(s.pin(1, s.probe(1, 512)), 512);
        let freed = s.evict_for(1.0);
        assert_eq!(freed, 256.0, "LRU zero-ref block (2,0) goes first");
        assert_eq!(s.probe(2, 256), 0);
        assert_eq!(s.probe(1, 512), 512, "pinned chain survives");
        // Unpin and evict everything: tail block (1,1) must go before (1,0).
        s.unpin(1, 512);
        assert_eq!(s.evict_for(256.0), 256.0);
        assert_eq!(s.probe(1, 512), 256, "chain stays prefix-closed");
        assert_eq!(s.evict_for(1e9), 256.0);
        assert_eq!(s.resident_blocks(), 0);
        assert!(s.shared_tokens.abs() < 1e-9);
        assert_eq!(s.evictions, 3);
    }

    #[test]
    fn pin_reports_blocks_evicted_between_probe_and_pin() {
        let mut s = PrefixStore::new(256);
        s.insert(1, 0, 768); // blocks (1,0),(1,1),(1,2)
        s.unpin(1, 768); // all zero-ref: evictable
        let hit = s.probe(1, 768);
        assert_eq!(hit, 768);
        // Admission pressure evicts the chain tail between probe and pin.
        assert_eq!(s.evict_for(1.0), 256.0);
        // pin must report the truncated chain, not silently skip the hole.
        let pinned = s.pin(1, hit);
        assert_eq!(pinned, 512, "one block evicted => 256 tokens short");
        // The surviving leading chain really is pinned now.
        assert_eq!(s.evict_for(1e9), 0.0, "pinned blocks are not evictable");
        s.unpin(1, pinned);
        assert_eq!(s.evict_for(1e9), 512.0);
        // Everything evicted: pin of a stale probe pins nothing.
        assert_eq!(s.pin(1, hit), 0);
        // Disabled store / null prefix keep returning 0.
        assert_eq!(PrefixStore::new(0).pin(1, 512), 0);
        assert_eq!(s.pin(0, 512), 0);
    }

    #[test]
    fn column_accounting_and_watermark() {
        let mut c = KvColumn::new(1000);
        assert!(c.reserve(600.0));
        assert!(!c.reserve(500.0), "overflow must be refused");
        assert!(c.reserve(400.0));
        assert!((c.occupancy_frac() - 1.0).abs() < 1e-12);
        c.release(700.0);
        assert!((c.held_tokens - 300.0).abs() < 1e-12);
        assert!((c.peak_frac() - 1.0).abs() < 1e-12, "watermark sticks at the peak");
    }
}
