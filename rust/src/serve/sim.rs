//! The serving engine: a resumable, steppable iteration-level simulation of
//! continuous batching on the wafer-scale decode model, plus the thin
//! `simulate` driver and the offered-load sweep that produces the goodput /
//! TTFT / TPOT curves.
//!
//! [`ServeEngine`] owns the scheduler, the stage-time model, the clock and
//! the per-request records. `step()` advances exactly one wave iteration
//! (or idle-jumps the clock to the next pending arrival), `inject()`
//! accepts arrivals mid-simulation — the hook the interleaved cluster fleet
//! uses to deliver routed arrivals and disaggregated KV handoffs at their
//! actual event times — and [`ServeEngine::snapshot`] exposes the live
//! state (clock, queue depth, KV occupancy, active users) that live routing
//! policies observe. `simulate()` is a driver loop over the engine and
//! reproduces the pre-engine monolithic loop byte-identically.
//!
//! Time advances one *stage-step* per tick (every pipeline wave advances one
//! stage; the wave wrapping from the last stage completes its iteration).
//! Tick duration is a two-phase model: the decode stage time of the
//! worst-loaded (column, wave) cell from the steady-state decode model
//! ([`DecodeEvaluator`]), plus the co-scheduled chunked-prefill work billed
//! by the *actual prefill dataflow simulation* of the chunk's causal
//! attention shape at its context offset
//! ([`PrefillEngine`](crate::serve::prefill::PrefillEngine)). Both phases
//! are memoized per (plan, dataflow, batch/chunk-bucket, kv/context-bucket)
//! in a shareable [`StageTimeCache`], on top of the kernel-level
//! [`KernelCache`] — the serving loop never re-simulates an identical
//! kernel shape.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::sync::{Arc, Mutex};

use crate::arch::config::{Dtype, SimFidelity};
use crate::metrics::Percentiles;
use crate::multichip::d2d::WaferSystem;
use crate::multichip::parallelism::{AttentionChoice, DecodeEvaluator, KernelCache, ParallelismPlan};
use crate::obs::attrib::assemble_waterfall;
use crate::obs::{AttribExport, AttribPhase, EngineObs, ObsConfig, SeriesRow};
use crate::serve::kv::KvCacheModel;
use crate::serve::prefill::PrefillEngine;
use crate::serve::request::{generate_trace, thin_trace, Request, TraceConfig, TrafficPattern};
use crate::serve::scheduler::{SchedEvent, Scheduler, SchedulerConfig};
use crate::workload::deepseek::DeepSeekConfig;

/// Serving-simulation configuration (system/plan side; traffic comes from
/// the trace).
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    pub plan: ParallelismPlan,
    pub choice: AttentionChoice,
    pub fidelity: SimFidelity,
    pub dtype: Dtype,
    pub scheduler: SchedulerConfig,
    /// Per-user TPOT SLO in ms (paper Table II: 50 ms).
    pub slo_tpot_ms: f64,
    /// TTFT SLO in ms for goodput accounting.
    pub slo_ttft_ms: f64,
    /// Hard tick bound (safety valve; never binds in practice).
    pub max_ticks: u64,
    /// Per-chip HBM bytes reserved before the KV budget is computed — the
    /// weight residency of *other* models co-served on this instance
    /// (multi-model shared pools; 0 for single-model serving).
    pub reserved_hbm_bytes: u64,
}

impl Default for ServeConfig {
    /// The Table II EP32-PP2 wafer operating regime.
    fn default() -> Self {
        ServeConfig {
            plan: ParallelismPlan::new(32, 2),
            choice: AttentionChoice::Flat,
            fidelity: SimFidelity::Analytic,
            dtype: Dtype::Fp8,
            scheduler: SchedulerConfig::default(),
            slo_tpot_ms: 50.0,
            slo_ttft_ms: 2000.0,
            max_ticks: 2_000_000,
            reserved_hbm_bytes: 0,
        }
    }
}

/// Shareable memo of stage times. Keys carry the full system identity
/// (chip fingerprint, D2D parameters, fidelity, dtype) alongside the plan,
/// dataflow and (batch, kv) buckets, so one cache can safely back
/// simulations of *different* wafer configurations — a mutated-in-place
/// ablation system can never alias the original's entries.
#[derive(Clone, Default)]
pub struct StageTimeCache {
    inner: Arc<Mutex<HashMap<String, f64>>>,
    /// Shared (hits, misses) lookup counters — all clones report into one
    /// pair, so the observability snapshot sees the whole process.
    stats: Arc<Mutex<(u64, u64)>>,
}

impl StageTimeCache {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Look up `key`, computing outside the lock on a miss (mirrors
    /// `KernelCache`; keeps the lock discipline inside the type).
    /// Crate-visible so the prefill engine shares one stage-time memo with
    /// the decode path.
    ///
    /// Hit/miss counting is interleaving-independent: a lookup counts as a
    /// miss only if ITS insert created the entry. When n threads race on one
    /// absent key the totals are always 1 miss + (n-1) hits regardless of
    /// scheduling, so the counters (exported into obs metrics) stay
    /// bit-identical across worker counts. Serial totals are unchanged.
    pub(crate) fn get_or_insert_with(&self, key: String, f: impl FnOnce() -> f64) -> f64 {
        if let Some(&s) = self.inner.lock().unwrap().get(&key) {
            self.stats.lock().unwrap().0 += 1;
            return s;
        }
        let s = f();
        match self.inner.lock().unwrap().entry(key) {
            std::collections::hash_map::Entry::Occupied(e) => {
                self.stats.lock().unwrap().0 += 1;
                *e.get()
            }
            std::collections::hash_map::Entry::Vacant(e) => {
                self.stats.lock().unwrap().1 += 1;
                *e.insert(s)
            }
        }
    }

    /// Lookups served from the memo (shared across clones).
    pub fn hits(&self) -> u64 {
        self.stats.lock().unwrap().0
    }

    /// Lookups that had to simulate (shared across clones).
    pub fn misses(&self) -> u64 {
        self.stats.lock().unwrap().1
    }

    /// Snapshot of every entry, sorted by key — the on-disk persistence
    /// format (`coordinator::cache`) wants deterministic output.
    pub fn entries(&self) -> Vec<(String, f64)> {
        let mut v: Vec<(String, f64)> =
            self.inner.lock().unwrap().iter().map(|(k, &s)| (k.clone(), s)).collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    /// Seed one entry (loading a persisted cache). Existing entries win —
    /// a live simulation result is never overwritten by a disk value.
    pub fn seed(&self, key: String, seconds: f64) {
        self.inner.lock().unwrap().entry(key).or_insert(seconds);
    }
}

/// Stage-time oracle for one (system, model, plan, dataflow) combination.
///
/// Tick duration is a two-phase model: a memoized *decode* stage time at
/// the bucketed (batch, kv) operating point, plus the co-scheduled prefill
/// chunk billed by the prefill dataflow simulation at its bucketed
/// (chunk, context) operating point — prefill attention is compute-bound
/// while decode is memory-bound, so neither phase may be billed at the
/// other's cost structure.
struct StageTimes<'a> {
    sys: &'a WaferSystem,
    ds: &'a DeepSeekConfig,
    cfg: ServeConfig,
    ev: DecodeEvaluator,
    shared: StageTimeCache,
    /// Constant cache-key prefix (system fingerprint, D2D, model, fidelity,
    /// dtype, dataflow, plan) — only `|b{}|kv{}` varies per lookup.
    key_prefix: String,
    /// Dataflow-grounded chunk billing (shares both caches).
    prefill: PrefillEngine<'a>,
}

/// Quantize the per-chip user count for the stage-time memo: powers of two
/// in the small range, multiples of 64 above (coarse enough to bound the
/// number of distinct decode evaluations, fine enough that a single
/// prefill chunk doesn't round a light batch up to a saturated one).
/// Rounding *up* keeps the estimate conservative.
fn batch_bucket(users: u64) -> u32 {
    let u = users.clamp(1, 4096) as u32;
    if u <= 64 {
        u.next_power_of_two()
    } else {
        u.div_ceil(64) * 64
    }
}

/// Round a KV/context length up to a 1 KiB-token multiple, capped at the
/// model's maximum context (NOT an arbitrary constant: a 64 KiB cap used to
/// fold every context beyond 65,536 tokens into one memo bucket, billing a
/// 128k-token conversation at 64k-token stage times).
pub fn kv_bucket(tokens: f64, max_context_tokens: u32) -> u32 {
    let cap = (max_context_tokens.max(1024) as u64).div_ceil(1024) * 1024;
    let t = tokens.max(1.0).ceil() as u64;
    (t.div_ceil(1024) * 1024).min(cap) as u32
}

impl<'a> StageTimes<'a> {
    fn new(sys: &'a WaferSystem, ds: &'a DeepSeekConfig, cfg: ServeConfig, kernels: KernelCache, shared: StageTimeCache) -> Self {
        let key_prefix = format!(
            "{}|d2d{}x{}+{:.4e}bps+{:.1e}s|{}L{}d{}|{:?}|{:?}|{}|ep{}pp{}",
            sys.chip.fingerprint(),
            sys.d2d.mesh_x,
            sys.d2d.mesh_y,
            sys.d2d.link_bandwidth_bytes_per_s,
            sys.d2d.hop_latency_s,
            ds.name,
            ds.layers,
            ds.d_model,
            cfg.fidelity,
            cfg.dtype,
            cfg.choice.label(),
            cfg.plan.ep,
            cfg.plan.pp,
        );
        StageTimes {
            sys,
            ds,
            cfg,
            ev: DecodeEvaluator::with_cache(cfg.fidelity, kernels.clone()),
            shared: shared.clone(),
            key_prefix,
            prefill: PrefillEngine::new(
                sys,
                ds,
                cfg.plan,
                cfg.choice,
                cfg.fidelity,
                cfg.dtype,
                kernels,
                shared,
            ),
        }
    }

    /// Memoized decode stage time at a bucketed (users, kv) point.
    fn decode_stage_seconds(&mut self, users: u64, kv_tokens: f64) -> f64 {
        let b = batch_bucket(users);
        let kv = kv_bucket(kv_tokens, self.ds.max_context);
        let key = format!("{}|b{}|kv{}", self.key_prefix, b, kv);
        let (sys, ds, plan, choice, ev) =
            (self.sys, self.ds, self.cfg.plan, self.cfg.choice, &mut self.ev);
        self.shared
            .get_or_insert_with(key, || ev.evaluate(sys, ds, plan, b, kv, choice).stage_seconds)
    }
}

/// Per-request latency record.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RequestRecord {
    pub id: u64,
    pub arrival_s: f64,
    pub prompt_tokens: u32,
    pub output_tokens: u32,
    pub first_token_s: Option<f64>,
    pub completion_s: Option<f64>,
}

impl RequestRecord {
    pub fn ttft_ms(&self) -> Option<f64> {
        self.first_token_s.map(|t| (t - self.arrival_s) * 1e3)
    }

    /// Steady-state per-token latency after the first token.
    pub fn tpot_ms(&self) -> Option<f64> {
        match (self.first_token_s, self.completion_s) {
            (Some(f), Some(c)) if self.output_tokens > 1 => {
                Some((c - f) * 1e3 / (self.output_tokens - 1) as f64)
            }
            _ => None,
        }
    }
}

/// Aggregate outcome of one serving simulation.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeOutcome {
    pub pattern: String,
    pub offered_rps: f64,
    pub horizon_s: f64,
    /// Requests in the trace (offered).
    pub offered: usize,
    /// Requests whose arrival time the simulation reached.
    pub arrived: usize,
    pub completed: usize,
    pub rejected: usize,
    pub in_flight: usize,
    pub queued: usize,
    pub completed_within_slo: usize,
    pub ttft_ms: Percentiles,
    pub tpot_ms: Percentiles,
    pub system_tokens_per_s: f64,
    /// SLO-satisfying completions per second over the horizon.
    pub goodput_rps: f64,
    pub peak_kv_occupancy: f64,
    pub kv_over_capacity: bool,
    pub preemptions: u64,
    /// Shareable prefix tokens served from the prefix cache at admission.
    pub prefix_hit_tokens: u64,
    /// Shareable prefix tokens that had to be prefilled (cold or evicted).
    pub prefix_miss_tokens: u64,
    /// Prefix-cache blocks evicted under memory pressure.
    pub prefix_evictions: u64,
    pub ticks: u64,
    pub elapsed_s: f64,
}

impl ServeOutcome {
    /// Request-conservation identity over the simulated portion of the
    /// trace: everything that arrived is exactly one of completed /
    /// rejected / in-flight / queued.
    pub fn conserves_requests(&self) -> bool {
        self.arrived == self.completed + self.rejected + self.in_flight + self.queued
    }

    /// Fraction of shareable prefix tokens served from the cache
    /// (0 when the trace has no shared prefixes).
    pub fn prefix_hit_rate(&self) -> f64 {
        let total = self.prefix_hit_tokens + self.prefix_miss_tokens;
        if total == 0 {
            0.0
        } else {
            self.prefix_hit_tokens as f64 / total as f64
        }
    }
}

/// A not-yet-enqueued arrival the engine knows about: either preloaded from
/// a trace or injected mid-simulation. Min-heap order: (arrival, seq) —
/// seq is the injection order, so a preloaded (sorted) trace is consumed in
/// exactly the order the pre-engine loop walked it.
#[derive(Debug, Clone, Copy)]
struct PendingArrival {
    arrival_s: f64,
    seq: u64,
    rec: usize,
}

impl PartialEq for PendingArrival {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}
impl Eq for PendingArrival {}
impl PartialOrd for PendingArrival {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for PendingArrival {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.arrival_s.total_cmp(&other.arrival_s).then(self.seq.cmp(&other.seq))
    }
}

/// What one [`ServeEngine::step`] call did.
#[derive(Debug, Clone, PartialEq)]
pub enum Step {
    /// Executed one wave iteration; record indices of first tokens and
    /// completions stamped at the engine's (new) clock.
    Ticked { first_tokens: Vec<usize>, completions: Vec<usize> },
    /// No resident or queued work existed — the clock idle-jumped to the
    /// next pending arrival inside the horizon (no iteration executed).
    Jumped,
    /// Nothing to do: past the horizon / tick budget, or fully drained and
    /// awaiting an injection.
    Idle,
}

impl Step {
    /// True when the engine made progress (a driver loop keeps stepping).
    pub fn advanced(&self) -> bool {
        !matches!(self, Step::Idle)
    }
}

/// What a [`ServeEngine::kill`] abort extracted from the instance, as
/// engine-local record indices. The cluster layer maps these back to fleet
/// trace positions and requeues them through the router.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct KillReport {
    /// `(rec, generated)` of requests that had arrived but were still
    /// waiting in the scheduler queue, in queue order.
    pub queued: Vec<(usize, f64)>,
    /// `(rec, generated)` of resident requests (prefilling or decoding) in
    /// admission order; `generated` is the decode progress they lose.
    pub in_flight: Vec<(usize, f64)>,
    /// `(rec, arrival_s)` of known future arrivals the dead instance will
    /// never reach, in (arrival, injection) order — the cluster layer
    /// requeues each no earlier than its original arrival time.
    pub pending: Vec<(usize, f64)>,
}

/// Observable live state of a [`ServeEngine`] — what a cluster router's
/// live policies (and the fleet event loop) read between steps.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EngineSnapshot {
    pub clock_s: f64,
    pub tick: u64,
    /// Requests waiting in the scheduler queue.
    pub queue_depth: usize,
    /// Requests resident in (column, wave) cells (prefilling or decoding).
    pub active_users: usize,
    /// Highest current KV occupancy fraction across EP columns.
    pub kv_occupancy_frac: f64,
    /// Arrivals the engine has reached (enqueued) so far.
    pub arrived: usize,
    pub completed: usize,
    /// Known future arrivals not yet enqueued.
    pub pending_arrivals: usize,
}

/// Resumable, steppable serving simulation of ONE wafer instance.
///
/// The engine is the unit the interleaved cluster fleet composes: every
/// instance is a `ServeEngine`, the fleet always steps the instance with
/// the smallest local clock, and routed arrivals / KV handoffs are
/// [`inject`](ServeEngine::inject)ed at their event times. A standalone
/// simulation ([`simulate`]) preloads the whole trace and drives `step()`
/// to completion.
pub struct ServeEngine<'a> {
    cfg: ServeConfig,
    horizon_s: f64,
    stage: StageTimes<'a>,
    sched: Scheduler,
    records: Vec<RequestRecord>,
    pending: BinaryHeap<Reverse<PendingArrival>>,
    next_seq: u64,
    clock: f64,
    tick: u64,
    total_tokens: f64,
    kv_violation: bool,
    /// Arrivals enqueued so far (the simulation reached their arrival time).
    arrived: usize,
    completed: usize,
    /// Observability sink — `None` (the default) allocates nothing and
    /// costs one pointer test per hook site.
    obs: Option<Box<EngineObs>>,
}

impl<'a> ServeEngine<'a> {
    /// A fresh engine with no requests; feed it via [`inject`].
    ///
    /// [`inject`]: ServeEngine::inject
    pub fn new(
        sys: &'a WaferSystem,
        ds: &'a DeepSeekConfig,
        cfg: ServeConfig,
        horizon_s: f64,
        kernels: &KernelCache,
        stages: &StageTimeCache,
    ) -> Self {
        let kv = KvCacheModel::with_reserved(sys, ds, cfg.plan, cfg.dtype, cfg.reserved_hbm_bytes);
        let tpi = ds.tokens_per_iteration();
        ServeEngine {
            cfg,
            horizon_s,
            stage: StageTimes::new(sys, ds, cfg, kernels.clone(), stages.clone()),
            sched: Scheduler::new(&[], &kv, cfg.plan.pp, cfg.scheduler, tpi),
            records: Vec::new(),
            pending: BinaryHeap::new(),
            next_seq: 0,
            clock: 0.0,
            tick: 0,
            total_tokens: 0.0,
            kv_violation: false,
            arrived: 0,
            completed: 0,
            obs: None,
        }
    }

    /// Attach an observability sink (trace recorder + gauge sampler +
    /// counters) and enable the scheduler's decision log. Attach before
    /// stepping — spans open when requests are enqueued, so a late attach
    /// only observes lifecycles from that point on.
    pub fn attach_obs(&mut self, obs: EngineObs) {
        self.sched.enable_event_log();
        self.obs = Some(Box::new(obs));
    }

    /// Detach the observability sink, closing still-open spans (in-flight
    /// requests at the horizon) at the current clock with
    /// `outcome=unfinished`. Call before [`ServeEngine::finish`] consumes
    /// the engine.
    pub fn take_obs(&mut self) -> Option<Box<EngineObs>> {
        let mut obs = self.obs.take()?;
        obs.trace.close_open(self.clock);
        Some(obs)
    }

    /// Translate this wave's scheduler decisions into lifecycle span
    /// transitions at `t0` — the wave's start: admission, rejection and
    /// preemption all happen before the wave's stage time elapses.
    fn note_sched_events(&mut self, events: &[SchedEvent], t0: f64) {
        let Some(obs) = self.obs.as_deref_mut() else { return };
        for e in events {
            match *e {
                SchedEvent::Admitted { rec, column, hit_tokens, decode_only } => {
                    let tid = rec as u64 + 1;
                    obs.counters.inc("admitted");
                    obs.trace.end(tid, t0, &[]);
                    let name = if decode_only { "decode" } else { "prefill" };
                    let mut args = vec![("req", self.records[rec].id.to_string()), ("col", column.to_string())];
                    if hit_tokens > 0 {
                        args.push(("prefix_hit_tokens", hit_tokens.to_string()));
                    }
                    obs.trace.begin(tid, name, "lifecycle", t0, args);
                    // Waterfall capture keeps the FIRST admission: later
                    // re-admissions (preemption churn) land in the prefill
                    // segment, not the queue wait.
                    let slot = obs.attrib.slot(rec);
                    if slot.admit_s.is_none() {
                        slot.admit_s = Some(t0);
                        slot.hit_tokens = hit_tokens as u64;
                        slot.prefix_saved_s = if hit_tokens > 0 {
                            self.stage.prefill.chunk_stage_seconds(hit_tokens as u64, hit_tokens as f64)
                        } else {
                            0.0
                        };
                    }
                }
                SchedEvent::Rejected { rec } => {
                    obs.counters.inc("rejected");
                    obs.trace.end(rec as u64 + 1, t0, &[("outcome", "rejected")]);
                }
                SchedEvent::Preempted { rec } => {
                    let tid = rec as u64 + 1;
                    obs.counters.inc("preempted");
                    obs.trace.end(tid, t0, &[("outcome", "preempted")]);
                    obs.trace.begin(tid, "queued", "lifecycle", t0, Vec::new());
                }
            }
        }
    }

    /// Bill this tick's stage seconds to the attribution recorder: one
    /// kernel-level re-walk of the memoized stage per (phase, bucket),
    /// settled against the billed stage time so the per-class seconds
    /// conserve engine busy time exactly. Obs-gated — the unobserved path
    /// never calls this.
    fn bill_attrib(
        &mut self,
        decode_users: u64,
        kv_tokens: f64,
        prefill_tokens: u64,
        prefill_ctx: f64,
        decode_s: f64,
        prefill_s: f64,
    ) {
        let Some(obs) = self.obs.as_deref_mut() else { return };
        let stage = &mut self.stage;
        let b = batch_bucket(decode_users.max(1));
        let kv = kv_bucket(kv_tokens, stage.ds.max_context);
        let (sys, ds, plan, choice) = (stage.sys, stage.ds, stage.cfg.plan, stage.cfg.choice);
        let ev = &mut stage.ev;
        obs.attrib.bill_memoized(AttribPhase::Decode, format!("d|b{b}|kv{kv}"), || {
            let mut a = ev.evaluate_attrib(sys, ds, plan, b, kv, choice);
            a.settle(decode_s);
            a
        });
        if prefill_tokens > 0 {
            let (cb, xb) = stage.prefill.bucketed(prefill_tokens, prefill_ctx);
            let prefill = &stage.prefill;
            obs.attrib.bill_memoized(AttribPhase::Prefill, format!("p|c{cb}|x{xb}"), || {
                let mut a = prefill.evaluate_chunk_attrib(cb, xb);
                a.settle(prefill_s);
                a
            });
        }
    }

    /// Accept a request (at construction or mid-simulation) and return its
    /// record index. The arrival may lie before the current clock — it is
    /// then enqueued at the next tick boundary, exactly as a trace arrival
    /// falling inside a tick would be. Pre-filled requests (disaggregated
    /// KV handoffs) go through this same path; `Request::prefilled` tells
    /// the scheduler to skip prefill on admission.
    pub fn inject(&mut self, r: Request) -> usize {
        let rec = self.sched.push_request(r);
        debug_assert_eq!(rec, self.records.len());
        self.records.push(RequestRecord {
            id: r.id,
            arrival_s: r.arrival_s,
            prompt_tokens: r.prompt_tokens,
            output_tokens: r.output_tokens,
            first_token_s: None,
            completion_s: None,
        });
        self.pending.push(Reverse(PendingArrival { arrival_s: r.arrival_s, seq: self.next_seq, rec }));
        self.next_seq += 1;
        rec
    }

    /// Advance exactly one iteration: enqueue due arrivals, admit/grow the
    /// current wave, bill the tick by the memoized stage-time model, and
    /// execute it — or idle-jump the clock to the next pending arrival.
    /// Returns [`Step::Idle`] when the engine can do nothing (drained,
    /// horizon reached, or awaiting injection).
    pub fn step(&mut self) -> Step {
        if self.clock >= self.horizon_s || self.tick >= self.cfg.max_ticks {
            return Step::Idle;
        }
        while let Some(&Reverse(p)) = self.pending.peek() {
            if p.arrival_s <= self.clock {
                self.sched.enqueue_arrival(p.rec);
                self.arrived += 1;
                if let Some(obs) = self.obs.as_deref_mut() {
                    let tid = p.rec as u64 + 1;
                    let id = self.records[p.rec].id.to_string();
                    obs.counters.inc("arrivals");
                    obs.attrib.slot(p.rec).arrival_s = Some(p.arrival_s);
                    obs.trace.instant(tid, "arrive", "lifecycle", p.arrival_s, vec![("req", id.clone())]);
                    obs.trace.begin(tid, "queued", "lifecycle", p.arrival_s, vec![("req", id)]);
                }
                self.pending.pop();
            } else {
                break;
            }
        }
        if self.sched.active_total() == 0 && self.sched.queue.is_empty() {
            return match self.pending.peek() {
                Some(&Reverse(p)) if p.arrival_s < self.horizon_s => {
                    self.clock = p.arrival_s;
                    Step::Jumped
                }
                _ => Step::Idle,
            };
        }
        let pp = self.cfg.plan.pp.max(1) as u64;
        let w = (self.tick % pp) as usize;
        let t0 = self.clock;
        self.sched.admit_wave(w);
        self.sched.grow_wave(w);
        if self.obs.is_some() {
            let events = self.sched.take_events();
            self.note_sched_events(&events, t0);
        }
        let (decode_users, prefill_tokens) = self.sched.peak_cell_load();
        let prefill_ctx = self.sched.peak_prefill_context() as f64;
        let kv_len = self.sched.max_context_tokens().max(1.0);
        // Two-phase tick billing: the memoized decode stage time plus the
        // co-scheduled prefill chunk at its dataflow-simulated cost. Kept as
        // two terms so the attribution layer can bill each phase separately.
        let decode_s = self.stage.decode_stage_seconds(decode_users.max(1), kv_len);
        let prefill_s = self.stage.prefill.chunk_stage_seconds(prefill_tokens, prefill_ctx);
        self.clock += decode_s + prefill_s;
        if self.obs.is_some() {
            self.bill_attrib(decode_users, kv_len, prefill_tokens, prefill_ctx, decode_s, prefill_s);
        }
        let t1 = self.clock;
        let ev = self.sched.execute_wave(w);
        self.total_tokens += ev.tokens_produced;
        for &rec in &ev.first_tokens {
            let fresh = self.records[rec].first_token_s.is_none();
            self.records[rec].first_token_s.get_or_insert(self.clock);
            if let Some(obs) = self.obs.as_deref_mut() {
                let tid = rec as u64 + 1;
                if fresh {
                    obs.counters.inc("first_tokens");
                    obs.trace.instant(tid, "first_token", "lifecycle", t1, Vec::new());
                }
                obs.attrib.slot(rec).first_s.get_or_insert(t1);
                // Prefill finished (possibly a re-prefill after preemption):
                // the lifecycle transitions into its decode span.
                if obs.trace.open_name(tid) == Some("prefill") {
                    obs.trace.end(tid, t1, &[]);
                    obs.trace.begin(tid, "decode", "lifecycle", t1, Vec::new());
                }
            }
        }
        for &rec in &ev.completions {
            self.records[rec].completion_s = Some(self.clock);
            if self.obs.is_some() {
                // Solo-decode baseline at the final context: what this
                // request's decode would have cost batched alone (shares the
                // stage-time memo; obs-gated).
                let r = self.records[rec];
                let solo = if r.output_tokens > 1 {
                    let ctx = r.prompt_tokens as f64 + r.output_tokens as f64;
                    (r.output_tokens - 1) as f64 * self.stage.decode_stage_seconds(1, ctx)
                } else {
                    0.0
                };
                let obs = self.obs.as_deref_mut().expect("checked above");
                let slot = obs.attrib.slot(rec);
                slot.completion_s = Some(t1);
                slot.solo_decode_s = solo;
                obs.counters.inc("completed");
                obs.trace.end(rec as u64 + 1, t1, &[("outcome", "completed")]);
            }
        }
        self.completed += ev.completions.len();
        self.kv_violation |= self.sched.kv_over_capacity();
        if let Some(obs) = self.obs.as_deref_mut() {
            obs.counters.inc("waves");
            obs.trace.complete(
                0,
                "wave",
                "engine",
                t0,
                t1,
                vec![
                    ("wave", w.to_string()),
                    ("decode_users", decode_users.to_string()),
                    ("prefill_tokens", prefill_tokens.to_string()),
                ],
            );
            if obs.series.ready(t1) {
                let (hit, miss) = (self.sched.prefix_hit_tokens, self.sched.prefix_miss_tokens);
                let total = hit + miss;
                let (util_frac, hbm_bw_frac) = obs.attrib.sample_gauges(t1);
                obs.series.record(SeriesRow {
                    t_s: t1,
                    pid: obs.trace.pid(),
                    queue_depth: self.sched.queue.len(),
                    active_users: self.sched.active_total(),
                    kv_frac: self.sched.kv_occupancy_frac(),
                    kv_col_frac: self.sched.columns.iter().map(|c| c.occupancy_frac()).collect(),
                    prefix_hit_rate: if total == 0 { 0.0 } else { hit as f64 / total as f64 },
                    link_busy_frac: 0.0,
                    edge_busy_frac: Vec::new(),
                    util_frac,
                    hbm_bw_frac,
                    instances_up: 0,
                    requeue_depth: 0,
                });
            }
        }
        self.tick += 1;
        Step::Ticked { first_tokens: ev.first_tokens, completions: ev.completions }
    }

    /// Epoch-bounded stepping for the sharded fleet: advance while the next
    /// event lies strictly before `end_s`, collecting `(completion_time,
    /// record_index)` pairs from every tick. Never crosses the horizon
    /// (`step` gates on it), and — exactly like the serial interleaved loop —
    /// a tick whose next-event time is inside the window may *finish* past
    /// `end_s`: ticks commit atomically, so the overshoot is identical on
    /// every path and the conservative-lookahead barrier stays bit-exact.
    pub fn step_until(&mut self, end_s: f64) -> Vec<(f64, usize)> {
        let mut done = Vec::new();
        while let Some(t) = self.next_event_s() {
            if t >= end_s {
                break;
            }
            if let Step::Ticked { completions, .. } = self.step() {
                let at = self.clock;
                done.extend(completions.into_iter().map(|rec| (at, rec)));
            }
        }
        done
    }

    pub fn clock_s(&self) -> f64 {
        self.clock
    }

    /// Push the local clock forward to `t` (no-op when `t` is in the past).
    /// The multi-model shared-pool fleet uses this to model chip-exclusive
    /// tick serialization: time a co-resident model spent on the chip has
    /// passed for this engine too.
    pub fn advance_clock_to(&mut self, t: f64) {
        if t > self.clock {
            self.clock = t;
        }
    }

    /// When this engine would next do something, on its own clock:
    /// `Some(clock)` while work is resident or queued, the next pending
    /// arrival time while drained but expecting one inside the horizon,
    /// `None` when it is finished (or can only wait for an injection).
    /// The interleaved fleet always steps the engine with the smallest
    /// next-event time.
    pub fn next_event_s(&self) -> Option<f64> {
        if self.clock >= self.horizon_s || self.tick >= self.cfg.max_ticks {
            return None;
        }
        if self.sched.active_total() > 0 || !self.sched.queue.is_empty() {
            return Some(self.clock);
        }
        match self.pending.peek() {
            Some(&Reverse(p)) if p.arrival_s < self.horizon_s => Some(p.arrival_s.max(self.clock)),
            _ => None,
        }
    }

    /// Abort the instance at its current clock — the fault-injection kill
    /// path. Everything resident dies with the HBM: queued and in-flight
    /// requests are extracted (losing all KV and decode progress), known
    /// future arrivals are handed back un-arrived, and the scheduler's
    /// ledger drops to zero. The engine object itself survives, empty —
    /// [`next_event_s`](ServeEngine::next_event_s) returns `None` until new
    /// work is injected, which is exactly how a restarted instance rejoins
    /// the pool. Open lifecycle spans end with `outcome=requeued` (the
    /// cluster layer re-routes every extracted request).
    pub fn kill(&mut self) -> KillReport {
        let (queued, in_flight) = self.sched.abort_all();
        let mut future: Vec<PendingArrival> = self.pending.drain().map(|Reverse(p)| p).collect();
        future.sort();
        // Extracted arrivals un-arrive: they leave this instance's
        // population entirely, so `arrived == completed + rejected +
        // in_flight + queued` keeps holding on the dead (and restarted)
        // engine. The fleet layer counts their re-arrivals elsewhere.
        self.arrived -= queued.len() + in_flight.len();
        if let Some(obs) = self.obs.as_deref_mut() {
            for w in queued.iter().chain(in_flight.iter()) {
                obs.trace.end(w.rec as u64 + 1, self.clock, &[("outcome", "requeued")]);
            }
        }
        KillReport {
            queued: queued.into_iter().map(|w| (w.rec, w.generated)).collect(),
            in_flight: in_flight.into_iter().map(|w| (w.rec, w.generated)).collect(),
            pending: future.into_iter().map(|p| (p.rec, p.arrival_s)).collect(),
        }
    }

    /// Live observable state (see [`EngineSnapshot`]).
    pub fn snapshot(&self) -> EngineSnapshot {
        EngineSnapshot {
            clock_s: self.clock,
            tick: self.tick,
            queue_depth: self.sched.queue.len(),
            active_users: self.sched.active_total(),
            kv_occupancy_frac: self.sched.kv_occupancy_frac(),
            arrived: self.arrived,
            completed: self.completed,
            pending_arrivals: self.pending.len(),
        }
    }

    /// Requests ever injected (the engine's "offered" population).
    pub fn offered(&self) -> usize {
        self.records.len()
    }

    pub fn records(&self) -> &[RequestRecord] {
        &self.records
    }

    /// Finalize into a [`ServeOutcome`] plus the per-request records.
    pub fn finish(self, pattern_label: &str, offered_rps: f64) -> (ServeOutcome, Vec<RequestRecord>) {
        let cfg = self.cfg;
        let horizon_s = self.horizon_s;
        let records = self.records;
        let completed: Vec<&RequestRecord> = records.iter().filter(|r| r.completion_s.is_some()).collect();
        // TTFT samples every request that got a first token — restricting to
        // completed requests would survivorship-bias the overload points,
        // where thousands start but don't finish inside the horizon.
        let ttft: Vec<f64> = records.iter().filter_map(|r| r.ttft_ms()).collect();
        let tpot: Vec<f64> = completed.iter().filter_map(|r| r.tpot_ms()).collect();
        let within_slo = completed
            .iter()
            .filter(|r| {
                r.ttft_ms().is_some_and(|t| t <= cfg.slo_ttft_ms)
                    && r.tpot_ms().map_or(true, |t| t <= cfg.slo_tpot_ms)
            })
            .count();
        let outcome = ServeOutcome {
            pattern: pattern_label.to_string(),
            offered_rps,
            horizon_s,
            offered: records.len(),
            arrived: self.arrived,
            completed: completed.len(),
            rejected: self.sched.rejected.len(),
            in_flight: self.sched.active_total(),
            queued: self.sched.queue.len(),
            completed_within_slo: within_slo,
            ttft_ms: Percentiles::from_values(&ttft),
            tpot_ms: Percentiles::from_values(&tpot),
            system_tokens_per_s: if horizon_s > 0.0 { self.total_tokens / horizon_s } else { 0.0 },
            goodput_rps: if horizon_s > 0.0 { within_slo as f64 / horizon_s } else { 0.0 },
            peak_kv_occupancy: self.sched.peak_kv_occupancy(),
            kv_over_capacity: self.kv_violation,
            preemptions: self.sched.preemptions,
            prefix_hit_tokens: self.sched.prefix_hit_tokens,
            prefix_miss_tokens: self.sched.prefix_miss_tokens,
            prefix_evictions: self.sched.prefix_evictions(),
            ticks: self.tick,
            elapsed_s: self.clock,
        };
        (outcome, records)
    }
}

/// Run one serving simulation of `trace` against the wafer system: a thin
/// driver loop over [`ServeEngine`] (preload the trace, step to quiescence).
/// Stops at `horizon_s` (in-flight work is reported, not drained), so
/// overload manifests as queue growth rather than unbounded simulation
/// time.
#[allow(clippy::too_many_arguments)]
pub fn simulate(
    sys: &WaferSystem,
    ds: &DeepSeekConfig,
    trace: &[Request],
    cfg: &ServeConfig,
    horizon_s: f64,
    pattern_label: &str,
    offered_rps: f64,
    kernels: &KernelCache,
    stages: &StageTimeCache,
) -> (ServeOutcome, Vec<RequestRecord>) {
    let mut engine = ServeEngine::new(sys, ds, *cfg, horizon_s, kernels, stages);
    for r in trace {
        engine.inject(*r);
    }
    while engine.step().advanced() {}
    engine.finish(pattern_label, offered_rps)
}

/// [`simulate`] with an observability sink attached: identical simulation
/// (same outcome and records, bit for bit), plus the run's trace recorder,
/// gauge series and counters. Standalone serving records under pid 0
/// ("serve").
#[allow(clippy::too_many_arguments)]
pub fn simulate_observed(
    sys: &WaferSystem,
    ds: &DeepSeekConfig,
    trace: &[Request],
    cfg: &ServeConfig,
    horizon_s: f64,
    pattern_label: &str,
    offered_rps: f64,
    kernels: &KernelCache,
    stages: &StageTimeCache,
    obs: ObsConfig,
) -> (ServeOutcome, Vec<RequestRecord>, Box<EngineObs>) {
    let mut engine = ServeEngine::new(sys, ds, *cfg, horizon_s, kernels, stages);
    engine.attach_obs(EngineObs::new(0, "serve", obs));
    for r in trace {
        engine.inject(*r);
    }
    while engine.step().advanced() {}
    let sink = engine.take_obs().expect("sink was attached above");
    let (outcome, records) = engine.finish(pattern_label, offered_rps);
    (outcome, records, sink)
}

/// Assemble the run-level attribution export for a standalone serve run:
/// the single engine's recorder plus one waterfall per request that got a
/// first token (entry and completer slots both live on the one engine; no
/// KV link, no requeues).
pub fn assemble_serve_attrib(records: &[RequestRecord], obs: &EngineObs) -> AttribExport {
    let mut x = AttribExport { offered: records.len(), ..AttribExport::default() };
    x.push_engine(0, &obs.attrib);
    for (i, r) in records.iter().enumerate() {
        if let Some(f) = r.first_token_s {
            let slot = obs.attrib.slots.get(i);
            x.waterfalls.push(assemble_waterfall(r.id, r.arrival_s, f, r.completion_s, 0.0, 0, slot, slot));
        }
    }
    x
}

/// Sweep offered load for one traffic pattern. A single master trace at the
/// top rate is generated and *coupled-thinned* down to each lower rate
/// (`serve::request::thin_trace`), so successive points see nested request
/// sets — the load axis is a true refinement, and p99 latencies are
/// monotone in offered load up to bucketing. Each rate simulates on its own
/// `std::thread` worker; the shared caches make results independent of
/// completion order.
#[allow(clippy::too_many_arguments)]
pub fn load_sweep(
    sys: &WaferSystem,
    ds: &DeepSeekConfig,
    cfg: &ServeConfig,
    pattern: TrafficPattern,
    rates_rps: &[f64],
    seed: u64,
    horizon_s: f64,
    kernels: &KernelCache,
    stages: &StageTimeCache,
) -> Vec<ServeOutcome> {
    let max_rate = rates_rps.iter().cloned().fold(0.0f64, f64::max);
    let master = generate_trace(&TraceConfig::new(seed, pattern, max_rate, horizon_s));
    std::thread::scope(|scope| {
        let handles: Vec<_> = rates_rps
            .iter()
            .map(|&rate| {
                let master = &master;
                let kernels = kernels.clone();
                let stages = stages.clone();
                scope.spawn(move || {
                    let trace = thin_trace(master, rate / max_rate, seed ^ 0xC0FF_EE00);
                    let (outcome, _) = simulate(
                        sys,
                        ds,
                        &trace,
                        cfg,
                        horizon_s,
                        pattern.label(),
                        rate,
                        &kernels,
                        &stages,
                    );
                    outcome
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("serve worker panicked")).collect()
    })
}

/// Lowest offered load whose p99 TPOT violates the SLO — the saturation
/// knee of a goodput curve (None if the sweep never saturates). Robust to
/// unsorted or arbitrarily ordered sweep inputs: points are ranked by
/// offered rate before the scan, so a shuffled curve yields the same knee.
pub fn saturation_knee(outcomes: &[ServeOutcome], slo_tpot_ms: f64) -> Option<f64> {
    let mut by_rate: Vec<&ServeOutcome> = outcomes.iter().collect();
    by_rate.sort_by(|a, b| a.offered_rps.total_cmp(&b.offered_rps));
    by_rate
        .into_iter()
        .find(|o| o.completed > 0 && o.tpot_ms.p99 > slo_tpot_ms)
        .map(|o| o.offered_rps)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_trace(rate: f64, horizon: f64, seed: u64) -> Vec<Request> {
        generate_trace(&TraceConfig::new(seed, TrafficPattern::Poisson, rate, horizon))
    }

    fn run(trace: &[Request], horizon: f64) -> ServeOutcome {
        let sys = WaferSystem::paper();
        let ds = DeepSeekConfig::v3_671b();
        let cfg = ServeConfig::default();
        let (o, _) = simulate(
            &sys,
            &ds,
            trace,
            &cfg,
            horizon,
            "poisson",
            0.0,
            &KernelCache::new(),
            &StageTimeCache::new(),
        );
        o
    }

    #[test]
    fn light_load_completes_everything_under_slo() {
        let trace = quick_trace(20.0, 2.0, 3);
        let o = run(&trace, 60.0);
        assert!(o.conserves_requests());
        assert_eq!(o.rejected, 0);
        assert_eq!(o.completed, trace.len(), "light load must fully drain: {o:?}");
        assert!(o.tpot_ms.p99 < 50.0, "p99 TPOT {} at light load", o.tpot_ms.p99);
        assert!(!o.kv_over_capacity);
    }

    #[test]
    fn bucketing_helpers() {
        assert_eq!(batch_bucket(1), 1);
        assert_eq!(batch_bucket(3), 4);
        assert_eq!(batch_bucket(64), 64);
        assert_eq!(batch_bucket(65), 128);
        assert_eq!(batch_bucket(512), 512);
        assert_eq!(batch_bucket(513), 576);
        let max = DeepSeekConfig::v3_671b().max_context;
        assert_eq!(kv_bucket(1.0, max), 1024);
        assert_eq!(kv_bucket(1024.0, max), 1024);
        assert_eq!(kv_bucket(1025.0, max), 2048);
    }

    #[test]
    fn kv_bucket_distinguishes_contexts_beyond_64k() {
        // Regression: the old 1<<16 cap folded every context above 65,536
        // tokens into one bucket. The cap now sits at the model's max
        // context, so two >64k lengths land in distinct buckets.
        let max = DeepSeekConfig::v3_671b().max_context;
        assert_eq!(max, 131_072);
        let a = kv_bucket(70_000.0, max);
        let b = kv_bucket(100_000.0, max);
        assert_ne!(a, b, "distinct >64k contexts must get distinct buckets");
        assert_eq!(a, 70_656);
        assert_eq!(b, 100_352);
        // The cap binds only at the model limit …
        assert_eq!(kv_bucket(1e9, max), 131_072);
        // … and degenerate caps still bucket sanely.
        assert_eq!(kv_bucket(4096.0, 0), 1024);
    }

    #[test]
    fn stage_cache_is_shared_between_runs() {
        let trace = quick_trace(20.0, 1.0, 5);
        let sys = WaferSystem::paper();
        let ds = DeepSeekConfig::v3_671b();
        let cfg = ServeConfig::default();
        let kernels = KernelCache::new();
        let stages = StageTimeCache::new();
        let (a, _) = simulate(&sys, &ds, &trace, &cfg, 30.0, "p", 20.0, &kernels, &stages);
        let n = stages.len();
        assert!(n > 0);
        let k = kernels.len();
        let (b, _) = simulate(&sys, &ds, &trace, &cfg, 30.0, "p", 20.0, &kernels, &stages);
        assert_eq!(a, b, "identical runs over shared caches must agree exactly");
        assert_eq!(stages.len(), n, "second run reuses every stage time");
        assert_eq!(kernels.len(), k, "second run reuses every kernel simulation");
    }

    #[test]
    fn shared_cache_never_aliases_across_systems() {
        // Two systems with identical chips but 6× different D2D bandwidth,
        // simulated over ONE shared cache pair: the slow system must not
        // inherit the fast system's stage times (kernel entries may be
        // legitimately shared — C2C lives outside the kernel simulations).
        let trace = quick_trace(20.0, 1.0, 6);
        let ds = DeepSeekConfig::v3_671b();
        let cfg = ServeConfig::default();
        let kernels = KernelCache::new();
        let stages = StageTimeCache::new();
        let fast = WaferSystem::paper();
        let slow = WaferSystem::paper_nvlink_class();
        let (a, _) = simulate(&fast, &ds, &trace, &cfg, 30.0, "p", 20.0, &kernels, &stages);
        let (b, _) = simulate(&slow, &ds, &trace, &cfg, 30.0, "p", 20.0, &kernels, &stages);
        assert!(
            b.tpot_ms.p50 > a.tpot_ms.p50,
            "slow D2D must show higher TPOT: {} vs {}",
            b.tpot_ms.p50,
            a.tpot_ms.p50
        );
        // A fresh-cache run of the slow system agrees exactly with the
        // shared-cache run — the cache changed nothing but speed.
        let (b2, _) =
            simulate(&slow, &ds, &trace, &cfg, 30.0, "p", 20.0, &KernelCache::new(), &StageTimeCache::new());
        assert_eq!(b, b2);
    }

    #[test]
    fn engine_step_driver_matches_manual_stepping() {
        // The simulate() driver and a hand-rolled step loop over a second
        // engine agree exactly — step() IS the simulation.
        let trace = quick_trace(30.0, 1.5, 9);
        let sys = WaferSystem::paper();
        let ds = DeepSeekConfig::v3_671b();
        let cfg = ServeConfig::default();
        let kernels = KernelCache::new();
        let stages = StageTimeCache::new();
        let (a, ra) = simulate(&sys, &ds, &trace, &cfg, 30.0, "p", 30.0, &kernels, &stages);
        let mut eng = ServeEngine::new(&sys, &ds, cfg, 30.0, &kernels, &stages);
        for r in &trace {
            eng.inject(*r);
        }
        let mut ticks = 0u64;
        let mut jumps = 0u64;
        loop {
            match eng.step() {
                Step::Ticked { .. } => ticks += 1,
                Step::Jumped => jumps += 1,
                Step::Idle => break,
            }
        }
        assert!(jumps >= 1, "a sparse trace must exercise the idle-jump path");
        assert_eq!(ticks, a.ticks);
        let (b, rb) = eng.finish("p", 30.0);
        assert_eq!(a, b);
        assert_eq!(ra, rb);
    }

    #[test]
    fn engine_inject_mid_simulation_is_processed() {
        // Drain a small preloaded trace, then inject a request mid-flight:
        // the engine resumes, serves it, and the snapshot tracks the whole
        // life cycle.
        let sys = WaferSystem::paper();
        let ds = DeepSeekConfig::v3_671b();
        let cfg = ServeConfig::default();
        let kernels = KernelCache::new();
        let stages = StageTimeCache::new();
        let mut eng = ServeEngine::new(&sys, &ds, cfg, 60.0, &kernels, &stages);
        eng.inject(Request::new(0, 0.0, 256, 4));
        while eng.step().advanced() {}
        let s = eng.snapshot();
        assert_eq!(s.completed, 1);
        assert_eq!(s.active_users, 0);
        assert_eq!(s.queue_depth, 0);
        assert_eq!(s.pending_arrivals, 0);
        let drained_clock = s.clock_s;
        assert_eq!(eng.step(), Step::Idle, "a drained engine idles awaiting injection");

        // Inject one request in the engine's past and one in its future.
        let past = eng.inject(Request::new(1, drained_clock * 0.5, 128, 4));
        let future = eng.inject(Request::new(2, drained_clock + 1.0, 128, 4));
        while eng.step().advanced() {}
        let s = eng.snapshot();
        assert_eq!(s.completed, 3, "both injected requests must complete");
        assert!(s.clock_s > drained_clock + 1.0);
        let recs = eng.records();
        assert!(recs[past].completion_s.is_some());
        let f = recs[future].first_token_s.unwrap();
        assert!(f > drained_clock + 1.0, "future arrival cannot start before its arrival time");
        // A pre-filled injection (disaggregated handoff) completes without
        // re-emitting its first token.
        let prefilled = eng.inject(Request {
            prefilled: true,
            ..Request::new(3, eng.clock_s() + 0.1, 512, 8)
        });
        while eng.step().advanced() {}
        let recs = eng.records();
        assert!(recs[prefilled].completion_s.is_some());
        assert!(recs[prefilled].first_token_s.is_none(), "token #1 was emitted upstream");
    }

    #[test]
    fn engine_next_event_and_advance_clock() {
        let sys = WaferSystem::paper();
        let ds = DeepSeekConfig::v3_671b();
        let cfg = ServeConfig::default();
        let kernels = KernelCache::new();
        let stages = StageTimeCache::new();
        let mut eng = ServeEngine::new(&sys, &ds, cfg, 10.0, &kernels, &stages);
        assert_eq!(eng.next_event_s(), None, "an empty engine has no next event");
        eng.inject(Request::new(0, 2.0, 128, 4));
        assert_eq!(eng.next_event_s(), Some(2.0), "drained engine waits for its pending arrival");
        assert_eq!(eng.step(), Step::Jumped);
        assert_eq!(eng.clock_s(), 2.0);
        assert_eq!(eng.next_event_s(), Some(2.0), "runnable engine fires at its own clock");
        // Chip-exclusive serialization: the co-resident model held the chip
        // until t=3; this engine's next tick cannot start earlier.
        eng.advance_clock_to(3.0);
        assert_eq!(eng.next_event_s(), Some(3.0));
        eng.advance_clock_to(2.5);
        assert_eq!(eng.clock_s(), 3.0, "advance_clock_to never rewinds");
        while eng.step().advanced() {}
        assert!(eng.snapshot().completed == 1);
        // An arrival pinned beyond the horizon is never an event.
        eng.inject(Request::new(1, 11.0, 128, 4));
        assert_eq!(eng.next_event_s(), None);
        assert_eq!(eng.step(), Step::Idle);
        let (o, _) = eng.finish("p", 0.0);
        assert_eq!(o.offered, 2);
        assert_eq!(o.arrived, 1, "the beyond-horizon arrival is offered but never arrives");
    }

    #[test]
    fn engine_kill_extracts_work_and_leaves_a_restartable_shell() {
        let sys = WaferSystem::paper();
        let ds = DeepSeekConfig::v3_671b();
        let cfg = ServeConfig::default();
        let kernels = KernelCache::new();
        let stages = StageTimeCache::new();
        let mut eng = ServeEngine::new(&sys, &ds, cfg, 60.0, &kernels, &stages);
        // One request decoding, one queued far in the future.
        let running = eng.inject(Request::new(0, 0.0, 256, 4000));
        let future = eng.inject(Request::new(1, 50.0, 128, 4));
        for _ in 0..5 {
            assert!(eng.step().advanced());
        }
        let t_kill = eng.clock_s();
        assert_eq!(eng.snapshot().active_users, 1);
        let report = eng.kill();
        assert_eq!(report.in_flight.len(), 1);
        assert_eq!(report.in_flight[0].0, running);
        assert!(report.in_flight[0].1 >= 0.0);
        assert_eq!(report.pending, vec![(future, 50.0)]);
        assert!(report.queued.is_empty());
        // The shell is empty and inert until something is injected …
        let s = eng.snapshot();
        assert_eq!((s.active_users, s.queue_depth, s.pending_arrivals), (0, 0, 0));
        assert_eq!(eng.next_event_s(), None);
        assert_eq!(eng.step(), Step::Idle);
        assert_eq!(eng.clock_s(), t_kill, "a dead engine's clock does not move");
        // … and a post-restart injection brings it back to life.
        let reborn = eng.inject(Request::new(2, t_kill + 1.0, 128, 4));
        while eng.step().advanced() {}
        assert!(eng.records()[reborn].completion_s.is_some());
        assert!(eng.records()[running].completion_s.is_none(), "killed work never completed here");
        let (o, _) = eng.finish("p", 0.0);
        assert_eq!(o.completed, 1);
        assert_eq!(o.in_flight + o.queued, 0);
        // Kill un-arrived the extracted request, so the engine-local
        // conservation identity survives the abort.
        assert_eq!(o.arrived, 1);
        assert!(o.conserves_requests());
    }

    #[test]
    fn saturation_knee_detection() {
        let mk = |rate: f64, p99: f64| {
            let mut o = run(&[], 1.0);
            o.offered_rps = rate;
            o.completed = 10;
            o.tpot_ms.p99 = p99;
            o
        };
        let curve = vec![mk(100.0, 12.0), mk(200.0, 30.0), mk(400.0, 61.0), mk(800.0, 90.0)];
        assert_eq!(saturation_knee(&curve, 50.0), Some(400.0));
        assert_eq!(saturation_knee(&curve[..2], 50.0), None);
        // Robustness: a shuffled curve yields the same knee — the scan
        // ranks by offered rate instead of trusting input order.
        let shuffled = vec![mk(800.0, 90.0), mk(100.0, 12.0), mk(400.0, 61.0), mk(200.0, 30.0)];
        assert_eq!(saturation_knee(&shuffled, 50.0), Some(400.0));
        // A violating point with no completions is skipped, not reported.
        let mut ghost = mk(50.0, 99.0);
        ghost.completed = 0;
        let with_ghost = vec![ghost, mk(100.0, 12.0), mk(400.0, 61.0)];
        assert_eq!(saturation_knee(&with_ghost, 50.0), Some(400.0));
    }
}
