//! Request and trace model for the serving simulator: seeded synthetic
//! arrival processes (Poisson, bursty on/off, diurnal) with mixed
//! prompt/output-length distributions.
//!
//! All generators are deterministic functions of a [`SplitMix64`] seed, so a
//! trace — and therefore a whole serving simulation — replays bit-exactly.
//! Non-homogeneous arrivals use Lewis–Shedler thinning of a homogeneous
//! Poisson process at the peak rate; [`thin_trace`] additionally supports
//! *coupled* subsampling (per-request uniforms), so the trace at offered
//! load `r` is a superset of the trace at any `r' < r` — the property the
//! load-monotonicity invariants lean on.

use crate::util::SplitMix64;

/// One inference request of the serving workload.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Request {
    pub id: u64,
    /// Arrival time in seconds from trace start.
    pub arrival_s: f64,
    /// Prompt (prefill) length in tokens.
    pub prompt_tokens: u32,
    /// Requested output (decode) length in tokens.
    pub output_tokens: u32,
    /// Shared-prompt family this request belongs to (a seeded system-prompt
    /// id; 0 = unique prompt, nothing shareable). Every request with the
    /// same non-zero `prefix_id` shares the identical leading
    /// `prefix_tokens` of its prompt — the prefix cache's reuse key.
    pub prefix_id: u64,
    /// Leading prompt tokens shared by the whole `prefix_id` family
    /// (`<= prompt_tokens`; 0 when `prefix_id` is 0).
    pub prefix_tokens: u32,
    /// Content fingerprint of the shared prefix — a stand-in for hashing the
    /// actual token blocks (0 when `prefix_id` is 0 or the content is
    /// unknown). Distinct families whose seeded prefix *content* coincides
    /// carry the same hash, which the `TokenHash` prefix-keying mode uses to
    /// share cache blocks across families.
    pub prefix_hash: u64,
    /// Scheduling priority class, 0 = most urgent (the `Priority` queue
    /// policy orders on this; FCFS/SJF ignore it).
    pub priority: u8,
    /// True when the prompt's KV arrives already computed — a disaggregated
    /// decode-pool arrival after a prefill-pool handoff. Admission skips
    /// prefill entirely and the first output token was already emitted
    /// upstream (the cluster layer constructs these; traces never do).
    pub prefilled: bool,
}

impl Request {
    /// A plain request with no shared prefix and default priority.
    pub fn new(id: u64, arrival_s: f64, prompt_tokens: u32, output_tokens: u32) -> Self {
        Request {
            id,
            arrival_s,
            prompt_tokens,
            output_tokens,
            prefix_id: 0,
            prefix_tokens: 0,
            prefix_hash: 0,
            priority: 0,
            prefilled: false,
        }
    }

    /// Total KV footprint in tokens once fully decoded.
    pub fn total_tokens(&self) -> u64 {
        self.prompt_tokens as u64 + self.output_tokens as u64
    }
}

/// Arrival-process shape; every variant has unit mean intensity so
/// [`TraceConfig::rate_rps`] is always the *average* offered load.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TrafficPattern {
    /// Homogeneous Poisson arrivals.
    Poisson,
    /// On/off modulated Poisson: a fraction `duty` of each `period_s` runs
    /// at `burst_factor`× the off-phase intensity.
    Bursty { period_s: f64, duty: f64, burst_factor: f64 },
    /// Sinusoidal intensity with trough at `trough_factor`× the mean
    /// (0 < trough_factor ≤ 1), period `period_s`.
    Diurnal { period_s: f64, trough_factor: f64 },
}

impl TrafficPattern {
    pub fn label(&self) -> &'static str {
        match self {
            TrafficPattern::Poisson => "poisson",
            TrafficPattern::Bursty { .. } => "bursty",
            TrafficPattern::Diurnal { .. } => "diurnal",
        }
    }

    /// Intensity multiplier at time `t` (mean 1 over a period).
    pub fn intensity(&self, t: f64) -> f64 {
        match *self {
            TrafficPattern::Poisson => 1.0,
            TrafficPattern::Bursty { period_s, duty, burst_factor } => {
                // lo/hi chosen so duty·hi + (1−duty)·lo = 1, hi = bf·lo.
                let lo = 1.0 / (duty * burst_factor + (1.0 - duty));
                let phase = (t / period_s).fract();
                if phase < duty {
                    burst_factor * lo
                } else {
                    lo
                }
            }
            TrafficPattern::Diurnal { period_s, trough_factor } => {
                let amp = 1.0 - trough_factor.clamp(0.0, 1.0);
                1.0 + amp * (2.0 * std::f64::consts::PI * t / period_s).sin()
            }
        }
    }

    /// Peak intensity multiplier (thinning envelope).
    fn peak_intensity(&self) -> f64 {
        match *self {
            TrafficPattern::Poisson => 1.0,
            TrafficPattern::Bursty { duty, burst_factor, .. } => {
                burst_factor / (duty * burst_factor + (1.0 - duty))
            }
            TrafficPattern::Diurnal { trough_factor, .. } => 2.0 - trough_factor.clamp(0.0, 1.0),
        }
    }
}

/// Prompt/output length mixture: exponential lengths clamped to
/// [min, max] — a heavy-ish right tail without needing special functions.
#[derive(Debug, Clone, Copy)]
pub struct LengthProfile {
    pub prompt_mean: f64,
    pub prompt_min: u32,
    pub prompt_max: u32,
    pub output_mean: f64,
    pub output_min: u32,
    pub output_max: u32,
}

impl LengthProfile {
    /// Chat-like default: ~512-token prompts (≤4096), ~192-token outputs
    /// (≤1024).
    pub fn chat() -> Self {
        LengthProfile {
            prompt_mean: 512.0,
            prompt_min: 32,
            prompt_max: 4096,
            output_mean: 192.0,
            output_min: 8,
            output_max: 1024,
        }
    }

    /// Short-prompt, long-generation mix (agentic decode-heavy traffic).
    pub fn decode_heavy() -> Self {
        LengthProfile {
            prompt_mean: 128.0,
            prompt_min: 16,
            prompt_max: 1024,
            output_mean: 512.0,
            output_min: 32,
            output_max: 2048,
        }
    }

    fn sample(&self, rng: &mut SplitMix64, mean: f64, min: u32, max: u32) -> u32 {
        let u = rng.next_f64();
        let x = -mean * (1.0 - u).ln();
        (x.round() as u64).clamp(min as u64, max as u64) as u32
    }

    pub fn sample_prompt(&self, rng: &mut SplitMix64) -> u32 {
        self.sample(rng, self.prompt_mean, self.prompt_min, self.prompt_max)
    }

    pub fn sample_output(&self, rng: &mut SplitMix64) -> u32 {
        self.sample(rng, self.output_mean, self.output_min, self.output_max)
    }
}

/// Shared-prompt population: which fraction of requests carry one of a
/// small set of seeded system prompts, and how long those prompts are.
/// Per-prefix lengths are a pure function of (trace seed, prefix id), so
/// every request of a family reports the identical `prefix_tokens`.
#[derive(Debug, Clone, Copy)]
pub struct PrefixProfile {
    /// Probability a request carries a shared prefix at all.
    pub share_prob: f64,
    /// Number of distinct shared prefixes (system prompts) in rotation.
    pub num_prefixes: u32,
    /// Distinct underlying prefix *contents* the families map onto (seeded).
    /// 0 (the default) means every family has unique content; a value below
    /// `num_prefixes` aliases several families onto one content — the
    /// population where token-hash prefix keying strictly beats exact-id
    /// keying, because cross-family hits become possible.
    pub content_classes: u32,
    /// Prefix length distribution (exponential, clamped like
    /// [`LengthProfile`]).
    pub prefix_mean: f64,
    pub prefix_min: u32,
    pub prefix_max: u32,
}

impl PrefixProfile {
    /// No shared prefixes (the default — traces behave exactly as before).
    pub fn none() -> Self {
        PrefixProfile {
            share_prob: 0.0,
            num_prefixes: 0,
            content_classes: 0,
            prefix_mean: 0.0,
            prefix_min: 0,
            prefix_max: 0,
        }
    }

    /// Agentic/RAG-like traffic: 70% of requests reuse one of 8 system
    /// prompts of ~1k tokens (≤4k), every family with unique content.
    pub fn agentic() -> Self {
        PrefixProfile {
            share_prob: 0.7,
            num_prefixes: 8,
            content_classes: 0,
            prefix_mean: 1024.0,
            prefix_min: 256,
            prefix_max: 4096,
        }
    }

    /// Agentic traffic whose 8 families alias onto 3 underlying contents
    /// (forked deployments of the same system prompt) — exact-id keying
    /// misses the cross-family reuse that token-hash keying captures.
    pub fn agentic_aliased() -> Self {
        PrefixProfile { content_classes: 3, ..Self::agentic() }
    }

    /// Underlying content id of family `id`: with aliasing enabled the
    /// family maps onto one of `content_classes` seeded contents, otherwise
    /// each family is its own content (id 0 stays 0 — no shared prefix).
    pub fn content_of(&self, seed: u64, id: u64) -> u64 {
        if self.content_classes == 0 || id == 0 {
            return id;
        }
        let mut rng = SplitMix64::new(seed ^ 0x51AE_D00D_BEEF_0005 ^ id.wrapping_mul(0xD6E8_FEB8_6659_FD93));
        1 + rng.next_range(self.content_classes as u64)
    }

    /// Deterministic length of prefix `id` under trace seed `seed` — keyed
    /// on the *content*, so aliased families report identical lengths (they
    /// genuinely share their leading tokens).
    pub fn prefix_len(&self, seed: u64, id: u64) -> u32 {
        let c = self.content_of(seed, id);
        let mut rng = SplitMix64::new(seed ^ 0x9D5F_AB12_77C0_0004 ^ c.wrapping_mul(0xA24B_AED4_963E_E407));
        let u = rng.next_f64();
        let x = -self.prefix_mean * (1.0 - u).ln();
        (x.round() as u64).clamp(self.prefix_min as u64, self.prefix_max as u64) as u32
    }

    /// Deterministic nonzero 64-bit fingerprint of family `id`'s prefix
    /// content under trace seed `seed` (0 for id 0). Aliased families share
    /// the fingerprint — the `TokenHash` keying mode's block key.
    pub fn prefix_hash(&self, seed: u64, id: u64) -> u64 {
        let c = self.content_of(seed, id);
        if c == 0 {
            return 0;
        }
        SplitMix64::new(seed ^ 0x7A5B_10C5_4A5B_0006 ^ c.wrapping_mul(0x2545_F491_4F6C_DD1D)).next_u64() | 1
    }
}

/// Everything needed to synthesize a trace deterministically.
#[derive(Debug, Clone, Copy)]
pub struct TraceConfig {
    pub seed: u64,
    pub pattern: TrafficPattern,
    /// Mean offered load in requests/second.
    pub rate_rps: f64,
    /// Trace horizon in seconds (arrivals beyond it are not generated).
    pub horizon_s: f64,
    pub lengths: LengthProfile,
    pub prefixes: PrefixProfile,
}

impl TraceConfig {
    pub fn new(seed: u64, pattern: TrafficPattern, rate_rps: f64, horizon_s: f64) -> Self {
        TraceConfig {
            seed,
            pattern,
            rate_rps,
            horizon_s,
            lengths: LengthProfile::chat(),
            prefixes: PrefixProfile::none(),
        }
    }

    /// Builder-style override of the shared-prefix population.
    pub fn with_prefixes(mut self, prefixes: PrefixProfile) -> Self {
        self.prefixes = prefixes;
        self
    }
}

/// Generate the arrival trace for `cfg` (sorted by arrival time).
pub fn generate_trace(cfg: &TraceConfig) -> Vec<Request> {
    let mut arr_rng = SplitMix64::new(cfg.seed ^ 0xA11C_E5A1_7EAF_0001);
    let mut len_rng = SplitMix64::new(cfg.seed ^ 0x5EED_0F0F_1E15_0002);
    // Prefix/priority draws use their own stream so enabling shared
    // prefixes never perturbs the arrival or length sequences.
    let mut pfx_rng = SplitMix64::new(cfg.seed ^ 0x10CA_70B5_0B0E_0003);
    let peak_rate = cfg.rate_rps * cfg.pattern.peak_intensity();
    let mut out = Vec::new();
    let mut t = 0.0f64;
    let mut id = 0u64;
    if peak_rate <= 0.0 {
        return out;
    }
    loop {
        // Exponential inter-arrival at the envelope rate …
        t += -(1.0 - arr_rng.next_f64()).ln() / peak_rate;
        if t >= cfg.horizon_s {
            break;
        }
        // … thinned down to the instantaneous intensity.
        let accept = arr_rng.next_f64() * cfg.pattern.peak_intensity() < cfg.pattern.intensity(t);
        // Lengths / prefixes / priorities are always drawn (accepted or
        // not) so the accepted subsequence stays aligned across nearby
        // configurations.
        let prompt = cfg.lengths.sample_prompt(&mut len_rng);
        let output = cfg.lengths.sample_output(&mut len_rng);
        let shared = pfx_rng.next_f64() < cfg.prefixes.share_prob && cfg.prefixes.num_prefixes > 0;
        let family = pfx_rng.next_range(cfg.prefixes.num_prefixes.max(1) as u64);
        let priority = (pfx_rng.next_range(4)) as u8;
        if accept {
            let (prefix_id, prefix_tokens, prefix_hash, prompt_tokens) = if shared {
                let pid = family + 1;
                let plen = cfg.prefixes.prefix_len(cfg.seed, pid);
                // The shared prefix prepends the request's own prompt, so
                // families genuinely share their leading tokens.
                let total = (plen as u64 + prompt as u64).min(u32::MAX as u64) as u32;
                (pid, plen, cfg.prefixes.prefix_hash(cfg.seed, pid), total)
            } else {
                (0, 0, 0, prompt)
            };
            out.push(Request {
                id,
                arrival_s: t,
                prompt_tokens,
                output_tokens: output,
                prefix_id,
                prefix_tokens,
                prefix_hash,
                priority,
                prefilled: false,
            });
            id += 1;
        }
    }
    out
}

/// Coupled subsampling: keep each request iff its per-id uniform is below
/// `keep_fraction`. The kept set at a higher fraction is a strict superset
/// of the kept set at a lower fraction (same `seed`), which makes offered
/// load comparable across points of a load sweep.
pub fn thin_trace(trace: &[Request], keep_fraction: f64, seed: u64) -> Vec<Request> {
    if keep_fraction >= 1.0 {
        return trace.to_vec();
    }
    trace
        .iter()
        .filter(|r| {
            let mut rng = SplitMix64::new(seed ^ r.id.wrapping_mul(0x9E37_79B9_7F4A_7C15));
            rng.next_f64() < keep_fraction
        })
        .copied()
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_deterministic_and_sorted() {
        let cfg = TraceConfig::new(7, TrafficPattern::Poisson, 100.0, 10.0);
        let a = generate_trace(&cfg);
        let b = generate_trace(&cfg);
        assert_eq!(a, b);
        assert!(!a.is_empty());
        for w in a.windows(2) {
            assert!(w[1].arrival_s >= w[0].arrival_s);
        }
        for r in &a {
            assert!(r.arrival_s < 10.0);
            assert!(r.prompt_tokens >= 32 && r.prompt_tokens <= 4096);
            assert!(r.output_tokens >= 8 && r.output_tokens <= 1024);
        }
    }

    #[test]
    fn poisson_rate_is_respected() {
        let cfg = TraceConfig::new(11, TrafficPattern::Poisson, 200.0, 50.0);
        let t = generate_trace(&cfg);
        let rate = t.len() as f64 / 50.0;
        assert!((rate - 200.0).abs() < 20.0, "empirical rate {rate}");
    }

    #[test]
    fn modulated_patterns_hold_mean_rate() {
        for pattern in [
            TrafficPattern::Bursty { period_s: 5.0, duty: 0.3, burst_factor: 4.0 },
            TrafficPattern::Diurnal { period_s: 10.0, trough_factor: 0.2 },
        ] {
            let cfg = TraceConfig::new(13, pattern, 200.0, 50.0);
            let t = generate_trace(&cfg);
            let rate = t.len() as f64 / 50.0;
            assert!((rate - 200.0).abs() < 25.0, "{}: empirical rate {rate}", pattern.label());
        }
    }

    #[test]
    fn bursty_concentrates_arrivals_in_duty_window() {
        let pattern = TrafficPattern::Bursty { period_s: 5.0, duty: 0.2, burst_factor: 8.0 };
        let cfg = TraceConfig::new(17, pattern, 100.0, 50.0);
        let t = generate_trace(&cfg);
        let in_burst = t.iter().filter(|r| (r.arrival_s / 5.0).fract() < 0.2).count();
        let frac = in_burst as f64 / t.len() as f64;
        // duty·hi = 0.2·8/(0.2·8+0.8) = 2/3 of arrivals in 20% of the time.
        assert!(frac > 0.5, "burst fraction {frac}");
    }

    #[test]
    fn shared_prefixes_are_consistent_within_a_family() {
        let cfg = TraceConfig::new(31, TrafficPattern::Poisson, 300.0, 20.0)
            .with_prefixes(PrefixProfile::agentic());
        let t = generate_trace(&cfg);
        let mut lens: std::collections::HashMap<u64, u32> = std::collections::HashMap::new();
        let mut shared = 0usize;
        for r in &t {
            assert!(r.priority < 4);
            if r.prefix_id == 0 {
                assert_eq!(r.prefix_tokens, 0);
                continue;
            }
            shared += 1;
            assert!(r.prefix_id <= 8);
            assert!(r.prefix_tokens >= 256 && r.prefix_tokens <= 4096);
            assert!(r.prefix_tokens <= r.prompt_tokens, "prefix within prompt");
            // Every member of a family shares the identical prefix length.
            let prev = lens.insert(r.prefix_id, r.prefix_tokens);
            if let Some(p) = prev {
                assert_eq!(p, r.prefix_tokens, "family {} length drifted", r.prefix_id);
            }
        }
        let frac = shared as f64 / t.len() as f64;
        assert!((frac - 0.7).abs() < 0.08, "shared fraction {frac}");
        // Replays bit-exactly.
        assert_eq!(t, generate_trace(&cfg));
    }

    #[test]
    fn aliased_content_classes_share_hash_and_length() {
        let p = PrefixProfile::agentic_aliased();
        let seed = 77u64;
        // Families land on 1..=3 contents; with 8 families over 3 contents
        // some pair must alias (pigeonhole), and aliased families agree on
        // both hash and length — they genuinely share their leading tokens.
        let mut by_content: std::collections::HashMap<u64, (u64, u32)> = std::collections::HashMap::new();
        let mut aliased_pairs = 0usize;
        for id in 1..=8u64 {
            let c = p.content_of(seed, id);
            assert!((1..=3).contains(&c), "content {c} out of range");
            let h = p.prefix_hash(seed, id);
            let l = p.prefix_len(seed, id);
            assert_ne!(h, 0);
            match by_content.get(&c) {
                Some(&(ph, pl)) => {
                    assert_eq!(ph, h, "aliased families must share the content hash");
                    assert_eq!(pl, l, "aliased families must share the prefix length");
                    aliased_pairs += 1;
                }
                None => {
                    by_content.insert(c, (h, l));
                }
            }
        }
        assert!(aliased_pairs > 0, "8 families over 3 contents must alias");
        // Distinct contents get distinct hashes.
        let hashes: std::collections::HashSet<u64> = by_content.values().map(|&(h, _)| h).collect();
        assert_eq!(hashes.len(), by_content.len());
        // Without aliasing every family is its own content and the lengths
        // replay the pre-aliasing keying bit-exactly.
        let flat = PrefixProfile::agentic();
        for id in 1..=8u64 {
            assert_eq!(flat.content_of(seed, id), id);
            assert_ne!(flat.prefix_hash(seed, id), 0);
        }
        assert_eq!(flat.content_of(seed, 0), 0);
        assert_eq!(flat.prefix_hash(seed, 0), 0);
    }

    #[test]
    fn traces_carry_content_hashes() {
        let cfg = TraceConfig::new(51, TrafficPattern::Poisson, 300.0, 10.0)
            .with_prefixes(PrefixProfile::agentic_aliased());
        let t = generate_trace(&cfg);
        let mut shared = 0usize;
        for r in &t {
            assert!(!r.prefilled, "traces never emit pre-filled requests");
            if r.prefix_id == 0 {
                assert_eq!(r.prefix_hash, 0);
            } else {
                shared += 1;
                assert_eq!(r.prefix_hash, cfg.prefixes.prefix_hash(cfg.seed, r.prefix_id));
                assert_ne!(r.prefix_hash, 0);
            }
        }
        assert!(shared > 0);
    }

    #[test]
    fn disabling_prefixes_leaves_arrivals_and_lengths_untouched() {
        let base = TraceConfig::new(41, TrafficPattern::Poisson, 200.0, 10.0);
        let with = base.with_prefixes(PrefixProfile::agentic());
        let a = generate_trace(&base);
        let b = generate_trace(&with);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival_s, y.arrival_s);
            assert_eq!(x.output_tokens, y.output_tokens);
            // Prompt only grows (by the prepended shared prefix).
            assert!(y.prompt_tokens >= x.prompt_tokens);
            assert_eq!(y.prompt_tokens - y.prefix_tokens, x.prompt_tokens);
        }
    }

    #[test]
    fn thinning_is_nested_and_rate_proportional() {
        let cfg = TraceConfig::new(23, TrafficPattern::Poisson, 400.0, 20.0);
        let full = generate_trace(&cfg);
        let half = thin_trace(&full, 0.5, 99);
        let quarter = thin_trace(&full, 0.25, 99);
        // Nested: every kept-at-0.25 id is kept at 0.5.
        let half_ids: std::collections::HashSet<u64> = half.iter().map(|r| r.id).collect();
        assert!(quarter.iter().all(|r| half_ids.contains(&r.id)));
        let f = half.len() as f64 / full.len() as f64;
        assert!((f - 0.5).abs() < 0.06, "kept fraction {f}");
    }

    #[test]
    fn intensity_means_are_unit() {
        for pattern in [
            TrafficPattern::Poisson,
            TrafficPattern::Bursty { period_s: 5.0, duty: 0.3, burst_factor: 4.0 },
            TrafficPattern::Diurnal { period_s: 10.0, trough_factor: 0.2 },
        ] {
            let n = 100_000;
            let mean: f64 = (0..n).map(|i| pattern.intensity(i as f64 * 10.0 / n as f64)).sum::<f64>() / n as f64;
            assert!((mean - 1.0).abs() < 0.01, "{}: mean {mean}", pattern.label());
            assert!(pattern.peak_intensity() + 1e-12 >= pattern.intensity(3.3));
        }
    }
}
