//! Iteration-level continuous-batching scheduler over the wafer's EP
//! columns and PP waves.
//!
//! Topology recap (matches the wave-pipelined decode model of
//! `multichip::parallelism`): an EP×PP plan gives `ep` *columns* × `pp`
//! *waves*. Each (column, wave) cell owns up to `max_batch_per_chip` user
//! slots on one chip; the KV budget is per *column* (all waves of a column
//! share the same chips' HBM, see `serve::kv`).
//!
//! Per wave-iteration the scheduler:
//! 1. admits waiting requests in queue-policy order (FCFS / SJF /
//!    Priority) into the wave's best column — the column holding the
//!    largest resident shared-prefix hit, then the freest — gated by the KV
//!    admission policy. Prefix hits skip both prefill compute and KV
//!    admission for the shared tokens; under pressure, unreferenced prefix
//!    blocks are evicted before admission fails;
//! 2. (on-demand policy) reserves this iteration's KV growth, evicting
//!    unreferenced prefix blocks first and then preempting the newest
//!    resident of an over-committed column — preempted requests lose their
//!    private cache and re-enter the queue for recomputation;
//! 3. executes the iteration: chunked prefill first (budget
//!    `prefill_chunk_tokens` per chip), the prefill-finishing iteration
//!    publishes the request's shareable prefix blocks and emits the first
//!    token, decoding users advance by `tokens_per_iteration`, finished
//!    users free their slot and KV (shared blocks stay resident).

use std::collections::VecDeque;

use crate::serve::kv::{KvCacheModel, KvColumn, PrefixStore};
use crate::serve::request::Request;

/// KV admission policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Reserve the full final context (prompt + output + margin) at
    /// admission: no preemption can ever be needed (vLLM's conservative
    /// mode). Queue-delays under pressure instead.
    ReserveFull,
    /// Reserve only the current context and grow per iteration; on
    /// overflow, preempt the newest resident for recomputation.
    OnDemandPreempt,
}

/// Queue-ordering policy: which waiting request is offered the next free
/// slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueuePolicy {
    /// First come, first served (head-of-line blocking on KV pressure).
    Fcfs,
    /// Shortest job first by prompt length — the TTFT-optimal greedy order
    /// for the prefill-bound queue (ties broken by arrival).
    Sjf,
    /// Strict priority classes (`Request::priority`, 0 = most urgent),
    /// FCFS within a class.
    Priority,
}

impl QueuePolicy {
    pub fn label(self) -> &'static str {
        match self {
            QueuePolicy::Fcfs => "fcfs",
            QueuePolicy::Sjf => "sjf",
            QueuePolicy::Priority => "priority",
        }
    }

    /// Parse a CLI policy name (case-insensitive).
    pub fn parse(s: &str) -> Option<QueuePolicy> {
        match s.to_ascii_lowercase().as_str() {
            "fcfs" => Some(QueuePolicy::Fcfs),
            "sjf" => Some(QueuePolicy::Sjf),
            "priority" | "prio" => Some(QueuePolicy::Priority),
            _ => None,
        }
    }
}

/// How the prefix store keys shared prompt prefixes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrefixKeying {
    /// Exact trace-family identity (`Request::prefix_id`): distinct families
    /// never share blocks, even when their seeded prefix content coincides.
    ExactId,
    /// Hashed token blocks (`Request::prefix_hash`): families with identical
    /// seeded prefix content hit each other's blocks — content-addressed
    /// prefix caching. Requests carrying a `prefix_id` but no content hash
    /// (hand-built traces) fall back to identity keying, so this mode is a
    /// strict superset of `ExactId` reuse.
    TokenHash,
}

impl PrefixKeying {
    /// Family key of `r` under this keying mode (0 = no shared prefix).
    /// The single source of truth — the scheduler's prefix store and the
    /// cluster router's affinity fingerprints must agree on it.
    pub fn key_of(self, r: &Request) -> u64 {
        match self {
            PrefixKeying::ExactId => r.prefix_id,
            // Content hash when the trace carries one; identity fallback
            // keeps hand-built traces (hash 0, id != 0) sharing within
            // their family exactly as under ExactId.
            PrefixKeying::TokenHash => {
                if r.prefix_hash != 0 {
                    r.prefix_hash
                } else {
                    r.prefix_id
                }
            }
        }
    }
}

/// Scheduler knobs.
#[derive(Debug, Clone, Copy)]
pub struct SchedulerConfig {
    /// Decode user slots per (column, wave) cell — per-chip batch ceiling.
    pub max_batch_per_chip: u32,
    /// Prefill tokens one chip may process per iteration (chunked prefill
    /// riding the decode iterations).
    pub prefill_chunk_tokens: u32,
    pub policy: AdmissionPolicy,
    pub queue_policy: QueuePolicy,
    /// Safety margin on reservations (draft-token overshoot of MTP).
    pub reserve_margin_tokens: f64,
    /// Prefix-cache block granularity in tokens (0 disables KV reuse).
    pub prefix_block_tokens: u32,
    /// Prefix-cache key: exact family id vs hashed token blocks.
    pub prefix_keying: PrefixKeying,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            max_batch_per_chip: 512,
            // SLO-aware chunk: small enough that one chip's prefill work
            // cannot stretch the whole EP group's iteration far past the
            // decode TPOT budget (the reason chunked prefill exists), large
            // enough that aggregate prefill bandwidth clears the offered
            // prompt load below the intended saturation knee.
            prefill_chunk_tokens: 1024,
            policy: AdmissionPolicy::ReserveFull,
            queue_policy: QueuePolicy::Fcfs,
            reserve_margin_tokens: 4.0,
            prefix_block_tokens: 256,
            prefix_keying: PrefixKeying::TokenHash,
        }
    }
}

/// A resident request in one (column, wave) cell.
#[derive(Debug, Clone)]
struct Active {
    rec: usize,
    admit_seq: u64,
    /// Context tokens still to prefill (full context minus the prefix-cache
    /// hit; the full context again on re-admission after a preemption —
    /// recomputation).
    remaining_prefill: u32,
    /// Total context tokens once prefill completes (prompt + pre-preemption
    /// generation, *including* reused prefix tokens) — the offset base for
    /// chunk billing.
    prefill_target: u32,
    /// Output tokens generated so far (fractional: MTP expected tokens).
    generated: f64,
    /// KV tokens currently reserved on the column for this request
    /// (excludes shared prefix blocks, which the store owns).
    held_tokens: f64,
    /// Prefix-store family key under the active [`PrefixKeying`] (0 = none).
    prefix_key: u64,
    /// Prefix tokens this request currently pins in the column's store.
    prefix_pinned: u32,
    /// Whole-block shareable tokens of its prefix (publish target).
    prefix_share_to: u32,
}

/// A queued request (fresh arrival or preempted resident).
#[derive(Debug, Clone, Copy)]
pub struct Waiting {
    pub rec: usize,
    pub generated: f64,
}

/// One scheduler decision, pushed to the optional event log the
/// observability layer drains after each wave (`take_events`). The log is
/// `None` by default — no allocation, no per-decision work — and only
/// fills once a sink is attached (`enable_event_log`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedEvent {
    /// Admitted into column `column` of the wave; `hit_tokens` prefix-cache
    /// tokens were reused, and `decode_only` marks a pre-filled
    /// disaggregated handoff that skips prefill entirely.
    Admitted { rec: usize, column: usize, hit_tokens: u32, decode_only: bool },
    /// Rejected at admission (can never fit a column).
    Rejected { rec: usize },
    /// Evicted back to the queue head (recomputation preemption).
    Preempted { rec: usize },
}

/// What happened during one wave iteration.
#[derive(Debug, Clone, Default)]
pub struct WaveEvents {
    /// Records whose first output token was emitted this iteration.
    pub first_tokens: Vec<usize>,
    /// Records that finished this iteration.
    pub completions: Vec<usize>,
    /// Output tokens produced this iteration (completion-clamped).
    pub tokens_produced: f64,
    /// Prefill tokens processed this iteration.
    pub prefill_tokens: u64,
    /// Users that ran a decode step this iteration.
    pub decode_users: u32,
}

pub struct Scheduler {
    /// Owned request storage: the initial trace plus any requests injected
    /// mid-simulation (`push_request`). Indices into it (`rec`) are stable —
    /// the steppable `ServeEngine` relies on that to map its own records.
    trace: Vec<Request>,
    cfg: SchedulerConfig,
    /// Expected tokens per decode iteration (MTP).
    tokens_per_iter: f64,
    pub columns: Vec<KvColumn>,
    /// Per-column prefix-cache stores (token-block tries).
    pub prefix: Vec<PrefixStore>,
    /// actives[wave][column] → residents in admission order.
    actives: Vec<Vec<Vec<Active>>>,
    pub queue: VecDeque<Waiting>,
    admit_seq: u64,
    pub preemptions: u64,
    /// Records rejected at admission (can never fit a column).
    pub rejected: Vec<usize>,
    /// Shareable prefix tokens served from the cache at admission.
    pub prefix_hit_tokens: u64,
    /// Shareable prefix tokens that had to be prefilled (cold or evicted).
    pub prefix_miss_tokens: u64,
    /// Decision log for the observability layer (`None` = disabled).
    log: Option<Vec<SchedEvent>>,
}

impl Scheduler {
    pub fn new(
        trace: &[Request],
        kv: &KvCacheModel,
        waves: u32,
        cfg: SchedulerConfig,
        tokens_per_iter: f64,
    ) -> Self {
        Scheduler {
            trace: trace.to_vec(),
            cfg,
            tokens_per_iter,
            columns: (0..kv.columns).map(|_| KvColumn::new(kv.column_capacity_tokens)).collect(),
            prefix: (0..kv.columns).map(|_| PrefixStore::new(cfg.prefix_block_tokens)).collect(),
            actives: (0..waves)
                .map(|_| (0..kv.columns).map(|_| Vec::new()).collect())
                .collect(),
            queue: VecDeque::new(),
            admit_seq: 0,
            preemptions: 0,
            rejected: Vec::new(),
            prefix_hit_tokens: 0,
            prefix_miss_tokens: 0,
            log: None,
        }
    }

    /// Start logging admission / rejection / preemption decisions for the
    /// observability layer.
    pub fn enable_event_log(&mut self) {
        if self.log.is_none() {
            self.log = Some(Vec::new());
        }
    }

    /// Drain the decision log (empty when logging is disabled).
    pub fn take_events(&mut self) -> Vec<SchedEvent> {
        match self.log.as_mut() {
            Some(l) => std::mem::take(l),
            None => Vec::new(),
        }
    }

    /// Append a request to the owned storage (a mid-simulation injection —
    /// a routed fleet arrival or a disaggregated KV handoff) and return its
    /// record index. The request is NOT enqueued; the caller decides when
    /// its arrival time has been reached.
    pub fn push_request(&mut self, r: Request) -> usize {
        self.trace.push(r);
        self.trace.len() - 1
    }

    /// The stored request at record index `rec`.
    pub fn request(&self, rec: usize) -> &Request {
        &self.trace[rec]
    }

    pub fn enqueue_arrival(&mut self, rec: usize) {
        self.queue.push_back(Waiting { rec, generated: 0.0 });
    }

    fn final_need(&self, r: &Request) -> f64 {
        r.total_tokens() as f64 + self.cfg.reserve_margin_tokens
    }

    /// Prefix-store family key of `r` under the configured keying mode.
    fn prefix_key_of(&self, r: &Request) -> u64 {
        self.cfg.prefix_keying.key_of(r)
    }

    fn admit_need(&self, r: &Request, generated: f64) -> f64 {
        match self.cfg.policy {
            AdmissionPolicy::ReserveFull => self.final_need(r),
            AdmissionPolicy::OnDemandPreempt => {
                r.prompt_tokens as f64 + generated + self.cfg.reserve_margin_tokens
            }
        }
    }

    /// Queue index of the next request the policy would admit.
    fn next_candidate(&self) -> Option<usize> {
        if self.queue.is_empty() {
            return None;
        }
        match self.cfg.queue_policy {
            QueuePolicy::Fcfs => Some(0),
            QueuePolicy::Sjf => (0..self.queue.len()).min_by_key(|&i| {
                let r = &self.trace[self.queue[i].rec];
                (r.prompt_tokens, r.id)
            }),
            QueuePolicy::Priority => (0..self.queue.len()).min_by_key(|&i| {
                let r = &self.trace[self.queue[i].rec];
                (r.priority, r.id)
            }),
        }
    }

    /// Admission into wave `w` in queue-policy order (head-of-line blocking
    /// on KV pressure stays strict within the policy's order).
    pub fn admit_wave(&mut self, w: usize) {
        loop {
            let Some(qi) = self.next_candidate() else { break };
            let head = self.queue[qi];
            let r = self.trace[head.rec];
            if self.final_need(&r) > self.columns[0].capacity_tokens {
                self.queue.remove(qi);
                self.rejected.push(head.rec);
                if let Some(l) = self.log.as_mut() {
                    l.push(SchedEvent::Rejected { rec: head.rec });
                }
                continue;
            }
            // A pre-filled arrival (disaggregated handoff: KV already
            // computed at a prefill-pool instance, token #1 already emitted
            // there) skips prefill and the prefix cache entirely on its
            // first admission. Once preempted it loses the transferred KV
            // and recomputes like any other resident.
            let fresh_prefilled = r.prefilled && head.generated == 0.0;
            let key = self.prefix_key_of(&r);
            // Prefix-aware placement: the column with the largest resident
            // hit for this request's prefix, then the freest, among those
            // with a spare slot in this wave.
            let mut best: Option<(usize, u32)> = None;
            for c in 0..self.columns.len() {
                if self.actives[w][c].len() >= self.cfg.max_batch_per_chip as usize {
                    continue;
                }
                let hit = if fresh_prefilled { 0 } else { self.prefix[c].probe(key, r.prefix_tokens) };
                let better = match best {
                    None => true,
                    Some((bc, bh)) => {
                        hit > bh
                            || (hit == bh
                                && self.columns[c].free_tokens() > self.columns[bc].free_tokens())
                    }
                };
                if better {
                    best = Some((c, hit));
                }
            }
            let Some((c, hit)) = best else { break };
            let context = (r.prompt_tokens as u64 + head.generated.floor() as u64)
                .clamp(1, u32::MAX as u64) as u32;
            // Even a full-prompt hit recomputes at least the final token
            // (its logits produce token #1), so cap the usable hit below
            // the context on a whole-block boundary.
            let hit = if hit >= context {
                let bt = self.cfg.prefix_block_tokens.max(1);
                ((context - 1) / bt) * bt
            } else {
                hit
            };
            // The upstream instance already emitted token #1 of a pre-filled
            // request; it resumes decoding from one generated token.
            let gen0 = if fresh_prefilled { 1.0 } else { head.generated };
            let need = (self.admit_need(&r, gen0) - hit as f64).max(0.0);
            if !self.columns[c].fits(need) {
                // Pressure: drop unreferenced prefix blocks before giving up.
                let deficit = need - self.columns[c].free_tokens();
                let freed = self.prefix[c].evict_for(deficit);
                if freed > 0.0 {
                    self.columns[c].release(freed);
                }
            }
            if !self.columns[c].reserve(need) {
                break;
            }
            // Blocks can vanish between the placement probe and this pin:
            // the pressure eviction above (and any earlier admission this
            // wave) runs `evict_for`, which is free to take zero-ref blocks
            // the probe counted. `pin` reports what actually survived; the
            // shortfall is no longer a cache hit, so those tokens are
            // re-billed as private KV and recomputed like a miss.
            let pinned = self.prefix[c].pin(key, hit);
            let shortfall = hit - pinned;
            if shortfall > 0 {
                let extra = shortfall as f64;
                if !self.columns[c].fits(extra) {
                    let deficit = extra - self.columns[c].free_tokens();
                    let freed = self.prefix[c].evict_for(deficit);
                    if freed > 0.0 {
                        self.columns[c].release(freed);
                    }
                }
                if !self.columns[c].reserve(extra) {
                    // Can't cover the recompute: undo and leave the request
                    // queued — identical to a plain failed reserve.
                    self.columns[c].release(need);
                    self.prefix[c].unpin(key, pinned);
                    break;
                }
            }
            let hit = pinned;
            let need = need + shortfall as f64;
            self.queue.remove(qi);
            let share_to = if fresh_prefilled { 0 } else { self.prefix[c].shareable_tokens(key, r.prefix_tokens) };
            self.prefix_hit_tokens += hit as u64;
            self.prefix_miss_tokens += (share_to.saturating_sub(hit)) as u64;
            // Re-admission recomputes the whole context (prompt + tokens
            // generated before preemption) minus whatever the cache serves.
            self.actives[w][c].push(Active {
                rec: head.rec,
                admit_seq: self.admit_seq,
                remaining_prefill: if fresh_prefilled { 0 } else { context - hit },
                prefill_target: context,
                generated: gen0,
                held_tokens: need,
                prefix_key: key,
                prefix_pinned: hit,
                prefix_share_to: share_to,
            });
            self.admit_seq += 1;
            if let Some(l) = self.log.as_mut() {
                l.push(SchedEvent::Admitted { rec: head.rec, column: c, hit_tokens: hit, decode_only: fresh_prefilled });
            }
        }
    }

    /// On-demand KV growth for wave `w`'s decoders: evict unreferenced
    /// prefix blocks first, then preempt the newest resident of any
    /// over-committed column (recomputation preemption).
    pub fn grow_wave(&mut self, w: usize) {
        if self.cfg.policy != AdmissionPolicy::OnDemandPreempt {
            return;
        }
        for c in 0..self.columns.len() {
            loop {
                let growers = self.actives[w][c].iter().filter(|a| a.remaining_prefill == 0).count();
                let need = growers as f64 * self.tokens_per_iter;
                if need <= 0.0 || self.columns[c].fits(need) {
                    if need > 0.0 {
                        assert!(self.columns[c].reserve(need));
                        for a in self.actives[w][c].iter_mut() {
                            if a.remaining_prefill == 0 {
                                a.held_tokens += self.tokens_per_iter;
                            }
                        }
                    }
                    break;
                }
                let deficit = need - self.columns[c].free_tokens();
                let freed = self.prefix[c].evict_for(deficit);
                if freed > 0.0 {
                    self.columns[c].release(freed);
                    continue;
                }
                if !self.preempt_newest_in_column(c) {
                    break;
                }
            }
        }
    }

    /// Evict the newest resident (largest admit_seq) of column `c` back to
    /// the queue head. Returns false if the column is empty.
    fn preempt_newest_in_column(&mut self, c: usize) -> bool {
        let mut newest: Option<(usize, usize, u64)> = None; // (wave, idx, seq)
        for (w, per_col) in self.actives.iter().enumerate() {
            for (i, a) in per_col[c].iter().enumerate() {
                if newest.map_or(true, |(_, _, seq)| a.admit_seq > seq) {
                    newest = Some((w, i, a.admit_seq));
                }
            }
        }
        let Some((w, i, _)) = newest else { return false };
        let victim = self.actives[w][c].remove(i);
        self.columns[c].release(victim.held_tokens);
        self.prefix[c].unpin(victim.prefix_key, victim.prefix_pinned);
        self.queue.push_front(Waiting { rec: victim.rec, generated: victim.generated });
        self.preemptions += 1;
        if let Some(l) = self.log.as_mut() {
            l.push(SchedEvent::Preempted { rec: victim.rec });
        }
        true
    }

    /// Abort every queued and resident request — the fault-injection kill
    /// path. All KV reservations and prefix pins are released (the
    /// instance's KV is gone, so nothing stays resident), the queue drains,
    /// and the aborted work comes back to the caller: `(queued, in_flight)`
    /// where `queued` is the waiting queue in order and `in_flight` the
    /// residents in admission order, each with the decode progress it loses.
    pub fn abort_all(&mut self) -> (Vec<Waiting>, Vec<Waiting>) {
        let queued: Vec<Waiting> = self.queue.drain(..).collect();
        let mut resident: Vec<(u64, Waiting)> = Vec::new();
        for per_col in self.actives.iter_mut() {
            for (c, cell) in per_col.iter_mut().enumerate() {
                for a in cell.drain(..) {
                    self.columns[c].release(a.held_tokens);
                    self.prefix[c].unpin(a.prefix_key, a.prefix_pinned);
                    resident.push((a.admit_seq, Waiting { rec: a.rec, generated: a.generated }));
                }
            }
        }
        resident.sort_by_key(|&(seq, _)| seq);
        // The shared prefix blocks die with the instance's HBM as well:
        // every block is zero-ref after the unpins above, so a full
        // pressure eviction clears the store and hands back the tokens the
        // column ledger charged for it.
        for (c, store) in self.prefix.iter_mut().enumerate() {
            let freed = store.evict_for(f64::INFINITY);
            if freed > 0.0 {
                self.columns[c].release(freed);
            }
        }
        (queued, resident.into_iter().map(|(_, w)| w).collect())
    }

    /// Execute one iteration of wave `w`: chunked prefill, prefix-block
    /// publication, first-token emission, decode progress, completions.
    pub fn execute_wave(&mut self, w: usize) -> WaveEvents {
        let mut ev = WaveEvents::default();
        let tpi = self.tokens_per_iter;
        for c in 0..self.columns.len() {
            let mut budget = self.cfg.prefill_chunk_tokens;
            let mut done: Vec<usize> = Vec::new();
            for i in 0..self.actives[w][c].len() {
                let a = &mut self.actives[w][c][i];
                let r = &self.trace[a.rec];
                if a.remaining_prefill > 0 {
                    let take = a.remaining_prefill.min(budget);
                    a.remaining_prefill -= take;
                    budget -= take;
                    ev.prefill_tokens += take as u64;
                    if a.remaining_prefill == 0 && take > 0 {
                        // Publish the shareable prefix blocks this request
                        // just prefilled: their tokens transfer from the
                        // private reservation to the shared store (column
                        // occupancy is unchanged — pure bookkeeping).
                        if a.prefix_key != 0 && a.prefix_share_to > a.prefix_pinned {
                            let newly =
                                self.prefix[c].insert(a.prefix_key, a.prefix_pinned, a.prefix_share_to);
                            a.held_tokens = (a.held_tokens - newly as f64).max(0.0);
                            a.prefix_pinned = a.prefix_share_to;
                        }
                        // The prefill-finishing iteration emits token #1.
                        a.generated += 1.0;
                        ev.first_tokens.push(a.rec);
                        ev.tokens_produced += 1.0;
                        if a.generated + 1e-9 >= r.output_tokens as f64 {
                            done.push(i);
                            ev.completions.push(a.rec);
                        }
                    }
                } else {
                    let before = a.generated;
                    a.generated += tpi;
                    ev.decode_users += 1;
                    ev.tokens_produced += (r.output_tokens as f64 - before).clamp(0.0, tpi);
                    if a.generated + 1e-9 >= r.output_tokens as f64 {
                        done.push(i);
                        ev.completions.push(a.rec);
                    }
                }
            }
            // Release completed residents (reverse order keeps indices
            // valid); their shared blocks stay resident for future hits.
            for &i in done.iter().rev() {
                let a = self.actives[w][c].remove(i);
                self.columns[c].release(a.held_tokens);
                self.prefix[c].unpin(a.prefix_key, a.prefix_pinned);
            }
        }
        ev
    }

    /// Worst-case per-chip iteration load across all (wave, column) cells:
    /// `(decode users, prefill tokens co-scheduled next iteration)`. The
    /// two maxima may come from different cells — the stage-time lookup
    /// combines them, which errs conservative. Drives the tick duration.
    pub fn peak_cell_load(&self) -> (u64, u64) {
        let mut decode_max = 0u64;
        let mut prefill_max = 0u64;
        for per_col in &self.actives {
            for cell in per_col {
                let decode_users = cell.iter().filter(|a| a.remaining_prefill == 0).count() as u64;
                let prefill_pending: u64 = cell.iter().map(|a| a.remaining_prefill as u64).sum();
                decode_max = decode_max.max(decode_users);
                prefill_max = prefill_max.max(prefill_pending.min(self.cfg.prefill_chunk_tokens as u64));
            }
        }
        (decode_max, prefill_max)
    }

    /// Largest context (tokens already in place + this chunk) any prefill
    /// chunk of the next iteration will attend over — the offset the
    /// chunk's dataflow billing should assume. Mirrors the budget walk of
    /// [`Scheduler::execute_wave`].
    pub fn peak_prefill_context(&self) -> u64 {
        let mut max = 0u64;
        for per_col in &self.actives {
            for cell in per_col {
                let mut budget = self.cfg.prefill_chunk_tokens;
                for a in cell {
                    if budget == 0 {
                        break;
                    }
                    if a.remaining_prefill == 0 {
                        continue;
                    }
                    let take = a.remaining_prefill.min(budget);
                    budget -= take;
                    let done = a.prefill_target - a.remaining_prefill;
                    max = max.max(done as u64 + take as u64);
                }
            }
        }
        max
    }

    /// Longest current context (prompt + generated) among residents, in
    /// tokens — the KV length the stage-time lookup should assume.
    pub fn max_context_tokens(&self) -> f64 {
        let mut max = 0.0f64;
        for per_col in &self.actives {
            for cell in per_col {
                for a in cell {
                    let ctx = self.trace[a.rec].prompt_tokens as f64 + a.generated;
                    max = max.max(ctx);
                }
            }
        }
        max
    }

    pub fn active_total(&self) -> usize {
        self.actives.iter().map(|pc| pc.iter().map(Vec::len).sum::<usize>()).sum()
    }

    /// Highest KV occupancy fraction reached on any column so far.
    pub fn peak_kv_occupancy(&self) -> f64 {
        self.columns.iter().map(KvColumn::peak_frac).fold(0.0, f64::max)
    }

    /// Highest *current* KV occupancy fraction across columns — the live
    /// pressure signal the engine snapshot (and fleet routing) observes.
    pub fn kv_occupancy_frac(&self) -> f64 {
        self.columns.iter().map(KvColumn::occupancy_frac).fold(0.0, f64::max)
    }

    /// True iff some column currently holds more than its capacity (must
    /// never happen; surfaced for the invariant tests).
    pub fn kv_over_capacity(&self) -> bool {
        self.columns.iter().any(|c| c.held_tokens > c.capacity_tokens + 1e-6)
    }

    /// Prefix-cache blocks evicted under pressure, across all columns.
    pub fn prefix_evictions(&self) -> u64 {
        self.prefix.iter().map(|s| s.evictions).sum()
    }

    /// Tokens currently resident in shared prefix blocks, across columns.
    pub fn prefix_shared_tokens(&self) -> f64 {
        self.prefix.iter().map(|s| s.shared_tokens).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::config::Dtype;
    use crate::multichip::d2d::WaferSystem;
    use crate::multichip::parallelism::ParallelismPlan;
    use crate::workload::deepseek::DeepSeekConfig;

    fn tiny_kv(capacity_tokens: u64, columns: u32) -> KvCacheModel {
        // Hand-built small model for policy tests.
        KvCacheModel {
            bytes_per_token_per_chip: 576,
            weight_bytes_per_chip: 0,
            hbm_capacity_bytes: capacity_tokens * 576,
            column_capacity_tokens: capacity_tokens,
            columns,
        }
    }

    fn req(id: u64, prompt: u32, output: u32) -> Request {
        Request::new(id, 0.0, prompt, output)
    }

    fn preq(id: u64, prompt: u32, output: u32, prefix_id: u64, prefix_tokens: u32) -> Request {
        Request { prefix_id, prefix_tokens, ..Request::new(id, 0.0, prompt, output) }
    }

    #[test]
    fn admits_fcfs_and_prefills_in_chunks() {
        let trace = vec![req(0, 3000, 4), req(1, 100, 4)];
        let kv = tiny_kv(100_000, 1);
        let mut s = Scheduler::new(
            &trace,
            &kv,
            1,
            SchedulerConfig { prefill_chunk_tokens: 2048, ..Default::default() },
            1.0,
        );
        s.enqueue_arrival(0);
        s.enqueue_arrival(1);
        s.admit_wave(0);
        assert_eq!(s.active_total(), 2);
        // Tick 1: request 0 eats the whole chunk; request 1 stalls.
        assert_eq!(s.peak_prefill_context(), 2048);
        let ev = s.execute_wave(0);
        assert_eq!(ev.prefill_tokens, 2048);
        assert!(ev.first_tokens.is_empty());
        // Tick 2: request 0 finishes (952) and request 1 (100) fits too;
        // the deepest chunk ends at request 0's full 3000-token context.
        assert_eq!(s.peak_prefill_context(), 3000);
        let ev = s.execute_wave(0);
        assert_eq!(ev.prefill_tokens, 952 + 100);
        assert_eq!(ev.first_tokens, vec![0, 1]);
    }

    #[test]
    fn reserve_full_never_overflows_and_blocks_head_of_line() {
        let trace = vec![req(0, 500, 100), req(1, 500, 100), req(2, 500, 100)];
        // Capacity fits two full reservations (604 each), not three.
        let kv = tiny_kv(1300, 1);
        let mut s = Scheduler::new(&trace, &kv, 1, SchedulerConfig::default(), 1.0);
        for i in 0..3 {
            s.enqueue_arrival(i);
        }
        s.admit_wave(0);
        assert_eq!(s.active_total(), 2);
        assert_eq!(s.queue.len(), 1, "third request must wait");
        assert!(!s.kv_over_capacity());
        // Run everything to completion; capacity is never exceeded.
        for _ in 0..300 {
            s.admit_wave(0);
            s.grow_wave(0);
            s.execute_wave(0);
            assert!(!s.kv_over_capacity());
        }
        assert_eq!(s.active_total(), 0);
        assert_eq!(s.queue.len(), 0);
        assert_eq!(s.preemptions, 0, "ReserveFull never preempts");
    }

    #[test]
    fn infeasible_request_is_rejected_not_wedged() {
        let trace = vec![req(0, 5000, 1000), req(1, 100, 10)];
        let kv = tiny_kv(2000, 1);
        let mut s = Scheduler::new(&trace, &kv, 1, SchedulerConfig::default(), 1.0);
        s.enqueue_arrival(0);
        s.enqueue_arrival(1);
        s.admit_wave(0);
        assert_eq!(s.rejected, vec![0]);
        assert_eq!(s.active_total(), 1, "queue must not wedge behind an impossible request");
    }

    #[test]
    fn on_demand_preempts_newest_and_recomputes() {
        let trace = vec![req(0, 400, 600), req(1, 400, 600)];
        // Each needs 1004 at completion; both admit on-demand (404 each)
        // but cannot both finish in a 1200-token column.
        let kv = tiny_kv(1200, 1);
        let cfg = SchedulerConfig { policy: AdmissionPolicy::OnDemandPreempt, ..Default::default() };
        let mut s = Scheduler::new(&trace, &kv, 1, cfg, 1.0);
        s.enqueue_arrival(0);
        s.enqueue_arrival(1);
        s.admit_wave(0);
        assert_eq!(s.active_total(), 2, "on-demand admits both");
        let mut preempted = false;
        for _ in 0..5000 {
            s.admit_wave(0);
            s.grow_wave(0);
            s.execute_wave(0);
            assert!(!s.kv_over_capacity(), "growth must never overflow the column");
            preempted |= s.preemptions > 0;
            if s.active_total() == 0 && s.queue.is_empty() {
                break;
            }
        }
        assert!(preempted, "KV pressure must have forced a preemption");
        assert_eq!(s.active_total() + s.queue.len(), 0, "both requests eventually drain");
    }

    #[test]
    fn peak_cell_load_counts_decode_and_chunked_prefill() {
        let trace = vec![req(0, 5000, 50), req(1, 64, 50)];
        let kv = tiny_kv(100_000, 1);
        let mut s = Scheduler::new(
            &trace,
            &kv,
            1,
            SchedulerConfig { prefill_chunk_tokens: 1024, ..Default::default() },
            1.0,
        );
        s.enqueue_arrival(0);
        s.enqueue_arrival(1);
        s.admit_wave(0);
        // Pending prefill 5064 capped at the 1024 chunk; no decoders yet.
        assert_eq!(s.peak_cell_load(), (0, 1024));
        for _ in 0..5 {
            s.execute_wave(0); // 5 chunks drain req0 (5000) and req1 (64)
        }
        let (decode, prefill) = s.peak_cell_load();
        assert_eq!(decode, 2, "both requests must be decoding");
        assert_eq!(prefill, 0);
    }

    #[test]
    fn prefix_hit_skips_prefill_and_admission() {
        // Two requests sharing a 512-token prefix (block 256), arriving one
        // after the other: the second hits the published blocks, prefills
        // only its private suffix and reserves less KV.
        let trace = vec![preq(0, 1024, 4, 7, 512), preq(1, 1024, 4, 7, 512)];
        let kv = tiny_kv(100_000, 1);
        let mut s = Scheduler::new(&trace, &kv, 1, SchedulerConfig::default(), 1.0);
        s.enqueue_arrival(0);
        s.admit_wave(0);
        let held_cold = s.columns[0].held_tokens;
        let ev = s.execute_wave(0); // 1024-token prefill fits one chunk
        assert_eq!(ev.prefill_tokens, 1024);
        assert_eq!(ev.first_tokens, vec![0]);
        assert_eq!(s.prefix[0].resident_blocks(), 2, "512 shared tokens published");
        assert_eq!(s.prefix_hit_tokens, 0);
        assert_eq!(s.prefix_miss_tokens, 512);
        // Second request: probe hits the full shareable 512.
        s.enqueue_arrival(1);
        s.admit_wave(0);
        assert_eq!(s.prefix_hit_tokens, 512);
        let a1_held = s.columns[0].held_tokens;
        // Cold admission reserved the full need (1024 + 4 output + 4 margin
        // = 1032); the warm one reserves need − 512 hit. Column occupancy
        // is unchanged by publication (a transfer, not a release).
        assert!((held_cold - 1032.0).abs() < 1e-9, "cold held {held_cold}");
        assert!((a1_held - (1032.0 - 512.0 + 1032.0)).abs() < 1e-9, "warm held {a1_held}");
        // And it prefills only the 512-token suffix before token #1.
        let ev = s.execute_wave(0);
        assert_eq!(ev.prefill_tokens, 512);
        assert!(ev.first_tokens.contains(&1));
        // Drain; shared blocks stay resident with zero refs.
        for _ in 0..20 {
            s.execute_wave(0);
        }
        assert_eq!(s.active_total(), 0);
        assert_eq!(s.prefix[0].resident_blocks(), 2);
        assert!((s.prefix_shared_tokens() - 512.0).abs() < 1e-9);
        assert!(!s.kv_over_capacity());
    }

    #[test]
    fn prefix_blocks_are_evicted_under_admission_pressure() {
        // Column fits ~1.5 reservations. After request 0 drains, its shared
        // blocks idle at zero refs; request 1 (different prefix) must evict
        // them to fit.
        let trace = vec![preq(0, 600, 4, 1, 512), preq(1, 700, 4, 2, 512)];
        let kv = tiny_kv(1100, 1);
        let mut s = Scheduler::new(&trace, &kv, 1, SchedulerConfig::default(), 1.0);
        s.enqueue_arrival(0);
        s.admit_wave(0);
        for _ in 0..10 {
            s.execute_wave(0);
        }
        assert_eq!(s.active_total(), 0);
        assert!((s.prefix_shared_tokens() - 512.0).abs() < 1e-9);
        s.enqueue_arrival(1);
        s.admit_wave(0);
        assert_eq!(s.active_total(), 1, "eviction must make room");
        assert!(s.prefix_evictions() > 0);
        assert!(!s.kv_over_capacity());
    }

    #[test]
    fn sjf_admits_shortest_prompt_first() {
        let trace = vec![req(0, 4000, 8), req(1, 100, 8), req(2, 900, 8)];
        let kv = tiny_kv(100_000, 1);
        let cfg = SchedulerConfig {
            queue_policy: QueuePolicy::Sjf,
            max_batch_per_chip: 1,
            ..Default::default()
        };
        let mut s = Scheduler::new(&trace, &kv, 1, cfg, 1.0);
        for i in 0..3 {
            s.enqueue_arrival(i);
        }
        s.admit_wave(0);
        assert_eq!(s.active_total(), 1, "one slot only");
        // The resident must be the shortest prompt (record 1).
        let ev = s.execute_wave(0);
        assert_eq!(ev.prefill_tokens, 100);
        assert_eq!(ev.first_tokens, vec![1]);
    }

    #[test]
    fn priority_policy_admits_urgent_first() {
        let mut r0 = req(0, 500, 8);
        r0.priority = 3;
        let mut r1 = req(1, 800, 8);
        r1.priority = 0;
        let trace = vec![r0, r1];
        let kv = tiny_kv(100_000, 1);
        let cfg = SchedulerConfig {
            queue_policy: QueuePolicy::Priority,
            max_batch_per_chip: 1,
            ..Default::default()
        };
        let mut s = Scheduler::new(&trace, &kv, 1, cfg, 1.0);
        s.enqueue_arrival(0);
        s.enqueue_arrival(1);
        s.admit_wave(0);
        let ev = s.execute_wave(0);
        assert_eq!(ev.prefill_tokens, 800, "priority 0 (record 1) runs first");
    }

    #[test]
    fn token_hash_keying_shares_across_aliased_families() {
        // Two distinct trace families (ids 3 and 9) carry the SAME content
        // hash — forked deployments of one seeded system prompt. Exact-id
        // keying re-prefills the second family cold; hashed-token-block
        // keying hits the blocks the first family published, so its hit
        // count is strictly above the exact-id baseline.
        let run = |keying: PrefixKeying| {
            let mut r0 = preq(0, 1024, 4, 3, 512);
            r0.prefix_hash = 0xAB;
            let mut r1 = preq(1, 1024, 4, 9, 512);
            r1.prefix_hash = 0xAB;
            let trace = vec![r0, r1];
            let kv = tiny_kv(100_000, 1);
            let cfg = SchedulerConfig { prefix_keying: keying, ..Default::default() };
            let mut s = Scheduler::new(&trace, &kv, 1, cfg, 1.0);
            s.enqueue_arrival(0);
            s.admit_wave(0);
            for _ in 0..10 {
                s.execute_wave(0);
            }
            s.enqueue_arrival(1);
            s.admit_wave(0);
            let hits = s.prefix_hit_tokens;
            for _ in 0..10 {
                s.execute_wave(0);
            }
            assert_eq!(s.active_total(), 0, "{keying:?}: both requests must drain");
            assert!(!s.kv_over_capacity());
            hits
        };
        let exact = run(PrefixKeying::ExactId);
        let hashed = run(PrefixKeying::TokenHash);
        assert_eq!(exact, 0, "distinct ids never share under exact keying");
        assert_eq!(hashed, 512, "identical content must hit across families");
        assert!(hashed > exact, "token-hash keying must beat the exact-id baseline");
    }

    #[test]
    fn token_hash_falls_back_to_id_when_hash_is_absent() {
        // Hand-built traces (hash 0) behave identically under both modes.
        let trace = vec![preq(0, 1024, 4, 7, 512), preq(1, 1024, 4, 7, 512)];
        let kv = tiny_kv(100_000, 1);
        let cfg = SchedulerConfig { prefix_keying: PrefixKeying::TokenHash, ..Default::default() };
        let mut s = Scheduler::new(&trace, &kv, 1, cfg, 1.0);
        s.enqueue_arrival(0);
        s.admit_wave(0);
        for _ in 0..10 {
            s.execute_wave(0);
        }
        s.enqueue_arrival(1);
        s.admit_wave(0);
        assert_eq!(s.prefix_hit_tokens, 512, "same-family reuse must survive the fallback");
    }

    #[test]
    fn prefilled_arrival_skips_prefill_and_resumes_from_token_one() {
        // A disaggregated handoff: KV arrives computed, token #1 was emitted
        // at the prefill-pool instance. No prefill tokens are billed, no
        // first token re-emitted, and the request completes after
        // (output − 1) decode iterations at tpi = 1.
        let mut r = req(0, 2048, 8);
        r.prefilled = true;
        let trace = vec![r];
        let kv = tiny_kv(100_000, 1);
        let mut s = Scheduler::new(&trace, &kv, 1, SchedulerConfig::default(), 1.0);
        s.enqueue_arrival(0);
        s.admit_wave(0);
        assert_eq!(s.active_total(), 1);
        assert_eq!(s.peak_cell_load(), (1, 0), "no prefill tokens may be pending");
        let mut decode_tokens = 0.0;
        let mut completions = 0usize;
        for i in 0..7 {
            let ev = s.execute_wave(0);
            assert_eq!(ev.prefill_tokens, 0, "iteration {i} billed prefill");
            assert!(ev.first_tokens.is_empty(), "token #1 was emitted upstream");
            decode_tokens += ev.tokens_produced;
            completions += ev.completions.len();
        }
        assert_eq!(completions, 1);
        assert!((decode_tokens - 7.0).abs() < 1e-9, "7 of 8 tokens decode here, got {decode_tokens}");
        assert_eq!(s.active_total(), 0);
        assert!(!s.kv_over_capacity());
    }

    #[test]
    fn queue_policy_parse_roundtrip() {
        for p in [QueuePolicy::Fcfs, QueuePolicy::Sjf, QueuePolicy::Priority] {
            assert_eq!(QueuePolicy::parse(p.label()), Some(p));
        }
        assert_eq!(QueuePolicy::parse("FCFS"), Some(QueuePolicy::Fcfs));
        assert_eq!(QueuePolicy::parse("nope"), None);
    }

    #[test]
    fn kv_model_based_scheduler_smoke() {
        // End-to-end with the real EP32-PP2 KV model: admit 64 requests,
        // run some iterations, conservation of residents + queue holds.
        let sys = WaferSystem::paper();
        let ds = DeepSeekConfig::v3_671b();
        let kv = KvCacheModel::new(&sys, &ds, ParallelismPlan::new(32, 2), Dtype::Fp8);
        let trace: Vec<Request> = (0..64).map(|i| req(i, 512, 64)).collect();
        let mut s = Scheduler::new(&trace, &kv, 2, SchedulerConfig::default(), ds.tokens_per_iteration());
        for i in 0..64 {
            s.enqueue_arrival(i);
        }
        let mut completed = 0usize;
        for t in 0..2000 {
            let w = t % 2;
            s.admit_wave(w);
            s.grow_wave(w);
            completed += s.execute_wave(w).completions.len();
            assert!(!s.kv_over_capacity());
        }
        assert_eq!(completed, 64);
        assert_eq!(s.active_total(), 0);
    }

    #[test]
    fn abort_all_returns_work_and_zeroes_the_ledger() {
        let trace = vec![preq(0, 512, 64, 7, 512), req(1, 500, 100), req(2, 500, 100), req(3, 50_000, 1)];
        let kv = tiny_kv(1300, 1);
        let mut s = Scheduler::new(
            &trace,
            &kv,
            1,
            SchedulerConfig { prefix_block_tokens: 256, ..Default::default() },
            1.0,
        );
        for i in 0..4 {
            s.enqueue_arrival(i);
        }
        s.admit_wave(0);
        // Requests 0 and 1 fit; 2 blocks head-of-line on KV; 3 waits behind.
        assert_eq!(s.active_total(), 2);
        assert_eq!(s.queue.len(), 2);
        // Run a few iterations so request 0 publishes its prefix blocks and
        // both residents make decode progress.
        for _ in 0..8 {
            s.execute_wave(0);
        }
        assert!(s.prefix[0].resident_blocks() > 0, "shared blocks published before the kill");
        let (queued, in_flight) = s.abort_all();
        assert_eq!(queued.iter().map(|w| w.rec).collect::<Vec<_>>(), vec![2, 3]);
        assert_eq!(in_flight.iter().map(|w| w.rec).collect::<Vec<_>>(), vec![0, 1], "residents in admission order");
        assert!(in_flight.iter().any(|w| w.generated > 0.0), "decode progress is reported as lost");
        // The instance's KV is gone: nothing resident, nothing queued, and
        // the column ledger (private + shared) drops to zero.
        assert_eq!(s.active_total(), 0);
        assert!(s.queue.is_empty());
        assert_eq!(s.prefix[0].resident_blocks(), 0, "prefix cache dies with the HBM");
        assert!(s.columns[0].held_tokens.abs() < 1e-9, "ledger must be empty, holds {}", s.columns[0].held_tokens);
        // The scheduler is reusable after the wipe (a restarted instance).
        s.enqueue_arrival(1);
        s.admit_wave(0);
        assert_eq!(s.active_total(), 1);
    }

    #[test]
    fn admission_pressure_eviction_between_probe_and_pin_is_reconciled() {
        // Geometry that forces the probe→evict→pin interleaving: request 2
        // probes a full 4-block hit on family 9, then its own admission
        // pressure evicts the chain tail (9,3) before the pin. The pin must
        // come back 256 tokens short and the scheduler must re-bill that
        // shortfall as private KV (evicting family 8's sacrificial zero-ref
        // block to make room) instead of skewing the ledger.
        let trace = vec![
            preq(0, 1024, 2, 9, 1024), // publishes family 9: blocks (9,0..3)
            preq(1, 512, 2, 8, 512),   // publishes family 8: blocks (8,0..1), newer LRU
            preq(2, 2048, 2, 9, 1024), // the victim of the interleaving
        ];
        let kv = tiny_kv(4000, 1);
        let mut s = Scheduler::new(
            &trace,
            &kv,
            1,
            SchedulerConfig { prefix_block_tokens: 256, ..Default::default() },
            1.0,
        );
        for rec in [0, 1] {
            s.enqueue_arrival(rec);
            s.admit_wave(0);
            for _ in 0..3 {
                s.execute_wave(0);
            }
        }
        // Both publishers completed; 1536 zero-ref shared tokens resident.
        assert_eq!(s.active_total(), 0);
        assert_eq!(s.prefix[0].resident_blocks(), 6);
        assert_eq!(s.prefix_evictions(), 0);
        // Shrink free space so request 2's reservation (need = 2054 − 1024
        // hit = 1030) runs 30 tokens short: free = 4000 − 1536 − 1464 =
        // 1000. Pressure evicts exactly one LRU chain tail — (9,3), a block
        // the probe just counted.
        assert!(s.columns[0].reserve(1464.0));
        s.enqueue_arrival(2);
        s.admit_wave(0);
        assert_eq!(s.active_total(), 1, "request must still admit, recomputing the evicted share");
        // Two evictions: (9,3) under admission pressure, then (8,1) to make
        // room for the re-billed 256-token shortfall.
        assert_eq!(s.prefix_evictions(), 2);
        // Hit/miss accounting uses the pinned (768), not probed (1024),
        // figure: the evicted block counts as a miss.
        assert_eq!(s.prefix_hit_tokens, 768);
        assert_eq!(s.prefix_miss_tokens, 1024 + 512 + 256);
        assert!(!s.kv_over_capacity());
        // The admitted request can run to completion on the books it holds.
        let mut completions = 0;
        for _ in 0..8 {
            completions += s.execute_wave(0).completions.len();
        }
        assert_eq!(completions, 1);
        assert!(!s.kv_over_capacity());
    }
}
