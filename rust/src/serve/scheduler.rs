//! Iteration-level continuous-batching scheduler over the wafer's EP
//! columns and PP waves.
//!
//! Topology recap (matches the wave-pipelined decode model of
//! `multichip::parallelism`): an EP×PP plan gives `ep` *columns* × `pp`
//! *waves*. Each (column, wave) cell owns up to `max_batch_per_chip` user
//! slots on one chip; the KV budget is per *column* (all waves of a column
//! share the same chips' HBM, see `serve::kv`).
//!
//! Per wave-iteration the scheduler:
//! 1. admits waiting requests FCFS into the wave's freest column, gated by
//!    the KV admission policy;
//! 2. (on-demand policy) reserves this iteration's KV growth, preempting the
//!    newest resident of an over-committed column — preempted requests lose
//!    their cache and re-enter the queue head for recomputation;
//! 3. executes the iteration: chunked prefill first (budget
//!    `prefill_chunk_tokens` per chip), the prefill-finishing iteration
//!    emits the first token, decoding users advance by
//!    `tokens_per_iteration`, finished users free their slot and KV.

use std::collections::VecDeque;

use crate::serve::kv::{KvCacheModel, KvColumn};
use crate::serve::request::Request;

/// KV admission policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Reserve the full final context (prompt + output + margin) at
    /// admission: no preemption can ever be needed (vLLM's conservative
    /// mode). Queue-delays under pressure instead.
    ReserveFull,
    /// Reserve only the current context and grow per iteration; on
    /// overflow, preempt the newest resident for recomputation.
    OnDemandPreempt,
}

/// Scheduler knobs.
#[derive(Debug, Clone, Copy)]
pub struct SchedulerConfig {
    /// Decode user slots per (column, wave) cell — per-chip batch ceiling.
    pub max_batch_per_chip: u32,
    /// Prefill tokens one chip may process per iteration (chunked prefill
    /// riding the decode iterations).
    pub prefill_chunk_tokens: u32,
    pub policy: AdmissionPolicy,
    /// Safety margin on reservations (draft-token overshoot of MTP).
    pub reserve_margin_tokens: f64,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            max_batch_per_chip: 512,
            // SLO-aware chunk: small enough that one chip's prefill work
            // cannot stretch the whole EP group's iteration far past the
            // decode TPOT budget (the reason chunked prefill exists), large
            // enough that aggregate prefill bandwidth clears the offered
            // prompt load below the intended saturation knee.
            prefill_chunk_tokens: 1024,
            policy: AdmissionPolicy::ReserveFull,
            reserve_margin_tokens: 4.0,
        }
    }
}

/// A resident request in one (column, wave) cell.
#[derive(Debug, Clone)]
struct Active {
    rec: usize,
    admit_seq: u64,
    /// Context tokens still to prefill (full context on re-admission after
    /// a preemption — recomputation).
    remaining_prefill: u32,
    /// Output tokens generated so far (fractional: MTP expected tokens).
    generated: f64,
    /// KV tokens currently reserved on the column for this request.
    held_tokens: f64,
}

/// A queued request (fresh arrival or preempted resident).
#[derive(Debug, Clone, Copy)]
pub struct Waiting {
    pub rec: usize,
    pub generated: f64,
}

/// What happened during one wave iteration.
#[derive(Debug, Clone, Default)]
pub struct WaveEvents {
    /// Records whose first output token was emitted this iteration.
    pub first_tokens: Vec<usize>,
    /// Records that finished this iteration.
    pub completions: Vec<usize>,
    /// Output tokens produced this iteration (completion-clamped).
    pub tokens_produced: f64,
    /// Prefill tokens processed this iteration.
    pub prefill_tokens: u64,
    /// Users that ran a decode step this iteration.
    pub decode_users: u32,
}

pub struct Scheduler<'t> {
    trace: &'t [Request],
    cfg: SchedulerConfig,
    /// Expected tokens per decode iteration (MTP).
    tokens_per_iter: f64,
    pub columns: Vec<KvColumn>,
    /// actives[wave][column] → residents in admission order.
    actives: Vec<Vec<Vec<Active>>>,
    pub queue: VecDeque<Waiting>,
    admit_seq: u64,
    pub preemptions: u64,
    /// Records rejected at admission (can never fit a column).
    pub rejected: Vec<usize>,
}

impl<'t> Scheduler<'t> {
    pub fn new(
        trace: &'t [Request],
        kv: &KvCacheModel,
        waves: u32,
        cfg: SchedulerConfig,
        tokens_per_iter: f64,
    ) -> Self {
        Scheduler {
            trace,
            cfg,
            tokens_per_iter,
            columns: (0..kv.columns).map(|_| KvColumn::new(kv.column_capacity_tokens)).collect(),
            actives: (0..waves)
                .map(|_| (0..kv.columns).map(|_| Vec::new()).collect())
                .collect(),
            queue: VecDeque::new(),
            admit_seq: 0,
            preemptions: 0,
            rejected: Vec::new(),
        }
    }

    pub fn enqueue_arrival(&mut self, rec: usize) {
        self.queue.push_back(Waiting { rec, generated: 0.0 });
    }

    fn final_need(&self, r: &Request) -> f64 {
        r.total_tokens() as f64 + self.cfg.reserve_margin_tokens
    }

    fn admit_need(&self, r: &Request, generated: f64) -> f64 {
        match self.cfg.policy {
            AdmissionPolicy::ReserveFull => self.final_need(r),
            AdmissionPolicy::OnDemandPreempt => {
                r.prompt_tokens as f64 + generated + self.cfg.reserve_margin_tokens
            }
        }
    }

    /// FCFS admission into wave `w` (head-of-line blocking on KV pressure,
    /// as a fair FCFS queue must).
    pub fn admit_wave(&mut self, w: usize) {
        loop {
            let Some(&head) = self.queue.front() else { break };
            let r = self.trace[head.rec];
            if self.final_need(&r) > self.columns[0].capacity_tokens {
                self.queue.pop_front();
                self.rejected.push(head.rec);
                continue;
            }
            // Freest column among those with a spare slot in this wave.
            let mut best: Option<usize> = None;
            for c in 0..self.columns.len() {
                if self.actives[w][c].len() >= self.cfg.max_batch_per_chip as usize {
                    continue;
                }
                if best.map_or(true, |b| self.columns[c].free_tokens() > self.columns[b].free_tokens()) {
                    best = Some(c);
                }
            }
            let Some(c) = best else { break };
            let need = self.admit_need(&r, head.generated);
            if !self.columns[c].reserve(need) {
                break;
            }
            self.queue.pop_front();
            // Re-admission recomputes the whole context (prompt + tokens
            // generated before preemption).
            let context = r.prompt_tokens as u64 + head.generated.floor() as u64;
            self.actives[w][c].push(Active {
                rec: head.rec,
                admit_seq: self.admit_seq,
                remaining_prefill: context.min(u32::MAX as u64) as u32,
                generated: head.generated,
                held_tokens: need,
            });
            self.admit_seq += 1;
        }
    }

    /// On-demand KV growth for wave `w`'s decoders, preempting the newest
    /// resident of any over-committed column (recomputation preemption).
    pub fn grow_wave(&mut self, w: usize) {
        if self.cfg.policy != AdmissionPolicy::OnDemandPreempt {
            return;
        }
        for c in 0..self.columns.len() {
            loop {
                let growers = self.actives[w][c].iter().filter(|a| a.remaining_prefill == 0).count();
                let need = growers as f64 * self.tokens_per_iter;
                if need <= 0.0 || self.columns[c].fits(need) {
                    if need > 0.0 {
                        assert!(self.columns[c].reserve(need));
                        for a in self.actives[w][c].iter_mut() {
                            if a.remaining_prefill == 0 {
                                a.held_tokens += self.tokens_per_iter;
                            }
                        }
                    }
                    break;
                }
                if !self.preempt_newest_in_column(c) {
                    break;
                }
            }
        }
    }

    /// Evict the newest resident (largest admit_seq) of column `c` back to
    /// the queue head. Returns false if the column is empty.
    fn preempt_newest_in_column(&mut self, c: usize) -> bool {
        let mut newest: Option<(usize, usize, u64)> = None; // (wave, idx, seq)
        for (w, per_col) in self.actives.iter().enumerate() {
            for (i, a) in per_col[c].iter().enumerate() {
                if newest.map_or(true, |(_, _, seq)| a.admit_seq > seq) {
                    newest = Some((w, i, a.admit_seq));
                }
            }
        }
        let Some((w, i, _)) = newest else { return false };
        let victim = self.actives[w][c].remove(i);
        self.columns[c].release(victim.held_tokens);
        self.queue.push_front(Waiting { rec: victim.rec, generated: victim.generated });
        self.preemptions += 1;
        true
    }

    /// Execute one iteration of wave `w`: chunked prefill, first-token
    /// emission, decode progress, completions.
    pub fn execute_wave(&mut self, w: usize) -> WaveEvents {
        let mut ev = WaveEvents::default();
        let tpi = self.tokens_per_iter;
        for c in 0..self.columns.len() {
            let mut budget = self.cfg.prefill_chunk_tokens;
            let mut done: Vec<usize> = Vec::new();
            for i in 0..self.actives[w][c].len() {
                let a = &mut self.actives[w][c][i];
                let r = &self.trace[a.rec];
                if a.remaining_prefill > 0 {
                    let take = a.remaining_prefill.min(budget);
                    a.remaining_prefill -= take;
                    budget -= take;
                    ev.prefill_tokens += take as u64;
                    if a.remaining_prefill == 0 && take > 0 {
                        // The prefill-finishing iteration emits token #1.
                        a.generated += 1.0;
                        ev.first_tokens.push(a.rec);
                        ev.tokens_produced += 1.0;
                        if a.generated + 1e-9 >= r.output_tokens as f64 {
                            done.push(i);
                            ev.completions.push(a.rec);
                        }
                    }
                } else {
                    let before = a.generated;
                    a.generated += tpi;
                    ev.decode_users += 1;
                    ev.tokens_produced += (r.output_tokens as f64 - before).clamp(0.0, tpi);
                    if a.generated + 1e-9 >= r.output_tokens as f64 {
                        done.push(i);
                        ev.completions.push(a.rec);
                    }
                }
            }
            // Release completed residents (reverse order keeps indices valid).
            for &i in done.iter().rev() {
                let a = self.actives[w][c].remove(i);
                self.columns[c].release(a.held_tokens);
            }
        }
        ev
    }

    /// Worst-case per-chip iteration load across all (wave, column) cells:
    /// `(decode users, prefill tokens co-scheduled next iteration)`. The
    /// two maxima may come from different cells — the stage-time lookup
    /// combines them, which errs conservative. Drives the tick duration.
    pub fn peak_cell_load(&self) -> (u64, u64) {
        let mut decode_max = 0u64;
        let mut prefill_max = 0u64;
        for per_col in &self.actives {
            for cell in per_col {
                let decode_users = cell.iter().filter(|a| a.remaining_prefill == 0).count() as u64;
                let prefill_pending: u64 = cell.iter().map(|a| a.remaining_prefill as u64).sum();
                decode_max = decode_max.max(decode_users);
                prefill_max = prefill_max.max(prefill_pending.min(self.cfg.prefill_chunk_tokens as u64));
            }
        }
        (decode_max, prefill_max)
    }

    /// Longest current context (prompt + generated) among residents, in
    /// tokens — the KV length the stage-time lookup should assume.
    pub fn max_context_tokens(&self) -> f64 {
        let mut max = 0.0f64;
        for per_col in &self.actives {
            for cell in per_col {
                for a in cell {
                    let ctx = self.trace[a.rec].prompt_tokens as f64 + a.generated;
                    max = max.max(ctx);
                }
            }
        }
        max
    }

    pub fn active_total(&self) -> usize {
        self.actives.iter().map(|pc| pc.iter().map(Vec::len).sum::<usize>()).sum()
    }

    /// Highest KV occupancy fraction reached on any column so far.
    pub fn peak_kv_occupancy(&self) -> f64 {
        self.columns.iter().map(KvColumn::peak_frac).fold(0.0, f64::max)
    }

    /// True iff some column currently holds more than its capacity (must
    /// never happen; surfaced for the invariant tests).
    pub fn kv_over_capacity(&self) -> bool {
        self.columns.iter().any(|c| c.held_tokens > c.capacity_tokens + 1e-6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::config::Dtype;
    use crate::multichip::d2d::WaferSystem;
    use crate::multichip::parallelism::ParallelismPlan;
    use crate::workload::deepseek::DeepSeekConfig;

    fn tiny_kv(capacity_tokens: u64, columns: u32) -> KvCacheModel {
        // Hand-built small model for policy tests.
        KvCacheModel {
            bytes_per_token_per_chip: 576,
            weight_bytes_per_chip: 0,
            hbm_capacity_bytes: capacity_tokens * 576,
            column_capacity_tokens: capacity_tokens,
            columns,
        }
    }

    fn req(id: u64, prompt: u32, output: u32) -> Request {
        Request { id, arrival_s: 0.0, prompt_tokens: prompt, output_tokens: output }
    }

    #[test]
    fn admits_fcfs_and_prefills_in_chunks() {
        let trace = vec![req(0, 3000, 4), req(1, 100, 4)];
        let kv = tiny_kv(100_000, 1);
        let mut s = Scheduler::new(
            &trace,
            &kv,
            1,
            SchedulerConfig { prefill_chunk_tokens: 2048, ..Default::default() },
            1.0,
        );
        s.enqueue_arrival(0);
        s.enqueue_arrival(1);
        s.admit_wave(0);
        assert_eq!(s.active_total(), 2);
        // Tick 1: request 0 eats the whole chunk; request 1 stalls.
        let ev = s.execute_wave(0);
        assert_eq!(ev.prefill_tokens, 2048);
        assert!(ev.first_tokens.is_empty());
        // Tick 2: request 0 finishes (952) and request 1 (100) fits too.
        let ev = s.execute_wave(0);
        assert_eq!(ev.prefill_tokens, 952 + 100);
        assert_eq!(ev.first_tokens, vec![0, 1]);
    }

    #[test]
    fn reserve_full_never_overflows_and_blocks_head_of_line() {
        let trace = vec![req(0, 500, 100), req(1, 500, 100), req(2, 500, 100)];
        // Capacity fits two full reservations (604 each), not three.
        let kv = tiny_kv(1300, 1);
        let mut s = Scheduler::new(&trace, &kv, 1, SchedulerConfig::default(), 1.0);
        for i in 0..3 {
            s.enqueue_arrival(i);
        }
        s.admit_wave(0);
        assert_eq!(s.active_total(), 2);
        assert_eq!(s.queue.len(), 1, "third request must wait");
        assert!(!s.kv_over_capacity());
        // Run everything to completion; capacity is never exceeded.
        for _ in 0..300 {
            s.admit_wave(0);
            s.grow_wave(0);
            s.execute_wave(0);
            assert!(!s.kv_over_capacity());
        }
        assert_eq!(s.active_total(), 0);
        assert_eq!(s.queue.len(), 0);
        assert_eq!(s.preemptions, 0, "ReserveFull never preempts");
    }

    #[test]
    fn infeasible_request_is_rejected_not_wedged() {
        let trace = vec![req(0, 5000, 1000), req(1, 100, 10)];
        let kv = tiny_kv(2000, 1);
        let mut s = Scheduler::new(&trace, &kv, 1, SchedulerConfig::default(), 1.0);
        s.enqueue_arrival(0);
        s.enqueue_arrival(1);
        s.admit_wave(0);
        assert_eq!(s.rejected, vec![0]);
        assert_eq!(s.active_total(), 1, "queue must not wedge behind an impossible request");
    }

    #[test]
    fn on_demand_preempts_newest_and_recomputes() {
        let trace = vec![req(0, 400, 600), req(1, 400, 600)];
        // Each needs 1004 at completion; both admit on-demand (404 each)
        // but cannot both finish in a 1200-token column.
        let kv = tiny_kv(1200, 1);
        let cfg = SchedulerConfig { policy: AdmissionPolicy::OnDemandPreempt, ..Default::default() };
        let mut s = Scheduler::new(&trace, &kv, 1, cfg, 1.0);
        s.enqueue_arrival(0);
        s.enqueue_arrival(1);
        s.admit_wave(0);
        assert_eq!(s.active_total(), 2, "on-demand admits both");
        let mut preempted = false;
        for _ in 0..5000 {
            s.admit_wave(0);
            s.grow_wave(0);
            s.execute_wave(0);
            assert!(!s.kv_over_capacity(), "growth must never overflow the column");
            preempted |= s.preemptions > 0;
            if s.active_total() == 0 && s.queue.is_empty() {
                break;
            }
        }
        assert!(preempted, "KV pressure must have forced a preemption");
        assert_eq!(s.active_total() + s.queue.len(), 0, "both requests eventually drain");
    }

    #[test]
    fn peak_cell_load_counts_decode_and_chunked_prefill() {
        let trace = vec![req(0, 5000, 50), req(1, 64, 50)];
        let kv = tiny_kv(100_000, 1);
        let mut s = Scheduler::new(
            &trace,
            &kv,
            1,
            SchedulerConfig { prefill_chunk_tokens: 1024, ..Default::default() },
            1.0,
        );
        s.enqueue_arrival(0);
        s.enqueue_arrival(1);
        s.admit_wave(0);
        // Pending prefill 5064 capped at the 1024 chunk; no decoders yet.
        assert_eq!(s.peak_cell_load(), (0, 1024));
        for _ in 0..5 {
            s.execute_wave(0); // 5 chunks drain req0 (5000) and req1 (64)
        }
        let (decode, prefill) = s.peak_cell_load();
        assert_eq!(decode, 2, "both requests must be decoding");
        assert_eq!(prefill, 0);
    }

    #[test]
    fn kv_model_based_scheduler_smoke() {
        // End-to-end with the real EP32-PP2 KV model: admit 64 requests,
        // run some iterations, conservation of residents + queue holds.
        let sys = WaferSystem::paper();
        let ds = DeepSeekConfig::v3_671b();
        let kv = KvCacheModel::new(&sys, &ds, ParallelismPlan::new(32, 2), Dtype::Fp8);
        let trace: Vec<Request> = (0..64).map(|i| req(i, 512, 64)).collect();
        let mut s = Scheduler::new(&trace, &kv, 2, SchedulerConfig::default(), ds.tokens_per_iteration());
        for i in 0..64 {
            s.enqueue_arrival(i);
        }
        let mut completed = 0usize;
        for t in 0..2000 {
            let w = t % 2;
            s.admit_wave(w);
            s.grow_wave(w);
            completed += s.execute_wave(w).completions.len();
            assert!(!s.kv_over_capacity());
        }
        assert_eq!(completed, 64);
        assert_eq!(s.active_total(), 0);
    }
}
