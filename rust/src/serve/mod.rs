//! Request-level serving simulator on top of the wafer-scale decode model
//! (the layer the paper's §V-C steady-state operating points abstract away).
//!
//! The steady-state `multichip::parallelism::DecodeEvaluator` answers "what
//! is TPOT/throughput at a *fixed* batch and KV length"; production serving
//! instead sees request arrivals, mixed prompt/output lengths, shared
//! system prompts, KV-cache pressure and queueing. This module closes that
//! gap with a deterministic, iteration-level simulation:
//!
//! - [`request`] — seeded synthetic traces: Poisson / bursty / diurnal
//!   arrivals × prompt/output-length mixtures × shared-prefix populations
//!   ([`request::PrefixProfile`]) and priority classes, with coupled
//!   thinning for load sweeps.
//! - [`kv`] — per-chip KV capacity from the MLA latent cache layout
//!   (`DeepSeekConfig`), weights subtracted (plus any co-served model's
//!   reserved weights), organized per EP column; plus [`kv::PrefixStore`],
//!   the token-block trie behind prefix-cache KV reuse (hits skip prefill
//!   compute and KV admission; LRU chain-tail eviction under pressure).
//! - [`scheduler`] — continuous batching: iteration-level batch formation,
//!   chunked prefill riding decode iterations, FCFS / SJF / Priority queue
//!   policies, prefix-aware placement with exact-id or hashed-token-block
//!   prefix keying ([`scheduler::PrefixKeying`] — content hashes share
//!   blocks across families with identical seeded prefixes), pre-filled
//!   decode-pool arrivals (disaggregated handoffs skip prefill and resume
//!   from one generated token), and reserve-full or on-demand+preemption KV
//!   admission.
//! - [`prefill`] — the dataflow-grounded prefill cost model: each chunk is
//!   billed by the actual FlatAttention/FlashAttention dataflow simulation
//!   of its causal attention shape at the request's context offset
//!   (replacing PR 1's marginal-row approximation).
//! - [`sim`] — the steppable serving engine: [`sim::ServeEngine`] owns the
//!   scheduler, the stage-time model (memoized decode stage times from
//!   [`DecodeEvaluator`](crate::multichip::parallelism::DecodeEvaluator)
//!   plus [`prefill::PrefillEngine`] chunk billing), the clock and the
//!   per-request records; `step()` advances exactly one wave iteration,
//!   `inject()` accepts arrivals mid-simulation (the cluster fleet's hook
//!   for routed arrivals and disaggregated KV handoffs), and `snapshot()`
//!   exposes the live state (clock, queue depth, KV occupancy, active
//!   users) that live routing policies read. [`sim::simulate`] is a thin
//!   driver loop over the engine, emitting TTFT/TPOT p50/p95/p99, system
//!   tokens/s, SLO goodput and prefix-cache hit rates; [`sim::load_sweep`]
//!   produces goodput-vs-offered-load curves and [`sim::saturation_knee`]
//!   finds the knee (robust to unsorted sweep inputs).
//!
//! The engine is the composition unit of the [`cluster`](crate::cluster)
//! layer: the interleaved fleet is N engines on one global event clock,
//! always stepping the one with the smallest local clock. The 1-instance
//! equivalence (fleet == `simulate`, byte-identical) is pinned by test.
//!
//! Entry points: `flatattention serve` (CLI), experiment ids `serve_load`,
//! `serve_policies` and `serve_prefix`, `examples/serving.rs`,
//! `benches/serve_load.rs`.

pub mod kv;
pub mod prefill;
pub mod request;
pub mod scheduler;
pub mod sim;

pub use kv::{KvCacheModel, PrefixStore};
pub use prefill::PrefillEngine;
pub use request::{
    generate_trace, thin_trace, LengthProfile, PrefixProfile, Request, TraceConfig, TrafficPattern,
};
pub use scheduler::{AdmissionPolicy, PrefixKeying, QueuePolicy, SchedEvent, Scheduler, SchedulerConfig};
pub use sim::{
    load_sweep, saturation_knee, simulate, simulate_observed, EngineSnapshot, ServeConfig, ServeEngine,
    ServeOutcome, StageTimeCache, Step,
};
