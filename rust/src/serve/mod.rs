//! Request-level serving simulator on top of the wafer-scale decode model
//! (the layer the paper's §V-C steady-state operating points abstract away).
//!
//! The steady-state `multichip::parallelism::DecodeEvaluator` answers "what
//! is TPOT/throughput at a *fixed* batch and KV length"; production serving
//! instead sees request arrivals, mixed prompt/output lengths, KV-cache
//! pressure and queueing. This module closes that gap with a deterministic,
//! iteration-level simulation:
//!
//! - [`request`] — seeded synthetic traces: Poisson / bursty / diurnal
//!   arrivals × prompt/output-length mixtures, with coupled thinning for
//!   load sweeps.
//! - [`kv`] — per-chip KV capacity from the MLA latent cache layout
//!   (`DeepSeekConfig`), weights subtracted, organized per EP column.
//! - [`scheduler`] — continuous batching: iteration-level batch formation,
//!   chunked prefill riding decode iterations, FCFS admission with
//!   reserve-full or on-demand+preemption KV policies.
//! - [`sim`] — the event loop driving memoized stage times from
//!   [`DecodeEvaluator`](crate::multichip::parallelism::DecodeEvaluator),
//!   emitting TTFT/TPOT p50/p95/p99, system tokens/s and SLO goodput, plus
//!   [`sim::load_sweep`] for goodput-vs-offered-load curves and
//!   [`sim::saturation_knee`] detection.
//!
//! Entry points: `flatattention serve` (CLI), experiment ids `serve_load`
//! and `serve_policies`, `examples/serving.rs`, `benches/serve_load.rs`.

pub mod kv;
pub mod request;
pub mod scheduler;
pub mod sim;

pub use kv::KvCacheModel;
pub use request::{generate_trace, thin_trace, LengthProfile, Request, TraceConfig, TrafficPattern};
pub use scheduler::{AdmissionPolicy, Scheduler, SchedulerConfig};
pub use sim::{load_sweep, saturation_knee, simulate, ServeConfig, ServeOutcome, StageTimeCache};
