//! Dataflow-grounded prefill cost model for the serving loop.
//!
//! PR 1 billed co-scheduled prefill tokens at the decode evaluator's
//! *marginal per-row* cost — a GEMM-only approximation that ignored the
//! phase change: prefill attention is compute-bound (Fig. 1a/1b) while
//! decode is memory-bound, and an MLA prefill chunk additionally pays the
//! un-absorbed K/V recompute over its whole context offset. This module
//! replaces that path end-to-end: each chunk is billed by the *actual*
//! FlatAttention / FlashAttention dataflow simulation of its causal
//! [`AttentionShape`](crate::workload::attention::AttentionShape) at the
//! request's current context offset, composed through the same per-layer
//! kernel flow machinery the decode evaluator uses
//! ([`prefill_layer_kernels`]).
//!
//! Chunk stage times are memoized per (system, model, plan, dataflow,
//! chunk-bucket, context-bucket) in the shared
//! [`StageTimeCache`](crate::serve::sim::StageTimeCache), on top of the
//! kernel-level [`KernelCache`] — the serving loop never re-simulates an
//! identical chunk shape, and GEMM/vector kernels whose shapes coincide
//! with decode kernels hit the same entries the decode evaluator populated.

use crate::arch::config::{Dtype, SimFidelity};
use crate::dataflow::{simulate_kernel, AttentionDataflow};
use crate::metrics::KernelMetrics;
use crate::multichip::d2d::WaferSystem;
use crate::multichip::parallelism::{AttentionChoice, KernelCache, ParallelismPlan};
use crate::obs::attrib::{AttribClass, StageAttrib};
use crate::serve::sim::{kv_bucket, StageTimeCache};
use crate::workload::attention::AttentionShape;
use crate::workload::deepseek::{prefill_layer_kernels, DeepSeekConfig, KernelClass, MoePlacement};

/// Quantize a chunk size for the stage-time memo: the next power of two.
/// Chunks are bounded by `prefill_chunk_tokens` (1k by default), so this
/// keeps the number of distinct chunk evaluations logarithmic while
/// rounding *up* stays conservative.
pub fn chunk_bucket(tokens: u64) -> u32 {
    (tokens.clamp(1, 1 << 20) as u32).next_power_of_two()
}

/// Stage-time oracle for prefill chunks of one (system, model, plan,
/// dataflow) combination. Mirrors the decode evaluator's structure: build
/// the per-layer kernel flow, simulate each kernel on the chip (memoized),
/// add EP all-to-all dispatch/combine and the PP boundary transfer, and
/// scale by the layers per pipeline stage.
pub struct PrefillEngine<'a> {
    sys: &'a WaferSystem,
    ds: &'a DeepSeekConfig,
    plan: ParallelismPlan,
    choice: AttentionChoice,
    fidelity: SimFidelity,
    dtype: Dtype,
    kernels: KernelCache,
    stages: StageTimeCache,
    /// Constant cache-key prefix (system fingerprint, D2D, model, fidelity,
    /// dtype, dataflow, plan) — only `|pfc{}|ctx{}` varies per lookup.
    key_prefix: String,
}

impl<'a> PrefillEngine<'a> {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        sys: &'a WaferSystem,
        ds: &'a DeepSeekConfig,
        plan: ParallelismPlan,
        choice: AttentionChoice,
        fidelity: SimFidelity,
        dtype: Dtype,
        kernels: KernelCache,
        stages: StageTimeCache,
    ) -> Self {
        let key_prefix = format!(
            "prefill|{}|d2d{}x{}+{:.4e}bps+{:.1e}s|{}L{}d{}|{:?}|{:?}|{}|ep{}pp{}",
            sys.chip.fingerprint(),
            sys.d2d.mesh_x,
            sys.d2d.mesh_y,
            sys.d2d.link_bandwidth_bytes_per_s,
            sys.d2d.hop_latency_s,
            ds.name,
            ds.layers,
            ds.d_model,
            fidelity,
            dtype,
            choice.label(),
            plan.ep,
            plan.pp,
        );
        PrefillEngine { sys, ds, plan, choice, fidelity, dtype, kernels, stages, key_prefix }
    }

    /// The (chunk, context) operating point a lookup is actually billed at.
    pub fn bucketed(&self, chunk_tokens: u64, context_tokens: f64) -> (u32, u32) {
        let chunk = chunk_bucket(chunk_tokens);
        let ctx = kv_bucket(context_tokens.max(chunk as f64), self.ds.max_context);
        (chunk, ctx.max(chunk))
    }

    /// The causal chunk attention shape billed at a bucketed operating
    /// point (exposed so regression tests can evaluate the identical shape
    /// directly against the dataflow simulation).
    pub fn chunk_shape(&self, chunk: u32, context: u32) -> AttentionShape {
        self.ds.mla_prefill_shape(chunk, context, self.dtype)
    }

    /// Memoized stage seconds one pipeline stage spends on a prefill chunk
    /// of `chunk_tokens` at `context_tokens` total context.
    pub fn chunk_stage_seconds(&self, chunk_tokens: u64, context_tokens: f64) -> f64 {
        if chunk_tokens == 0 {
            return 0.0;
        }
        let (chunk, ctx) = self.bucketed(chunk_tokens, context_tokens);
        let key = format!("{}|pfc{}|ctx{}", self.key_prefix, chunk, ctx);
        let stages = self.stages.clone();
        stages.get_or_insert_with(key, || self.evaluate_chunk(chunk, ctx))
    }

    /// Direct (unmemoized at stage level; kernel-memoized) dataflow
    /// evaluation of one chunk: the ground truth `chunk_stage_seconds`
    /// must match.
    pub fn evaluate_chunk(&self, chunk: u32, context: u32) -> f64 {
        let cfg = &self.sys.chip;
        let chip_fp = cfg.fingerprint();
        let rows = chunk.max(1) as u64;

        // MoE routing statistics across the EP group (every column runs a
        // comparable chunk concurrently in the worst iteration).
        let group_tokens = rows * self.plan.ep as u64;
        let total_pairs = group_tokens * self.ds.experts_per_token as u64;
        let active_total = total_pairs.min(self.ds.n_experts as u64).max(1);
        let rows_per_expert = total_pairs.div_ceil(active_total);
        let active_per_chip = active_total
            .div_ceil(self.plan.ep as u64)
            .min((self.ds.n_experts / self.plan.ep).max(1) as u64);
        let moe = MoePlacement { experts_on_chip: active_per_chip as u32, rows_per_expert };

        // Per-layer kernel times (attention at the causal chunk shape).
        let kernels = prefill_layer_kernels(self.ds, chunk, context, self.dtype, moe);
        let mut layer_s = 0.0;
        let mut moe_s = 0.0;
        for k in &kernels {
            let m = self.kernel(&chip_fp, &k.class);
            layer_s += m.seconds;
            if k.name.starts_with("moe.") {
                moe_s += m.seconds;
            }
        }

        // C2C dispatch + combine per MoE layer (within the EP group).
        let dispatch_bytes = rows as f64
            * self.ds.experts_per_token as f64
            * self.ds.d_model as f64
            * self.dtype.bytes() as f64;
        let c2c_s = 2.0 * self.sys.d2d.all_to_all_seconds(self.plan.ep, dispatch_bytes);
        let moe_layer_s = layer_s + c2c_s;

        // Dense leading layers: replace MoE kernels with the dense FFN.
        let d = self.ds.d_model as u64;
        let di = self.ds.dense_inter as u64;
        let up = self.kernel(&chip_fp, &KernelClass::Gemm { m: rows, k: d, n: 2 * di, batch: 1 });
        let down = self.kernel(&chip_fp, &KernelClass::Gemm { m: rows, k: di, n: d, batch: 1 });
        let dense_layer_s = moe_layer_s - c2c_s - moe_s + up.seconds + down.seconds;

        // Stage time: layers split over pipeline stages + PP boundary xfer.
        let moe_layers = (self.ds.layers - self.ds.dense_layers) as f64;
        let per_stage_moe = moe_layers / self.plan.pp as f64;
        let per_stage_dense = self.ds.dense_layers as f64 / self.plan.pp as f64;
        let boundary = if self.plan.pp > 1 {
            self.sys
                .d2d
                .neighbor_transfer_seconds(rows as f64 * d as f64 * self.dtype.bytes() as f64)
        } else {
            0.0
        };
        per_stage_moe * moe_layer_s + per_stage_dense * dense_layer_s + boundary
    }

    /// Attribution re-walk of [`PrefillEngine::evaluate_chunk`]: the
    /// identical kernel walk and per-stage scaling, billed per kernel class
    /// with the simulated FLOPs/HBM-bytes/utilizations attached. Unsettled —
    /// the caller pins it to the memoized chunk time via
    /// [`StageAttrib::settle`]. Kernel-memoized, so after the first
    /// evaluation of a (chunk, context) bucket this is pure arithmetic.
    pub fn evaluate_chunk_attrib(&self, chunk: u32, context: u32) -> StageAttrib {
        let chip_fp = self.sys.chip.fingerprint();
        let rows = chunk.max(1) as u64;

        let group_tokens = rows * self.plan.ep as u64;
        let total_pairs = group_tokens * self.ds.experts_per_token as u64;
        let active_total = total_pairs.min(self.ds.n_experts as u64).max(1);
        let rows_per_expert = total_pairs.div_ceil(active_total);
        let active_per_chip = active_total
            .div_ceil(self.plan.ep as u64)
            .min((self.ds.n_experts / self.plan.ep).max(1) as u64);
        let moe = MoePlacement { experts_on_chip: active_per_chip as u32, rows_per_expert };

        let moe_layers = (self.ds.layers - self.ds.dense_layers) as f64;
        let per_stage_moe = moe_layers / self.plan.pp as f64;
        let per_stage_dense = self.ds.dense_layers as f64 / self.plan.pp as f64;

        let mut a = StageAttrib::default();
        for k in &prefill_layer_kernels(self.ds, chunk, context, self.dtype, moe) {
            let m = self.kernel(&chip_fp, &k.class);
            let mult = if k.name.starts_with("moe.") { per_stage_moe } else { per_stage_moe + per_stage_dense };
            let class = match &k.class {
                KernelClass::Attention(_) => AttribClass::Attention,
                KernelClass::Gemm { .. } => AttribClass::Gemm,
                KernelClass::Vector { .. } => AttribClass::Vector,
            };
            a.add_kernel(class, mult, &m);
        }
        let d = self.ds.d_model as u64;
        let di = self.ds.dense_inter as u64;
        let up = self.kernel(&chip_fp, &KernelClass::Gemm { m: rows, k: d, n: 2 * di, batch: 1 });
        a.add_kernel(AttribClass::Gemm, per_stage_dense, &up);
        let down = self.kernel(&chip_fp, &KernelClass::Gemm { m: rows, k: di, n: d, batch: 1 });
        a.add_kernel(AttribClass::Gemm, per_stage_dense, &down);
        let dispatch_bytes = rows as f64
            * self.ds.experts_per_token as f64
            * self.ds.d_model as f64
            * self.dtype.bytes() as f64;
        a.add_seconds(AttribClass::Comm, per_stage_moe * 2.0 * self.sys.d2d.all_to_all_seconds(self.plan.ep, dispatch_bytes));
        if self.plan.pp > 1 {
            let boundary = self
                .sys
                .d2d
                .neighbor_transfer_seconds(rows as f64 * d as f64 * self.dtype.bytes() as f64);
            a.add_seconds(AttribClass::Comm, boundary);
        }
        a
    }

    /// Memoized single-kernel simulation. The key layout matches the decode
    /// evaluator's exactly, so GEMM/vector kernels with coinciding shapes
    /// share entries across the two engines; attention kernels can never
    /// collide because their shapes carry the phase.
    fn kernel(&self, chip_fp: &str, class: &KernelClass) -> KernelMetrics {
        let cfg = &self.sys.chip;
        let key = format!("{chip_fp}|{:?}|{:?}|{:?}", self.fidelity, self.choice, class);
        let (choice, fidelity) = (self.choice, self.fidelity);
        self.kernels.get_or_insert_with(key, || {
            simulate_kernel(
                cfg,
                class,
                |s| match choice {
                    AttentionChoice::Flat => AttentionDataflow::auto_flat(cfg, s),
                    // The prefill-side SoA baseline is FlashAttention-3
                    // (Fig. 1b), not the decode-side FA-2 lowering.
                    AttentionChoice::FlashMla => AttentionDataflow::Fa3,
                },
                fidelity,
            )
        })
    }

    /// Entries currently in the backing stage-time memo (shared with the
    /// decode stage times).
    pub fn stage_cache_len(&self) -> usize {
        self.stages.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::sim::ServeConfig;

    fn engine<'a>(
        sys: &'a WaferSystem,
        ds: &'a DeepSeekConfig,
        cfg: &ServeConfig,
    ) -> PrefillEngine<'a> {
        PrefillEngine::new(
            sys,
            ds,
            cfg.plan,
            cfg.choice,
            cfg.fidelity,
            cfg.dtype,
            KernelCache::new(),
            StageTimeCache::new(),
        )
    }

    #[test]
    fn chunk_bucket_rounds_up_to_pow2() {
        assert_eq!(chunk_bucket(1), 1);
        assert_eq!(chunk_bucket(3), 4);
        assert_eq!(chunk_bucket(512), 512);
        assert_eq!(chunk_bucket(513), 1024);
        assert_eq!(chunk_bucket(1024), 1024);
    }

    #[test]
    fn billed_time_matches_direct_dataflow_evaluation() {
        // Acceptance criterion: a chunk's billed time equals a direct
        // dataflow evaluation of the same (bucketed) shape within 1%.
        let sys = WaferSystem::paper();
        let ds = DeepSeekConfig::v3_671b();
        let cfg = ServeConfig::default();
        let e = engine(&sys, &ds, &cfg);
        for (chunk, ctx) in [(800u64, 900.0f64), (1024, 5000.0), (300, 40_000.0)] {
            let billed = e.chunk_stage_seconds(chunk, ctx);
            let (cb, xb) = e.bucketed(chunk, ctx);
            let direct = e.evaluate_chunk(cb, xb);
            assert!(billed > 0.0);
            assert!(
                (billed - direct).abs() <= 0.01 * direct,
                "chunk {chunk} ctx {ctx}: billed {billed} vs direct {direct}"
            );
        }
    }

    #[test]
    fn attrib_rewalk_conserves_chunk_seconds() {
        let sys = WaferSystem::paper();
        let ds = DeepSeekConfig::v3_671b();
        let cfg = ServeConfig::default();
        let e = engine(&sys, &ds, &cfg);
        let (cb, xb) = e.bucketed(800, 5000.0);
        let direct = e.evaluate_chunk(cb, xb);
        let a = e.evaluate_chunk_attrib(cb, xb);
        let rel = (a.billed_s() - direct).abs() / direct;
        assert!(rel < 1e-9, "re-walk drifted from evaluate_chunk: {} vs {direct}", a.billed_s());
        assert!(a.by_class.iter().filter(|b| b.seconds > 0.0).count() >= 3, "{a:?}");
    }

    #[test]
    fn chunk_cost_grows_with_size_and_offset() {
        let sys = WaferSystem::paper();
        let ds = DeepSeekConfig::v3_671b();
        let cfg = ServeConfig::default();
        let e = engine(&sys, &ds, &cfg);
        let small = e.chunk_stage_seconds(256, 256.0);
        let big = e.chunk_stage_seconds(1024, 1024.0);
        assert!(big > small, "bigger chunk must cost more: {big} vs {small}");
        // Deep offsets pay K/V recompute + longer attention.
        let shallow = e.chunk_stage_seconds(1024, 2048.0);
        let deep = e.chunk_stage_seconds(1024, 65_536.0);
        assert!(deep > 1.5 * shallow, "deep {deep} vs shallow {shallow}");
        // Zero prefill tokens cost nothing.
        assert_eq!(e.chunk_stage_seconds(0, 4096.0), 0.0);
    }

    #[test]
    fn memoization_is_bucket_grained_and_shared() {
        let sys = WaferSystem::paper();
        let ds = DeepSeekConfig::v3_671b();
        let cfg = ServeConfig::default();
        let e = engine(&sys, &ds, &cfg);
        let a = e.chunk_stage_seconds(700, 3000.0);
        let n = e.stage_cache_len();
        assert_eq!(n, 1);
        // Same buckets (1024, 3072) → same memo entry, identical time.
        let b = e.chunk_stage_seconds(600, 2100.0);
        assert_eq!(e.stage_cache_len(), n);
        assert_eq!(a, b);
        // A different context bucket adds one entry.
        e.chunk_stage_seconds(600, 9000.0);
        assert_eq!(e.stage_cache_len(), n + 1);
    }
}
