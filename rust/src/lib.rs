//! # FlatAttention — reproduction library
//!
//! Full-system reproduction of *FlatAttention: Dataflow and Fabric
//! Collectives Co-Optimization for Large Attention-Based Model Inference on
//! Tile-Based Accelerators* (Zhang, Colagrande, Andri, Benini).
//!
//! The crate provides, in dependency order:
//!
//! - [`sim`] — a deterministic discrete-event simulator (op DAGs over FIFO
//!   resource servers) with busy-interval accounting for the paper's runtime
//!   breakdowns.
//! - [`arch`] — the tile-based many-PE architecture template: tile engines
//!   (RedMulE matrix engine, Spatz vector engine, DMA), 2D-mesh NoC, HBM
//!   channels, and fabric collectives in three flavours (`HW`, `SW.Tree`,
//!   `SW.Seq`).
//! - [`dataflow`] — dataflow schedulers that lower attention / GEMM kernels
//!   onto the architecture: FlashAttention-2/-3, FlatAttention
//!   (SC/TC/HC/Async, Algorithm 2 of the paper), SUMMA, and the general
//!   tiling + group-scaling strategy (paper Fig. 10).
//! - [`workload`] — attention-variant workloads (MHA/MQA/GQA/MLA ×
//!   prefill/decode/speculative) and the DeepSeek-v3 decoder kernel flow.
//! - [`exec`] — a functional (numerics-carrying) executor used to verify the
//!   dataflow math against the PJRT-executed JAX golden.
//! - [`runtime`] — PJRT CPU client wrapper that loads `artifacts/*.hlo.txt`
//!   produced by the build-time Python layer.
//! - [`multichip`] — the wafer-scale multi-die system model: D2D mesh,
//!   PP / EP / hybrid parallelism, throughput + TPOT estimation.
//! - [`serve`] — the request-level serving simulator layered on the decode
//!   model, built around the steppable `ServeEngine` (one `step()` per wave
//!   iteration, mid-simulation `inject()`, live snapshots): synthetic
//!   arrival traces (Poisson/bursty/diurnal, with shared system-prompt
//!   populations and priority classes), KV-cache admission from the MLA
//!   cache layout, prefix-cache KV reuse via a per-column token-block trie
//!   (keyed exactly or by hashed token blocks), continuous batching with
//!   chunked prefill billed by the *actual prefill dataflow simulation*
//!   (per-chunk causal attention shapes at the request's context offset),
//!   FCFS/SJF/priority queue policies, preemption, and offered-load sweeps
//!   reporting TTFT/TPOT percentiles, prefix hit rates and SLO goodput.
//! - [`cluster`] — the fleet layer above `serve`: N serving engines
//!   interleaved on ONE global event clock behind a cluster router
//!   (round-robin / fluid least-outstanding / prefix-affinity with a
//!   live spill guard / live least-queue-depth), colocated or
//!   disaggregated into prefill and decode pools with the MLA latent-KV
//!   handoff serialized over a contended shared link (busy-until
//!   queueing), and shared multi-model pools whose co-resident models'
//!   ticks interleave on each chip (simulated interference). Every
//!   instance is an unmodified `serve` engine, so fleet TTFT/TPOT/goodput
//!   numbers stay dataflow-grounded.
//! - [`obs`] — deterministic observability threaded through `serve` and
//!   `cluster`: a simulated-clock span/event recorder (request lifecycles,
//!   engine waves, router decisions, KV-link transfers) exported as Chrome
//!   `trace_event` JSON loadable in Perfetto, a fixed-interval gauge
//!   sampler (queue depth, batch occupancy, per-EP-column KV utilization,
//!   prefix hit rate, link busy fraction) with CSV/JSON export, and
//!   monotonic counters rendered in Prometheus text format — off by
//!   default and zero-cost when disabled (`--trace-out` / `--series-out` /
//!   `--metrics-out` on the CLI).
//! - [`baseline`] — GH200 roofline/efficiency baselines and SoA system rows.
//! - [`coordinator`] — the experiment registry (one entry per paper
//!   figure/table, plus the `serve_*`/`cluster_*` experiments), sweep
//!   runner, report emitters, CLI parsers, and the on-disk kernel/stage
//!   cache persistence behind `--cache-dir` (cross-process memoization).
//!
//! Python (JAX + Pallas) is build-time only: `make artifacts` lowers the
//! attention models to HLO text once; the Rust binary then runs standalone.

pub mod sim;
pub mod arch;
pub mod dataflow;
pub mod workload;
pub mod exec;
pub mod runtime;
pub mod multichip;
pub mod serve;
pub mod cluster;
pub mod obs;
pub mod baseline;
pub mod coordinator;
pub mod metrics;
pub mod util;

pub use arch::config::{ChipConfig, SimFidelity};
pub use workload::attention::{AttentionShape, AttentionVariant, Phase};
