//! Span/instant recorder on the simulated clock, exported as Chrome
//! `trace_event` JSON (the format Perfetto and `chrome://tracing` load).
//!
//! One [`TraceRecorder`] per process lane (pid = instance): spans live on
//! `(pid, tid)` lanes, at most one *open* span per tid at a time — request
//! lifecycles are sequential (`queued` → `prefill` → `decode`), never
//! nested within a lane. `begin` defensively closes a forgotten open span,
//! and `end` clamps `end_s ≥ start_s`, so exported spans are always
//! well-formed. The recorder is bounded: events beyond `cap` increment
//! [`TraceRecorder::dropped`] instead of growing memory without bound.

use std::collections::HashMap;

/// A closed span on one `(pid, tid)` lane, in simulated seconds.
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    pub name: &'static str,
    /// Category: `lifecycle`, `engine`, `router` or `link`.
    pub cat: &'static str,
    pub pid: u32,
    pub tid: u64,
    pub start_s: f64,
    pub end_s: f64,
    pub args: Vec<(&'static str, String)>,
}

/// A point event.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceInstant {
    pub name: &'static str,
    pub cat: &'static str,
    pub pid: u32,
    pub tid: u64,
    pub t_s: f64,
    pub args: Vec<(&'static str, String)>,
}

#[derive(Debug, Clone)]
struct OpenSpan {
    name: &'static str,
    cat: &'static str,
    start_s: f64,
    args: Vec<(&'static str, String)>,
}

/// Bounded, deterministic span/instant recorder for one process lane.
#[derive(Debug, Clone)]
pub struct TraceRecorder {
    pid: u32,
    process_name: String,
    cap: usize,
    spans: Vec<Span>,
    instants: Vec<TraceInstant>,
    open: HashMap<u64, OpenSpan>,
    dropped: u64,
}

impl TraceRecorder {
    pub fn new(pid: u32, process_name: &str, cap: usize) -> Self {
        TraceRecorder {
            pid,
            process_name: process_name.to_string(),
            cap: cap.max(1),
            spans: Vec::new(),
            instants: Vec::new(),
            open: HashMap::new(),
            dropped: 0,
        }
    }

    pub fn pid(&self) -> u32 {
        self.pid
    }

    pub fn process_name(&self) -> &str {
        &self.process_name
    }

    /// Closed spans, in recording order.
    pub fn spans(&self) -> &[Span] {
        &self.spans
    }

    pub fn instants(&self) -> &[TraceInstant] {
        &self.instants
    }

    /// Events discarded because the recorder hit its cap.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    fn has_room(&self) -> bool {
        self.spans.len() + self.instants.len() < self.cap
    }

    /// Open a span on `tid`. A span already open there is closed at `t_s`
    /// first (defensive — lifecycles are sequential per lane).
    pub fn begin(&mut self, tid: u64, name: &'static str, cat: &'static str, t_s: f64, args: Vec<(&'static str, String)>) {
        if let Some(open) = self.open.remove(&tid) {
            self.push_span(tid, open, t_s, &[]);
        }
        self.open.insert(tid, OpenSpan { name, cat, start_s: t_s, args });
    }

    /// Name of the span currently open on `tid`, if any.
    pub fn open_name(&self, tid: u64) -> Option<&'static str> {
        self.open.get(&tid).map(|o| o.name)
    }

    /// Close the span open on `tid` at `t_s`, appending `extra` args.
    /// Returns false when no span was open there.
    pub fn end(&mut self, tid: u64, t_s: f64, extra: &[(&'static str, &str)]) -> bool {
        match self.open.remove(&tid) {
            Some(open) => {
                self.push_span(tid, open, t_s, extra);
                true
            }
            None => false,
        }
    }

    fn push_span(&mut self, tid: u64, open: OpenSpan, end_s: f64, extra: &[(&'static str, &str)]) {
        if !self.has_room() {
            self.dropped += 1;
            return;
        }
        let mut args = open.args;
        args.extend(extra.iter().map(|&(k, v)| (k, v.to_string())));
        self.spans.push(Span {
            name: open.name,
            cat: open.cat,
            pid: self.pid,
            tid,
            start_s: open.start_s,
            end_s: end_s.max(open.start_s),
            args,
        });
    }

    /// Record an already-delimited span (e.g. an engine `wave` tick or a
    /// link `handoff` whose start and end are both known at record time).
    pub fn complete(
        &mut self,
        tid: u64,
        name: &'static str,
        cat: &'static str,
        start_s: f64,
        end_s: f64,
        args: Vec<(&'static str, String)>,
    ) {
        if !self.has_room() {
            self.dropped += 1;
            return;
        }
        self.spans.push(Span { name, cat, pid: self.pid, tid, start_s, end_s: end_s.max(start_s), args });
    }

    /// Record a point event.
    pub fn instant(&mut self, tid: u64, name: &'static str, cat: &'static str, t_s: f64, args: Vec<(&'static str, String)>) {
        if !self.has_room() {
            self.dropped += 1;
            return;
        }
        self.instants.push(TraceInstant { name, cat, pid: self.pid, tid, t_s, args });
    }

    /// Close every dangling open span at `t_s` with `outcome=unfinished`
    /// (in-flight requests at the horizon). Sorted tid order — exports stay
    /// deterministic regardless of `HashMap` iteration order.
    pub fn close_open(&mut self, t_s: f64) {
        let mut tids: Vec<u64> = self.open.keys().copied().collect();
        tids.sort_unstable();
        for tid in tids {
            self.end(tid, t_s, &[("outcome", "unfinished")]);
        }
    }
}

/// Escape a string for embedding in a JSON string literal.
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Microsecond timestamp with fixed precision — deterministic formatting.
fn ts_us(t_s: f64) -> String {
    format!("{:.3}", t_s * 1e6)
}

fn args_json(args: &[(&'static str, String)]) -> String {
    let mut out = String::from("{");
    for (i, (k, v)) in args.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!("\"{}\":\"{}\"", esc(k), esc(v)));
    }
    out.push('}');
    out
}

fn push_event(out: &mut String, first: &mut bool, ev: String) {
    if *first {
        *first = false;
    } else {
        out.push(',');
    }
    out.push_str(&ev);
}

/// Merge recorders (callers pass them in pid order) into one Chrome
/// `trace_event` JSON document. Within a recorder, events appear in
/// recording order after the pid's `process_name`/`thread_name` metadata;
/// total drop count lands in `otherData.dropped_events`.
pub fn export_chrome_trace(recorders: &[&TraceRecorder]) -> String {
    let dropped: u64 = recorders.iter().map(|r| r.dropped).sum();
    let mut out = String::new();
    out.push_str("{\"displayTimeUnit\":\"ms\",\"otherData\":{\"dropped_events\":\"");
    out.push_str(&dropped.to_string());
    out.push_str("\"},\"traceEvents\":[");
    let mut first = true;
    for r in recorders {
        push_event(
            &mut out,
            &mut first,
            format!(
                "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":{},\"tid\":0,\"args\":{{\"name\":\"{}\"}}}}",
                r.pid,
                esc(&r.process_name)
            ),
        );
        push_event(
            &mut out,
            &mut first,
            format!("{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":{},\"tid\":0,\"args\":{{\"name\":\"engine\"}}}}", r.pid),
        );
        for s in &r.spans {
            push_event(
                &mut out,
                &mut first,
                format!(
                    "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\"pid\":{},\"tid\":{},\"args\":{}}}",
                    esc(s.name),
                    esc(s.cat),
                    ts_us(s.start_s),
                    ts_us(s.end_s - s.start_s),
                    s.pid,
                    s.tid,
                    args_json(&s.args)
                ),
            );
        }
        for i in &r.instants {
            push_event(
                &mut out,
                &mut first,
                format!(
                    "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"i\",\"ts\":{},\"s\":\"t\",\"pid\":{},\"tid\":{},\"args\":{}}}",
                    esc(i.name),
                    esc(i.cat),
                    ts_us(i.t_s),
                    i.pid,
                    i.tid,
                    args_json(&i.args)
                ),
            );
        }
    }
    out.push_str("]}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_close_in_lifecycle_order_and_clamp() {
        let mut r = TraceRecorder::new(0, "serve", 1024);
        r.begin(1, "queued", "lifecycle", 0.0, vec![("req", "0".to_string())]);
        assert_eq!(r.open_name(1), Some("queued"));
        assert!(r.end(1, 0.5, &[]));
        r.begin(1, "prefill", "lifecycle", 0.5, Vec::new());
        r.begin(1, "decode", "lifecycle", 1.0, Vec::new()); // defensive close of prefill
        assert!(r.end(1, 0.25, &[("outcome", "completed")])); // before start → clamped
        assert!(!r.end(1, 2.0, &[]), "nothing left open");
        let s = r.spans();
        assert_eq!(s.len(), 3);
        assert_eq!((s[0].name, s[0].start_s, s[0].end_s), ("queued", 0.0, 0.5));
        assert_eq!((s[1].name, s[1].start_s, s[1].end_s), ("prefill", 0.5, 1.0));
        assert_eq!((s[2].name, s[2].start_s, s[2].end_s), ("decode", 1.0, 1.0), "end clamps to start");
        assert_eq!(s[2].args, vec![("outcome", "completed".to_string())]);
        assert!(s.iter().all(|sp| sp.end_s >= sp.start_s));
    }

    #[test]
    fn cap_drops_are_counted_not_silent() {
        let mut r = TraceRecorder::new(0, "p", 2);
        r.complete(0, "wave", "engine", 0.0, 0.1, Vec::new());
        r.instant(1, "arrive", "lifecycle", 0.0, Vec::new());
        r.complete(0, "wave", "engine", 0.1, 0.2, Vec::new());
        r.begin(2, "queued", "lifecycle", 0.0, Vec::new());
        r.end(2, 0.3, &[]);
        assert_eq!(r.spans().len() + r.instants().len(), 2);
        assert_eq!(r.dropped(), 2);
    }

    #[test]
    fn close_open_marks_unfinished_in_tid_order() {
        let mut r = TraceRecorder::new(3, "p", 64);
        r.begin(9, "decode", "lifecycle", 1.0, Vec::new());
        r.begin(2, "queued", "lifecycle", 0.5, Vec::new());
        r.close_open(4.0);
        let s = r.spans();
        assert_eq!(s.len(), 2);
        assert_eq!(s[0].tid, 2, "sorted tid order");
        assert_eq!(s[1].tid, 9);
        assert!(s.iter().all(|sp| sp.end_s == 4.0 && sp.args.contains(&(("outcome"), "unfinished".to_string()))));
    }

    #[test]
    fn chrome_export_shape_and_escaping() {
        let mut r = TraceRecorder::new(0, "inst\"0\"", 64);
        r.complete(0, "wave", "engine", 0.0, 0.001, vec![("wave", "0".to_string())]);
        r.instant(1, "arrive", "lifecycle", 0.0005, Vec::new());
        let json = export_chrome_trace(&[&r]);
        assert!(json.starts_with('{') && json.ends_with('}'));
        assert!(json.contains("\"name\":\"inst\\\"0\\\"\""), "process name escaped: {json}");
        assert!(json.contains("\"ph\":\"X\",\"ts\":0.000,\"dur\":1000.000"), "{json}");
        assert!(json.contains("\"ph\":\"i\",\"ts\":500.000"), "{json}");
        assert!(json.contains("\"dropped_events\":\"0\""));
        // Balanced braces/brackets — a cheap well-formedness proxy the
        // integration tests strengthen with a real parser in CI.
        let opens = json.matches('{').count();
        let closes = json.matches('}').count();
        assert_eq!(opens, closes);
        assert_eq!(json.matches('[').count(), json.matches(']').count());
    }

    #[test]
    fn export_is_byte_deterministic() {
        let build = || {
            let mut r = TraceRecorder::new(1, "decode-0", 64);
            r.begin(4, "queued", "lifecycle", 0.125, vec![("req", "3".to_string())]);
            r.end(4, 0.5, &[("outcome", "rejected")]);
            r.begin(5, "prefill", "lifecycle", 0.25, Vec::new());
            r.close_open(1.0);
            export_chrome_trace(&[&r])
        };
        assert_eq!(build(), build());
    }
}
