//! Deterministic observability: simulated-clock tracing, fixed-interval
//! time series and monotonic counters, threaded through the serving engine
//! ([`ServeEngine`](crate::serve::sim::ServeEngine)) and the interleaved
//! cluster fleet ([`simulate_cluster_observed`](crate::cluster::fleet::simulate_cluster_observed)).
//!
//! Every end-of-run aggregate (`ServeOutcome`, `ClusterOutcome`) answers
//! "how much"; this layer answers "when" and "which request": when the KV
//! link congested, which requests ate the p99, why live routing beat the
//! fluid proxy. All timestamps are **simulated seconds** — the crate's
//! no-`Date::now` discipline holds, so two same-seed runs export
//! byte-identical artifacts (pinned by `tests/integration_obs.rs`).
//!
//! # Trace schema ([`trace`])
//!
//! Chrome `trace_event` JSON (Perfetto-loadable). Lanes:
//!
//! - **pid** = instance. Standalone serving uses pid 0 ("serve"). A fleet
//!   uses pid `0..n_entry` for the entry pool ("instance-i" colocated,
//!   "prefill-i" disaggregated), `n_entry..n_entry+n_decode` for the decode
//!   pool ("decode-i"), and one extra pid ("fleet") for router/link events.
//! - **tid** 0 = the engine lane (wave spans; router instants on the fleet
//!   pid); **tid k ≥ 1** = request lane for record index `k - 1`.
//!
//! Span names (cat `lifecycle`): `queued` (arrival → admission; closed with
//! `outcome=rejected` when admission can never fit the request, reopened on
//! preemption), `prefill` (admission → first token; carries `col` and any
//! `prefix_hit_tokens`), `decode` (first token → completion; opened
//! directly for pre-filled disaggregated handoffs). The terminal span
//! closes with `outcome` ∈ {`completed`, `rejected`, `preempted`,
//! `unfinished`} — `unfinished` marks in-flight work at the horizon.
//! Instants: `arrive`, `first_token`. Engine lane (cat `engine`): one
//! `wave` span per tick with `wave`, `decode_users`, `prefill_tokens`.
//! Fleet lane: `route` instants (cat `router`; `instance`, `spill` when the
//! affinity guard steered away, `requeued=1` when the routed request came
//! off a killed instance) and `handoff` spans (cat `link`; transfer
//! serialization + queue wait, `bytes`, `link_wait_s`, `decode_instance`,
//! plus the fabric route: `hops` and `path` — the `src>via>dst` node
//! chain the KV traversed).
//! Fault injection adds a `fault` track on the fleet pid: one `fault`
//! instant per applied event (`instance`, `kind` ∈ {`kill`, `drain`}) at
//! its epoch barrier, and a `restart` instant (`instance`) when a faulted
//! instance rejoins the pool.
//!
//! The recorder is bounded by [`ObsConfig::span_cap`]; events beyond the
//! cap are counted in `dropped_events` (exported under `otherData` and the
//! `flatattention_trace_events_dropped_total` counter), never silently lost.
//!
//! # Series schema ([`series`])
//!
//! Fixed-interval ([`ObsConfig::series_interval_s`]) per-instance gauges,
//! sampled at the first wave boundary past each grid point: `queue_depth`,
//! `active_users` (batch occupancy), `kv_frac` (worst column),
//! `kv_col_frac` (per EP column), `prefix_hit_rate`, `link_busy_frac`
//! (fleet pid only; the fabric-wide mean) and `edge_busy_frac` (fleet pid
//! only; one fraction per fabric edge in construction order — per-edge
//! hotspots like the prefill-pool boundary show up here), plus the
//! attribution gauges `util_frac` (engine busy fraction of the elapsed
//! interval), `hbm_bw_frac` (average HBM-bandwidth fraction over it) and
//! the fault-visibility pair `instances_up` / `requeue_depth` (fleet pid
//! only, sampled at every epoch barrier; zero on engine lanes). CSV (one
//! row per sample, `edge_busy_frac` then `kv_col_frac` semicolon-joined
//! last) or JSON (full per-edge / per-column arrays).
//! The sampler is bounded by [`ObsConfig::series_cap`]; rows beyond it are
//! dropped loudly (`dropped_points` in both exports and a
//! `flatattention_series_points_dropped_total` counter).
//!
//! # Attribution schema ([`attrib`], [`report`])
//!
//! `--attrib-out` / `flatattention report` export
//! `flatattention-attrib-v1` JSON with three sections:
//!
//! - `kernels` (merged) and `engines[].kernels` (per instance): one row
//!   per `(phase ∈ {prefill, decode}, class ∈ {attention, gemm, vector,
//!   comm, other})` with billed `seconds`, `pct_busy`, `flops`,
//!   `hbm_bytes`, time-weighted `compute_util` / `hbm_bw_util` /
//!   `matrix_eff_active`, `intensity_flop_per_byte` and the roofline
//!   verdict `bound` — **compute-bound iff `compute_util >=
//!   hbm_bw_util`**. Per-class seconds sum to the engine's busy time
//!   (`busy_s`) exactly; any re-walk residual is billed to `other`.
//! - `waterfalls`: one row per delivered request with the additive
//!   segments `ttft_s = queue_wait_s + prefill_s + requeue_stall_s`
//!   (requeue stall is the TTFT residual — zero to rounding unless the
//!   request was requeued by a fault) and `decode_span_s = link_wait_s +
//!   decode_solo_s + interference_s` (interference is the decode residual
//!   vs a batch-of-one baseline at the request's final context).
//!   `prefix_hit_tokens` / `prefix_saved_s` are non-additive annotations.
//! - The text report additionally prints the Fig. 9 dataflow anchor
//!   (matrix efficiency while active at the Table-II operating point) and,
//!   for cluster runs, the wall-clock DES self-profile note (per-worker
//!   busy/barrier-stall, load imbalance) — the only non-deterministic
//!   line, kept out of every byte-pinned artifact.
//!
//! # Counters schema ([`counters`])
//!
//! Monotonic event counts rendered in Prometheus text exposition format as
//! `flatattention_<name>_total`: `arrivals`, `admitted`, `rejected`,
//! `preempted`, `first_tokens`, `completed`, `waves`, `routed`,
//! `router_spills`, `handoffs`, `migrated`, `fabric_hops` (edges
//! traversed by KV handoffs and cold-start weight reloads), plus the
//! shared simulation caches' `stage_cache_hits`/`misses` and
//! `kernel_cache_hits`/`misses`.
//! Fault injection adds `faults` (events applied), `instance_restarts`,
//! `requests_requeued` (extracted from a killed instance and re-routed),
//! `requests_lost` (extraction fell past the horizon) and `kv_lost_bytes`
//! (resident + in-transit KV bytes destroyed by kills).
//!
//! # Zero-cost when disabled
//!
//! The engine holds `Option<Box<EngineObs>>` — `None` by default, no
//! allocation, no per-tick work beyond one pointer test — and the scheduler
//! only fills its decision log once a sink is attached. Default runs are
//! byte-identical to pre-observability builds (pinned by the existing
//! equivalence tests).
//!
//! # Perfetto how-to
//!
//! `flatattention serve --rate 800 --trace-out trace.json`, then open
//! <https://ui.perfetto.dev> and drag `trace.json` in (or load it in
//! `chrome://tracing`).

pub mod attrib;
pub mod counters;
pub mod report;
pub mod series;
pub mod trace;

pub use attrib::{AttribClass, AttribExport, AttribPhase, AttribRecorder, DesProfile, StageAttrib, Waterfall};
pub use counters::Counters;
pub use series::{export_series_csv, export_series_json, SeriesRow, SeriesSampler};
pub use trace::{export_chrome_trace, Span, TraceInstant, TraceRecorder};

/// Recorder sizing knobs (the CLI uses the defaults).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ObsConfig {
    /// Upper bound on recorded events (spans + instants) per recorder;
    /// events beyond it are dropped and counted, never silently lost.
    pub span_cap: usize,
    /// Gauge sampling grid in simulated seconds.
    pub series_interval_s: f64,
    /// Upper bound on recorded gauge rows per sampler; rows beyond it are
    /// dropped and counted (`dropped_points`), never silently lost.
    pub series_cap: usize,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig { span_cap: 262_144, series_interval_s: 0.05, series_cap: 65_536 }
    }
}

/// The per-engine observability sink: one trace recorder, one gauge
/// sampler and one counter set, attached to a `ServeEngine` via
/// `attach_obs` and detached with `take_obs`.
#[derive(Debug, Clone)]
pub struct EngineObs {
    pub trace: TraceRecorder,
    pub series: SeriesSampler,
    pub counters: Counters,
    pub attrib: AttribRecorder,
}

impl EngineObs {
    pub fn new(pid: u32, process_name: &str, cfg: ObsConfig) -> Self {
        EngineObs {
            trace: TraceRecorder::new(pid, process_name, cfg.span_cap),
            series: SeriesSampler::new(pid, cfg.series_interval_s).with_cap(cfg.series_cap),
            counters: Counters::new(),
            attrib: AttribRecorder::default(),
        }
    }
}

/// Everything one observed run produced, across all instances: the export
/// unit behind `--trace-out` / `--series-out` / `--metrics-out`.
#[derive(Debug, Clone, Default)]
pub struct ObsBundle {
    /// One recorder per instance (pid order), plus the fleet recorder last
    /// for cluster runs.
    pub traces: Vec<TraceRecorder>,
    pub series: Vec<SeriesSampler>,
    pub counters: Counters,
    /// Run-level attribution (kernel rooflines + latency waterfalls),
    /// assembled by the serve/cluster drivers after the run.
    pub attrib: AttribExport,
}

impl ObsBundle {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold one engine's sink into the bundle (counters merge; trace and
    /// series append in pid order).
    pub fn push_engine(&mut self, o: EngineObs) {
        self.counters.merge(&o.counters);
        self.traces.push(o.trace);
        self.series.push(o.series);
    }

    /// Render every export format. Deterministic: same bundle, same bytes.
    pub fn exports(&self) -> ObsExports {
        let trefs: Vec<&TraceRecorder> = self.traces.iter().collect();
        let srefs: Vec<&SeriesSampler> = self.series.iter().collect();
        let mut counters = self.counters.clone();
        let dropped: u64 = self.traces.iter().map(TraceRecorder::dropped).sum();
        if dropped > 0 {
            counters.add("trace_events_dropped", dropped);
        }
        let series_dropped: u64 = self.series.iter().map(SeriesSampler::dropped).sum();
        if series_dropped > 0 {
            counters.add("series_points_dropped", series_dropped);
        }
        ObsExports {
            trace_json: export_chrome_trace(&trefs),
            series_csv: export_series_csv(&srefs),
            series_json: export_series_json(&srefs),
            metrics_text: counters.to_prometheus(),
            attrib_json: self.attrib.to_json(),
        }
    }
}

/// Rendered export artifacts (the CLI writes whichever files were asked
/// for; `--series-out` picks CSV unless the path ends in `.json`).
#[derive(Debug, Clone)]
pub struct ObsExports {
    pub trace_json: String,
    pub series_csv: String,
    pub series_json: String,
    pub metrics_text: String,
    /// `flatattention-attrib-v1` JSON (kernel rooflines + waterfalls).
    pub attrib_json: String,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bundle_exports_are_deterministic_and_account_drops() {
        let build = || {
            let mut o = EngineObs::new(0, "serve", ObsConfig { span_cap: 2, series_interval_s: 0.1, series_cap: 1 });
            o.trace.begin(1, "queued", "lifecycle", 0.0, vec![("req", "7".to_string())]);
            o.trace.end(1, 0.5, &[("outcome", "completed")]);
            o.trace.instant(1, "first_token", "lifecycle", 0.25, Vec::new());
            o.trace.instant(1, "arrive", "lifecycle", 0.0, Vec::new()); // over cap → dropped
            o.counters.inc("completed");
            o.series.record(SeriesRow {
                t_s: 0.1,
                pid: 0,
                queue_depth: 1,
                active_users: 2,
                kv_frac: 0.5,
                kv_col_frac: vec![0.5, 0.25],
                prefix_hit_rate: 0.0,
                link_busy_frac: 0.0,
                edge_busy_frac: Vec::new(),
                util_frac: 0.75,
                hbm_bw_frac: 0.25,
                instances_up: 0,
                requeue_depth: 0,
            });
            // Over the series cap of 1 → dropped loudly, not grown.
            o.series.record(SeriesRow { t_s: 0.25, ..o.series.rows()[0].clone() });
            let mut b = ObsBundle::new();
            b.push_engine(o);
            b.exports()
        };
        let (a, b) = (build(), build());
        assert_eq!(a.trace_json, b.trace_json);
        assert_eq!(a.series_csv, b.series_csv);
        assert_eq!(a.series_json, b.series_json);
        assert_eq!(a.metrics_text, b.metrics_text);
        assert_eq!(a.attrib_json, b.attrib_json);
        assert!(a.trace_json.contains("\"dropped_events\":\"1\""), "{}", a.trace_json);
        assert!(a.metrics_text.contains("flatattention_trace_events_dropped_total 1"), "{}", a.metrics_text);
        assert!(a.metrics_text.contains("flatattention_series_points_dropped_total 1"), "{}", a.metrics_text);
        assert!(a.metrics_text.contains("flatattention_completed_total 1"));
        assert!(a.series_csv.contains("# dropped_points 1"), "{}", a.series_csv);
        assert!(a.series_json.contains("\"dropped_points\":1"), "{}", a.series_json);
        assert!(a.attrib_json.contains("\"schema\":\"flatattention-attrib-v1\""), "{}", a.attrib_json);
    }

    #[test]
    fn default_config_is_generous() {
        let cfg = ObsConfig::default();
        assert!(cfg.span_cap >= 100_000);
        assert!(cfg.series_interval_s > 0.0);
        assert!(cfg.series_cap >= 10_000);
    }
}
