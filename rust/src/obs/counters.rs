//! Monotonic event counters with a Prometheus text-exposition snapshot.
//!
//! Counter names are static identifiers (`admitted`, `preempted`,
//! `stage_cache_hits`, …) rendered as `flatattention_<name>_total`. A
//! `BTreeMap` keeps the snapshot sorted — exports are byte-deterministic.

use std::collections::BTreeMap;

/// A set of monotonic counters.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Counters {
    inner: BTreeMap<&'static str, u64>,
}

impl Counters {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&mut self, name: &'static str) {
        self.add(name, 1);
    }

    pub fn add(&mut self, name: &'static str, v: u64) {
        *self.inner.entry(name).or_insert(0) += v;
    }

    /// Current value (0 for a counter never touched).
    pub fn get(&self, name: &str) -> u64 {
        self.inner.get(name).copied().unwrap_or(0)
    }

    pub fn len(&self) -> usize {
        self.inner.len()
    }

    pub fn is_empty(&self) -> bool {
        self.inner.is_empty()
    }

    /// Fold another counter set in (per-instance sinks merge into the
    /// bundle's fleet-wide totals).
    pub fn merge(&mut self, other: &Counters) {
        for (k, v) in &other.inner {
            self.add(k, *v);
        }
    }

    /// Prometheus text exposition format snapshot, sorted by name.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        for (k, v) in &self.inner {
            out.push_str(&format!("# TYPE flatattention_{k}_total counter\nflatattention_{k}_total {v}\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_merge() {
        let mut a = Counters::new();
        a.inc("admitted");
        a.add("admitted", 2);
        a.inc("completed");
        let mut b = Counters::new();
        b.add("admitted", 10);
        b.inc("preempted");
        a.merge(&b);
        assert_eq!(a.get("admitted"), 13);
        assert_eq!(a.get("completed"), 1);
        assert_eq!(a.get("preempted"), 1);
        assert_eq!(a.get("never_touched"), 0);
        assert_eq!(a.len(), 3);
        assert!(!a.is_empty());
    }

    #[test]
    fn prometheus_snapshot_is_sorted_and_typed() {
        let mut c = Counters::new();
        c.add("waves", 7);
        c.add("admitted", 42);
        let text = c.to_prometheus();
        assert_eq!(
            text,
            "# TYPE flatattention_admitted_total counter\nflatattention_admitted_total 42\n\
             # TYPE flatattention_waves_total counter\nflatattention_waves_total 7\n"
        );
        // Deterministic regardless of insertion order.
        let mut d = Counters::new();
        d.add("admitted", 42);
        d.add("waves", 7);
        assert_eq!(d.to_prometheus(), text);
    }
}
