//! Cross-layer performance attribution on the simulated clock.
//!
//! Two complementary decompositions of one observed run, both
//! conservation-pinned (see `tests/integration_obs.rs`):
//!
//! - **Kernel attribution**: every engine wave bills its memoized stage
//!   time against `(phase, class)` aggregates carrying seconds, FLOPs, HBM
//!   bytes and time-weighted utilizations from the underlying dataflow
//!   simulation. Summed over classes, the billed seconds equal the
//!   engine's busy time exactly (any re-walk residual is billed to
//!   [`AttribClass::Other`], never dropped). Rooflines follow from the
//!   aggregates: a row is compute-bound iff its time-weighted compute
//!   utilization is at least its HBM-bandwidth utilization.
//! - **Per-request latency waterfalls**: each delivered request's
//!   end-to-end latency decomposes into queue wait, prefill compute, KV
//!   link wait (the prefill→decode handoff lands *before* the first
//!   token, so it sits inside TTFT), fault-requeue stall (TTFT residual —
//!   zero to rounding for requests that were never requeued),
//!   solo-decode baseline and decode batch-interference slowdown (decode
//!   residual). Segments sum to the measured TTFT and decode span by
//!   construction; prefix-hit savings are a non-additive annotation (time
//!   the prefill *didn't* spend).
//!
//! Everything here is simulated-clock data: same-seed runs export
//! byte-identical attribution JSON. The one wall-clock type,
//! [`DesProfile`], profiles the sharded DES itself (per-worker busy and
//! barrier-stall wall time) and is confined to printed report notes —
//! it never enters a byte-pinned artifact.

use std::collections::{BTreeMap, HashMap};

use crate::metrics::{KernelMetrics, Percentiles};

/// Kernel class a billed second belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum AttribClass {
    /// FlatAttention / FA-3 attention kernels.
    Attention,
    /// Dense and MoE GEMMs.
    Gemm,
    /// Vector / elementwise kernels.
    Vector,
    /// Fabric time: MoE all-to-all dispatch/combine and PP boundary hops.
    Comm,
    /// Conservation residual (re-walk vs memoized stage time) — kept loud.
    Other,
}

impl AttribClass {
    pub const ALL: [AttribClass; 5] =
        [AttribClass::Attention, AttribClass::Gemm, AttribClass::Vector, AttribClass::Comm, AttribClass::Other];

    pub fn name(self) -> &'static str {
        match self {
            AttribClass::Attention => "attention",
            AttribClass::Gemm => "gemm",
            AttribClass::Vector => "vector",
            AttribClass::Comm => "comm",
            AttribClass::Other => "other",
        }
    }

    fn index(self) -> usize {
        match self {
            AttribClass::Attention => 0,
            AttribClass::Gemm => 1,
            AttribClass::Vector => 2,
            AttribClass::Comm => 3,
            AttribClass::Other => 4,
        }
    }
}

/// Which serving phase billed the time.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum AttribPhase {
    Prefill,
    Decode,
}

impl AttribPhase {
    pub fn name(self) -> &'static str {
        match self {
            AttribPhase::Prefill => "prefill",
            AttribPhase::Decode => "decode",
        }
    }
}

/// Accumulated bill for one `(phase, class)` cell.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ClassBill {
    /// Simulated seconds billed.
    pub seconds: f64,
    /// FLOPs executed (achieved rate × billed seconds).
    pub flops: f64,
    /// HBM bytes moved.
    pub hbm_bytes: f64,
    /// Σ seconds × compute utilization (fraction of chip peak FLOP/s).
    pub util_w_s: f64,
    /// Σ seconds × HBM-bandwidth utilization.
    pub hbm_bw_w_s: f64,
    /// Σ seconds × matrix-engine efficiency while active (Fig. 9 metric).
    pub matrix_eff_w_s: f64,
}

impl ClassBill {
    pub fn merge(&mut self, o: &ClassBill) {
        self.seconds += o.seconds;
        self.flops += o.flops;
        self.hbm_bytes += o.hbm_bytes;
        self.util_w_s += o.util_w_s;
        self.hbm_bw_w_s += o.hbm_bw_w_s;
        self.matrix_eff_w_s += o.matrix_eff_w_s;
    }

    /// Time-weighted average compute utilization.
    pub fn compute_util(&self) -> f64 {
        if self.seconds > 0.0 {
            self.util_w_s / self.seconds
        } else {
            0.0
        }
    }

    /// Time-weighted average HBM-bandwidth utilization.
    pub fn hbm_bw_util(&self) -> f64 {
        if self.seconds > 0.0 {
            self.hbm_bw_w_s / self.seconds
        } else {
            0.0
        }
    }

    /// Time-weighted matrix-engine efficiency while active.
    pub fn matrix_eff_active(&self) -> f64 {
        if self.seconds > 0.0 {
            self.matrix_eff_w_s / self.seconds
        } else {
            0.0
        }
    }

    /// Arithmetic intensity in FLOP/byte (0 when no HBM traffic).
    pub fn intensity(&self) -> f64 {
        if self.hbm_bytes > 0.0 {
            self.flops / self.hbm_bytes
        } else {
            0.0
        }
    }

    /// Roofline classification rule: a cell is compute-bound iff its
    /// achieved compute utilization is at least its achieved HBM-bandwidth
    /// utilization (the binding roof is the one it sits closer to).
    pub fn bound(&self) -> &'static str {
        if self.compute_util() >= self.hbm_bw_util() {
            "compute"
        } else {
            "memory"
        }
    }
}

/// One memoized stage's attribution: the stage's total simulated seconds
/// split across kernel classes, with the re-walk residual billed to
/// [`AttribClass::Other`] so the split always sums to `total_s`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StageAttrib {
    pub total_s: f64,
    pub by_class: [ClassBill; 5],
}

impl StageAttrib {
    fn cell(&mut self, class: AttribClass) -> &mut ClassBill {
        &mut self.by_class[class.index()]
    }

    /// Bill `mult` invocations of a simulated kernel to `class`.
    pub fn add_kernel(&mut self, class: AttribClass, mult: f64, m: &KernelMetrics) {
        let s = mult * m.seconds;
        let b = self.cell(class);
        b.seconds += s;
        b.flops += m.tflops * 1e12 * s;
        b.hbm_bytes += mult * m.hbm_bytes as f64;
        b.util_w_s += s * m.compute_utilization;
        b.hbm_bw_w_s += s * m.hbm_bw_utilization;
        b.matrix_eff_w_s += s * m.matrix_efficiency_active;
    }

    /// Bill plain fabric/serialization seconds (no kernel metrics).
    pub fn add_seconds(&mut self, class: AttribClass, seconds: f64) {
        self.cell(class).seconds += seconds;
    }

    /// Sum of per-class billed seconds (before settling, the re-walk total).
    pub fn billed_s(&self) -> f64 {
        self.by_class.iter().map(|b| b.seconds).sum()
    }

    /// Pin the split to the memoized stage time: any difference between the
    /// re-walk total and `measured_s` lands in [`AttribClass::Other`], so
    /// the per-class seconds always sum to `total_s == measured_s`.
    pub fn settle(&mut self, measured_s: f64) {
        let residual = measured_s - self.billed_s();
        self.cell(AttribClass::Other).seconds += residual;
        self.total_s = measured_s;
    }
}

/// Per-`(phase, class)` aggregates across every billed wave.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct KernelAgg {
    pub rows: BTreeMap<(AttribPhase, AttribClass), ClassBill>,
}

impl KernelAgg {
    /// Bill one settled stage attribution under `phase`, scaled by `mult`
    /// (how many times the memoized stage ran this wave — 1 per tick here).
    pub fn bill(&mut self, phase: AttribPhase, a: &StageAttrib, mult: f64) {
        for (i, b) in a.by_class.iter().enumerate() {
            if b.seconds == 0.0 && b.flops == 0.0 {
                continue;
            }
            let scaled = ClassBill {
                seconds: mult * b.seconds,
                flops: mult * b.flops,
                hbm_bytes: mult * b.hbm_bytes,
                util_w_s: mult * b.util_w_s,
                hbm_bw_w_s: mult * b.hbm_bw_w_s,
                matrix_eff_w_s: mult * b.matrix_eff_w_s,
            };
            self.rows.entry((phase, AttribClass::ALL[i])).or_default().merge(&scaled);
        }
    }

    pub fn merge(&mut self, o: &KernelAgg) {
        for (k, b) in &o.rows {
            self.rows.entry(*k).or_default().merge(b);
        }
    }

    /// Total billed seconds across all cells.
    pub fn total_s(&self) -> f64 {
        self.rows.values().map(|b| b.seconds).sum()
    }

    /// Σ seconds × HBM-bandwidth utilization across all cells.
    pub fn hbm_bw_w_s(&self) -> f64 {
        self.rows.values().map(|b| b.hbm_bw_w_s).sum()
    }
}

/// Per-request capture slots, parallel to the engine's record vector.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ReqSlot {
    /// Request arrival as this engine saw it (re-injection time for
    /// requeued or handed-off work).
    pub arrival_s: Option<f64>,
    /// First (or post-requeue) admission time on this engine.
    pub admit_s: Option<f64>,
    /// First token produced by this engine.
    pub first_s: Option<f64>,
    /// Completion on this engine.
    pub completion_s: Option<f64>,
    /// Prefix-cache tokens this request skipped at admission.
    pub hit_tokens: u64,
    /// Prefill seconds those hit tokens would have cost (annotation).
    pub prefix_saved_s: f64,
    /// Solo-decode baseline: decoded tokens × the batch-of-one stage time
    /// at the request's final context (filled at completion).
    pub solo_decode_s: f64,
}

/// Per-engine attribution recorder, carried inside `EngineObs`. Zero-cost
/// when observability is off (the engine holds no recorder at all).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct AttribRecorder {
    /// Engine busy time: Σ per-wave stage seconds (idle jumps excluded).
    pub busy_s: f64,
    pub kernels: KernelAgg,
    /// Request slots indexed by record position.
    pub slots: Vec<ReqSlot>,
    /// Settled stage attributions memoized per engine-local bucket key
    /// (`d|b{}|kv{}` / `p|c{}|x{}`), mirroring the stage-time memo so the
    /// attribution re-walk runs once per bucket, not once per tick.
    pub memo: HashMap<String, StageAttrib>,
    prev_sample_t: f64,
    prev_busy_s: f64,
    prev_hbm_w_s: f64,
}

impl AttribRecorder {
    /// Bill one settled stage attribution and advance engine busy time.
    pub fn bill(&mut self, phase: AttribPhase, a: &StageAttrib) {
        self.busy_s += a.total_s;
        self.kernels.bill(phase, a, 1.0);
    }

    /// Bill one wave's phase via the memoized settled attribution for
    /// `key`, computing (and settling) it on first use — the engine's
    /// per-bucket fast path.
    pub fn bill_memoized(&mut self, phase: AttribPhase, key: String, f: impl FnOnce() -> StageAttrib) {
        let a = self.memo.entry(key).or_insert_with(f);
        self.busy_s += a.total_s;
        self.kernels.bill(phase, a, 1.0);
    }

    pub fn slot(&mut self, pos: usize) -> &mut ReqSlot {
        if pos >= self.slots.len() {
            self.slots.resize(pos + 1, ReqSlot::default());
        }
        &mut self.slots[pos]
    }

    /// Gauge deltas since the previous series sample at time `t_s`:
    /// `(util_frac, hbm_bw_frac)` — busy fraction of the elapsed interval
    /// and average HBM-bandwidth fraction over it.
    pub fn sample_gauges(&mut self, t_s: f64) -> (f64, f64) {
        let dt = t_s - self.prev_sample_t;
        let hbm_w_s = self.kernels.hbm_bw_w_s();
        let (util, hbm) = if dt > 0.0 {
            ((self.busy_s - self.prev_busy_s) / dt, (hbm_w_s - self.prev_hbm_w_s) / dt)
        } else {
            (0.0, 0.0)
        };
        self.prev_sample_t = t_s;
        self.prev_busy_s = self.busy_s;
        self.prev_hbm_w_s = hbm_w_s;
        (util.clamp(0.0, 1.0), hbm.clamp(0.0, 1.0))
    }
}

/// One delivered request's latency waterfall. All additive identities hold
/// by construction (residual segments), so the conservation tests assert
/// them exactly:
///
/// - `ttft_s = queue_wait_s + prefill_s + link_wait_s + requeue_stall_s`
/// - `decode_span_s = decode_solo_s + interference_s`
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Waterfall {
    pub id: u64,
    pub arrival_s: f64,
    /// First token − arrival.
    pub ttft_s: f64,
    /// Local admission − local arrival on the delivering engine.
    pub queue_wait_s: f64,
    /// Local first token − local admission (includes prefill batching).
    pub prefill_s: f64,
    /// TTFT residual: time lost to faults/requeues before the final
    /// admission. Zero (to rounding) for requests that were never requeued.
    pub requeue_stall_s: f64,
    /// Completion − first token (None fields collapse to 0 when the
    /// request didn't finish inside the horizon).
    pub decode_span_s: f64,
    /// KV handoff serialization + queue wait on the shared link. Part of
    /// the TTFT identity: in disaggregated serving the handoff delivers
    /// token #1, so the exposed transfer delay precedes the first token.
    pub link_wait_s: f64,
    /// Batch-of-one decode baseline at the request's final context.
    pub decode_solo_s: f64,
    /// Decode residual vs the solo baseline: batch interference.
    pub interference_s: f64,
    /// Prefix-cache tokens skipped at admission (annotation).
    pub prefix_hit_tokens: u64,
    /// Prefill seconds the prefix hit saved (annotation, non-additive).
    pub prefix_saved_s: f64,
    pub requeues: u32,
    pub completed: bool,
}

/// Build one waterfall from the merged record view plus the delivering
/// engines' capture slots. `entry` is the slot on the engine that produced
/// the first token; `completer` the slot on the engine that finished decode
/// (the same slot when colocated).
#[allow(clippy::too_many_arguments)]
pub fn assemble_waterfall(
    id: u64,
    arrival_s: f64,
    first_token_s: f64,
    completion_s: Option<f64>,
    transfer_s: f64,
    requeues: u32,
    entry: Option<&ReqSlot>,
    completer: Option<&ReqSlot>,
) -> Waterfall {
    let ttft = first_token_s - arrival_s;
    let (queue, prefill) = match entry {
        Some(s) => {
            let local_arrival = s.arrival_s.unwrap_or(arrival_s);
            let admit = s.admit_s.unwrap_or(local_arrival);
            let first_local = s.first_s.unwrap_or(first_token_s);
            (admit - local_arrival, first_local - admit)
        }
        None => (0.0, ttft),
    };
    let link = transfer_s;
    let stall = ttft - queue - prefill - link;
    let (span, solo) = match completion_s {
        Some(c) => (c - first_token_s, completer.map(|s| s.solo_decode_s).unwrap_or(0.0)),
        None => (0.0, 0.0),
    };
    Waterfall {
        id,
        arrival_s,
        ttft_s: ttft,
        queue_wait_s: queue,
        prefill_s: prefill,
        requeue_stall_s: stall,
        decode_span_s: span,
        link_wait_s: link,
        decode_solo_s: solo,
        interference_s: span - solo,
        prefix_hit_tokens: entry.map(|s| s.hit_tokens).unwrap_or(0),
        prefix_saved_s: entry.map(|s| s.prefix_saved_s).unwrap_or(0.0),
        requeues,
        completed: completion_s.is_some(),
    }
}

/// One engine's contribution to the run-level attribution export.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct EngineAttrib {
    pub pid: u32,
    pub busy_s: f64,
    pub kernels: KernelAgg,
}

/// Run-level attribution: per-engine and merged kernel aggregates plus the
/// per-request waterfalls, assembled by the serve/cluster drivers after the
/// run and rendered by `obs::report`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct AttribExport {
    pub engines: Vec<EngineAttrib>,
    pub kernels: KernelAgg,
    pub waterfalls: Vec<Waterfall>,
    /// Requests offered to the run (waterfalls only cover delivered ones).
    pub offered: usize,
}

impl AttribExport {
    /// Fold one engine's recorder in (pid order — deterministic).
    pub fn push_engine(&mut self, pid: u32, rec: &AttribRecorder) {
        self.kernels.merge(&rec.kernels);
        self.engines.push(EngineAttrib { pid, busy_s: rec.busy_s, kernels: rec.kernels.clone() });
    }

    /// Total engine busy seconds across the run.
    pub fn busy_s(&self) -> f64 {
        self.engines.iter().map(|e| e.busy_s).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.engines.is_empty() && self.waterfalls.is_empty()
    }

    /// Percentile summary of one waterfall segment in milliseconds.
    pub fn segment_percentiles(&self, f: impl Fn(&Waterfall) -> f64) -> Percentiles {
        let v: Vec<f64> = self.waterfalls.iter().map(|w| 1e3 * f(w)).collect();
        Percentiles::from_values(&v)
    }

    /// Deterministic JSON export (`flatattention-attrib-v1`): merged and
    /// per-engine kernel rooflines plus every per-request waterfall.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\"schema\":\"flatattention-attrib-v1\"");
        out.push_str(&format!(",\"offered\":{},\"busy_s\":{:.9}", self.offered, self.busy_s()));
        out.push_str(",\"kernels\":");
        push_kernels_json(&mut out, &self.kernels);
        out.push_str(",\"engines\":[");
        for (i, e) in self.engines.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("{{\"pid\":{},\"busy_s\":{:.9},\"kernels\":", e.pid, e.busy_s));
            push_kernels_json(&mut out, &e.kernels);
            out.push('}');
        }
        out.push_str("],\"waterfalls\":[");
        for (i, w) in self.waterfalls.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "{{\"id\":{},\"arrival_s\":{:.9},\"ttft_s\":{:.9},\"queue_wait_s\":{:.9},\"prefill_s\":{:.9},\
                 \"requeue_stall_s\":{:.9},\"decode_span_s\":{:.9},\"link_wait_s\":{:.9},\"decode_solo_s\":{:.9},\
                 \"interference_s\":{:.9},\"prefix_hit_tokens\":{},\"prefix_saved_s\":{:.9},\"requeues\":{},\"completed\":{}}}",
                w.id,
                w.arrival_s,
                w.ttft_s,
                w.queue_wait_s,
                w.prefill_s,
                w.requeue_stall_s,
                w.decode_span_s,
                w.link_wait_s,
                w.decode_solo_s,
                w.interference_s,
                w.prefix_hit_tokens,
                w.prefix_saved_s,
                w.requeues,
                w.completed
            ));
        }
        out.push_str("]}");
        out
    }
}

fn push_kernels_json(out: &mut String, agg: &KernelAgg) {
    let total = agg.total_s();
    out.push('[');
    for (i, ((phase, class), b)) in agg.rows.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let pct = if total > 0.0 { b.seconds / total } else { 0.0 };
        out.push_str(&format!(
            "{{\"phase\":\"{}\",\"class\":\"{}\",\"seconds\":{:.9},\"pct_busy\":{:.6},\"flops\":{:.3e},\
             \"hbm_bytes\":{:.3e},\"compute_util\":{:.6},\"hbm_bw_util\":{:.6},\"matrix_eff_active\":{:.6},\
             \"intensity_flop_per_byte\":{:.6},\"bound\":\"{}\"}}",
            phase.name(),
            class.name(),
            b.seconds,
            pct,
            b.flops,
            b.hbm_bytes,
            b.compute_util(),
            b.hbm_bw_util(),
            b.matrix_eff_active(),
            b.intensity(),
            b.bound()
        ));
    }
    out.push(']');
}

/// Wall-clock self-profile of the sharded DES: how the fleet run itself
/// spent host time. **Not deterministic** — confined to printed report
/// notes, never to byte-pinned exports.
#[derive(Debug, Clone, Default)]
pub struct DesProfile {
    pub workers: usize,
    pub epochs: u64,
    /// Total wall seconds for the fleet run.
    pub wall_s: f64,
    /// Per-worker wall seconds spent advancing engines.
    pub worker_busy_s: Vec<f64>,
    /// Per-worker wall seconds blocked at epoch barriers.
    pub barrier_stall_s: Vec<f64>,
}

impl DesProfile {
    /// Load imbalance: max over mean per-worker busy time (1.0 = perfect).
    pub fn imbalance(&self) -> f64 {
        if self.worker_busy_s.is_empty() {
            return 1.0;
        }
        let max = self.worker_busy_s.iter().cloned().fold(0.0_f64, f64::max);
        let mean = self.worker_busy_s.iter().sum::<f64>() / self.worker_busy_s.len() as f64;
        if mean > 0.0 {
            max / mean
        } else {
            1.0
        }
    }

    /// One-line human summary for cluster report notes.
    pub fn note(&self) -> String {
        let busy: f64 = self.worker_busy_s.iter().sum();
        let stall: f64 = self.barrier_stall_s.iter().sum();
        format!(
            "DES self-profile: {} worker(s), {} epochs, wall {:.3} s (busy {:.3} s, barrier stall {:.3} s, imbalance {:.2}x)",
            self.workers,
            self.epochs,
            self.wall_s,
            busy,
            stall,
            self.imbalance()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn metrics(seconds: f64, tflops: f64, cu: f64, hbm: f64, bytes: u64) -> KernelMetrics {
        KernelMetrics {
            cycles: 1,
            seconds,
            tflops,
            compute_utilization: cu,
            hbm_bw_utilization: hbm,
            hbm_bytes: bytes,
            noc_bytes: 0,
            matrix_utilization_active: cu,
            matrix_efficiency_active: cu,
            exposed: [0; 5],
        }
    }

    #[test]
    fn stage_attrib_settles_residual_into_other() {
        let mut a = StageAttrib::default();
        a.add_kernel(AttribClass::Attention, 2.0, &metrics(0.5, 10.0, 0.9, 0.3, 1 << 20));
        a.add_seconds(AttribClass::Comm, 0.25);
        assert!((a.billed_s() - 1.25).abs() < 1e-12);
        a.settle(1.5);
        assert_eq!(a.total_s, 1.5);
        assert!((a.billed_s() - 1.5).abs() < 1e-12);
        assert!((a.by_class[AttribClass::Other.index()].seconds - 0.25).abs() < 1e-12);
        // Attention bill carries flops at the achieved rate × billed time.
        let att = &a.by_class[AttribClass::Attention.index()];
        assert!((att.flops - 10.0 * 1e12).abs() < 1.0);
        assert!((att.hbm_bytes - 2.0 * (1 << 20) as f64).abs() < 1e-6);
    }

    #[test]
    fn roofline_rule_splits_on_utilization_ratio() {
        let mut compute = StageAttrib::default();
        compute.add_kernel(AttribClass::Gemm, 1.0, &metrics(1.0, 100.0, 0.9, 0.2, 1000));
        assert_eq!(compute.by_class[AttribClass::Gemm.index()].bound(), "compute");
        let mut memory = StageAttrib::default();
        memory.add_kernel(AttribClass::Attention, 1.0, &metrics(1.0, 5.0, 0.1, 0.8, 1000));
        assert_eq!(memory.by_class[AttribClass::Attention.index()].bound(), "memory");
    }

    #[test]
    fn recorder_bills_busy_time_and_conserves() {
        let mut rec = AttribRecorder::default();
        let mut a = StageAttrib::default();
        a.add_kernel(AttribClass::Attention, 1.0, &metrics(0.3, 10.0, 0.9, 0.3, 100));
        a.settle(0.4);
        rec.bill(AttribPhase::Decode, &a);
        rec.bill(AttribPhase::Decode, &a);
        let mut p = StageAttrib::default();
        p.add_kernel(AttribClass::Gemm, 1.0, &metrics(0.1, 50.0, 0.8, 0.1, 100));
        p.settle(0.1);
        rec.bill(AttribPhase::Prefill, &p);
        assert!((rec.busy_s - 0.9).abs() < 1e-12);
        assert!((rec.kernels.total_s() - rec.busy_s).abs() < 1e-12, "kernel bill must conserve busy time");
        // Gauges advance on the sampled deltas.
        let (u, _) = rec.sample_gauges(1.0);
        assert!((u - 0.9).abs() < 1e-12);
        let (u2, _) = rec.sample_gauges(2.0);
        assert_eq!(u2, 0.0, "no new billing between samples");
    }

    #[test]
    fn waterfall_segments_sum_by_construction() {
        // Disaggregated shape: token #1 left the prefill engine at 1.4 and
        // landed after a 0.1 s KV handoff — the link wait sits inside TTFT.
        let entry = ReqSlot {
            arrival_s: Some(1.0),
            admit_s: Some(1.2),
            first_s: Some(1.4),
            completion_s: None,
            hit_tokens: 128,
            prefix_saved_s: 0.05,
            solo_decode_s: 0.0,
        };
        let completer = ReqSlot { solo_decode_s: 0.8, ..ReqSlot::default() };
        let w = assemble_waterfall(7, 1.0, 1.5, Some(3.0), 0.1, 0, Some(&entry), Some(&completer));
        assert!((w.ttft_s - (w.queue_wait_s + w.prefill_s + w.link_wait_s + w.requeue_stall_s)).abs() < 1e-12);
        assert!((w.decode_span_s - (w.decode_solo_s + w.interference_s)).abs() < 1e-12);
        assert!((w.link_wait_s - 0.1).abs() < 1e-12);
        assert!(w.requeue_stall_s.abs() < 1e-12, "clean request has no requeue stall: {w:?}");
        assert_eq!(w.prefix_hit_tokens, 128);
        assert!(w.completed);
        // A requeued request's displaced first life lands in the stall.
        let late = ReqSlot { arrival_s: Some(2.0), admit_s: Some(2.1), first_s: Some(2.4), ..entry };
        let w2 = assemble_waterfall(7, 1.0, 2.4, None, 0.0, 1, Some(&late), None);
        assert!((w2.requeue_stall_s - 1.0).abs() < 1e-12, "{w2:?}");
        assert!(!w2.completed);
        assert_eq!(w2.decode_span_s, 0.0);
    }

    #[test]
    fn export_json_is_deterministic_and_tagged() {
        let build = || {
            let mut rec = AttribRecorder::default();
            let mut a = StageAttrib::default();
            a.add_kernel(AttribClass::Attention, 1.0, &metrics(0.3, 10.0, 0.9, 0.3, 100));
            a.settle(0.3);
            rec.bill(AttribPhase::Decode, &a);
            let mut x = AttribExport { offered: 1, ..AttribExport::default() };
            x.push_engine(0, &rec);
            x.waterfalls.push(assemble_waterfall(0, 0.0, 0.5, Some(1.0), 0.0, 0, None, None));
            x.to_json()
        };
        let (a, b) = (build(), build());
        assert_eq!(a, b);
        assert!(a.contains("\"schema\":\"flatattention-attrib-v1\""));
        assert!(a.contains("\"class\":\"attention\""));
        assert!(a.contains("\"bound\":"));
        assert!(a.contains("\"waterfalls\":["));
    }

    #[test]
    fn des_profile_note_reports_imbalance() {
        let p = DesProfile {
            workers: 2,
            epochs: 10,
            wall_s: 1.0,
            worker_busy_s: vec![0.9, 0.3],
            barrier_stall_s: vec![0.05, 0.65],
        };
        assert!((p.imbalance() - 1.5).abs() < 1e-12);
        assert!(p.note().contains("2 worker(s)"));
        assert!(DesProfile::default().note().contains("0 worker(s)"));
    }
}
