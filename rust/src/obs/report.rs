//! Rendering for the attribution layer: the `flatattention report` text
//! profile (top kernels by simulated time with roofline classification,
//! latency-waterfall percentiles, the Fig. 9 dataflow anchor and the DES
//! self-profile note) plus the structured `BENCH_*.json` emitter shared by
//! the bench drivers.

use std::path::PathBuf;

use crate::arch::config::{ChipConfig, Dtype, SimFidelity};
use crate::coordinator::report::Report;
use crate::dataflow::{simulate_attention, AttentionDataflow, FlatParams, FlatTiling};
use crate::metrics::{fmt_pct, KernelMetrics, Percentiles};
use crate::obs::attrib::{AttribExport, DesProfile, Waterfall};
use crate::workload::attention::AttentionShape;

/// The Table-II operating point behind the Fig. 9 golden anchor: FlatAsync
/// 32×32 grouping with 128×128 slices on the Table I chip, S=4096, D=128,
/// full fidelity. The report prints its matrix efficiency while active so
/// every profile carries the chip-level ground truth next to the
/// serve-scale aggregates (the acceptance test pins the two within 1%).
pub fn dataflow_anchor() -> KernelMetrics {
    let cfg = ChipConfig::table1();
    let shape = AttentionShape::mha_prefill(4, 32, 128, 4096, Dtype::Fp16);
    let t = FlatTiling { gx: 32, gy: 32, slice_r: 128, slice_c: 128 };
    simulate_attention(&cfg, &shape, AttentionDataflow::Flat(FlatParams::flat_async(t)), SimFidelity::Full)
}

fn fmt_bytes(b: f64) -> String {
    if b >= 1e12 {
        format!("{:.2} TB", b / 1e12)
    } else if b >= 1e9 {
        format!("{:.2} GB", b / 1e9)
    } else if b >= 1e6 {
        format!("{:.2} MB", b / 1e6)
    } else {
        format!("{b:.0} B")
    }
}

/// Render the full attribution profile as text. Deterministic except for
/// the optional DES self-profile note (wall-clock by design).
pub fn render_attrib_report(title: &str, attrib: &AttribExport, profile: Option<&DesProfile>) -> String {
    let mut kernels = Report::new(format!("{title} — top kernels by simulated time"));
    kernels
        .preamble(format!(
            "{} engine(s), busy {:.3} s, {} offered request(s), {} waterfall(s)",
            attrib.engines.len(),
            attrib.busy_s(),
            attrib.offered,
            attrib.waterfalls.len()
        ))
        .header(&["phase", "class", "time s", "% busy", "TFLOP/s", "HBM", "compute", "hbm bw", "flop/B", "bound"]);
    let total = attrib.kernels.total_s();
    let mut rows: Vec<_> = attrib.kernels.rows.iter().collect();
    rows.sort_by(|a, b| b.1.seconds.total_cmp(&a.1.seconds).then(a.0.cmp(b.0)));
    for ((phase, class), b) in rows {
        let tflops = if b.seconds > 0.0 { b.flops / b.seconds / 1e12 } else { 0.0 };
        kernels.row(vec![
            phase.name().to_string(),
            class.name().to_string(),
            format!("{:.4}", b.seconds),
            fmt_pct(if total > 0.0 { b.seconds / total } else { 0.0 }),
            format!("{tflops:.1}"),
            fmt_bytes(b.hbm_bytes),
            fmt_pct(b.compute_util()),
            fmt_pct(b.hbm_bw_util()),
            format!("{:.1}", b.intensity()),
            b.bound().to_string(),
        ]);
    }
    let anchor = dataflow_anchor();
    kernels.note("roofline rule: compute-bound iff achieved compute utilization >= achieved HBM-bandwidth utilization");
    kernels.note(format!(
        "dataflow anchor (Fig. 9 / Table II op point, 32x32 flat-async): matrix efficiency when active = {}",
        fmt_pct(anchor.matrix_efficiency_active)
    ));
    if let Some(p) = profile {
        kernels.note(p.note());
    }

    let mut wf = Report::new(format!("{title} — latency waterfalls (ms)"));
    wf.preamble("additive: ttft = queue_wait + prefill + link_wait + requeue_stall; decode_span = decode_solo + interference")
        .header(&["segment", "n", "mean", "p50", "p95", "p99", "max"]);
    let segments: [(&str, fn(&Waterfall) -> f64); 9] = [
        ("ttft", |w| w.ttft_s),
        ("queue_wait", |w| w.queue_wait_s),
        ("prefill", |w| w.prefill_s),
        ("requeue_stall", |w| w.requeue_stall_s),
        ("decode_span", |w| w.decode_span_s),
        ("link_wait", |w| w.link_wait_s),
        ("decode_solo", |w| w.decode_solo_s),
        ("interference", |w| w.interference_s),
        ("prefix_saved", |w| w.prefix_saved_s),
    ];
    for (name, f) in segments {
        let p: Percentiles = attrib.segment_percentiles(f);
        wf.row(vec![
            name.to_string(),
            p.n.to_string(),
            format!("{:.3}", p.mean),
            format!("{:.3}", p.p50),
            format!("{:.3}", p.p95),
            format!("{:.3}", p.p99),
            format!("{:.3}", p.max),
        ]);
    }
    let requeued = attrib.waterfalls.iter().filter(|w| w.requeues > 0).count();
    let hits: u64 = attrib.waterfalls.iter().map(|w| w.prefix_hit_tokens).sum();
    wf.note(format!("{requeued} requeued waterfall(s); {hits} prefix-hit token(s) saved across the run"));
    format!("{}\n{}", kernels.render(), wf.render())
}

/// One bench measurement row for the structured perf trajectory.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchRow {
    pub label: String,
    pub shards: u32,
    /// Simulated seconds covered by the run.
    pub sim_s: f64,
    /// Host wall seconds the run took.
    pub wall_s: f64,
    /// Speedup vs the row the driver calls its baseline (1.0 for it).
    pub speedup: f64,
}

/// `flatattention-bench-v1` JSON for `BENCH_*.json` artifacts: machine-
/// readable config + sim-s/wall-s/speedup/shards per row, so the perf
/// trajectory is comparable across PRs. Wall times are wall-clock by
/// nature; these artifacts are diagnostics, never byte-pinned.
pub fn bench_json(bench: &str, config: &str, rows: &[BenchRow]) -> String {
    let mut out = format!("{{\"schema\":\"flatattention-bench-v1\",\"bench\":\"{bench}\",\"config\":\"{config}\",\"rows\":[");
    for (i, r) in rows.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "{{\"label\":\"{}\",\"shards\":{},\"sim_s\":{:.6},\"wall_s\":{:.6},\"speedup\":{:.4}}}",
            r.label, r.shards, r.sim_s, r.wall_s, r.speedup
        ));
    }
    out.push_str("]}");
    out
}

/// Where a bench driver should write its JSON: an explicit `--json-out
/// PATH` argument wins; otherwise `FLATATTENTION_BENCH_JSON=<dir>` maps to
/// `<dir>/BENCH_<name>.json`; otherwise no artifact is written.
pub fn bench_json_path(bench: &str) -> Option<PathBuf> {
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        if a == "--json-out" {
            if let Some(p) = args.next() {
                return Some(PathBuf::from(p));
            }
        }
    }
    match std::env::var("FLATATTENTION_BENCH_JSON") {
        Ok(dir) if !dir.is_empty() => Some(PathBuf::from(dir).join(format!("BENCH_{bench}.json"))),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::attrib::{AttribClass, AttribPhase, AttribRecorder, StageAttrib};

    #[test]
    fn anchor_sits_in_the_fig9_band() {
        let m = dataflow_anchor();
        assert!(m.matrix_efficiency_active > 0.80, "Fig. 9 anchor regressed: {}", m.matrix_efficiency_active);
        assert!(m.matrix_efficiency_active <= 1.0);
    }

    #[test]
    fn report_renders_kernels_waterfalls_and_anchor() {
        let mut rec = AttribRecorder::default();
        let mut a = StageAttrib::default();
        a.add_seconds(AttribClass::Comm, 0.2);
        a.settle(0.5);
        rec.bill(AttribPhase::Decode, &a);
        let mut x = AttribExport { offered: 3, ..AttribExport::default() };
        x.push_engine(0, &rec);
        x.waterfalls.push(crate::obs::attrib::assemble_waterfall(0, 0.0, 0.4, Some(1.0), 0.0, 0, None, None));
        let s = render_attrib_report("serve", &x, Some(&DesProfile::default()));
        assert!(s.contains("top kernels by simulated time"));
        assert!(s.contains("dataflow anchor"));
        assert!(s.contains("latency waterfalls"));
        assert!(s.contains("DES self-profile"));
        assert!(s.contains("comm"));
        assert!(s.contains("requeue_stall"));
    }

    #[test]
    fn bench_json_schema_and_rows() {
        let rows = vec![
            BenchRow { label: "shards=1".into(), shards: 1, sim_s: 2.0, wall_s: 0.5, speedup: 1.0 },
            BenchRow { label: "shards=4".into(), shards: 4, sim_s: 2.0, wall_s: 0.2, speedup: 2.5 },
        ];
        let j = bench_json("cluster_pools", "instances=64 rate=8000", &rows);
        assert!(j.contains("\"schema\":\"flatattention-bench-v1\""));
        assert!(j.contains("\"bench\":\"cluster_pools\""));
        assert!(j.contains("\"shards\":4"));
        assert!(j.contains("\"speedup\":2.5000"));
        assert!(j.ends_with("]}"));
    }
}
