//! Fixed-interval gauge sampler: per-instance time series of queue depth,
//! batch occupancy, KV utilization (worst and per EP column), prefix-cache
//! hit rate, link busy fraction, engine utilization / HBM-bandwidth
//! fractions and fault visibility (instances up, requeue backlog), on the
//! simulated clock.
//!
//! The engine samples at wave boundaries, so the sampler works on a grid:
//! [`SeriesSampler::ready`] is true once the clock passed the next grid
//! point, and [`SeriesSampler::record`] advances the grid past the sampled
//! time — one row per interval regardless of tick duration jitter.
//!
//! The sampler is bounded: rows beyond the cap are dropped and counted
//! (`dropped_points` in both exports plus the
//! `flatattention_series_points_dropped_total` counter), mirroring the
//! trace recorder's loud-drop contract — a long horizon with a tight
//! interval can no longer grow the recorder unboundedly and silently.

/// One gauge sample.
#[derive(Debug, Clone, PartialEq)]
pub struct SeriesRow {
    /// Sample time (simulated seconds — the wave boundary that crossed the
    /// grid point).
    pub t_s: f64,
    /// Instance lane (matches the trace pid).
    pub pid: u32,
    /// Requests waiting in the scheduler queue.
    pub queue_depth: usize,
    /// Requests resident in (column, wave) cells — batch occupancy.
    pub active_users: usize,
    /// Worst current KV occupancy fraction across EP columns.
    pub kv_frac: f64,
    /// Per-EP-column KV occupancy fractions (empty on the fleet lane).
    pub kv_col_frac: Vec<f64>,
    /// Cumulative prefix-cache hit rate (0 without shared prefixes).
    pub prefix_hit_rate: f64,
    /// Fabric-wide KV busy fraction (fleet lane only; 0 elsewhere) — the
    /// pooled link's share on the degenerate topology, the mean per-edge
    /// share otherwise.
    pub link_busy_frac: f64,
    /// Per-edge busy fractions of the KV fabric in edge-construction order
    /// (fleet lane only; empty elsewhere and on fabric-less runs). The
    /// degenerate 1-switch topology reports its pool as one logical edge.
    pub edge_busy_frac: Vec<f64>,
    /// Engine busy fraction of the elapsed sampling interval (0 on the
    /// fleet lane and when attribution is not recording).
    pub util_frac: f64,
    /// Average HBM-bandwidth fraction over the elapsed interval (0 on the
    /// fleet lane and when attribution is not recording).
    pub hbm_bw_frac: f64,
    /// Instances currently up (fleet lane only; 0 on engine lanes).
    pub instances_up: usize,
    /// Requests sitting in the fault-requeue backlog (fleet lane only).
    pub requeue_depth: usize,
}

/// Grid-based sampler for one instance, bounded by a row cap.
#[derive(Debug, Clone)]
pub struct SeriesSampler {
    pid: u32,
    interval_s: f64,
    next_s: f64,
    cap: usize,
    dropped: u64,
    rows: Vec<SeriesRow>,
}

/// Default row cap (matches [`crate::obs::ObsConfig::default`]).
const DEFAULT_SERIES_CAP: usize = 65_536;

impl SeriesSampler {
    pub fn new(pid: u32, interval_s: f64) -> Self {
        SeriesSampler {
            pid,
            interval_s: interval_s.max(1e-6),
            next_s: 0.0,
            cap: DEFAULT_SERIES_CAP,
            dropped: 0,
            rows: Vec::new(),
        }
    }

    /// Override the row cap (a cap of 0 drops everything — loudly).
    pub fn with_cap(mut self, cap: usize) -> Self {
        self.cap = cap;
        self
    }

    pub fn pid(&self) -> u32 {
        self.pid
    }

    pub fn interval_s(&self) -> f64 {
        self.interval_s
    }

    /// Rows dropped because the cap was reached.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// True when the clock reached the next grid point — time to sample.
    pub fn ready(&self, t_s: f64) -> bool {
        t_s >= self.next_s
    }

    /// Record a sample and advance the grid past it. Rows beyond the cap
    /// are counted in [`SeriesSampler::dropped`], never silently lost.
    pub fn record(&mut self, row: SeriesRow) {
        while self.next_s <= row.t_s {
            self.next_s += self.interval_s;
        }
        if self.rows.len() >= self.cap {
            self.dropped += 1;
            return;
        }
        self.rows.push(row);
    }

    pub fn rows(&self) -> &[SeriesRow] {
        &self.rows
    }
}

/// Rows of every sampler merged and sorted by (time, instance) —
/// deterministic regardless of per-instance sampling cadence.
fn merged<'a>(samplers: &'a [&'a SeriesSampler]) -> Vec<&'a SeriesRow> {
    let mut rows: Vec<&SeriesRow> = samplers.iter().flat_map(|s| s.rows().iter()).collect();
    rows.sort_by(|a, b| a.t_s.total_cmp(&b.t_s).then(a.pid.cmp(&b.pid)));
    rows
}

fn total_dropped(samplers: &[&SeriesSampler]) -> u64 {
    samplers.iter().map(|s| s.dropped()).sum()
}

/// CSV export: one row per sample; the vector gauges are semicolon-joined
/// (`edge_busy_frac` — per fabric edge, and `kv_col_frac` last — per EP
/// column) so the breakdowns survive the flat format. A trailing
/// `# dropped_points N` comment line appears when any sampler hit its cap.
pub fn export_series_csv(samplers: &[&SeriesSampler]) -> String {
    let mut out = String::from(
        "t_s,instance,queue_depth,active_users,kv_frac,prefix_hit_rate,link_busy_frac,\
         util_frac,hbm_bw_frac,instances_up,requeue_depth,edge_busy_frac,kv_col_frac\n",
    );
    for r in merged(samplers) {
        let edges: Vec<String> = r.edge_busy_frac.iter().map(|f| format!("{f:.6}")).collect();
        let cols: Vec<String> = r.kv_col_frac.iter().map(|f| format!("{f:.6}")).collect();
        out.push_str(&format!(
            "{:.6},{},{},{},{:.6},{:.6},{:.6},{:.6},{:.6},{},{},{},{}\n",
            r.t_s,
            r.pid,
            r.queue_depth,
            r.active_users,
            r.kv_frac,
            r.prefix_hit_rate,
            r.link_busy_frac,
            r.util_frac,
            r.hbm_bw_frac,
            r.instances_up,
            r.requeue_depth,
            edges.join(";"),
            cols.join(";")
        ));
    }
    let dropped = total_dropped(samplers);
    if dropped > 0 {
        out.push_str(&format!("# dropped_points {dropped}\n"));
    }
    out
}

/// JSON export with full per-column arrays (for plotting pipelines).
pub fn export_series_json(samplers: &[&SeriesSampler]) -> String {
    let mut out = format!("{{\"dropped_points\":{},\"rows\":[", total_dropped(samplers));
    for (i, r) in merged(samplers).iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let edges: Vec<String> = r.edge_busy_frac.iter().map(|f| format!("{f:.6}")).collect();
        let cols: Vec<String> = r.kv_col_frac.iter().map(|f| format!("{f:.6}")).collect();
        out.push_str(&format!(
            "{{\"t_s\":{:.6},\"instance\":{},\"queue_depth\":{},\"active_users\":{},\"kv_frac\":{:.6},\
             \"prefix_hit_rate\":{:.6},\"link_busy_frac\":{:.6},\"util_frac\":{:.6},\"hbm_bw_frac\":{:.6},\
             \"instances_up\":{},\"requeue_depth\":{},\"edge_busy_frac\":[{}],\"kv_col_frac\":[{}]}}",
            r.t_s,
            r.pid,
            r.queue_depth,
            r.active_users,
            r.kv_frac,
            r.prefix_hit_rate,
            r.link_busy_frac,
            r.util_frac,
            r.hbm_bw_frac,
            r.instances_up,
            r.requeue_depth,
            edges.join(","),
            cols.join(",")
        ));
    }
    out.push_str("]}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(t_s: f64, pid: u32, q: usize) -> SeriesRow {
        SeriesRow {
            t_s,
            pid,
            queue_depth: q,
            active_users: 2 * q,
            kv_frac: 0.5,
            kv_col_frac: vec![0.5, 0.25],
            prefix_hit_rate: 0.0,
            link_busy_frac: 0.0,
            edge_busy_frac: Vec::new(),
            util_frac: 0.75,
            hbm_bw_frac: 0.5,
            instances_up: 0,
            requeue_depth: 0,
        }
    }

    #[test]
    fn grid_takes_one_sample_per_interval() {
        let mut s = SeriesSampler::new(0, 0.1);
        assert!(s.ready(0.0));
        s.record(row(0.0, 0, 1));
        assert!(!s.ready(0.05), "grid advanced past the sample");
        assert!(s.ready(0.1));
        s.record(row(0.13, 0, 2));
        assert!(!s.ready(0.19));
        assert!(s.ready(0.2));
        // A long idle gap yields one sample, not a backlog of catch-ups.
        s.record(row(1.0, 0, 3));
        assert!(!s.ready(1.05));
        assert_eq!(s.rows().len(), 3);
    }

    #[test]
    fn csv_and_json_merge_sorted_by_time_then_instance() {
        let mut a = SeriesSampler::new(0, 0.1);
        let mut b = SeriesSampler::new(1, 0.1);
        b.record(row(0.05, 1, 9));
        a.record(row(0.05, 0, 4));
        a.record(row(0.2, 0, 5));
        let csv = export_series_csv(&[&a, &b]);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("t_s,instance,"));
        assert!(lines[1].starts_with("0.050000,0,4,8,"), "{csv}");
        assert!(lines[2].starts_with("0.050000,1,9,18,"), "{csv}");
        assert!(lines[3].starts_with("0.200000,0,5,10,"), "{csv}");
        assert!(lines[1].ends_with("0.500000;0.250000"), "{csv}");
        let json = export_series_json(&[&a, &b]);
        assert!(json.starts_with("{\"dropped_points\":0,\"rows\":[") && json.ends_with("]}"), "{json}");
        assert!(json.contains("\"kv_col_frac\":[0.500000,0.250000]"), "{json}");
        assert!(json.contains("\"util_frac\":0.750000"), "{json}");
        assert!(json.contains("\"instances_up\":0"), "{json}");
        assert_eq!(json.matches("\"t_s\"").count(), 3);
        // Determinism.
        assert_eq!(csv, export_series_csv(&[&a, &b]));
        assert_eq!(json, export_series_json(&[&a, &b]));
    }

    #[test]
    fn edge_busy_column_round_trips_both_exports() {
        let mut s = SeriesSampler::new(2, 0.1);
        s.record(SeriesRow { edge_busy_frac: vec![0.125, 0.0, 1.0], ..row(0.0, 2, 1) });
        let csv = export_series_csv(&[&s]);
        let lines: Vec<&str> = csv.lines().collect();
        assert!(lines[0].contains(",requeue_depth,edge_busy_frac,kv_col_frac"), "{csv}");
        assert!(lines[1].contains(",0.125000;0.000000;1.000000,"), "{csv}");
        let json = export_series_json(&[&s]);
        assert!(json.contains("\"edge_busy_frac\":[0.125000,0.000000,1.000000]"), "{json}");
        // An engine-lane row without fabric data exports an empty column.
        let mut e = SeriesSampler::new(0, 0.1);
        e.record(row(0.0, 0, 1));
        assert!(export_series_csv(&[&e]).lines().nth(1).unwrap().contains(",0,0,,"));
        assert!(export_series_json(&[&e]).contains("\"edge_busy_frac\":[]"));
    }

    #[test]
    fn cap_drops_loudly_and_exports_account_for_it() {
        let mut s = SeriesSampler::new(0, 0.1).with_cap(2);
        s.record(row(0.0, 0, 1));
        s.record(row(0.1, 0, 2));
        s.record(row(0.2, 0, 3));
        s.record(row(0.3, 0, 4));
        assert_eq!(s.rows().len(), 2);
        assert_eq!(s.dropped(), 2);
        // The grid keeps advancing past dropped samples — no catch-up burst.
        assert!(!s.ready(0.35));
        let csv = export_series_csv(&[&s]);
        assert!(csv.ends_with("# dropped_points 2\n"), "{csv}");
        assert!(csv.starts_with("t_s,instance,"), "header must stay first for downstream greps: {csv}");
        let json = export_series_json(&[&s]);
        assert!(json.starts_with("{\"dropped_points\":2,"), "{json}");
    }
}
