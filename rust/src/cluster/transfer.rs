//! KV-cache handoff model for disaggregated prefill/decode pools.
//!
//! When a request migrates from a prefill-pool instance to a decode-pool
//! instance, its whole prompt KV must move. Under MLA the payload is the
//! *latent* cache layout — `(kv_lora_rank + qk_rope_dim)` values per token
//! per layer, the same layout `serve::kv::KvCacheModel` budgets — which is
//! what makes disaggregation affordable for DeepSeek-class models in the
//! first place: ~576 B/token/layer at FP8 instead of the 32 KiB/token/layer
//! an uncompressed MHA cache would ship.
//!
//! The transfer is *layer-streamable*: stage `l`'s latent KV is final as
//! soon as layer `l`'s prefill finishes, so a fraction of the serialization
//! overlaps the tail of prefill (and the prefill instance's next chunk).
//! Only the exposed remainder delays the user's first token and the decode
//! pool's admission — the prefill instance itself is never stalled (the
//! D2D/NIC engines move the bytes, not the compute tiles).

use crate::arch::config::Dtype;
use crate::workload::deepseek::DeepSeekConfig;

/// Closed-form KV handoff cost between two instances.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KvTransferModel {
    /// Latent-KV layout bytes one context token occupies across *all*
    /// layers (`(kv_lora_rank + qk_rope_dim) × dtype × layers`).
    pub bytes_per_token: u64,
    /// Effective inter-instance bandwidth in bytes/second.
    pub link_bandwidth_bytes_per_s: f64,
    /// Per-transfer base latency in seconds (connection setup, routing).
    pub base_latency_s: f64,
    /// Fraction of the serialization hidden behind the tail of prefill /
    /// the next prefill chunk (layer-streamed transfer), in [0, 1].
    pub overlap_fraction: f64,
}

impl KvTransferModel {
    /// Inter-node class links between wafer instances: 16 GB/s effective
    /// per-flow (RDMA NIC class) with a 1 ms setup latency, half the
    /// serialization hidden by layer streaming.
    pub fn inter_node(ds: &DeepSeekConfig, dtype: Dtype) -> Self {
        KvTransferModel {
            bytes_per_token: Self::layout_bytes_per_token(ds, dtype),
            link_bandwidth_bytes_per_s: 16.0e9,
            base_latency_s: 1.0e-3,
            overlap_fraction: 0.5,
        }
    }

    /// D2D-class links (instances on one wafer carrier, paper Fig. 2c
    /// numbers: 1 TB/s, 256 ns): handoff is essentially free.
    pub fn d2d_class(ds: &DeepSeekConfig, dtype: Dtype) -> Self {
        KvTransferModel {
            bytes_per_token: Self::layout_bytes_per_token(ds, dtype),
            link_bandwidth_bytes_per_s: 1.0e12,
            base_latency_s: 256e-9,
            overlap_fraction: 0.5,
        }
    }

    /// Latent-KV layout bytes per context token, all layers — the single
    /// source of truth the transfer-bytes invariant tests pin against.
    pub fn layout_bytes_per_token(ds: &DeepSeekConfig, dtype: Dtype) -> u64 {
        (ds.kv_lora_rank + ds.qk_rope_dim) as u64 * dtype.bytes() * ds.layers as u64
    }

    /// Bytes a migrated request of `context_tokens` ships.
    pub fn bytes_for(&self, context_tokens: u64) -> u64 {
        context_tokens * self.bytes_per_token
    }

    /// Raw serialization time of the payload (no overlap, no base latency).
    pub fn serialization_seconds(&self, context_tokens: u64) -> f64 {
        self.bytes_for(context_tokens) as f64 / self.link_bandwidth_bytes_per_s.max(1.0)
    }

    /// Exposed handoff delay the migrating request experiences: base
    /// latency plus the non-overlapped share of serialization. This delays
    /// both the user-visible first token and the decode-pool arrival.
    pub fn exposed_seconds(&self, context_tokens: u64) -> f64 {
        let hidden = self.overlap_fraction.clamp(0.0, 1.0);
        self.base_latency_s + (1.0 - hidden) * self.serialization_seconds(context_tokens)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_bytes_match_mla_cache() {
        let ds = DeepSeekConfig::v3_671b();
        let t = KvTransferModel::inter_node(&ds, Dtype::Fp8);
        // (512 latent + 64 rope) × 1 B × 61 layers.
        assert_eq!(t.bytes_per_token, 576 * 61);
        assert_eq!(
            t.bytes_per_token,
            ds.kv_cache_bytes_per_user_layer(1, Dtype::Fp8) * ds.layers as u64
        );
        assert_eq!(t.bytes_for(1000), 1000 * 576 * 61);
    }

    #[test]
    fn exposed_delay_is_base_plus_unhidden_serialization() {
        let ds = DeepSeekConfig::v3_671b();
        let t = KvTransferModel::inter_node(&ds, Dtype::Fp8);
        let ctx = 1024u64;
        let ser = t.serialization_seconds(ctx);
        assert!(ser > 0.0);
        let exp = t.exposed_seconds(ctx);
        assert!((exp - (t.base_latency_s + 0.5 * ser)).abs() < 1e-15);
        // Full overlap leaves only the base latency; zero overlap exposes
        // the whole serialization.
        let full = KvTransferModel { overlap_fraction: 1.0, ..t };
        assert!((full.exposed_seconds(ctx) - t.base_latency_s).abs() < 1e-15);
        let none = KvTransferModel { overlap_fraction: 0.0, ..t };
        assert!((none.exposed_seconds(ctx) - (t.base_latency_s + ser)).abs() < 1e-15);
    }

    #[test]
    fn d2d_class_is_orders_of_magnitude_cheaper() {
        let ds = DeepSeekConfig::v3_671b();
        let inter = KvTransferModel::inter_node(&ds, Dtype::Fp8);
        let d2d = KvTransferModel::d2d_class(&ds, Dtype::Fp8);
        assert!(d2d.exposed_seconds(4096) < inter.exposed_seconds(4096) / 50.0);
    }

    #[test]
    fn longer_contexts_ship_proportionally_more() {
        let ds = DeepSeekConfig::v3_671b();
        let t = KvTransferModel::inter_node(&ds, Dtype::Fp8);
        assert_eq!(t.bytes_for(2048), 2 * t.bytes_for(1024));
        assert!(t.exposed_seconds(2048) > t.exposed_seconds(1024));
        assert_eq!(t.bytes_for(0), 0);
    }
}
