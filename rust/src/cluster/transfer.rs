//! KV-cache handoff model for disaggregated prefill/decode pools.
//!
//! When a request migrates from a prefill-pool instance to a decode-pool
//! instance, its whole prompt KV must move. Under MLA the payload is the
//! *latent* cache layout — `(kv_lora_rank + qk_rope_dim)` values per token
//! per layer, the same layout `serve::kv::KvCacheModel` budgets — which is
//! what makes disaggregation affordable for DeepSeek-class models in the
//! first place: ~576 B/token/layer at FP8 instead of the 32 KiB/token/layer
//! an uncompressed MHA cache would ship.
//!
//! The transfer is *layer-streamable*: stage `l`'s latent KV is final as
//! soon as layer `l`'s prefill finishes, so a fraction of the serialization
//! overlaps the tail of prefill (and the prefill instance's next chunk).
//! Only the exposed remainder delays the user's first token and the decode
//! pool's admission — the prefill instance itself is never stalled (the
//! D2D/NIC engines move the bytes, not the compute tiles).
//!
//! Concurrent migrations are NOT free: the pools share one inter-instance
//! fabric of [`KvTransferModel::parallel_flows`] equal channels, and
//! [`SharedLink`] serializes transfers on it with busy-until accounting —
//! a migration that finds every channel occupied queues behind the earliest
//! one to free up, and the wait adds to its exposed handoff delay. The
//! fleet routes every handoff over [`crate::cluster::fabric::Fabric`]: the
//! degenerate topology is exactly one pooled `SharedLink` (field-identical
//! to the historical shared-fabric path), while routed topologies (torus,
//! fat-tree) instantiate one 1-channel `SharedLink` per directed edge — so
//! the busy-until ledger in [`SharedLink::schedule_bytes`] (the single
//! writer; [`SharedLink::schedule`] routes through it) and the exact
//! time-in-window integral in [`SharedLink::busy_fraction`] are reused
//! verbatim per edge, and link congestion shows up in TTFT exactly when
//! migration traffic exceeds the capacity of the edges it crosses.

use crate::arch::config::Dtype;
use crate::workload::deepseek::DeepSeekConfig;

/// Closed-form KV handoff cost between two instances.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KvTransferModel {
    /// Latent-KV layout bytes one context token occupies across *all*
    /// layers (`(kv_lora_rank + qk_rope_dim) × dtype × layers`).
    pub bytes_per_token: u64,
    /// Effective inter-instance bandwidth in bytes/second.
    pub link_bandwidth_bytes_per_s: f64,
    /// Per-transfer base latency in seconds (connection setup, routing).
    pub base_latency_s: f64,
    /// Fraction of the serialization hidden behind the tail of prefill /
    /// the next prefill chunk (layer-streamed transfer), in [0, 1].
    pub overlap_fraction: f64,
    /// Equal channels of the shared inter-pool fabric ([`SharedLink`]):
    /// this many migrations serialize concurrently at full per-flow
    /// bandwidth; the rest queue.
    pub parallel_flows: u32,
}

impl KvTransferModel {
    /// Inter-node class links between wafer instances: 16 GB/s effective
    /// per-flow (RDMA NIC class) with a 1 ms setup latency, half the
    /// serialization hidden by layer streaming, four bonded flows on the
    /// shared fabric.
    pub fn inter_node(ds: &DeepSeekConfig, dtype: Dtype) -> Self {
        KvTransferModel {
            bytes_per_token: Self::layout_bytes_per_token(ds, dtype),
            link_bandwidth_bytes_per_s: 16.0e9,
            base_latency_s: 1.0e-3,
            overlap_fraction: 0.5,
            parallel_flows: 4,
        }
    }

    /// D2D-class links (instances on one wafer carrier, paper Fig. 2c
    /// numbers: 1 TB/s, 256 ns): handoff is essentially free.
    pub fn d2d_class(ds: &DeepSeekConfig, dtype: Dtype) -> Self {
        KvTransferModel {
            bytes_per_token: Self::layout_bytes_per_token(ds, dtype),
            link_bandwidth_bytes_per_s: 1.0e12,
            base_latency_s: 256e-9,
            overlap_fraction: 0.5,
            parallel_flows: 8,
        }
    }

    /// Latent-KV layout bytes per context token, all layers — the single
    /// source of truth the transfer-bytes invariant tests pin against.
    pub fn layout_bytes_per_token(ds: &DeepSeekConfig, dtype: Dtype) -> u64 {
        (ds.kv_lora_rank + ds.qk_rope_dim) as u64 * dtype.bytes() * ds.layers as u64
    }

    /// Bytes a migrated request of `context_tokens` ships.
    pub fn bytes_for(&self, context_tokens: u64) -> u64 {
        context_tokens * self.bytes_per_token
    }

    /// Raw serialization time of the payload (no overlap, no base latency).
    pub fn serialization_seconds(&self, context_tokens: u64) -> f64 {
        self.bytes_for(context_tokens) as f64 / self.link_bandwidth_bytes_per_s.max(1.0)
    }

    /// Exposed handoff delay the migrating request experiences on an idle
    /// fabric: base latency plus the non-overlapped share of serialization.
    /// This delays both the user-visible first token and the decode-pool
    /// arrival. Under contention, [`SharedLink::schedule`] adds the queue
    /// wait on top.
    pub fn exposed_seconds(&self, context_tokens: u64) -> f64 {
        let hidden = self.overlap_fraction.clamp(0.0, 1.0);
        self.base_latency_s + (1.0 - hidden) * self.serialization_seconds(context_tokens)
    }

    /// Safe conservative-lookahead window for the sharded fleet engine.
    ///
    /// Every cross-instance event in the fleet is a KV handoff, and its
    /// decode-pool arrival lands at `ready_s + exposed`, where `exposed =
    /// base_latency_s + wait + (1 - overlap) * serialization ≥
    /// base_latency_s`. So a handoff that becomes ready inside epoch `k`
    /// can only inject an arrival at or after the start of epoch `k + 1`
    /// when epochs are `base_latency_s` long — shards may advance a full
    /// epoch without seeing each other's in-flight events, and exchanging
    /// them at the barrier is causally sufficient. This is the classic
    /// conservative PDES lookahead, with the link's base latency as the
    /// minimum event propagation delay.
    pub fn lookahead_s(&self) -> f64 {
        self.base_latency_s
    }
}

/// Busy-until serialization state of the shared inter-pool KV fabric.
///
/// The fabric is `parallel_flows` equal channels; a migration occupies the
/// earliest-free channel for its full serialization time. When every
/// channel is busy at the migration's ready time, the transfer *queues* —
/// concurrent migrations no longer overlap for free — and the wait is
/// exposed to the user on top of [`KvTransferModel::exposed_seconds`].
#[derive(Debug, Clone, PartialEq)]
pub struct SharedLink {
    /// Per-channel busy-until times.
    free_at: Vec<f64>,
    /// `(start, serialization)` of every scheduled transfer, in schedule
    /// order — the exact-occupancy ledger [`SharedLink::busy_fraction`]
    /// integrates over.
    intervals: Vec<(f64, f64)>,
    /// Transfers scheduled so far.
    pub transfers: u64,
    /// Summed serialization time occupying the fabric.
    pub busy_s: f64,
    /// Summed queueing wait across transfers (0 on an uncontended fabric).
    pub wait_s: f64,
}

impl SharedLink {
    pub fn new(parallel_flows: u32) -> Self {
        SharedLink {
            free_at: vec![0.0; parallel_flows.max(1) as usize],
            intervals: Vec::new(),
            transfers: 0,
            busy_s: 0.0,
            wait_s: 0.0,
        }
    }

    /// Schedule a migration of `context_tokens` that becomes ready (prefill
    /// complete) at `ready_s`. Returns the exposed handoff delay: base
    /// latency + queue wait + the non-overlapped serialization share.
    /// Deterministic: the earliest-free channel wins, ties to the lowest
    /// index.
    pub fn schedule(&mut self, ready_s: f64, context_tokens: u64, model: &KvTransferModel) -> f64 {
        self.schedule_bytes(ready_s, model.bytes_for(context_tokens), model)
    }

    /// [`SharedLink::schedule`] for an arbitrary byte payload — the cluster
    /// layer bills cold-start weight reloads (a restarting instance pulling
    /// its shard of weights back into HBM) through the same contended
    /// fabric as KV handoffs.
    pub fn schedule_bytes(&mut self, ready_s: f64, bytes: u64, model: &KvTransferModel) -> f64 {
        let ser = bytes as f64 / model.link_bandwidth_bytes_per_s.max(1.0);
        let mut ch = 0usize;
        for (i, &t) in self.free_at.iter().enumerate().skip(1) {
            if t < self.free_at[ch] {
                ch = i;
            }
        }
        let start = ready_s.max(self.free_at[ch]);
        let wait = start - ready_s;
        self.free_at[ch] = start + ser;
        self.intervals.push((start, ser));
        self.transfers += 1;
        self.busy_s += ser;
        self.wait_s += wait;
        let hidden = model.overlap_fraction.clamp(0.0, 1.0);
        model.base_latency_s + wait + (1.0 - hidden) * ser
    }

    /// Fraction of the fabric's capacity (all channels × horizon) spent
    /// serializing transfers — the router-telemetry congestion signal.
    /// Exact time-in-window integral: each transfer's occupied interval
    /// `[start, start + ser)` is clamped to `[0, horizon_s]` before
    /// summing, so a handoff scheduled near the end of the window only
    /// books the part of its serialization that actually lands inside it.
    pub fn busy_fraction(&self, horizon_s: f64) -> f64 {
        if horizon_s <= 0.0 {
            return 0.0;
        }
        let in_window: f64 = self
            .intervals
            .iter()
            .map(|&(start, ser)| (start + ser).min(horizon_s).max(0.0) - start.clamp(0.0, horizon_s))
            .sum();
        (in_window / (horizon_s * self.free_at.len() as f64)).min(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_bytes_match_mla_cache() {
        let ds = DeepSeekConfig::v3_671b();
        let t = KvTransferModel::inter_node(&ds, Dtype::Fp8);
        // (512 latent + 64 rope) × 1 B × 61 layers.
        assert_eq!(t.bytes_per_token, 576 * 61);
        assert_eq!(
            t.bytes_per_token,
            ds.kv_cache_bytes_per_user_layer(1, Dtype::Fp8) * ds.layers as u64
        );
        assert_eq!(t.bytes_for(1000), 1000 * 576 * 61);
    }

    #[test]
    fn exposed_delay_is_base_plus_unhidden_serialization() {
        let ds = DeepSeekConfig::v3_671b();
        let t = KvTransferModel::inter_node(&ds, Dtype::Fp8);
        let ctx = 1024u64;
        let ser = t.serialization_seconds(ctx);
        assert!(ser > 0.0);
        let exp = t.exposed_seconds(ctx);
        assert!((exp - (t.base_latency_s + 0.5 * ser)).abs() < 1e-15);
        // Full overlap leaves only the base latency; zero overlap exposes
        // the whole serialization.
        let full = KvTransferModel { overlap_fraction: 1.0, ..t };
        assert!((full.exposed_seconds(ctx) - t.base_latency_s).abs() < 1e-15);
        let none = KvTransferModel { overlap_fraction: 0.0, ..t };
        assert!((none.exposed_seconds(ctx) - (t.base_latency_s + ser)).abs() < 1e-15);
    }

    #[test]
    fn d2d_class_is_orders_of_magnitude_cheaper() {
        let ds = DeepSeekConfig::v3_671b();
        let inter = KvTransferModel::inter_node(&ds, Dtype::Fp8);
        let d2d = KvTransferModel::d2d_class(&ds, Dtype::Fp8);
        assert!(d2d.exposed_seconds(4096) < inter.exposed_seconds(4096) / 50.0);
    }

    #[test]
    fn shared_link_queues_concurrent_migrations() {
        let ds = DeepSeekConfig::v3_671b();
        // One flow, no base latency / overlap noise: exposure is pure
        // serialization + queueing.
        let model = KvTransferModel {
            base_latency_s: 0.0,
            overlap_fraction: 0.0,
            parallel_flows: 1,
            ..KvTransferModel::inter_node(&ds, Dtype::Fp8)
        };
        let ser = model.serialization_seconds(1024);
        let mut link = SharedLink::new(model.parallel_flows);
        // First transfer at t=0 rides an idle link.
        let e0 = link.schedule(0.0, 1024, &model);
        assert!((e0 - ser).abs() < 1e-15, "idle link exposes only serialization");
        // A second transfer ready at the same instant queues a full slot.
        let e1 = link.schedule(0.0, 1024, &model);
        assert!((e1 - 2.0 * ser).abs() < 1e-12, "concurrent migration must wait: {e1} vs {ser}");
        assert!((link.wait_s - ser).abs() < 1e-12);
        // A transfer ready after the backlog drains pays no wait.
        let e2 = link.schedule(10.0, 1024, &model);
        assert!((e2 - ser).abs() < 1e-15);
        assert_eq!(link.transfers, 3);
        assert!((link.busy_s - 3.0 * ser).abs() < 1e-12);
        assert!(link.busy_fraction(10.0) > 0.0 && link.busy_fraction(10.0) <= 1.0);
        assert_eq!(link.busy_fraction(0.0), 0.0);
    }

    #[test]
    fn shared_link_parallel_flows_absorb_bursts() {
        let ds = DeepSeekConfig::v3_671b();
        let model = KvTransferModel {
            base_latency_s: 0.0,
            overlap_fraction: 0.0,
            ..KvTransferModel::inter_node(&ds, Dtype::Fp8)
        };
        assert_eq!(model.parallel_flows, 4);
        let ser = model.serialization_seconds(2048);
        let mut link = SharedLink::new(model.parallel_flows);
        // Four simultaneous migrations each get their own channel …
        for _ in 0..4 {
            let e = link.schedule(0.0, 2048, &model);
            assert!((e - ser).abs() < 1e-15, "within-capacity burst must not queue");
        }
        // … and the fifth queues behind the earliest.
        let e = link.schedule(0.0, 2048, &model);
        assert!((e - 2.0 * ser).abs() < 1e-12);
        assert!((link.wait_s - ser).abs() < 1e-12);
        // Uncontended exposure matches the closed-form model exactly.
        let full = KvTransferModel::inter_node(&ds, Dtype::Fp8);
        let mut idle = SharedLink::new(full.parallel_flows);
        let e = idle.schedule(0.0, 4096, &full);
        assert!((e - full.exposed_seconds(4096)).abs() < 1e-15);
    }

    #[test]
    fn busy_fraction_clamps_transfers_to_the_window() {
        let ds = DeepSeekConfig::v3_671b();
        let model = KvTransferModel {
            base_latency_s: 0.0,
            overlap_fraction: 0.0,
            parallel_flows: 1,
            ..KvTransferModel::inter_node(&ds, Dtype::Fp8)
        };
        let ser = model.serialization_seconds(1024);
        let horizon = 2.0 * ser;
        let mut link = SharedLink::new(model.parallel_flows);
        // One transfer entirely inside the window …
        link.schedule(0.0, 1024, &model);
        assert!((link.busy_fraction(horizon) - 0.5).abs() < 1e-12);
        // … one straddling the horizon: only half of it is in-window …
        link.schedule(1.5 * ser, 1024, &model);
        assert!((link.busy_fraction(horizon) - 0.75).abs() < 1e-12, "straddling transfer books only its in-window share");
        // … and one entirely past the horizon books nothing, even though
        // `busy_s` (the all-time total) keeps counting it.
        link.schedule(10.0 * ser, 1024, &model);
        assert!((link.busy_fraction(horizon) - 0.75).abs() < 1e-12, "post-horizon transfer must not inflate occupancy");
        assert!((link.busy_s - 3.0 * ser).abs() < 1e-12);
        // schedule_bytes is the same ledger: a weight-load payload occupies
        // the fabric exactly like a KV handoff of equal bytes.
        let mut a = SharedLink::new(1);
        let mut b = SharedLink::new(1);
        a.schedule(0.0, 1024, &model);
        b.schedule_bytes(0.0, model.bytes_for(1024), &model);
        assert_eq!(a, b);
    }

    #[test]
    fn longer_contexts_ship_proportionally_more() {
        let ds = DeepSeekConfig::v3_671b();
        let t = KvTransferModel::inter_node(&ds, Dtype::Fp8);
        assert_eq!(t.bytes_for(2048), 2 * t.bytes_for(1024));
        assert!(t.exposed_seconds(2048) > t.exposed_seconds(1024));
        assert_eq!(t.bytes_for(0), 0);
    }
}
