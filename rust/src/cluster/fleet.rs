//! Fleet-level simulation: N wafer instances, pool roles, routing and KV
//! handoff.
//!
//! The fleet simulator composes the *existing* request-level serving
//! simulator (`serve::sim::simulate`) — every instance runs the same
//! iteration-level continuous-batching loop against the shared
//! `StageTimeCache`/`KernelCache`, so all latencies stay grounded in the
//! FlatAttention dataflow simulations. The cluster layer adds exactly the
//! parts one instance cannot see:
//!
//! - **Routing** ([`Router`]): arrivals are assigned to an instance of the
//!   entry pool (colocated or prefill) by a pluggable policy; migrated
//!   requests are assigned to a decode instance at handoff time.
//! - **Disaggregation**: prefill-pool instances serve truncated requests
//!   (`output_tokens = 1` — prefill + first token, then the KV leaves);
//!   decode-pool instances receive `prefilled` arrivals that skip prefill
//!   and resume from one generated token. Decode iterations therefore never
//!   carry chunked-prefill interference — the mechanism behind the
//!   colocated-vs-disaggregated TPOT crossover.
//! - **KV handoff** ([`KvTransferModel`]): the migrated prompt's latent-KV
//!   layout bytes ship over the inter-instance link; the exposed share of
//!   the transfer delays both the user-visible first token and the decode
//!   arrival (TetriInfer/DistServe-style accounting).
//!
//! Simulation is two-phase and exactly replayable: entry-pool instances run
//! first (concurrently, over shared caches), handoffs are sorted by
//! completion time, routed, and the decode pool runs second. Every routing
//! decision is a pure function of the arrival/handoff sequence.

use std::collections::HashMap;

use crate::cluster::router::{Router, RoutingPolicy};
use crate::cluster::transfer::KvTransferModel;
use crate::metrics::Percentiles;
use crate::multichip::d2d::WaferSystem;
use crate::multichip::parallelism::KernelCache;
use crate::serve::request::Request;
use crate::serve::sim::{simulate, RequestRecord, ServeConfig, ServeOutcome, StageTimeCache};
use crate::workload::deepseek::DeepSeekConfig;

/// Role split of the fleet.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FleetMode {
    /// Every instance runs prefill + decode (classic continuous batching).
    Colocated { instances: u32 },
    /// Dedicated prefill and decode pools with KV handoff between them.
    Disaggregated { prefill: u32, decode: u32 },
}

impl FleetMode {
    pub fn instances(&self) -> u32 {
        match *self {
            FleetMode::Colocated { instances } => instances,
            FleetMode::Disaggregated { prefill, decode } => prefill + decode,
        }
    }

    pub fn label(&self) -> String {
        match *self {
            FleetMode::Colocated { instances } => format!("colocated-{instances}"),
            FleetMode::Disaggregated { prefill, decode } => format!("disagg-{prefill}p{decode}d"),
        }
    }

    fn validate(&self) {
        match *self {
            FleetMode::Colocated { instances } => assert!(instances >= 1, "empty fleet"),
            FleetMode::Disaggregated { prefill, decode } => {
                assert!(prefill >= 1 && decode >= 1, "both pools need at least one instance")
            }
        }
    }
}

/// Fleet configuration: per-instance serving config plus the cluster-only
/// knobs (mode, routing policies, transfer model, router drain proxy).
#[derive(Debug, Clone, Copy)]
pub struct ClusterConfig {
    pub mode: FleetMode,
    /// Per-instance serving configuration (identical across the fleet).
    pub serve: ServeConfig,
    /// Arrival routing into the entry pool (colocated or prefill).
    pub routing: RoutingPolicy,
    /// Handoff routing into the decode pool (disaggregated only).
    pub decode_routing: RoutingPolicy,
    pub transfer: KvTransferModel,
    /// Fluid drain rate of the router's outstanding-work proxy.
    pub drain_rate: f64,
}

impl ClusterConfig {
    /// Colocated fleet of `instances` wafer instances, prefix-affinity
    /// arrival routing (prefix caches live on the entry pool).
    pub fn colocated(instances: u32, ds: &DeepSeekConfig) -> Self {
        let serve = ServeConfig::default();
        ClusterConfig {
            mode: FleetMode::Colocated { instances },
            serve,
            routing: RoutingPolicy::PrefixAffinity,
            decode_routing: RoutingPolicy::LeastOutstanding,
            transfer: KvTransferModel::inter_node(ds, serve.dtype),
            drain_rate: Router::DEFAULT_DRAIN_RATE,
        }
    }

    /// Disaggregated `prefill`:`decode` pools. Prefix affinity routes the
    /// *prefill* pool (that is where cached prefixes save compute); the
    /// decode pool balances by outstanding work.
    pub fn disaggregated(prefill: u32, decode: u32, ds: &DeepSeekConfig) -> Self {
        ClusterConfig {
            mode: FleetMode::Disaggregated { prefill, decode },
            ..Self::colocated(prefill + decode, ds)
        }
    }
}

/// Fleet-level view of one request's life.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClusterRecord {
    pub id: u64,
    pub arrival_s: f64,
    pub prompt_tokens: u32,
    pub output_tokens: u32,
    /// User-visible first-token time: prefill completion plus (for migrated
    /// requests) the exposed KV-handoff delay.
    pub first_token_s: Option<f64>,
    pub completion_s: Option<f64>,
    /// Entry-pool instance (colocated or prefill), `u32::MAX` if unrouted.
    pub prefill_instance: u32,
    /// Decode-pool instance (== entry instance when colocated).
    pub decode_instance: u32,
    /// Latent-KV bytes shipped at handoff (0 when not migrated).
    pub transfer_bytes: u64,
    /// Exposed handoff delay in seconds (0 when not migrated).
    pub transfer_s: f64,
}

impl ClusterRecord {
    pub fn ttft_ms(&self) -> Option<f64> {
        self.first_token_s.map(|t| (t - self.arrival_s) * 1e3)
    }

    /// Per-token latency after the first token; for migrated requests this
    /// includes decode-pool queueing — the user's actual stream cadence.
    pub fn tpot_ms(&self) -> Option<f64> {
        match (self.first_token_s, self.completion_s) {
            (Some(f), Some(c)) if self.output_tokens > 1 => {
                Some((c - f) * 1e3 / (self.output_tokens - 1) as f64)
            }
            _ => None,
        }
    }
}

/// Per-instance roll-up inside a [`ClusterOutcome`].
#[derive(Debug, Clone, PartialEq)]
pub struct InstanceSummary {
    /// "colocated" | "prefill" | "decode".
    pub role: &'static str,
    /// Requests routed to this instance.
    pub routed: usize,
    pub completed: usize,
    pub rejected: usize,
    /// In-flight + queued at the horizon.
    pub backlog: usize,
    pub tokens_per_s: f64,
    pub peak_kv_occupancy: f64,
    pub prefix_hit_tokens: u64,
    pub preemptions: u64,
}

impl InstanceSummary {
    fn from_outcome(role: &'static str, o: &ServeOutcome) -> Self {
        InstanceSummary {
            role,
            routed: o.offered,
            completed: o.completed,
            rejected: o.rejected,
            backlog: o.in_flight + o.queued,
            tokens_per_s: o.system_tokens_per_s,
            peak_kv_occupancy: o.peak_kv_occupancy,
            prefix_hit_tokens: o.prefix_hit_tokens,
            preemptions: o.preemptions,
        }
    }
}

/// Aggregate outcome of one fleet simulation.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterOutcome {
    pub label: String,
    pub mode: FleetMode,
    pub offered_rps: f64,
    pub horizon_s: f64,
    /// Requests in the input trace.
    pub offered: usize,
    /// Arrivals the entry pool reached inside the horizon.
    pub arrived: usize,
    /// End-to-end completions (decode side for disaggregated fleets).
    pub completed: usize,
    /// Rejections across both pools.
    pub rejected: usize,
    /// Arrived but neither completed nor rejected at the horizon: entry-pool
    /// backlog + KV transfers en route + decode-pool backlog.
    pub in_flight: usize,
    /// Of `in_flight`: handoffs whose KV had not landed by the horizon.
    pub in_transfer: usize,
    pub completed_within_slo: usize,
    pub ttft_ms: Percentiles,
    pub tpot_ms: Percentiles,
    /// Output tokens/s summed over every instance of the fleet.
    pub fleet_tokens_per_s: f64,
    pub goodput_rps: f64,
    /// Requests whose KV migrated prefill → decode.
    pub migrated: usize,
    pub kv_transfer_bytes: u64,
    /// Summed exposed handoff delay across migrations.
    pub kv_transfer_exposed_s: f64,
    /// Exposed transfer time as a share of completed migrated requests'
    /// end-to-end latency (0 for colocated fleets).
    pub transfer_overhead_share: f64,
    pub kv_over_capacity: bool,
    pub preemptions: u64,
    pub instances: Vec<InstanceSummary>,
}

impl ClusterOutcome {
    /// Fleet-wide request conservation: every arrival is exactly one of
    /// completed / rejected / in-flight (pool backlogs + transfers en
    /// route) at the horizon.
    pub fn conserves_requests(&self) -> bool {
        self.arrived == self.completed + self.rejected + self.in_flight
    }
}

/// Split `trace` across the entry pool: per-instance sub-traces (arrival
/// order preserved) plus the chosen instance per request index. `work`
/// prices a request in the pool's own currency — prompt + output tokens
/// for a colocated pool, prompt tokens only for a prefill pool (whose
/// instances never do the decode work).
fn route_arrivals(
    trace: &[Request],
    cfg: &ClusterConfig,
    n: usize,
    work: fn(&Request) -> f64,
) -> (Vec<Vec<Request>>, Vec<usize>) {
    let mut router = Router::new(cfg.routing, cfg.serve.scheduler.prefix_keying, n, cfg.drain_rate);
    let mut subs: Vec<Vec<Request>> = vec![Vec::new(); n];
    let mut chosen = Vec::with_capacity(trace.len());
    for r in trace {
        let i = router.route(r, r.arrival_s, work(r));
        subs[i].push(*r);
        chosen.push(i);
    }
    (subs, chosen)
}

/// Run one serving simulation per sub-trace concurrently over the shared
/// caches (deterministic: cached stage times are pure simulation results,
/// so worker completion order cannot change any value).
#[allow(clippy::too_many_arguments)]
fn run_pool(
    sys: &WaferSystem,
    ds: &DeepSeekConfig,
    subs: &[Vec<Request>],
    cfg: &ServeConfig,
    horizon_s: f64,
    label: &str,
    kernels: &KernelCache,
    stages: &StageTimeCache,
) -> Vec<(ServeOutcome, Vec<RequestRecord>)> {
    std::thread::scope(|scope| {
        let handles: Vec<_> = subs
            .iter()
            .map(|t| {
                let kernels = kernels.clone();
                let stages = stages.clone();
                scope.spawn(move || simulate(sys, ds, t, cfg, horizon_s, label, 0.0, &kernels, &stages))
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("cluster instance worker panicked")).collect()
    })
}

/// Simulate `trace` on the fleet described by `cfg`. Deterministic: two
/// identical invocations return identical outcomes and records.
#[allow(clippy::too_many_arguments)]
pub fn simulate_cluster(
    sys: &WaferSystem,
    ds: &DeepSeekConfig,
    trace: &[Request],
    cfg: &ClusterConfig,
    horizon_s: f64,
    offered_rps: f64,
    kernels: &KernelCache,
    stages: &StageTimeCache,
) -> (ClusterOutcome, Vec<ClusterRecord>) {
    cfg.mode.validate();
    let mut records: Vec<ClusterRecord> = trace
        .iter()
        .map(|r| ClusterRecord {
            id: r.id,
            arrival_s: r.arrival_s,
            prompt_tokens: r.prompt_tokens,
            output_tokens: r.output_tokens,
            first_token_s: None,
            completion_s: None,
            prefill_instance: u32::MAX,
            decode_instance: u32::MAX,
            transfer_bytes: 0,
            transfer_s: 0.0,
        })
        .collect();
    let pos_of: HashMap<u64, usize> = trace.iter().enumerate().map(|(i, r)| (r.id, i)).collect();

    match cfg.mode {
        FleetMode::Colocated { instances } => {
            let (subs, chosen) =
                route_arrivals(trace, cfg, instances as usize, |r| r.prompt_tokens as f64 + r.output_tokens as f64);
            for (idx, &i) in chosen.iter().enumerate() {
                records[idx].prefill_instance = i as u32;
                records[idx].decode_instance = i as u32;
            }
            let results = run_pool(sys, ds, &subs, &cfg.serve, horizon_s, "colocated", kernels, stages);
            for (_, recs) in &results {
                for rec in recs {
                    let p = pos_of[&rec.id];
                    records[p].first_token_s = rec.first_token_s;
                    records[p].completion_s = rec.completion_s;
                }
            }
            let outcome = aggregate(cfg, trace.len(), &records, &results, &[], 0, horizon_s, offered_rps, "colocated");
            (outcome, records)
        }
        FleetMode::Disaggregated { prefill, decode } => {
            // Phase 1: route arrivals into the prefill pool — priced at
            // prompt tokens only, the work this pool actually does — and
            // truncate each request to prefill + first token (the KV then
            // leaves).
            let (mut subs, chosen) = route_arrivals(trace, cfg, prefill as usize, |r| r.prompt_tokens as f64);
            for sub in &mut subs {
                for r in sub.iter_mut() {
                    r.output_tokens = 1;
                }
            }
            for (idx, &i) in chosen.iter().enumerate() {
                records[idx].prefill_instance = i as u32;
            }
            let prefill_results = run_pool(sys, ds, &subs, &cfg.serve, horizon_s, "prefill", kernels, stages);

            // Phase 2: handoffs in completion order. The migrated context is
            // the prompt KV (token #1's cache entry is produced decode-side).
            let mut handoffs: Vec<(f64, u64)> = Vec::new(); // (completion, id)
            for (_, recs) in &prefill_results {
                for rec in recs {
                    if let Some(c) = rec.completion_s {
                        handoffs.push((c, rec.id));
                    }
                }
            }
            handoffs.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
            let mut router = Router::new(cfg.decode_routing, cfg.serve.scheduler.prefix_keying, decode as usize, cfg.drain_rate);
            let mut dsubs: Vec<Vec<Request>> = vec![Vec::new(); decode as usize];
            for &(c, id) in &handoffs {
                let p = pos_of[&id];
                let orig = trace[p];
                let ctx = orig.prompt_tokens as u64;
                let delay = cfg.transfer.exposed_seconds(ctx);
                let i = router.route(&orig, c, orig.output_tokens as f64);
                records[p].decode_instance = i as u32;
                records[p].transfer_bytes = cfg.transfer.bytes_for(ctx);
                records[p].transfer_s = delay;
                // The user sees token #1 once the handoff lands. Sampling
                // rule (mirrors the colocated side): every request whose
                // prefill finished inside the simulated window contributes
                // a TTFT sample — colocated first tokens stamped during the
                // final tick may likewise overshoot the horizon by up to
                // one tick, and here the overshoot bound is one tick plus
                // the exposed transfer delay. A migrated request the decode
                // pool later rejects keeps its sample too: its first token
                // WAS delivered (post-prefill aborts in real disaggregated
                // serving still stream token #1).
                records[p].first_token_s = Some(c + delay);
                dsubs[i].push(Request {
                    arrival_s: c + delay,
                    prefix_id: 0,
                    prefix_tokens: 0,
                    prefix_hash: 0,
                    prefilled: true,
                    ..orig
                });
            }
            // Handoff delays differ per context length, so per-instance
            // decode arrivals must be re-sorted.
            for sub in &mut dsubs {
                sub.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s).then(a.id.cmp(&b.id)));
            }

            // Phase 3: the decode pool (pure decode iterations — no chunked
            // prefill riding the ticks).
            let decode_results = run_pool(sys, ds, &dsubs, &cfg.serve, horizon_s, "decode", kernels, stages);
            for (_, recs) in &decode_results {
                for rec in recs {
                    records[pos_of[&rec.id]].completion_s = rec.completion_s;
                }
            }
            let outcome = aggregate(
                cfg,
                trace.len(),
                &records,
                &prefill_results,
                &decode_results,
                handoffs.len(),
                horizon_s,
                offered_rps,
                "prefill",
            );
            (outcome, records)
        }
    }
}

/// Roll per-instance outcomes and fleet records into a [`ClusterOutcome`].
#[allow(clippy::too_many_arguments)]
fn aggregate(
    cfg: &ClusterConfig,
    offered: usize,
    records: &[ClusterRecord],
    entry: &[(ServeOutcome, Vec<RequestRecord>)],
    decode: &[(ServeOutcome, Vec<RequestRecord>)],
    migrated: usize,
    horizon_s: f64,
    offered_rps: f64,
    entry_role: &'static str,
) -> ClusterOutcome {
    let disagg = !decode.is_empty();
    let arrived: usize = entry.iter().map(|(o, _)| o.arrived).sum();
    let completed: usize = if disagg {
        decode.iter().map(|(o, _)| o.completed).sum()
    } else {
        entry.iter().map(|(o, _)| o.completed).sum()
    };
    let rejected: usize = entry.iter().map(|(o, _)| o.rejected).sum::<usize>()
        + decode.iter().map(|(o, _)| o.rejected).sum::<usize>();
    let entry_backlog: usize = entry.iter().map(|(o, _)| o.in_flight + o.queued).sum();
    let decode_backlog: usize = decode.iter().map(|(o, _)| o.in_flight + o.queued).sum();
    let decode_arrived: usize = decode.iter().map(|(o, _)| o.arrived).sum();
    let in_transfer = if disagg { migrated - decode_arrived } else { 0 };
    let in_flight = entry_backlog + in_transfer + decode_backlog;

    let ttft: Vec<f64> = records.iter().filter_map(ClusterRecord::ttft_ms).collect();
    let tpot: Vec<f64> = records
        .iter()
        .filter(|r| r.completion_s.is_some())
        .filter_map(ClusterRecord::tpot_ms)
        .collect();
    let within_slo = records
        .iter()
        .filter(|r| r.completion_s.is_some())
        .filter(|r| {
            r.ttft_ms().is_some_and(|t| t <= cfg.serve.slo_ttft_ms)
                && r.tpot_ms().map_or(true, |t| t <= cfg.serve.slo_tpot_ms)
        })
        .count();

    let kv_transfer_bytes: u64 = records.iter().map(|r| r.transfer_bytes).sum();
    let kv_transfer_exposed_s: f64 = records.iter().map(|r| r.transfer_s).sum();
    let (mut xfer_s, mut e2e_s) = (0.0f64, 0.0f64);
    for r in records {
        if let Some(c) = r.completion_s {
            if r.transfer_bytes > 0 {
                xfer_s += r.transfer_s;
                e2e_s += c - r.arrival_s;
            }
        }
    }
    let transfer_overhead_share = if e2e_s > 0.0 { xfer_s / e2e_s } else { 0.0 };

    let all = entry.iter().chain(decode.iter());
    let fleet_tokens_per_s: f64 = all.clone().map(|(o, _)| o.system_tokens_per_s).sum();
    let kv_over_capacity = all.clone().any(|(o, _)| o.kv_over_capacity);
    let preemptions: u64 = all.map(|(o, _)| o.preemptions).sum();

    let mut instances: Vec<InstanceSummary> = entry
        .iter()
        .map(|(o, _)| InstanceSummary::from_outcome(entry_role, o))
        .collect();
    instances.extend(decode.iter().map(|(o, _)| InstanceSummary::from_outcome("decode", o)));

    ClusterOutcome {
        label: cfg.mode.label(),
        mode: cfg.mode,
        offered_rps,
        horizon_s,
        offered,
        arrived,
        completed,
        rejected,
        in_flight,
        in_transfer,
        completed_within_slo: within_slo,
        ttft_ms: Percentiles::from_values(&ttft),
        tpot_ms: Percentiles::from_values(&tpot),
        fleet_tokens_per_s,
        goodput_rps: if horizon_s > 0.0 { within_slo as f64 / horizon_s } else { 0.0 },
        migrated,
        kv_transfer_bytes,
        kv_transfer_exposed_s,
        transfer_overhead_share,
        kv_over_capacity,
        preemptions,
        instances,
    }
}

/// First offered load at which the disaggregated fleet's p99 TPOT drops
/// below the colocated fleet's — the crossover the `cluster_pools`
/// experiment reports. Curves must be paired by offered rate.
pub fn tpot_crossover(colocated: &[ClusterOutcome], disagg: &[ClusterOutcome]) -> Option<f64> {
    colocated
        .iter()
        .zip(disagg.iter())
        .find(|(c, d)| {
            c.completed > 0 && d.completed > 0 && d.tpot_ms.p99 < c.tpot_ms.p99
        })
        .map(|(c, _)| c.offered_rps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::request::{generate_trace, TraceConfig, TrafficPattern};

    fn trace(rate: f64, horizon: f64, seed: u64) -> Vec<Request> {
        generate_trace(&TraceConfig::new(seed, TrafficPattern::Poisson, rate, horizon))
    }

    #[test]
    fn colocated_single_instance_matches_serve_sim() {
        // A colocated fleet of 1 is exactly the serving simulator: every
        // first-token / completion time must agree with a direct call.
        let sys = WaferSystem::paper();
        let ds = DeepSeekConfig::v3_671b();
        let ccfg = ClusterConfig::colocated(1, &ds);
        let t = trace(60.0, 4.0, 3);
        let kernels = KernelCache::new();
        let stages = StageTimeCache::new();
        let (co, crecs) = simulate_cluster(&sys, &ds, &t, &ccfg, 4.0, 60.0, &kernels, &stages);
        let (so, srecs) = simulate(&sys, &ds, &t, &ccfg.serve, 4.0, "p", 60.0, &kernels, &stages);
        assert_eq!(co.completed, so.completed);
        assert_eq!(co.arrived, so.arrived);
        assert!(co.conserves_requests());
        assert_eq!(co.migrated, 0);
        assert_eq!(co.kv_transfer_bytes, 0);
        for (c, s) in crecs.iter().zip(&srecs) {
            assert_eq!(c.id, s.id);
            assert_eq!(c.first_token_s, s.first_token_s);
            assert_eq!(c.completion_s, s.completion_s);
            assert_eq!(c.prefill_instance, 0);
        }
        assert_eq!(co.tpot_ms, so.tpot_ms);
    }

    #[test]
    fn disaggregated_smoke_conserves_and_bills_transfers() {
        let sys = WaferSystem::paper();
        let ds = DeepSeekConfig::v3_671b();
        let ccfg = ClusterConfig::disaggregated(1, 1, &ds);
        let t = trace(80.0, 4.0, 7);
        let kernels = KernelCache::new();
        let stages = StageTimeCache::new();
        let (o, recs) = simulate_cluster(&sys, &ds, &t, &ccfg, 4.0, 80.0, &kernels, &stages);
        assert!(o.conserves_requests(), "{o:?}");
        assert!(o.completed > 0, "light load must complete requests end-to-end");
        assert!(o.migrated >= o.completed);
        assert!(!o.kv_over_capacity);
        let layout = KvTransferModel::layout_bytes_per_token(&ds, ccfg.serve.dtype);
        for r in &recs {
            if r.transfer_bytes > 0 {
                // The transfer-bytes invariant: exactly the latent-KV layout
                // bytes of the migrated prompt context.
                assert_eq!(r.transfer_bytes, r.prompt_tokens as u64 * layout);
                assert!(r.transfer_s > 0.0);
                assert_eq!(r.decode_instance, 0);
            }
            if let (Some(f), Some(c)) = (r.first_token_s, r.completion_s) {
                assert!(f >= r.arrival_s && c >= f, "causality violated: {r:?}");
            }
        }
        assert_eq!(o.kv_transfer_bytes, recs.iter().map(|r| r.transfer_bytes).sum::<u64>());
        assert!(o.transfer_overhead_share > 0.0 && o.transfer_overhead_share < 0.5);
    }

    #[test]
    fn cluster_simulation_is_deterministic() {
        let sys = WaferSystem::paper();
        let ds = DeepSeekConfig::v3_671b();
        let t = trace(100.0, 3.0, 11);
        let run = |mode: FleetMode| {
            let ccfg = ClusterConfig { mode, ..ClusterConfig::colocated(2, &ds) };
            simulate_cluster(
                &sys,
                &ds,
                &t,
                &ccfg,
                3.0,
                100.0,
                &KernelCache::new(),
                &StageTimeCache::new(),
            )
        };
        for mode in [FleetMode::Colocated { instances: 2 }, FleetMode::Disaggregated { prefill: 1, decode: 1 }] {
            let (a, ra) = run(mode);
            let (b, rb) = run(mode);
            assert_eq!(a, b, "{mode:?} must replay identically");
            assert_eq!(ra, rb);
        }
    }

    #[test]
    fn routing_policies_spread_load() {
        let sys = WaferSystem::paper();
        let ds = DeepSeekConfig::v3_671b();
        let t = trace(120.0, 3.0, 13);
        let kernels = KernelCache::new();
        let stages = StageTimeCache::new();
        for policy in [RoutingPolicy::RoundRobin, RoutingPolicy::LeastOutstanding, RoutingPolicy::PrefixAffinity] {
            let ccfg = ClusterConfig { routing: policy, ..ClusterConfig::colocated(3, &ds) };
            let (o, _) = simulate_cluster(&sys, &ds, &t, &ccfg, 3.0, 120.0, &kernels, &stages);
            assert!(o.conserves_requests(), "{policy:?}");
            assert_eq!(o.instances.len(), 3);
            let routed: Vec<usize> = o.instances.iter().map(|i| i.routed).collect();
            let total: usize = routed.iter().sum();
            assert_eq!(total, t.len());
            // No instance may be starved by a balancing policy.
            assert!(routed.iter().all(|&r| r > total / 10), "{policy:?}: skewed {routed:?}");
        }
    }

    #[test]
    fn tpot_crossover_detection() {
        let sys = WaferSystem::paper();
        let ds = DeepSeekConfig::v3_671b();
        let ccfg = ClusterConfig::colocated(1, &ds);
        let t = trace(10.0, 1.0, 5);
        let (base, _) = simulate_cluster(
            &sys,
            &ds,
            &t,
            &ccfg,
            1.0,
            10.0,
            &KernelCache::new(),
            &StageTimeCache::new(),
        );
        let mk = |rate: f64, p99: f64| {
            let mut o = base.clone();
            o.offered_rps = rate;
            o.completed = 5;
            o.tpot_ms.p99 = p99;
            o
        };
        let colo = vec![mk(100.0, 10.0), mk(400.0, 40.0), mk(1600.0, 90.0)];
        let disagg = vec![mk(100.0, 12.0), mk(400.0, 38.0), mk(1600.0, 45.0)];
        assert_eq!(tpot_crossover(&colo, &disagg), Some(400.0));
        assert_eq!(tpot_crossover(&colo[..1], &disagg[..1]), None);
    }
}
