//! Fleet-level simulation: N wafer instances advanced by a sharded
//! conservative-lookahead discrete-event engine, with live routing, pool
//! roles and congested KV handoff — bit-identical at every shard count.
//!
//! The fleet simulator composes the *steppable* request-level serving
//! engine (`serve::sim::ServeEngine`) — every instance is one engine
//! running the same iteration-level continuous-batching simulation against
//! the shared `StageTimeCache`/`KernelCache`, so all latencies stay
//! grounded in the FlatAttention dataflow simulations. The cluster layer
//! adds exactly the parts one instance cannot see (routing, disaggregated
//! pools, KV handoff over a contended [`Fabric`]), and advances the
//! whole fleet with a classic conservative parallel-DES scheme:
//!
//! # Epochs and the lookahead window
//!
//! The only way instances affect each other is through *cluster events* —
//! routed trace arrivals and prefill→decode KV handoffs. A handoff that
//! becomes ready at `t` lands on its decode instance no earlier than
//! `t + L`, where `L = KvTransferModel::lookahead_s()` (the link's base
//! latency — a floor under every exposed handoff delay). So simulated time
//! is cut into epochs of length `L`: within one epoch no instance can
//! observe another's in-flight events, which makes it safe for every
//! shard to advance its engines through the epoch *independently*
//! (`ServeEngine::step_until` — epoch-bounded, never crosses the horizon).
//! Cluster events are exchanged at the epoch **barriers**:
//!
//! - trace arrivals landing inside the upcoming epoch are routed and
//!   injected before the epoch runs;
//! - handoffs that became ready *before* the epoch start are serialized on
//!   the shared link (in global (ready, id) order — ticks never produce a
//!   handoff earlier than their own epoch, so the order is total), routed,
//!   and injected as future decode arrivals (≥ one epoch away, by the
//!   lookahead bound);
//! - empty stretches are skipped: the next barrier jumps straight to the
//!   epoch of the globally earliest pending event.
//!
//! # The event-ordering comparator and bit-identity
//!
//! One shared comparator ([`event_order`]) fixes the global order: time
//! first (`f64::total_cmp`), then kind (arrival < handoff < engine tick),
//! then the stable tie-breaks (trace order for arrivals, request id for
//! handoffs, the engine's own FIFO `seq` for same-time injections). It is
//! applied identically at the barrier merge and inside every engine, and
//! none of the barrier logic ever reads *which worker* produced an event:
//! injections are emitted per engine in barrier order, handoffs drain
//! from one heap with a strict total order, live loads are written by
//! engine id, and the merged obs export orders by pid. A run at ANY shard
//! count — including `shards = 1`, which executes the very same barrier
//! code inline without threads — is therefore bit-identical: outcomes,
//! records, and obs exports (pinned by `integration_cluster`).
//!
//! # The epoch-start snapshot contract (live routing)
//!
//! Live policies read instance state at routing decisions. Mid-epoch
//! state is a race under sharding, so the contract (see
//! [`router`](crate::cluster::router)) is: every routed event resolves
//! against the pool snapshot frozen at the start of the epoch it lands
//! in — arrivals against the entry-pool state at their epoch's barrier,
//! handoffs against the decode-pool state at the start of the epoch their
//! prefill completed in. The serial path applies the identical rule, so
//! snapshots are at most one lookahead (≤ 1 ms inter-node) older than the
//! decision time — far fresher than an engine iteration (tens of ms).
//!
//! # Fault events (kill / drain / restart)
//!
//! Instance faults ([`FaultPlan`]) are cluster events too, and they obey
//! the same discipline: a fault scheduled at `t` applies at the first
//! epoch barrier at or after `t` — the only instants at which cluster
//! state may change. A **drain** masks the instance on its router
//! (prefix-affinity families re-home on their next arrival) and lets
//! resident work finish. A **kill** additionally runs a *zero-width
//! phase* at the barrier: every engine steps to the barrier time (all
//! transports execute the identical call sequence, so shard bit-identity
//! is untouched), then the victims abort ([`ServeEngine::kill`]) and hand
//! their queued / in-flight / un-arrived work back. Extracted work
//! re-enters the ENTRY router as fresh arrivals no earlier than the
//! barrier (re-prefill from scratch; the resident latent KV died with the
//! HBM, and a re-migration ships it over the [`Fabric`] again) —
//! exactly like a handoff, a requeue can never inject into the *running*
//! epoch, so the conservative-lookahead bound survives kills. An optional
//! restart unmasks the instance after a cold-start delay; a killed
//! instance first reloads its weights over the shared link (billed, so a
//! restart congests concurrent handoffs), a drained one kept its weights.
//! Extracted requests whose requeue time falls at/after the horizon are
//! counted `lost`; the conservation identity becomes
//! `arrived == completed + rejected + in_flight + extracted_from_decode`
//! (decode-side extractions stay counted under `migrated`, hence the
//! explicit term — it is 0 in any fault-free run).
//!
//! Shared multi-model pools ([`simulate_shared_pool`]) interleave BOTH
//! models' engines on one chip clock per instance: a tick occupies the
//! chip exclusively, so a co-resident model's iterations genuinely stretch
//! the other's cadence instead of being statically billed. (This path is
//! serial: chip-exclusive serialization has no lookahead to exploit.)
//!
//! Everything is deterministic: two identical invocations return identical
//! outcomes and records, at any `ClusterConfig::shards` and any
//! `--threads` budget.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::cluster::fabric::{Fabric, TopologySpec};
use crate::cluster::router::{LiveLoad, Router, RoutingPolicy};
use crate::cluster::transfer::KvTransferModel;
use crate::metrics::Percentiles;
use crate::multichip::d2d::WaferSystem;
use crate::multichip::parallelism::KernelCache;
use crate::obs::attrib::{assemble_waterfall, ReqSlot};
use crate::obs::{AttribExport, DesProfile, EngineObs, ObsBundle, ObsConfig, SeriesRow};
use crate::serve::request::Request;
use crate::serve::sim::{RequestRecord, ServeConfig, ServeEngine, ServeOutcome, StageTimeCache, Step};
use crate::workload::deepseek::DeepSeekConfig;

/// Role split of the fleet.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FleetMode {
    /// Every instance runs prefill + decode (classic continuous batching).
    Colocated { instances: u32 },
    /// Dedicated prefill and decode pools with KV handoff between them.
    Disaggregated { prefill: u32, decode: u32 },
}

impl FleetMode {
    pub fn instances(&self) -> u32 {
        match *self {
            FleetMode::Colocated { instances } => instances,
            FleetMode::Disaggregated { prefill, decode } => prefill + decode,
        }
    }

    pub fn label(&self) -> String {
        match *self {
            FleetMode::Colocated { instances } => format!("colocated-{instances}"),
            FleetMode::Disaggregated { prefill, decode } => format!("disagg-{prefill}p{decode}d"),
        }
    }

    fn validate(&self) {
        match *self {
            FleetMode::Colocated { instances } => assert!(instances >= 1, "empty fleet"),
            FleetMode::Disaggregated { prefill, decode } => {
                assert!(prefill >= 1 && decode >= 1, "both pools need at least one instance")
            }
        }
    }
}

/// Fleet configuration: per-instance serving config plus the cluster-only
/// knobs (mode, routing policies, transfer model, router drain proxy).
#[derive(Debug, Clone, Copy)]
pub struct ClusterConfig {
    pub mode: FleetMode,
    /// Per-instance serving configuration (identical across the fleet).
    pub serve: ServeConfig,
    /// Arrival routing into the entry pool (colocated or prefill).
    pub routing: RoutingPolicy,
    /// Handoff routing into the decode pool (disaggregated only).
    pub decode_routing: RoutingPolicy,
    pub transfer: KvTransferModel,
    /// Inter-instance fabric the KV handoffs (and restart weight reloads /
    /// requeue re-ships) are routed over. [`TopologySpec::Degenerate`] is
    /// the classic pooled [`SharedLink`](crate::cluster::transfer::SharedLink)
    /// — field-identical to the pre-fabric fleet.
    pub topology: TopologySpec,
    /// Fluid drain rate of the router's outstanding-work proxy.
    pub drain_rate: f64,
    /// Shards the fleet's engines are partitioned into (`gid % shards`).
    /// 1 (the default) runs the barrier loop inline without threads; any
    /// value yields bit-identical results — shards only control how much
    /// of the epoch work can run concurrently (capped by the
    /// `--threads`/`FLATATTENTION_THREADS` worker budget).
    pub shards: u32,
}

impl ClusterConfig {
    /// Colocated fleet of `instances` wafer instances, prefix-affinity
    /// arrival routing (prefix caches live on the entry pool).
    pub fn colocated(instances: u32, ds: &DeepSeekConfig) -> Self {
        let serve = ServeConfig::default();
        ClusterConfig {
            mode: FleetMode::Colocated { instances },
            serve,
            routing: RoutingPolicy::PrefixAffinity,
            decode_routing: RoutingPolicy::LeastOutstanding,
            transfer: KvTransferModel::inter_node(ds, serve.dtype),
            topology: TopologySpec::Degenerate,
            drain_rate: Router::DEFAULT_DRAIN_RATE,
            shards: 1,
        }
    }

    /// Disaggregated `prefill`:`decode` pools. Prefix affinity routes the
    /// *prefill* pool (that is where cached prefixes save compute); the
    /// decode pool balances by outstanding work.
    pub fn disaggregated(prefill: u32, decode: u32, ds: &DeepSeekConfig) -> Self {
        ClusterConfig {
            mode: FleetMode::Disaggregated { prefill, decode },
            ..Self::colocated(prefill + decode, ds)
        }
    }
}

/// What a fault does to an instance.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum FaultKind {
    /// Abort at the epoch barrier: resident KV and decode progress die
    /// with the HBM; queued, in-flight and not-yet-arrived work requeues
    /// through the entry router as fresh arrivals.
    Kill,
    /// Graceful removal: the router stops sending new work (prefix
    /// families re-home), resident work runs to completion.
    Drain,
}

/// One scheduled fault against one instance, addressed by global engine
/// id: `0..n_entry` is the entry pool (colocated or prefill), then the
/// decode pool.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    /// When the fault fires. Faults snap to the next epoch barrier at or
    /// after this time — the only instants at which cluster state may
    /// change under the conservative-lookahead engine.
    pub at_s: f64,
    /// Global engine id the fault targets.
    pub instance: usize,
    pub kind: FaultKind,
    /// Rejoin the pool this long after the fault applies. A killed
    /// instance additionally reloads its weights over the shared KV link
    /// first (billed — a restart congests concurrent handoffs); a drained
    /// instance just unmasks (its weights never left).
    pub restart_after_s: Option<f64>,
}

/// A deterministic schedule of instance faults for one fleet run. The
/// empty plan is exactly the no-fault simulator, bit for bit.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// The empty plan.
    pub fn none() -> Self {
        Self::default()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Add a kill of `instance` at `at_s` (no restart).
    pub fn kill(mut self, instance: usize, at_s: f64) -> Self {
        self.events.push(FaultEvent { at_s, instance, kind: FaultKind::Kill, restart_after_s: None });
        self
    }

    /// Add a drain of `instance` at `at_s` (no restart).
    pub fn drain(mut self, instance: usize, at_s: f64) -> Self {
        self.events.push(FaultEvent { at_s, instance, kind: FaultKind::Drain, restart_after_s: None });
        self
    }

    /// Give the most recently added event a restart `delay_s` after it
    /// applies.
    pub fn with_restart(mut self, delay_s: f64) -> Self {
        if let Some(e) = self.events.last_mut() {
            e.restart_after_s = Some(delay_s);
        }
        self
    }

    /// `kills` seeded-random kill events across `instances` instances,
    /// uniform over the horizon — the same SplitMix64 discipline as trace
    /// generation, so a (seed, instances, horizon, kills) tuple names one
    /// exact failure schedule forever.
    pub fn seeded_random(seed: u64, instances: usize, horizon_s: f64, kills: usize) -> Self {
        let mut rng = crate::util::SplitMix64::new(seed ^ 0xFA17_0FA1_7000_0007);
        let mut plan = FaultPlan::none();
        for _ in 0..kills {
            let instance = rng.next_range(instances.max(1) as u64) as usize;
            let at_s = rng.next_f64() * horizon_s;
            plan = plan.kill(instance, at_s);
        }
        plan
    }

    /// Events in application order — (time, instance, kind), total and
    /// deterministic — with out-of-window events dropped and targets
    /// validated against the fleet size.
    fn sorted(&self, n_engines: usize, horizon_s: f64) -> Vec<FaultEvent> {
        let mut ev: Vec<FaultEvent> = self.events.iter().copied().filter(|e| e.at_s < horizon_s).collect();
        for e in &ev {
            assert!(
                e.instance < n_engines,
                "fault targets instance {} of a {n_engines}-engine fleet",
                e.instance
            );
            assert!(e.at_s >= 0.0, "fault time must be non-negative");
        }
        ev.sort_by(|a, b| {
            a.at_s.total_cmp(&b.at_s).then(a.instance.cmp(&b.instance)).then(a.kind.cmp(&b.kind))
        });
        ev
    }
}

/// Fleet-level view of one request's life.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClusterRecord {
    pub id: u64,
    pub arrival_s: f64,
    pub prompt_tokens: u32,
    pub output_tokens: u32,
    /// User-visible first-token time: prefill completion plus (for migrated
    /// requests) the exposed KV-handoff delay including any link-queue wait.
    pub first_token_s: Option<f64>,
    pub completion_s: Option<f64>,
    /// Entry-pool instance (colocated or prefill), `u32::MAX` if unrouted;
    /// a requeued request reports its LAST home.
    pub prefill_instance: u32,
    /// Decode-pool instance (== entry instance when colocated).
    pub decode_instance: u32,
    /// Latent-KV bytes shipped at handoff (0 when not migrated); a
    /// requeued request accumulates every re-migration's bytes.
    pub transfer_bytes: u64,
    /// Exposed handoff delay in seconds, link-queue wait included
    /// (0 when not migrated); accumulates across re-migrations.
    pub transfer_s: f64,
    /// Σ bytes × fabric hops over this request's migrations — the total
    /// per-edge occupancy it billed. On the degenerate 1-switch topology
    /// this equals `transfer_bytes`; the conservation test divides by link
    /// bandwidth and matches the fleet's per-edge busy ledgers.
    pub transfer_hop_bytes: u64,
    /// Times this request was extracted from a killed instance and
    /// re-routed as a fresh arrival (0 in any fault-free run).
    pub requeues: u32,
}

impl ClusterRecord {
    pub fn ttft_ms(&self) -> Option<f64> {
        self.first_token_s.map(|t| (t - self.arrival_s) * 1e3)
    }

    /// Per-token latency after the first token; for migrated requests this
    /// includes decode-pool queueing — the user's actual stream cadence.
    pub fn tpot_ms(&self) -> Option<f64> {
        match (self.first_token_s, self.completion_s) {
            (Some(f), Some(c)) if self.output_tokens > 1 => {
                Some((c - f) * 1e3 / (self.output_tokens - 1) as f64)
            }
            _ => None,
        }
    }
}

/// Per-instance roll-up inside a [`ClusterOutcome`].
#[derive(Debug, Clone, PartialEq)]
pub struct InstanceSummary {
    /// "colocated" | "prefill" | "decode" | "shared".
    pub role: &'static str,
    /// Requests routed to this instance.
    pub routed: usize,
    pub completed: usize,
    pub rejected: usize,
    /// In-flight + queued at the horizon.
    pub backlog: usize,
    pub tokens_per_s: f64,
    pub peak_kv_occupancy: f64,
    pub prefix_hit_tokens: u64,
    pub preemptions: u64,
}

impl InstanceSummary {
    fn from_outcome(role: &'static str, o: &ServeOutcome) -> Self {
        InstanceSummary {
            role,
            routed: o.offered,
            completed: o.completed,
            rejected: o.rejected,
            backlog: o.in_flight + o.queued,
            tokens_per_s: o.system_tokens_per_s,
            peak_kv_occupancy: o.peak_kv_occupancy,
            prefix_hit_tokens: o.prefix_hit_tokens,
            preemptions: o.preemptions,
        }
    }
}

/// Aggregate outcome of one fleet simulation.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterOutcome {
    pub label: String,
    pub mode: FleetMode,
    pub offered_rps: f64,
    pub horizon_s: f64,
    /// Requests in the input trace.
    pub offered: usize,
    /// Arrivals the entry pool reached inside the horizon.
    pub arrived: usize,
    /// End-to-end completions (decode side for disaggregated fleets).
    pub completed: usize,
    /// Rejections across both pools.
    pub rejected: usize,
    /// Arrived but neither completed nor rejected at the horizon: entry-pool
    /// backlog + KV transfers en route + decode-pool backlog.
    pub in_flight: usize,
    /// Of `in_flight`: handoffs whose KV had not landed by the horizon.
    pub in_transfer: usize,
    pub completed_within_slo: usize,
    pub ttft_ms: Percentiles,
    pub tpot_ms: Percentiles,
    /// Output tokens/s summed over every instance of the fleet.
    pub fleet_tokens_per_s: f64,
    pub goodput_rps: f64,
    /// Requests whose KV migrated prefill → decode.
    pub migrated: usize,
    pub kv_transfer_bytes: u64,
    /// Summed exposed handoff delay across migrations (link wait included).
    pub kv_transfer_exposed_s: f64,
    /// Exposed transfer time as a share of completed migrated requests'
    /// end-to-end latency (0 for colocated fleets).
    pub transfer_overhead_share: f64,
    pub kv_over_capacity: bool,
    pub preemptions: u64,
    /// Router telemetry: affinity-overload spill/rebalance events across
    /// the fleet's routers (entry + decode).
    pub router_spills: u64,
    /// Share of the shared KV link's capacity spent serializing transfers
    /// (0 for colocated fleets).
    pub link_busy_frac: f64,
    /// Summed link-queue wait across migrations — the congestion cost the
    /// old overlap-for-free model never billed.
    pub link_wait_s: f64,
    /// Fabric hops traversed by all migrations (equals `migrated` on the
    /// degenerate topology, where every handoff is one switch traversal).
    pub fabric_hops: u64,
    /// Per-edge busy seconds of the fabric ledgers, in edge-construction
    /// order (one entry — the pooled link — on the degenerate topology).
    pub edge_busy_s: Vec<f64>,
    /// Fault events applied within the horizon (kills + drains; restarts
    /// are not counted).
    pub faults: usize,
    /// Extracted requests re-routed into the fleet as fresh arrivals.
    pub requeued: usize,
    /// Extracted requests abandoned because their requeue time fell at or
    /// after the horizon.
    pub lost: usize,
    /// Of `requeued + lost`, the requests pulled out of decode-pool
    /// engines — their original KV landings stay counted under
    /// `migrated`/`arrived`, so the conservation identity carries this
    /// term explicitly (0 in any fault-free run).
    pub extracted_from_decode: usize,
    /// Latent-KV bytes (resident context, landed and in-flight
    /// migrations) destroyed by kills.
    pub kv_lost_bytes: u64,
    /// Shard count the run used (self-describing artifacts; never affects
    /// any other field — bit-identity across shard counts is pinned by
    /// test).
    pub shards: u32,
    pub instances: Vec<InstanceSummary>,
}

impl ClusterOutcome {
    /// Fleet-wide request conservation: every arrival is exactly one of
    /// completed / rejected / in-flight (pool backlogs + transfers en
    /// route) at the horizon, plus the decode-side kill extractions whose
    /// first landing the arrival counters already saw (see the fault
    /// section of the module docs). Reduces to the classic three-term
    /// identity whenever the fault plan is empty.
    pub fn conserves_requests(&self) -> bool {
        self.arrived == self.completed + self.rejected + self.in_flight + self.extracted_from_decode
    }
}

/// Router/fabric/fault telemetry carried into [`ClusterOutcome`].
#[derive(Debug, Clone, Default)]
struct FleetTelemetry {
    router_spills: u64,
    link_busy_frac: f64,
    link_wait_s: f64,
    fabric_hops: u64,
    edge_busy_s: Vec<f64>,
    faults: usize,
    requeued: usize,
    lost: usize,
    extracted_from_decode: usize,
    kv_lost_bytes: u64,
}

/// THE global event-ordering contract, factored into one comparator so
/// shards and merge points cannot drift apart.
///
/// Fleet events order by time first (`f64::total_cmp` — a total order, so
/// NaN can never poison a heap), then by kind: **arrival < handoff <
/// engine tick**. The remaining tie-breaks are stable and owner-local:
/// arrivals follow trace order, handoffs the request id
/// ([`HandoffEv`]'s `Ord`), and same-time injections into one engine its
/// FIFO `seq` counter (`serve::sim::PendingArrival`). Engine ticks no
/// longer need a global arbiter — within an epoch no tick can observe
/// another instance, so each engine's local clock is the only tick order
/// that exists; cross-engine "smallest local clock first" of the old
/// serial loop is subsumed by the epoch cut.
pub(crate) mod event_order {
    /// Routed trace arrival (entry pool).
    pub const ARRIVAL: u8 = 0;
    /// KV handoff ready (link serialization + decode routing).
    pub const HANDOFF: u8 = 1;

    /// Compare two (time, kind) event keys. Applied identically at the
    /// barrier merge of due arrivals and handoffs and inside the handoff
    /// heap; an equal result defers to the per-kind stable tie-break.
    pub fn cmp(a: (f64, u8), b: (f64, u8)) -> std::cmp::Ordering {
        a.0.total_cmp(&b.0).then(a.1.cmp(&b.1))
    }
}

/// A KV handoff waiting to be routed and transferred. Min-heap order:
/// ([`event_order`] on ready time, then id) — a strict total order (ids
/// are unique), so the pop sequence is independent of push order and
/// therefore of how engines are grouped onto workers.
#[derive(Debug, Clone, Copy)]
struct HandoffEv {
    ready_s: f64,
    id: u64,
    pos: usize,
}

impl PartialEq for HandoffEv {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}
impl Eq for HandoffEv {}
impl PartialOrd for HandoffEv {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HandoffEv {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        event_order::cmp((self.ready_s, event_order::HANDOFF), (other.ready_s, event_order::HANDOFF))
            .then(self.id.cmp(&other.id))
    }
}

/// Index of the epoch containing time `t` under epoch length `lookahead`.
/// Saturating at the ends; barrier processing compares against the epoch
/// *bounds* (`k * lookahead`), so a float edge here can only cost an empty
/// round, never correctness.
fn epoch_index(t: f64, lookahead: f64) -> u64 {
    (t / lookahead).floor().max(0.0) as u64
}

/// Lower `*m` to `t` (treating `None` as +inf) — the one reduction the
/// epoch ladder and reply folding both use.
fn merge_min(t: f64, m: &mut Option<f64>) {
    let lower = match *m {
        None => true,
        Some(cur) => t < cur,
    };
    if lower {
        *m = Some(t);
    }
}

/// One worker's marching orders for one epoch phase.
struct PhaseCmd {
    /// Exclusive end of the epoch window (`step_until` bound).
    end_s: f64,
    /// Barrier-emitted injections, in global barrier order:
    /// (slot in this worker's engine list, request).
    injections: Vec<(usize, Request)>,
    /// Engine slots to abort AFTER the window runs (zero-width fault
    /// phases only — `end_s` is then the barrier itself, so the victim
    /// has seen every event before its death instant). Empty otherwise.
    kills: Vec<usize>,
}

/// What a worker reports back from one epoch phase. Everything is keyed by
/// engine id (`gid`), never by worker, so folding replies in worker order
/// is order-insensitive — the engine→worker assignment cannot leak into
/// results.
struct PhaseReply {
    /// Disaggregated entry-engine completions: (ready time, gid,
    /// engine-local record index) — future handoffs.
    completions: Vec<(f64, usize, usize)>,
    /// Post-phase live loads of the engines whose role the routers read.
    loads: Vec<(usize, LiveLoad)>,
    /// Earliest next event across this worker's engines (None: all idle).
    next_event_s: Option<f64>,
    /// Work extracted from engines this phase killed, keyed by gid (the
    /// driver re-sorts globally before requeueing).
    killed: Vec<(usize, KillReport)>,
}

/// Extracted work waiting to re-enter the fleet. Min-heap order:
/// (requeue time under the shared comparator, then extraction sequence) —
/// strict, total, and independent of the worker partition because kill
/// reports are ingested in global gid order.
#[derive(Debug, Clone, Copy)]
struct RequeueEv {
    at_s: f64,
    seq: u64,
    pos: usize,
}

impl PartialEq for RequeueEv {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}
impl Eq for RequeueEv {}
impl PartialOrd for RequeueEv {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for RequeueEv {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.at_s.total_cmp(&other.at_s).then(self.seq.cmp(&other.seq))
    }
}

/// Run one epoch phase over one worker's engines: apply the barrier's
/// injections (already in global order; per-engine order is what the
/// engines' FIFO `seq` tie-break sees), advance every engine through the
/// window, and collect the cross-shard outputs. The ONE code path both the
/// inline (`shards = 1` / single worker) and threaded transports execute —
/// bit-identity across shard counts is by construction, not by parallel
/// reimplementation.
fn run_worker_phase(
    engines: &mut [(usize, &mut ServeEngine)],
    n_entry: usize,
    disagg: bool,
    want_entry_loads: bool,
    want_dec_loads: bool,
    cmd: PhaseCmd,
) -> PhaseReply {
    for (slot, r) in cmd.injections {
        engines[slot].1.inject(r);
    }
    let mut completions = Vec::new();
    for (gid, e) in engines.iter_mut() {
        let done = e.step_until(cmd.end_s);
        if disagg && *gid < n_entry {
            completions.extend(done.into_iter().map(|(t, rec)| (t, *gid, rec)));
        }
    }
    // Barrier kills execute after the window: the victim has processed
    // every event strictly before the barrier, then its remaining work is
    // extracted. Loads and next-event times are read afterwards, so a
    // fresh corpse reports empty/idle like any drained shell.
    let mut killed = Vec::new();
    for &slot in &cmd.kills {
        let (gid, e) = &mut engines[slot];
        killed.push((*gid, e.kill()));
    }
    let mut loads = Vec::new();
    let mut next: Option<f64> = None;
    for (gid, e) in engines.iter_mut() {
        if let Some(t) = e.next_event_s() {
            next = Some(match next {
                Some(n) if n <= t => n,
                _ => t,
            });
        }
        let want = if *gid < n_entry { want_entry_loads } else { want_dec_loads };
        if want {
            loads.push((*gid, LiveLoad::of(&e.snapshot())));
        }
    }
    PhaseReply { completions, loads, next_event_s: next, killed }
}

/// The barrier-side state of one fleet run: everything the epoch loop
/// mutates *between* phases (routers, link, handoff heap, records, obs
/// fleet lane, epoch-start load snapshots). Engines live on the workers
/// during the loop; the driver only ever sees them through [`PhaseReply`]s.
struct EpochDriver<'a> {
    trace: &'a [Request],
    cfg: &'a ClusterConfig,
    horizon_s: f64,
    disagg: bool,
    n_entry: usize,
    /// Epoch length: [`KvTransferModel::lookahead_s`], floored to a tiny
    /// positive value so a degenerate zero-latency link cannot stall the
    /// epoch ladder.
    lookahead: f64,
    /// Engine id → (worker, slot in the worker's engine list).
    whereis: Vec<(usize, usize)>,
    records: Vec<ClusterRecord>,
    entry_pos: Vec<Vec<usize>>,
    dec_pos: Vec<Vec<usize>>,
    router: Router,
    drouter: Router,
    fabric: Fabric,
    /// Fabric hops traversed by every billed transfer so far.
    fabric_hops: u64,
    fleet_obs: Option<EngineObs>,
    handoffs: BinaryHeap<Reverse<HandoffEv>>,
    next_arrival: usize,
    migrated: usize,
    /// Entry-pool loads at the CURRENT barrier (state with every event
    /// before the upcoming epoch committed) — the epoch-start snapshot for
    /// arrivals landing in the upcoming epoch.
    entry_loads: Vec<LiveLoad>,
    /// Decode-pool loads at the current barrier / at the previous barrier.
    /// A handoff ready at `t < prev_end` landed inside the *previous*
    /// phase's window, so its epoch-start snapshot is the previous
    /// barrier's state (`prev_dec_loads`); later handoffs resolve against
    /// the current state.
    dec_loads: Vec<LiveLoad>,
    prev_dec_loads: Vec<LiveLoad>,
    prev_end: f64,
    /// Earliest engine event reported by the last phase's replies.
    engines_next: Option<f64>,
    /// Fault machinery: the plan's events in application order and a
    /// cursor into them.
    faults: Vec<FaultEvent>,
    next_fault: usize,
    /// Scheduled rejoins, kept sorted by (time, gid).
    restarts: Vec<(f64, usize)>,
    /// Extracted work waiting to re-enter the entry router.
    requeue: BinaryHeap<Reverse<RequeueEv>>,
    requeue_seq: u64,
    /// Weight bytes one instance reloads over the link on a cold start.
    restart_weight_bytes: u64,
    requeued: usize,
    lost: usize,
    extracted_from_decode: usize,
    kv_lost_bytes: u64,
    faults_applied: usize,
    /// Instances currently masked (killed or draining, not yet rejoined) —
    /// drives the fleet lane's `instances_up` gauge.
    down: usize,
    /// Epoch-loop iterations (DES self-profile; wall-clock notes only).
    epochs: u64,
}

impl EpochDriver<'_> {
    /// The epoch loop. `exec` runs one phase: it hands each worker its
    /// injection list and the window end, and returns the replies (any
    /// order-insensitive transport — inline calls or channels to
    /// `std::thread` workers — produces identical results; see
    /// [`PhaseReply`]).
    fn run<F>(&mut self, workers: usize, exec: &mut F)
    where
        F: FnMut(f64, Vec<Vec<(usize, Request)>>, Vec<Vec<usize>>) -> Vec<PhaseReply>,
    {
        let mut next_k: u64 = 0;
        loop {
            // The globally earliest pending event decides the next epoch;
            // empty stretches are skipped in one jump. `next_k` forces
            // strict progress: when the only due event is a handoff inside
            // the current epoch (its barrier cutoff is the epoch START),
            // the bump costs one pass and the following barrier admits it.
            // Faults and restarts work the same way — merging their times
            // here guarantees a barrier with `t_start >= at_s` exists even
            // when every queue is otherwise empty.
            let mut t_min: Option<f64> = None;
            if let Some(r) = self.trace.get(self.next_arrival) {
                merge_min(r.arrival_s, &mut t_min);
            }
            if let Some(&Reverse(q)) = self.requeue.peek() {
                merge_min(q.at_s, &mut t_min);
            }
            if let Some(&Reverse(h)) = self.handoffs.peek() {
                merge_min(h.ready_s, &mut t_min);
            }
            if let Some(t) = self.engines_next {
                merge_min(t, &mut t_min);
            }
            if let Some(e) = self.faults.get(self.next_fault) {
                merge_min(e.at_s, &mut t_min);
            }
            if let Some(&(t, _)) = self.restarts.first() {
                merge_min(t, &mut t_min);
            }
            let Some(t_min) = t_min else { break };
            self.epochs += 1;
            let k = epoch_index(t_min, self.lookahead).max(next_k);
            next_k = k + 1;
            let t_start = k as f64 * self.lookahead;
            let t_end = (k + 1) as f64 * self.lookahead;

            // Cluster faults change state only at barriers. Due restarts
            // rejoin first, then due faults mask their victims (so this
            // barrier's routing already avoids them); kills run as a
            // zero-width phase AT the barrier — every engine steps to
            // `t_start` (all transports identically, preserving shard
            // bit-identity) and the victims abort, landing their
            // extracted work in the requeue pool before the merge below.
            while let Some(&(t, gid)) = self.restarts.first() {
                if t > t_start {
                    break;
                }
                self.restarts.remove(0);
                self.set_up_gid(gid, true);
                self.down = self.down.saturating_sub(1);
                if let Some(f) = self.fleet_obs.as_mut() {
                    f.counters.inc("instance_restarts");
                    f.trace.instant(0, "restart", "fault", t_start, vec![("instance", gid.to_string())]);
                }
            }
            let mut kill_slots: Vec<Vec<usize>> = vec![Vec::new(); workers];
            let mut any_kill = false;
            while let Some(&ev) = self.faults.get(self.next_fault) {
                if ev.at_s > t_start {
                    break;
                }
                self.next_fault += 1;
                any_kill |= self.apply_fault(ev, t_start, &mut kill_slots);
            }
            if any_kill {
                let replies = exec(t_start, vec![Vec::new(); workers], kill_slots);
                self.fold_replies(replies, t_start);
            }

            // Fleet-lane gauge sample at the barrier: fault visibility
            // (instances up, requeue backlog) plus pending handoffs and
            // link business. Barrier times are shard-invariant, so the
            // sampled series is too.
            if let Some(f) = self.fleet_obs.as_mut() {
                if f.series.ready(t_start) {
                    f.series.record(SeriesRow {
                        t_s: t_start,
                        pid: f.trace.pid(),
                        queue_depth: self.handoffs.len(),
                        active_users: 0,
                        kv_frac: 0.0,
                        kv_col_frac: Vec::new(),
                        prefix_hit_rate: 0.0,
                        link_busy_frac: self.fabric.busy_fraction(self.horizon_s),
                        edge_busy_frac: self.fabric.edge_busy_fractions(self.horizon_s),
                        util_frac: 0.0,
                        hbm_bw_frac: 0.0,
                        instances_up: (self.n_entry + self.dec_loads.len()).saturating_sub(self.down),
                        requeue_depth: self.requeue.len(),
                    });
                }
            }

            // Barrier: merge due arrivals and requeued re-arrivals
            // (landing inside the upcoming window) and due handoffs
            // (ready before its start — they can only inject ≥ one
            // lookahead later, so the window stays safe) in
            // shared-comparator order; a trace arrival beats a requeue at
            // the same instant (fixed tie).
            let mut injections: Vec<Vec<(usize, Request)>> = vec![Vec::new(); workers];
            loop {
                let arr = self
                    .trace
                    .get(self.next_arrival)
                    .filter(|r| r.arrival_s < t_end)
                    .map(|r| (r.arrival_s, event_order::ARRIVAL, 0u8));
                let rq = match self.requeue.peek() {
                    Some(&Reverse(q)) if q.at_s < t_end => Some((q.at_s, event_order::ARRIVAL, 1u8)),
                    _ => None,
                };
                let hof = match self.handoffs.peek() {
                    Some(&Reverse(h)) if h.ready_s < t_start => Some((h.ready_s, event_order::HANDOFF, 2u8)),
                    _ => None,
                };
                let mut best: Option<(f64, u8, u8)> = None;
                for cand in [arr, rq, hof].into_iter().flatten() {
                    let replace = match best {
                        None => true,
                        Some(b) => event_order::cmp((cand.0, cand.1), (b.0, b.1))
                            .then(cand.2.cmp(&b.2))
                            .is_lt(),
                    };
                    if replace {
                        best = Some(cand);
                    }
                }
                match best {
                    None => break,
                    Some((_, _, 0)) => self.route_arrival(&mut injections),
                    Some((_, _, 1)) => self.route_requeue(&mut injections),
                    Some(_) => self.process_handoff(&mut injections),
                }
            }

            self.prev_dec_loads.clone_from(&self.dec_loads);
            let replies = exec(t_end, injections, vec![Vec::new(); workers]);
            self.fold_replies(replies, t_start);
            self.prev_end = t_end;
        }
    }

    /// Fold one phase's replies into driver state: completions become
    /// handoffs, loads refresh the epoch-start snapshots, next-event times
    /// merge, and kill reports (fault phases only) are ingested in global
    /// gid order so the requeue sequence cannot depend on the worker
    /// partition.
    fn fold_replies(&mut self, replies: Vec<PhaseReply>, barrier_s: f64) {
        self.engines_next = None;
        let mut killed: Vec<(usize, KillReport)> = Vec::new();
        for rep in replies {
            for (ready, gid, rec) in rep.completions {
                let pos = self.entry_pos[gid][rec];
                self.handoffs.push(Reverse(HandoffEv { ready_s: ready, id: self.trace[pos].id, pos }));
            }
            for (gid, l) in rep.loads {
                if gid < self.n_entry {
                    self.entry_loads[gid] = l;
                } else {
                    self.dec_loads[gid - self.n_entry] = l;
                }
            }
            if let Some(t) = rep.next_event_s {
                merge_min(t, &mut self.engines_next);
            }
            killed.extend(rep.killed);
        }
        killed.sort_by_key(|&(gid, _)| gid);
        for (gid, report) in killed {
            self.ingest_kill(gid, report, barrier_s);
        }
    }

    /// Engines in the fleet (entry pool + decode pool).
    fn n_engines(&self) -> usize {
        self.n_entry + self.dec_loads.len()
    }

    /// Mask (or unmask) instance `gid` on whichever router owns it.
    fn set_up_gid(&mut self, gid: usize, up: bool) {
        if gid < self.n_entry {
            self.router.set_up(gid, up);
        } else {
            self.drouter.set_up(gid - self.n_entry, up);
        }
    }

    /// Apply one due fault at the barrier: mask the victim, schedule its
    /// optional rejoin (a kill bills a weight reload over the shared link
    /// first), and return whether a kill phase is needed for it.
    fn apply_fault(&mut self, ev: FaultEvent, barrier_s: f64, kill_slots: &mut [Vec<usize>]) -> bool {
        self.faults_applied += 1;
        self.set_up_gid(ev.instance, false);
        self.down += 1;
        let kill = matches!(ev.kind, FaultKind::Kill);
        if let Some(f) = self.fleet_obs.as_mut() {
            f.counters.inc("faults");
            f.trace.instant(
                0,
                "fault",
                "fault",
                barrier_s,
                vec![
                    ("instance", ev.instance.to_string()),
                    ("kind", if kill { "kill".to_string() } else { "drain".to_string() }),
                ],
            );
        }
        if kill {
            let (w, slot) = self.whereis[ev.instance];
            kill_slots[w].push(slot);
        }
        if let Some(delay) = ev.restart_after_s {
            let rejoin = if kill {
                // Cold start: the replacement reloads this instance's
                // weights over the same contended fabric the KV handoffs
                // use — concurrent migrations queue behind the reload's
                // per-edge occupancy. The weight source is deterministic:
                // instance 0 serves as the fleet's checkpoint host (its
                // own reload streams from instance 1 when one exists).
                let src = if ev.instance == 0 && self.n_engines() > 1 { 1 } else { 0 };
                let xfer = self.fabric.schedule_bytes(
                    src,
                    ev.instance,
                    barrier_s,
                    self.restart_weight_bytes,
                    &self.cfg.transfer,
                );
                self.fabric_hops += xfer.hops;
                if let Some(f) = self.fleet_obs.as_mut() {
                    f.counters.add("fabric_hops", xfer.hops);
                }
                barrier_s + delay + xfer.exposed_s
            } else {
                barrier_s + delay
            };
            if rejoin < self.horizon_s {
                self.restarts.push((rejoin, ev.instance));
                self.restarts.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
            }
        }
        kill
    }

    /// Fold one victim's extracted work into the requeue pool and the
    /// loss ledgers. Queued and in-flight work requeues at the kill
    /// barrier; not-yet-arrived work no earlier than its original arrival
    /// time. KV lost: the full resident context for in-flight work, and
    /// on the decode side also the landed / in-transit latent-KV of
    /// queued and pending migrations. Anything whose requeue time falls
    /// at/after the horizon is `lost` instead of requeued.
    fn ingest_kill(&mut self, gid: usize, report: KillReport, barrier_s: f64) {
        let decode_side = gid >= self.n_entry;
        let di = gid.saturating_sub(self.n_entry);
        let mut items: Vec<(usize, f64, u64)> = Vec::new();
        for &(rec, _) in &report.queued {
            let pos = if decode_side { self.dec_pos[di][rec] } else { self.entry_pos[gid][rec] };
            let kv =
                if decode_side { self.cfg.transfer.bytes_for(self.trace[pos].prompt_tokens as u64) } else { 0 };
            items.push((pos, barrier_s, kv));
        }
        for &(rec, generated) in &report.in_flight {
            let pos = if decode_side { self.dec_pos[di][rec] } else { self.entry_pos[gid][rec] };
            let ctx = self.trace[pos].prompt_tokens as u64 + generated as u64;
            items.push((pos, barrier_s, self.cfg.transfer.bytes_for(ctx)));
        }
        for &(rec, arrival_s) in &report.pending {
            let pos = if decode_side { self.dec_pos[di][rec] } else { self.entry_pos[gid][rec] };
            let kv =
                if decode_side { self.cfg.transfer.bytes_for(self.trace[pos].prompt_tokens as u64) } else { 0 };
            items.push((pos, arrival_s.max(barrier_s), kv));
        }
        if decode_side {
            self.extracted_from_decode += items.len();
        }
        let mut lost_bytes = 0u64;
        for (pos, at_s, kv) in items {
            lost_bytes += kv;
            if at_s >= self.horizon_s {
                self.lost += 1;
                if let Some(f) = self.fleet_obs.as_mut() {
                    f.counters.inc("requests_lost");
                }
                continue;
            }
            self.requeue.push(Reverse(RequeueEv { at_s, seq: self.requeue_seq, pos }));
            self.requeue_seq += 1;
        }
        self.kv_lost_bytes += lost_bytes;
        if lost_bytes > 0 {
            if let Some(f) = self.fleet_obs.as_mut() {
                f.counters.add("kv_lost_bytes", lost_bytes);
            }
        }
    }

    /// Route one extracted request back into the entry pool as a fresh
    /// arrival: prefill re-runs from scratch (the latent KV and any
    /// decode progress died with the instance, and a re-migration ships
    /// the KV over the link again), while the prefix identity is kept — a
    /// survivor holding the family's blocks still serves its hits.
    fn route_requeue(&mut self, injections: &mut [Vec<(usize, Request)>]) {
        let Reverse(q) = self.requeue.pop().expect("peeked requeue vanished");
        let r = Request { arrival_s: q.at_s, prefilled: false, ..self.trace[q.pos] };
        let work = if self.disagg {
            r.prompt_tokens as f64
        } else {
            r.prompt_tokens as f64 + r.output_tokens as f64
        };
        let loads = self.cfg.routing.uses_live_state().then_some(self.entry_loads.as_slice());
        let spills_before = self.router.spill_events();
        let i = self.router.route_live(&r, q.at_s, work, loads);
        self.records[q.pos].prefill_instance = i as u32;
        self.records[q.pos].requeues += 1;
        self.requeued += 1;
        if let Some(f) = self.fleet_obs.as_mut() {
            f.counters.inc("requests_requeued");
            let spilled = self.router.spill_events() > spills_before;
            let mut args = vec![
                ("req", r.id.to_string()),
                ("instance", i.to_string()),
                ("requeued", "1".to_string()),
            ];
            if spilled {
                f.counters.inc("router_spills");
                args.push(("spill", "affinity-overload".to_string()));
            }
            f.trace.instant(0, "route", "router", q.at_s, args);
        }
        let (w, slot) = self.whereis[i];
        if self.disagg {
            injections[w].push((slot, Request { output_tokens: 1, ..r }));
        } else {
            self.records[q.pos].decode_instance = i as u32;
            injections[w].push((slot, r));
        }
        self.entry_pos[i].push(q.pos);
    }

    /// Route the next trace arrival at its arrival time against the
    /// epoch-start entry-pool snapshot; the entry pool is priced in its
    /// own currency — prompt + output tokens for a colocated pool, prompt
    /// tokens only for a prefill pool (whose instances never decode).
    fn route_arrival(&mut self, injections: &mut [Vec<(usize, Request)>]) {
        let r = self.trace[self.next_arrival];
        let work = if self.disagg {
            r.prompt_tokens as f64
        } else {
            r.prompt_tokens as f64 + r.output_tokens as f64
        };
        let loads = self.cfg.routing.uses_live_state().then_some(self.entry_loads.as_slice());
        let spills_before = self.router.spill_events();
        let i = self.router.route_live(&r, r.arrival_s, work, loads);
        self.records[self.next_arrival].prefill_instance = i as u32;
        if let Some(f) = self.fleet_obs.as_mut() {
            f.counters.inc("routed");
            let spilled = self.router.spill_events() > spills_before;
            let mut args = vec![("req", r.id.to_string()), ("instance", i.to_string())];
            if spilled {
                f.counters.inc("router_spills");
                args.push(("spill", "affinity-overload".to_string()));
            }
            f.trace.instant(0, "route", "router", r.arrival_s, args);
        }
        let (w, slot) = self.whereis[i];
        if self.disagg {
            // Truncate to prefill + first token; the KV then leaves.
            injections[w].push((slot, Request { output_tokens: 1, ..r }));
        } else {
            self.records[self.next_arrival].decode_instance = i as u32;
            injections[w].push((slot, r));
        }
        self.entry_pos[i].push(self.next_arrival);
        self.next_arrival += 1;
    }

    /// A handoff became ready: route the decode destination against the
    /// epoch-start decode-pool snapshot (hop-distance signal from the
    /// fabric under topo-aware placement), serialize the latent KV over
    /// the route's edges (queueing behind concurrent migrations on every
    /// hop), and deliver the pre-filled request at the landing time. The
    /// migrated context is the prompt KV (token #1's cache entry is
    /// produced decode-side).
    fn process_handoff(&mut self, injections: &mut [Vec<(usize, Request)>]) {
        let Reverse(h) = self.handoffs.pop().expect("peeked handoff vanished");
        let orig = self.trace[h.pos];
        let ctx = orig.prompt_tokens as u64;
        let src = self.records[h.pos].prefill_instance as usize;
        let loads = self.cfg.decode_routing.uses_live_state().then_some(if h.ready_s < self.prev_end {
            self.prev_dec_loads.as_slice()
        } else {
            self.dec_loads.as_slice()
        });
        // Hop distances from THIS handoff's source to every decode
        // instance — computed only when the policy reads them, so every
        // other policy's decision sequence is untouched by the fabric.
        let hop_costs: Option<Vec<u64>> = (self.cfg.decode_routing == RoutingPolicy::TopoAware).then(|| {
            (0..self.dec_loads.len()).map(|i| self.fabric.hops(src, self.n_entry + i)).collect()
        });
        let spills_before = self.drouter.spill_events();
        let di = self.drouter.route_with_hops(
            &orig,
            h.ready_s,
            orig.output_tokens as f64,
            loads,
            hop_costs.as_deref(),
        );
        let bytes = self.cfg.transfer.bytes_for(ctx);
        let xfer = self.fabric.schedule_bytes(src, self.n_entry + di, h.ready_s, bytes, &self.cfg.transfer);
        let exposed = xfer.exposed_s;
        self.fabric_hops += xfer.hops;
        self.records[h.pos].decode_instance = di as u32;
        // Accumulate, don't overwrite: a requeued request that re-migrates
        // ships its latent KV over the fabric AGAIN, and the record
        // reports the total it cost.
        self.records[h.pos].transfer_bytes += bytes;
        self.records[h.pos].transfer_s += exposed;
        self.records[h.pos].transfer_hop_bytes += bytes * xfer.hops;
        if let Some(f) = self.fleet_obs.as_mut() {
            f.counters.inc("handoffs");
            f.counters.add("fabric_hops", xfer.hops);
            let spilled = self.drouter.spill_events() > spills_before;
            let mut args = vec![
                ("req", orig.id.to_string()),
                ("decode_instance", di.to_string()),
                ("bytes", bytes.to_string()),
                ("link_wait_s", format!("{:.6}", xfer.wait_s)),
                ("hops", xfer.hops.to_string()),
                ("path", xfer.path_label()),
            ];
            if spilled {
                f.counters.inc("router_spills");
                args.push(("spill", "affinity-overload".to_string()));
            }
            // The handoff span starts at prefill completion (the source
            // engine's clock when token #1 left) and ends at the
            // decode-pool landing — serialization + queue wait.
            f.trace.complete(h.pos as u64 + 1, "handoff", "link", h.ready_s, h.ready_s + exposed, args);
            if f.series.ready(h.ready_s) {
                f.series.record(SeriesRow {
                    t_s: h.ready_s,
                    pid: f.trace.pid(),
                    queue_depth: self.handoffs.len(),
                    active_users: 0,
                    kv_frac: 0.0,
                    kv_col_frac: Vec::new(),
                    prefix_hit_rate: 0.0,
                    link_busy_frac: self.fabric.busy_fraction(self.horizon_s),
                    edge_busy_frac: self.fabric.edge_busy_fractions(self.horizon_s),
                    util_frac: 0.0,
                    hbm_bw_frac: 0.0,
                    instances_up: (self.n_entry + self.dec_loads.len()).saturating_sub(self.down),
                    requeue_depth: self.requeue.len(),
                });
            }
        }
        // The user sees token #1 once the handoff lands. Sampling rule
        // (mirrors the colocated side): every request whose prefill
        // finished inside the simulated window contributes a TTFT sample —
        // colocated first tokens stamped during the final tick may
        // likewise overshoot the horizon by up to one tick, and here the
        // overshoot bound is one tick plus the exposed transfer delay. A
        // migrated request the decode pool later rejects keeps its sample
        // too: its first token WAS delivered (post-prefill aborts in real
        // disaggregated serving still stream token #1). A requeued request
        // keeps the EARLIEST stamp — the user really saw token #1 before
        // the instance died; the stall shows up in its per-token cadence.
        let t1 = h.ready_s + exposed;
        self.records[h.pos].first_token_s = Some(match self.records[h.pos].first_token_s {
            Some(f) => f.min(t1),
            None => t1,
        });
        let (w, slot) = self.whereis[self.n_entry + di];
        injections[w].push((
            slot,
            Request {
                arrival_s: h.ready_s + exposed,
                prefix_id: 0,
                prefix_tokens: 0,
                prefix_hash: 0,
                prefilled: true,
                ..orig
            },
        ));
        self.dec_pos[di].push(h.pos);
        self.migrated += 1;
    }
}

/// Simulate `trace` on the fleet described by `cfg` with the sharded
/// conservative-lookahead engine (`cfg.shards`; 1 = inline, no threads).
/// Deterministic: two identical invocations return identical outcomes and
/// records, and ANY shard count reproduces the serial path bit-identically
/// (pinned by tests). A 1-instance colocated fleet reproduces
/// `serve::sim::simulate` byte-identically (pinned by tests) — the fleet
/// layer adds nothing an isolated instance would notice.
#[allow(clippy::too_many_arguments)]
pub fn simulate_cluster(
    sys: &WaferSystem,
    ds: &DeepSeekConfig,
    trace: &[Request],
    cfg: &ClusterConfig,
    horizon_s: f64,
    offered_rps: f64,
    kernels: &KernelCache,
    stages: &StageTimeCache,
) -> (ClusterOutcome, Vec<ClusterRecord>) {
    let (outcome, records, _) = simulate_cluster_observed(sys, ds, trace, cfg, horizon_s, offered_rps, kernels, stages, None);
    (outcome, records)
}

/// [`simulate_cluster`] with an optional observability sink: identical
/// simulation (same outcome and records, bit for bit), plus per-instance
/// trace recorders / gauge series (pid `0..n_entry` entry pool, then the
/// decode pool) and a fleet lane (last pid) carrying router decisions,
/// KV-handoff link spans, fault instants and the shared-link busy series.
#[allow(clippy::too_many_arguments)]
pub fn simulate_cluster_observed(
    sys: &WaferSystem,
    ds: &DeepSeekConfig,
    trace: &[Request],
    cfg: &ClusterConfig,
    horizon_s: f64,
    offered_rps: f64,
    kernels: &KernelCache,
    stages: &StageTimeCache,
    obs: Option<ObsConfig>,
) -> (ClusterOutcome, Vec<ClusterRecord>, Option<ObsBundle>) {
    simulate_cluster_faulted_observed(
        sys,
        ds,
        trace,
        cfg,
        &FaultPlan::none(),
        horizon_s,
        offered_rps,
        kernels,
        stages,
        obs,
    )
}

/// [`simulate_cluster_observed`] under a [`FaultPlan`]: kill/drain events
/// execute at epoch barriers (see the module docs' fault section), dead
/// instances' work requeues through the router, and optional restarts
/// rejoin after a cold start billed over the shared link. With the empty
/// plan this IS `simulate_cluster_observed` — same code path, bit for
/// bit. Deterministic and bit-identical at every shard count, fault plan
/// active or not (pinned by `integration_cluster`).
#[allow(clippy::too_many_arguments)]
pub fn simulate_cluster_faulted_observed(
    sys: &WaferSystem,
    ds: &DeepSeekConfig,
    trace: &[Request],
    cfg: &ClusterConfig,
    faults: &FaultPlan,
    horizon_s: f64,
    offered_rps: f64,
    kernels: &KernelCache,
    stages: &StageTimeCache,
    obs: Option<ObsConfig>,
) -> (ClusterOutcome, Vec<ClusterRecord>, Option<ObsBundle>) {
    let (outcome, records, bundle, _) =
        simulate_cluster_profiled(sys, ds, trace, cfg, faults, horizon_s, offered_rps, kernels, stages, obs);
    (outcome, records, bundle)
}

/// [`simulate_cluster_faulted_observed`] plus the DES wall-clock
/// self-profile: per-worker busy and barrier-stall host seconds, epoch
/// count and total wall time. The profile is the ONLY non-deterministic
/// output — it is confined to printed report notes and never enters a
/// byte-pinned export; outcome, records and bundle are bit-identical to
/// the unprofiled call.
#[allow(clippy::too_many_arguments)]
pub fn simulate_cluster_profiled(
    sys: &WaferSystem,
    ds: &DeepSeekConfig,
    trace: &[Request],
    cfg: &ClusterConfig,
    faults: &FaultPlan,
    horizon_s: f64,
    offered_rps: f64,
    kernels: &KernelCache,
    stages: &StageTimeCache,
    obs: Option<ObsConfig>,
) -> (ClusterOutcome, Vec<ClusterRecord>, Option<ObsBundle>, DesProfile) {
    let wall0 = std::time::Instant::now();
    cfg.mode.validate();
    let disagg = matches!(cfg.mode, FleetMode::Disaggregated { .. });
    let (n_entry, n_decode) = match cfg.mode {
        FleetMode::Colocated { instances } => (instances as usize, 0usize),
        FleetMode::Disaggregated { prefill, decode } => (prefill as usize, decode as usize),
    };
    let records: Vec<ClusterRecord> = trace
        .iter()
        .map(|r| ClusterRecord {
            id: r.id,
            arrival_s: r.arrival_s,
            prompt_tokens: r.prompt_tokens,
            output_tokens: r.output_tokens,
            first_token_s: None,
            completion_s: None,
            prefill_instance: u32::MAX,
            decode_instance: u32::MAX,
            transfer_bytes: 0,
            transfer_s: 0.0,
            transfer_hop_bytes: 0,
            requeues: 0,
        })
        .collect();

    let mut entry: Vec<ServeEngine> =
        (0..n_entry).map(|_| ServeEngine::new(sys, ds, cfg.serve, horizon_s, kernels, stages)).collect();
    let mut dec: Vec<ServeEngine> =
        (0..n_decode).map(|_| ServeEngine::new(sys, ds, cfg.serve, horizon_s, kernels, stages)).collect();
    if let Some(ocfg) = obs {
        let entry_name = if disagg { "prefill" } else { "instance" };
        for (i, e) in entry.iter_mut().enumerate() {
            e.attach_obs(EngineObs::new(i as u32, &format!("{entry_name}-{i}"), ocfg));
        }
        for (i, e) in dec.iter_mut().enumerate() {
            e.attach_obs(EngineObs::new((n_entry + i) as u32, &format!("decode-{i}"), ocfg));
        }
    }
    // The fleet lane (last pid): router decisions and KV-link transfers —
    // events no single instance can see.
    let fleet_obs: Option<EngineObs> = obs.map(|ocfg| EngineObs::new((n_entry + n_decode) as u32, "fleet", ocfg));
    // Per-engine record index → position in `trace`/`records`.
    let entry_pos: Vec<Vec<usize>> = vec![Vec::new(); n_entry];
    let dec_pos: Vec<Vec<usize>> = vec![Vec::new(); n_decode];
    let keying = cfg.serve.scheduler.prefix_keying;
    let n_engines = n_entry + n_decode;
    // Shard partition (semantic, any value is bit-identical) folded onto
    // the process-wide worker budget (wall-clock only).
    let shards = cfg.shards.max(1) as usize;
    let workers = shards.min(crate::util::worker_threads()).min(n_engines).max(1);
    let fabric = Fabric::new(cfg.topology, n_engines, &cfg.transfer);
    // The conservative lookahead is the minimum single-edge traversal
    // latency over the fabric: every edge charges the full per-hop base
    // latency, so no cross-instance event lands sooner — numerically the
    // link base latency for every topology (pooled included).
    let lookahead = {
        let l = fabric.lookahead_s(&cfg.transfer);
        if l > 0.0 {
            l
        } else {
            1e-6
        }
    };
    let want_entry_loads = cfg.routing.uses_live_state();
    let want_dec_loads = disagg && cfg.decode_routing.uses_live_state();
    let mut whereis = vec![(0usize, 0usize); n_engines];
    // A cold-started replacement reloads the full per-instance weight
    // footprint (every chip of the EP×PP plan) over the shared fabric.
    let restart_weight_bytes = {
        let kvm = crate::serve::kv::KvCacheModel::new(sys, ds, cfg.serve.plan, cfg.serve.dtype);
        kvm.weight_bytes_per_chip * cfg.serve.plan.ep as u64 * cfg.serve.plan.pp as u64
    };

    let mut drv = EpochDriver {
        trace,
        cfg,
        horizon_s,
        disagg,
        n_entry,
        lookahead,
        whereis: Vec::new(),
        records,
        entry_pos,
        dec_pos,
        router: Router::new(cfg.routing, keying, n_entry, cfg.drain_rate),
        drouter: Router::new(cfg.decode_routing, keying, n_decode.max(1), cfg.drain_rate),
        fabric,
        fabric_hops: 0,
        fleet_obs,
        handoffs: BinaryHeap::new(),
        next_arrival: 0,
        migrated: 0,
        entry_loads: vec![LiveLoad { queued: 0, active: 0 }; n_entry],
        dec_loads: vec![LiveLoad { queued: 0, active: 0 }; n_decode],
        prev_dec_loads: vec![LiveLoad { queued: 0, active: 0 }; n_decode],
        prev_end: 0.0,
        engines_next: None,
        faults: faults.sorted(n_engines, horizon_s),
        next_fault: 0,
        restarts: Vec::new(),
        requeue: BinaryHeap::new(),
        requeue_seq: 0,
        restart_weight_bytes,
        requeued: 0,
        lost: 0,
        extracted_from_decode: 0,
        kv_lost_bytes: 0,
        faults_applied: 0,
        down: 0,
        epochs: 0,
    };

    // Per-worker wall-clock accumulators for the DES self-profile.
    let mut prof_busy = vec![0.0f64; workers];
    let mut prof_stall = vec![0.0f64; workers];
    {
        // Partition engines across workers: engine gid → shard (gid %
        // shards) → worker. The grouping is invisible to results (see
        // `PhaseReply`); it only decides which thread steps which engine.
        let mut groups: Vec<Vec<(usize, &mut ServeEngine)>> = (0..workers).map(|_| Vec::new()).collect();
        for (gid, e) in entry.iter_mut().chain(dec.iter_mut()).enumerate() {
            let w = (gid % shards) % workers;
            whereis[gid] = (w, groups[w].len());
            groups[w].push((gid, e));
        }
        drv.whereis = whereis;

        if workers <= 1 {
            // Inline transport: the same phase code, no threads — this IS
            // the serial path (`--shards 1`).
            drv.run(workers, &mut |end_s, inj, kills| {
                groups
                    .iter_mut()
                    .zip(inj.into_iter().zip(kills))
                    .enumerate()
                    .map(|(w, (g, (injections, kills)))| {
                        let t = std::time::Instant::now();
                        let rep = run_worker_phase(
                            g,
                            n_entry,
                            disagg,
                            want_entry_loads,
                            want_dec_loads,
                            PhaseCmd { end_s, injections, kills },
                        );
                        prof_busy[w] += t.elapsed().as_secs_f64();
                        rep
                    })
                    .collect()
            });
        } else {
            // Threaded transport: persistent scoped workers, one phase
            // command/reply pair per epoch. Replies are collected in
            // worker order, but nothing downstream depends on it. The
            // atomics carry wall-clock busy / barrier-stall nanos for the
            // DES self-profile only — never simulated state.
            use std::sync::atomic::{AtomicU64, Ordering};
            let busy_ns: Vec<AtomicU64> = (0..workers).map(|_| AtomicU64::new(0)).collect();
            let stall_ns: Vec<AtomicU64> = (0..workers).map(|_| AtomicU64::new(0)).collect();
            std::thread::scope(|s| {
                let mut txs = Vec::with_capacity(workers);
                let mut rxs = Vec::with_capacity(workers);
                for (w, mut g) in groups.into_iter().enumerate() {
                    let (ctx, crx) = std::sync::mpsc::channel::<PhaseCmd>();
                    let (rtx, rrx) = std::sync::mpsc::channel::<PhaseReply>();
                    let (busy, stall) = (&busy_ns[w], &stall_ns[w]);
                    s.spawn(move || loop {
                        let t = std::time::Instant::now();
                        let Ok(cmd) = crx.recv() else { break };
                        stall.fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
                        let t = std::time::Instant::now();
                        let rep = run_worker_phase(
                            &mut g,
                            n_entry,
                            disagg,
                            want_entry_loads,
                            want_dec_loads,
                            cmd,
                        );
                        busy.fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
                        if rtx.send(rep).is_err() {
                            break;
                        }
                    });
                    txs.push(ctx);
                    rxs.push(rrx);
                }
                drv.run(workers, &mut |end_s, inj, kills| {
                    for (tx, (injections, kills)) in txs.iter().zip(inj.into_iter().zip(kills)) {
                        tx.send(PhaseCmd { end_s, injections, kills }).expect("fleet worker died");
                    }
                    rxs.iter().map(|rx| rx.recv().expect("fleet worker died")).collect()
                });
                drop(txs);
            });
            for (dst, src) in prof_busy.iter_mut().chain(prof_stall.iter_mut()).zip(busy_ns.iter().chain(&stall_ns)) {
                *dst = src.load(Ordering::Relaxed) as f64 * 1e-9;
            }
        }
    }

    let EpochDriver {
        mut records,
        entry_pos,
        dec_pos,
        router,
        drouter,
        fabric,
        fabric_hops,
        mut fleet_obs,
        migrated,
        requeued,
        lost,
        extracted_from_decode,
        kv_lost_bytes,
        faults_applied,
        epochs,
        ..
    } = drv;

    // Detach sinks before `finish` consumes the engines; engine recorders
    // land in pid order (entry, decode), the fleet lane last. Cache
    // counters are process-wide (the caches are shared), snapshotted here.
    // Attribution recorders leave their sinks now: kernel aggregates fold
    // into the export immediately, while the per-request capture slots
    // wait for the merged record stamps below (waterfalls need the
    // first-token / completion times that only exist after the merge).
    let mut attrib_slots: Vec<Vec<ReqSlot>> = Vec::new();
    let mut bundle = obs.map(|_| {
        let mut b = ObsBundle::new();
        let mut ax = AttribExport { offered: trace.len(), ..AttribExport::default() };
        for (pid, e) in entry.iter_mut().chain(dec.iter_mut()).enumerate() {
            if let Some(mut sink) = e.take_obs() {
                let attrib = std::mem::take(&mut sink.attrib);
                ax.push_engine(pid as u32, &attrib);
                attrib_slots.push(attrib.slots);
                b.push_engine(*sink);
            }
        }
        if let Some(mut f) = fleet_obs.take() {
            f.counters.add("migrated", migrated as u64);
            f.trace.close_open(horizon_s);
            b.push_engine(f);
        }
        b.counters.add("stage_cache_hits", stages.hits());
        b.counters.add("stage_cache_misses", stages.misses());
        b.counters.add("kernel_cache_hits", kernels.hits());
        b.counters.add("kernel_cache_misses", kernels.misses());
        b.attrib = ax;
        b
    });

    let entry_role: &'static str = if disagg { "prefill" } else { "colocated" };
    let entry_results: Vec<(ServeOutcome, Vec<RequestRecord>)> =
        entry.into_iter().map(|e| e.finish(entry_role, 0.0)).collect();
    let decode_results: Vec<(ServeOutcome, Vec<RequestRecord>)> =
        dec.into_iter().map(|e| e.finish("decode", 0.0)).collect();
    // A requeued request maps from TWO engines (the corpse and the
    // survivor), so stamps merge instead of overwrite: the earliest first
    // token wins (the user really saw it before the instance died), and
    // completion comes from whichever engine actually finished — at most
    // one, since completed requests are never extracted.
    for (i, (_, recs)) in entry_results.iter().enumerate() {
        for (k, rec) in recs.iter().enumerate() {
            if !disagg {
                let p = entry_pos[i][k];
                if let Some(t) = rec.first_token_s {
                    records[p].first_token_s = Some(match records[p].first_token_s {
                        Some(f) => f.min(t),
                        None => t,
                    });
                }
                if rec.completion_s.is_some() {
                    records[p].completion_s = rec.completion_s;
                }
            }
        }
    }
    for (i, (_, recs)) in decode_results.iter().enumerate() {
        for (k, rec) in recs.iter().enumerate() {
            if rec.completion_s.is_some() {
                records[dec_pos[i][k]].completion_s = rec.completion_s;
            }
        }
    }
    if let Some(b) = bundle.as_mut() {
        // Waterfalls read the MERGED stamps: the entry slot comes from the
        // engine that ran the (final) prefill, the completer slot from the
        // engine that finished decode. A requeued request appears in
        // several engines' position maps — the last occurrence on its
        // final instance wins; its earlier lives land in the requeue-stall
        // residual.
        for (p, r) in records.iter().enumerate() {
            let Some(first) = r.first_token_s else { continue };
            let slot_at = |gid: usize, positions: &[usize]| {
                positions
                    .iter()
                    .rposition(|&q| q == p)
                    .and_then(|k| attrib_slots.get(gid).and_then(|s| s.get(k).copied()))
            };
            let entry_slot = if r.prefill_instance != u32::MAX {
                let i = r.prefill_instance as usize;
                slot_at(i, &entry_pos[i])
            } else {
                None
            };
            let completer = if !disagg {
                entry_slot
            } else if r.decode_instance != u32::MAX {
                let d = r.decode_instance as usize;
                slot_at(n_entry + d, &dec_pos[d])
            } else {
                None
            };
            b.attrib.waterfalls.push(assemble_waterfall(
                r.id,
                r.arrival_s,
                first,
                r.completion_s,
                r.transfer_s,
                r.requeues,
                entry_slot.as_ref(),
                completer.as_ref(),
            ));
        }
    }
    let telemetry = FleetTelemetry {
        router_spills: router.spill_events() + drouter.spill_events(),
        link_busy_frac: fabric.busy_fraction(horizon_s),
        link_wait_s: fabric.wait_s(),
        fabric_hops,
        edge_busy_s: fabric.edge_busy_s(),
        faults: faults_applied,
        requeued,
        lost,
        extracted_from_decode,
        kv_lost_bytes,
    };
    let outcome = aggregate(
        cfg,
        trace.len(),
        &records,
        &entry_results,
        &decode_results,
        migrated,
        horizon_s,
        offered_rps,
        entry_role,
        telemetry,
    );
    let profile = DesProfile {
        workers,
        epochs,
        wall_s: wall0.elapsed().as_secs_f64(),
        worker_busy_s: prof_busy,
        barrier_stall_s: prof_stall,
    };
    (outcome, records, bundle, profile)
}

/// Per-model serve config for co-residency on a shared instance: the
/// co-resident model's weight bytes are reserved out of the KV budget and
/// the per-chip user slots are split between the models. This is THE
/// co-residency billing recipe — both the static-bound arm (isolated
/// fleets, no interference) and the interleaved shared pool use it, so the
/// golden interference anchor compares the two arms under one definition.
pub fn co_resident_serve(sys: &WaferSystem, other: &DeepSeekConfig, base: ServeConfig) -> ServeConfig {
    let reserved = crate::serve::kv::KvCacheModel::new(sys, other, base.plan, base.dtype).weight_bytes_per_chip;
    ServeConfig {
        reserved_hbm_bytes: base.reserved_hbm_bytes + reserved,
        scheduler: crate::serve::scheduler::SchedulerConfig {
            max_batch_per_chip: (base.scheduler.max_batch_per_chip / 2).max(1),
            ..base.scheduler
        },
        ..base
    }
}

/// One co-resident model of a shared multi-model pool.
pub struct SharedPoolSpec<'a> {
    pub ds: &'a DeepSeekConfig,
    pub trace: &'a [Request],
    /// Per-instance serving config for this model (reserved co-resident
    /// weight bytes and the split batch ceiling included).
    pub serve: ServeConfig,
    pub offered_rps: f64,
}

/// Simulate several models co-resident on ONE pool of `instances` shared
/// instances, with cross-model tick interference: each instance's chip is
/// exclusively occupied during any model's iteration, so co-resident ticks
/// serialize on one chip clock — a model's decode cadence genuinely
/// stretches while the other model runs, instead of being statically
/// billed (the pre-interleaving lower bound). Returns one
/// (outcome, records) pair per model, in input order.
///
/// Fairness at equal readiness is deterministic: the engine that has
/// waited longest (smallest own clock) takes the chip next.
#[allow(clippy::too_many_arguments)]
pub fn simulate_shared_pool(
    sys: &WaferSystem,
    models: &[SharedPoolSpec],
    instances: u32,
    routing: RoutingPolicy,
    drain_rate: f64,
    horizon_s: f64,
    kernels: &KernelCache,
    stages: &StageTimeCache,
) -> Vec<(ClusterOutcome, Vec<ClusterRecord>)> {
    assert!(instances >= 1, "empty shared pool");
    assert!(!models.is_empty(), "a shared pool needs at least one model");
    let n = instances as usize;
    let mut engines: Vec<Vec<ServeEngine>> = models
        .iter()
        .map(|m| (0..n).map(|_| ServeEngine::new(sys, m.ds, m.serve, horizon_s, kernels, stages)).collect())
        .collect();
    let mut pos: Vec<Vec<Vec<usize>>> = models.iter().map(|_| vec![Vec::new(); n]).collect();
    let mut routers: Vec<Router> = models
        .iter()
        .map(|m| Router::new(routing, m.serve.scheduler.prefix_keying, n, drain_rate))
        .collect();
    let mut next_arrival: Vec<usize> = vec![0; models.len()];
    // Chip-exclusive serialization state: instance i's chip is busy until
    // chip_free[i]; any engine's next tick starts no earlier.
    let mut chip_free: Vec<f64> = vec![0.0; n];

    loop {
        // (time, kind, waited-since, model, instance); arrivals first at
        // ties, then the engine that has waited longest on the busy chip.
        let mut best: Option<(f64, u8, f64, usize, usize)> = None;
        let mut consider =
            |cand: (f64, u8, f64, usize, usize), best: &mut Option<(f64, u8, f64, usize, usize)>| {
                let replace = match *best {
                    None => true,
                    Some(b) => {
                        cand.0
                            .total_cmp(&b.0)
                            .then(cand.1.cmp(&b.1))
                            .then(cand.2.total_cmp(&b.2))
                            .then(cand.3.cmp(&b.3))
                            .then(cand.4.cmp(&b.4))
                            == std::cmp::Ordering::Less
                    }
                };
                if replace {
                    *best = Some(cand);
                }
            };
        for (m, spec) in models.iter().enumerate() {
            if let Some(r) = spec.trace.get(next_arrival[m]) {
                consider((r.arrival_s, 0, r.arrival_s, m, 0), &mut best);
            }
        }
        for (m, es) in engines.iter().enumerate() {
            for (i, e) in es.iter().enumerate() {
                if let Some(t) = e.next_event_s() {
                    consider((t.max(chip_free[i]), 1, e.clock_s(), m, i), &mut best);
                }
            }
        }
        let Some((_, kind, _, m, i)) = best else { break };
        if kind == 0 {
            // Route this model's arrival against the live COMBINED load of
            // each shared instance (both models' queues compete for the
            // same chips).
            let r = models[m].trace[next_arrival[m]];
            let loads: Option<Vec<LiveLoad>> = routing.uses_live_state().then(|| {
                (0..n)
                    .map(|j| {
                        let mut queued = 0usize;
                        let mut active = 0usize;
                        for es in &engines {
                            let s = es[j].snapshot();
                            queued += s.queue_depth;
                            active += s.active_users;
                        }
                        LiveLoad { queued, active }
                    })
                    .collect()
            });
            let work = r.prompt_tokens as f64 + r.output_tokens as f64;
            let j = routers[m].route_live(&r, r.arrival_s, work, loads.as_deref());
            engines[m][j].inject(r);
            pos[m][j].push(next_arrival[m]);
            next_arrival[m] += 1;
        } else {
            // The chip may still be held by a co-resident model's tick:
            // that time has passed for this engine too.
            let e = &mut engines[m][i];
            e.advance_clock_to(chip_free[i]);
            if let Step::Ticked { .. } = e.step() {
                chip_free[i] = e.clock_s();
            }
        }
    }

    let mut out = Vec::with_capacity(models.len());
    for (m, spec) in models.iter().enumerate() {
        let mut records: Vec<ClusterRecord> = spec
            .trace
            .iter()
            .map(|r| ClusterRecord {
                id: r.id,
                arrival_s: r.arrival_s,
                prompt_tokens: r.prompt_tokens,
                output_tokens: r.output_tokens,
                first_token_s: None,
                completion_s: None,
                prefill_instance: u32::MAX,
                decode_instance: u32::MAX,
                transfer_bytes: 0,
                transfer_s: 0.0,
                transfer_hop_bytes: 0,
                requeues: 0,
            })
            .collect();
        let results: Vec<(ServeOutcome, Vec<RequestRecord>)> =
            std::mem::take(&mut engines[m]).into_iter().map(|e| e.finish("shared", 0.0)).collect();
        for (i, (_, recs)) in results.iter().enumerate() {
            for (k, rec) in recs.iter().enumerate() {
                let p = pos[m][i][k];
                records[p].prefill_instance = i as u32;
                records[p].decode_instance = i as u32;
                records[p].first_token_s = rec.first_token_s;
                records[p].completion_s = rec.completion_s;
            }
        }
        let pseudo = ClusterConfig {
            mode: FleetMode::Colocated { instances },
            serve: spec.serve,
            routing,
            decode_routing: routing,
            transfer: KvTransferModel::inter_node(spec.ds, spec.serve.dtype),
            topology: TopologySpec::Degenerate,
            drain_rate,
            shards: 1,
        };
        let telemetry = FleetTelemetry {
            router_spills: routers[m].spill_events(),
            ..Default::default()
        };
        let outcome = aggregate(
            &pseudo,
            spec.trace.len(),
            &records,
            &results,
            &[],
            0,
            horizon_s,
            spec.offered_rps,
            "shared",
            telemetry,
        );
        out.push((outcome, records));
    }
    out
}

/// Roll per-instance outcomes and fleet records into a [`ClusterOutcome`].
#[allow(clippy::too_many_arguments)]
fn aggregate(
    cfg: &ClusterConfig,
    offered: usize,
    records: &[ClusterRecord],
    entry: &[(ServeOutcome, Vec<RequestRecord>)],
    decode: &[(ServeOutcome, Vec<RequestRecord>)],
    migrated: usize,
    horizon_s: f64,
    offered_rps: f64,
    entry_role: &'static str,
    telemetry: FleetTelemetry,
) -> ClusterOutcome {
    let disagg = !decode.is_empty();
    let arrived: usize = entry.iter().map(|(o, _)| o.arrived).sum();
    let completed: usize = if disagg {
        decode.iter().map(|(o, _)| o.completed).sum()
    } else {
        entry.iter().map(|(o, _)| o.completed).sum()
    };
    let rejected: usize = entry.iter().map(|(o, _)| o.rejected).sum::<usize>()
        + decode.iter().map(|(o, _)| o.rejected).sum::<usize>();
    let entry_backlog: usize = entry.iter().map(|(o, _)| o.in_flight + o.queued).sum();
    let decode_backlog: usize = decode.iter().map(|(o, _)| o.in_flight + o.queued).sum();
    let decode_arrived: usize = decode.iter().map(|(o, _)| o.arrived).sum();
    // Migrations still en route at the horizon: of everything that left a
    // prefill instance, subtract what landed (net of kill extractions —
    // an extracted landing left `arrived` again via `ServeEngine::kill`)
    // and what was extracted from the decode side entirely (landed or
    // in-flight, its migration is dead, not en route).
    let in_transfer =
        if disagg { migrated - decode_arrived - telemetry.extracted_from_decode } else { 0 };
    let in_flight = entry_backlog + in_transfer + decode_backlog;

    let ttft: Vec<f64> = records.iter().filter_map(ClusterRecord::ttft_ms).collect();
    let tpot: Vec<f64> = records
        .iter()
        .filter(|r| r.completion_s.is_some())
        .filter_map(ClusterRecord::tpot_ms)
        .collect();
    let within_slo = records
        .iter()
        .filter(|r| r.completion_s.is_some())
        .filter(|r| {
            r.ttft_ms().is_some_and(|t| t <= cfg.serve.slo_ttft_ms)
                && r.tpot_ms().map_or(true, |t| t <= cfg.serve.slo_tpot_ms)
        })
        .count();

    let kv_transfer_bytes: u64 = records.iter().map(|r| r.transfer_bytes).sum();
    let kv_transfer_exposed_s: f64 = records.iter().map(|r| r.transfer_s).sum();
    let (mut xfer_s, mut e2e_s) = (0.0f64, 0.0f64);
    for r in records {
        if let Some(c) = r.completion_s {
            if r.transfer_bytes > 0 {
                xfer_s += r.transfer_s;
                e2e_s += c - r.arrival_s;
            }
        }
    }
    let transfer_overhead_share = if e2e_s > 0.0 { xfer_s / e2e_s } else { 0.0 };

    let all = entry.iter().chain(decode.iter());
    let fleet_tokens_per_s: f64 = all.clone().map(|(o, _)| o.system_tokens_per_s).sum();
    let kv_over_capacity = all.clone().any(|(o, _)| o.kv_over_capacity);
    let preemptions: u64 = all.map(|(o, _)| o.preemptions).sum();

    let mut instances: Vec<InstanceSummary> = entry
        .iter()
        .map(|(o, _)| InstanceSummary::from_outcome(entry_role, o))
        .collect();
    instances.extend(decode.iter().map(|(o, _)| InstanceSummary::from_outcome("decode", o)));

    ClusterOutcome {
        label: cfg.mode.label(),
        mode: cfg.mode,
        offered_rps,
        horizon_s,
        offered,
        arrived,
        completed,
        rejected,
        in_flight,
        in_transfer,
        completed_within_slo: within_slo,
        ttft_ms: Percentiles::from_values(&ttft),
        tpot_ms: Percentiles::from_values(&tpot),
        fleet_tokens_per_s,
        goodput_rps: if horizon_s > 0.0 { within_slo as f64 / horizon_s } else { 0.0 },
        migrated,
        kv_transfer_bytes,
        kv_transfer_exposed_s,
        transfer_overhead_share,
        kv_over_capacity,
        preemptions,
        router_spills: telemetry.router_spills,
        link_busy_frac: telemetry.link_busy_frac,
        link_wait_s: telemetry.link_wait_s,
        fabric_hops: telemetry.fabric_hops,
        edge_busy_s: telemetry.edge_busy_s,
        faults: telemetry.faults,
        requeued: telemetry.requeued,
        lost: telemetry.lost,
        extracted_from_decode: telemetry.extracted_from_decode,
        kv_lost_bytes: telemetry.kv_lost_bytes,
        shards: cfg.shards.max(1),
        instances,
    }
}

/// Lowest offered load at which the disaggregated fleet's p99 TPOT drops
/// below the colocated fleet's — the crossover the `cluster_pools`
/// experiment reports. Robust to unsorted or unequal-length inputs: the
/// curves are paired by offered rate (points without a partner in the
/// other curve are skipped) and scanned in increasing-rate order.
pub fn tpot_crossover(colocated: &[ClusterOutcome], disagg: &[ClusterOutcome]) -> Option<f64> {
    let mut pairs: Vec<(&ClusterOutcome, &ClusterOutcome)> = colocated
        .iter()
        .filter_map(|c| disagg.iter().find(|d| d.offered_rps == c.offered_rps).map(|d| (c, d)))
        .collect();
    pairs.sort_by(|a, b| a.0.offered_rps.total_cmp(&b.0.offered_rps));
    pairs
        .into_iter()
        .find(|(c, d)| c.completed > 0 && d.completed > 0 && d.tpot_ms.p99 < c.tpot_ms.p99)
        .map(|(c, _)| c.offered_rps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::request::{generate_trace, TraceConfig, TrafficPattern};
    use crate::serve::sim::simulate;

    fn trace(rate: f64, horizon: f64, seed: u64) -> Vec<Request> {
        generate_trace(&TraceConfig::new(seed, TrafficPattern::Poisson, rate, horizon))
    }

    #[test]
    fn colocated_single_instance_matches_serve_sim() {
        // A colocated fleet of 1 is exactly the serving simulator: every
        // first-token / completion time must agree with a direct call.
        let sys = WaferSystem::paper();
        let ds = DeepSeekConfig::v3_671b();
        let ccfg = ClusterConfig::colocated(1, &ds);
        let t = trace(60.0, 4.0, 3);
        let kernels = KernelCache::new();
        let stages = StageTimeCache::new();
        let (co, crecs) = simulate_cluster(&sys, &ds, &t, &ccfg, 4.0, 60.0, &kernels, &stages);
        let (so, srecs) = simulate(&sys, &ds, &t, &ccfg.serve, 4.0, "p", 60.0, &kernels, &stages);
        assert_eq!(co.completed, so.completed);
        assert_eq!(co.arrived, so.arrived);
        assert!(co.conserves_requests());
        assert_eq!(co.migrated, 0);
        assert_eq!(co.kv_transfer_bytes, 0);
        assert_eq!(co.link_busy_frac, 0.0);
        for (c, s) in crecs.iter().zip(&srecs) {
            assert_eq!(c.id, s.id);
            assert_eq!(c.first_token_s, s.first_token_s);
            assert_eq!(c.completion_s, s.completion_s);
            assert_eq!(c.prefill_instance, 0);
        }
        assert_eq!(co.tpot_ms, so.tpot_ms);
    }

    #[test]
    fn disaggregated_smoke_conserves_and_bills_transfers() {
        let sys = WaferSystem::paper();
        let ds = DeepSeekConfig::v3_671b();
        let ccfg = ClusterConfig::disaggregated(1, 1, &ds);
        let t = trace(80.0, 4.0, 7);
        let kernels = KernelCache::new();
        let stages = StageTimeCache::new();
        let (o, recs) = simulate_cluster(&sys, &ds, &t, &ccfg, 4.0, 80.0, &kernels, &stages);
        assert!(o.conserves_requests(), "{o:?}");
        assert!(o.completed > 0, "light load must complete requests end-to-end");
        assert!(o.migrated >= o.completed);
        assert!(!o.kv_over_capacity);
        let layout = KvTransferModel::layout_bytes_per_token(&ds, ccfg.serve.dtype);
        for r in &recs {
            if r.transfer_bytes > 0 {
                // The transfer-bytes invariant: exactly the latent-KV layout
                // bytes of the migrated prompt context.
                assert_eq!(r.transfer_bytes, r.prompt_tokens as u64 * layout);
                assert!(r.transfer_s > 0.0);
                assert_eq!(r.decode_instance, 0);
            }
            if let (Some(f), Some(c)) = (r.first_token_s, r.completion_s) {
                assert!(f >= r.arrival_s && c >= f, "causality violated: {r:?}");
            }
        }
        assert_eq!(o.kv_transfer_bytes, recs.iter().map(|r| r.transfer_bytes).sum::<u64>());
        assert!(o.transfer_overhead_share > 0.0 && o.transfer_overhead_share < 0.5);
        // Link telemetry: the fabric carried every migration.
        assert!(o.link_busy_frac > 0.0 && o.link_busy_frac <= 1.0);
    }

    #[test]
    fn link_congestion_queues_migrations_under_load() {
        // Starve the fabric down to one slow flow: concurrent migrations
        // must queue (wait_s > 0) and every exposed delay still lands in
        // the per-request records, keeping the timelines causal.
        let sys = WaferSystem::paper();
        let ds = DeepSeekConfig::v3_671b();
        let mut slow = ClusterConfig::disaggregated(1, 1, &ds);
        slow.transfer.parallel_flows = 1;
        slow.transfer.link_bandwidth_bytes_per_s = 2.0e9;
        let mut free = slow;
        free.transfer.parallel_flows = 64;
        let t = trace(400.0, 3.0, 17);
        let kernels = KernelCache::new();
        let stages = StageTimeCache::new();
        let (o_slow, recs) = simulate_cluster(&sys, &ds, &t, &slow, 3.0, 400.0, &kernels, &stages);
        let (o_free, _) = simulate_cluster(&sys, &ds, &t, &free, 3.0, 400.0, &kernels, &stages);
        assert!(o_slow.conserves_requests() && o_free.conserves_requests());
        assert!(o_slow.link_wait_s > 0.0, "a single congested flow must queue migrations");
        assert!(o_free.link_wait_s < o_slow.link_wait_s, "64 flows must absorb what 1 cannot");
        assert!(
            o_slow.kv_transfer_exposed_s > o_free.kv_transfer_exposed_s,
            "queueing must show up in the exposed handoff time: {} vs {}",
            o_slow.kv_transfer_exposed_s,
            o_free.kv_transfer_exposed_s
        );
        for r in &recs {
            if let Some(f) = r.first_token_s {
                assert!(f >= r.arrival_s + r.transfer_s, "first token beat the congested handoff: {r:?}");
            }
        }
    }

    #[test]
    fn cluster_simulation_is_deterministic() {
        let sys = WaferSystem::paper();
        let ds = DeepSeekConfig::v3_671b();
        let t = trace(100.0, 3.0, 11);
        let run = |mode: FleetMode| {
            let ccfg = ClusterConfig { mode, ..ClusterConfig::colocated(2, &ds) };
            simulate_cluster(
                &sys,
                &ds,
                &t,
                &ccfg,
                3.0,
                100.0,
                &KernelCache::new(),
                &StageTimeCache::new(),
            )
        };
        for mode in [FleetMode::Colocated { instances: 2 }, FleetMode::Disaggregated { prefill: 1, decode: 1 }] {
            let (a, ra) = run(mode);
            let (b, rb) = run(mode);
            assert_eq!(a, b, "{mode:?} must replay identically");
            assert_eq!(ra, rb);
        }
    }

    #[test]
    fn empty_fault_plan_is_the_unfaulted_simulator() {
        let sys = WaferSystem::paper();
        let ds = DeepSeekConfig::v3_671b();
        let t = trace(80.0, 3.0, 29);
        let kernels = KernelCache::new();
        let stages = StageTimeCache::new();
        for ccfg in [ClusterConfig::colocated(2, &ds), ClusterConfig::disaggregated(1, 1, &ds)] {
            let (o, recs) = simulate_cluster(&sys, &ds, &t, &ccfg, 3.0, 80.0, &kernels, &stages);
            let (fo, frecs, _) = simulate_cluster_faulted_observed(
                &sys,
                &ds,
                &t,
                &ccfg,
                &FaultPlan::none(),
                3.0,
                80.0,
                &kernels,
                &stages,
                None,
            );
            assert_eq!(o, fo, "the empty plan must be bit-identical to the no-fault path");
            assert_eq!(recs, frecs);
            assert_eq!(fo.faults, 0);
            assert_eq!(fo.requeued, 0);
            assert_eq!(fo.kv_lost_bytes, 0);
            assert!(recs.iter().all(|r| r.requeues == 0));
        }
    }

    #[test]
    fn colocated_kill_requeues_to_the_survivor_and_conserves() {
        let sys = WaferSystem::paper();
        let ds = DeepSeekConfig::v3_671b();
        let ccfg = ClusterConfig { routing: RoutingPolicy::RoundRobin, ..ClusterConfig::colocated(2, &ds) };
        let t = trace(80.0, 4.0, 31);
        let kernels = KernelCache::new();
        let stages = StageTimeCache::new();
        let plan = FaultPlan::none().kill(0, 1.5);
        let (o, recs, _) =
            simulate_cluster_faulted_observed(&sys, &ds, &t, &ccfg, &plan, 4.0, 80.0, &kernels, &stages, None);
        assert_eq!(o.faults, 1);
        assert!(o.requeued > 0, "a loaded instance dying mid-run must strand work: {o:?}");
        assert!(o.conserves_requests(), "{o:?}");
        assert_eq!(o.extracted_from_decode, 0, "a colocated fleet has no decode pool");
        assert!(o.completed > 0);
        assert_eq!(recs.iter().map(|r| r.requeues as usize).sum::<usize>(), o.requeued);
        for r in recs.iter().filter(|r| r.requeues > 0) {
            assert_eq!(r.prefill_instance, 1, "requeues must re-home to the survivor: {r:?}");
            if let (Some(f), Some(c)) = (r.first_token_s, r.completion_s) {
                assert!(f >= r.arrival_s && c >= f, "causality violated after requeue: {r:?}");
            }
        }
        assert!(
            recs.iter().any(|r| r.requeues > 0 && r.completion_s.is_some()),
            "under light load some requeued request must complete on the survivor"
        );
    }

    #[test]
    fn disaggregated_decode_kill_loses_kv_and_reships_on_requeue() {
        let sys = WaferSystem::paper();
        let ds = DeepSeekConfig::v3_671b();
        // Entry pool is gid 0; the two decode instances are gids 1 and 2.
        let ccfg = ClusterConfig::disaggregated(1, 2, &ds);
        let t = trace(80.0, 4.0, 7);
        let kernels = KernelCache::new();
        let stages = StageTimeCache::new();
        let plan = FaultPlan::none().kill(1, 1.5);
        let (o, recs, _) =
            simulate_cluster_faulted_observed(&sys, &ds, &t, &ccfg, &plan, 4.0, 80.0, &kernels, &stages, None);
        assert_eq!(o.faults, 1);
        assert!(o.conserves_requests(), "{o:?}");
        assert!(o.extracted_from_decode > 0, "the dead decode instance must strand landed KV");
        assert_eq!(
            o.extracted_from_decode,
            o.requeued + o.lost,
            "every decode extraction either requeues or falls past the horizon"
        );
        assert!(o.requeued > 0);
        assert!(o.kv_lost_bytes > 0, "landed and in-transit KV dies with decode HBM");
        assert!(o.completed > 0);
        let layout = KvTransferModel::layout_bytes_per_token(&ds, ccfg.serve.dtype);
        for r in recs.iter().filter(|r| r.requeues > 0) {
            // Transfer bytes accumulate one full latent-KV layout per
            // migration — never a fraction of one.
            assert_eq!(r.transfer_bytes % (r.prompt_tokens as u64 * layout), 0, "{r:?}");
        }
        assert!(
            recs.iter().any(|r| r.requeues > 0
                && r.transfer_bytes == 2 * r.prompt_tokens as u64 * layout
                && r.decode_instance == 1),
            "some victim must re-prefill and re-ship its KV to the surviving decode instance"
        );
    }

    #[test]
    fn drain_masks_new_work_but_finishes_residents() {
        let sys = WaferSystem::paper();
        let ds = DeepSeekConfig::v3_671b();
        let ccfg = ClusterConfig { routing: RoutingPolicy::RoundRobin, ..ClusterConfig::colocated(2, &ds) };
        let t = trace(60.0, 4.0, 37);
        let kernels = KernelCache::new();
        let stages = StageTimeCache::new();
        let plan = FaultPlan::none().drain(0, 1.0);
        let (o, recs, _) =
            simulate_cluster_faulted_observed(&sys, &ds, &t, &ccfg, &plan, 4.0, 60.0, &kernels, &stages, None);
        assert_eq!(o.faults, 1);
        assert_eq!(o.requeued, 0, "a drain must never strand work");
        assert_eq!(o.extracted_from_decode, 0);
        assert_eq!(o.kv_lost_bytes, 0);
        assert!(o.conserves_requests(), "{o:?}");
        // The drain snaps to the next epoch barrier (at most one ~1 ms
        // lookahead past 1.0 s); past a safety margin every arrival must
        // land on the survivor.
        assert!(recs.iter().any(|r| r.arrival_s > 1.05), "trace must outlive the drain");
        for r in recs.iter().filter(|r| r.arrival_s > 1.05) {
            assert_eq!(r.prefill_instance, 1, "drained instance took new work: {r:?}");
        }
        // Residents admitted before the drain still run to completion.
        assert!(
            recs.iter().any(|r| r.prefill_instance == 0 && r.completion_s.is_some()),
            "pre-drain residents of instance 0 must finish in place"
        );
    }

    #[test]
    fn restarted_instance_rejoins_and_takes_traffic_again() {
        let sys = WaferSystem::paper();
        let ds = DeepSeekConfig::v3_671b();
        let ccfg = ClusterConfig { routing: RoutingPolicy::RoundRobin, ..ClusterConfig::colocated(2, &ds) };
        let t = trace(60.0, 4.0, 41);
        let kernels = KernelCache::new();
        let stages = StageTimeCache::new();
        let run = |plan: &FaultPlan| {
            simulate_cluster_faulted_observed(&sys, &ds, &t, &ccfg, plan, 4.0, 60.0, &kernels, &stages, None)
        };
        let (o_stay, stay, _) = run(&FaultPlan::none().drain(0, 1.0));
        let (o_back, back, _) = run(&FaultPlan::none().drain(0, 1.0).with_restart(0.5));
        assert!(o_stay.conserves_requests() && o_back.conserves_requests());
        // A drain rejoins delay seconds after its barrier (no weight
        // reload); with round-robin it must take arrivals again well
        // before 4.0 s, while the plain drain never does.
        assert!(stay.iter().filter(|r| r.arrival_s > 1.6).all(|r| r.prefill_instance == 1));
        assert!(
            back.iter().any(|r| r.arrival_s > 1.6 && r.prefill_instance == 0),
            "restarted instance must rejoin the rotation"
        );
    }

    #[test]
    fn faulted_run_is_shard_invariant() {
        let sys = WaferSystem::paper();
        let ds = DeepSeekConfig::v3_671b();
        let t = trace(100.0, 3.0, 43);
        let kernels = KernelCache::new();
        let stages = StageTimeCache::new();
        // Mixed plan across both pools of a 2+2 disaggregated fleet: a
        // drained prefill instance, plus a killed-then-restarted decode
        // instance (gid 3 = decode 1) whose weight reload bills the link.
        let plan = FaultPlan::none().drain(0, 0.8).kill(3, 1.2).with_restart(0.4);
        let run = |shards: u32| {
            let ccfg = ClusterConfig { shards, ..ClusterConfig::disaggregated(2, 2, &ds) };
            let (mut o, recs, _) =
                simulate_cluster_faulted_observed(&sys, &ds, &t, &ccfg, &plan, 3.0, 100.0, &kernels, &stages, None);
            o.shards = 1;
            (o, recs)
        };
        let base = run(1);
        assert!(base.0.faults == 2 && base.0.conserves_requests(), "{:?}", base.0);
        for s in [2, 4] {
            assert_eq!(run(s), base, "shard count {s} must be bit-identical under faults");
        }
    }

    #[test]
    fn seeded_random_fault_plans_are_reproducible() {
        let a = FaultPlan::seeded_random(9, 4, 10.0, 6);
        let b = FaultPlan::seeded_random(9, 4, 10.0, 6);
        assert_eq!(a, b, "one (seed, instances, horizon, kills) tuple names one schedule");
        assert_eq!(a.events.len(), 6);
        for e in &a.events {
            assert!(e.instance < 4);
            assert!(e.at_s >= 0.0 && e.at_s < 10.0);
            assert_eq!(e.kind, FaultKind::Kill);
            assert!(e.restart_after_s.is_none());
        }
        let c = FaultPlan::seeded_random(10, 4, 10.0, 6);
        assert_ne!(a, c, "different seeds must draw different schedules");
        assert!(FaultPlan::seeded_random(9, 4, 10.0, 0).is_empty());
    }

    #[test]
    fn routing_policies_spread_load() {
        let sys = WaferSystem::paper();
        let ds = DeepSeekConfig::v3_671b();
        let t = trace(120.0, 3.0, 13);
        let kernels = KernelCache::new();
        let stages = StageTimeCache::new();
        for policy in [
            RoutingPolicy::RoundRobin,
            RoutingPolicy::LeastOutstanding,
            RoutingPolicy::LeastQueueDepth,
            RoutingPolicy::PrefixAffinity,
            RoutingPolicy::TopoAware,
        ] {
            let ccfg = ClusterConfig { routing: policy, ..ClusterConfig::colocated(3, &ds) };
            let (o, _) = simulate_cluster(&sys, &ds, &t, &ccfg, 3.0, 120.0, &kernels, &stages);
            assert!(o.conserves_requests(), "{policy:?}");
            assert_eq!(o.instances.len(), 3);
            let routed: Vec<usize> = o.instances.iter().map(|i| i.routed).collect();
            let total: usize = routed.iter().sum();
            assert_eq!(total, t.len());
            // No instance may be starved by a balancing policy.
            assert!(routed.iter().all(|&r| r > total / 10), "{policy:?}: skewed {routed:?}");
        }
    }

    #[test]
    fn fabric_topologies_bill_hops_and_conserve() {
        let sys = WaferSystem::paper();
        let ds = DeepSeekConfig::v3_671b();
        let t = trace(100.0, 3.0, 47);
        let kernels = KernelCache::new();
        let stages = StageTimeCache::new();
        let run = |topology: TopologySpec| {
            let ccfg = ClusterConfig { topology, ..ClusterConfig::disaggregated(2, 2, &ds) };
            simulate_cluster(&sys, &ds, &t, &ccfg, 3.0, 100.0, &kernels, &stages)
        };
        let (deg, deg_recs) = run(TopologySpec::Degenerate);
        assert!(deg.conserves_requests() && deg.migrated > 0);
        // Degenerate: every migration is one switch traversal, so
        // hop-bytes == bytes and fleet hops == migrations.
        assert_eq!(deg.fabric_hops, deg.migrated as u64);
        assert_eq!(deg.edge_busy_s.len(), 1);
        for r in &deg_recs {
            assert_eq!(r.transfer_hop_bytes, r.transfer_bytes);
        }
        for topology in [TopologySpec::Torus, TopologySpec::FatTree] {
            let (o, recs) = run(topology);
            assert!(o.conserves_requests(), "{topology:?}: {o:?}");
            assert!(o.migrated > 0 && o.completed > 0, "{topology:?}");
            // Routed topologies: prefill gids {0,1} never coincide with
            // decode gids {2,3}, so every migration crosses ≥ 1 edge and
            // hop-bytes are a whole multiple of bytes.
            assert!(o.fabric_hops >= o.migrated as u64, "{topology:?}");
            assert!(o.edge_busy_s.len() > 1, "{topology:?}");
            for r in recs.iter().filter(|r| r.transfer_bytes > 0) {
                assert!(r.transfer_hop_bytes >= r.transfer_bytes, "{topology:?}: {r:?}");
                assert_eq!(r.transfer_hop_bytes % r.transfer_bytes, 0, "{topology:?}: {r:?}");
            }
            // Conservation: Σ per-request hop-bytes / bandwidth equals the
            // fabric's summed per-edge serialization ledger (fault-free).
            let hop_bytes: u64 = recs.iter().map(|r| r.transfer_hop_bytes).sum();
            let expect = hop_bytes as f64 / ClusterConfig::colocated(1, &ds).transfer.link_bandwidth_bytes_per_s;
            let ledger: f64 = o.edge_busy_s.iter().sum();
            assert!(
                (ledger - expect).abs() <= 1e-9 * expect.max(1.0),
                "{topology:?}: ledger {ledger} vs hop-bytes {expect}"
            );
        }
    }

    #[test]
    fn shared_pool_interference_stretches_both_models() {
        // Two co-resident models on one shared instance, each saturating
        // enough to keep residents active: with chip-exclusive tick
        // serialization, each model's p50 TPOT must sit strictly above its
        // solo (sole-tenant) run on the identical trace and config — and
        // the combined pass must conserve requests per model.
        let sys = WaferSystem::paper();
        let big = DeepSeekConfig::v3_671b();
        let small = DeepSeekConfig::v3_16b();
        let kernels = KernelCache::new();
        let stages = StageTimeCache::new();
        let horizon = 2.0;
        let t_big = trace(60.0, horizon, 19);
        let t_small = trace(120.0, horizon, 23);
        let serve = ServeConfig::default();
        let specs = [
            SharedPoolSpec { ds: &big, trace: &t_big, serve, offered_rps: 60.0 },
            SharedPoolSpec { ds: &small, trace: &t_small, serve, offered_rps: 120.0 },
        ];
        let shared = simulate_shared_pool(
            &sys,
            &specs,
            1,
            RoutingPolicy::LeastQueueDepth,
            Router::DEFAULT_DRAIN_RATE,
            horizon,
            &kernels,
            &stages,
        );
        assert_eq!(shared.len(), 2);
        for (o, recs) in &shared {
            assert!(o.conserves_requests(), "{o:?}");
            assert!(o.completed > 0);
            assert_eq!(recs.len(), o.offered);
        }
        // Solo runs of the same traces on a private instance each.
        let solo = |ds: &DeepSeekConfig, t: &[Request]| {
            let ccfg = ClusterConfig::colocated(1, ds);
            simulate_cluster(&sys, ds, t, &ccfg, horizon, 0.0, &kernels, &stages).0
        };
        let solo_big = solo(&big, &t_big);
        let solo_small = solo(&small, &t_small);
        assert!(
            shared[0].0.tpot_ms.p50 > solo_big.tpot_ms.p50,
            "co-residency must stretch the 671B's cadence: {} vs solo {}",
            shared[0].0.tpot_ms.p50,
            solo_big.tpot_ms.p50
        );
        assert!(
            shared[1].0.tpot_ms.p50 > solo_small.tpot_ms.p50,
            "co-residency must stretch the 16B's cadence: {} vs solo {}",
            shared[1].0.tpot_ms.p50,
            solo_small.tpot_ms.p50
        );
        // Determinism of the interleaved shared pass.
        let replay = simulate_shared_pool(
            &sys,
            &specs,
            1,
            RoutingPolicy::LeastQueueDepth,
            Router::DEFAULT_DRAIN_RATE,
            horizon,
            &KernelCache::new(),
            &StageTimeCache::new(),
        );
        assert_eq!(replay[0].0, shared[0].0);
        assert_eq!(replay[1].0, shared[1].0);
    }

    #[test]
    fn tpot_crossover_detection() {
        let sys = WaferSystem::paper();
        let ds = DeepSeekConfig::v3_671b();
        let ccfg = ClusterConfig::colocated(1, &ds);
        let t = trace(10.0, 1.0, 5);
        let (base, _) = simulate_cluster(
            &sys,
            &ds,
            &t,
            &ccfg,
            1.0,
            10.0,
            &KernelCache::new(),
            &StageTimeCache::new(),
        );
        let mk = |rate: f64, p99: f64| {
            let mut o = base.clone();
            o.offered_rps = rate;
            o.completed = 5;
            o.tpot_ms.p99 = p99;
            o
        };
        let colo = vec![mk(100.0, 10.0), mk(400.0, 40.0), mk(1600.0, 90.0)];
        let disagg = vec![mk(100.0, 12.0), mk(400.0, 38.0), mk(1600.0, 45.0)];
        assert_eq!(tpot_crossover(&colo, &disagg), Some(400.0));
        assert_eq!(tpot_crossover(&colo[..1], &disagg[..1]), None);
        // Hardened pairing: unsorted curves and unequal lengths must not
        // mis-pair points by index. The shuffled disagg curve still crosses
        // at 400, and the colocated point with no 1600-rps partner is
        // skipped instead of being zipped against the wrong rate.
        let shuffled = vec![mk(1600.0, 45.0), mk(100.0, 12.0), mk(400.0, 38.0)];
        assert_eq!(tpot_crossover(&colo, &shuffled), Some(400.0));
        let short = vec![mk(400.0, 38.0), mk(100.0, 12.0)];
        assert_eq!(tpot_crossover(&colo, &short), Some(400.0));
        let disjoint = vec![mk(250.0, 1.0)];
        assert_eq!(tpot_crossover(&colo, &disjoint), None, "no shared rates → no crossover");
        // An index-zip would have paired colo[0] (100 rps) with shuffled[0]
        // (p99 45 < 90) and mis-reported the crossover at 100 rps.
        assert_ne!(tpot_crossover(&colo, &shuffled), Some(100.0));
    }
}
