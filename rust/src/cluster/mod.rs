//! Fleet-level cluster simulator: multiple wafer instances, disaggregated
//! prefill/decode pools, KV-transfer modeling and prefix-affinity routing.
//!
//! # Layering: `serve` vs `cluster`
//!
//! The [`serve`](crate::serve) layer answers "what does ONE wafer instance
//! do under a request stream" — continuous batching, KV admission, chunked
//! prefill billed by the real dataflow simulation. This module is the layer
//! above: a *fleet* of such instances behind a cluster router, which is
//! where the paper's end-to-end claims (§V-C) and the ROADMAP's "millions
//! of users" north star actually live. Nothing in `serve` knows about the
//! fleet; nothing here re-models what an instance already simulates — every
//! instance runs the unmodified `serve::sim` event loop against the shared
//! `StageTimeCache`/`KernelCache`, so fleet numbers inherit the dataflow
//! grounding.
//!
//! The cluster layer owns exactly three concerns:
//!
//! - [`router`] — which instance a request (or a KV handoff) lands on:
//!   round-robin, fluid least-outstanding-work, or prefix-affinity keyed on
//!   the per-instance `PrefixStore` fingerprints.
//! - [`transfer`] — what a prefill→decode migration costs: the MLA
//!   *latent*-KV layout bytes over an inter-instance link, partially
//!   overlappable with the prefill tail (layer streaming).
//! - [`fleet`] — the two-phase fleet simulation itself: colocated fleets,
//!   or prefill pools feeding decode pools whose iterations never carry
//!   chunked-prefill interference. Prefill is compute-bound and decode
//!   memory-bound (PAPERS.md, "Rethinking LLM Inference Bottlenecks"), so
//!   the split trades first-token transfer latency for interference-free
//!   decode cadence — the colocated-vs-disaggregated crossover the
//!   `cluster_pools` experiment sweeps.
//!
//! Entry points: `flatattention cluster` (CLI), experiment ids
//! `cluster_pools` and `cluster_models`, `examples/cluster.rs`,
//! `benches/cluster_pools.rs`.

pub mod fleet;
pub mod router;
pub mod transfer;

pub use fleet::{simulate_cluster, tpot_crossover, ClusterConfig, ClusterOutcome, ClusterRecord, FleetMode, InstanceSummary};
pub use router::{Router, RoutingPolicy};
pub use transfer::KvTransferModel;
