//! Fleet-level cluster simulator: multiple wafer instances advanced by a
//! sharded conservative-lookahead event engine, disaggregated
//! prefill/decode pools, congested KV-transfer modeling and live
//! (feedback-driven) routing.
//!
//! # Layering: `serve` vs `cluster`
//!
//! The [`serve`](crate::serve) layer answers "what does ONE wafer instance
//! do under a request stream" — and, since the engine refactor, exposes it
//! as a *steppable* unit: `serve::sim::ServeEngine` advances one iteration
//! per `step()`, accepts `inject()`ed arrivals mid-simulation and publishes
//! a live snapshot. This module is the layer above: a *fleet* of such
//! engines behind a cluster router, which is where the paper's end-to-end
//! claims (§V-C) and the ROADMAP's "millions of users" north star actually
//! live. Nothing here re-models what an instance already simulates — every
//! instance is an unmodified engine over the shared
//! `StageTimeCache`/`KernelCache`, so fleet numbers inherit the dataflow
//! grounding; and a 1-instance colocated fleet reproduces
//! `serve::sim::simulate` byte-identically (pinned by test).
//!
//! The cluster layer owns exactly four concerns:
//!
//! - **the global event order** ([`fleet`]): arrivals, KV handoffs and
//!   engine iterations advance in causal order under one shared comparator
//!   (time, then arrival < handoff < tick, then stable tie-breaks). Since
//!   the sharded-engine refactor the fleet no longer walks one interleaved
//!   clock: simulated time is cut into epochs of the KV link's base
//!   latency (the conservative *lookahead* — no cross-instance event can
//!   land sooner), engines advance through each epoch independently on a
//!   pool of shard workers (`ClusterConfig::shards`, `--threads` budget),
//!   and cluster events are exchanged at the epoch barriers. Any shard
//!   count is bit-identical to the serial loop — `shards = 1` runs the
//!   very same barrier code inline. The old two-phase (route →
//!   prefill-all → handoff → decode-all) mode is gone; its behavior for
//!   static policies falls out of the epoch loop as a special case.
//! - [`router`] — which instance a request (or a KV handoff) lands on:
//!   round-robin, fluid least-outstanding-work, prefix-affinity keyed on
//!   the per-instance `PrefixStore` fingerprints, *live* least-queue-depth
//!   reading each engine's snapshot at the decision time (and feeding the
//!   prefix-affinity spill guard — decode-side feedback), or *topo-aware*:
//!   live depth plus a hop penalty from the fabric, so prefill→decode
//!   placement prefers close, lightly-loaded decode instances.
//! - [`transfer`] + [`fabric`] — what a prefill→decode migration costs:
//!   the MLA *latent*-KV layout bytes ([`transfer`]) routed over an
//!   explicit inter-instance topology ([`fabric`]): a 2D-torus wafer mesh
//!   (dimension-ordered X-then-Y routing, shortest wraparound), a
//!   two-level fat-tree (up/down through a hashed spine), or the
//!   degenerate 1-switch pool — today's [`SharedLink`], field-identical.
//!   Every directed edge owns a 1-channel busy-until ledger, so a
//!   handoff's exposed latency is `hops × base latency + max per-edge
//!   queue wait + unhidden serialization` and hot edges (the prefill-pool
//!   boundary) genuinely congest. The sharded engine's lookahead is the
//!   minimum single-edge traversal latency over the fabric — numerically
//!   the link base latency for every topology, so the epoch ladder (and
//!   shard bit-identity) is unchanged. `flatattention report cluster`
//!   surfaces per-edge hotspots via the fleet lane's `edge_busy_frac`
//!   series column and the `fabric_hops` counter.
//! - **shared multi-model pools** ([`fleet::simulate_shared_pool`]): both
//!   co-resident models' engines interleave on one chip clock per
//!   instance, so cross-model tick interference is simulated rather than
//!   statically billed. Prefill is compute-bound and decode memory-bound
//!   (PAPERS.md, "Rethinking LLM Inference Bottlenecks"), so the
//!   disaggregation split trades first-token transfer latency for
//!   interference-free decode cadence — the colocated-vs-disaggregated
//!   crossover the `cluster_pools` experiment sweeps.
//!
//! A fifth, cross-cutting concern rides on the barrier engine: **fault
//! injection** ([`fleet::FaultPlan`]). Kill/drain/restart events snap to
//! the epoch barriers — the only instants cluster state may change — so a
//! fleet with a fault schedule stays bit-identical at every shard count.
//! Kills abort an instance and requeue its stranded work through the entry
//! router as fresh arrivals (lost KV re-billed end to end); drains mask
//! the instance and let residents finish; restarts rejoin after a delay,
//! with a killed instance's weight reload hop-routed over the fabric from
//! a surviving peer (bytes land in the per-edge ledgers like any handoff).
//!
//! Entry points: `flatattention cluster` (CLI, `--kill`/`--drain` fault
//! flags, `--topology`/`--routing topo-aware` fabric flags), experiment
//! ids `cluster_pools`, `cluster_models`, `cluster_dynamic`,
//! `cluster_failures` and `cluster_topology`, `examples/cluster.rs`,
//! `benches/cluster_pools.rs`.

pub mod fabric;
pub mod fleet;
pub mod router;
pub mod transfer;

pub use fabric::{Fabric, FabricXfer, TopologySpec};
pub use fleet::{
    co_resident_serve, simulate_cluster, simulate_cluster_faulted_observed, simulate_cluster_observed,
    simulate_cluster_profiled, simulate_shared_pool, tpot_crossover, ClusterConfig, ClusterOutcome,
    ClusterRecord, FaultEvent, FaultKind, FaultPlan, FleetMode, InstanceSummary, SharedPoolSpec,
};
pub use router::{LiveLoad, Router, RoutingPolicy};
pub use transfer::{KvTransferModel, SharedLink};
