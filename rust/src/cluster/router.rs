//! Cluster-level request routing across instances of one pool.
//!
//! Routing decisions are made at arrival (prefill / colocated routing) or
//! at KV-handoff time (decode routing) and are pure functions of the
//! arrival sequence, so a routed fleet simulation replays bit-exactly.
//!
//! Outstanding work is tracked with a fluid proxy: every instance drains
//! its backlog at a nominal `drain_rate` tokens/s and each routed request
//! deposits its token work. The proxy only shapes *balancing* — the actual
//! per-instance latencies come from the instances' own iteration-level
//! simulations — so any positive drain rate yields a sane policy; the
//! default is the order of one wafer instance's serving throughput.

use std::collections::HashMap;

use crate::serve::request::Request;
use crate::serve::scheduler::PrefixKeying;

/// Pluggable routing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutingPolicy {
    /// Cycle through the pool's instances in order.
    RoundRobin,
    /// Fluid least-outstanding-work: route to the instance with the least
    /// undrained token work (ties to the lowest index).
    LeastOutstanding,
    /// Prefix affinity: requests of one shared-prefix family stick to the
    /// instance whose `PrefixStore` fingerprints their blocks (first member
    /// placed least-outstanding); prefix-free requests fall back to
    /// least-outstanding. A 2× overload guard spills a family's traffic
    /// without re-homing the fingerprint.
    PrefixAffinity,
}

impl RoutingPolicy {
    pub fn label(self) -> &'static str {
        match self {
            RoutingPolicy::RoundRobin => "round-robin",
            RoutingPolicy::LeastOutstanding => "least-outstanding",
            RoutingPolicy::PrefixAffinity => "prefix-affinity",
        }
    }

    /// Parse a CLI policy name (case-insensitive).
    pub fn parse(s: &str) -> Option<RoutingPolicy> {
        match s.to_ascii_lowercase().as_str() {
            "roundrobin" | "round-robin" | "rr" => Some(RoutingPolicy::RoundRobin),
            "leastoutstanding" | "least-outstanding" | "low" => Some(RoutingPolicy::LeastOutstanding),
            "prefixaffinity" | "prefix-affinity" | "prefix" => Some(RoutingPolicy::PrefixAffinity),
            _ => None,
        }
    }
}

/// Deterministic router over one pool of `n` instances.
pub struct Router {
    policy: RoutingPolicy,
    /// Family-key mode — MUST match the instances' scheduler keying, or the
    /// affinity fingerprints and the per-instance `PrefixStore`s would
    /// disagree about which requests share blocks.
    keying: PrefixKeying,
    rr_next: usize,
    /// Fluid undrained token work per instance.
    outstanding: Vec<f64>,
    last_t: f64,
    drain_rate: f64,
    /// Prefix-family fingerprint → owning instance (mirrors which
    /// instance's `PrefixStore` holds the family's blocks).
    affinity: HashMap<u64, usize>,
}

impl Router {
    /// Nominal per-instance drain rate for the fluid backlog proxy
    /// (order of one wafer instance's serving throughput in tokens/s).
    pub const DEFAULT_DRAIN_RATE: f64 = 250_000.0;

    pub fn new(policy: RoutingPolicy, keying: PrefixKeying, n: usize, drain_rate: f64) -> Self {
        assert!(n >= 1, "a pool needs at least one instance");
        Router {
            policy,
            keying,
            rr_next: 0,
            outstanding: vec![0.0; n],
            last_t: 0.0,
            drain_rate: drain_rate.max(1.0),
            affinity: HashMap::new(),
        }
    }

    pub fn instances(&self) -> usize {
        self.outstanding.len()
    }

    /// Lightest current backlog (read-only; the affinity guard's yardstick).
    fn min_outstanding(&self) -> f64 {
        self.outstanding.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    /// Pick the least-loaded instance, breaking (near-)ties by rotating
    /// preference — otherwise a fully-drained fleet would funnel every
    /// light-load arrival to instance 0.
    fn least_outstanding(&mut self) -> usize {
        let n = self.outstanding.len();
        let start = self.rr_next;
        let mut best = start;
        for k in 1..n {
            let i = (start + k) % n;
            if self.outstanding[i] + 1e-9 < self.outstanding[best] {
                best = i;
            }
        }
        self.rr_next = (best + 1) % n;
        best
    }

    /// Route a request arriving at time `t` carrying `work_tokens` of
    /// future work (prompt tokens for a prefill pool, output tokens for a
    /// decode pool). Returns the chosen instance index.
    pub fn route(&mut self, r: &Request, t: f64, work_tokens: f64) -> usize {
        // Fluid drain since the previous decision.
        let dt = (t - self.last_t).max(0.0);
        self.last_t = self.last_t.max(t);
        for w in &mut self.outstanding {
            *w = (*w - dt * self.drain_rate).max(0.0);
        }
        let i = match self.policy {
            RoutingPolicy::RoundRobin => {
                let i = self.rr_next;
                self.rr_next = (self.rr_next + 1) % self.outstanding.len();
                i
            }
            RoutingPolicy::LeastOutstanding => self.least_outstanding(),
            RoutingPolicy::PrefixAffinity => {
                let key = self.keying.key_of(r);
                if key == 0 {
                    self.least_outstanding()
                } else {
                    match self.affinity.get(&key) {
                        Some(&home) => {
                            // Overload guard: spill (this request only, the
                            // fingerprint stays home) once affinity would
                            // cost more than ~1 s of extra backlog over the
                            // lightest instance.
                            let light = self.min_outstanding();
                            if self.outstanding[home] > 2.0 * light + self.drain_rate {
                                self.least_outstanding()
                            } else {
                                home
                            }
                        }
                        None => {
                            let home = self.least_outstanding();
                            self.affinity.insert(key, home);
                            home
                        }
                    }
                }
            }
        };
        self.outstanding[i] += work_tokens.max(0.0);
        i
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plain(id: u64, t: f64) -> Request {
        Request::new(id, t, 512, 64)
    }

    fn fam(id: u64, t: f64, family: u64) -> Request {
        Request { prefix_id: family, prefix_tokens: 256, prefix_hash: family.wrapping_mul(0x9E37) | 1, ..plain(id, t) }
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(RoutingPolicy::RoundRobin, PrefixKeying::TokenHash, 3, Router::DEFAULT_DRAIN_RATE);
        let picks: Vec<usize> = (0..6).map(|i| r.route(&plain(i, 0.0), 0.0, 100.0)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_outstanding_balances_uneven_work() {
        let mut r = Router::new(RoutingPolicy::LeastOutstanding, PrefixKeying::TokenHash, 2, 1000.0);
        assert_eq!(r.route(&plain(0, 0.0), 0.0, 10_000.0), 0);
        // Instance 0 carries 10k tokens; light requests land on instance 1
        // until its backlog catches up, then balancing resumes.
        assert_eq!(r.route(&plain(1, 0.0), 0.0, 4_000.0), 1);
        assert_eq!(r.route(&plain(2, 0.0), 0.0, 4_000.0), 1);
        assert_eq!(r.route(&plain(3, 0.0), 0.0, 4_000.0), 1);
        assert_eq!(r.route(&plain(4, 0.0), 0.0, 4_000.0), 0);
    }

    #[test]
    fn fluid_drain_forgets_old_work() {
        let mut r = Router::new(RoutingPolicy::LeastOutstanding, PrefixKeying::TokenHash, 2, 1000.0);
        assert_eq!(r.route(&plain(0, 0.0), 0.0, 50_000.0), 0);
        // The heavy backlog on 0 steers the next arrivals away …
        assert_eq!(r.route(&plain(1, 0.1), 0.1, 100.0), 1);
        assert_eq!(r.route(&plain(2, 0.2), 0.2, 100.0), 1);
        // … but a minute later it has fully drained and rotation resumes.
        assert_eq!(r.route(&plain(3, 60.0), 60.0, 100.0), 0);
    }

    #[test]
    fn prefix_affinity_keeps_families_together() {
        // All arrivals at t = 0 so the fluid drain stays out of the picture.
        let mut r = Router::new(RoutingPolicy::PrefixAffinity, PrefixKeying::TokenHash, 4, Router::DEFAULT_DRAIN_RATE);
        let a0 = r.route(&fam(0, 0.0, 5), 0.0, 500.0);
        let b0 = r.route(&fam(1, 0.0, 9), 0.0, 500.0);
        assert_ne!(a0, b0, "second family homes on a lighter instance");
        for i in 2..10 {
            let fam5 = r.route(&fam(i, 0.0, 5), 0.0, 500.0);
            assert_eq!(fam5, a0, "family 5 must stick to its home");
        }
        // Prefix-free traffic still spreads to an idle instance.
        let free = r.route(&plain(99, 0.0), 0.0, 500.0);
        assert!(free != a0, "least-outstanding fallback avoids the loaded home");
    }

    #[test]
    fn prefix_affinity_spills_under_overload() {
        let mut r = Router::new(RoutingPolicy::PrefixAffinity, PrefixKeying::TokenHash, 2, 1000.0);
        let home = r.route(&fam(0, 0.0, 7), 0.0, 1_000.0);
        // Pile family work onto the home until the imbalance guard
        // (2× lightest + 1 s of drain) trips.
        let mut spilled = false;
        for i in 1..20 {
            spilled |= r.route(&fam(i, 0.0, 7), 0.0, 1_000.0) != home;
        }
        assert!(spilled, "a hot family must eventually spill");
    }

    #[test]
    fn affinity_keying_matches_scheduler_semantics() {
        // Two families with identical content hashes: under TokenHash they
        // are ONE family (one home — the instance whose PrefixStore will
        // hold the shared blocks); under ExactId they are distinct families
        // with distinct homes, mirroring the scheduler's block keying.
        let mk = |id: u64, family: u64| Request {
            prefix_id: family,
            prefix_tokens: 256,
            prefix_hash: 0xFEED,
            ..plain(id, 0.0)
        };
        let mut hashed = Router::new(RoutingPolicy::PrefixAffinity, PrefixKeying::TokenHash, 4, 1e9);
        let h3 = hashed.route(&mk(0, 3), 0.0, 500.0);
        let h9 = hashed.route(&mk(1, 9), 0.0, 500.0);
        assert_eq!(h3, h9, "shared content must share a home under TokenHash");
        let mut exact = Router::new(RoutingPolicy::PrefixAffinity, PrefixKeying::ExactId, 4, 1e9);
        let e3 = exact.route(&mk(0, 3), 0.0, 500.0);
        let e9 = exact.route(&mk(1, 9), 0.0, 500.0);
        assert_ne!(e3, e9, "distinct ids must home separately under ExactId");
    }

    #[test]
    fn routing_policy_parse_roundtrip() {
        for p in [RoutingPolicy::RoundRobin, RoutingPolicy::LeastOutstanding, RoutingPolicy::PrefixAffinity] {
            assert_eq!(RoutingPolicy::parse(p.label()), Some(p));
        }
        assert_eq!(RoutingPolicy::parse("RR"), Some(RoutingPolicy::RoundRobin));
        assert_eq!(RoutingPolicy::parse("nope"), None);
    }
}
