//! Cluster-level request routing across instances of one pool.
//!
//! Routing decisions are made at arrival (prefill / colocated routing) or
//! at KV-handoff time (decode routing). Two kinds of state feed them:
//!
//! - a **fluid proxy** of outstanding work: every instance drains its
//!   backlog at a nominal `drain_rate` tokens/s and each routed request
//!   deposits its token work. Decisions driven only by the proxy are pure
//!   functions of the arrival sequence ("static" routing — what a
//!   two-phase fleet simulation could already do);
//! - **live instance state** ([`LiveLoad`], sampled from each instance's
//!   `ServeEngine` snapshot by the interleaved fleet): actual queue depth,
//!   resident users and KV occupancy *at the decision time*. The
//!   [`RoutingPolicy::LeastQueueDepth`] policy and the prefix-affinity
//!   spill guard consume it — this is the decode-side feedback loop a
//!   single-clock fleet simulation makes possible.
//!
//! Either way the router is deterministic: a routed fleet simulation
//! replays bit-exactly. The router also counts affinity spill events, and
//! together with the per-instance routed/backlog numbers each engine
//! already reports (`InstanceSummary`), routing experiments are
//! explainable from the `ClusterOutcome` alone.
//!
//! # Epoch-start snapshot contract (sharded fleet)
//!
//! Live loads are sampled at epoch *barriers*, never mid-phase: every
//! routed event resolves against the pool snapshot frozen at the start of
//! the epoch it lands in. This is what keeps the sharded fleet engine
//! bit-identical at every shard count — a load reading can never depend on
//! how far some other shard happened to have advanced. The serial
//! (`--shards 1`) path goes through the same barrier code, so the contract
//! holds there too by construction.

use std::collections::HashMap;

use crate::serve::request::Request;
use crate::serve::scheduler::PrefixKeying;

/// Pluggable routing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutingPolicy {
    /// Cycle through the pool's instances in order.
    RoundRobin,
    /// Fluid least-outstanding-work: route to the instance with the least
    /// undrained token work (ties to the lowest index). Static — sees only
    /// its own deposits, never the instances' actual progress.
    LeastOutstanding,
    /// Live least-queue-depth: route to the instance whose *actual*
    /// queued + resident request count is lowest at the decision time
    /// (rotating tie-break). Requires live loads; falls back to the fluid
    /// proxy when none are supplied.
    LeastQueueDepth,
    /// Prefix affinity: requests of one shared-prefix family stick to the
    /// instance whose `PrefixStore` fingerprints their blocks (first member
    /// placed least-outstanding); prefix-free requests fall back to
    /// least-outstanding. An overload guard spills a family's traffic
    /// without re-homing the fingerprint — against live queue depths when
    /// available (decode-side feedback), else against the fluid proxy.
    PrefixAffinity,
    /// Topology-aware live routing: rank instances by live depth *plus* a
    /// fabric hop penalty ([`Router::HOP_PENALTY`] queue slots per hop
    /// from the transfer source), so prefill→decode placement prefers
    /// close, lightly-loaded instances — fewer edges crossed means less
    /// fabric occupancy injected AND a shorter exposed handoff. Without a
    /// hop signal ([`Router::route_live`], arrival-side use) it is exactly
    /// live least-queue-depth; without live state, the fluid proxy.
    TopoAware,
}

impl RoutingPolicy {
    pub fn label(self) -> &'static str {
        match self {
            RoutingPolicy::RoundRobin => "round-robin",
            RoutingPolicy::LeastOutstanding => "least-outstanding",
            RoutingPolicy::LeastQueueDepth => "least-queue-depth",
            RoutingPolicy::PrefixAffinity => "prefix-affinity",
            RoutingPolicy::TopoAware => "topo-aware",
        }
    }

    /// Parse a CLI policy name (case-insensitive).
    pub fn parse(s: &str) -> Option<RoutingPolicy> {
        match s.to_ascii_lowercase().as_str() {
            "roundrobin" | "round-robin" | "rr" => Some(RoutingPolicy::RoundRobin),
            "leastoutstanding" | "least-outstanding" | "low" => Some(RoutingPolicy::LeastOutstanding),
            "leastqueuedepth" | "least-queue-depth" | "queue-depth" | "lqd" => {
                Some(RoutingPolicy::LeastQueueDepth)
            }
            "prefixaffinity" | "prefix-affinity" | "prefix" => Some(RoutingPolicy::PrefixAffinity),
            "topoaware" | "topo-aware" | "topo" => Some(RoutingPolicy::TopoAware),
            _ => None,
        }
    }

    /// True when decisions under this policy read live instance state —
    /// the fleet skips sampling every engine's snapshot for the static
    /// policies (round-robin, fluid least-outstanding), which would
    /// otherwise scan all columns of all instances per arrival for a
    /// value the router discards.
    pub fn uses_live_state(self) -> bool {
        matches!(
            self,
            RoutingPolicy::LeastQueueDepth | RoutingPolicy::PrefixAffinity | RoutingPolicy::TopoAware
        )
    }
}

/// Live state of one instance at a routing decision, sampled from its
/// `ServeEngine` snapshot by the interleaved fleet. Deliberately minimal:
/// exactly the fields a policy reads (KV-pressure-aware placement is a
/// ROADMAP follow-up, not carried state).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LiveLoad {
    /// Requests waiting in the instance's scheduler queue.
    pub queued: usize,
    /// Requests resident in (column, wave) cells.
    pub active: usize,
}

impl LiveLoad {
    /// Total requests the instance currently holds — the congestion signal
    /// live policies rank on.
    pub fn depth(&self) -> usize {
        self.queued + self.active
    }

    /// Sample one engine's snapshot into the routing view.
    pub fn of(s: &crate::serve::sim::EngineSnapshot) -> LiveLoad {
        LiveLoad { queued: s.queue_depth, active: s.active_users }
    }
}

/// Deterministic router over one pool of `n` instances.
pub struct Router {
    policy: RoutingPolicy,
    /// Family-key mode — MUST match the instances' scheduler keying, or the
    /// affinity fingerprints and the per-instance `PrefixStore`s would
    /// disagree about which requests share blocks.
    keying: PrefixKeying,
    rr_next: usize,
    /// Fluid undrained token work per instance.
    outstanding: Vec<f64>,
    /// Routability mask — `false` for draining or dead instances. Every
    /// selection path skips masked instances; the fleet's fault injection
    /// flips entries via [`Router::set_up`].
    up: Vec<bool>,
    last_t: f64,
    drain_rate: f64,
    /// Prefix-family fingerprint → owning instance (mirrors which
    /// instance's `PrefixStore` holds the family's blocks).
    affinity: HashMap<u64, usize>,
    /// Affinity-overload spill events (telemetry): requests steered away
    /// from their family's home instance. Per-instance routed counts are
    /// NOT duplicated here — each instance's engine already knows exactly
    /// what was injected into it (`InstanceSummary::routed`).
    spills: u64,
}

impl Router {
    /// Nominal per-instance drain rate for the fluid backlog proxy
    /// (order of one wafer instance's serving throughput in tokens/s).
    pub const DEFAULT_DRAIN_RATE: f64 = 250_000.0;

    /// Live-depth slack of the affinity spill guard: the home instance must
    /// hold at least this many more requests than twice the lightest before
    /// a family spills (keeps tiny imbalances from shredding affinity).
    pub const SPILL_DEPTH_SLACK: usize = 16;

    /// Queue slots one fabric hop is worth to [`RoutingPolicy::TopoAware`]:
    /// an instance one hop closer wins unless it is this many requests
    /// deeper. One slot per hop biases placement toward the near side of
    /// the fabric without overriding real congestion signals — the queue
    /// term still dominates once imbalance exceeds the fleet diameter.
    pub const HOP_PENALTY: f64 = 1.0;

    pub fn new(policy: RoutingPolicy, keying: PrefixKeying, n: usize, drain_rate: f64) -> Self {
        assert!(n >= 1, "a pool needs at least one instance");
        Router {
            policy,
            keying,
            rr_next: 0,
            outstanding: vec![0.0; n],
            up: vec![true; n],
            last_t: 0.0,
            drain_rate: drain_rate.max(1.0),
            affinity: HashMap::new(),
            spills: 0,
        }
    }

    pub fn instances(&self) -> usize {
        self.outstanding.len()
    }

    /// Affinity-overload spill events so far.
    pub fn spill_events(&self) -> u64 {
        self.spills
    }

    /// Mark instance `i` routable (`true`) or unroutable (`false` — it is
    /// draining or dead). Masking down also forgets the instance's fluid
    /// backlog: its extracted work is redeposited wherever the fleet
    /// requeues it, and a later restart joins with an empty ledger.
    pub fn set_up(&mut self, i: usize, up: bool) {
        assert!(i < self.up.len(), "instance {i} outside the pool");
        self.up[i] = up;
        if !up {
            self.outstanding[i] = 0.0;
        }
    }

    /// Is instance `i` currently routable?
    pub fn is_up(&self, i: usize) -> bool {
        self.up[i]
    }

    /// Routable instances remaining.
    pub fn up_instances(&self) -> usize {
        self.up.iter().filter(|&&u| u).count()
    }

    /// Live depth of instance `i` as the selection loops see it: `None`
    /// when the instance is masked down **or** the live slice has no entry
    /// for it. The slice-length contract ("one `LiveLoad` per instance")
    /// is thus enforced structurally — a short slice makes the uncovered
    /// instances unroutable-by-live-signal instead of an out-of-bounds
    /// panic, and the caller degrades to the fluid proxy if nothing is
    /// covered at all.
    fn live_depth(&self, live: &[LiveLoad], i: usize) -> Option<usize> {
        if !self.up[i] {
            return None;
        }
        live.get(i).map(LiveLoad::depth)
    }

    /// Lightest current backlog among up instances (read-only; the affinity
    /// guard's yardstick).
    fn min_outstanding(&self) -> f64 {
        self.outstanding
            .iter()
            .zip(&self.up)
            .filter(|&(_, &u)| u)
            .map(|(&w, _)| w)
            .fold(f64::INFINITY, f64::min)
    }

    /// Pick the least-loaded up instance, breaking (near-)ties by rotating
    /// preference — otherwise a fully-drained fleet would funnel every
    /// light-load arrival to instance 0. With every instance masked down
    /// (a fully faulted pool) it degrades to the rotation slot rather than
    /// panicking; the fleet accounts such requests as lost either way.
    fn least_outstanding(&mut self) -> usize {
        let n = self.outstanding.len();
        let start = self.rr_next;
        let mut best: Option<usize> = None;
        for k in 0..n {
            let i = (start + k) % n;
            if !self.up[i] {
                continue;
            }
            match best {
                None => best = Some(i),
                Some(b) => {
                    if self.outstanding[i] + 1e-9 < self.outstanding[b] {
                        best = Some(i);
                    }
                }
            }
        }
        let i = best.unwrap_or(start);
        self.rr_next = (i + 1) % n;
        i
    }

    /// Pick the up instance with the lowest live queue depth (rotating
    /// tie-break, mirroring [`Router::least_outstanding`]). Instances the
    /// live slice does not cover are skipped; if it covers none, the fluid
    /// proxy decides.
    fn least_depth(&mut self, live: &[LiveLoad]) -> usize {
        let n = self.outstanding.len();
        let start = self.rr_next;
        let mut best: Option<(usize, usize)> = None; // (depth, instance)
        for k in 0..n {
            let i = (start + k) % n;
            let Some(d) = self.live_depth(live, i) else { continue };
            if best.map_or(true, |(bd, _)| d < bd) {
                best = Some((d, i));
            }
        }
        match best {
            Some((_, i)) => {
                self.rr_next = (i + 1) % n;
                i
            }
            None => self.least_outstanding(),
        }
    }

    /// Pick the up instance minimizing live depth + hop penalty (rotating
    /// near-tie-break, mirroring [`Router::least_depth`] — which this IS
    /// whenever no hop signal is supplied). Instances the live slice does
    /// not cover are skipped; uncovered hop entries cost nothing.
    fn topo_aware(&mut self, live: &[LiveLoad], hops: Option<&[u64]>) -> usize {
        let Some(hops) = hops else { return self.least_depth(live) };
        let n = self.outstanding.len();
        let start = self.rr_next;
        let mut best: Option<(f64, usize)> = None; // (cost, instance)
        for k in 0..n {
            let i = (start + k) % n;
            let Some(d) = self.live_depth(live, i) else { continue };
            let cost = d as f64 + Self::HOP_PENALTY * hops.get(i).copied().unwrap_or(0) as f64;
            if best.map_or(true, |(bc, _)| cost + 1e-9 < bc) {
                best = Some((cost, i));
            }
        }
        match best {
            Some((_, i)) => {
                self.rr_next = (i + 1) % n;
                i
            }
            None => self.least_outstanding(),
        }
    }

    /// True when routing to the family home would pile onto a visibly
    /// overloaded instance. With live state: the home holds more than twice
    /// the lightest up instance's requests plus a slack. Without: the fluid
    /// proxy's ~1 s-of-backlog rule. A home the live slice does not cover
    /// counts as overloaded — with no signal, spilling to a covered
    /// instance is the safe move.
    fn home_overloaded(&self, home: usize, live: Option<&[LiveLoad]>) -> bool {
        match live {
            Some(l) => {
                let lightest = (0..self.up.len())
                    .filter_map(|i| self.live_depth(l, i))
                    .min()
                    .unwrap_or(0);
                l.get(home).map_or(true, |h| h.depth() > 2 * lightest + Self::SPILL_DEPTH_SLACK)
            }
            None => {
                let light = self.min_outstanding();
                self.outstanding[home] > 2.0 * light + self.drain_rate
            }
        }
    }

    /// Route a request arriving at time `t` carrying `work_tokens` of
    /// future work (prompt tokens for a prefill pool, output tokens for a
    /// decode pool), without live state — static policies only (live
    /// policies degrade to their fluid fallback).
    pub fn route(&mut self, r: &Request, t: f64, work_tokens: f64) -> usize {
        self.route_live(r, t, work_tokens, None)
    }

    /// [`Router::route`] with the pool's live instance state at time `t`.
    /// The interleaved fleet samples every instance's engine snapshot at
    /// each decision, so live policies see actual queues — including decode
    /// backlog, which a static arrival-sequence router can never observe.
    pub fn route_live(
        &mut self,
        r: &Request,
        t: f64,
        work_tokens: f64,
        live: Option<&[LiveLoad]>,
    ) -> usize {
        self.route_with_hops(r, t, work_tokens, live, None)
    }

    /// [`Router::route_live`] with a fabric distance signal: `hops[i]` is
    /// the hop count from the transfer's source instance to pool member
    /// `i`, as [`crate::cluster::Fabric::hops`] reports it. Only
    /// [`RoutingPolicy::TopoAware`] reads it; every other policy behaves
    /// exactly as [`Router::route_live`] — which delegates here with no
    /// signal, so the two entry points cannot drift.
    pub fn route_with_hops(
        &mut self,
        r: &Request,
        t: f64,
        work_tokens: f64,
        live: Option<&[LiveLoad]>,
        hops: Option<&[u64]>,
    ) -> usize {
        // Fluid drain since the previous decision.
        let dt = (t - self.last_t).max(0.0);
        self.last_t = self.last_t.max(t);
        for w in &mut self.outstanding {
            *w = (*w - dt * self.drain_rate).max(0.0);
        }
        let i = match self.policy {
            RoutingPolicy::RoundRobin => {
                // Cycle to the next up instance (identical to the plain
                // rotation while the whole pool is up).
                let n = self.outstanding.len();
                let mut i = self.rr_next;
                for k in 0..n {
                    let c = (self.rr_next + k) % n;
                    if self.up[c] {
                        i = c;
                        break;
                    }
                }
                self.rr_next = (i + 1) % n;
                i
            }
            RoutingPolicy::LeastOutstanding => self.least_outstanding(),
            RoutingPolicy::LeastQueueDepth => match live {
                Some(l) => self.least_depth(l),
                None => self.least_outstanding(),
            },
            RoutingPolicy::TopoAware => match live {
                Some(l) => self.topo_aware(l, hops),
                None => self.least_outstanding(),
            },
            RoutingPolicy::PrefixAffinity => {
                let key = self.keying.key_of(r);
                if key == 0 {
                    self.least_outstanding()
                } else {
                    match self.affinity.get(&key) {
                        Some(&home) if self.up[home] => {
                            // Overload guard: spill (this request only, the
                            // fingerprint stays home) once affinity would
                            // visibly overload the home instance.
                            if self.home_overloaded(home, live) {
                                self.spills += 1;
                                match live {
                                    Some(l) => self.least_depth(l),
                                    None => self.least_outstanding(),
                                }
                            } else {
                                home
                            }
                        }
                        // Unknown family — or its home is draining/dead,
                        // whose blocks are gone (or going) with it: the
                        // fingerprint re-homes permanently on the least
                        // loaded up instance, where the family's blocks
                        // will be re-prefilled.
                        _ => {
                            let home = self.least_outstanding();
                            self.affinity.insert(key, home);
                            home
                        }
                    }
                }
            }
        };
        self.outstanding[i] += work_tokens.max(0.0);
        i
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plain(id: u64, t: f64) -> Request {
        Request::new(id, t, 512, 64)
    }

    fn fam(id: u64, t: f64, family: u64) -> Request {
        Request { prefix_id: family, prefix_tokens: 256, prefix_hash: family.wrapping_mul(0x9E37) | 1, ..plain(id, t) }
    }

    fn load(queued: usize, active: usize) -> LiveLoad {
        LiveLoad { queued, active }
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(RoutingPolicy::RoundRobin, PrefixKeying::TokenHash, 3, Router::DEFAULT_DRAIN_RATE);
        let picks: Vec<usize> = (0..6).map(|i| r.route(&plain(i, 0.0), 0.0, 100.0)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
        assert_eq!(r.spill_events(), 0);
    }

    #[test]
    fn least_outstanding_balances_uneven_work() {
        let mut r = Router::new(RoutingPolicy::LeastOutstanding, PrefixKeying::TokenHash, 2, 1000.0);
        assert_eq!(r.route(&plain(0, 0.0), 0.0, 10_000.0), 0);
        // Instance 0 carries 10k tokens; light requests land on instance 1
        // until its backlog catches up, then balancing resumes.
        assert_eq!(r.route(&plain(1, 0.0), 0.0, 4_000.0), 1);
        assert_eq!(r.route(&plain(2, 0.0), 0.0, 4_000.0), 1);
        assert_eq!(r.route(&plain(3, 0.0), 0.0, 4_000.0), 1);
        assert_eq!(r.route(&plain(4, 0.0), 0.0, 4_000.0), 0);
    }

    #[test]
    fn fluid_drain_forgets_old_work() {
        let mut r = Router::new(RoutingPolicy::LeastOutstanding, PrefixKeying::TokenHash, 2, 1000.0);
        assert_eq!(r.route(&plain(0, 0.0), 0.0, 50_000.0), 0);
        // The heavy backlog on 0 steers the next arrivals away …
        assert_eq!(r.route(&plain(1, 0.1), 0.1, 100.0), 1);
        assert_eq!(r.route(&plain(2, 0.2), 0.2, 100.0), 1);
        // … but a minute later it has fully drained and rotation resumes.
        assert_eq!(r.route(&plain(3, 60.0), 60.0, 100.0), 0);
    }

    #[test]
    fn least_queue_depth_follows_live_state_not_deposits() {
        let mut r = Router::new(RoutingPolicy::LeastQueueDepth, PrefixKeying::TokenHash, 3, 1000.0);
        // The fluid proxy believes instance 0 is buried (huge deposit), but
        // live state says instance 0 is actually the emptiest — live wins.
        assert_eq!(r.route_live(&plain(0, 0.0), 0.0, 1e9, Some(&[load(0, 0), load(5, 5), load(9, 1)])), 0);
        // And when live state flips, so does the decision.
        assert_eq!(r.route_live(&plain(1, 0.0), 0.0, 100.0, Some(&[load(50, 0), load(0, 1), load(9, 1)])), 1);
        // Rotating tie-break: equal depths spread instead of funneling.
        let picks: Vec<usize> =
            (2..8).map(|i| r.route_live(&plain(i, 0.0), 0.0, 0.0, Some(&[load(1, 1), load(1, 1), load(1, 1)]))).collect();
        let mut sorted = picks.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 3, "ties must rotate across all instances: {picks:?}");
        // Without live state the policy degrades to the fluid proxy rather
        // than panicking (instance 0 still carries the 1e9 deposit).
        assert_ne!(r.route(&plain(99, 0.0), 0.0, 100.0), 0);
    }

    #[test]
    fn prefix_affinity_keeps_families_together() {
        // All arrivals at t = 0 so the fluid drain stays out of the picture.
        let mut r = Router::new(RoutingPolicy::PrefixAffinity, PrefixKeying::TokenHash, 4, Router::DEFAULT_DRAIN_RATE);
        let a0 = r.route(&fam(0, 0.0, 5), 0.0, 500.0);
        let b0 = r.route(&fam(1, 0.0, 9), 0.0, 500.0);
        assert_ne!(a0, b0, "second family homes on a lighter instance");
        for i in 2..10 {
            let fam5 = r.route(&fam(i, 0.0, 5), 0.0, 500.0);
            assert_eq!(fam5, a0, "family 5 must stick to its home");
        }
        // Prefix-free traffic still spreads to an idle instance.
        let free = r.route(&plain(99, 0.0), 0.0, 500.0);
        assert!(free != a0, "least-outstanding fallback avoids the loaded home");
    }

    #[test]
    fn prefix_affinity_spills_under_overload() {
        let mut r = Router::new(RoutingPolicy::PrefixAffinity, PrefixKeying::TokenHash, 2, 1000.0);
        let home = r.route(&fam(0, 0.0, 7), 0.0, 1_000.0);
        // Pile family work onto the home until the imbalance guard
        // (2× lightest + 1 s of drain) trips.
        let mut spilled = false;
        for i in 1..20 {
            spilled |= r.route(&fam(i, 0.0, 7), 0.0, 1_000.0) != home;
        }
        assert!(spilled, "a hot family must eventually spill");
        assert!(r.spill_events() > 0, "spills must be counted");
    }

    #[test]
    fn prefix_affinity_spills_on_live_depth_feedback() {
        // The fluid proxy sees a healthy home (tiny deposits), but the live
        // snapshot shows the home drowning in decode backlog — exactly what
        // a static arrival-sequence router cannot observe. The guard must
        // spill on the live signal, to the live-lightest instance.
        let mut r = Router::new(RoutingPolicy::PrefixAffinity, PrefixKeying::TokenHash, 3, 1e9);
        let idle = [load(0, 0), load(0, 0), load(0, 0)];
        let home = r.route_live(&fam(0, 0.0, 7), 0.0, 10.0, Some(&idle));
        let mut drowned = idle;
        drowned[home] = load(100, 40);
        let away = r.route_live(&fam(1, 0.0, 7), 0.0, 10.0, Some(&drowned));
        assert_ne!(away, home, "live overload must spill the family");
        assert_eq!(r.spill_events(), 1);
        // Recovery: once the home drains, affinity resumes (fingerprint
        // never re-homed).
        let healed = r.route_live(&fam(2, 0.0, 7), 0.0, 10.0, Some(&idle));
        assert_eq!(healed, home, "the fingerprint stays home across a spill");
        // Below the slack the guard holds even with mild imbalance.
        let mut mild = idle;
        mild[home] = load(Router::SPILL_DEPTH_SLACK, 0);
        assert_eq!(r.route_live(&fam(3, 0.0, 7), 0.0, 10.0, Some(&mild)), home);
    }

    #[test]
    fn affinity_keying_matches_scheduler_semantics() {
        // Two families with identical content hashes: under TokenHash they
        // are ONE family (one home — the instance whose PrefixStore will
        // hold the shared blocks); under ExactId they are distinct families
        // with distinct homes, mirroring the scheduler's block keying.
        let mk = |id: u64, family: u64| Request {
            prefix_id: family,
            prefix_tokens: 256,
            prefix_hash: 0xFEED,
            ..plain(id, 0.0)
        };
        let mut hashed = Router::new(RoutingPolicy::PrefixAffinity, PrefixKeying::TokenHash, 4, 1e9);
        let h3 = hashed.route(&mk(0, 3), 0.0, 500.0);
        let h9 = hashed.route(&mk(1, 9), 0.0, 500.0);
        assert_eq!(h3, h9, "shared content must share a home under TokenHash");
        let mut exact = Router::new(RoutingPolicy::PrefixAffinity, PrefixKeying::ExactId, 4, 1e9);
        let e3 = exact.route(&mk(0, 3), 0.0, 500.0);
        let e9 = exact.route(&mk(1, 9), 0.0, 500.0);
        assert_ne!(e3, e9, "distinct ids must home separately under ExactId");
    }

    #[test]
    fn short_live_slice_degrades_instead_of_panicking() {
        // Regression: a LiveLoad slice shorter than the pool (what a masked
        // dead instance's missing sample produces) used to index
        // out-of-bounds in release builds. Uncovered instances must simply
        // be unroutable-by-live-signal.
        let mut r = Router::new(RoutingPolicy::LeastQueueDepth, PrefixKeying::TokenHash, 3, 1000.0);
        let short = [load(5, 0), load(0, 0)]; // no entry for instance 2
        for i in 0..4 {
            let pick = r.route_live(&plain(i, 0.0), 0.0, 100.0, Some(&short));
            assert!(pick < 2, "uncovered instance 2 must not be picked, got {pick}");
        }
        // An empty slice covers nothing: the fluid proxy decides, and every
        // instance stays reachable.
        let picks: Vec<usize> =
            (4..10).map(|i| r.route_live(&plain(i, 0.0), 0.0, 0.0, Some(&[]))).collect();
        assert!(picks.iter().all(|&p| p < 3));
        // The affinity guard tolerates a short slice too: whether the home
        // is covered (depth 0, healthy) or uncovered (counts as overloaded,
        // spills to the covered instance), the pick lands on instance 0.
        let mut a = Router::new(RoutingPolicy::PrefixAffinity, PrefixKeying::TokenHash, 3, 1e9);
        let _home = a.route_live(&fam(0, 0.0, 7), 0.0, 10.0, Some(&[load(0, 0); 3]));
        let next = a.route_live(&fam(1, 0.0, 7), 0.0, 10.0, Some(&[load(0, 0)]));
        assert_eq!(next, 0, "mismatched slice must degrade, not panic");
    }

    #[test]
    fn masked_instances_are_skipped_by_every_policy() {
        // Round-robin hops over the down instance and resumes on restart.
        let mut rr = Router::new(RoutingPolicy::RoundRobin, PrefixKeying::TokenHash, 3, 1e9);
        rr.set_up(1, false);
        assert!(!rr.is_up(1));
        assert_eq!(rr.up_instances(), 2);
        let picks: Vec<usize> = (0..4).map(|i| rr.route(&plain(i, 0.0), 0.0, 1.0)).collect();
        assert_eq!(picks, vec![0, 2, 0, 2]);
        rr.set_up(1, true);
        let picks: Vec<usize> = (4..10).map(|i| rr.route(&plain(i, 0.0), 0.0, 1.0)).collect();
        assert!(picks.contains(&1), "a restarted instance rejoins the rotation");

        // Fluid least-outstanding never lands on a masked instance even
        // though it is (artificially) the lightest.
        let mut lo = Router::new(RoutingPolicy::LeastOutstanding, PrefixKeying::TokenHash, 3, 1e9);
        assert_eq!(lo.route(&plain(0, 0.0), 0.0, 10.0), 0);
        lo.set_up(2, false);
        for i in 1..6 {
            assert_ne!(lo.route(&plain(i, 0.0), 0.0, 10.0), 2);
        }

        // Live least-depth: the masked instance's zero depth is invisible.
        let mut lqd = Router::new(RoutingPolicy::LeastQueueDepth, PrefixKeying::TokenHash, 3, 1e9);
        lqd.set_up(0, false);
        let l = [load(0, 0), load(4, 4), load(9, 9)];
        assert_eq!(lqd.route_live(&plain(0, 0.0), 0.0, 1.0, Some(&l)), 1);
    }

    #[test]
    fn affinity_rehomes_family_when_home_goes_down() {
        let mut r = Router::new(RoutingPolicy::PrefixAffinity, PrefixKeying::TokenHash, 3, 1e9);
        let home = r.route(&fam(0, 0.0, 7), 0.0, 500.0);
        assert_eq!(r.route(&fam(1, 0.0, 7), 0.0, 500.0), home);
        r.set_up(home, false);
        let new_home = r.route(&fam(2, 0.0, 7), 0.0, 500.0);
        assert_ne!(new_home, home, "family must leave its dead home");
        // The re-homing is permanent: even after the old home restarts (its
        // blocks are gone), the family sticks to the new home.
        r.set_up(home, true);
        assert_eq!(r.route(&fam(3, 0.0, 7), 0.0, 500.0), new_home);
        assert_eq!(r.route(&fam(4, 0.0, 7), 0.0, 500.0), new_home);
    }

    #[test]
    fn routing_policy_parse_roundtrip() {
        for p in [
            RoutingPolicy::RoundRobin,
            RoutingPolicy::LeastOutstanding,
            RoutingPolicy::LeastQueueDepth,
            RoutingPolicy::PrefixAffinity,
            RoutingPolicy::TopoAware,
        ] {
            assert_eq!(RoutingPolicy::parse(p.label()), Some(p));
        }
        assert_eq!(RoutingPolicy::parse("RR"), Some(RoutingPolicy::RoundRobin));
        assert_eq!(RoutingPolicy::parse("lqd"), Some(RoutingPolicy::LeastQueueDepth));
        assert_eq!(RoutingPolicy::parse("topo"), Some(RoutingPolicy::TopoAware));
        assert_eq!(RoutingPolicy::parse("nope"), None);
        // Only the live/feedback policies ask for engine snapshots.
        assert!(RoutingPolicy::LeastQueueDepth.uses_live_state());
        assert!(RoutingPolicy::PrefixAffinity.uses_live_state());
        assert!(RoutingPolicy::TopoAware.uses_live_state());
        assert!(!RoutingPolicy::RoundRobin.uses_live_state());
        assert!(!RoutingPolicy::LeastOutstanding.uses_live_state());
    }

    #[test]
    fn topo_aware_trades_hops_against_queue_depth() {
        let mut r = Router::new(RoutingPolicy::TopoAware, PrefixKeying::TokenHash, 3, 1e9);
        // Equal depths: the near instance wins outright.
        let near = r.route_with_hops(&plain(0, 0.0), 0.0, 1.0, Some(&[load(2, 0); 3]), Some(&[4, 1, 4]));
        assert_eq!(near, 1, "equal queues must break toward the fewest hops");
        // A hop advantage is worth HOP_PENALTY queue slots — a far instance
        // that is sufficiently emptier still wins.
        let far = r.route_with_hops(
            &plain(1, 0.0),
            0.0,
            1.0,
            Some(&[load(0, 0), load(8, 0), load(8, 0)]),
            Some(&[4, 1, 1]),
        );
        assert_eq!(far, 0, "deep queues must override distance");
        // Without a hop signal the policy IS live least-queue-depth …
        let no_hops = r.route_with_hops(
            &plain(2, 0.0),
            0.0,
            1.0,
            Some(&[load(9, 0), load(0, 0), load(9, 0)]),
            None,
        );
        assert_eq!(no_hops, 1);
        // … and without live state it degrades to the fluid proxy.
        let pick = r.route(&plain(3, 0.0), 0.0, 1.0);
        assert!(pick < 3);
    }

    #[test]
    fn topo_aware_skips_masked_instances_and_rotates_ties() {
        let mut r = Router::new(RoutingPolicy::TopoAware, PrefixKeying::TokenHash, 3, 1e9);
        r.set_up(0, false);
        // Instance 0 is closest AND emptiest but down: unroutable.
        let pick = r.route_with_hops(&plain(0, 0.0), 0.0, 1.0, Some(&[load(0, 0); 3]), Some(&[0, 2, 2]));
        assert_ne!(pick, 0);
        r.set_up(0, true);
        // Exact cost ties rotate across the tied instances.
        let picks: Vec<usize> = (1..7)
            .map(|i| r.route_with_hops(&plain(i, 0.0), 0.0, 0.0, Some(&[load(1, 1); 3]), Some(&[2, 2, 2])))
            .collect();
        let mut sorted = picks.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 3, "cost ties must rotate: {picks:?}");
    }
}
