//! Inter-instance KV fabric: explicit topology with per-edge contention.
//!
//! The fleet's KV handoffs used to serialize on ONE pooled [`SharedLink`]
//! with `parallel_flows` undifferentiated channels — every migration cost
//! the same regardless of where prefill and decode instances sit. This
//! module replaces that pool with an explicit inter-instance topology,
//! one level up from the on-chip NoC: the same hop discipline as
//! `arch/noc.rs` ([`TileCoord`] coordinates, dimension-ordered moves) and
//! the same per-link latency discipline as `arch/collective.rs`, applied
//! to whole wafer instances instead of tiles.
//!
//! # Topologies
//!
//! - [`TopologySpec::Degenerate`] — the 1-switch fabric: every instance
//!   hangs off one pooled link, exactly today's [`SharedLink`] with
//!   `parallel_flows` channels. This is the field-identical anchor: a
//!   degenerate fabric IS a `SharedLink`, bit for bit (pinned by test).
//! - [`TopologySpec::Torus`] — a 2D torus wafer mesh over the most-square
//!   factorization of the instance count (16 → 4×4, 64 → 8×8; a prime
//!   count degrades to a 1×n ring). Handoffs take dimension-ordered
//!   routes (X first, then Y), each dimension stepping in its shortest
//!   wraparound direction (ties go positive).
//! - [`TopologySpec::FatTree`] — a two-level fat-tree (leaf/spine Clos):
//!   `ceil(sqrt(n))` instances per leaf switch, one spine per leaf.
//!   Same-leaf handoffs go up/down through the leaf (2 hops); others
//!   climb to a deterministically hashed spine (`(src + dst) % spines`,
//!   a static ECMP stand-in) and back down (4 hops).
//!
//! # Contention model
//!
//! Every directed edge owns a 1-channel [`SharedLink`] ledger — the SAME
//! scheduling and busy-interval code path as the pooled fabric, so
//! `busy_fraction`'s exact time-in-window integral carries over per edge.
//! A transfer serializes `bytes` on every edge of its route (the cut
//! enters edge `i` one per-hop base latency after edge `i-1`), and its
//! exposed latency is
//!
//! ```text
//! exposed = hops × base_latency + max per-edge queue wait + (1 − overlap) × ser
//! ```
//!
//! — hot edges (e.g. the prefill-pool boundary) genuinely congest, and a
//! route's cost is decided by its worst edge, not a fleet-wide average.
//!
//! # Lookahead
//!
//! The sharded conservative-lookahead engine needs a lower bound on how
//! fast the fabric can deliver anything. That bound is the minimum
//! single-edge traversal latency over the fabric ([`Fabric::lookahead_s`]):
//! every edge charges the full per-hop base latency, so no handoff can
//! land sooner than `base_latency_s` after it became ready — numerically
//! the same epoch length the pooled link derived, which is exactly why
//! the degenerate anchor holds across barriers too.

use std::collections::HashMap;

use super::transfer::{KvTransferModel, SharedLink};
use crate::arch::noc::TileCoord;

/// Which inter-instance topology the fleet's KV fabric instantiates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TopologySpec {
    /// One pooled switch — the historical `SharedLink` fabric.
    Degenerate,
    /// 2D torus wafer mesh, dimension-ordered (X then Y) routing.
    Torus,
    /// Two-level fat-tree (leaf/spine), up/down routing.
    FatTree,
}

impl TopologySpec {
    pub fn label(self) -> &'static str {
        match self {
            TopologySpec::Degenerate => "degenerate",
            TopologySpec::Torus => "torus",
            TopologySpec::FatTree => "fat-tree",
        }
    }

    /// Parse a CLI topology name (case-insensitive).
    pub fn parse(s: &str) -> Option<TopologySpec> {
        match s.to_ascii_lowercase().as_str() {
            "degenerate" | "pooled" | "shared-link" => Some(TopologySpec::Degenerate),
            "torus" | "mesh" | "2d-torus" => Some(TopologySpec::Torus),
            "fattree" | "fat-tree" | "tree" | "clos" => Some(TopologySpec::FatTree),
            _ => None,
        }
    }
}

/// One scheduled fabric transfer: what the fleet bills and what the obs
/// layer annotates on the handoff span.
#[derive(Debug, Clone, PartialEq)]
pub struct FabricXfer {
    /// Exposed latency from ready to landing (base × hops + worst edge
    /// wait + unhidden serialization).
    pub exposed_s: f64,
    /// Edges traversed (1 for the degenerate switch, 0 for a same-node
    /// transfer on a routed topology).
    pub hops: u64,
    /// Worst per-edge queue wait along the route (the pooled link's
    /// channel wait in the degenerate case).
    pub wait_s: f64,
    /// Node ids along the route, `src` to `dst`; ids `>= instances` are
    /// fat-tree switches. Rendered into the handoff span's `path` arg.
    pub nodes: Vec<usize>,
}

impl FabricXfer {
    /// `src>via>dst` — the handoff span's `path` argument.
    pub fn path_label(&self) -> String {
        self.nodes.iter().map(|n| n.to_string()).collect::<Vec<_>>().join(">")
    }
}

/// The fleet's inter-instance KV fabric: topology + per-edge ledgers.
#[derive(Debug, Clone)]
pub struct Fabric {
    spec: TopologySpec,
    /// Wafer instances (fabric endpoints); switches come after.
    n: usize,
    /// Degenerate only: the pooled multi-channel link.
    pooled: Option<SharedLink>,
    /// Routed topologies: one 1-channel ledger per directed edge.
    edges: Vec<SharedLink>,
    /// Directed edge endpoints, indexed like `edges`.
    edge_ends: Vec<(usize, usize)>,
    /// (u, v) → edge index.
    edge_index: HashMap<(usize, usize), usize>,
    /// Torus geometry (cols × rows == n).
    cols: usize,
    rows: usize,
    /// Fat-tree geometry.
    radix: usize,
    leaves: usize,
    spines: usize,
    /// Routed transfers scheduled (degenerate delegates to the pool).
    transfers: u64,
    /// Accumulated worst-edge waits of routed transfers.
    wait_s: f64,
}

impl Fabric {
    /// Build the fabric for `instances` endpoints. The degenerate switch
    /// takes its channel count from `model.parallel_flows` — field-
    /// identical to the pooled `SharedLink` the fleet used to hold.
    pub fn new(spec: TopologySpec, instances: usize, model: &KvTransferModel) -> Fabric {
        assert!(instances >= 1, "a fabric needs at least one endpoint");
        let mut f = Fabric {
            spec,
            n: instances,
            pooled: None,
            edges: Vec::new(),
            edge_ends: Vec::new(),
            edge_index: HashMap::new(),
            cols: 1,
            rows: 1,
            radix: 1,
            leaves: 1,
            spines: 0,
            transfers: 0,
            wait_s: 0.0,
        };
        match spec {
            TopologySpec::Degenerate => f.pooled = Some(SharedLink::new(model.parallel_flows)),
            TopologySpec::Torus => f.build_torus(),
            TopologySpec::FatTree => f.build_fat_tree(),
        }
        f
    }

    /// Most-square factorization of `n`: the widest `cols <= sqrt(n)`
    /// dividing it evenly (primes degrade to a 1×n ring).
    fn build_torus(&mut self) {
        let n = self.n;
        let mut cols = (n as f64).sqrt().floor() as usize;
        while cols > 1 && n % cols != 0 {
            cols -= 1;
        }
        self.cols = cols.max(1);
        self.rows = n / self.cols;
        for u in 0..n {
            let c = self.coord(u);
            if self.cols > 1 {
                let v = self.node_at((c.x as usize + 1) % self.cols, c.y as usize);
                self.add_edge_pair(u, v);
            }
            if self.rows > 1 {
                let v = self.node_at(c.x as usize, (c.y as usize + 1) % self.rows);
                self.add_edge_pair(u, v);
            }
        }
    }

    /// Two-level leaf/spine Clos: `ceil(sqrt(n))` ports per leaf, one
    /// spine per leaf, every leaf wired to every spine.
    fn build_fat_tree(&mut self) {
        let n = self.n;
        self.radix = (n as f64).sqrt().ceil() as usize;
        self.radix = self.radix.max(1);
        self.leaves = n.div_ceil(self.radix);
        self.spines = if self.leaves > 1 { self.leaves } else { 0 };
        for u in 0..n {
            let leaf = self.leaf_id(u);
            self.add_edge_pair(u, leaf);
        }
        for l in 0..self.leaves {
            for s in 0..self.spines {
                self.add_edge_pair(self.n + l, self.spine_id(s));
            }
        }
    }

    fn add_edge_pair(&mut self, u: usize, v: usize) {
        if u == v {
            return;
        }
        for (a, b) in [(u, v), (v, u)] {
            if let std::collections::hash_map::Entry::Vacant(e) = self.edge_index.entry((a, b)) {
                e.insert(self.edge_ends.len());
                self.edge_ends.push((a, b));
                self.edges.push(SharedLink::new(1));
            }
        }
    }

    /// Torus coordinate of instance `i` (the `arch/noc.rs` tile
    /// abstraction, one level up: wafer instances as tiles of the fleet).
    fn coord(&self, i: usize) -> TileCoord {
        TileCoord { x: (i % self.cols) as u32, y: (i / self.cols) as u32 }
    }

    fn node_at(&self, x: usize, y: usize) -> usize {
        y * self.cols + x
    }

    /// Fat-tree: node id of instance `u`'s leaf switch.
    fn leaf_id(&self, u: usize) -> usize {
        self.n + u / self.radix
    }

    fn spine_id(&self, s: usize) -> usize {
        self.n + self.leaves + s
    }

    pub fn spec(&self) -> TopologySpec {
        self.spec
    }

    /// Directed edges in the fabric (0 for the degenerate switch, which
    /// exposes its pool as ONE logical edge in the telemetry accessors).
    pub fn edge_count(&self) -> usize {
        self.edges.len()
    }

    /// The degenerate pooled link, for the field-identity anchor.
    pub fn pooled(&self) -> Option<&SharedLink> {
        self.pooled.as_ref()
    }

    /// Shortest wraparound step distance along one torus dimension.
    fn ring_dist(from: usize, to: usize, len: usize) -> u64 {
        let fwd = (to + len - from) % len;
        fwd.min(len - fwd) as u64
    }

    /// Hop count of the route `src → dst` (read-only; the topo-aware
    /// router's distance signal). The degenerate switch is always one hop.
    pub fn hops(&self, src: usize, dst: usize) -> u64 {
        match self.spec {
            TopologySpec::Degenerate => 1,
            _ if src == dst => 0,
            TopologySpec::Torus => {
                let (a, b) = (self.coord(src), self.coord(dst));
                Self::ring_dist(a.x as usize, b.x as usize, self.cols)
                    + Self::ring_dist(a.y as usize, b.y as usize, self.rows)
            }
            TopologySpec::FatTree => {
                if self.leaf_id(src) == self.leaf_id(dst) {
                    2
                } else {
                    4
                }
            }
        }
    }

    /// Node ids along the route `src → dst` (inclusive). Degenerate routes
    /// are the logical `[src, dst]` through the one switch.
    pub fn route_nodes(&self, src: usize, dst: usize) -> Vec<usize> {
        match self.spec {
            TopologySpec::Degenerate => vec![src, dst],
            _ if src == dst => vec![src],
            TopologySpec::Torus => {
                let mut nodes = vec![src];
                let dst_c = self.coord(dst);
                let mut x = src % self.cols;
                let mut y = src / self.cols;
                // X first, shortest wraparound direction (ties positive).
                while x != dst_c.x as usize {
                    let fwd = (dst_c.x as usize + self.cols - x) % self.cols;
                    x = if fwd <= self.cols - fwd { (x + 1) % self.cols } else { (x + self.cols - 1) % self.cols };
                    nodes.push(self.node_at(x, y));
                }
                while y != dst_c.y as usize {
                    let fwd = (dst_c.y as usize + self.rows - y) % self.rows;
                    y = if fwd <= self.rows - fwd { (y + 1) % self.rows } else { (y + self.rows - 1) % self.rows };
                    nodes.push(self.node_at(x, y));
                }
                nodes
            }
            TopologySpec::FatTree => {
                let (ls, ld) = (self.leaf_id(src), self.leaf_id(dst));
                if ls == ld {
                    vec![src, ls, dst]
                } else {
                    let spine = self.spine_id((src + dst) % self.spines.max(1));
                    vec![src, ls, spine, ld, dst]
                }
            }
        }
    }

    /// Schedule `bytes` from instance `src` to instance `dst`, ready at
    /// `ready_s`: serialize on every edge of the route (each edge's own
    /// 1-channel ledger queues it behind earlier traffic) and return the
    /// exposed latency, hop count, worst-edge wait and route. The
    /// degenerate switch delegates verbatim to the pooled
    /// [`SharedLink::schedule_bytes`] — same channels, same ledger, same
    /// return value as the pre-fabric fleet.
    pub fn schedule_bytes(
        &mut self,
        src: usize,
        dst: usize,
        ready_s: f64,
        bytes: u64,
        model: &KvTransferModel,
    ) -> FabricXfer {
        if let Some(pooled) = self.pooled.as_mut() {
            let wait_before = pooled.wait_s;
            let exposed_s = pooled.schedule_bytes(ready_s, bytes, model);
            return FabricXfer {
                exposed_s,
                hops: 1,
                wait_s: pooled.wait_s - wait_before,
                nodes: vec![src, dst],
            };
        }
        let nodes = self.route_nodes(src, dst);
        let hops = (nodes.len() - 1) as u64;
        let ser = bytes as f64 / model.link_bandwidth_bytes_per_s.max(1.0);
        let unhidden = (1.0 - model.overlap_fraction.clamp(0.0, 1.0)) * ser;
        if hops == 0 {
            // Same-node transfer (no edges): base latency + unhidden copy.
            self.transfers += 1;
            return FabricXfer { exposed_s: model.base_latency_s + unhidden, hops, wait_s: 0.0, nodes };
        }
        // Per-hop scheduling: the cut reaches edge `i` one base latency
        // after edge `i-1`; each edge's 1-channel ledger queues the full
        // serialization behind earlier traffic (schedule_bytes is the ONE
        // ledger writer — per-edge `busy_fraction` shares the pooled
        // link's exact time-in-window integral). The zero-base hop model
        // makes the per-edge return value `wait + unhidden`, so the worst
        // edge wait falls out by subtraction.
        let hop_model = KvTransferModel { base_latency_s: 0.0, ..*model };
        let mut max_wait = 0.0f64;
        for (i, pair) in nodes.windows(2).enumerate() {
            let e = self.edge_index[&(pair[0], pair[1])];
            let hop_ready = ready_s + i as f64 * model.base_latency_s;
            let wait = self.edges[e].schedule_bytes(hop_ready, bytes, &hop_model) - unhidden;
            max_wait = max_wait.max(wait);
        }
        self.transfers += 1;
        self.wait_s += max_wait;
        FabricXfer {
            exposed_s: hops as f64 * model.base_latency_s + max_wait + unhidden,
            hops,
            wait_s: max_wait,
            nodes,
        }
    }

    /// The sharded engine's epoch length: minimum single-edge traversal
    /// latency over the fabric. Every edge charges the full per-hop base
    /// latency, so no handoff lands sooner than `base_latency_s` after
    /// becoming ready — for every topology, the pooled link's old bound.
    pub fn lookahead_s(&self, model: &KvTransferModel) -> f64 {
        model.lookahead_s()
    }

    /// Transfers scheduled over the fabric.
    pub fn transfers(&self) -> u64 {
        self.pooled.as_ref().map_or(self.transfers, |p| p.transfers)
    }

    /// Total queue wait billed to transfers: each routed transfer's worst
    /// edge wait (its exposed queueing), or the pooled channel waits.
    pub fn wait_s(&self) -> f64 {
        self.pooled.as_ref().map_or(self.wait_s, |p| p.wait_s)
    }

    /// Fabric-wide busy share over `[0, horizon_s]`: the pooled link's
    /// `busy_fraction`, or the mean of the per-edge fractions.
    pub fn busy_fraction(&self, horizon_s: f64) -> f64 {
        if let Some(p) = self.pooled.as_ref() {
            return p.busy_fraction(horizon_s);
        }
        if self.edges.is_empty() {
            return 0.0;
        }
        self.edges.iter().map(|e| e.busy_fraction(horizon_s)).sum::<f64>() / self.edges.len() as f64
    }

    /// Per-edge busy share over `[0, horizon_s]` (the series gauge). The
    /// degenerate switch reports its pool as one logical edge.
    pub fn edge_busy_fractions(&self, horizon_s: f64) -> Vec<f64> {
        match self.pooled.as_ref() {
            Some(p) => vec![p.busy_fraction(horizon_s)],
            None => self.edges.iter().map(|e| e.busy_fraction(horizon_s)).collect(),
        }
    }

    /// Per-edge serialization seconds (the conservation ledger: every
    /// transfer deposits `bytes / bandwidth` on each edge of its route).
    pub fn edge_busy_s(&self) -> Vec<f64> {
        match self.pooled.as_ref() {
            Some(p) => vec![p.busy_s],
            None => self.edges.iter().map(|e| e.busy_s).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::config::Dtype;
    use crate::workload::deepseek::DeepSeekConfig;

    fn model() -> KvTransferModel {
        KvTransferModel::inter_node(&DeepSeekConfig::v3_671b(), Dtype::Fp8)
    }

    #[test]
    fn topology_labels_roundtrip() {
        for spec in [TopologySpec::Degenerate, TopologySpec::Torus, TopologySpec::FatTree] {
            assert_eq!(TopologySpec::parse(spec.label()), Some(spec));
        }
        assert_eq!(TopologySpec::parse("TORUS"), Some(TopologySpec::Torus));
        assert_eq!(TopologySpec::parse("clos"), Some(TopologySpec::FatTree));
        assert_eq!(TopologySpec::parse("hypercube"), None);
    }

    /// THE degenerate anchor: a degenerate fabric is a `SharedLink` with
    /// the model's channel count — same returns, field-identical ledger.
    #[test]
    fn degenerate_is_field_identical_to_shared_link() {
        let m = model();
        let mut fabric = Fabric::new(TopologySpec::Degenerate, 4, &m);
        let mut reference = SharedLink::new(m.parallel_flows);
        for (i, &(ready, tokens)) in
            [(0.0, 4096u64), (0.001, 8192), (0.001, 1024), (0.0015, 16384), (0.2, 2048)].iter().enumerate()
        {
            let bytes = m.bytes_for(tokens);
            let xfer = fabric.schedule_bytes(i % 2, (i + 1) % 4, ready, bytes, &m);
            let want = reference.schedule_bytes(ready, bytes, &m);
            assert_eq!(xfer.exposed_s, want, "transfer {i} diverged");
            assert_eq!(xfer.hops, 1);
        }
        assert_eq!(fabric.pooled(), Some(&reference), "pooled ledger must be field-identical");
        assert_eq!(fabric.transfers(), reference.transfers);
        assert_eq!(fabric.wait_s(), reference.wait_s);
        assert_eq!(fabric.busy_fraction(1.0), reference.busy_fraction(1.0));
        assert_eq!(fabric.edge_busy_s(), vec![reference.busy_s]);
    }

    #[test]
    fn torus_uses_most_square_grid_and_wraparound_hops() {
        let m = model();
        let f = Fabric::new(TopologySpec::Torus, 16, &m);
        assert_eq!((f.cols, f.rows), (4, 4));
        // 4×4 torus: 2 dims × 2 directions per node.
        assert_eq!(f.edge_count(), 64);
        assert_eq!(f.hops(0, 5), 2); // (0,0) → (1,1)
        assert_eq!(f.hops(0, 3), 1); // wraparound beats 3 forward steps
        assert_eq!(f.hops(0, 12), 1); // Y wraparound
        assert_eq!(f.hops(0, 10), 4); // (0,0) → (2,2): the diameter
        assert_eq!(f.hops(7, 7), 0);
        // Dimension order: X moves come before Y moves.
        assert_eq!(f.route_nodes(0, 5), vec![0, 1, 5]);
        assert_eq!(f.route_nodes(0, 3), vec![0, 3]);
        // A prime count degrades to a ring.
        let ring = Fabric::new(TopologySpec::Torus, 7, &m);
        assert_eq!((ring.cols, ring.rows), (1, 7));
        assert_eq!(ring.hops(0, 6), 1);
        assert_eq!(ring.hops(0, 3), 3);
    }

    #[test]
    fn fat_tree_routes_up_and_down() {
        let m = model();
        let f = Fabric::new(TopologySpec::FatTree, 16, &m);
        assert_eq!((f.radix, f.leaves, f.spines), (4, 4, 4));
        assert_eq!(f.hops(0, 3), 2); // same leaf
        assert_eq!(f.hops(0, 15), 4); // via a spine
        let route = f.route_nodes(0, 15);
        assert_eq!(route.len(), 5);
        assert_eq!(route[1], 16); // leaf 0
        assert!(route[2] >= 20, "second hop must be a spine");
        assert_eq!(route[3], 19); // leaf 3
        // Single-leaf fleets need no spine layer at all.
        let tiny = Fabric::new(TopologySpec::FatTree, 2, &m);
        assert_eq!(tiny.spines, 0);
        assert_eq!(tiny.hops(0, 1), 2);
    }

    #[test]
    fn routed_transfer_pays_hops_and_worst_edge_wait() {
        let mut m = model();
        m.overlap_fraction = 0.0;
        let mut f = Fabric::new(TopologySpec::Torus, 16, &m);
        let bytes = 16_000_000_000u64; // 1 s of serialization at 16 GB/s
        let ser = bytes as f64 / m.link_bandwidth_bytes_per_s;
        let first = f.schedule_bytes(0, 2, 0.0, bytes, &m);
        assert_eq!(first.hops, 2);
        assert!((first.exposed_s - (2.0 * m.base_latency_s + ser)).abs() < 1e-9, "{first:?}");
        assert_eq!(first.wait_s, 0.0);
        // Same route, same ready time: queues a full serialization behind
        // the first transfer on the shared first edge.
        let second = f.schedule_bytes(0, 2, 0.0, bytes, &m);
        assert!((second.wait_s - ser).abs() < 1e-6, "{second:?}");
        assert!(second.exposed_s > first.exposed_s);
        // A disjoint route sees no wait at all.
        let disjoint = f.schedule_bytes(10, 9, 0.0, bytes, &m);
        assert_eq!(disjoint.wait_s, 0.0);
    }

    /// Conservation: every transfer deposits `hops × ser` seconds of edge
    /// occupancy — billed bytes × hops equals the summed per-edge ledger.
    #[test]
    fn edge_occupancy_conserves_bytes_times_hops() {
        let m = model();
        for spec in [TopologySpec::Torus, TopologySpec::FatTree] {
            let mut f = Fabric::new(spec, 12, &m);
            let mut hop_bytes = 0u64;
            for i in 0..40usize {
                let (src, dst) = (i % 12, (i * 7 + 3) % 12);
                let bytes = m.bytes_for(512 + 64 * i as u64);
                let xfer = f.schedule_bytes(src, dst, i as f64 * 1e-4, bytes, &m);
                assert_eq!(xfer.hops, f.hops(src, dst), "hops must match the route");
                hop_bytes += bytes * xfer.hops;
            }
            let ledger: f64 = f.edge_busy_s().iter().sum();
            let want = hop_bytes as f64 / m.link_bandwidth_bytes_per_s;
            assert!((ledger - want).abs() <= 1e-9 * want.max(1.0), "{spec:?}: {ledger} vs {want}");
        }
    }

    #[test]
    fn lookahead_is_the_single_edge_traversal_bound() {
        let m = model();
        for spec in [TopologySpec::Degenerate, TopologySpec::Torus, TopologySpec::FatTree] {
            let f = Fabric::new(spec, 16, &m);
            assert_eq!(f.lookahead_s(&m), m.base_latency_s);
        }
    }

    #[test]
    fn per_edge_busy_fractions_use_the_exact_integral() {
        let mut m = model();
        m.overlap_fraction = 0.0;
        let mut f = Fabric::new(TopologySpec::Torus, 16, &m);
        let bytes = 8_000_000_000u64; // 0.5 s at 16 GB/s
        f.schedule_bytes(0, 1, 0.0, bytes, &m);
        let fracs = f.edge_busy_fractions(1.0);
        assert_eq!(fracs.len(), f.edge_count());
        let busy: Vec<f64> = fracs.iter().copied().filter(|&b| b > 0.0).collect();
        assert_eq!(busy.len(), 1, "exactly the routed edge is busy");
        assert!((busy[0] - 0.5).abs() < 1e-9);
        // The fabric-wide share is the per-edge mean.
        assert!((f.busy_fraction(1.0) - 0.5 / f.edge_count() as f64).abs() < 1e-12);
    }
}
