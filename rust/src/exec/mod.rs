//! Functional (numerics-carrying) executors — verify the dataflow math.
pub mod tensor;
pub mod functional;
