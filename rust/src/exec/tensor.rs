//! Minimal row-major f32 matrix type for the functional executors.
//!
//! This is deliberately tiny: the functional path exists to *verify the
//! dataflow math* (online softmax with group collectives) against the
//! PJRT-executed JAX golden, not to be a general tensor library.

use crate::util::SplitMix64;

/// Row-major f32 matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn filled(rows: usize, cols: usize, v: f32) -> Self {
        Mat { rows, cols, data: vec![v; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    /// Deterministic random matrix in [-1, 1).
    pub fn random(rows: usize, cols: usize, rng: &mut SplitMix64) -> Self {
        let data = (0..rows * cols).map(|_| rng.next_f32_signed()).collect();
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }
    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// `self · other`
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self.at(i, k);
                if a == 0.0 {
                    continue;
                }
                let orow = &other.data[k * other.cols..(k + 1) * other.cols];
                let out_row = &mut out.data[i * other.cols..(i + 1) * other.cols];
                for (o, &b) in out_row.iter_mut().zip(orow.iter()) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// `self · otherᵀ`
    pub fn matmul_t(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols, "matmul_t shape mismatch");
        let mut out = Mat::zeros(self.rows, other.rows);
        for i in 0..self.rows {
            for j in 0..other.rows {
                let mut acc = 0.0f32;
                for k in 0..self.cols {
                    acc += self.at(i, k) * other.at(j, k);
                }
                *out.at_mut(i, j) = acc;
            }
        }
        out
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                *out.at_mut(c, r) = self.at(r, c);
            }
        }
        out
    }

    pub fn scale(&self, s: f32) -> Mat {
        Mat { rows: self.rows, cols: self.cols, data: self.data.iter().map(|x| x * s).collect() }
    }

    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect(),
        }
    }

    /// Contiguous row slice [r0, r1).
    pub fn rows_slice(&self, r0: usize, r1: usize) -> Mat {
        assert!(r0 <= r1 && r1 <= self.rows);
        Mat { rows: r1 - r0, cols: self.cols, data: self.data[r0 * self.cols..r1 * self.cols].to_vec() }
    }

    /// Column slice [c0, c1).
    pub fn cols_slice(&self, c0: usize, c1: usize) -> Mat {
        assert!(c0 <= c1 && c1 <= self.cols);
        let mut out = Mat::zeros(self.rows, c1 - c0);
        for r in 0..self.rows {
            for c in c0..c1 {
                *out.at_mut(r, c - c0) = self.at(r, c);
            }
        }
        out
    }

    /// Row-wise numerically-stable softmax.
    pub fn softmax_rows(&self) -> Mat {
        let mut out = self.clone();
        for r in 0..self.rows {
            let row = &mut out.data[r * self.cols..(r + 1) * self.cols];
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0f32;
            for x in row.iter_mut() {
                *x = (*x - m).exp();
                sum += *x;
            }
            for x in row.iter_mut() {
                *x /= sum;
            }
        }
        out
    }

    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().map(|x| x.abs()).fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let mut i2 = Mat::zeros(2, 2);
        *i2.at_mut(0, 0) = 1.0;
        *i2.at_mut(1, 1) = 1.0;
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(a.matmul(&i2), a);
    }

    #[test]
    fn matmul_known_values() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let ones = Mat::filled(2, 2, 1.0);
        let c = a.matmul(&ones);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_t_matches_transpose() {
        let mut rng = SplitMix64::new(1);
        let a = Mat::random(3, 5, &mut rng);
        let b = Mat::random(4, 5, &mut rng);
        let via_t = a.matmul(&b.transpose());
        let direct = a.matmul_t(&b);
        assert!(via_t.max_abs_diff(&direct) < 1e-6);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut rng = SplitMix64::new(2);
        let a = Mat::random(4, 7, &mut rng).scale(3.0);
        let s = a.softmax_rows();
        for r in 0..4 {
            let sum: f32 = s.row(r).iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn slices() {
        let a = Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(a.rows_slice(1, 2).data, vec![4.0, 5.0, 6.0]);
        assert_eq!(a.cols_slice(1, 3).data, vec![2.0, 3.0, 5.0, 6.0]);
    }
}
