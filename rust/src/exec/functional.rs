//! Functional (numerics-carrying) executors for the attention dataflows.
//!
//! These mirror, slice-for-slice, the tiled online-softmax math of
//! FlashAttention-2 (Algorithm 1) and FlatAttention (Algorithm 2, including
//! the group-level row-wise max/sum/O reductions), so that the *dataflow
//! algebra* — not just the performance model — is verified against a dense
//! reference and, through [`crate::runtime`], against the PJRT-executed JAX
//! golden produced by the Pallas kernel.

use crate::dataflow::tiling::FlatTiling;
use crate::exec::tensor::Mat;

/// Dense reference: softmax(Q·Kᵀ/√D)·V, optionally causal.
pub fn reference_attention(q: &Mat, k: &Mat, v: &Mat, causal: bool) -> Mat {
    let d = q.cols as f32;
    let mut s = q.matmul_t(k).scale(1.0 / d.sqrt());
    if causal {
        // Standard causal mask for square S; for rectangular (decode with
        // queries at the sequence end) offset so the last query sees all.
        let off = k.rows as isize - q.rows as isize;
        for r in 0..s.rows {
            for c in 0..s.cols {
                if (c as isize) > r as isize + off {
                    *s.at_mut(r, c) = f32::NEG_INFINITY;
                }
            }
        }
    }
    s.softmax_rows().matmul(v)
}

/// FlashAttention-2 (Algorithm 1): single-tile online softmax over
/// (Br, Bc) blocks. Numerically equivalent to the reference.
pub fn flash_attention(q: &Mat, k: &Mat, v: &Mat, br: usize, bc: usize) -> Mat {
    let s_q = q.rows;
    let s_kv = k.rows;
    let d = q.cols;
    let dv = v.cols;
    let scale = 1.0 / (d as f32).sqrt();
    let mut out = Mat::zeros(s_q, dv);

    let t_r = s_q.div_ceil(br);
    let t_c = s_kv.div_ceil(bc);
    for i in 0..t_r {
        let r0 = i * br;
        let r1 = (r0 + br).min(s_q);
        let qi = q.rows_slice(r0, r1);
        let mut m = vec![f32::NEG_INFINITY; r1 - r0];
        let mut l = vec![0.0f32; r1 - r0];
        let mut o = Mat::zeros(r1 - r0, dv);
        for j in 0..t_c {
            let c0 = j * bc;
            let c1 = (c0 + bc).min(s_kv);
            let kj = k.rows_slice(c0, c1);
            let vj = v.rows_slice(c0, c1);
            let s = qi.matmul_t(&kj).scale(scale);
            for r in 0..s.rows {
                let row_max = s.row(r).iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let m_new = m[r].max(row_max);
                let corr = (m[r] - m_new).exp();
                let mut row_sum = 0.0f32;
                let mut p = vec![0.0f32; s.cols];
                for c in 0..s.cols {
                    p[c] = (s.at(r, c) - m_new).exp();
                    row_sum += p[c];
                }
                l[r] = corr * l[r] + row_sum;
                for c in 0..dv {
                    let mut acc = o.at(r, c) * corr;
                    for (kk, &pv) in p.iter().enumerate() {
                        acc += pv * vj.at(kk, c);
                    }
                    *o.at_mut(r, c) = acc;
                }
                m[r] = m_new;
            }
        }
        for r in 0..(r1 - r0) {
            for c in 0..dv {
                *out.at_mut(r0 + r, c) = o.at(r, c) / l[r];
            }
        }
    }
    out
}

/// FlatAttention (Algorithm 2): the group-distributed version. Each group
/// tile (x, y) owns Q slice `iy` and K/V slice `jx`; row-wise *collective*
/// reductions produce the global row max, the global denominator and the
/// summed O slices. Exactly mirrors the per-tile slicing of the dataflow.
pub fn flat_attention(q: &Mat, k: &Mat, v: &Mat, t: &FlatTiling) -> Mat {
    let s_q = q.rows;
    let s_kv = k.rows;
    let d = q.cols;
    let dv = v.cols;
    let scale = 1.0 / (d as f32).sqrt();
    let gx = t.gx as usize;
    let gy = t.gy as usize;
    let br_blk = (t.block_r() as usize).min(s_q.max(1));
    let bc_blk = (t.block_c() as usize).min(s_kv.max(1));
    let mut out = Mat::zeros(s_q, dv);

    let t_r = s_q.div_ceil(br_blk);
    let t_c = s_kv.div_ceil(bc_blk);
    for i in 0..t_r {
        let r0 = i * br_blk;
        let r1 = (r0 + br_blk).min(s_q);
        // Row-slice boundaries per group row y.
        let rows_here = r1 - r0;
        let sl_r = rows_here.div_ceil(gy);
        let y_bounds: Vec<(usize, usize)> =
            (0..gy).map(|y| (r0 + (y * sl_r).min(rows_here), r0 + ((y + 1) * sl_r).min(rows_here))).collect();

        // Per-tile state: O accumulator, m, l per group row.
        let mut o: Vec<Vec<Mat>> = (0..gy)
            .map(|y| {
                let (a, b) = y_bounds[y];
                (0..gx).map(|_| Mat::zeros(b - a, dv)).collect()
            })
            .collect();
        let mut m: Vec<Vec<f32>> = y_bounds.iter().map(|&(a, b)| vec![f32::NEG_INFINITY; b - a]).collect();
        let mut l: Vec<Vec<f32>> = y_bounds.iter().map(|&(a, b)| vec![0.0f32; b - a]).collect();

        for j in 0..t_c {
            let c0 = j * bc_blk;
            let c1 = (c0 + bc_blk).min(s_kv);
            let cols_here = c1 - c0;
            let sl_c = cols_here.div_ceil(gx);
            let x_bounds: Vec<(usize, usize)> =
                (0..gx).map(|x| (c0 + (x * sl_c).min(cols_here), c0 + ((x + 1) * sl_c).min(cols_here))).collect();

            for y in 0..gy {
                let (a, b) = y_bounds[y];
                if a == b {
                    continue;
                }
                let qy = q.rows_slice(a, b);
                // Per-tile local scores and rowmax (lines 10–13).
                let s_tiles: Vec<Mat> = (0..gx)
                    .map(|x| {
                        let (ca, cb) = x_bounds[x];
                        if ca == cb {
                            Mat::zeros(b - a, 0)
                        } else {
                            qy.matmul_t(&k.rows_slice(ca, cb)).scale(scale)
                        }
                    })
                    .collect();
                // Row-wise max REDUCTION across the group row (line 15) and
                // multicast back (line 16).
                let mut m_new = m[y].clone();
                for s_t in &s_tiles {
                    for r in 0..s_t.rows {
                        for c in 0..s_t.cols {
                            m_new[r] = m_new[r].max(s_t.at(r, c));
                        }
                    }
                }
                // exp + local rowsum (17–18), sum REDUCTION (19–20).
                let mut p_tiles: Vec<Mat> = Vec::with_capacity(gx);
                let mut lsum = vec![0.0f32; b - a];
                for s_t in &s_tiles {
                    let mut p = s_t.clone();
                    for r in 0..p.rows {
                        for c in 0..p.cols {
                            *p.at_mut(r, c) = (p.at(r, c) - m_new[r]).exp();
                            lsum[r] += p.at(r, c);
                        }
                    }
                    p_tiles.push(p);
                }
                // Tracking-stat update (22) and O rescale + accumulate (23–25).
                for r in 0..(b - a) {
                    let corr = if m[y][r] == f32::NEG_INFINITY { 0.0 } else { (m[y][r] - m_new[r]).exp() };
                    l[y][r] = corr * l[y][r] + lsum[r];
                    m[y][r] = m_new[r];
                    for x in 0..gx {
                        for c in 0..dv {
                            *o[y][x].at_mut(r, c) *= corr;
                        }
                    }
                }
                for x in 0..gx {
                    let (ca, cb) = x_bounds[x];
                    if ca == cb {
                        continue;
                    }
                    let vx = v.rows_slice(ca, cb);
                    let pv = p_tiles[x].matmul(&vx);
                    o[y][x] = o[y][x].add(&pv);
                }
            }
        }

        // Epilogue: normalize (line 28), row-wise O REDUCTION (29), store (30).
        for y in 0..gy {
            let (a, b) = y_bounds[y];
            for r in 0..(b - a) {
                let inv = 1.0 / l[y][r];
                for c in 0..dv {
                    let sum: f32 = (0..gx).map(|x| o[y][x].at(r, c)).sum();
                    *out.at_mut(a + r, c) = sum * inv;
                }
            }
        }
    }
    out
}

/// MLA weight-absorbed attention (paper Eq. 7–8 / Appendix A), functional:
/// given per-head absorbed queries `q_abs[h] ∈ (sq, d_c+d_rope)` and the
/// shared latent cache `c_kv ∈ (kv, d_c+d_rope)` with value part
/// `c_kv[:, :d_c]`, compute per-head outputs in the latent space.
pub fn mla_absorbed_attention(q_abs: &[Mat], c_kv: &Mat, d_c: usize, causal: bool) -> Vec<Mat> {
    let v_latent = c_kv.cols_slice(0, d_c);
    q_abs.iter().map(|qh| reference_attention(qh, c_kv, &v_latent, causal)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SplitMix64;

    fn qkv(sq: usize, skv: usize, d: usize, dv: usize, seed: u64) -> (Mat, Mat, Mat) {
        let mut rng = SplitMix64::new(seed);
        (Mat::random(sq, d, &mut rng), Mat::random(skv, d, &mut rng), Mat::random(skv, dv, &mut rng))
    }

    #[test]
    fn flash_matches_reference() {
        let (q, k, v) = qkv(64, 96, 32, 32, 3);
        let r = reference_attention(&q, &k, &v, false);
        let f = flash_attention(&q, &k, &v, 16, 24);
        assert!(f.max_abs_diff(&r) < 1e-4, "diff {}", f.max_abs_diff(&r));
    }

    #[test]
    fn flash_uneven_blocks() {
        let (q, k, v) = qkv(50, 70, 16, 24, 4);
        let r = reference_attention(&q, &k, &v, false);
        let f = flash_attention(&q, &k, &v, 16, 32);
        assert!(f.max_abs_diff(&r) < 1e-4);
    }

    #[test]
    fn flat_matches_reference_full_group() {
        let (q, k, v) = qkv(64, 64, 32, 32, 5);
        let r = reference_attention(&q, &k, &v, false);
        let t = FlatTiling { gx: 4, gy: 4, slice_r: 16, slice_c: 16 };
        let f = flat_attention(&q, &k, &v, &t);
        assert!(f.max_abs_diff(&r) < 1e-4, "diff {}", f.max_abs_diff(&r));
    }

    #[test]
    fn flat_matches_reference_multi_block() {
        // Group covers only part of the problem → multiple outer/inner
        // blocks exercise the online rescaling across iterations.
        let (q, k, v) = qkv(96, 128, 16, 16, 6);
        let t = FlatTiling { gx: 2, gy: 2, slice_r: 16, slice_c: 16 };
        let r = reference_attention(&q, &k, &v, false);
        let f = flat_attention(&q, &k, &v, &t);
        assert!(f.max_abs_diff(&r) < 1e-4, "diff {}", f.max_abs_diff(&r));
    }

    #[test]
    fn flat_single_row_group_decode() {
        // Decode: one query row, single-row group (paper §III-D).
        let (q, k, v) = qkv(1, 256, 64, 64, 7);
        let t = FlatTiling { gx: 8, gy: 1, slice_r: 1, slice_c: 16 };
        let r = reference_attention(&q, &k, &v, false);
        let f = flat_attention(&q, &k, &v, &t);
        assert!(f.max_abs_diff(&r) < 1e-4);
    }

    #[test]
    fn flat_equals_flash() {
        let (q, k, v) = qkv(64, 80, 24, 24, 8);
        let fl = flash_attention(&q, &k, &v, 16, 16);
        let t = FlatTiling { gx: 4, gy: 4, slice_r: 8, slice_c: 8 };
        let ft = flat_attention(&q, &k, &v, &t);
        assert!(ft.max_abs_diff(&fl) < 1e-4);
    }

    #[test]
    fn mla_absorbed_runs() {
        let mut rng = SplitMix64::new(9);
        let d_c = 16;
        let d_rope = 4;
        let c_kv = Mat::random(32, d_c + d_rope, &mut rng);
        let q_abs: Vec<Mat> = (0..4).map(|_| Mat::random(2, d_c + d_rope, &mut rng)).collect();
        let outs = mla_absorbed_attention(&q_abs, &c_kv, d_c, false);
        assert_eq!(outs.len(), 4);
        assert_eq!(outs[0].cols, d_c);
    }

    #[test]
    fn causal_mask_reference() {
        let (q, k, v) = qkv(8, 8, 8, 8, 10);
        let r = reference_attention(&q, &k, &v, true);
        // First row attends only to first key → equals v[0] after softmax of
        // a single element.
        for c in 0..8 {
            assert!((r.at(0, c) - v.at(0, c)).abs() < 1e-5);
        }
    }
}
