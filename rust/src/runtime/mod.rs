//! PJRT runtime: load and execute AOT artifacts (HLO text).
pub mod pjrt;
pub mod artifacts;
