//! PJRT runtime: load and execute AOT artifacts (HLO text).
//!
//! The real PJRT client needs external `xla` (xla_extension) bindings that
//! are not vendored into the offline build; they sit behind the `pjrt`
//! cargo feature. The default build substitutes [`pjrt_stub`] (re-exported
//! under the same `pjrt` path), whose `HloExecutable::load` returns a clear
//! error — callers already treat missing artifacts/runtime as a skip.
#[cfg(feature = "pjrt")]
pub mod pjrt;
#[cfg(not(feature = "pjrt"))]
#[path = "pjrt_stub.rs"]
pub mod pjrt;
pub mod artifacts;
