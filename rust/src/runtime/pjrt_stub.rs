//! Stub PJRT runtime used when the `pjrt` feature (and with it the external
//! `xla` bindings) is disabled. API-compatible with the real
//! `runtime::pjrt`; every entry point fails with an actionable message, and
//! callers that probe artifacts first never reach these paths.

use std::path::Path;

use anyhow::{bail, Result};

use crate::exec::tensor::Mat;

/// Placeholder for the compiled-HLO executable of the real runtime.
pub struct HloExecutable {
    name: String,
}

impl HloExecutable {
    /// Always fails: the offline build carries no PJRT client.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        bail!(
            "PJRT runtime unavailable: built without the `pjrt` feature (artifact {}); \
             rebuild with `--features pjrt` and the xla_extension bindings",
            path.as_ref().display()
        )
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn platform(&self) -> String {
        "stub".into()
    }

    pub fn run_f32(&self, _inputs: &[&Mat], _out_rows: usize, _out_cols: usize) -> Result<Mat> {
        bail!("PJRT runtime unavailable (stub build)")
    }

    pub fn run_f32_raw(&self, _inputs: &[(&[f32], Vec<i64>)]) -> Result<Vec<f32>> {
        bail!("PJRT runtime unavailable (stub build)")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_reports_feature_hint() {
        let e = match HloExecutable::load("artifacts/mha_prefill.hlo.txt") {
            Err(e) => e,
            Ok(_) => panic!("stub must refuse to load"),
        };
        assert!(format!("{e:#}").contains("pjrt"));
    }
}
