//! PJRT runtime: load AOT-compiled HLO **text** artifacts (produced once by
//! `python/compile/aot.py`) and execute them on the PJRT CPU client.
//!
//! Interchange is HLO text, not serialized `HloModuleProto`: jax ≥ 0.5 emits
//! protos with 64-bit instruction ids which xla_extension 0.5.1 rejects; the
//! text parser reassigns ids (see /opt/xla-example/README.md).

use std::path::Path;

use anyhow::{Context, Result};

use crate::exec::tensor::Mat;

/// A compiled HLO executable on the PJRT CPU client.
pub struct HloExecutable {
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

impl HloExecutable {
    /// Load an HLO text file and compile it for CPU.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("compiling HLO")?;
        Ok(HloExecutable {
            client,
            exe,
            name: path.file_stem().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default(),
        })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute with f32 matrix inputs; the artifact was lowered with
    /// `return_tuple=True`, so the single output is a 1-tuple whose element
    /// is returned reshaped as (rows, cols).
    pub fn run_f32(&self, inputs: &[&Mat], out_rows: usize, out_cols: usize) -> Result<Mat> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|m| {
                let lit = xla::Literal::vec1(&m.data);
                lit.reshape(&[m.rows as i64, m.cols as i64]).context("reshape input")
            })
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals).context("executing")?[0][0]
            .to_literal_sync()
            .context("fetching result")?;
        let out = result.to_tuple1().context("unwrapping 1-tuple output")?;
        let values = out.to_vec::<f32>().context("reading f32 output")?;
        anyhow::ensure!(
            values.len() == out_rows * out_cols,
            "output size {} != {}x{}",
            values.len(),
            out_rows,
            out_cols
        );
        Ok(Mat::from_vec(out_rows, out_cols, values))
    }

    /// Execute with 3-D f32 inputs flattened row-major as (dim0·rows, cols)
    /// matrices; shape bookkeeping is the caller's.
    pub fn run_f32_raw(&self, inputs: &[(&[f32], Vec<i64>)]) -> Result<Vec<f32>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, dims)| {
                let lit = xla::Literal::vec1(data);
                lit.reshape(dims).context("reshape input")
            })
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals).context("executing")?[0][0]
            .to_literal_sync()
            .context("fetching result")?;
        let out = result.to_tuple1().context("unwrapping 1-tuple output")?;
        Ok(out.to_vec::<f32>().context("reading f32 output")?)
    }
}
