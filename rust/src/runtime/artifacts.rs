//! Artifact registry: locates the HLO text files produced by
//! `make artifacts` (`python/compile/aot.py`). The Rust binary never runs
//! Python; if an artifact is missing the caller gets a clear error telling
//! it to run `make artifacts`.

use std::path::{Path, PathBuf};

use anyhow::Result;

/// Known artifacts and their entry-point metadata (must stay in sync with
/// `python/compile/aot.py`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Artifact {
    /// MHA forward via the Pallas FlatAttention kernel.
    /// Inputs: q (S×D), k (S×D), v (S×D); output (S×D). S=256, D=64.
    MhaPrefill,
    /// GQA decode: q (G·sp × D), k/v (KV×D); output (G·sp × D).
    GqaDecode,
    /// MLA weight-absorbed decode core: q_abs (R×(dc+dr)), c_kv (KV×(dc+dr));
    /// output (R×dc).
    MlaDecode,
    /// Dense reference attention (pure jnp, no Pallas) — used to check the
    /// kernel artifact against an independently lowered graph.
    MhaReference,
}

impl Artifact {
    pub fn all() -> [Artifact; 4] {
        [Artifact::MhaPrefill, Artifact::GqaDecode, Artifact::MlaDecode, Artifact::MhaReference]
    }

    pub fn file_name(self) -> &'static str {
        match self {
            Artifact::MhaPrefill => "mha_prefill.hlo.txt",
            Artifact::GqaDecode => "gqa_decode.hlo.txt",
            Artifact::MlaDecode => "mla_decode.hlo.txt",
            Artifact::MhaReference => "mha_reference.hlo.txt",
        }
    }
}

/// Directory containing artifacts: `$FLATATTENTION_ARTIFACTS` or
/// `<repo>/artifacts` (relative to the current directory, walking up).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(d) = std::env::var("FLATATTENTION_ARTIFACTS") {
        return PathBuf::from(d);
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = dir.join("artifacts");
        if cand.is_dir() {
            return cand;
        }
        if !dir.pop() {
            return PathBuf::from("artifacts");
        }
    }
}

/// Full path of an artifact, verifying it exists.
pub fn artifact_path(a: Artifact) -> Result<PathBuf> {
    let p = artifacts_dir().join(a.file_name());
    anyhow::ensure!(
        p.is_file(),
        "artifact {} not found at {} — run `make artifacts` first",
        a.file_name(),
        p.display()
    );
    Ok(p)
}

/// True if every artifact is present.
pub fn artifacts_ready() -> bool {
    Artifact::all().iter().all(|a| artifacts_dir().join(a.file_name()).is_file())
}

/// Check a path exists (test helper for non-registry artifacts).
pub fn ensure_file(p: &Path) -> Result<()> {
    anyhow::ensure!(p.is_file(), "missing file {}", p.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn file_names_unique() {
        let names: Vec<_> = Artifact::all().iter().map(|a| a.file_name()).collect();
        let mut d = names.clone();
        d.sort();
        d.dedup();
        assert_eq!(d.len(), names.len());
    }

    #[test]
    fn artifacts_dir_env_override() {
        std::env::set_var("FLATATTENTION_ARTIFACTS", "/tmp/fa-test-artifacts");
        assert_eq!(artifacts_dir(), PathBuf::from("/tmp/fa-test-artifacts"));
        std::env::remove_var("FLATATTENTION_ARTIFACTS");
    }
}
