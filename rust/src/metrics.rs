//! Cross-cutting performance metrics derived from simulation results.

use crate::arch::config::ChipConfig;
use crate::sim::SimResult;

/// Metrics of one kernel execution on one chip, in chip-relative terms.
#[derive(Debug, Clone)]
pub struct KernelMetrics {
    pub cycles: u64,
    pub seconds: f64,
    /// Achieved FLOP/s over the whole kernel.
    pub tflops: f64,
    /// Achieved fraction of the chip's peak FLOP/s.
    pub compute_utilization: f64,
    /// Average HBM bandwidth utilization over the kernel runtime.
    pub hbm_bw_utilization: f64,
    pub hbm_bytes: u64,
    pub noc_bytes: u64,
    /// Matrix-engine utilization counting only engines with work.
    pub matrix_utilization_active: f64,
    /// FLOP efficiency of the matrix engines *while busy* (the paper's
    /// "utilization of the matrix engine when active" labels in Fig. 9):
    /// achieved FLOPs over busy-cycles × peak rate.
    pub matrix_efficiency_active: f64,
    /// Exposed-time breakdown (cycles): [gemm, vector, hbm, noc, other].
    pub exposed: [u64; 5],
}

impl KernelMetrics {
    pub fn from_sim(cfg: &ChipConfig, r: &SimResult) -> Self {
        let seconds = cfg.cycles_to_seconds(r.makespan);
        let tflops = if seconds > 0.0 { r.flops as f64 / seconds / 1e12 } else { 0.0 };
        let hbm_bw = if r.makespan > 0 {
            r.hbm_bytes() as f64 / (r.makespan as f64 * cfg.hbm_bytes_per_cycle())
        } else {
            0.0
        };
        let matrix_efficiency_active = if r.matrix_busy > 0 {
            r.flops as f64 / (r.matrix_busy as f64 * cfg.tile.matrix_flops_per_cycle() as f64)
        } else {
            0.0
        };
        KernelMetrics {
            cycles: r.makespan,
            seconds,
            tflops,
            compute_utilization: if seconds > 0.0 { tflops * 1e12 / cfg.peak_flops() } else { 0.0 },
            hbm_bw_utilization: hbm_bw,
            hbm_bytes: r.hbm_bytes(),
            noc_bytes: r.noc_bytes,
            matrix_utilization_active: r.matrix_utilization_active(),
            matrix_efficiency_active: matrix_efficiency_active.min(1.0),
            exposed: [
                r.exposed.get(crate::sim::Category::Gemm),
                r.exposed.get(crate::sim::Category::Vector),
                r.exposed.hbm_exposed(),
                r.exposed.noc_exposed(),
                r.exposed.other_exposed(),
            ],
        }
    }

    /// Speedup of `self` over `other` (runtime ratio).
    pub fn speedup_over(&self, other: &KernelMetrics) -> f64 {
        if self.seconds == 0.0 {
            return f64::INFINITY;
        }
        other.seconds / self.seconds
    }
}

/// Latency-distribution summary (milliseconds or any unit the caller used):
/// the serving layer's TTFT/TPOT headline numbers.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Percentiles {
    pub n: usize,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Percentiles {
    /// Nearest-rank percentiles over `values` (order irrelevant; empty input
    /// yields all-zero summary).
    pub fn from_values(values: &[f64]) -> Self {
        if values.is_empty() {
            return Percentiles::default();
        }
        let mut v: Vec<f64> = values.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).expect("non-finite latency sample"));
        let pick = |q: f64| -> f64 {
            let rank = (q * v.len() as f64).ceil() as usize;
            v[rank.clamp(1, v.len()) - 1]
        };
        Percentiles {
            n: v.len(),
            mean: v.iter().sum::<f64>() / v.len() as f64,
            p50: pick(0.50),
            p95: pick(0.95),
            p99: pick(0.99),
            max: *v.last().unwrap(),
        }
    }
}

/// Pretty-print a ratio as `x.x×`.
pub fn fmt_speedup(x: f64) -> String {
    format!("{x:.1}×")
}

/// Pretty-print a fraction as a percentage.
pub fn fmt_pct(x: f64) -> String {
    format!("{:.1}%", 100.0 * x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{Category, Graph, Op, ResourceKind, ResourceTable};

    #[test]
    fn metrics_from_trivial_sim() {
        let cfg = ChipConfig::table1();
        let mut t = ResourceTable::new();
        let m = t.add(ResourceKind::MatrixEngine(0));
        let mut g = Graph::new(t);
        g.push(Op::new(Some(m), 1000, Category::Gemm).flops(1000 * 1024), &[]);
        let r = g.simulate();
        let k = KernelMetrics::from_sim(&cfg, &r);
        assert_eq!(k.cycles, 1000);
        // One tile at full rate = 1/1024 of chip peak.
        assert!((k.compute_utilization - 1.0 / 1024.0).abs() < 1e-6);
        assert!((k.matrix_utilization_active - 1.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles_nearest_rank() {
        let vals: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let p = Percentiles::from_values(&vals);
        assert_eq!(p.n, 100);
        assert_eq!(p.p50, 50.0);
        assert_eq!(p.p95, 95.0);
        assert_eq!(p.p99, 99.0);
        assert_eq!(p.max, 100.0);
        assert!((p.mean - 50.5).abs() < 1e-12);
    }

    #[test]
    fn percentiles_small_and_empty() {
        assert_eq!(Percentiles::from_values(&[]), Percentiles::default());
        let p = Percentiles::from_values(&[7.0]);
        assert_eq!((p.p50, p.p95, p.p99, p.max), (7.0, 7.0, 7.0, 7.0));
        // Unsorted input is handled.
        let p = Percentiles::from_values(&[3.0, 1.0, 2.0]);
        assert_eq!(p.p50, 2.0);
        assert_eq!(p.p99, 3.0);
    }

    #[test]
    fn speedup_ratio() {
        let a = KernelMetrics {
            cycles: 100,
            seconds: 1.0,
            tflops: 0.0,
            compute_utilization: 0.0,
            hbm_bw_utilization: 0.0,
            hbm_bytes: 0,
            noc_bytes: 0,
            matrix_utilization_active: 0.0,
            matrix_efficiency_active: 0.0,
            exposed: [0; 5],
        };
        let mut b = a.clone();
        b.seconds = 2.0;
        assert!((a.speedup_over(&b) - 2.0).abs() < 1e-12);
    }
}
