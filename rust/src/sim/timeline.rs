//! Busy-interval accounting for runtime breakdowns.
//!
//! The paper's Fig. 8 / Fig. 9 stacked bars report, per category, the time
//! *not overlapped* with higher-priority categories ("runtime not overlapped
//! with matrix engine", "… with either vector or matrix engine"). We record
//! +1/−1 deltas per category at op start/finish and compute the masked
//! exposure in one sweep over the sorted deltas.

use super::engine::Category;
use super::Cycles;

/// Raw activity deltas collected during simulation.
#[derive(Debug, Default, Clone)]
pub struct Timeline {
    /// (time, category index, delta ±1)
    deltas: Vec<(Cycles, u8, i8)>,
}

impl Timeline {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, start: Cycles, finish: Cycles, cat: Category) {
        debug_assert!(finish >= start);
        if finish == start {
            return;
        }
        let c = cat.index() as u8;
        self.deltas.push((start, c, 1));
        self.deltas.push((finish, c, -1));
    }

    /// Number of recorded interval endpoints (2 per nonzero-length op).
    pub fn len(&self) -> usize {
        self.deltas.len()
    }
    pub fn is_empty(&self) -> bool {
        self.deltas.is_empty()
    }

    /// Compute the priority-masked exposed time per category.
    pub fn exposed_breakdown(&self) -> ExposedBreakdown {
        let mut deltas = self.deltas.clone();
        // Sort by time; at equal time apply −1 before +1 so that
        // back-to-back intervals do not create spurious overlap, except that
        // masking is insensitive to this for exposure sums (we process whole
        // segments between distinct timestamps).
        deltas.sort_unstable_by_key(|&(t, c, d)| (t, c, d));

        let mut active = [0i64; Category::COUNT];
        let mut exposed = [0u64; Category::COUNT];
        let mut union_busy: Cycles = 0;
        let mut i = 0;
        let mut last_t: Cycles = 0;
        let n = deltas.len();
        while i < n {
            let t = deltas[i].0;
            if t > last_t {
                let span = t - last_t;
                // Highest-priority active category claims the span.
                let mut any = false;
                for (pi, cat) in Category::PRIORITY.iter().enumerate() {
                    let _ = cat;
                    if active[pi] > 0 {
                        exposed[pi] += span;
                        any = true;
                        break;
                    }
                }
                if any {
                    union_busy += span;
                }
            }
            last_t = t;
            while i < n && deltas[i].0 == t {
                let (_, c, d) = deltas[i];
                active[c as usize] += d as i64;
                i += 1;
            }
        }
        ExposedBreakdown { per_cat: exposed, union_busy }
    }
}

/// Priority-masked exposure per category.
#[derive(Debug, Clone, Default)]
pub struct ExposedBreakdown {
    /// Exposed cycles per [`Category::PRIORITY`] index. `per_cat[Gemm]` is
    /// the total time at least one matrix engine was active; `per_cat[Vector]`
    /// is vector-active time *not* overlapped with any matrix engine; etc.
    pub per_cat: [u64; Category::COUNT],
    /// Time at least one op of any category was active.
    pub union_busy: Cycles,
}

impl ExposedBreakdown {
    pub fn get(&self, cat: Category) -> u64 {
        self.per_cat[cat.index()]
    }

    /// Exposed HBM time (read + write, not overlapped with compute).
    pub fn hbm_exposed(&self) -> u64 {
        self.get(Category::HbmRead) + self.get(Category::HbmWrite)
    }

    /// Exposed NoC time (collective + unicast).
    pub fn noc_exposed(&self) -> u64 {
        self.get(Category::NocCollective) + self.get(Category::NocUnicast)
    }

    /// Remaining control/sync exposure.
    pub fn other_exposed(&self) -> u64 {
        self.get(Category::DmaIssue) + self.get(Category::Sync) + self.get(Category::D2d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_timeline() {
        let t = Timeline::new();
        let e = t.exposed_breakdown();
        assert_eq!(e.union_busy, 0);
        assert!(e.per_cat.iter().all(|&x| x == 0));
    }

    #[test]
    fn non_overlapping_intervals() {
        let mut t = Timeline::new();
        t.record(0, 10, Category::Gemm);
        t.record(10, 15, Category::Vector);
        let e = t.exposed_breakdown();
        assert_eq!(e.get(Category::Gemm), 10);
        assert_eq!(e.get(Category::Vector), 5);
        assert_eq!(e.union_busy, 15);
    }

    #[test]
    fn lower_priority_is_masked() {
        let mut t = Timeline::new();
        t.record(0, 10, Category::Gemm);
        t.record(5, 20, Category::HbmRead); // 5 cycles overlap
        let e = t.exposed_breakdown();
        assert_eq!(e.get(Category::Gemm), 10);
        assert_eq!(e.get(Category::HbmRead), 10); // 10..20 exposed
        assert_eq!(e.union_busy, 20);
    }

    #[test]
    fn vector_masks_hbm_but_not_gemm() {
        let mut t = Timeline::new();
        t.record(0, 4, Category::Vector);
        t.record(2, 8, Category::HbmRead);
        t.record(6, 10, Category::Gemm);
        let e = t.exposed_breakdown();
        assert_eq!(e.get(Category::Gemm), 4); // 6..10
        assert_eq!(e.get(Category::Vector), 4); // 0..4
        assert_eq!(e.get(Category::HbmRead), 2); // 4..6 only
        assert_eq!(e.union_busy, 10);
    }

    #[test]
    fn overlapping_same_category_counts_once() {
        let mut t = Timeline::new();
        t.record(0, 10, Category::Gemm);
        t.record(5, 15, Category::Gemm);
        let e = t.exposed_breakdown();
        assert_eq!(e.get(Category::Gemm), 15);
        assert_eq!(e.union_busy, 15);
    }

    #[test]
    fn zero_length_records_ignored() {
        let mut t = Timeline::new();
        t.record(5, 5, Category::Gemm);
        assert!(t.is_empty());
    }
}
