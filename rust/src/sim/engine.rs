//! The discrete-event engine: op DAGs over FIFO resource servers.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::timeline::{ExposedBreakdown, Timeline};
use super::Cycles;

/// Index of an op in its [`Graph`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct OpId(pub u32);

/// Index of a resource in the [`ResourceTable`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ResourceId(pub u32);

/// What a resource physically is. Used for utilization reporting only; the
/// engine itself treats every resource as an exclusive FIFO server.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ResourceKind {
    /// A tile's matrix engine (RedMulE). `0` = flat tile index.
    MatrixEngine(u32),
    /// A tile's vector engine (Spatz cluster).
    VectorEngine(u32),
    /// A tile's DMA command queue (issue side).
    Dma(u32),
    /// One HBM channel (index within the chip).
    HbmChannel(u32),
    /// NoC path used by row-wise collectives of mesh row `r`.
    NocRow(u32),
    /// NoC path used by column-wise collectives of mesh column `c`.
    NocCol(u32),
    /// A D2D link or generic chip-level resource (multichip model).
    D2dLink(u32),
    /// Anything else (barriers placed on a resource, test fixtures, …).
    Generic(u32),
}

/// Behavioural category of an op; drives the runtime-breakdown accounting.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Category {
    /// Matrix-engine GEMM work.
    Gemm,
    /// Vector-engine work (softmax pieces: rowmax, exp, rowsum, rescale).
    Vector,
    /// HBM read occupancy.
    HbmRead,
    /// HBM write occupancy.
    HbmWrite,
    /// On-chip collective transfer (multicast / reduction) occupancy.
    NocCollective,
    /// On-chip point-to-point transfer occupancy (SW collectives lower here).
    NocUnicast,
    /// DMA issue / descriptor setup.
    DmaIssue,
    /// Synchronization / barrier / control overhead.
    Sync,
    /// D2D chip-to-chip transfer (multichip model).
    D2d,
}

impl Category {
    /// All categories, in the priority order used for exposed-time masking
    /// (earlier = higher priority; the paper's breakdown bars mask lower
    /// categories by "not overlapped with matrix engine" etc.).
    pub const PRIORITY: [Category; 9] = [
        Category::Gemm,
        Category::Vector,
        Category::HbmRead,
        Category::HbmWrite,
        Category::NocCollective,
        Category::NocUnicast,
        Category::DmaIssue,
        Category::Sync,
        Category::D2d,
    ];

    /// Stable dense index (for array-backed accounting).
    pub fn index(self) -> usize {
        Self::PRIORITY.iter().position(|c| *c == self).unwrap()
    }

    pub const COUNT: usize = 9;
}

/// A single block-level operation.
#[derive(Debug, Clone)]
pub struct Op {
    /// Resource this op occupies exclusively for `duration` cycles.
    /// `None` = no contention (barriers, joins): starts as soon as ready.
    pub resource: Option<ResourceId>,
    /// Service time in cycles (fixed; queueing adds on top).
    pub duration: Cycles,
    pub category: Category,
    /// FLOPs performed (GEMM / vector ops) — for utilization metrics.
    pub flops: u64,
    /// Bytes moved (DMA / NoC / HBM ops) — for traffic metrics.
    pub bytes: u64,
}

impl Op {
    pub fn new(resource: Option<ResourceId>, duration: Cycles, category: Category) -> Self {
        Op { resource, duration, category, flops: 0, bytes: 0 }
    }
    pub fn flops(mut self, f: u64) -> Self {
        self.flops = f;
        self
    }
    pub fn bytes(mut self, b: u64) -> Self {
        self.bytes = b;
        self
    }
}

/// Resource declarations for a graph.
#[derive(Debug, Clone, Default)]
pub struct ResourceTable {
    kinds: Vec<ResourceKind>,
}

impl ResourceTable {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn add(&mut self, kind: ResourceKind) -> ResourceId {
        self.kinds.push(kind);
        ResourceId((self.kinds.len() - 1) as u32)
    }
    pub fn len(&self) -> usize {
        self.kinds.len()
    }
    pub fn is_empty(&self) -> bool {
        self.kinds.is_empty()
    }
    pub fn kind(&self, id: ResourceId) -> ResourceKind {
        self.kinds[id.0 as usize]
    }
    pub fn iter(&self) -> impl Iterator<Item = (ResourceId, ResourceKind)> + '_ {
        self.kinds.iter().enumerate().map(|(i, k)| (ResourceId(i as u32), *k))
    }
}

/// An op DAG under construction.
pub struct Graph {
    pub resources: ResourceTable,
    ops: Vec<Op>,
    /// Flattened dependency lists: `deps[dep_ranges[i].0..dep_ranges[i].1]`.
    deps: Vec<OpId>,
    dep_ranges: Vec<(u32, u32)>,
}

impl Graph {
    pub fn new(resources: ResourceTable) -> Self {
        Graph { resources, ops: Vec::new(), deps: Vec::new(), dep_ranges: Vec::new() }
    }

    /// Add an op depending on `deps`; returns its id.
    pub fn push(&mut self, op: Op, deps: &[OpId]) -> OpId {
        let id = OpId(self.ops.len() as u32);
        debug_assert!(deps.iter().all(|d| d.0 < id.0), "deps must precede op (DAG by construction)");
        let start = self.deps.len() as u32;
        self.deps.extend_from_slice(deps);
        self.dep_ranges.push((start, self.deps.len() as u32));
        self.ops.push(op);
        id
    }

    /// Zero-duration join of `deps` (no resource).
    pub fn join(&mut self, deps: &[OpId]) -> OpId {
        self.push(Op::new(None, 0, Category::Sync), deps)
    }

    pub fn op_count(&self) -> usize {
        self.ops.len()
    }

    fn deps_of(&self, id: OpId) -> &[OpId] {
        let (s, e) = self.dep_ranges[id.0 as usize];
        &self.deps[s as usize..e as usize]
    }

    /// Run the graph to completion; deterministic.
    pub fn simulate(self) -> SimResult {
        let n = self.ops.len();
        let mut indegree: Vec<u32> = vec![0; n];
        let mut dependents: Vec<Vec<OpId>> = vec![Vec::new(); n];
        for i in 0..n {
            let id = OpId(i as u32);
            for &d in self.deps_of(id) {
                indegree[i] += 1;
                dependents[d.0 as usize].push(id);
            }
        }

        let nres = self.resources.len();
        // Per-resource waiting queue (min-heap by op id for determinism) and
        // busy flag.
        let mut waiting: Vec<BinaryHeap<Reverse<OpId>>> = (0..nres).map(|_| BinaryHeap::new()).collect();
        let mut busy: Vec<bool> = vec![false; nres];

        // Completion event heap: (finish_time, op_id).
        let mut events: BinaryHeap<Reverse<(Cycles, OpId)>> = BinaryHeap::new();

        let mut timeline = Timeline::new();
        let mut busy_by_cat = [0u64; Category::COUNT];
        let mut busy_by_res: Vec<Cycles> = vec![0; nres];
        let mut flops_total: u64 = 0;
        let mut hbm_read_bytes: u64 = 0;
        let mut hbm_write_bytes: u64 = 0;
        let mut noc_bytes: u64 = 0;
        let mut d2d_bytes: u64 = 0;

        let start_op = |op_id: OpId,
                            now: Cycles,
                            ops: &[Op],
                            busy: &mut [bool],
                            events: &mut BinaryHeap<Reverse<(Cycles, OpId)>>,
                            timeline: &mut Timeline,
                            busy_by_cat: &mut [u64; Category::COUNT],
                            busy_by_res: &mut [Cycles]| {
            let op = &ops[op_id.0 as usize];
            let finish = now + op.duration;
            if let Some(r) = op.resource {
                busy[r.0 as usize] = true;
                busy_by_res[r.0 as usize] += op.duration;
            }
            if op.duration > 0 {
                timeline.record(now, finish, op.category);
                busy_by_cat[op.category.index()] += op.duration;
            }
            events.push(Reverse((finish, op_id)));
        };

        // Seed: ops with indegree 0.
        let mut completed = 0usize;
        for i in 0..n {
            if indegree[i] == 0 {
                let id = OpId(i as u32);
                let op = &self.ops[i];
                flops_total += op.flops;
                match op.category {
                    Category::HbmRead => hbm_read_bytes += op.bytes,
                    Category::HbmWrite => hbm_write_bytes += op.bytes,
                    Category::NocCollective | Category::NocUnicast => noc_bytes += op.bytes,
                    Category::D2d => d2d_bytes += op.bytes,
                    _ => {}
                }
                match op.resource {
                    None => start_op(id, 0, &self.ops, &mut busy, &mut events, &mut timeline, &mut busy_by_cat, &mut busy_by_res),
                    Some(r) => waiting[r.0 as usize].push(Reverse(id)),
                }
            } else {
                let op = &self.ops[i];
                flops_total += op.flops;
                match op.category {
                    Category::HbmRead => hbm_read_bytes += op.bytes,
                    Category::HbmWrite => hbm_write_bytes += op.bytes,
                    Category::NocCollective | Category::NocUnicast => noc_bytes += op.bytes,
                    Category::D2d => d2d_bytes += op.bytes,
                    _ => {}
                }
            }
        }
        // Kick idle resources.
        for r in 0..nres {
            if !busy[r] {
                if let Some(Reverse(id)) = waiting[r].pop() {
                    start_op(id, 0, &self.ops, &mut busy, &mut events, &mut timeline, &mut busy_by_cat, &mut busy_by_res);
                }
            }
        }

        let mut makespan: Cycles = 0;
        while let Some(Reverse((t, id))) = events.pop() {
            makespan = makespan.max(t);
            completed += 1;
            let op = &self.ops[id.0 as usize];
            // Free the resource and start the next waiter.
            if let Some(r) = op.resource {
                busy[r.0 as usize] = false;
                if let Some(Reverse(next)) = waiting[r.0 as usize].pop() {
                    start_op(next, t, &self.ops, &mut busy, &mut events, &mut timeline, &mut busy_by_cat, &mut busy_by_res);
                }
            }
            // Release dependents.
            for &dep in &dependents[id.0 as usize] {
                let di = dep.0 as usize;
                indegree[di] -= 1;
                if indegree[di] == 0 {
                    match self.ops[di].resource {
                        None => start_op(dep, t, &self.ops, &mut busy, &mut events, &mut timeline, &mut busy_by_cat, &mut busy_by_res),
                        Some(r) => {
                            waiting[r.0 as usize].push(Reverse(dep));
                            if !busy[r.0 as usize] {
                                let Reverse(next) = waiting[r.0 as usize].pop().unwrap();
                                start_op(next, t, &self.ops, &mut busy, &mut events, &mut timeline, &mut busy_by_cat, &mut busy_by_res);
                            }
                        }
                    }
                }
            }
        }

        assert_eq!(completed, n, "deadlock or dangling dependency: {completed}/{n} ops completed");

        // Matrix-engine aggregate utilization (only over engines that did work:
        // the paper reports utilization of the *active* engines for groups
        // smaller than the mesh).
        let mut matrix_engines = 0u64;
        let mut matrix_busy: Cycles = 0;
        let mut active_matrix_engines = 0u64;
        for (id, kind) in self.resources.iter() {
            if let ResourceKind::MatrixEngine(_) = kind {
                matrix_engines += 1;
                let b = busy_by_res[id.0 as usize];
                matrix_busy += b;
                if b > 0 {
                    active_matrix_engines += 1;
                }
            }
        }

        let exposed = timeline.exposed_breakdown();

        SimResult {
            makespan,
            busy_by_cat,
            exposed,
            busy_by_res,
            flops: flops_total,
            hbm_read_bytes,
            hbm_write_bytes,
            noc_bytes,
            d2d_bytes,
            matrix_engines,
            active_matrix_engines,
            matrix_busy,
            op_count: n as u64,
        }
    }
}

/// Everything the engine measured for one graph execution.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Total runtime in cycles.
    pub makespan: Cycles,
    /// Summed service time per category (overlap *not* removed).
    pub busy_by_cat: [u64; Category::COUNT],
    /// Priority-masked exposed time per category (overlap removed; sums to
    /// ≤ makespan). This is what the paper's stacked bars show.
    pub exposed: ExposedBreakdown,
    /// Busy cycles per resource.
    pub busy_by_res: Vec<Cycles>,
    /// Total FLOPs annotated on ops.
    pub flops: u64,
    pub hbm_read_bytes: u64,
    pub hbm_write_bytes: u64,
    pub noc_bytes: u64,
    pub d2d_bytes: u64,
    pub matrix_engines: u64,
    pub active_matrix_engines: u64,
    pub matrix_busy: Cycles,
    pub op_count: u64,
}

impl SimResult {
    /// Fraction of the makespan during which at least one matrix engine was
    /// busy — the "matrix engine active" share.
    pub fn matrix_active_fraction(&self) -> f64 {
        if self.makespan == 0 {
            return 0.0;
        }
        self.exposed.per_cat[Category::Gemm.index()] as f64 / self.makespan as f64
    }

    /// Average utilization of matrix engines over the whole run
    /// (busy / (engines × makespan)).
    pub fn matrix_utilization(&self) -> f64 {
        if self.makespan == 0 || self.matrix_engines == 0 {
            return 0.0;
        }
        self.matrix_busy as f64 / (self.matrix_engines as f64 * self.makespan as f64)
    }

    /// Utilization counting only engines that were assigned work (the
    /// paper's "utilization of the matrix engine when active" labels).
    pub fn matrix_utilization_active(&self) -> f64 {
        if self.makespan == 0 || self.active_matrix_engines == 0 {
            return 0.0;
        }
        self.matrix_busy as f64 / (self.active_matrix_engines as f64 * self.makespan as f64)
    }

    pub fn hbm_bytes(&self) -> u64 {
        self.hbm_read_bytes + self.hbm_write_bytes
    }

    /// Achieved FLOP/cycle.
    pub fn flops_per_cycle(&self) -> f64 {
        if self.makespan == 0 {
            return 0.0;
        }
        self.flops as f64 / self.makespan as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one_res() -> (ResourceTable, ResourceId) {
        let mut t = ResourceTable::new();
        let r = t.add(ResourceKind::Generic(0));
        (t, r)
    }

    #[test]
    fn empty_graph() {
        let g = Graph::new(ResourceTable::new());
        let r = g.simulate();
        assert_eq!(r.makespan, 0);
        assert_eq!(r.op_count, 0);
    }

    #[test]
    fn serial_chain_adds_durations() {
        let (t, r) = one_res();
        let mut g = Graph::new(t);
        let a = g.push(Op::new(Some(r), 10, Category::Gemm), &[]);
        let b = g.push(Op::new(Some(r), 20, Category::Gemm), &[a]);
        let _c = g.push(Op::new(Some(r), 30, Category::Gemm), &[b]);
        let res = g.simulate();
        assert_eq!(res.makespan, 60);
        assert_eq!(res.busy_by_cat[Category::Gemm.index()], 60);
    }

    #[test]
    fn independent_ops_on_same_resource_serialize() {
        let (t, r) = one_res();
        let mut g = Graph::new(t);
        for _ in 0..5 {
            g.push(Op::new(Some(r), 7, Category::Vector), &[]);
        }
        assert_eq!(g.simulate().makespan, 35);
    }

    #[test]
    fn independent_ops_on_distinct_resources_parallelize() {
        let mut t = ResourceTable::new();
        let rs: Vec<_> = (0..5).map(|i| t.add(ResourceKind::Generic(i))).collect();
        let mut g = Graph::new(t);
        for r in rs {
            g.push(Op::new(Some(r), 7, Category::Vector), &[]);
        }
        assert_eq!(g.simulate().makespan, 7);
    }

    #[test]
    fn diamond_dependency() {
        let mut t = ResourceTable::new();
        let r1 = t.add(ResourceKind::Generic(0));
        let r2 = t.add(ResourceKind::Generic(1));
        let mut g = Graph::new(t);
        let a = g.push(Op::new(Some(r1), 5, Category::Gemm), &[]);
        let b = g.push(Op::new(Some(r1), 10, Category::Gemm), &[a]);
        let c = g.push(Op::new(Some(r2), 3, Category::Vector), &[a]);
        let d = g.push(Op::new(Some(r1), 2, Category::Gemm), &[b, c]);
        let _ = d;
        let res = g.simulate();
        // a:0-5, b:5-15, c:5-8 (parallel), d:15-17
        assert_eq!(res.makespan, 17);
    }

    #[test]
    fn join_is_free() {
        let (t, r) = one_res();
        let mut g = Graph::new(t);
        let a = g.push(Op::new(Some(r), 5, Category::Gemm), &[]);
        let b = g.push(Op::new(Some(r), 5, Category::Gemm), &[]);
        let j = g.join(&[a, b]);
        let _k = g.push(Op::new(Some(r), 1, Category::Vector), &[j]);
        assert_eq!(g.simulate().makespan, 11);
    }

    #[test]
    fn fifo_order_is_deterministic_by_op_id() {
        // Two ops become ready at the same instant; lower id must run first.
        let mut t = ResourceTable::new();
        let r = t.add(ResourceKind::Generic(0));
        let r2 = t.add(ResourceKind::Generic(1));
        let mut g = Graph::new(t);
        let gate = g.push(Op::new(Some(r2), 5, Category::Sync), &[]);
        let a = g.push(Op::new(Some(r), 10, Category::Gemm), &[gate]);
        let b = g.push(Op::new(Some(r), 1, Category::Gemm), &[gate]);
        let _ = (a, b);
        let res = g.simulate();
        // a (id smaller) runs 5-15, b 15-16.
        assert_eq!(res.makespan, 16);
    }

    #[test]
    fn flops_and_bytes_accumulate() {
        let (t, r) = one_res();
        let mut g = Graph::new(t);
        g.push(Op::new(Some(r), 1, Category::Gemm).flops(100), &[]);
        g.push(Op::new(Some(r), 1, Category::HbmRead).bytes(64), &[]);
        g.push(Op::new(Some(r), 1, Category::HbmWrite).bytes(32), &[]);
        let res = g.simulate();
        assert_eq!(res.flops, 100);
        assert_eq!(res.hbm_read_bytes, 64);
        assert_eq!(res.hbm_write_bytes, 32);
        assert_eq!(res.hbm_bytes(), 96);
    }

    #[test]
    fn matrix_utilization_counts_engines() {
        let mut t = ResourceTable::new();
        let m0 = t.add(ResourceKind::MatrixEngine(0));
        let _m1 = t.add(ResourceKind::MatrixEngine(1));
        let mut g = Graph::new(t);
        g.push(Op::new(Some(m0), 10, Category::Gemm), &[]);
        let res = g.simulate();
        assert_eq!(res.makespan, 10);
        assert_eq!(res.matrix_engines, 2);
        assert_eq!(res.active_matrix_engines, 1);
        assert!((res.matrix_utilization() - 0.5).abs() < 1e-12);
        assert!((res.matrix_utilization_active() - 1.0).abs() < 1e-12);
    }
}
