//! Deterministic discrete-event simulation core.
//!
//! The simulator executes *op graphs*: DAGs of block-level operations
//! (a GEMM on a slice, a DMA burst, one collective step, …) over *resources*
//! (a tile's matrix engine, an HBM channel, a NoC row path, …) modeled as
//! FIFO servers. Dataflow schedulers in [`crate::dataflow`] lower kernels to
//! these graphs; the engine computes the makespan, per-category busy time,
//! priority-masked *exposed* time (the paper's "runtime not overlapped with
//! the matrix engine"), HBM traffic, and achieved FLOP/s.
//!
//! Determinism: ties are broken by op id; there is no wall-clock or RNG
//! anywhere in the core.

pub mod engine;
pub mod timeline;

pub use engine::{Category, Graph, Op, OpId, ResourceId, ResourceKind, ResourceTable, SimResult};
pub use timeline::{ExposedBreakdown, Timeline};

/// Simulated clock cycles.
pub type Cycles = u64;
