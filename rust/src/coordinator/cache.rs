//! On-disk persistence for the simulation memo caches — cross-process
//! memoization of kernel simulations and stage times.
//!
//! The in-memory [`KernelCache`] / [`StageTimeCache`] already make repeated
//! sweeps cheap *within* one process; this module closes the ROADMAP's
//! cross-process gap: `--cache-dir DIR` loads a JSON snapshot at startup
//! and writes it back after the run, so a second `flatattention` invocation
//! never re-simulates a kernel shape the first one already priced.
//!
//! Safety of a single shared file: every cache key embeds the full config
//! identity it was computed under — the chip fingerprint, D2D parameters,
//! model, fidelity, dtype, dataflow, plan and operating-point buckets — so
//! entries for different systems can never alias (the same property that
//! lets one in-memory cache back concurrent sweeps of different wafers).
//! The file name carries the schema version ([`SCHEMA_VERSION`]); a file
//! with a different embedded schema is ignored rather than misread.
//!
//! The JSON codec is a deliberately tiny, dependency-free subset (objects,
//! arrays, strings with escapes, numbers): the offline build vendors no
//! serde. Values round-trip exactly — floats are written in shortest
//! `{:e}` form, which `f64::from_str` parses back bit-identically.

use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::metrics::KernelMetrics;
use crate::multichip::parallelism::KernelCache;
use crate::serve::sim::StageTimeCache;

/// Bump when the serialized layout changes; mismatched files are ignored.
pub const SCHEMA_VERSION: u32 = 1;

/// Per-section snapshot cap: at most this many stage entries and this many
/// kernel entries are persisted. Long-lived cache dirs accumulating sweeps
/// over many configs stay bounded instead of growing without limit; the
/// in-memory caches are unaffected. Eviction is deterministic — entries
/// are sorted by key, the first [`MAX_SNAPSHOT_ENTRIES`] survive — so two
/// saves of the same caches still produce identical bytes.
pub const MAX_SNAPSHOT_ENTRIES: usize = 4096;

/// The shared cache pair every serving/cluster experiment draws on.
#[derive(Clone, Default)]
pub struct SimCaches {
    pub kernels: KernelCache,
    pub stages: StageTimeCache,
}

impl SimCaches {
    pub fn fresh() -> Self {
        Self::default()
    }
}

/// Path of the cache file inside `dir` (keyed by schema version; the
/// per-entry config hashes live in the keys themselves).
pub fn cache_path(dir: &Path) -> PathBuf {
    dir.join(format!("flatattention-cache-v{SCHEMA_VERSION}.json"))
}

/// Load the caches persisted under `dir`. A missing file (or one written
/// by a different schema) yields fresh caches; a corrupt file is an error
/// rather than a silent cold start.
pub fn load(dir: &Path) -> Result<SimCaches> {
    let path = cache_path(dir);
    let caches = SimCaches::fresh();
    let text = match std::fs::read_to_string(&path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(caches),
        Err(e) => return Err(e).with_context(|| format!("reading cache file {}", path.display())),
    };
    let doc = JsonValue::parse(&text).with_context(|| format!("parsing cache file {}", path.display()))?;
    let obj = doc.as_object().context("cache root must be a JSON object")?;
    match obj.iter().find(|(k, _)| k == "schema").map(|(_, v)| v) {
        Some(v) if v.as_f64() == Some(SCHEMA_VERSION as f64) => {}
        _ => return Ok(caches), // other schema (or none): start cold, don't misread
    }
    if let Some(stages) = obj.iter().find(|(k, _)| k == "stages").map(|(_, v)| v) {
        for (key, v) in stages.as_object().context("'stages' must be an object")? {
            let s = v.as_f64().with_context(|| format!("stage entry '{key}' is not a number"))?;
            caches.stages.seed(key.clone(), s);
        }
    }
    if let Some(kernels) = obj.iter().find(|(k, _)| k == "kernels").map(|(_, v)| v) {
        for (key, v) in kernels.as_object().context("'kernels' must be an object")? {
            let arr = v.as_array().with_context(|| format!("kernel entry '{key}' is not an array"))?;
            let m = metrics_from_fields(arr)
                .with_context(|| format!("kernel entry '{key}' has a malformed field list"))?;
            caches.kernels.seed(key.clone(), m);
        }
    }
    Ok(caches)
}

/// Persist the caches under `dir` (created if needed). Output is
/// deterministic: entries are sorted by key, floats in shortest `{:e}`
/// form, at most [`MAX_SNAPSHOT_ENTRIES`] per section. The write is
/// atomic (temp file + rename in the same directory), so an interrupted
/// or concurrent save can never leave a truncated file that would fail
/// every subsequent `load`.
pub fn save(dir: &Path, caches: &SimCaches) -> Result<()> {
    save_with_cap(dir, caches, MAX_SNAPSHOT_ENTRIES)
}

/// [`save`] with an explicit per-section entry cap (tests shrink it).
fn save_with_cap(dir: &Path, caches: &SimCaches, cap: usize) -> Result<()> {
    std::fs::create_dir_all(dir).with_context(|| format!("creating cache dir {}", dir.display()))?;
    let mut out = String::new();
    out.push_str(&format!("{{\"schema\":{SCHEMA_VERSION},\"stages\":{{"));
    for (i, (k, s)) in caches.stages.entries().iter().take(cap).enumerate() {
        if i > 0 {
            out.push(',');
        }
        write_json_string(&mut out, k);
        let _ = write!(out, ":{s:e}");
    }
    out.push_str("},\"kernels\":{");
    for (i, (k, m)) in caches.kernels.entries().iter().take(cap).enumerate() {
        if i > 0 {
            out.push(',');
        }
        write_json_string(&mut out, k);
        out.push(':');
        write_metrics_fields(&mut out, m);
    }
    out.push_str("}}\n");
    let path = cache_path(dir);
    let tmp = dir.join(format!("flatattention-cache-v{SCHEMA_VERSION}.json.tmp-{}", std::process::id()));
    std::fs::write(&tmp, out).with_context(|| format!("writing cache file {}", tmp.display()))?;
    std::fs::rename(&tmp, &path)
        .with_context(|| format!("moving cache file into place at {}", path.display()))
}

/// Field order of a serialized [`KernelMetrics`] — integers for the u64
/// fields, shortest-exponential floats for the rest.
fn write_metrics_fields(out: &mut String, m: &KernelMetrics) {
    let _ = write!(
        out,
        "[{},{:e},{:e},{:e},{:e},{},{},{:e},{:e},{},{},{},{},{}]",
        m.cycles,
        m.seconds,
        m.tflops,
        m.compute_utilization,
        m.hbm_bw_utilization,
        m.hbm_bytes,
        m.noc_bytes,
        m.matrix_utilization_active,
        m.matrix_efficiency_active,
        m.exposed[0],
        m.exposed[1],
        m.exposed[2],
        m.exposed[3],
        m.exposed[4],
    );
}

fn metrics_from_fields(arr: &[JsonValue]) -> Result<KernelMetrics> {
    if arr.len() != 14 {
        bail!("expected 14 fields, got {}", arr.len());
    }
    let f = |i: usize| -> Result<f64> { arr[i].as_f64().with_context(|| format!("field {i} is not a number")) };
    let u = |i: usize| -> Result<u64> { arr[i].as_u64().with_context(|| format!("field {i} is not a u64")) };
    Ok(KernelMetrics {
        cycles: u(0)?,
        seconds: f(1)?,
        tflops: f(2)?,
        compute_utilization: f(3)?,
        hbm_bw_utilization: f(4)?,
        hbm_bytes: u(5)?,
        noc_bytes: u(6)?,
        matrix_utilization_active: f(7)?,
        matrix_efficiency_active: f(8)?,
        exposed: [u(9)?, u(10)?, u(11)?, u(12)?, u(13)?],
    })
}

fn write_json_string(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// The JSON subset the cache file uses. Object member order is preserved
/// (a Vec, not a map) — duplicates cannot occur in our own output, and
/// lookups are by linear scan over a 3-member root.
#[derive(Debug, Clone, PartialEq)]
enum JsonValue {
    Number { raw: String },
    String(String),
    Array(Vec<JsonValue>),
    Object(Vec<(String, JsonValue)>),
}

impl JsonValue {
    fn parse(text: &str) -> Result<JsonValue> {
        let mut p = Parser { bytes: text.as_bytes(), i: 0 };
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.bytes.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    fn as_object(&self) -> Option<&[(String, JsonValue)]> {
        match self {
            JsonValue::Object(m) => Some(m),
            _ => None,
        }
    }

    fn as_array(&self) -> Option<&[JsonValue]> {
        match self {
            JsonValue::Array(a) => Some(a),
            _ => None,
        }
    }

    fn as_f64(&self) -> Option<f64> {
        match self {
            JsonValue::Number { raw } => raw.parse().ok(),
            _ => None,
        }
    }

    fn as_u64(&self) -> Option<u64> {
        match self {
            JsonValue::Number { raw } => raw.parse().ok(),
            _ => None,
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while self.i < self.bytes.len() && matches!(self.bytes[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&mut self) -> Result<u8> {
        self.skip_ws();
        self.bytes.get(self.i).copied().with_context(|| "unexpected end of input".to_string())
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        let got = self.peek()?;
        if got != b {
            bail!("expected '{}' at byte {}, found '{}'", b as char, self.i, got as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<JsonValue> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(JsonValue::String(self.string()?)),
            b'-' | b'0'..=b'9' => self.number(),
            other => bail!("unexpected '{}' at byte {}", other as char, self.i),
        }
    }

    fn object(&mut self) -> Result<JsonValue> {
        self.expect(b'{')?;
        let mut members = Vec::new();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(JsonValue::Object(members));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.expect(b':')?;
            let v = self.value()?;
            members.push((key, v));
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(JsonValue::Object(members));
                }
                other => bail!("expected ',' or '}}' at byte {}, found '{}'", self.i, other as char),
            }
        }
    }

    fn array(&mut self) -> Result<JsonValue> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(JsonValue::Array(items));
        }
        loop {
            items.push(self.value()?);
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(JsonValue::Array(items));
                }
                other => bail!("expected ',' or ']' at byte {}, found '{}'", self.i, other as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let Some(&b) = self.bytes.get(self.i) else { bail!("unterminated string") };
            self.i += 1;
            match b {
                b'"' => return Ok(s),
                b'\\' => {
                    let Some(&esc) = self.bytes.get(self.i) else { bail!("unterminated escape") };
                    self.i += 1;
                    match esc {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let end = self.i + 4;
                            let hex = self
                                .bytes
                                .get(self.i..end)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .with_context(|| "truncated \\u escape".to_string())?;
                            let code = u32::from_str_radix(hex, 16).context("bad \\u escape")?;
                            // The writer only emits \u for control chars; any
                            // BMP scalar parses, surrogates are rejected.
                            let c = char::from_u32(code).context("\\u escape is not a scalar value")?;
                            s.push(c);
                            self.i = end;
                        }
                        other => bail!("unknown escape '\\{}'", other as char),
                    }
                }
                _ => {
                    // Collect the full UTF-8 sequence starting at b.
                    let start = self.i - 1;
                    let width = utf8_width(b);
                    let end = start + width;
                    let chunk = self
                        .bytes
                        .get(start..end)
                        .and_then(|c| std::str::from_utf8(c).ok())
                        .with_context(|| format!("invalid UTF-8 at byte {start}"))?;
                    s.push_str(chunk);
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<JsonValue> {
        self.skip_ws();
        let start = self.i;
        while self.i < self.bytes.len()
            && matches!(self.bytes[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let raw = std::str::from_utf8(&self.bytes[start..self.i]).expect("ascii number");
        if raw.parse::<f64>().is_err() {
            bail!("malformed number '{raw}' at byte {start}");
        }
        Ok(JsonValue::Number { raw: raw.to_string() })
    }
}

fn utf8_width(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::config::SimFidelity;
    use crate::multichip::d2d::WaferSystem;
    use crate::multichip::parallelism::{AttentionChoice, DecodeEvaluator, ParallelismPlan};
    use crate::workload::deepseek::DeepSeekConfig;

    fn temp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("flatattention-cache-test-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn missing_file_loads_fresh() {
        let dir = temp_dir("missing");
        let c = load(&dir).expect("missing cache dir is a cold start, not an error");
        assert!(c.kernels.is_empty());
        assert!(c.stages.is_empty());
    }

    #[test]
    fn populated_caches_round_trip_exactly() {
        // Populate both caches with REAL simulation results (a decode
        // evaluation fills kernel entries; stage keys use the production
        // key shape with '|' separators), plus adversarial keys exercising
        // every escape path.
        let dir = temp_dir("roundtrip");
        let caches = SimCaches::fresh();
        let sys = WaferSystem::paper();
        let ds = DeepSeekConfig::v3_671b();
        let mut ev = DecodeEvaluator::with_cache(SimFidelity::Analytic, caches.kernels.clone());
        ev.evaluate(&sys, &ds, ParallelismPlan::new(32, 2), 128, 4096, AttentionChoice::Flat);
        assert!(!caches.kernels.is_empty());
        caches.stages.seed("chip|b128|kv4096".into(), 1.234e-3);
        caches.stages.seed("weird \"quoted\" \\ key\twith\ncontrol \u{1} bytes".into(), 5.5e-7);
        caches.stages.seed("unicode µs — key".into(), f64::MIN_POSITIVE);
        save(&dir, &caches).expect("save");

        let loaded = load(&dir).expect("load");
        assert_eq!(loaded.stages.len(), caches.stages.len());
        assert_eq!(loaded.kernels.len(), caches.kernels.len());
        for ((ka, va), (kb, vb)) in caches.stages.entries().iter().zip(loaded.stages.entries()) {
            assert_eq!(ka, &kb);
            assert!(va.to_bits() == vb.to_bits(), "stage '{ka}' drifted: {va} vs {vb}");
        }
        for ((ka, ma), (kb, mb)) in caches.kernels.entries().iter().zip(loaded.kernels.entries()) {
            assert_eq!(ka, &kb);
            assert_eq!(ma.cycles, mb.cycles);
            assert!(ma.seconds.to_bits() == mb.seconds.to_bits());
            assert!(ma.tflops.to_bits() == mb.tflops.to_bits());
            assert_eq!(ma.hbm_bytes, mb.hbm_bytes);
            assert_eq!(ma.noc_bytes, mb.noc_bytes);
            assert_eq!(ma.exposed, mb.exposed);
        }
        // A warmed evaluator over the loaded cache re-simulates nothing.
        let n = loaded.kernels.len();
        let mut ev2 = DecodeEvaluator::with_cache(SimFidelity::Analytic, loaded.kernels.clone());
        ev2.evaluate(&sys, &ds, ParallelismPlan::new(32, 2), 128, 4096, AttentionChoice::Flat);
        assert_eq!(loaded.kernels.len(), n, "the persisted cache must serve every kernel");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn save_is_deterministic_and_live_entries_win() {
        let dir = temp_dir("determinism");
        let caches = SimCaches::fresh();
        caches.stages.seed("b".into(), 2.0);
        caches.stages.seed("a".into(), 1.0);
        save(&dir, &caches).expect("save");
        let first = std::fs::read_to_string(cache_path(&dir)).unwrap();
        save(&dir, &caches).expect("save again");
        assert_eq!(first, std::fs::read_to_string(cache_path(&dir)).unwrap());
        assert!(first.find("\"a\"").unwrap() < first.find("\"b\"").unwrap(), "sorted by key");
        // The atomic rename leaves no temp residue behind.
        let residue: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().contains(".tmp"))
            .collect();
        assert!(residue.is_empty(), "temp files left behind: {residue:?}");
        // seed() never clobbers a live value.
        let loaded = load(&dir).unwrap();
        loaded.stages.seed("a".into(), 99.0);
        assert_eq!(loaded.stages.entries()[0], ("a".to_string(), 1.0));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn snapshot_cap_evicts_deterministically_and_survivors_round_trip() {
        let dir = temp_dir("cap");
        let caches = SimCaches::fresh();
        // 10 stage entries with keys whose lexicographic order differs from
        // insertion order; survivors must be the first `cap` SORTED keys.
        for i in (0..10u32).rev() {
            caches.stages.seed(format!("stage-{i:02}"), 1.0 + f64::from(i) * 0.125);
        }
        save_with_cap(&dir, &caches, 4).expect("save");
        let loaded = load(&dir).expect("load");
        assert_eq!(loaded.stages.len(), 4, "cap must bound the snapshot");
        let keys: Vec<String> = loaded.stages.entries().iter().map(|(k, _)| k.clone()).collect();
        assert_eq!(keys, ["stage-00", "stage-01", "stage-02", "stage-03"]);
        // Survivors round-trip bit-exactly.
        for (k, v) in loaded.stages.entries() {
            let orig = caches.stages.entries().iter().find(|(ko, _)| *ko == k).unwrap().1;
            assert!(v.to_bits() == orig.to_bits(), "'{k}' drifted: {orig} vs {v}");
        }
        // Two capped saves of the same caches produce identical bytes.
        let first = std::fs::read_to_string(cache_path(&dir)).unwrap();
        save_with_cap(&dir, &caches, 4).expect("save again");
        assert_eq!(first, std::fs::read_to_string(cache_path(&dir)).unwrap());
        // The default cap is generous: a normal save keeps everything.
        save(&dir, &caches).expect("default save");
        assert_eq!(load(&dir).unwrap().stages.len(), 10);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn snapshot_cap_bounds_kernel_entries_too() {
        let dir = temp_dir("cap-kernels");
        let caches = SimCaches::fresh();
        let sys = WaferSystem::paper();
        let ds = DeepSeekConfig::v3_671b();
        let mut ev = DecodeEvaluator::with_cache(SimFidelity::Analytic, caches.kernels.clone());
        ev.evaluate(&sys, &ds, ParallelismPlan::new(32, 2), 128, 4096, AttentionChoice::Flat);
        let total = caches.kernels.len();
        assert!(total > 1, "need multiple kernel entries to exercise the cap");
        save_with_cap(&dir, &caches, 1).expect("save");
        let loaded = load(&dir).expect("load");
        assert_eq!(loaded.kernels.len(), 1);
        // The survivor is the lexicographically first key, bit-exact.
        let (k, m) = &loaded.kernels.entries()[0];
        let (ko, mo) = &caches.kernels.entries()[0];
        assert_eq!(k, ko);
        assert_eq!(m.cycles, mo.cycles);
        assert!(m.seconds.to_bits() == mo.seconds.to_bits());
        assert_eq!(m.exposed, mo.exposed);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_file_is_loud_and_wrong_schema_is_cold() {
        let dir = temp_dir("corrupt");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(cache_path(&dir), "{\"schema\":1,\"stages\":{\"k\":").unwrap();
        assert!(load(&dir).is_err(), "a truncated cache file must be an error");
        std::fs::write(cache_path(&dir), "not json at all").unwrap();
        assert!(load(&dir).is_err());
        // A different schema parses fine but is ignored (cold start).
        std::fs::write(cache_path(&dir), "{\"schema\":999,\"stages\":{\"k\":1.0}}").unwrap();
        let c = load(&dir).expect("foreign schema is a cold start");
        assert!(c.stages.is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
