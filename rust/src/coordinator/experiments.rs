//! The experiment registry: one entry per paper figure/table. Each
//! experiment regenerates the corresponding rows/series (workloads,
//! baselines, sweeps) and returns a printable [`Report`].
//!
//! `fast` mode shrinks sweeps (used by tests); benches and the CLI default
//! to the paper's full parameter sets.

use anyhow::{bail, Result};

use crate::arch::collective;
use crate::arch::collective::{multicast_latency_cycles, reduce_latency_cycles, CollectiveImpl};
use crate::arch::config::{ChipConfig, Dtype, SimFidelity};
use crate::arch::noc::ChipResources;
use crate::arch::tile::{gemm_cycles, gemm_utilization};
use crate::baseline::gh200::{self, Bound, Gh200};
use crate::baseline::soa::SoaSystem;
use crate::cluster::{
    simulate_cluster, simulate_cluster_faulted_observed, simulate_cluster_observed, simulate_cluster_profiled,
    simulate_shared_pool, tpot_crossover, ClusterConfig, ClusterOutcome, ClusterRecord, FaultPlan, FleetMode,
    Router, RoutingPolicy, SharedPoolSpec, TopologySpec,
};
use crate::coordinator::cache::SimCaches;
use crate::coordinator::report::{fmt_time, stacked_bar, Report};
use crate::dataflow::tiling::{l1_working_set, slice_utilization, Concurrency, FlatTiling};
use crate::dataflow::{simulate_attention, AttentionDataflow, FlatParams};
use crate::metrics::{fmt_pct, KernelMetrics};
use crate::multichip::d2d::WaferSystem;
use crate::multichip::parallelism::{AttentionChoice, DecodeEvaluator, ParallelismPlan};
use crate::multichip::wafer::{best_under_tpot, ep_plans, parallel_batch_sweeps};
use crate::obs::report::render_attrib_report;
use crate::obs::{ObsBundle, ObsConfig, ObsExports};
use crate::serve::request::{generate_trace, thin_trace, PrefixProfile, TraceConfig, TrafficPattern};
use crate::serve::scheduler::{AdmissionPolicy, QueuePolicy, SchedulerConfig};
use crate::serve::sim::{assemble_serve_attrib, load_sweep, saturation_knee, simulate, simulate_observed, ServeConfig};
use crate::sim::Graph;
use crate::workload::attention::{AttentionShape, Phase};
use crate::workload::deepseek::{flop_breakdown_per_token, DeepSeekConfig, DenseModelConfig};

/// All experiment ids with one-line descriptions.
pub fn list() -> Vec<(&'static str, &'static str)> {
    vec![
        ("fig1a", "FLOP breakdown: attention vs rest (Qw7B, DS16B, DS671B; prefill+decode)"),
        ("fig1b", "GH200 roofline: FA-3 prefill / FlashMLA decode efficiency envelope"),
        ("fig6", "Cost-model calibration: RedMulE GEMM cycles; 4x4-mesh collectives"),
        ("fig7", "Collective latency: HW vs SW.Tree vs SW.Seq on 32x32 mesh"),
        ("fig8", "Prefill MHA: FA-2/FA-3/FlatSC/FlatTC/FlatHC/FlatAsync runtime breakdown"),
        ("fig9", "FlatAttention group-scale trade-off (over-flattening)"),
        ("fig11", "Per-tile tiling: RedMulE utilization and L1 occupancy"),
        ("fig12", "FlatAttention (tile accel) vs GH200 across attention variants"),
        ("fig13a", "DeepSeek-v3 decode: throughput vs TPOT, Flat vs FlashMLA"),
        ("fig13b", "DeepSeek-v3 decode-layer runtime breakdown @ b=256"),
        ("fig13c", "Expert-parallelism degree sweep"),
        ("fig13d", "D2D communication overhead vs EP degree @ b=256"),
        ("tab2", "SoA comparison: per-chip throughput + TPOT vs CM384/DS-Prof"),
        ("tab3", "Related-work feature matrix"),
        ("serve_load", "Serving: goodput + TTFT/TPOT percentiles vs offered load, 3 traffic patterns"),
        ("serve_policies", "Serving: KV admission policies (reserve vs on-demand+preempt) under memory pressure"),
        ("serve_prefix", "Serving: prefix-cache KV reuse + FCFS/SJF/priority scheduling on shared-prompt traffic"),
        ("cluster_pools", "Cluster: prefill:decode pool ratios, KV-link congestion, colocated-vs-disaggregated crossover"),
        ("cluster_models", "Cluster: two DeepSeek variants co-served; interleaved shared pools vs the static bound"),
        ("cluster_dynamic", "Cluster: static (arrival-sequence) vs live routing on the interleaved single-clock fleet"),
        ("cluster_failures", "Cluster: fault injection — decode kill/drain blast radius, requeue recovery, restart rejoin"),
        ("cluster_topology", "Cluster: KV fabric topologies (pooled/torus/fat-tree) × hop-aware placement — fleet-level Fig. 7"),
    ]
}

/// Run an experiment by id over fresh caches.
pub fn run(id: &str, fast: bool) -> Result<Report> {
    run_with(id, fast, &SimCaches::fresh())
}

/// Run an experiment by id over shared caches — possibly loaded from a
/// `--cache-dir` snapshot (`coordinator::cache`), so a warm second process
/// re-simulates nothing. Cache reuse never changes a value: entries are
/// pure simulation results keyed by their full config identity.
pub fn run_with(id: &str, fast: bool, caches: &SimCaches) -> Result<Report> {
    Ok(match id {
        "fig1a" => fig1a(),
        "fig1b" => fig1b(),
        "fig6" => fig6(),
        "fig7" => fig7(fast),
        "fig8" => fig8(fast),
        "fig9" => fig9(fast),
        "fig11" => fig11(),
        "fig12" => fig12(fast),
        "fig13a" => fig13a(fast, caches),
        "fig13b" => fig13b(fast),
        "fig13c" => fig13c(fast, caches),
        "fig13d" => fig13d(fast),
        "tab2" => tab2(fast),
        "tab3" => tab3(),
        "serve_load" => serve_load(fast, caches),
        "serve_policies" => serve_policies(fast, caches),
        "serve_prefix" => serve_prefix(fast, caches),
        "cluster_pools" => cluster_pools(fast, caches),
        "cluster_models" => cluster_models(fast, caches),
        "cluster_dynamic" => cluster_dynamic(fast, caches),
        "cluster_failures" => cluster_failures(fast, caches),
        "cluster_topology" => cluster_topology(fast, caches),
        _ => bail!("unknown experiment '{id}'; see `flatattention list`"),
    })
}

// ---------------------------------------------------------------------------

fn fig1a() -> Report {
    let mut r = Report::new("Fig. 1a — FLOP breakdown: attention vs other kernels");
    r.header(&["model", "phase", "len", "attention %", "other %"]);
    let qw = DenseModelConfig::qwen7b();
    let ds16 = DeepSeekConfig::v3_16b();
    let ds671 = DeepSeekConfig::v3_671b();
    for (phase, lens) in [(Phase::Prefill, [4096u32, 16384, 65536]), (Phase::Decode, [4096, 16384, 65536])] {
        let pname = if phase == Phase::Prefill { "prefill" } else { "decode" };
        for len in lens {
            let (a, o) = qw.flop_breakdown_per_token(phase, len);
            r.row(vec![
                qw.name.clone(),
                pname.into(),
                len.to_string(),
                fmt_pct(a / (a + o)),
                fmt_pct(o / (a + o)),
            ]);
            for ds in [&ds16, &ds671] {
                let (a, o) = flop_breakdown_per_token(ds, phase, len, Dtype::Fp8);
                r.row(vec![
                    ds.name.clone(),
                    pname.into(),
                    len.to_string(),
                    fmt_pct(a / (a + o)),
                    fmt_pct(o / (a + o)),
                ]);
            }
        }
    }
    r.note("paper: Qw7B attention ≈19% of FLOPs; DS671B decode reaches ≈71% at long context");
    r
}

fn fig1b() -> Report {
    let gh = Gh200::new();
    let mut r = Report::new("Fig. 1b — GH200 roofline: SoA attention kernels");
    r.preamble(format!(
        "GH200: {:.0} TFLOPS FP16, {:.1} TB/s, ridge {:.0} FLOP/B",
        gh.peak_fp16_flops / 1e12,
        gh.hbm_bytes_per_s / 1e12,
        gh.ridge_flops_per_byte()
    ));
    r.header(&["kernel", "shape", "intensity (FLOP/B)", "achieved TFLOPS", "roofline TFLOPS", "gap"]);
    let mut shapes: Vec<AttentionShape> = Vec::new();
    for d in [64u32, 128] {
        for s in [2048u32, 4096, 8192] {
            shapes.push(AttentionShape::mha_prefill(2, 32, d, s, Dtype::Fp16));
        }
    }
    for kv in [4096u32, 8192, 16384] {
        for sp in [1u32, 2] {
            shapes.push(AttentionShape::mla_absorbed_decode(64, 128, 512, 64, kv, sp, Dtype::Fp16));
        }
    }
    for s in shapes {
        let a = gh200::attention(&gh, &s);
        let flops = s.flops() as f64;
        let roof = flops / s.roofline_seconds(&ChipConfig::table1_gh200_match());
        let ach = flops / a.seconds;
        r.row(vec![
            a.kernel.into(),
            s.label(),
            format!("{:.0}", s.ideal_intensity()),
            format!("{:.0}", ach / 1e12),
            format!("{:.0}", roof / 1e12),
            fmt_pct(1.0 - ach / roof),
        ]);
    }
    r.note("paper: 26%–64% gap to the roofline across these kernels");
    r
}

fn fig6() -> Report {
    let cfg = ChipConfig::calib_4x4();
    let mut r = Report::new("Fig. 6 — cost-model calibration (substitutes RTL calibration)");
    r.header(&["model", "point", "cycles", "reference", "deviation"]);
    // RedMulE: the analytic model vs the systolic-array first-principles
    // count tiles_m*tiles_n*k + setup; deviation is 0 by construction, the
    // table documents the operating points the paper's Fig. 6a covers.
    for (m, k, n) in [(32u64, 32u64, 32u64), (64, 64, 64), (96, 96, 96), (128, 128, 128), (192, 192, 192)] {
        let c = gemm_cycles(&cfg.tile, m, k, n);
        let ref_c = m.div_ceil(32) * n.div_ceil(16) * k + cfg.tile.gemm_setup_cycles;
        r.row(vec![
            "RedMulE".into(),
            format!("GEMM {m}x{k}x{n}"),
            c.to_string(),
            ref_c.to_string(),
            fmt_pct((c as f64 - ref_c as f64).abs() / ref_c as f64),
        ]);
    }
    // NoC: DES vs closed form for the two calibration patterns.
    let res = ChipResources::new(&cfg);
    for bytes in [4096u64, 65536, 1 << 20] {
        let mut g = Graph::new(res.table.clone());
        collective::multicast(&mut g, &res, &cfg, CollectiveImpl::SwSeq, collective::Axis::Row, 0, 4, bytes, &[]);
        let des = g.simulate().makespan;
        let ana = multicast_latency_cycles(&cfg, CollectiveImpl::SwSeq, 4, bytes);
        r.row(vec![
            "NoC".into(),
            format!("SW.Seq row mcast {bytes}B"),
            des.to_string(),
            ana.to_string(),
            fmt_pct((des as f64 - ana as f64).abs() / des as f64),
        ]);
        let mut g = Graph::new(res.table.clone());
        collective::reduce(
            &mut g,
            &res,
            &cfg,
            CollectiveImpl::Hw,
            collective::Axis::Row,
            0,
            4,
            crate::arch::noc::TileCoord { x: 0, y: 0 },
            bytes,
            Dtype::Fp16,
            &[],
        );
        let des = g.simulate().makespan;
        let ana = reduce_latency_cycles(&cfg, CollectiveImpl::Hw, 4, bytes, Dtype::Fp16);
        r.row(vec![
            "NoC".into(),
            format!("HW row reduce {bytes}B"),
            des.to_string(),
            ana.to_string(),
            fmt_pct((des as f64 - ana as f64).abs() / des as f64),
        ]);
    }
    r.note("paper calibration: RedMulE 0.17%, NoC 6–12% average cycle deviation vs RTL");
    r
}

fn fig7(fast: bool) -> Report {
    let cfg = ChipConfig::table1();
    let res = ChipResources::new(&cfg);
    let mut r = Report::new("Fig. 7 — collective primitives on a 32x32 mesh (row of 32 tiles)");
    r.header(&["pattern", "size", "HW (cyc)", "SW.Tree (cyc)", "SW.Seq (cyc)", "HW vs Tree", "HW vs Seq"]);
    let sizes: &[u64] = if fast { &[1 << 12, 1 << 20] } else { &[1 << 10, 1 << 12, 1 << 14, 1 << 16, 1 << 18, 1 << 20, 1 << 22] };
    for &bytes in sizes {
        let lat = |imp: CollectiveImpl| {
            let mut g = Graph::new(res.table.clone());
            collective::multicast(&mut g, &res, &cfg, imp, collective::Axis::Row, 0, 32, bytes, &[]);
            g.simulate().makespan
        };
        let (hw, tree, seq) = (lat(CollectiveImpl::Hw), lat(CollectiveImpl::SwTree), lat(CollectiveImpl::SwSeq));
        r.row(vec![
            "row multicast".into(),
            crate::util::fmt_bytes(bytes),
            hw.to_string(),
            tree.to_string(),
            seq.to_string(),
            format!("{:.1}x", tree as f64 / hw as f64),
            format!("{:.1}x", seq as f64 / hw as f64),
        ]);
    }
    for &bytes in sizes {
        let lat = |imp: CollectiveImpl| {
            let mut g = Graph::new(res.table.clone());
            collective::reduce(
                &mut g,
                &res,
                &cfg,
                imp,
                collective::Axis::Row,
                0,
                32,
                crate::arch::noc::TileCoord { x: 0, y: 0 },
                bytes,
                Dtype::Fp16,
                &[],
            );
            g.simulate().makespan
        };
        let (hw, tree, seq) = (lat(CollectiveImpl::Hw), lat(CollectiveImpl::SwTree), lat(CollectiveImpl::SwSeq));
        r.row(vec![
            "row sum-reduce".into(),
            crate::util::fmt_bytes(bytes),
            hw.to_string(),
            tree.to_string(),
            seq.to_string(),
            format!("{:.1}x", tree as f64 / hw as f64),
            format!("{:.1}x", seq as f64 / hw as f64),
        ]);
    }
    r.note("paper: HW multicast 5.1x / 30.7x over SW.Tree / SW.Seq; HW reduce 10.9x / 67.3x");
    r
}

/// The six dataflows of Fig. 8 for one MHA layer shape.
pub fn fig8_dataflows(cfg: &ChipConfig, shape: &AttentionShape) -> Vec<(String, AttentionDataflow)> {
    let full = FlatTiling {
        gx: cfg.mesh_x,
        gy: cfg.mesh_y,
        slice_r: ((shape.effective_q_rows() as u32).div_ceil(cfg.mesh_y)).max(1),
        slice_c: (shape.seq_kv.div_ceil(cfg.mesh_x)).max(1),
    };
    // Cap slices at the L1-feasible 128.
    let full = FlatTiling { slice_r: full.slice_r.min(128), slice_c: full.slice_c.min(128), ..full };
    vec![
        ("FA-2".into(), AttentionDataflow::Fa2),
        ("FA-3".into(), AttentionDataflow::Fa3),
        ("FlatSC".into(), AttentionDataflow::Flat(FlatParams::flat_sc(full))),
        ("FlatTC".into(), AttentionDataflow::Flat(FlatParams::flat_tc(full))),
        ("FlatHC".into(), AttentionDataflow::Flat(FlatParams::flat_hc(full))),
        ("FlatAsync".into(), AttentionDataflow::Flat(FlatParams::flat_async(full))),
    ]
}

fn breakdown_row(name: &str, label: &str, cfg: &ChipConfig, m: &KernelMetrics) -> Vec<String> {
    let total = m.cycles.max(1) as f64;
    let bar = stacked_bar(
        &[
            ('M', m.exposed[0] as f64),
            ('V', m.exposed[1] as f64),
            ('H', m.exposed[2] as f64),
            ('N', m.exposed[3] as f64),
            ('.', total - m.exposed.iter().sum::<u64>() as f64),
        ],
        24,
    );
    let _ = cfg;
    vec![
        label.to_string(),
        name.to_string(),
        fmt_time(m.seconds),
        bar,
        fmt_pct(m.compute_utilization),
        fmt_pct(m.hbm_bw_utilization),
        crate::util::fmt_bytes(m.hbm_bytes),
        format!("{:.0} TFLOPS", m.tflops),
    ]
}

fn fig8(fast: bool) -> Report {
    let cfg = ChipConfig::table1();
    let mut r = Report::new("Fig. 8 — prefill MHA: runtime breakdown and HBM BW utilization");
    r.preamble(format!("chip: {} (2 TB/s HBM), B=2, H=32", cfg.name));
    r.preamble("bar: M=matmul V=softmax(exposed) H=HBM(exposed) N=NoC(exposed) .=other");
    r.header(&["layer", "impl", "runtime", "breakdown", "util", "HBM BW", "HBM traffic", "achieved"]);
    let configs: Vec<(u32, u32)> = if fast {
        vec![(64, 1024)]
    } else {
        vec![(64, 1024), (64, 2048), (64, 4096), (128, 1024), (128, 2048), (128, 4096)]
    };
    let mut fa3_at: Option<f64> = None;
    let mut best_at: Option<(String, f64, u64)> = None;
    let mut fa3_traffic = 0u64;
    for (d, s) in configs {
        let shape = AttentionShape::mha_prefill(2, 32, d, s, Dtype::Fp16);
        let label = format!("D{d} S{s}");
        for (name, df) in fig8_dataflows(&cfg, &shape) {
            let m = simulate_attention(&cfg, &shape, df, SimFidelity::Full);
            if (d, s) == (128, 4096) {
                if name == "FA-3" {
                    fa3_at = Some(m.seconds);
                    fa3_traffic = m.hbm_bytes;
                }
                if name == "FlatAsync" {
                    best_at = Some((name.clone(), m.seconds, m.hbm_bytes));
                }
            }
            r.row(breakdown_row(&name, &label, &cfg, &m));
        }
    }
    if let (Some(fa3), Some((_, flat, traffic))) = (fa3_at, best_at) {
        r.note(format!(
            "D128 S4096: FlatAsync speedup over FA-3 = {:.1}x, HBM traffic reduction = {:.1}x (paper: 4.1x, 16x)",
            fa3 / flat,
            fa3_traffic as f64 / traffic as f64
        ));
    }
    r.note("paper: FlashAttention memory-bound (≤80% HBM BW); FlatAsync up to 92.3% utilization");
    r
}

fn fig9(fast: bool) -> Report {
    let cfg = ChipConfig::table1();
    let mut r = Report::new("Fig. 9 — group-scale trade-off (FlatAsync), D=128, H=32, B=4");
    r.header(&["S", "group", "slice", "runtime", "breakdown", "util(active)", "HBM BW"]);
    let seqs: &[u32] = if fast { &[512, 2048] } else { &[512, 1024, 2048, 4096] };
    let groups: &[u32] = if fast { &[8, 32] } else { &[4, 8, 16, 32] };
    for &s in seqs {
        for &g in groups {
            let shape = AttentionShape::mha_prefill(4, 32, 128, s, Dtype::Fp16);
            let slice = (s / g).min(128).max(1);
            let t = FlatTiling { gx: g, gy: g, slice_r: slice, slice_c: slice };
            let m = simulate_attention(&cfg, &shape, AttentionDataflow::Flat(FlatParams::flat_async(t)), SimFidelity::Full);
            let total = m.cycles.max(1) as f64;
            let bar = stacked_bar(
                &[
                    ('M', m.exposed[0] as f64),
                    ('V', m.exposed[1] as f64),
                    ('H', m.exposed[2] as f64),
                    ('N', m.exposed[3] as f64),
                    ('.', total - m.exposed.iter().sum::<u64>() as f64),
                ],
                20,
            );
            r.row(vec![
                s.to_string(),
                format!("{g}x{g}"),
                slice.to_string(),
                fmt_time(m.seconds),
                bar,
                fmt_pct(m.matrix_efficiency_active),
                fmt_pct(m.hbm_bw_utilization),
            ]);
        }
    }
    r.note("paper: S=4096 → 92.7% (16x16) / 92.3% (32x32); S=512 + 32x32 over-flattens to ~20% active util");
    r
}

fn fig11() -> Report {
    let cfg = ChipConfig::table1();
    let mut r = Report::new("Fig. 11 — per-tile tiling: RedMulE utilization and L1 occupancy (D=128, FP16)");
    r.header(&["slice", "score-GEMM util", "combined util", "L1 (KiB)", "fits 384 KiB"]);
    for s in [16u64, 32, 64, 128, 256] {
        let u_score = gemm_utilization(&cfg.tile, s, 128, s);
        let u = slice_utilization(&cfg, s, s, 128, 128);
        let ws = l1_working_set(s, s, 128, 128, Dtype::Fp16, true, Concurrency::TwoRowBlocks);
        r.row(vec![
            format!("{s}x{s}"),
            fmt_pct(u_score),
            fmt_pct(u),
            format!("{:.0}", ws.total_kib()),
            if ws.fits(&cfg.tile) { "yes".into() } else { "NO".into() },
        ]);
    }
    r.note("paper: 128x128 chosen — ≥95% utilization within the 384 KiB budget (up to 98% at 256)");
    r
}

/// The Fig. 12 shape set.
pub fn fig12_shapes(fast: bool) -> Vec<AttentionShape> {
    let mut v = Vec::new();
    // Prefill MHA: hd × sq.
    for d in [64u32, 128] {
        for s in if fast { vec![4096u32] } else { vec![2048u32, 4096, 8192] } {
            v.push(AttentionShape::mha_prefill(2, 32, d, s, Dtype::Fp16));
        }
    }
    // Decode MHA (sp × kv), GQA, MLA.
    let kvs: Vec<u32> = if fast { vec![4096] } else { vec![4096, 8192] };
    for &kv in &kvs {
        for sp in [1u32, 4] {
            v.push(AttentionShape::mha_decode(64, 32, 128, kv, sp, Dtype::Fp16));
        }
        v.push(AttentionShape::gqa_decode(64, 32, 8, 128, kv, 1, Dtype::Fp16));
        v.push(AttentionShape::gqa_decode(64, 32, 8, 128, kv, 4, Dtype::Fp16));
        v.push(AttentionShape::mla_absorbed_decode(64, 128, 512, 64, kv, 2, Dtype::Fp16));
    }
    v
}

fn fig12(fast: bool) -> Report {
    let cfg = ChipConfig::table1_gh200_match();
    let gh = Gh200::new();
    let mut r = Report::new("Fig. 12 — FlatAttention (tile accel, 4 TB/s) vs GH200 SoA kernels");
    r.header(&["shape", "ours", "ours label", "GH200", "GH200 kernel", "speedup"]);
    let mut speedups = Vec::new();
    for shape in fig12_shapes(fast) {
        let df = AttentionDataflow::auto_flat(&cfg, &shape);
        let m = simulate_attention(&cfg, &shape, df, SimFidelity::Full);
        let g = gh200::attention(&gh, &shape);
        let sp = g.seconds / m.seconds;
        speedups.push(sp);
        let ours_label = if shape.is_compute_bound(&cfg) {
            format!("C:{}", fmt_pct(m.compute_utilization))
        } else {
            format!("M:{}", fmt_pct(m.hbm_bw_utilization))
        };
        let gh_label = match g.bound {
            Bound::Compute => format!("C:{}", fmt_pct(g.efficiency)),
            Bound::Memory => format!("M:{}", fmt_pct(g.efficiency)),
        };
        r.row(vec![
            shape.label(),
            fmt_time(m.seconds),
            ours_label,
            fmt_time(g.seconds),
            format!("{} {gh_label}", g.kernel),
            format!("{sp:.1}x"),
        ]);
    }
    let avg = speedups.iter().sum::<f64>() / speedups.len() as f64;
    r.note(format!("average speedup {avg:.1}x (paper: 1.9x; 86% util compute-bound, 78% BW memory-bound)"));
    r
}

fn fig13a(fast: bool, caches: &SimCaches) -> Report {
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let fidelity = SimFidelity::Analytic;
    let mut r = Report::new("Fig. 13a — DeepSeek-v3-671B decode: throughput vs TPOT (EP32-PP2, 64 chips)");
    r.header(&["dataflow", "batch/chip", "TPOT (ms)", "system tok/s", "per-chip tok/s", "attn util"]);
    let plan = ParallelismPlan::new(32, 2);
    // Both dataflow series sweep concurrently over one shared kernel cache.
    let specs = [(plan, AttentionChoice::Flat), (plan, AttentionChoice::FlashMla)];
    let sweeps = parallel_batch_sweeps(&sys, &ds, &specs, 4096, fidelity, &caches.kernels);
    for ((_, choice), sweep) in specs.iter().zip(sweeps) {
        let choice = *choice;
        let sweep = if fast { sweep.into_iter().step_by(3).collect::<Vec<_>>() } else { sweep };
        for o in sweep {
            r.row(vec![
                choice.label().into(),
                o.batch_per_chip.to_string(),
                format!("{:.1}", o.tpot_ms),
                format!("{:.0}", o.system_tokens_per_s),
                format!("{:.0}", o.per_chip_tokens_per_s),
                fmt_pct(o.attention_utilization),
            ]);
        }
    }
    r.note("paper: FlatAttention up to 2.1x system throughput over FlashMLA at high batch, with lower TPOT");
    r
}

fn fig13b(fast: bool) -> Report {
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let mut ev = DecodeEvaluator::new(if fast { SimFidelity::Analytic } else { SimFidelity::Analytic });
    let plan = ParallelismPlan::new(32, 2);
    let mut r = Report::new("Fig. 13b — decode-layer runtime breakdown @ 256 batch/chip");
    r.header(&["dataflow", "attention", "GEMMs", "vector", "C2C", "attention %", "e2e layer"]);
    let mut flat_total = 0.0;
    let mut flat_attn = 0.0;
    for choice in [AttentionChoice::Flat, AttentionChoice::FlashMla] {
        let o = ev.evaluate(&sys, &ds, plan, 256, 4096, choice);
        if choice == AttentionChoice::Flat {
            flat_total = o.layer.total();
            flat_attn = o.layer.attention_s;
        } else {
            let sp_attn = o.layer.attention_s / flat_attn;
            let sp_e2e = o.layer.total() / flat_total;
            r.note(format!(
                "FlatAttention speedup: attention {sp_attn:.1}x, end-to-end layer {sp_e2e:.1}x (paper: 4.5x, 2.1x)"
            ));
        }
        r.row(vec![
            choice.label().into(),
            fmt_time(o.layer.attention_s),
            fmt_time(o.layer.gemm_s),
            fmt_time(o.layer.vector_s),
            fmt_time(o.layer.c2c_s),
            fmt_pct(o.layer.attention_s / o.layer.total()),
            fmt_time(o.layer.total()),
        ]);
    }
    r.note("paper: attention is 42% of runtime with FlatAttention vs 71% with FlashMLA");
    r
}

fn fig13c(fast: bool, caches: &SimCaches) -> Report {
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let mut r = Report::new("Fig. 13c — expert-parallelism sweep (FlatAttention)");
    r.header(&["plan", "batch/chip", "TPOT (ms)", "system tok/s"]);
    // One thread worker per EP plan, all hitting a common kernel cache
    // (plans share most GEMM/vector kernel shapes).
    let specs: Vec<_> = ep_plans().into_iter().map(|p| (p, AttentionChoice::Flat)).collect();
    let sweeps = parallel_batch_sweeps(&sys, &ds, &specs, 4096, SimFidelity::Analytic, &caches.kernels);
    for ((plan, _), sweep) in specs.iter().zip(sweeps) {
        let plan = *plan;
        let sweep: Vec<_> = if fast { sweep.into_iter().step_by(3).collect() } else { sweep };
        for o in sweep {
            r.row(vec![
                plan.label(),
                o.batch_per_chip.to_string(),
                format!("{:.1}", o.tpot_ms),
                format!("{:.0}", o.system_tokens_per_s),
            ]);
        }
    }
    r.note("paper: EP improves throughput+TPOT at low/mid batch; C2C overhead grows with EP degree at high batch");
    r
}

fn fig13d(_fast: bool) -> Report {
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let mut ev = DecodeEvaluator::new(SimFidelity::Analytic);
    let mut r = Report::new("Fig. 13d — D2D communication overhead @ 256 batch/chip");
    r.header(&["plan", "C2C per layer", "share of layer", "mean hops"]);
    for plan in ep_plans().into_iter().filter(|p| p.ep > 1) {
        let o = ev.evaluate(&sys, &ds, plan, 256, 4096, AttentionChoice::Flat);
        let (gx, gy) = sys.d2d.group_dims(plan.ep);
        r.row(vec![
            plan.label(),
            fmt_time(o.layer.c2c_s),
            fmt_pct(o.layer.c2c_s / o.layer.total()),
            format!("{:.2}", crate::multichip::d2d::D2dConfig::mean_hops(gx, gy)),
        ]);
    }
    r.note("paper: multi-hop mesh communication amplifies D2D overhead as EP degree grows");
    r
}

fn tab2(fast: bool) -> Report {
    let mut r = Report::new("Table II — DeepSeek-v3-671B decoding vs SoA systems (TPOT ≤ 50 ms)");
    r.header(&["system", "chips", "HBM", "TFLOPS", "batch", "kv", "tok/s/chip", "TPOT (ms)"]);
    for s in [SoaSystem::cm384(), SoaSystem::ds_prof()] {
        r.row(vec![
            s.name.into(),
            format!("{} {}", s.chips, s.chip_desc),
            format!("{:.1} TB/s", s.hbm_tb_s),
            format!("{:.0}@{}", s.tflops, s.tflops_desc),
            s.batch_per_chip.to_string(),
            s.kv_len.to_string(),
            format!("{:.0}", s.tokens_per_s_per_chip),
            format!("{:.1}", s.tpot_ms),
        ]);
    }
    let fidelity = SimFidelity::Analytic;
    let _ = fast;
    for (name, sweep) in [
        ("Ours1 (1 TB/s D2D)", crate::multichip::wafer::ours1(fidelity)),
        ("Ours2 (160 GB/s D2D)", crate::multichip::wafer::ours2(fidelity)),
    ] {
        if let Some(o) = best_under_tpot(&sweep, 50.0) {
            r.row(vec![
                name.into(),
                "64 Tile Accel.".into(),
                "4.0 TB/s".into(),
                "1976@FP8".into(),
                o.batch_per_chip.to_string(),
                "4096".into(),
                format!("{:.0}", o.per_chip_tokens_per_s),
                format!("{:.1}", o.tpot_ms),
            ]);
        }
    }
    r.note("paper: Ours1 6940 tok/s/chip @35.8 ms; Ours2 3773 @33.1; DS-Prof 2325 @50.2");
    r
}

fn tab3() -> Report {
    let mut r = Report::new("Table III — related-work feature matrix");
    r.header(&["work", "layer fusion", "attention", "multi-tile", "scope", "collectives", "HW mcast/redu"]);
    for row in [
        ["single-tile dataflows [37-40]", "yes", "yes", "no", "-", "no", "no"],
        ["FlashAttention-2", "yes", "yes", "yes", "gpu", "no", "no"],
        ["FlashFuser", "yes", "no", "yes", "gpu", "yes", "no"],
        ["Zen-Attention", "yes", "yes", "yes", "mesh", "yes", "no"],
        ["COMET", "yes", "yes", "yes", "noc", "yes", "no"],
        ["ClusterFusion", "yes", "yes", "yes", "gpu", "yes", "no"],
        ["WaferLLM", "no*", "yes", "yes", "mesh", "yes", "partial"],
        ["FlatAttention (ours)", "yes", "yes", "yes", "mesh", "yes", "yes"],
    ] {
        r.row(row.iter().map(|s| s.to_string()).collect());
    }
    r.note("* wafer-scale assumption: models fit on-chip, so no fused-layer dataflow needed");
    r
}

/// The serving traffic patterns of `serve_load`. Periods scale with the
/// simulation horizon so every pattern completes whole cycles inside it —
/// otherwise a partial cycle would skew realized load away from the
/// reported offered rps (a 16 s diurnal over a 4 s fast horizon would run
/// ~1.5× hot).
pub fn serve_patterns(horizon_s: f64) -> Vec<TrafficPattern> {
    vec![
        TrafficPattern::Poisson,
        TrafficPattern::Bursty { period_s: horizon_s / 4.0, duty: 0.3, burst_factor: 4.0 },
        TrafficPattern::Diurnal { period_s: horizon_s, trough_factor: 0.25 },
    ]
}

/// Offered-load points in requests/s. The EP32-PP2 wafer sustains roughly
/// 2k chat requests/s (≈444k tok/s ÷ ~190 output tokens, minus prefill
/// interference), so the top points deliberately overdrive the system.
pub fn serve_rates(fast: bool) -> Vec<f64> {
    if fast {
        vec![250.0, 1000.0]
    } else {
        vec![125.0, 250.0, 500.0, 1000.0, 2000.0, 4000.0]
    }
}

fn serve_outcome_row(o: &crate::serve::sim::ServeOutcome) -> Vec<String> {
    vec![
        o.pattern.clone(),
        format!("{:.0}", o.offered_rps),
        o.completed.to_string(),
        (o.in_flight + o.queued).to_string(),
        format!("{:.0}", o.ttft_ms.p50),
        format!("{:.0}", o.ttft_ms.p99),
        format!("{:.1}", o.tpot_ms.p50),
        format!("{:.1}", o.tpot_ms.p95),
        format!("{:.1}", o.tpot_ms.p99),
        format!("{:.0}", o.system_tokens_per_s),
        format!("{:.0}", o.goodput_rps),
        fmt_pct(o.peak_kv_occupancy),
    ]
}

fn serve_load(fast: bool, caches: &SimCaches) -> Report {
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let cfg = ServeConfig::default();
    let horizon = if fast { 4.0 } else { 20.0 };
    let rates = serve_rates(fast);
    let mut r = Report::new(
        "Serving — goodput vs offered load (DeepSeek-v3-671B, EP32-PP2 wafer, continuous batching)",
    );
    r.preamble(format!(
        "plan EP32-PP2, FlatAttention, horizon {horizon} s, TPOT SLO {} ms, TTFT SLO {} ms, seed 2026",
        cfg.slo_tpot_ms, cfg.slo_ttft_ms
    ));
    r.preamble("backlog = in-flight + queued at horizon; KV peak = max column occupancy");
    r.header(&[
        "pattern", "rps", "done", "backlog", "TTFT p50", "p99 (ms)", "TPOT p50", "p95", "p99 (ms)",
        "tok/s", "goodput", "KV peak",
    ]);
    for pattern in serve_patterns(horizon) {
        let outcomes =
            load_sweep(&sys, &ds, &cfg, pattern, &rates, 2026, horizon, &caches.kernels, &caches.stages);
        for o in &outcomes {
            assert!(o.conserves_requests(), "request conservation violated");
            assert!(!o.kv_over_capacity, "KV overflow in {} @ {}", o.pattern, o.offered_rps);
            r.row(serve_outcome_row(o));
        }
        match saturation_knee(&outcomes, cfg.slo_tpot_ms) {
            Some(rate) => r.note(format!(
                "{}: saturation knee at {rate:.0} rps (first load with p99 TPOT > {} ms)",
                pattern.label(),
                cfg.slo_tpot_ms
            )),
            None => r.note(format!("{}: no saturation inside the sweep", pattern.label())),
        };
    }
    r.note(
        "steady-state anchor: Table II Ours1 holds 50 ms TPOT at batch 256 — the serving knee sits where continuous batching pushes past that regime",
    );
    r
}

/// Serving sweep at a caller-chosen queue policy / rate / horizon / seed
/// (the `flatattention serve --policy/--rate/...` path).
pub fn serve_custom(policy: QueuePolicy, rate: f64, horizon: f64, seed: u64, caches: &SimCaches) -> Report {
    serve_custom_observed(policy, rate, horizon, seed, caches, None).0
}

/// [`serve_custom`] with an optional observability sink: same simulation
/// and report, plus the Chrome-trace / gauge-series / Prometheus exports
/// when `obs` is set (the `flatattention serve --trace-out/...` path).
pub fn serve_custom_observed(
    policy: QueuePolicy,
    rate: f64,
    horizon: f64,
    seed: u64,
    caches: &SimCaches,
    obs: Option<ObsConfig>,
) -> (Report, Option<ObsExports>) {
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let cfg = ServeConfig {
        scheduler: SchedulerConfig { queue_policy: policy, ..Default::default() },
        ..Default::default()
    };
    let trace = generate_trace(&TraceConfig::new(seed, TrafficPattern::Poisson, rate, horizon));
    let mut r = Report::new("Serving — custom sweep (DeepSeek-v3-671B, EP32-PP2 wafer)");
    r.preamble(format!(
        "poisson {rate:.0} rps over {horizon} s, queue policy {}, seed {seed}",
        policy.label()
    ));
    r.header(&[
        "policy", "rps", "done", "backlog", "TTFT mean", "p99 (ms)", "TPOT p99 (ms)", "tok/s",
        "goodput",
    ]);
    let (o, exports) = match obs {
        Some(ocfg) => {
            let (o, records, sink) = simulate_observed(
                &sys,
                &ds,
                &trace,
                &cfg,
                horizon,
                policy.label(),
                rate,
                &caches.kernels,
                &caches.stages,
                ocfg,
            );
            let mut bundle = ObsBundle::new();
            bundle.attrib = assemble_serve_attrib(&records, &sink);
            bundle.push_engine(*sink);
            bundle.counters.add("stage_cache_hits", caches.stages.hits());
            bundle.counters.add("stage_cache_misses", caches.stages.misses());
            bundle.counters.add("kernel_cache_hits", caches.kernels.hits());
            bundle.counters.add("kernel_cache_misses", caches.kernels.misses());
            (o, Some(bundle.exports()))
        }
        None => {
            let (o, _) = simulate(
                &sys,
                &ds,
                &trace,
                &cfg,
                horizon,
                policy.label(),
                rate,
                &caches.kernels,
                &caches.stages,
            );
            (o, None)
        }
    };
    r.row(vec![
        policy.label().into(),
        format!("{:.0}", o.offered_rps),
        o.completed.to_string(),
        (o.in_flight + o.queued).to_string(),
        format!("{:.0}", o.ttft_ms.mean),
        format!("{:.0}", o.ttft_ms.p99),
        format!("{:.1}", o.tpot_ms.p99),
        format!("{:.0}", o.system_tokens_per_s),
        format!("{:.0}", o.goodput_rps),
    ]);
    (r, exports)
}

/// Prefix-cache KV reuse + scheduling policies on shared-prompt traffic:
/// the `serve_prefix` experiment. Deterministic at the fixed seed — the
/// whole table (hit rates, TTFT deltas) replays bit-exactly.
fn serve_prefix(fast: bool, caches: &SimCaches) -> Report {
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let horizon = if fast { 4.0 } else { 15.0 };
    let rate = if fast { 300.0 } else { 1000.0 };
    let seed = 4242u64;
    let tc = TraceConfig::new(seed, TrafficPattern::Poisson, rate, horizon)
        .with_prefixes(PrefixProfile::agentic());
    let trace = generate_trace(&tc);
    let mut r = Report::new(
        "Serving — prefix-cache KV reuse + scheduling policies (shared-prompt traffic)",
    );
    r.preamble(format!(
        "poisson {rate:.0} rps over {horizon} s, EP32-PP2, seed {seed}; 70% of prompts share one of 8 system prefixes (~1k tokens)"
    ));
    r.preamble("hit rate = shareable prefix tokens served from the cache at admission");
    r.header(&[
        "config", "done", "hit rate", "evict", "TTFT mean", "TTFT p50", "p99 (ms)", "TPOT p99",
        "tok/s", "goodput",
    ]);
    let kernels = &caches.kernels;
    let stages = &caches.stages;
    let mut baseline_ttft: Option<f64> = None;
    let mut prefix_ttft: Option<f64> = None;
    for (name, queue_policy, block) in [
        ("fcfs (no cache)", QueuePolicy::Fcfs, 0u32),
        ("fcfs + prefix", QueuePolicy::Fcfs, 256),
        ("sjf + prefix", QueuePolicy::Sjf, 256),
        ("priority + prefix", QueuePolicy::Priority, 256),
    ] {
        let cfg = ServeConfig {
            scheduler: SchedulerConfig {
                queue_policy,
                prefix_block_tokens: block,
                ..Default::default()
            },
            ..Default::default()
        };
        let (o, _) = simulate(&sys, &ds, &trace, &cfg, horizon, name, rate, kernels, stages);
        assert!(o.conserves_requests(), "request conservation violated in {name}");
        assert!(!o.kv_over_capacity, "KV overflow in {name}");
        if name == "fcfs (no cache)" {
            baseline_ttft = Some(o.ttft_ms.mean);
        }
        if name == "fcfs + prefix" {
            prefix_ttft = Some(o.ttft_ms.mean);
        }
        r.row(vec![
            name.into(),
            o.completed.to_string(),
            fmt_pct(o.prefix_hit_rate()),
            o.prefix_evictions.to_string(),
            format!("{:.0}", o.ttft_ms.mean),
            format!("{:.0}", o.ttft_ms.p50),
            format!("{:.0}", o.ttft_ms.p99),
            format!("{:.1}", o.tpot_ms.p99),
            format!("{:.0}", o.system_tokens_per_s),
            format!("{:.0}", o.goodput_rps),
        ]);
    }
    if let (Some(base), Some(pfx)) = (baseline_ttft, prefix_ttft) {
        if base > 0.0 {
            r.note(format!(
                "prefix cache TTFT delta (fcfs): mean {base:.0} ms → {pfx:.0} ms ({:+.1}%)",
                100.0 * (pfx - base) / base
            ));
        }
    }
    r.note("reused prefix blocks skip both prefill compute and KV admission; SJF reorders the queue by prompt length for TTFT");
    r
}

fn serve_policies(fast: bool, caches: &SimCaches) -> Report {
    let ds = DeepSeekConfig::v3_671b();
    // Memory-constrained wafer (24 GiB HBM/chip): full-context reservations
    // cap residency well below the batch ceiling, so the two admission
    // policies separate. NOTE: the mutated chip is safe over the shared
    // caches — every cache key embeds the chip fingerprint.
    let mut sys = WaferSystem::paper();
    sys.chip.hbm.capacity_gib_per_stack = 12;
    let horizon = if fast { 3.0 } else { 10.0 };
    let rate = if fast { 400.0 } else { 1200.0 };
    let trace = generate_trace(&TraceConfig::new(77, TrafficPattern::Poisson, rate, horizon));
    let mut r = Report::new("Serving — KV admission policies under memory pressure (24 GiB HBM/chip)");
    r.preamble(format!("poisson {rate:.0} rps over {horizon} s, EP32-PP2, seed 77"));
    r.header(&[
        "policy", "done", "backlog", "preempt", "TTFT p99 (ms)", "TPOT p99 (ms)", "tok/s", "goodput",
        "KV peak",
    ]);
    for (name, policy) in [
        ("reserve-full", AdmissionPolicy::ReserveFull),
        ("on-demand+preempt", AdmissionPolicy::OnDemandPreempt),
    ] {
        let cfg = ServeConfig {
            scheduler: crate::serve::scheduler::SchedulerConfig { policy, ..Default::default() },
            ..Default::default()
        };
        let (o, _) =
            simulate(&sys, &ds, &trace, &cfg, horizon, name, rate, &caches.kernels, &caches.stages);
        assert!(o.conserves_requests());
        assert!(!o.kv_over_capacity);
        r.row(vec![
            name.into(),
            o.completed.to_string(),
            (o.in_flight + o.queued).to_string(),
            o.preemptions.to_string(),
            format!("{:.0}", o.ttft_ms.p99),
            format!("{:.1}", o.tpot_ms.p99),
            format!("{:.0}", o.system_tokens_per_s),
            format!("{:.0}", o.goodput_rps),
            fmt_pct(o.peak_kv_occupancy),
        ]);
    }
    r.note("on-demand admission packs more residents (higher KV peak) at the cost of recompute preemptions");
    r
}

/// Fleet size of the cluster experiments (wafer instances).
pub const CLUSTER_FLEET: u32 = 4;

/// Offered fleet loads of `cluster_pools` in requests/s. A 4-instance fleet
/// of EP32-PP2 wafers saturates around 4× the single-instance knee, so the
/// top points deliberately overdrive the colocated fleet.
pub fn cluster_rates(fast: bool) -> Vec<f64> {
    if fast {
        vec![125.0, 2000.0]
    } else {
        vec![125.0, 500.0, 2000.0, 4000.0, 8000.0]
    }
}

fn cluster_outcome_row(o: &ClusterOutcome) -> Vec<String> {
    vec![
        o.label.clone(),
        format!("{:.0}", o.offered_rps),
        o.completed.to_string(),
        o.in_flight.to_string(),
        format!("{:.0}", o.ttft_ms.p50),
        format!("{:.0}", o.ttft_ms.p99),
        format!("{:.1}", o.tpot_ms.p50),
        format!("{:.1}", o.tpot_ms.p95),
        format!("{:.1}", o.tpot_ms.p99),
        format!("{:.0}", o.fleet_tokens_per_s),
        format!("{:.0}", o.goodput_rps),
        o.migrated.to_string(),
        fmt_pct(o.transfer_overhead_share),
        o.router_spills.to_string(),
        fmt_pct(o.link_busy_frac),
        format!("{:.1}", o.link_wait_s * 1e3),
        o.fabric_hops.to_string(),
        o.shards.to_string(),
    ]
}

/// Column headers matching [`cluster_outcome_row`].
const CLUSTER_ROW_HEADER: [&str; 18] = [
    "fleet", "rps", "done", "backlog", "TTFT p50", "p99 (ms)", "TPOT p50", "p95", "p99 (ms)",
    "tok/s", "goodput", "migrated", "transfer", "spills", "link busy", "wait (ms)", "hops", "shards",
];

/// `cluster_pools`: sweep the prefill:decode pool ratio at fixed fleet size
/// over offered load, against the colocated baseline. Coupled thinning of
/// one master trace makes the load axis a true refinement, and the whole
/// table (including the crossover notes) replays bit-exactly at the fixed
/// seed — the acceptance criterion's determinism anchor.
fn cluster_pools(fast: bool, caches: &SimCaches) -> Report {
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let horizon = if fast { 3.0 } else { 10.0 };
    let rates = cluster_rates(fast);
    let seed = 2026u64;
    let max_rate = rates.iter().cloned().fold(0.0f64, f64::max);
    let master = generate_trace(
        &TraceConfig::new(seed, TrafficPattern::Poisson, max_rate, horizon).with_prefixes(PrefixProfile::agentic()),
    );
    let modes = [
        FleetMode::Colocated { instances: CLUSTER_FLEET },
        FleetMode::Disaggregated { prefill: 1, decode: 3 },
        FleetMode::Disaggregated { prefill: 2, decode: 2 },
        FleetMode::Disaggregated { prefill: 3, decode: 1 },
    ];
    let mut r = Report::new("Cluster — prefill:decode pool ratios across a 4-instance interleaved wafer fleet");
    r.preamble(format!(
        "4× EP32-PP2 wafer instances on one event clock, poisson traffic (70% shared prompts), horizon {horizon} s, \
         seed {seed}; prefix-affinity arrival routing (live spill guard), least-outstanding decode routing, \
         inter-node KV handoff over the shared contended link"
    ));
    r.preamble(
        "transfer = exposed KV-handoff share of migrated requests' end-to-end latency; \
         spills = affinity-overload rebalance events; link busy = shared-fabric serialization share",
    );
    r.header(&CLUSTER_ROW_HEADER);
    let mut curves: Vec<Vec<ClusterOutcome>> = Vec::new();
    for mode in modes {
        let ccfg = ClusterConfig { mode, ..ClusterConfig::colocated(CLUSTER_FLEET, &ds) };
        let mut curve = Vec::new();
        for &rate in &rates {
            let trace = thin_trace(&master, rate / max_rate, seed ^ 0xC0FF_EE00);
            let (o, _) =
                simulate_cluster(&sys, &ds, &trace, &ccfg, horizon, rate, &caches.kernels, &caches.stages);
            assert!(o.conserves_requests(), "request conservation violated in {} @ {rate}", o.label);
            assert!(!o.kv_over_capacity, "KV overflow in {} @ {rate}", o.label);
            r.row(cluster_outcome_row(&o));
            curve.push(o);
        }
        curves.push(curve);
    }
    for (mode, curve) in modes.iter().zip(&curves).skip(1) {
        match tpot_crossover(&curves[0], curve) {
            Some(rate) => r.note(format!(
                "{}: p99 TPOT beats colocated from {rate:.0} rps (decode pool carries no chunked-prefill interference)",
                mode.label()
            )),
            None => r.note(format!("{}: colocated p99 TPOT never beaten inside the sweep", mode.label())),
        }
    }
    let colo_ttft = curves[0][0].ttft_ms.p50;
    let best_disagg_ttft = curves[1..].iter().map(|c| c[0].ttft_ms.p50).fold(f64::INFINITY, f64::min);
    r.note(format!(
        "low load ({:.0} rps): colocated TTFT p50 {colo_ttft:.0} ms vs best disaggregated {best_disagg_ttft:.0} ms — \
         the KV handoff is pure first-token overhead when nothing queues",
        rates[0]
    ));
    r
}

/// `cluster_models`: two DeepSeek variants co-served on a 4-instance fleet —
/// dedicated sub-fleets (partitioned) vs every instance hosting both models
/// (shared: full-fleet parallelism, but co-resident weights shrink the KV
/// budget and the per-chip batch ceiling is split between the models).
///
/// Shared pools are simulated TWO ways. The *static bound* bills
/// co-residency as reserved weights + the split batch ceiling only — each
/// model runs its own isolated fleet pass, so cross-model tick interference
/// is absent and the latencies are a lower bound (the pre-interleaving
/// model). The *shared (interleaved)* rows run both models' engines on one
/// chip clock per instance ([`simulate_shared_pool`]): a tick occupies the
/// chip exclusively, so the 16B's iterations genuinely stretch the 671B's
/// cadence and vice versa. The interference-dominance claim (interleaved
/// latencies strictly above the static bound) is asserted here and pinned
/// by a golden test.
fn cluster_models(fast: bool, caches: &SimCaches) -> Report {
    let sys = WaferSystem::paper();
    let big = DeepSeekConfig::v3_671b();
    let small = DeepSeekConfig::v3_16b();
    let horizon = if fast { 3.0 } else { 10.0 };
    let (rate_big, rate_small) = if fast { (300.0, 600.0) } else { (1000.0, 2000.0) };
    let seed = 7100u64;
    let kernels = &caches.kernels;
    let stages = &caches.stages;
    let trace_big = generate_trace(&TraceConfig::new(seed, TrafficPattern::Poisson, rate_big, horizon));
    let trace_small = generate_trace(&TraceConfig::new(seed ^ 0x51AA, TrafficPattern::Poisson, rate_small, horizon));
    let base = ServeConfig::default();
    // The shared co-residency billing recipe (reserved co-resident weights
    // + split batch ceiling) — identical in both shared arms, so the only
    // delta between 'static bound' and 'interleaved' is tick interference.
    // Routing is also held identical across ALL arms (live least-queue-
    // depth), so no routing delta can masquerade as interference.
    let shared_serve = |other: &DeepSeekConfig| crate::cluster::co_resident_serve(&sys, other, base);

    let mut r = Report::new("Cluster — two DeepSeek variants co-served: partitioned vs shared pools (4 instances)");
    r.preamble(format!(
        "{} @ {rate_big:.0} rps + {} @ {rate_small:.0} rps, horizon {horizon} s, seed {seed}",
        big.name, small.name
    ));
    r.preamble(
        "partitioned: 3 dedicated instances for the 671B, 1 for the 16B; shared: both models resident on all 4 \
         (split batch ceiling, co-resident weights reserved out of the KV budget); 'static bound' omits tick \
         interference, 'interleaved' serializes both models' ticks on each chip",
    );
    r.header(&[
        "scheme", "model", "done", "backlog", "TTFT p99 (ms)", "TPOT p99 (ms)", "tok/s", "goodput", "KV peak",
        "spills", "link busy",
    ]);
    let model_row = |r: &mut Report, scheme: &str, name: &str, o: &ClusterOutcome| {
        let kv_peak = o.instances.iter().map(|i| i.peak_kv_occupancy).fold(0.0f64, f64::max);
        r.row(vec![
            scheme.into(),
            name.into(),
            o.completed.to_string(),
            o.in_flight.to_string(),
            format!("{:.0}", o.ttft_ms.p99),
            format!("{:.1}", o.tpot_ms.p99),
            format!("{:.0}", o.fleet_tokens_per_s),
            format!("{:.0}", o.goodput_rps),
            fmt_pct(kv_peak),
            o.router_spills.to_string(),
            fmt_pct(o.link_busy_frac),
        ]);
    };
    let isolated = |scheme: &str,
                    ds: &DeepSeekConfig,
                    trace: &[crate::serve::request::Request],
                    rate: f64,
                    instances: u32,
                    serve: ServeConfig| {
        let mut ccfg = ClusterConfig::colocated(instances, ds);
        ccfg.serve = serve;
        // Same routing policy as the interleaved shared pool below, so the
        // static-vs-interleaved comparison isolates interference.
        ccfg.routing = RoutingPolicy::LeastQueueDepth;
        let (o, _) = simulate_cluster(&sys, ds, trace, &ccfg, horizon, rate, kernels, stages);
        assert!(o.conserves_requests(), "conservation violated: {scheme} {}", ds.name);
        assert!(!o.kv_over_capacity, "KV overflow: {scheme} {}", ds.name);
        o
    };
    let part_big = isolated("partitioned", &big, &trace_big, rate_big, 3, base);
    model_row(&mut r, "partitioned", &big.name, &part_big);
    let part_small = isolated("partitioned", &small, &trace_small, rate_small, 1, base);
    model_row(&mut r, "partitioned", &small.name, &part_small);

    // Static lower bound: reserved weights + split ceiling, no interference.
    let static_big =
        isolated("shared (static bound)", &big, &trace_big, rate_big, CLUSTER_FLEET, shared_serve(&small));
    model_row(&mut r, "shared (static bound)", &big.name, &static_big);
    let static_small =
        isolated("shared (static bound)", &small, &trace_small, rate_small, CLUSTER_FLEET, shared_serve(&big));
    model_row(&mut r, "shared (static bound)", &small.name, &static_small);

    // Interleaved shared pool: both models' ticks serialize on each chip.
    let specs = [
        SharedPoolSpec { ds: &big, trace: &trace_big, serve: shared_serve(&small), offered_rps: rate_big },
        SharedPoolSpec { ds: &small, trace: &trace_small, serve: shared_serve(&big), offered_rps: rate_small },
    ];
    let shared = simulate_shared_pool(
        &sys,
        &specs,
        CLUSTER_FLEET,
        RoutingPolicy::LeastQueueDepth,
        Router::DEFAULT_DRAIN_RATE,
        horizon,
        kernels,
        stages,
    );
    for ((o, _), name) in shared.iter().zip([&big.name, &small.name]) {
        assert!(o.conserves_requests(), "conservation violated: shared interleaved {name}");
        model_row(&mut r, "shared (interleaved)", name, o);
    }
    // The acceptance anchor: simulated interference strictly dominates the
    // static lower bound for the big model (its ticks now wait out the
    // 16B's chip time), and never undercuts it for the small one. At 4
    // instances the arms' routing DECISIONS can still differ (live loads
    // differ between an isolated and a co-resident fleet), so the strict
    // ordering is asserted at the full-scale operating point only; the
    // controlled 1-instance version — where the arms are identical except
    // for interference — is pinned unconditionally by the golden test
    // `golden_cluster_models_interference_dominates_static_bound`.
    if !fast {
        assert!(
            shared[0].0.tpot_ms.p99 > static_big.tpot_ms.p99,
            "interleaved co-residency must dominate the static bound: {} vs {}",
            shared[0].0.tpot_ms.p99,
            static_big.tpot_ms.p99
        );
        assert!(
            shared[1].0.tpot_ms.p99 >= static_small.tpot_ms.p99,
            "the 16B cannot be faster shared than isolated: {} vs {}",
            shared[1].0.tpot_ms.p99,
            static_small.tpot_ms.p99
        );
    }
    r.note(format!(
        "interference premium (p99 TPOT over the static bound): {} {:+.1}%, {} {:+.1}%",
        big.name,
        100.0 * (shared[0].0.tpot_ms.p99 - static_big.tpot_ms.p99) / static_big.tpot_ms.p99,
        small.name,
        100.0 * (shared[1].0.tpot_ms.p99 - static_small.tpot_ms.p99) / static_small.tpot_ms.p99,
    ));
    r.note(
        "shared pools trade KV headroom and batch ceiling for full-fleet parallelism per model; \
         partitioned pools isolate the models at the cost of static capacity splits",
    );
    r.note(
        "co-residency interference is now SIMULATED: the interleaved rows serialize both models' ticks on one \
         chip clock per instance, so the old static rows are exactly the lower bound they claimed to be",
    );
    r
}

/// `cluster_dynamic`: static (arrival-sequence) vs live routing on the
/// interleaved single-clock fleet. Static policies — round-robin and the
/// fluid least-outstanding proxy — make every decision from the arrival
/// sequence alone, exactly what the old two-phase simulation supported.
/// Live least-queue-depth reads each instance's engine snapshot (queue +
/// residents) at the decision time, a signal that only exists because all
/// instances advance on one event clock. At and above the saturation knee
/// the fluid proxy's belief diverges from actual per-instance progress
/// (slot/KV pressure, decode residency), so live routing holds p99 TTFT
/// strictly below static fluid routing — asserted here for both seeds.
fn cluster_dynamic(fast: bool, caches: &SimCaches) -> Report {
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let horizon = if fast { 3.0 } else { 8.0 };
    let rates: Vec<f64> = if fast { vec![1000.0, 8000.0] } else { vec![1000.0, 4000.0, 8000.0, 12000.0] };
    let seeds: [u64; 2] = [2026, 909];
    let policies = [
        ("round-robin (static)", RoutingPolicy::RoundRobin),
        ("fluid least-outstanding (static)", RoutingPolicy::LeastOutstanding),
        ("live least-queue-depth", RoutingPolicy::LeastQueueDepth),
    ];
    let top = rates.iter().cloned().fold(0.0f64, f64::max);
    let mut r = Report::new("Cluster — static vs live routing on the interleaved fleet (4 colocated instances)");
    r.preamble(format!(
        "4× EP32-PP2 colocated wafer instances on one event clock, poisson traffic, horizon {horizon} s, \
         seeds {seeds:?}; the 4-instance fleet's knee sits near 8000 rps — the top points drive at/past it"
    ));
    r.preamble(
        "static policies see only the arrival sequence (fluid work proxy); live least-queue-depth reads each \
         instance's engine snapshot at the decision time",
    );
    r.header(&[
        "seed", "routing", "rps", "done", "TTFT p50", "p99 (ms)", "TPOT p99", "goodput", "spills",
        "link busy", "wait (ms)",
    ]);
    for &seed in &seeds {
        let master = generate_trace(&TraceConfig::new(seed, TrafficPattern::Poisson, top, horizon));
        let mut top_ttft_p99: Vec<f64> = Vec::new(); // per policy, at the top rate
        for (name, policy) in policies {
            let ccfg = ClusterConfig { routing: policy, ..ClusterConfig::colocated(CLUSTER_FLEET, &ds) };
            for &rate in &rates {
                let trace = thin_trace(&master, rate / top, seed ^ 0xC0FF_EE00);
                let (o, _) =
                    simulate_cluster(&sys, &ds, &trace, &ccfg, horizon, rate, &caches.kernels, &caches.stages);
                assert!(o.conserves_requests(), "conservation violated: {name} seed {seed} @ {rate}");
                assert!(!o.kv_over_capacity, "KV overflow: {name} seed {seed} @ {rate}");
                r.row(vec![
                    seed.to_string(),
                    name.into(),
                    format!("{rate:.0}"),
                    o.completed.to_string(),
                    format!("{:.0}", o.ttft_ms.p50),
                    format!("{:.0}", o.ttft_ms.p99),
                    format!("{:.1}", o.tpot_ms.p99),
                    format!("{:.0}", o.goodput_rps),
                    o.router_spills.to_string(),
                    fmt_pct(o.link_busy_frac),
                    format!("{:.1}", o.link_wait_s * 1e3),
                ]);
                if rate == top {
                    top_ttft_p99.push(o.ttft_ms.p99);
                }
            }
        }
        let (fluid, live) = (top_ttft_p99[1], top_ttft_p99[2]);
        // The acceptance anchor: live routing must beat the static fluid
        // proxy on p99 TTFT at the overdriven point, on every seed. The
        // ordering is a property of the full-scale operating point the
        // preamble reasons about (the knee near 8000 rps over an 8 s
        // horizon); the shrunken fast sweep reports the same comparison as
        // a note without gating CI on a 3 s statistical window.
        if !fast {
            assert!(
                live < fluid,
                "live least-queue-depth must beat static fluid routing at {top:.0} rps (seed {seed}): \
                 {live:.1} ms vs {fluid:.1} ms"
            );
        }
        r.note(format!(
            "seed {seed}: at {top:.0} rps live least-queue-depth p99 TTFT {live:.0} ms vs static fluid \
             {fluid:.0} ms ({:+.1}%){}",
            100.0 * (live - fluid) / fluid,
            if fast { " [fast mode: informative only]" } else { "" }
        ));
    }
    r.note(
        "the fluid proxy balances deposited token work at an assumed drain rate; past the knee that belief \
         diverges from actual per-instance progress (KV pressure, decode residency, queue aging), which only \
         the live snapshot captures — the decode-side feedback the interleaved fleet makes possible",
    );
    r
}

/// Median per-token cadence of the requests that *arrived* at or after
/// `cut_s` and completed — the post-recovery window comparator of
/// `cluster_failures`. `None` when no such request finished.
fn median_tpot_after(recs: &[ClusterRecord], cut_s: f64) -> Option<f64> {
    let mut v: Vec<f64> = recs.iter().filter(|r| r.arrival_s >= cut_s).filter_map(|r| r.tpot_ms()).collect();
    if v.is_empty() {
        return None;
    }
    v.sort_by(f64::total_cmp);
    Some(v[v.len() / 2])
}

/// `cluster_failures`: fault injection on the disaggregated fleet — kill or
/// drain a decode instance mid-run and measure the blast radius against the
/// no-failure baseline. Runs on the d2d-class carrier link so a killed
/// instance's cold-start weight reload (~0.7 s for the 671B at 1 TB/s) can
/// rejoin inside the horizon; over inter-node NIC links the same reload
/// takes tens of seconds and a restart never lands in these windows.
fn cluster_failures(fast: bool, caches: &SimCaches) -> Report {
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let horizon = if fast { 3.0 } else { 8.0 };
    let rate = if fast { 400.0 } else { 1000.0 };
    let seed = 2026u64;
    let t_fault = horizon * 0.5;
    let restart_s = if fast { 0.25 } else { 0.5 };
    let mut ccfg = ClusterConfig::disaggregated(2, 2, &ds);
    ccfg.transfer = crate::cluster::KvTransferModel::d2d_class(&ds, ccfg.serve.dtype);
    let trace = generate_trace(&TraceConfig::new(seed, TrafficPattern::Poisson, rate, horizon));
    // Global engine id 3 = decode instance 1 (the entry pool is gids 0..2).
    let victim = 3usize;
    let scenarios: [(&str, FaultPlan); 4] = [
        ("baseline (no faults)", FaultPlan::none()),
        ("drain decode@mid", FaultPlan::none().drain(victim, t_fault)),
        ("kill decode@mid", FaultPlan::none().kill(victim, t_fault)),
        ("kill + restart", FaultPlan::none().kill(victim, t_fault).with_restart(restart_s)),
    ];
    let run = |plan: &FaultPlan, shards: u32| {
        let cfg = ClusterConfig { shards, ..ccfg };
        simulate_cluster_faulted_observed(
            &sys,
            &ds,
            &trace,
            &cfg,
            plan,
            horizon,
            rate,
            &caches.kernels,
            &caches.stages,
            None,
        )
    };
    let mut r = Report::new("Cluster — fault injection: kill/drain a decode instance mid-run");
    r.preamble(format!(
        "2 prefill + 2 decode EP32-PP2 wafer instances on a d2d-class carrier link, poisson {rate:.0} rps over \
         {horizon} s, seed {seed}; faults hit decode instance 1 (gid {victim}) at {t_fault:.1} s — a kill aborts \
         at the epoch barrier and requeues stranded work through the entry router (lost KV re-prefilled and \
         re-shipped); the restarted kill rejoins {restart_s} s later plus a weight reload billed over the link"
    ));
    r.header(&[
        "scenario", "done", "requeued", "lost", "KV lost (GB)", "TTFT p50", "p99 (ms)", "TPOT p50",
        "p99 (ms)", "goodput",
    ]);
    let mut results: Vec<(ClusterOutcome, Vec<ClusterRecord>)> = Vec::new();
    for (name, plan) in &scenarios {
        let (o, recs, _) = run(plan, 1);
        assert!(o.conserves_requests(), "conservation violated under faults ({name}): {o:?}");
        r.row(vec![
            (*name).into(),
            o.completed.to_string(),
            o.requeued.to_string(),
            o.lost.to_string(),
            format!("{:.2}", o.kv_lost_bytes as f64 / 1e9),
            format!("{:.0}", o.ttft_ms.p50),
            format!("{:.0}", o.ttft_ms.p99),
            format!("{:.1}", o.tpot_ms.p50),
            format!("{:.1}", o.tpot_ms.p99),
            format!("{:.0}", o.goodput_rps),
        ]);
        results.push((o, recs));
    }
    // Determinism anchor: the kill plan must be byte-identical at every
    // shard count — faults only ever apply at the epoch barriers.
    for shards in [2u32, 4] {
        let (mut o, recs, _) = run(&scenarios[2].1, shards);
        o.shards = 1;
        assert_eq!(o, results[2].0, "shard count {shards} diverged under the kill plan");
        assert_eq!(recs, results[2].1, "per-request records diverged at {shards} shards");
    }
    r.note("kill scenario replayed at 2 and 4 shards: byte-identical outcome and per-request records");
    let base = &results[0].0;
    let kill = &results[2].0;
    let blast = kill.ttft_ms.p99 / base.ttft_ms.p99.max(1e-9);
    r.note(format!(
        "blast radius: kill p99 TTFT {:.0} ms vs baseline {:.0} ms ({blast:.1}x) — requeued prefills re-bill \
         and the surviving decode instance absorbs the whole pool",
        kill.ttft_ms.p99, base.ttft_ms.p99
    ));
    // Post-recovery cadence: arrivals two fault-free epochs after the
    // restart rejoined, against the same window of the baseline.
    let cut = t_fault + if fast { 1.0 } else { 2.0 };
    let base_win = median_tpot_after(&results[0].1, cut);
    let back_win = median_tpot_after(&results[3].1, cut);
    if let (Some(b), Some(k)) = (base_win, back_win) {
        r.note(format!(
            "recovery: post-{cut:.1} s arrivals decode at p50 TPOT {k:.1} ms vs baseline {b:.1} ms ({:+.1}%)",
            100.0 * (k - b) / b
        ));
        let n = results[3].1.iter().filter(|r| r.arrival_s >= cut && r.tpot_ms().is_some()).count();
        if !fast && n >= 20 {
            assert!(
                k <= b * 1.10,
                "post-recovery TPOT did not return to within 10% of baseline: {k:.2} vs {b:.2} ms"
            );
        }
    }
    if !fast {
        assert!(kill.requeued > 0, "a mid-run decode kill must strand work: {kill:?}");
        assert!(kill.kv_lost_bytes > 0, "a decode kill must lose landed KV: {kill:?}");
        assert!(blast < 20.0, "p99 TTFT blast radius unbounded: {blast:.1}x");
        assert_eq!(results[1].0.requeued, 0, "a drain must never strand work");
    }
    r
}

/// `cluster_topology`: the fleet-level analogue of Fig. 7 — the same KV
/// handoff traffic routed over three inter-instance fabrics (the pooled
/// degenerate switch, a 2D torus, a two-level fat-tree), with and without
/// hop-aware decode placement. On the torus the prefill→decode pool
/// boundary edges serialize the traffic, and `topo-aware` placement folds
/// the hop count into the decode cost so handoffs land on close,
/// lightly-loaded instances — the golden anchor pins it strictly beating
/// topology-blind least-queue-depth at p99 TTFT *and* mean link wait at
/// the overdriven point. Where the hop profile is flat (every pair one hop
/// on the pooled switch; every prefill→decode pair cross-leaf at four hops
/// on this fat-tree), the penalty carries no signal and topo-aware must
/// reproduce least-queue-depth decision-for-decision — asserted bit-equal
/// even in fast mode.
fn cluster_topology(fast: bool, caches: &SimCaches) -> Report {
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let (prefill, decode) = if fast { (2u32, 2u32) } else { (8, 8) };
    let horizon = if fast { 3.0 } else { 6.0 };
    let rate = if fast { 400.0 } else { 2400.0 };
    let seed = 2026u64;
    let trace = generate_trace(
        &TraceConfig::new(seed, TrafficPattern::Poisson, rate, horizon).with_prefixes(PrefixProfile::agentic()),
    );
    let topologies = [TopologySpec::Degenerate, TopologySpec::Torus, TopologySpec::FatTree];
    let routings = [("least-queue-depth", RoutingPolicy::LeastQueueDepth), ("topo-aware", RoutingPolicy::TopoAware)];
    let mut r = Report::new("Cluster — KV fabric topology × hop-aware decode placement (disaggregated fleet)");
    r.preamble(format!(
        "{prefill} prefill + {decode} decode EP32-PP2 wafer instances, poisson {rate:.0} rps (70% shared prompts) \
         over {horizon} s, seed {seed}; every KV handoff routes over explicit fabric edges (dimension-ordered on \
         the torus, up/down on the fat-tree) with per-edge busy-until ledgers — the decode pool sits across the \
         torus pool boundary, so those edges congest first at the overdriven point"
    ));
    r.preamble(
        "decode placement: least-queue-depth reads live queue depths only (topology-blind); topo-aware adds one \
         queue slot of cost per fabric hop, trading distance against depth",
    );
    r.header(&[
        "topology", "decode routing", "done", "TTFT p50", "p99 (ms)", "TPOT p99", "goodput", "link busy",
        "wait/mig (ms)", "hops/mig",
    ]);
    let mut by_topo: Vec<Vec<ClusterOutcome>> = Vec::new();
    for topo in topologies {
        let mut pair = Vec::new();
        for (name, policy) in routings {
            let mut ccfg = ClusterConfig::disaggregated(prefill, decode, &ds);
            ccfg.topology = topo;
            ccfg.decode_routing = policy;
            let (o, _) = simulate_cluster(&sys, &ds, &trace, &ccfg, horizon, rate, &caches.kernels, &caches.stages);
            assert!(o.conserves_requests(), "conservation violated: {} / {name}", topo.label());
            let mig = o.migrated.max(1) as f64;
            r.row(vec![
                topo.label().into(),
                name.into(),
                o.completed.to_string(),
                format!("{:.0}", o.ttft_ms.p50),
                format!("{:.0}", o.ttft_ms.p99),
                format!("{:.1}", o.tpot_ms.p99),
                format!("{:.0}", o.goodput_rps),
                fmt_pct(o.link_busy_frac),
                format!("{:.2}", o.link_wait_s * 1e3 / mig),
                format!("{:.2}", o.fabric_hops as f64 / mig),
            ]);
            pair.push(o);
        }
        by_topo.push(pair);
    }
    // Structural identity on flat hop profiles — no statistical window, so
    // it gates in fast mode too.
    for (ti, topo_name) in [(0usize, "degenerate"), (2, "fat-tree")] {
        let (blind, aware) = (&by_topo[ti][0], &by_topo[ti][1]);
        assert_eq!(blind.completed, aware.completed, "{topo_name}: flat hop profile must not change placement");
        assert_eq!(blind.ttft_ms, aware.ttft_ms, "{topo_name}: flat hop profile must not change TTFT");
        assert_eq!(blind.fabric_hops, aware.fabric_hops, "{topo_name}: flat hop profile must not change routes");
    }
    r.note(
        "flat hop profiles (pooled switch: one hop; fat-tree pools: cross-leaf four hops) leave topo-aware \
         decision-identical to least-queue-depth — asserted bit-equal",
    );
    let (blind, aware) = (&by_topo[1][0], &by_topo[1][1]);
    let blind_wait = blind.link_wait_s / blind.migrated.max(1) as f64;
    let aware_wait = aware.link_wait_s / aware.migrated.max(1) as f64;
    // The acceptance anchor: hop-aware placement must strictly win both
    // p99 TTFT and mean link wait on the contended torus at full scale.
    // The shrunken fast sweep reports the same comparison as a note
    // without gating CI on a 3 s statistical window.
    if !fast {
        assert!(
            aware.ttft_ms.p99 < blind.ttft_ms.p99,
            "topo-aware must beat least-queue-depth p99 TTFT on the contended torus: {:.1} vs {:.1} ms",
            aware.ttft_ms.p99,
            blind.ttft_ms.p99
        );
        assert!(
            aware_wait < blind_wait,
            "topo-aware must beat least-queue-depth mean link wait on the contended torus: {:.4} vs {:.4} s",
            aware_wait,
            blind_wait
        );
        assert!(
            aware.fabric_hops * blind.migrated as u64 <= blind.fabric_hops * aware.migrated as u64,
            "topo-aware must not lengthen routes: {} hops/{} migs vs {} hops/{} migs",
            aware.fabric_hops,
            aware.migrated,
            blind.fabric_hops,
            blind.migrated
        );
    }
    r.note(format!(
        "torus @ {rate:.0} rps: topo-aware p99 TTFT {:.0} ms vs least-queue-depth {:.0} ms ({:+.1}%); mean link \
         wait {:.2} vs {:.2} ms/migration{}",
        aware.ttft_ms.p99,
        blind.ttft_ms.p99,
        100.0 * (aware.ttft_ms.p99 - blind.ttft_ms.p99) / blind.ttft_ms.p99.max(1e-9),
        aware_wait * 1e3,
        blind_wait * 1e3,
        if fast { " [fast mode: informative only]" } else { "" }
    ));
    r.note(
        "the fleet-level Fig. 7 claim: fabric topology and placement, not raw bandwidth, set the KV handoff \
         cost — the same traffic on the same links swings p99 TTFT purely by where it is routed",
    );
    r
}

/// One fleet simulation at a caller-chosen mode/routing/topology/link/rate/
/// horizon/seed (the `flatattention cluster --prefill/--decode/...` path).
/// `d2d_link` swaps the inter-node KV-handoff fabric for the D2D-class one
/// (instances on a single wafer carrier).
#[allow(clippy::too_many_arguments)]
pub fn cluster_custom(
    mode: FleetMode,
    routing: RoutingPolicy,
    topology: TopologySpec,
    d2d_link: bool,
    rate: f64,
    horizon: f64,
    seed: u64,
    caches: &SimCaches,
) -> Report {
    cluster_custom_observed(mode, routing, topology, d2d_link, rate, horizon, seed, &FaultPlan::none(), 1, caches, None)
        .0
}

/// Shared CLI-path fleet config: topology threads into the fabric, and
/// `--routing topo-aware` steers the *decode* placement too (the hop
/// signal only exists on the prefill→decode handoff; the entry router
/// falls back to least-queue-depth there, as documented on the policy).
fn cluster_custom_config(mode: FleetMode, routing: RoutingPolicy, topology: TopologySpec, d2d_link: bool) -> ClusterConfig {
    let ds = DeepSeekConfig::v3_671b();
    let mut ccfg = ClusterConfig { mode, ..ClusterConfig::colocated(mode.instances(), &ds) };
    ccfg.routing = routing;
    ccfg.topology = topology;
    if routing == RoutingPolicy::TopoAware {
        ccfg.decode_routing = RoutingPolicy::TopoAware;
    }
    if d2d_link {
        ccfg.transfer = crate::cluster::KvTransferModel::d2d_class(&ds, ccfg.serve.dtype);
    }
    ccfg
}

/// [`cluster_custom`] with an optional observability sink and fault plan:
/// same fleet simulation and report, plus the Chrome-trace / gauge-series /
/// Prometheus exports when `obs` is set (the `flatattention cluster
/// --trace-out/...` path) and scheduled kill/drain events when `faults` is
/// non-empty (the `--kill`/`--drain`/`--random-kills` path). `shards`
/// selects the sharded conservative-lookahead engine (1 = inline serial
/// path; any value is bit-identical, faults included).
#[allow(clippy::too_many_arguments)]
pub fn cluster_custom_observed(
    mode: FleetMode,
    routing: RoutingPolicy,
    topology: TopologySpec,
    d2d_link: bool,
    rate: f64,
    horizon: f64,
    seed: u64,
    faults: &FaultPlan,
    shards: u32,
    caches: &SimCaches,
    obs: Option<ObsConfig>,
) -> (Report, Option<ObsExports>) {
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let trace = generate_trace(
        &TraceConfig::new(seed, TrafficPattern::Poisson, rate, horizon).with_prefixes(PrefixProfile::agentic()),
    );
    let mut ccfg = cluster_custom_config(mode, routing, topology, d2d_link);
    ccfg.shards = shards.max(1);
    let obs_on = obs.is_some();
    let (o, _, bundle, profile) = simulate_cluster_profiled(
        &sys,
        &ds,
        &trace,
        &ccfg,
        faults,
        horizon,
        rate,
        &caches.kernels,
        &caches.stages,
        obs,
    );
    let exports = bundle.map(|b| b.exports());
    assert!(o.conserves_requests(), "request conservation violated");
    let mut r = Report::new("Cluster — custom fleet simulation (DeepSeek-v3-671B wafer instances)");
    r.preamble(format!(
        "{} fleet, {} arrival routing, {} fabric over the {} KV link, poisson {rate:.0} rps (70% shared prompts) \
         over {horizon} s, seed {seed}, {} shard(s){}",
        mode.label(),
        routing.label(),
        topology.label(),
        if d2d_link { "d2d-class" } else { "inter-node" },
        ccfg.shards,
        if faults.is_empty() { String::new() } else { format!(", {} scheduled fault(s)", faults.events.len()) },
    ));
    r.header(&CLUSTER_ROW_HEADER);
    r.row(cluster_outcome_row(&o));
    for (i, s) in o.instances.iter().enumerate() {
        r.note(format!(
            "instance {i} ({}): routed {}, done {}, backlog {}, {:.0} tok/s, KV peak {}, prefix hits {} tokens",
            s.role,
            s.routed,
            s.completed,
            s.backlog,
            s.tokens_per_s,
            fmt_pct(s.peak_kv_occupancy),
            s.prefix_hit_tokens
        ));
    }
    r.note(format!(
        "router: {} affinity spills; link: {} busy, {:.1} ms queued across {} migrations",
        o.router_spills,
        fmt_pct(o.link_busy_frac),
        o.link_wait_s * 1e3,
        o.migrated
    ));
    if o.migrated > 0 {
        let hot = o.edge_busy_s.iter().cloned().fold(0.0f64, f64::max);
        r.note(format!(
            "fabric: {} hop(s) billed over {} edge(s) ({:.2} hops/migration); hottest edge {} busy",
            o.fabric_hops,
            o.edge_busy_s.len(),
            o.fabric_hops as f64 / o.migrated.max(1) as f64,
            fmt_pct(hot / horizon.max(1e-12)),
        ));
    }
    if !faults.is_empty() {
        r.note(format!(
            "faults: {} applied, {} requests requeued, {} lost past the horizon, {:.2} GB KV lost",
            o.faults,
            o.requeued,
            o.lost,
            o.kv_lost_bytes as f64 / 1e9
        ));
    }
    // Wall-clock diagnostic only: printed as a note when the obs layer is
    // on, never part of the byte-pinned exports or the no-obs report.
    if obs_on {
        r.note(profile.note());
    }
    (r, exports)
}

/// The `flatattention report serve` path: run the observed serving
/// simulation, assemble the per-request waterfalls and kernel attribution,
/// and return the rendered profile text plus its `flatattention-attrib-v1`
/// JSON export.
pub fn serve_report(
    policy: QueuePolicy,
    rate: f64,
    horizon: f64,
    seed: u64,
    caches: &SimCaches,
) -> (String, String) {
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let cfg = ServeConfig {
        scheduler: SchedulerConfig { queue_policy: policy, ..Default::default() },
        ..Default::default()
    };
    let trace = generate_trace(&TraceConfig::new(seed, TrafficPattern::Poisson, rate, horizon));
    let (o, records, sink) = simulate_observed(
        &sys,
        &ds,
        &trace,
        &cfg,
        horizon,
        policy.label(),
        rate,
        &caches.kernels,
        &caches.stages,
        ObsConfig::default(),
    );
    assert!(o.conserves_requests(), "request conservation violated");
    let attrib = assemble_serve_attrib(&records, &sink);
    let title = format!("serve — {} @ {rate:.0} rps over {horizon} s, seed {seed}", policy.label());
    (render_attrib_report(&title, &attrib, None), attrib.to_json())
}

/// The `flatattention report cluster` path: [`cluster_custom_observed`]'s
/// simulation with the attribution export rendered as the profile instead
/// of the outcome table. The DES self-profile rides along as a note
/// (wall-clock by design; the returned JSON stays deterministic).
#[allow(clippy::too_many_arguments)]
pub fn cluster_report(
    mode: FleetMode,
    routing: RoutingPolicy,
    topology: TopologySpec,
    d2d_link: bool,
    rate: f64,
    horizon: f64,
    seed: u64,
    faults: &FaultPlan,
    shards: u32,
    caches: &SimCaches,
) -> (String, String) {
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let trace = generate_trace(
        &TraceConfig::new(seed, TrafficPattern::Poisson, rate, horizon).with_prefixes(PrefixProfile::agentic()),
    );
    let mut ccfg = cluster_custom_config(mode, routing, topology, d2d_link);
    ccfg.shards = shards.max(1);
    let (o, _, bundle, profile) = simulate_cluster_profiled(
        &sys,
        &ds,
        &trace,
        &ccfg,
        faults,
        horizon,
        rate,
        &caches.kernels,
        &caches.stages,
        Some(ObsConfig::default()),
    );
    assert!(o.conserves_requests(), "request conservation violated");
    let attrib = bundle.expect("obs was requested above").attrib;
    let title = format!(
        "cluster — {} fleet, {} routing, {} fabric @ {rate:.0} rps over {horizon} s, seed {seed}, {} shard(s)",
        mode.label(),
        routing.label(),
        topology.label(),
        ccfg.shards
    );
    let mut text = render_attrib_report(&title, &attrib, Some(&profile));
    // Per-edge hotspot footer: where the KV traffic actually serialized.
    if let Some((e, busy)) = o.edge_busy_s.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)) {
        text.push_str(&format!(
            "fabric hotspot: edge {e}/{} busiest at {} of the horizon ({} hops billed across {} migrations)\n",
            o.edge_busy_s.len(),
            fmt_pct(busy / horizon.max(1e-12)),
            o.fabric_hops,
            o.migrated
        ));
    }
    (text, attrib.to_json())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_lists_all() {
        let ids = list();
        assert!(ids.len() >= 14);
        for (id, _) in ids {
            let rep = run(id, true).unwrap_or_else(|e| panic!("experiment {id} failed: {e}"));
            assert!(!rep.render().is_empty());
        }
    }

    #[test]
    fn unknown_experiment_errors() {
        assert!(run("nope", true).is_err());
    }
}
