//! Argument parsing for the `flatattention serve` subcommand.
//!
//! Lives in the library (not `main.rs`) so the parser is unit-testable:
//! bad policy names, malformed numbers and out-of-range rates must come
//! back as `Err`, never as a panic inside the CLI.

use anyhow::{bail, Result};

use crate::serve::scheduler::QueuePolicy;

/// Parsed `flatattention serve` options.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServeArgs {
    /// Shrink sweeps (test/CI mode).
    pub fast: bool,
    /// Also run the KV admission-policy comparison.
    pub policies: bool,
    /// Run the prefix-cache / scheduling-policy experiment instead of the
    /// load sweep.
    pub prefix: bool,
    /// Queue policy for the custom sweep (`--policy`, default FCFS).
    pub queue_policy: QueuePolicy,
    /// Custom offered load in requests/s (`--rate`).
    pub rate_rps: Option<f64>,
    /// Custom horizon in seconds (`--horizon`).
    pub horizon_s: Option<f64>,
    /// Trace seed (`--seed`, default 2026).
    pub seed: u64,
}

impl Default for ServeArgs {
    fn default() -> Self {
        ServeArgs {
            fast: false,
            policies: false,
            prefix: false,
            queue_policy: QueuePolicy::Fcfs,
            rate_rps: None,
            horizon_s: None,
            seed: 2026,
        }
    }
}

impl ServeArgs {
    /// True when the user asked for a non-default single sweep (a custom
    /// rate/horizon/queue-policy) rather than the canned `serve_load`.
    pub fn is_custom(&self) -> bool {
        self.queue_policy != QueuePolicy::Fcfs
            || self.rate_rps.is_some()
            || self.horizon_s.is_some()
            || self.seed != 2026
    }

    /// Parse the argument tail after `serve`. Unknown flags, bad policy
    /// names and out-of-range numbers are errors, not panics.
    pub fn parse(args: &[String]) -> Result<ServeArgs> {
        let mut out = ServeArgs::default();
        let mut i = 0usize;
        while i < args.len() {
            match args[i].as_str() {
                "--fast" => out.fast = true,
                "--policies" => out.policies = true,
                "--prefix" => out.prefix = true,
                "--policy" => {
                    let v = value(args, i, "--policy")?;
                    out.queue_policy = match QueuePolicy::parse(v) {
                        Some(p) => p,
                        None => bail!("unknown queue policy '{v}' (expected fcfs|sjf|priority)"),
                    };
                    i += 1;
                }
                "--rate" => {
                    let v = parse_num(args, i, "--rate")?;
                    if !(v > 0.0 && v <= 1e6) {
                        bail!("--rate must be in (0, 1e6] requests/s, got {v}");
                    }
                    out.rate_rps = Some(v);
                    i += 1;
                }
                "--horizon" => {
                    let v = parse_num(args, i, "--horizon")?;
                    if !(v > 0.0 && v <= 3600.0) {
                        bail!("--horizon must be in (0, 3600] seconds, got {v}");
                    }
                    out.horizon_s = Some(v);
                    i += 1;
                }
                "--seed" => {
                    let v = value(args, i, "--seed")?;
                    out.seed = match v.parse::<u64>() {
                        Ok(s) => s,
                        Err(_) => bail!("--seed expects an unsigned integer, got '{v}'"),
                    };
                    i += 1;
                }
                other => bail!("unknown serve option '{other}'; see `flatattention help`"),
            }
            i += 1;
        }
        Ok(out)
    }
}

fn value<'a>(args: &'a [String], i: usize, flag: &str) -> Result<&'a str> {
    match args.get(i + 1) {
        Some(v) => Ok(v.as_str()),
        None => bail!("{flag} expects a value"),
    }
}

fn parse_num(args: &[String], i: usize, flag: &str) -> Result<f64> {
    let v = value(args, i, flag)?;
    match v.parse::<f64>() {
        Ok(x) if x.is_finite() => Ok(x),
        _ => bail!("{flag} expects a finite number, got '{v}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_and_flags() {
        let a = ServeArgs::parse(&argv(&[])).unwrap();
        assert_eq!(a, ServeArgs::default());
        assert!(!a.is_custom());
        let a = ServeArgs::parse(&argv(&["--fast", "--policies", "--prefix"])).unwrap();
        assert!(a.fast && a.policies && a.prefix);
        assert!(!a.is_custom());
    }

    #[test]
    fn parses_policy_rate_horizon_seed() {
        let a = ServeArgs::parse(&argv(&[
            "--policy", "sjf", "--rate", "800", "--horizon", "12.5", "--seed", "7",
        ]))
        .unwrap();
        assert_eq!(a.queue_policy, QueuePolicy::Sjf);
        assert_eq!(a.rate_rps, Some(800.0));
        assert_eq!(a.horizon_s, Some(12.5));
        assert_eq!(a.seed, 7);
        assert!(a.is_custom());
    }

    #[test]
    fn bad_policy_name_is_an_error_not_a_panic() {
        let e = ServeArgs::parse(&argv(&["--policy", "lifo"])).unwrap_err();
        assert!(e.to_string().contains("unknown queue policy"), "{e}");
        assert!(ServeArgs::parse(&argv(&["--policy"])).is_err(), "missing value");
    }

    #[test]
    fn out_of_range_and_malformed_rates_error() {
        for bad in [["--rate", "0"], ["--rate", "-5"], ["--rate", "1e9"], ["--rate", "abc"]] {
            assert!(ServeArgs::parse(&argv(&bad)).is_err(), "{bad:?} must fail");
        }
        for bad in [["--horizon", "0"], ["--horizon", "1e7"], ["--horizon", "NaN"]] {
            assert!(ServeArgs::parse(&argv(&bad)).is_err(), "{bad:?} must fail");
        }
        assert!(ServeArgs::parse(&argv(&["--seed", "-1"])).is_err());
        assert!(ServeArgs::parse(&argv(&["--bogus"])).is_err());
    }
}
