//! Argument parsing for the `flatattention serve` and `flatattention
//! cluster` subcommands.
//!
//! Lives in the library (not `main.rs`) so the parsers are unit-testable:
//! bad policy names, malformed numbers, out-of-range rates and inconsistent
//! pool specs must come back as `Err`, never as a panic inside the CLI.

use anyhow::{bail, Result};

use crate::cluster::{FaultPlan, FleetMode, RoutingPolicy, TopologySpec};
use crate::serve::scheduler::QueuePolicy;

/// Parsed `flatattention serve` options.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeArgs {
    /// Shrink sweeps (test/CI mode).
    pub fast: bool,
    /// Also run the KV admission-policy comparison.
    pub policies: bool,
    /// Run the prefix-cache / scheduling-policy experiment instead of the
    /// load sweep.
    pub prefix: bool,
    /// Queue policy for the custom sweep (`--policy`, default FCFS).
    pub queue_policy: QueuePolicy,
    /// Custom offered load in requests/s (`--rate`).
    pub rate_rps: Option<f64>,
    /// Custom horizon in seconds (`--horizon`).
    pub horizon_s: Option<f64>,
    /// Trace seed (`--seed`, default 2026).
    pub seed: u64,
    /// On-disk kernel/stage cache directory (`--cache-dir`): loaded at
    /// startup, written back after the run. Orthogonal to custom-run
    /// dispatch — caching never changes a result.
    pub cache_dir: Option<String>,
    /// Chrome-trace JSON output path (`--trace-out`). Orthogonal to
    /// custom-run dispatch — observability never changes a result.
    pub trace_out: Option<String>,
    /// Gauge-series output path (`--series-out`; CSV, or JSON when the
    /// path ends in `.json`).
    pub series_out: Option<String>,
    /// Prometheus text-format counter output path (`--metrics-out`).
    pub metrics_out: Option<String>,
    /// Performance-attribution JSON output path (`--attrib-out`): kernel
    /// rooflines + latency waterfalls (`flatattention-attrib-v1`).
    pub attrib_out: Option<String>,
    /// Worker-thread budget (`--threads`): pins
    /// [`crate::util::set_worker_threads`]. Orthogonal to custom-run
    /// dispatch — thread counts never change a result.
    pub threads: Option<usize>,
}

impl Default for ServeArgs {
    fn default() -> Self {
        ServeArgs {
            fast: false,
            policies: false,
            prefix: false,
            queue_policy: QueuePolicy::Fcfs,
            rate_rps: None,
            horizon_s: None,
            seed: 2026,
            cache_dir: None,
            trace_out: None,
            series_out: None,
            metrics_out: None,
            attrib_out: None,
            threads: None,
        }
    }
}

impl ServeArgs {
    /// True when the user asked for a non-default single sweep (a custom
    /// rate/horizon/queue-policy) rather than the canned `serve_load`.
    pub fn is_custom(&self) -> bool {
        self.queue_policy != QueuePolicy::Fcfs
            || self.rate_rps.is_some()
            || self.horizon_s.is_some()
            || self.seed != 2026
    }

    /// True when any observability export was requested.
    pub fn obs_requested(&self) -> bool {
        self.trace_out.is_some()
            || self.series_out.is_some()
            || self.metrics_out.is_some()
            || self.attrib_out.is_some()
    }

    /// Parse the argument tail after `serve`. Unknown flags, bad policy
    /// names and out-of-range numbers are errors, not panics.
    pub fn parse(args: &[String]) -> Result<ServeArgs> {
        let mut out = ServeArgs::default();
        let mut i = 0usize;
        while i < args.len() {
            match args[i].as_str() {
                "--fast" => out.fast = true,
                "--policies" => out.policies = true,
                "--prefix" => out.prefix = true,
                "--policy" => {
                    let v = value(args, i, "--policy")?;
                    out.queue_policy = match QueuePolicy::parse(v) {
                        Some(p) => p,
                        None => bail!("unknown queue policy '{v}' (expected fcfs|sjf|priority)"),
                    };
                    i += 1;
                }
                "--rate" => {
                    let v = parse_num(args, i, "--rate")?;
                    if !(v > 0.0 && v <= 1e6) {
                        bail!("--rate must be in (0, 1e6] requests/s, got {v}");
                    }
                    out.rate_rps = Some(v);
                    i += 1;
                }
                "--horizon" => {
                    let v = parse_num(args, i, "--horizon")?;
                    if !(v > 0.0 && v <= 3600.0) {
                        bail!("--horizon must be in (0, 3600] seconds, got {v}");
                    }
                    out.horizon_s = Some(v);
                    i += 1;
                }
                "--seed" => {
                    let v = value(args, i, "--seed")?;
                    out.seed = match v.parse::<u64>() {
                        Ok(s) => s,
                        Err(_) => bail!("--seed expects an unsigned integer, got '{v}'"),
                    };
                    i += 1;
                }
                "--cache-dir" => {
                    out.cache_dir = Some(value(args, i, "--cache-dir")?.to_string());
                    i += 1;
                }
                "--trace-out" => {
                    out.trace_out = Some(value(args, i, "--trace-out")?.to_string());
                    i += 1;
                }
                "--series-out" => {
                    out.series_out = Some(value(args, i, "--series-out")?.to_string());
                    i += 1;
                }
                "--metrics-out" => {
                    out.metrics_out = Some(value(args, i, "--metrics-out")?.to_string());
                    i += 1;
                }
                "--attrib-out" => {
                    out.attrib_out = Some(value(args, i, "--attrib-out")?.to_string());
                    i += 1;
                }
                "--threads" => {
                    out.threads = Some(parse_threads(args, i)?);
                    i += 1;
                }
                other => bail!("unknown serve option '{other}'; see `flatattention help`"),
            }
            i += 1;
        }
        Ok(out)
    }
}

/// Inter-instance KV-handoff link class of a custom cluster run
/// (`--link`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum LinkClass {
    /// RDMA-NIC-class inter-node fabric (the default).
    #[default]
    InterNode,
    /// D2D-class links — instances on one wafer carrier.
    D2dClass,
}

impl LinkClass {
    pub fn label(self) -> &'static str {
        match self {
            LinkClass::InterNode => "inter-node",
            LinkClass::D2dClass => "d2d",
        }
    }

    /// Parse a CLI link-class name (case-insensitive).
    pub fn parse(s: &str) -> Option<LinkClass> {
        match s.to_ascii_lowercase().as_str() {
            "inter-node" | "internode" | "nic" => Some(LinkClass::InterNode),
            "d2d" | "d2d-class" | "wafer" => Some(LinkClass::D2dClass),
            _ => None,
        }
    }
}

/// Parsed `flatattention cluster` options.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterArgs {
    /// Shrink sweeps (test/CI mode).
    pub fast: bool,
    /// Run the multi-model co-serving experiment instead of the pool sweep.
    pub models: bool,
    /// Run the static-vs-live routing experiment instead of the pool sweep.
    pub dynamic: bool,
    /// Arrival-routing policy for the custom fleet (`--routing`). The
    /// `topo-aware` policy additionally steers decode placement by fabric
    /// hop distance.
    pub routing: RoutingPolicy,
    /// KV-handoff link class for the custom fleet (`--link`).
    pub link: LinkClass,
    /// Inter-instance fabric topology of the custom fleet (`--topology`,
    /// default the degenerate pooled switch).
    pub topology: TopologySpec,
    /// Prefill-pool size of a custom disaggregated fleet (`--prefill`).
    pub prefill: Option<u32>,
    /// Decode-pool size of a custom disaggregated fleet (`--decode`).
    pub decode: Option<u32>,
    /// Colocated fleet size of a custom run (`--instances`).
    pub instances: Option<u32>,
    /// Custom offered load in requests/s (`--rate`).
    pub rate_rps: Option<f64>,
    /// Custom horizon in seconds (`--horizon`).
    pub horizon_s: Option<f64>,
    /// Trace seed (`--seed`, default 2026).
    pub seed: u64,
    /// On-disk kernel/stage cache directory (`--cache-dir`): loaded at
    /// startup, written back after the run. Orthogonal to custom-run
    /// dispatch — caching never changes a result.
    pub cache_dir: Option<String>,
    /// Chrome-trace JSON output path (`--trace-out`). Orthogonal to
    /// custom-run dispatch — observability never changes a result.
    pub trace_out: Option<String>,
    /// Gauge-series output path (`--series-out`; CSV, or JSON when the
    /// path ends in `.json`).
    pub series_out: Option<String>,
    /// Prometheus text-format counter output path (`--metrics-out`).
    pub metrics_out: Option<String>,
    /// Performance-attribution JSON output path (`--attrib-out`): kernel
    /// rooflines + latency waterfalls (`flatattention-attrib-v1`).
    pub attrib_out: Option<String>,
    /// Shard count of the custom fleet's conservative-lookahead engine
    /// (`--shards`, default 1 = inline serial path). Bit-identical at any
    /// value — shards only control concurrency — but it selects a custom
    /// run because only the custom path takes a shard count.
    pub shards: u32,
    /// Worker-thread budget (`--threads`): pins
    /// [`crate::util::set_worker_threads`]. Orthogonal to custom-run
    /// dispatch — thread counts never change a result.
    pub threads: Option<usize>,
    /// Scheduled kills (`--kill <instance>@<seconds>`, repeatable): the
    /// instance aborts at the next epoch barrier and its work requeues.
    pub kills: Vec<(usize, f64)>,
    /// Scheduled drains (`--drain <instance>@<seconds>`, repeatable): the
    /// router masks the instance, residents run to completion.
    pub drains: Vec<(usize, f64)>,
    /// Rejoin delay applied to every scheduled fault (`--fault-restart`).
    pub fault_restart_s: Option<f64>,
    /// Seeded random-failure mode (`--random-kills N`): N kill times drawn
    /// uniformly over the horizon from the trace seed.
    pub random_kills: usize,
    /// Set when ANY custom-fleet flag was given, even with a value equal to
    /// its default — `--seed 2026` is still a request for a custom run.
    custom: bool,
}

impl Default for ClusterArgs {
    fn default() -> Self {
        ClusterArgs {
            fast: false,
            models: false,
            dynamic: false,
            routing: RoutingPolicy::PrefixAffinity,
            link: LinkClass::InterNode,
            topology: TopologySpec::Degenerate,
            prefill: None,
            decode: None,
            instances: None,
            rate_rps: None,
            horizon_s: None,
            seed: 2026,
            cache_dir: None,
            trace_out: None,
            series_out: None,
            metrics_out: None,
            attrib_out: None,
            shards: 1,
            threads: None,
            kills: Vec::new(),
            drains: Vec::new(),
            fault_restart_s: None,
            random_kills: 0,
            custom: false,
        }
    }
}

impl ClusterArgs {
    /// True when the user asked for a single custom fleet simulation rather
    /// than the canned `cluster_pools` sweep (any of `--routing`,
    /// `--prefill/--decode`, `--instances`, `--rate`, `--horizon`, `--seed`
    /// appeared — explicitly-passed default values count).
    pub fn is_custom(&self) -> bool {
        self.custom
    }

    /// True when any observability export was requested.
    pub fn obs_requested(&self) -> bool {
        self.trace_out.is_some()
            || self.series_out.is_some()
            || self.metrics_out.is_some()
            || self.attrib_out.is_some()
    }

    /// True when any fault-injection flag was given.
    pub fn has_faults(&self) -> bool {
        !self.kills.is_empty() || !self.drains.is_empty() || self.random_kills > 0
    }

    /// Assemble the fault schedule of a custom run. Scheduled kills and
    /// drains carry the `--fault-restart` rejoin delay when one was given;
    /// `--random-kills` appends seeded kills (no restart) drawn over the
    /// run's horizon from the trace seed.
    pub fn fault_plan(&self, n_engines: usize, horizon_s: f64) -> FaultPlan {
        let mut plan = FaultPlan::none();
        for &(inst, at) in &self.kills {
            plan = plan.kill(inst, at);
            if let Some(d) = self.fault_restart_s {
                plan = plan.with_restart(d);
            }
        }
        for &(inst, at) in &self.drains {
            plan = plan.drain(inst, at);
            if let Some(d) = self.fault_restart_s {
                plan = plan.with_restart(d);
            }
        }
        if self.random_kills > 0 {
            let random = FaultPlan::seeded_random(self.seed, n_engines, horizon_s, self.random_kills);
            plan.events.extend(random.events);
        }
        plan
    }

    /// Fleet mode of a custom run (colocated 4 when nothing was specified).
    pub fn mode(&self) -> FleetMode {
        match (self.prefill, self.decode, self.instances) {
            (Some(p), Some(d), None) => FleetMode::Disaggregated { prefill: p, decode: d },
            (None, None, Some(n)) => FleetMode::Colocated { instances: n },
            _ => FleetMode::Colocated { instances: 4 },
        }
    }

    /// Parse the argument tail after `cluster`.
    pub fn parse(args: &[String]) -> Result<ClusterArgs> {
        let mut out = ClusterArgs::default();
        let mut i = 0usize;
        while i < args.len() {
            match args[i].as_str() {
                "--fast" => out.fast = true,
                "--models" => out.models = true,
                "--dynamic" => out.dynamic = true,
                "--routing" => {
                    let v = value(args, i, "--routing")?;
                    out.routing = match RoutingPolicy::parse(v) {
                        Some(p) => p,
                        None => bail!("unknown routing policy '{v}' (expected round-robin|least-outstanding|least-queue-depth|prefix-affinity|topo-aware)"),
                    };
                    out.custom = true;
                    i += 1;
                }
                "--topology" => {
                    let v = value(args, i, "--topology")?;
                    out.topology = match TopologySpec::parse(v) {
                        Some(t) => t,
                        None => bail!("unknown fabric topology '{v}' (expected degenerate|torus|fat-tree)"),
                    };
                    out.custom = true;
                    i += 1;
                }
                "--link" => {
                    let v = value(args, i, "--link")?;
                    out.link = match LinkClass::parse(v) {
                        Some(l) => l,
                        None => bail!("unknown link class '{v}' (expected inter-node|d2d)"),
                    };
                    out.custom = true;
                    i += 1;
                }
                "--prefill" => {
                    out.prefill = Some(parse_count(args, i, "--prefill")?);
                    out.custom = true;
                    i += 1;
                }
                "--decode" => {
                    out.decode = Some(parse_count(args, i, "--decode")?);
                    out.custom = true;
                    i += 1;
                }
                "--instances" => {
                    out.instances = Some(parse_count(args, i, "--instances")?);
                    out.custom = true;
                    i += 1;
                }
                "--rate" => {
                    let v = parse_num(args, i, "--rate")?;
                    if !(v > 0.0 && v <= 1e6) {
                        bail!("--rate must be in (0, 1e6] requests/s, got {v}");
                    }
                    out.rate_rps = Some(v);
                    out.custom = true;
                    i += 1;
                }
                "--horizon" => {
                    let v = parse_num(args, i, "--horizon")?;
                    if !(v > 0.0 && v <= 3600.0) {
                        bail!("--horizon must be in (0, 3600] seconds, got {v}");
                    }
                    out.horizon_s = Some(v);
                    out.custom = true;
                    i += 1;
                }
                "--seed" => {
                    let v = value(args, i, "--seed")?;
                    out.seed = match v.parse::<u64>() {
                        Ok(s) => s,
                        Err(_) => bail!("--seed expects an unsigned integer, got '{v}'"),
                    };
                    out.custom = true;
                    i += 1;
                }
                "--cache-dir" => {
                    out.cache_dir = Some(value(args, i, "--cache-dir")?.to_string());
                    i += 1;
                }
                "--trace-out" => {
                    out.trace_out = Some(value(args, i, "--trace-out")?.to_string());
                    i += 1;
                }
                "--series-out" => {
                    out.series_out = Some(value(args, i, "--series-out")?.to_string());
                    i += 1;
                }
                "--metrics-out" => {
                    out.metrics_out = Some(value(args, i, "--metrics-out")?.to_string());
                    i += 1;
                }
                "--attrib-out" => {
                    out.attrib_out = Some(value(args, i, "--attrib-out")?.to_string());
                    i += 1;
                }
                "--shards" => {
                    out.shards = parse_count(args, i, "--shards")?;
                    out.custom = true;
                    i += 1;
                }
                "--threads" => {
                    out.threads = Some(parse_threads(args, i)?);
                    i += 1;
                }
                "--kill" => {
                    out.kills.push(parse_fault_spec(args, i, "--kill")?);
                    out.custom = true;
                    i += 1;
                }
                "--drain" => {
                    out.drains.push(parse_fault_spec(args, i, "--drain")?);
                    out.custom = true;
                    i += 1;
                }
                "--fault-restart" => {
                    let v = parse_num(args, i, "--fault-restart")?;
                    if !(0.0..=3600.0).contains(&v) {
                        bail!("--fault-restart must be in [0, 3600] seconds, got {v}");
                    }
                    out.fault_restart_s = Some(v);
                    out.custom = true;
                    i += 1;
                }
                "--random-kills" => {
                    let v = value(args, i, "--random-kills")?;
                    out.random_kills = match v.parse::<usize>() {
                        Ok(n) if (1..=64).contains(&n) => n,
                        Ok(n) => bail!("--random-kills must be in 1..=64, got {n}"),
                        Err(_) => bail!("--random-kills expects a positive integer, got '{v}'"),
                    };
                    out.custom = true;
                    i += 1;
                }
                other => bail!("unknown cluster option '{other}'; see `flatattention help`"),
            }
            i += 1;
        }
        // Pool specs must be consistent: --prefill and --decode together,
        // and never mixed with --instances.
        match (out.prefill, out.decode, out.instances) {
            (Some(_), None, _) | (None, Some(_), _) => {
                bail!("--prefill and --decode must be given together")
            }
            (Some(_), Some(_), Some(_)) => {
                bail!("--instances conflicts with --prefill/--decode")
            }
            _ => {}
        }
        // `--models` / `--dynamic` run canned experiments at their pinned
        // parameters — silently ignoring custom fleet/rate/seed flags would
        // hand back a report that reflects none of them. (`--cache-dir` is
        // fine: caching cannot change a result.)
        if out.models && out.dynamic {
            bail!("--models and --dynamic are distinct canned experiments; pick one");
        }
        if (out.models || out.dynamic) && out.is_custom() {
            let which = if out.models { "--models" } else { "--dynamic" };
            bail!("{which} runs a fixed experiment; it cannot be combined with --routing/--link/--topology/--prefill/--decode/--instances/--rate/--horizon/--seed/--shards/--kill/--drain/--fault-restart/--random-kills");
        }
        if out.fault_restart_s.is_some() && out.kills.is_empty() && out.drains.is_empty() {
            bail!("--fault-restart needs at least one --kill or --drain to apply to");
        }
        Ok(out)
    }
}

/// Parse a small positive instance count (bounded so a typo cannot spawn a
/// thousand concurrent wafer simulations).
fn parse_count(args: &[String], i: usize, flag: &str) -> Result<u32> {
    let v = value(args, i, flag)?;
    match v.parse::<u32>() {
        Ok(n) if (1..=64).contains(&n) => Ok(n),
        Ok(n) => bail!("{flag} must be in 1..=64 instances, got {n}"),
        Err(_) => bail!("{flag} expects a positive integer, got '{v}'"),
    }
}

/// Parse the `--threads` worker budget (bounded so a typo cannot spawn a
/// thousand OS threads).
fn parse_threads(args: &[String], i: usize) -> Result<usize> {
    let v = value(args, i, "--threads")?;
    match v.parse::<usize>() {
        Ok(n) if (1..=1024).contains(&n) => Ok(n),
        Ok(n) => bail!("--threads must be in 1..=1024, got {n}"),
        Err(_) => bail!("--threads expects a positive integer, got '{v}'"),
    }
}

/// Parse a `<instance>@<seconds>` fault spec (`--kill 0@1.5`). The
/// instance is a *global engine id* — entry pool first, then decode —
/// bounded like the pool sizes; range against the actual fleet is checked
/// when the plan is applied.
fn parse_fault_spec(args: &[String], i: usize, flag: &str) -> Result<(usize, f64)> {
    let v = value(args, i, flag)?;
    let (inst, at) = match v.split_once('@') {
        Some(parts) => parts,
        None => bail!("{flag} expects <instance>@<seconds> (e.g. {flag} 0@1.5), got '{v}'"),
    };
    let inst = match inst.parse::<usize>() {
        Ok(n) if n < 128 => n,
        Ok(n) => bail!("{flag}: instance {n} out of range (global engine id, < 128)"),
        Err(_) => bail!("{flag} expects <instance>@<seconds>, got '{v}'"),
    };
    let at = match at.parse::<f64>() {
        Ok(t) if t.is_finite() && t >= 0.0 => t,
        _ => bail!("{flag}: fault time must be a non-negative finite number of seconds, got '{v}'"),
    };
    Ok((inst, at))
}

fn value<'a>(args: &'a [String], i: usize, flag: &str) -> Result<&'a str> {
    match args.get(i + 1) {
        Some(v) => Ok(v.as_str()),
        None => bail!("{flag} expects a value"),
    }
}

fn parse_num(args: &[String], i: usize, flag: &str) -> Result<f64> {
    let v = value(args, i, flag)?;
    match v.parse::<f64>() {
        Ok(x) if x.is_finite() => Ok(x),
        _ => bail!("{flag} expects a finite number, got '{v}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_and_flags() {
        let a = ServeArgs::parse(&argv(&[])).unwrap();
        assert_eq!(a, ServeArgs::default());
        assert!(!a.is_custom());
        let a = ServeArgs::parse(&argv(&["--fast", "--policies", "--prefix"])).unwrap();
        assert!(a.fast && a.policies && a.prefix);
        assert!(!a.is_custom());
    }

    #[test]
    fn parses_policy_rate_horizon_seed() {
        let a = ServeArgs::parse(&argv(&[
            "--policy", "sjf", "--rate", "800", "--horizon", "12.5", "--seed", "7",
        ]))
        .unwrap();
        assert_eq!(a.queue_policy, QueuePolicy::Sjf);
        assert_eq!(a.rate_rps, Some(800.0));
        assert_eq!(a.horizon_s, Some(12.5));
        assert_eq!(a.seed, 7);
        assert!(a.is_custom());
    }

    #[test]
    fn bad_policy_name_is_an_error_not_a_panic() {
        let e = ServeArgs::parse(&argv(&["--policy", "lifo"])).unwrap_err();
        assert!(e.to_string().contains("unknown queue policy"), "{e}");
        assert!(ServeArgs::parse(&argv(&["--policy"])).is_err(), "missing value");
    }

    #[test]
    fn out_of_range_and_malformed_rates_error() {
        for bad in [["--rate", "0"], ["--rate", "-5"], ["--rate", "1e9"], ["--rate", "abc"]] {
            assert!(ServeArgs::parse(&argv(&bad)).is_err(), "{bad:?} must fail");
        }
        for bad in [["--horizon", "0"], ["--horizon", "1e7"], ["--horizon", "NaN"]] {
            assert!(ServeArgs::parse(&argv(&bad)).is_err(), "{bad:?} must fail");
        }
        assert!(ServeArgs::parse(&argv(&["--seed", "-1"])).is_err());
        assert!(ServeArgs::parse(&argv(&["--bogus"])).is_err());
    }

    #[test]
    fn cluster_defaults_and_flags() {
        let a = ClusterArgs::parse(&argv(&[])).unwrap();
        assert_eq!(a, ClusterArgs::default());
        assert!(!a.is_custom());
        assert_eq!(a.mode(), FleetMode::Colocated { instances: 4 });
        let a = ClusterArgs::parse(&argv(&["--fast", "--models"])).unwrap();
        assert!(a.fast && a.models);
        assert!(!a.is_custom());
    }

    #[test]
    fn cluster_parses_pools_routing_rate() {
        let a = ClusterArgs::parse(&argv(&[
            "--prefill", "2", "--decode", "6", "--routing", "rr", "--rate", "1500", "--horizon", "8", "--seed", "9",
        ]))
        .unwrap();
        assert_eq!(a.mode(), FleetMode::Disaggregated { prefill: 2, decode: 6 });
        assert_eq!(a.routing, RoutingPolicy::RoundRobin);
        assert_eq!(a.rate_rps, Some(1500.0));
        assert_eq!(a.horizon_s, Some(8.0));
        assert_eq!(a.seed, 9);
        assert!(a.is_custom());
        let b = ClusterArgs::parse(&argv(&["--instances", "2"])).unwrap();
        assert_eq!(b.mode(), FleetMode::Colocated { instances: 2 });
        assert!(b.is_custom());
    }

    #[test]
    fn cluster_rejects_inconsistent_pool_specs() {
        assert!(ClusterArgs::parse(&argv(&["--prefill", "2"])).is_err(), "prefill without decode");
        assert!(ClusterArgs::parse(&argv(&["--decode", "2"])).is_err(), "decode without prefill");
        assert!(
            ClusterArgs::parse(&argv(&["--prefill", "1", "--decode", "1", "--instances", "2"])).is_err(),
            "pools conflict with --instances"
        );
        for bad in [["--prefill", "0"], ["--instances", "65"], ["--decode", "x"]] {
            assert!(ClusterArgs::parse(&argv(&bad)).is_err(), "{bad:?} must fail");
        }
        assert!(ClusterArgs::parse(&argv(&["--routing", "hash"])).is_err());
        assert!(ClusterArgs::parse(&argv(&["--bogus"])).is_err());
        // --models runs a canned experiment: combining it with custom flags
        // must be an error, never a silent ignore.
        let e = ClusterArgs::parse(&argv(&["--models", "--seed", "9"])).unwrap_err();
        assert!(e.to_string().contains("--models"), "{e}");
        assert!(ClusterArgs::parse(&argv(&["--models", "--rate", "500"])).is_err());
        assert!(ClusterArgs::parse(&argv(&["--models", "--fast"])).is_ok(), "--fast stays compatible");
    }

    #[test]
    fn cache_dir_is_orthogonal_to_custom_dispatch() {
        // --cache-dir is pure memoization plumbing: it must neither flip a
        // run to custom nor conflict with the canned experiments.
        let a = ServeArgs::parse(&argv(&["--cache-dir", "/tmp/c"])).unwrap();
        assert_eq!(a.cache_dir.as_deref(), Some("/tmp/c"));
        assert!(!a.is_custom());
        let b = ClusterArgs::parse(&argv(&["--cache-dir", "/tmp/c", "--models"])).unwrap();
        assert_eq!(b.cache_dir.as_deref(), Some("/tmp/c"));
        assert!(b.models && !b.is_custom());
        assert!(ServeArgs::parse(&argv(&["--cache-dir"])).is_err(), "missing value");
        assert!(ClusterArgs::parse(&argv(&["--cache-dir"])).is_err(), "missing value");
    }

    #[test]
    fn obs_flags_are_orthogonal_to_custom_dispatch() {
        // Like --cache-dir, the observability exports are pure plumbing:
        // they must neither flip a run to custom nor conflict with the
        // canned experiments.
        let a = ServeArgs::parse(&argv(&["--trace-out", "/tmp/t.json", "--metrics-out", "/tmp/m.prom"])).unwrap();
        assert_eq!(a.trace_out.as_deref(), Some("/tmp/t.json"));
        assert_eq!(a.metrics_out.as_deref(), Some("/tmp/m.prom"));
        assert!(a.obs_requested());
        assert!(!a.is_custom());
        assert!(!ServeArgs::parse(&argv(&[])).unwrap().obs_requested());
        let b = ClusterArgs::parse(&argv(&["--models", "--series-out", "/tmp/s.csv"])).unwrap();
        assert_eq!(b.series_out.as_deref(), Some("/tmp/s.csv"));
        assert!(b.models && b.obs_requested() && !b.is_custom());
        let c = ClusterArgs::parse(&argv(&["--trace-out", "/tmp/t.json", "--rate", "500"])).unwrap();
        assert!(c.is_custom() && c.obs_requested());
        // --attrib-out rides the same plumbing as the other exports.
        let d = ServeArgs::parse(&argv(&["--attrib-out", "/tmp/a.json"])).unwrap();
        assert_eq!(d.attrib_out.as_deref(), Some("/tmp/a.json"));
        assert!(d.obs_requested() && !d.is_custom());
        let e = ClusterArgs::parse(&argv(&["--attrib-out", "/tmp/a.json", "--shards", "4"])).unwrap();
        assert!(e.obs_requested() && e.is_custom());
        for bad in ["--trace-out", "--series-out", "--metrics-out", "--attrib-out"] {
            assert!(ServeArgs::parse(&argv(&[bad])).is_err(), "{bad} missing value");
            assert!(ClusterArgs::parse(&argv(&[bad])).is_err(), "{bad} missing value");
        }
    }

    #[test]
    fn cluster_parses_link_and_live_routing() {
        let a = ClusterArgs::parse(&argv(&["--routing", "least-queue-depth", "--link", "d2d"])).unwrap();
        assert_eq!(a.routing, RoutingPolicy::LeastQueueDepth);
        assert_eq!(a.link, LinkClass::D2dClass);
        assert!(a.is_custom());
        let b = ClusterArgs::parse(&argv(&["--routing", "lqd"])).unwrap();
        assert_eq!(b.routing, RoutingPolicy::LeastQueueDepth);
        assert_eq!(b.link, LinkClass::InterNode, "inter-node is the default link");
        for l in [LinkClass::InterNode, LinkClass::D2dClass] {
            assert_eq!(LinkClass::parse(l.label()), Some(l));
        }
        assert!(ClusterArgs::parse(&argv(&["--link", "carrier-pigeon"])).is_err());
        // Canned experiments reject custom link/routing flags …
        assert!(ClusterArgs::parse(&argv(&["--models", "--link", "d2d"])).is_err());
        assert!(ClusterArgs::parse(&argv(&["--models", "--topology", "torus"])).is_err());
        assert!(ClusterArgs::parse(&argv(&["--dynamic", "--routing", "lqd"])).is_err());
        assert!(ClusterArgs::parse(&argv(&["--models", "--dynamic"])).is_err());
        // … but --dynamic alone (with --fast) is a valid canned run.
        let d = ClusterArgs::parse(&argv(&["--dynamic", "--fast"])).unwrap();
        assert!(d.dynamic && d.fast && !d.is_custom());
    }

    #[test]
    fn cluster_parses_topology_and_topo_aware_routing() {
        let a = ClusterArgs::parse(&argv(&["--topology", "torus", "--routing", "topo-aware"])).unwrap();
        assert_eq!(a.topology, TopologySpec::Torus);
        assert_eq!(a.routing, RoutingPolicy::TopoAware);
        assert!(a.is_custom(), "--topology must request a custom run");
        for (alias, want) in [
            ("degenerate", TopologySpec::Degenerate),
            ("pooled", TopologySpec::Degenerate),
            ("mesh", TopologySpec::Torus),
            ("fat-tree", TopologySpec::FatTree),
            ("fattree", TopologySpec::FatTree),
        ] {
            let p = ClusterArgs::parse(&argv(&["--topology", alias])).unwrap();
            assert_eq!(p.topology, want, "{alias}");
        }
        assert_eq!(ClusterArgs::parse(&argv(&[])).unwrap().topology, TopologySpec::Degenerate);
        assert!(ClusterArgs::parse(&argv(&["--topology", "hypercube"])).is_err());
        assert!(ClusterArgs::parse(&argv(&["--topology"])).is_err(), "missing value");
    }

    #[test]
    fn cluster_parses_shards_and_threads() {
        // --shards selects the custom path (only the custom run takes a
        // shard count); --threads is pure plumbing like --cache-dir.
        let a = ClusterArgs::parse(&argv(&["--shards", "4"])).unwrap();
        assert_eq!(a.shards, 4);
        assert!(a.is_custom(), "--shards must request a custom run");
        let b = ClusterArgs::parse(&argv(&["--threads", "3"])).unwrap();
        assert_eq!(b.threads, Some(3));
        assert!(!b.is_custom(), "--threads must stay orthogonal to dispatch");
        let c = ClusterArgs::parse(&argv(&["--models", "--threads", "2"])).unwrap();
        assert!(c.models && c.threads == Some(2), "canned runs accept --threads");
        assert!(ClusterArgs::parse(&argv(&["--models", "--shards", "2"])).is_err());
        for bad in [["--shards", "0"], ["--shards", "65"], ["--threads", "0"], ["--threads", "9999"]] {
            assert!(ClusterArgs::parse(&argv(&bad)).is_err(), "{bad:?} must fail");
        }
        assert!(ClusterArgs::parse(&argv(&["--shards"])).is_err(), "missing value");
        let s = ServeArgs::parse(&argv(&["--threads", "8", "--fast"])).unwrap();
        assert_eq!(s.threads, Some(8));
        assert!(!s.is_custom(), "--threads must stay orthogonal for serve too");
        assert!(ServeArgs::parse(&argv(&["--threads", "x"])).is_err());
    }

    #[test]
    fn cluster_explicit_default_values_still_mean_custom() {
        // Passing a flag whose value happens to equal the default is still
        // a request for a custom run — dispatch must not depend on whether
        // the value matches the default.
        let a = ClusterArgs::parse(&argv(&["--seed", "2026"])).unwrap();
        assert!(a.is_custom(), "--seed 2026 must request a custom run");
        let b = ClusterArgs::parse(&argv(&["--routing", "prefix-affinity"])).unwrap();
        assert!(b.is_custom(), "--routing with the default policy is still custom");
        // And the --models guard catches them too.
        assert!(ClusterArgs::parse(&argv(&["--models", "--seed", "2026"])).is_err());
        assert!(ClusterArgs::parse(&argv(&["--models", "--routing", "prefix-affinity"])).is_err());
    }

    #[test]
    fn cluster_fault_flags() {
        let a = ClusterArgs::parse(&argv(&[
            "--kill",
            "0@1.5",
            "--kill",
            "2@2",
            "--drain",
            "1@0.75",
            "--fault-restart",
            "0.5",
        ]))
        .unwrap();
        assert!(a.is_custom() && a.has_faults());
        assert_eq!(a.kills, vec![(0, 1.5), (2, 2.0)]);
        assert_eq!(a.drains, vec![(1, 0.75)]);
        assert_eq!(a.fault_restart_s, Some(0.5));
        let plan = a.fault_plan(4, 10.0);
        assert_eq!(plan.events.len(), 3);
        assert!(plan.events.iter().all(|e| e.restart_after_s == Some(0.5)));
        // Seeded random-failure mode reproduces the library schedule.
        let r = ClusterArgs::parse(&argv(&["--random-kills", "3"])).unwrap();
        assert!(r.is_custom() && r.has_faults());
        assert_eq!(r.fault_plan(4, 10.0), FaultPlan::seeded_random(r.seed, 4, 10.0, 3));
        for bad in [
            ["--kill", "0"],
            ["--kill", "x@1"],
            ["--kill", "0@-1"],
            ["--kill", "999@1"],
            ["--drain", "0@nan"],
            ["--random-kills", "0"],
            ["--fault-restart", "1.0"],
        ] {
            assert!(ClusterArgs::parse(&argv(&bad)).is_err(), "{bad:?} must fail");
        }
        // Fault flags select the custom path, so canned experiments
        // reject them like every other custom flag.
        assert!(ClusterArgs::parse(&argv(&["--models", "--kill", "0@1"])).is_err());
        assert!(ClusterArgs::parse(&argv(&["--dynamic", "--drain", "0@1"])).is_err());
    }
}
