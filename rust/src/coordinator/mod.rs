//! Experiment coordinator: registry, sweeps, reports, CLI parsing.
pub mod cli;
pub mod experiments;
pub mod report;
