//! Experiment coordinator: registry, sweeps, reports, CLI parsing, and the
//! on-disk simulation-cache persistence behind `--cache-dir`.
pub mod cache;
pub mod cli;
pub mod experiments;
pub mod report;
