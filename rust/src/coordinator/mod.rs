//! Experiment coordinator: registry, sweeps, reports.
pub mod experiments;
pub mod report;
