//! Plain-text report emitters: aligned tables and ASCII stacked bars, so
//! every experiment prints the same rows/series as the paper's figures.

/// A printable experiment report.
#[derive(Debug, Clone, Default)]
pub struct Report {
    pub title: String,
    pub preamble: Vec<String>,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub notes: Vec<String>,
}

impl Report {
    pub fn new(title: impl Into<String>) -> Self {
        Report { title: title.into(), ..Default::default() }
    }

    pub fn preamble(&mut self, line: impl Into<String>) -> &mut Self {
        self.preamble.push(line.into());
        self
    }

    pub fn header(&mut self, cols: &[&str]) -> &mut Self {
        self.header = cols.iter().map(|s| s.to_string()).collect();
        self
    }

    pub fn row(&mut self, cols: Vec<String>) -> &mut Self {
        self.rows.push(cols);
        self
    }

    pub fn note(&mut self, line: impl Into<String>) -> &mut Self {
        self.notes.push(line.into());
        self
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        for p in &self.preamble {
            out.push_str(p);
            out.push('\n');
        }
        if !self.header.is_empty() || !self.rows.is_empty() {
            let ncols = self.header.len().max(self.rows.iter().map(|r| r.len()).max().unwrap_or(0));
            let mut widths = vec![0usize; ncols];
            for (i, h) in self.header.iter().enumerate() {
                widths[i] = widths[i].max(h.len());
            }
            for r in &self.rows {
                for (i, c) in r.iter().enumerate() {
                    widths[i] = widths[i].max(c.len());
                }
            }
            let fmt_row = |cells: &[String], widths: &[usize]| -> String {
                let mut s = String::new();
                for (i, c) in cells.iter().enumerate() {
                    if i > 0 {
                        s.push_str("  ");
                    }
                    s.push_str(&format!("{:<w$}", c, w = widths[i]));
                }
                s
            };
            if !self.header.is_empty() {
                out.push_str(&fmt_row(&self.header, &widths));
                out.push('\n');
                out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncols - 1)));
                out.push('\n');
            }
            for r in &self.rows {
                out.push_str(&fmt_row(r, &widths));
                out.push('\n');
            }
        }
        for n in &self.notes {
            out.push_str(&format!("note: {n}\n"));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Render a stacked breakdown as an ASCII bar of width `width`.
/// `parts` are (label-char, value) pairs; the bar is annotated with a
/// percentage legend.
pub fn stacked_bar(parts: &[(char, f64)], width: usize) -> String {
    let total: f64 = parts.iter().map(|(_, v)| v.max(0.0)).sum();
    if total <= 0.0 {
        return " ".repeat(width);
    }
    let mut bar = String::new();
    let mut used = 0usize;
    for (i, (ch, v)) in parts.iter().enumerate() {
        let w = if i + 1 == parts.len() {
            width - used
        } else {
            ((v / total) * width as f64).round() as usize
        };
        let w = w.min(width - used);
        bar.extend(std::iter::repeat(*ch).take(w));
        used += w;
    }
    bar
}

/// Format seconds with an adaptive unit.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2} s")
    } else if s >= 1e-3 {
        format!("{:.2} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.2} µs", s * 1e6)
    } else {
        format!("{:.0} ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut r = Report::new("t");
        r.header(&["a", "bbbb"]).row(vec!["xxx".into(), "y".into()]);
        let s = r.render();
        assert!(s.contains("== t =="));
        assert!(s.contains("a    bbbb"));
        assert!(s.contains("xxx  y"));
    }

    #[test]
    fn stacked_bar_fills_width() {
        let b = stacked_bar(&[('#', 3.0), ('.', 1.0)], 8);
        assert_eq!(b.len(), 8);
        assert_eq!(b.matches('#').count(), 6);
        assert_eq!(b.matches('.').count(), 2);
    }

    #[test]
    fn stacked_bar_zero_total() {
        assert_eq!(stacked_bar(&[('#', 0.0)], 4), "    ");
    }

    #[test]
    fn time_formatting() {
        assert_eq!(fmt_time(2.0), "2.00 s");
        assert_eq!(fmt_time(0.0025), "2.50 ms");
        assert_eq!(fmt_time(3.2e-6), "3.20 µs");
    }
}
