//! Attention workload shapes and analytic complexity (paper §II-B, §III-A).
//!
//! Every modern attention variant is normalized to a unified MHA-style
//! formulation (paper §III-D): the variants differ in the number of distinct
//! KV heads, the effective query rows attending to each KV group, and the
//! query/value head dimensions (MLA's weight-absorbed MQA mode has
//! `head_dim = d_c + d_rope`, `v_head_dim = d_c`).



use crate::arch::config::{ChipConfig, Dtype};

/// Attention mechanism family.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AttentionVariant {
    /// Classic multi-head attention: one KV head per query head.
    Mha,
    /// Multi-query attention: all query heads share one KV head.
    Mqa,
    /// Grouped-query attention with `group` query heads per KV head.
    Gqa { group: u32 },
    /// Multi-head latent attention in weight-absorbed MQA mode
    /// (DeepSeek-v2/v3): all heads share the compressed KV `c^KV`.
    MlaAbsorbed,
}

impl AttentionVariant {
    pub fn label(self) -> String {
        match self {
            AttentionVariant::Mha => "MHA".into(),
            AttentionVariant::Mqa => "MQA".into(),
            AttentionVariant::Gqa { group } => format!("GQA{group}"),
            AttentionVariant::MlaAbsorbed => "MLA".into(),
        }
    }
}

/// Inference phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Phase {
    /// Prompt processing: `seq_q == seq_kv == S`.
    Prefill,
    /// Auto-regressive decoding: `seq_q == 1`, `seq_kv` = KV-cache length.
    Decode,
    /// Speculative decoding with draft length `sp` (MTP: sp = 2).
    SpecDecode { sp: u32 },
}

/// One attention-layer invocation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AttentionShape {
    pub variant: AttentionVariant,
    pub phase: Phase,
    pub batch: u32,
    /// Query heads H.
    pub heads: u32,
    /// Distinct KV heads (H for MHA, H/G for GQA, 1 for MQA/MLA-absorbed).
    pub kv_heads: u32,
    /// Q/K head dimension D (MLA-absorbed: d_c + d_rope).
    pub head_dim: u32,
    /// V head dimension (usually D; MLA-absorbed: d_c).
    pub v_head_dim: u32,
    /// Query rows per head.
    pub seq_q: u32,
    /// KV rows (context length).
    pub seq_kv: u32,
    pub dtype: Dtype,
    pub causal: bool,
}

impl AttentionShape {
    pub fn mha_prefill(batch: u32, heads: u32, head_dim: u32, seq: u32, dtype: Dtype) -> Self {
        AttentionShape {
            variant: AttentionVariant::Mha,
            phase: Phase::Prefill,
            batch,
            heads,
            kv_heads: heads,
            head_dim,
            v_head_dim: head_dim,
            seq_q: seq,
            seq_kv: seq,
            dtype,
            causal: true,
        }
    }

    /// A chunked-prefill invocation: `chunk` fresh query rows appended at
    /// context offset `context − chunk`, attending causally over the whole
    /// `context` (prior KV plus the chunk itself). This is the shape the
    /// serving layer bills one prefill chunk at — for a fresh prompt
    /// (`context == chunk`) it degenerates to [`AttentionShape::mha_prefill`].
    #[allow(clippy::too_many_arguments)]
    pub fn mha_chunked_prefill(
        batch: u32,
        heads: u32,
        head_dim: u32,
        v_head_dim: u32,
        chunk: u32,
        context: u32,
        dtype: Dtype,
    ) -> Self {
        let chunk = chunk.max(1);
        AttentionShape {
            variant: AttentionVariant::Mha,
            phase: Phase::Prefill,
            batch,
            heads,
            kv_heads: heads,
            head_dim,
            v_head_dim,
            seq_q: chunk,
            seq_kv: context.max(chunk),
            dtype,
            causal: true,
        }
    }

    pub fn mha_decode(batch: u32, heads: u32, head_dim: u32, kv_len: u32, sp: u32, dtype: Dtype) -> Self {
        AttentionShape {
            variant: AttentionVariant::Mha,
            phase: if sp <= 1 { Phase::Decode } else { Phase::SpecDecode { sp } },
            batch,
            heads,
            kv_heads: heads,
            head_dim,
            v_head_dim: head_dim,
            seq_q: sp.max(1),
            seq_kv: kv_len,
            dtype,
            causal: sp > 1,
        }
    }

    pub fn gqa_decode(batch: u32, heads: u32, group: u32, head_dim: u32, kv_len: u32, sp: u32, dtype: Dtype) -> Self {
        assert!(heads % group == 0, "heads must divide into groups");
        AttentionShape {
            variant: AttentionVariant::Gqa { group },
            phase: if sp <= 1 { Phase::Decode } else { Phase::SpecDecode { sp } },
            batch,
            heads,
            kv_heads: heads / group,
            head_dim,
            v_head_dim: head_dim,
            seq_q: sp.max(1),
            seq_kv: kv_len,
            dtype,
            causal: sp > 1,
        }
    }

    /// DeepSeek-style MLA in weight-absorbed MQA mode: `heads` query heads
    /// over one latent KV of width `d_c` (+ shared rope dim on the score).
    pub fn mla_absorbed_decode(batch: u32, heads: u32, d_c: u32, d_rope: u32, kv_len: u32, sp: u32, dtype: Dtype) -> Self {
        AttentionShape {
            variant: AttentionVariant::MlaAbsorbed,
            phase: if sp <= 1 { Phase::Decode } else { Phase::SpecDecode { sp } },
            batch,
            heads,
            kv_heads: 1,
            head_dim: d_c + d_rope,
            v_head_dim: d_c,
            seq_q: sp.max(1),
            seq_kv: kv_len,
            dtype,
            causal: sp > 1,
        }
    }

    /// Query heads sharing one KV head.
    pub fn heads_per_kv(&self) -> u32 {
        self.heads / self.kv_heads
    }

    /// Effective rows of the score matrix per KV head: the paper's
    /// generalization (§III-D) concatenates the grouped queries, turning
    /// GEMV back into GEMM — `G · seq_q` rows attend to one KV.
    pub fn effective_q_rows(&self) -> u64 {
        self.heads_per_kv() as u64 * self.seq_q as u64
    }

    /// Number of independent attention computations (batch × KV heads).
    pub fn independent_units(&self) -> u64 {
        self.batch as u64 * self.kv_heads as u64
    }

    /// Exact FLOPs: score GEMM (2·rows·kv·D) + output GEMM (2·rows·kv·Dv)
    /// per unit (softmax vector work excluded, consistent with the paper's
    /// matrix-engine utilization metric). Causal masks in prefill halve the
    /// score/output work; a chunked prefill (`seq_q < seq_kv`, see
    /// [`AttentionShape::mha_chunked_prefill`]) instead attends over the
    /// average causal context `seq_kv − (seq_q − 1)/2`.
    pub fn flops(&self) -> u64 {
        let rows = self.effective_q_rows();
        let kv = self.seq_kv as u64;
        let dims = self.head_dim as u64 + self.v_head_dim as u64;
        let per_unit = 2 * rows * kv * dims;
        let full = self.independent_units() * per_unit;
        if self.causal && self.phase == Phase::Prefill {
            if self.seq_q == self.seq_kv {
                full / 2
            } else {
                let avg_kv = kv - (self.seq_q as u64 - 1) / 2;
                self.independent_units() * 2 * rows * avg_kv * dims
            }
        } else {
            full
        }
    }

    /// Bytes of Q (+ output O) per unit and of the KV cache per unit.
    pub fn q_bytes_per_unit(&self) -> u64 {
        self.effective_q_rows() * self.head_dim as u64 * self.dtype.bytes()
    }
    pub fn o_bytes_per_unit(&self) -> u64 {
        self.effective_q_rows() * self.v_head_dim as u64 * self.dtype.bytes()
    }
    /// Bytes per KV row as stored (MLA caches the shared latent once:
    /// V is a subview of the K latent, so only `head_dim` columns exist).
    pub fn kv_row_bytes(&self) -> u64 {
        match self.variant {
            AttentionVariant::MlaAbsorbed => self.head_dim as u64 * self.dtype.bytes(),
            _ => (self.head_dim + self.v_head_dim) as u64 * self.dtype.bytes(),
        }
    }

    pub fn kv_bytes_per_unit(&self) -> u64 {
        // K is head_dim wide, V is v_head_dim wide; MLA stores the shared
        // compressed c^KV once (head_dim already includes the rope part).
        match self.variant {
            AttentionVariant::MlaAbsorbed => self.seq_kv as u64 * self.head_dim as u64 * self.dtype.bytes(),
            _ => self.seq_kv as u64 * (self.head_dim + self.v_head_dim) as u64 * self.dtype.bytes(),
        }
    }

    /// Compulsory (ideal) HBM traffic: read Q and KV once, write O once.
    pub fn ideal_io_bytes(&self) -> u64 {
        self.independent_units() * (self.q_bytes_per_unit() + self.kv_bytes_per_unit() + self.o_bytes_per_unit())
    }

    /// FlashAttention HBM I/O (paper §III-A): each of the `N_outer` row
    /// blocks re-reads the whole KV. `m` is the per-tile block size.
    pub fn flash_io_bytes(&self, m: u32) -> u64 {
        self.io_bytes_with_flattening(m, 1)
    }

    /// FlatAttention HBM I/O with an `n`-wide tile group (paper §III-A):
    /// the group collectively holds an (N·Br, N·Bc) block, dividing the KV
    /// re-read factor by `n`.
    pub fn io_bytes_with_flattening(&self, m: u32, n: u32) -> u64 {
        let rows = self.effective_q_rows();
        let block = (m as u64 * n as u64).min(rows.max(1));
        let n_outer = rows.div_ceil(block.max(1));
        self.independent_units()
            * (rows * self.head_dim as u64 * self.dtype.bytes()
                + rows * self.v_head_dim as u64 * self.dtype.bytes()
                + n_outer * self.kv_bytes_per_unit())
    }

    /// Arithmetic intensity against compulsory traffic (FLOP/byte).
    pub fn ideal_intensity(&self) -> f64 {
        self.flops() as f64 / self.ideal_io_bytes() as f64
    }

    /// True if the kernel is compute-bound on `cfg` at ideal traffic.
    pub fn is_compute_bound(&self, cfg: &ChipConfig) -> bool {
        self.ideal_intensity() >= cfg.ridge_flops_per_byte()
    }

    /// Roofline-limited runtime (seconds) on `cfg` at ideal traffic.
    pub fn roofline_seconds(&self, cfg: &ChipConfig) -> f64 {
        let compute = self.flops() as f64 / cfg.peak_flops();
        let memory = self.ideal_io_bytes() as f64 / cfg.hbm.total_bandwidth_bytes_per_s;
        compute.max(memory)
    }

    /// Short label, e.g. `MHA-prefill hd128 sq4096`.
    pub fn label(&self) -> String {
        match self.phase {
            Phase::Prefill => format!("{}-prefill hd{} sq{}", self.variant.label(), self.head_dim, self.seq_q),
            Phase::Decode => format!("{}-decode hd{} kv{}", self.variant.label(), self.head_dim, self.seq_kv),
            Phase::SpecDecode { sp } => {
                format!("{}-decode hd{} sp{} kv{}", self.variant.label(), self.head_dim, sp, self.seq_kv)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mha_prefill_flops() {
        let s = AttentionShape::mha_prefill(1, 1, 64, 128, Dtype::Fp16);
        // causal prefill: 2·S²·(D+Dv)/2 = S²·2D
        assert_eq!(s.flops(), 128 * 128 * 2 * 64);
    }

    #[test]
    fn chunked_prefill_flops_interpolate_causal_cost() {
        // A fresh chunk (offset 0, chunk == context) degenerates to the
        // classic causal prefill exactly; a late chunk at a deep offset
        // approaches the full rectangle (almost every key is visible to
        // every chunk row).
        let fresh = AttentionShape::mha_chunked_prefill(1, 1, 64, 64, 128, 128, Dtype::Fp16);
        let classic = AttentionShape::mha_prefill(1, 1, 64, 128, Dtype::Fp16);
        assert_eq!(fresh.flops(), classic.flops());
        let late = AttentionShape::mha_chunked_prefill(1, 1, 64, 64, 128, 8192, Dtype::Fp16);
        let rect = 2 * 128 * 8192 * (64 + 64);
        let ratio = late.flops() as f64 / rect as f64;
        assert!(ratio > 0.98 && ratio <= 1.0, "late-chunk ratio {ratio}");
        // And the chunk still reads the whole KV once.
        assert_eq!(late.kv_bytes_per_unit(), 8192 * (64 + 64) * 2);
    }

    #[test]
    fn decode_is_memory_bound_prefill_long_is_compute_bound() {
        let cfg = ChipConfig::table1();
        let dec = AttentionShape::mha_decode(2, 32, 128, 4096, 1, Dtype::Fp16);
        assert!(!dec.is_compute_bound(&cfg));
        let pre = AttentionShape::mha_prefill(2, 32, 128, 4096, Dtype::Fp16);
        assert!(pre.is_compute_bound(&cfg));
    }

    #[test]
    fn flash_vs_flat_io_ratio_matches_paper() {
        // §III-A: S=4096, M=128, N=8 → 6.6× theoretical HBM reduction.
        let s = AttentionShape::mha_prefill(1, 1, 128, 4096, Dtype::Fp16);
        let flash = s.flash_io_bytes(128) as f64;
        let flat = s.io_bytes_with_flattening(128, 8) as f64;
        let ratio = flash / flat;
        assert!((ratio - 6.6).abs() < 0.2, "ratio {ratio}");
    }

    #[test]
    fn flat_full_flattening_16x_reduction() {
        // §V-A headline: 16× lower HBM traffic at D=128, S=4096, N=32.
        let s = AttentionShape::mha_prefill(2, 32, 128, 4096, Dtype::Fp16);
        let flash = s.flash_io_bytes(128) as f64;
        let flat = s.io_bytes_with_flattening(128, 32) as f64;
        let ratio = flash / flat;
        assert!((ratio - 16.5).abs() < 0.5, "ratio {ratio}");
    }

    #[test]
    fn gqa_groups_queries() {
        let s = AttentionShape::gqa_decode(1, 32, 8, 128, 4096, 1, Dtype::Fp16);
        assert_eq!(s.kv_heads, 4);
        assert_eq!(s.effective_q_rows(), 8);
        assert_eq!(s.independent_units(), 4);
    }

    #[test]
    fn mla_absorbed_dimensions() {
        let s = AttentionShape::mla_absorbed_decode(1, 128, 512, 64, 4096, 2, Dtype::Fp8);
        assert_eq!(s.head_dim, 576);
        assert_eq!(s.v_head_dim, 512);
        assert_eq!(s.kv_heads, 1);
        assert_eq!(s.effective_q_rows(), 256);
        // KV cache stores the compressed latent once, not per head.
        assert_eq!(s.kv_bytes_per_unit(), 4096 * 576);
    }

    #[test]
    fn mla_has_high_intensity() {
        // Weight absorption turns MLA decode into a GEMM-rich MQA: its
        // arithmetic intensity is far higher than MHA decode (the reason
        // FlashMLA still underuses GH200 but FlatAttention does not).
        let mla = AttentionShape::mla_absorbed_decode(64, 128, 512, 64, 4096, 2, Dtype::Fp8);
        let mha = AttentionShape::mha_decode(64, 32, 128, 4096, 1, Dtype::Fp16);
        assert!(mla.ideal_intensity() > 20.0 * mha.ideal_intensity());
    }

    #[test]
    fn spec_decode_multiplies_rows() {
        let s1 = AttentionShape::mha_decode(1, 32, 128, 4096, 1, Dtype::Fp16);
        let s2 = AttentionShape::mha_decode(1, 32, 128, 4096, 4, Dtype::Fp16);
        assert_eq!(s2.flops(), 4 * s1.flops());
    }

    #[test]
    fn io_monotone_in_flattening() {
        let s = AttentionShape::mha_prefill(2, 32, 128, 4096, Dtype::Fp16);
        let mut last = u64::MAX;
        for n in [1u32, 2, 4, 8, 16, 32] {
            let io = s.io_bytes_with_flattening(128, n);
            assert!(io <= last);
            last = io;
        }
        // And never below compulsory traffic.
        assert!(last >= s.ideal_io_bytes());
    }
}
