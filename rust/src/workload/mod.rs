//! Workload definitions: attention variants (MHA/MQA/GQA/MLA across
//! prefill / decode / speculative decode) and the DeepSeek-v3 decoder
//! kernel flow used in the end-to-end evaluation.

pub mod attention;
pub mod deepseek;

pub use attention::{AttentionShape, AttentionVariant, Phase};
pub use deepseek::{DecoderKernel, DeepSeekConfig, KernelClass};
