//! DeepSeek-v3 decoder workload (paper §III-E, Appendix B) plus the model
//! configurations used in Fig. 1a (Qwen-chat-7B, DeepSeek-v3-16B/671B).
//!
//! Performance depends only on tensor shapes, precision and routing
//! statistics, all public in the DeepSeek-v3 technical report; weights are
//! synthetic (see DESIGN.md §Substitutions).



use crate::arch::config::Dtype;
use crate::workload::attention::{AttentionShape, Phase};

/// DeepSeek-style MLA + MoE decoder configuration.
#[derive(Debug, Clone)]
pub struct DeepSeekConfig {
    pub name: String,
    pub layers: u32,
    /// Leading dense-FFN layers (DeepSeek-v3: 3).
    pub dense_layers: u32,
    pub d_model: u32,
    pub n_heads: u32,
    /// Per-head no-rope Q/K dim.
    pub qk_nope_dim: u32,
    /// Shared rope dim.
    pub qk_rope_dim: u32,
    pub v_head_dim: u32,
    /// KV low-rank (latent) dim d_c.
    pub kv_lora_rank: u32,
    /// Q low-rank dim (0 = no Q compression).
    pub q_lora_rank: u32,
    /// Routed experts.
    pub n_experts: u32,
    pub experts_per_token: u32,
    pub shared_experts: u32,
    /// Routed/shared expert intermediate dim.
    pub expert_inter: u32,
    /// Dense-layer FFN intermediate dim.
    pub dense_inter: u32,
    /// Multi-token prediction: speculative length (1 = disabled).
    pub mtp_spec_len: u32,
    /// MTP draft acceptance rate.
    pub mtp_acceptance: f64,
    /// Maximum supported context length in tokens (KV-length memo buckets
    /// and admission sanity checks cap here, not at an arbitrary constant).
    pub max_context: u32,
}

impl DeepSeekConfig {
    /// DeepSeek-v3-671B (the paper's end-to-end case study).
    pub fn v3_671b() -> Self {
        DeepSeekConfig {
            name: "DeepSeek-v3-671B".into(),
            layers: 61,
            dense_layers: 3,
            d_model: 7168,
            n_heads: 128,
            qk_nope_dim: 128,
            qk_rope_dim: 64,
            v_head_dim: 128,
            kv_lora_rank: 512,
            q_lora_rank: 1536,
            n_experts: 256,
            experts_per_token: 8,
            shared_experts: 1,
            expert_inter: 2048,
            dense_inter: 18432,
            mtp_spec_len: 2,
            mtp_acceptance: 0.7,
            max_context: 131_072,
        }
    }

    /// DeepSeek-v3-16B (Fig. 1a's DS16B; DeepSeek-v2-Lite-scale MLA+MoE).
    pub fn v3_16b() -> Self {
        DeepSeekConfig {
            name: "DeepSeek-v3-16B".into(),
            layers: 27,
            dense_layers: 1,
            d_model: 2048,
            n_heads: 16,
            qk_nope_dim: 128,
            qk_rope_dim: 64,
            v_head_dim: 128,
            kv_lora_rank: 512,
            q_lora_rank: 0,
            n_experts: 64,
            experts_per_token: 6,
            shared_experts: 2,
            expert_inter: 1408,
            dense_inter: 10944,
            mtp_spec_len: 1,
            mtp_acceptance: 1.0,
            max_context: 32_768,
        }
    }

    /// Tokens produced per decoding iteration with MTP speculative decoding
    /// (1 committed + accepted drafts).
    pub fn tokens_per_iteration(&self) -> f64 {
        1.0 + (self.mtp_spec_len.saturating_sub(1)) as f64 * self.mtp_acceptance
    }

    /// The attention core shape of one decode iteration for `batch` users.
    pub fn mla_decode_shape(&self, batch: u32, kv_len: u32, dtype: Dtype) -> AttentionShape {
        AttentionShape::mla_absorbed_decode(
            batch,
            self.n_heads,
            self.kv_lora_rank,
            self.qk_rope_dim,
            kv_len,
            self.mtp_spec_len,
            dtype,
        )
    }

    /// The attention core shape of one prefill chunk: `chunk` fresh prompt
    /// rows attending causally over `context` total tokens (prior KV +
    /// chunk). Prefill runs *un-absorbed* — per-head K/V are materialized
    /// from the cached latent, so the core is an MHA-style kernel with
    /// `qk_nope + rope` score width and the full per-head V width (the
    /// compute-bound regime of Fig. 1a/1b, unlike absorbed MQA decode).
    pub fn mla_prefill_shape(&self, chunk: u32, context: u32, dtype: Dtype) -> AttentionShape {
        AttentionShape::mha_chunked_prefill(
            1,
            self.n_heads,
            self.qk_nope_dim + self.qk_rope_dim,
            self.v_head_dim,
            chunk,
            context,
            dtype,
        )
    }

    /// Per-layer KV-cache bytes per user at `kv_len` (compressed latent +
    /// rope, the MLA cache layout).
    pub fn kv_cache_bytes_per_user_layer(&self, kv_len: u32, dtype: Dtype) -> u64 {
        kv_len as u64 * (self.kv_lora_rank + self.qk_rope_dim) as u64 * dtype.bytes()
    }

    /// Routed-expert weight bytes per layer (all experts).
    pub fn expert_weight_bytes_per_layer(&self, dtype: Dtype) -> u64 {
        self.n_experts as u64 * 3 * self.d_model as u64 * self.expert_inter as u64 * dtype.bytes()
    }

    /// Total parameter count (sanity anchor: v3_671b ≈ 671e9).
    pub fn param_count(&self) -> u64 {
        let d = self.d_model as u64;
        let h = self.n_heads as u64;
        let qk = (self.qk_nope_dim + self.qk_rope_dim) as u64;
        let dc = self.kv_lora_rank as u64;
        let attn_per_layer = if self.q_lora_rank > 0 {
            let ql = self.q_lora_rank as u64;
            d * ql + ql * h * qk
        } else {
            d * h * qk
        } + d * (dc + self.qk_rope_dim as u64)
            + dc * h * (self.qk_nope_dim + self.v_head_dim) as u64
            + h * self.v_head_dim as u64 * d;
        let moe_layers = (self.layers - self.dense_layers) as u64;
        let moe_per_layer = (self.n_experts + self.shared_experts) as u64 * 3 * d * self.expert_inter as u64
            + d * self.n_experts as u64;
        let dense_per_layer = 3 * d * self.dense_inter as u64;
        self.layers as u64 * attn_per_layer
            + moe_layers * moe_per_layer
            + self.dense_layers as u64 * dense_per_layer
    }
}

/// The compute class of one decoder kernel.
#[derive(Debug, Clone, PartialEq)]
pub enum KernelClass {
    /// `batch` independent m×k×n GEMMs (batch>1 for per-head / per-expert).
    Gemm { m: u64, k: u64, n: u64, batch: u64 },
    /// An attention core invocation.
    Attention(AttentionShape),
    /// Vector-engine work: norms, rope, activations, routing (`elems` total).
    Vector { elems: u64 },
}

impl KernelClass {
    pub fn flops(&self) -> u64 {
        match self {
            KernelClass::Gemm { m, k, n, batch } => 2 * m * k * n * batch,
            KernelClass::Attention(a) => a.flops(),
            KernelClass::Vector { elems } => *elems,
        }
    }

    /// Weight bytes that must stream from HBM (activations excluded).
    pub fn weight_bytes(&self, dtype: Dtype) -> u64 {
        match self {
            KernelClass::Gemm { k, n, batch, .. } => k * n * batch * dtype.bytes(),
            KernelClass::Attention(a) => a.independent_units() * a.kv_bytes_per_unit(),
            KernelClass::Vector { .. } => 0,
        }
    }
}

/// One kernel in the decoder flow.
#[derive(Debug, Clone)]
pub struct DecoderKernel {
    pub name: String,
    pub class: KernelClass,
}

impl DecoderKernel {
    fn gemm(name: &str, m: u64, k: u64, n: u64) -> Self {
        DecoderKernel { name: name.into(), class: KernelClass::Gemm { m, k, n, batch: 1 } }
    }
    fn gemm_b(name: &str, m: u64, k: u64, n: u64, batch: u64) -> Self {
        DecoderKernel { name: name.into(), class: KernelClass::Gemm { m, k, n, batch } }
    }
    fn vec(name: &str, elems: u64) -> Self {
        DecoderKernel { name: name.into(), class: KernelClass::Vector { elems } }
    }

    pub fn is_attention(&self) -> bool {
        matches!(self.class, KernelClass::Attention(_))
    }
}

/// Per-chip MoE placement for one decode iteration.
#[derive(Debug, Clone, Copy)]
pub struct MoePlacement {
    /// Routed experts resident on this chip (E / EP degree).
    pub experts_on_chip: u32,
    /// Average token rows processed per resident expert this iteration
    /// (global tokens × top-k / E).
    pub rows_per_expert: u64,
}

/// Build the kernel flow of one MoE decoder layer for one decode iteration
/// (paper §III-E / Appendix B). `rows` = batch × speculative length on this
/// chip; attention runs data-parallel over the chip's own users.
pub fn decode_layer_kernels(
    ds: &DeepSeekConfig,
    batch: u32,
    kv_len: u32,
    dtype: Dtype,
    moe: MoePlacement,
) -> Vec<DecoderKernel> {
    let rows = batch as u64 * ds.mtp_spec_len as u64;
    let d = ds.d_model as u64;
    let h = ds.n_heads as u64;
    let qk = (ds.qk_nope_dim + ds.qk_rope_dim) as u64;
    let dc = ds.kv_lora_rank as u64;
    let mut v = Vec::new();

    v.push(DecoderKernel::vec("attn.rmsnorm", rows * d));
    if ds.q_lora_rank > 0 {
        let ql = ds.q_lora_rank as u64;
        v.push(DecoderKernel::gemm("attn.q_down (W^DQ)", rows, d, ql));
        v.push(DecoderKernel::vec("attn.q_norm", rows * ql));
        v.push(DecoderKernel::gemm("attn.q_up (W^UQ)", rows, ql, h * qk));
    } else {
        v.push(DecoderKernel::gemm("attn.q_proj (W^Q)", rows, d, h * qk));
    }
    // Weight absorption (Eq. 7/8): project q_nope into the latent space.
    v.push(DecoderKernel::gemm_b("attn.q_absorb (W^UQK)", rows, ds.qk_nope_dim as u64, dc, h));
    v.push(DecoderKernel::gemm("attn.kv_down (W^DKV)", rows, d, dc + ds.qk_rope_dim as u64));
    v.push(DecoderKernel::vec("attn.kv_norm+rope", rows * (dc + 2 * ds.qk_rope_dim as u64)));
    v.push(DecoderKernel {
        name: "attn.mla_core".into(),
        class: KernelClass::Attention(ds.mla_decode_shape(batch, kv_len, dtype)),
    });
    // Un-absorb values: latent output back to per-head v dim.
    v.push(DecoderKernel::gemm_b("attn.v_unabsorb (W^UV)", rows, dc, ds.v_head_dim as u64, h));
    v.push(DecoderKernel::gemm("attn.o_proj (W^O)", rows, h * ds.v_head_dim as u64, d));
    v.push(DecoderKernel::vec("ffn.rmsnorm", rows * d));
    v.push(DecoderKernel::gemm("moe.gate", rows, d, ds.n_experts as u64));
    v.push(DecoderKernel::vec("moe.routing(top-k)", rows * ds.n_experts as u64));
    let ei = ds.expert_inter as u64;
    for s in 0..ds.shared_experts {
        v.push(DecoderKernel::gemm(&format!("moe.shared{s}.gate_up"), rows, d, 2 * ei));
        v.push(DecoderKernel::vec(&format!("moe.shared{s}.silu"), rows * ei));
        v.push(DecoderKernel::gemm(&format!("moe.shared{s}.down"), rows, ei, d));
    }
    if moe.experts_on_chip > 0 && moe.rows_per_expert > 0 {
        v.push(DecoderKernel::gemm_b(
            "moe.routed.gate_up",
            moe.rows_per_expert,
            d,
            2 * ei,
            moe.experts_on_chip as u64,
        ));
        v.push(DecoderKernel::vec(
            "moe.routed.silu",
            moe.rows_per_expert * ei * moe.experts_on_chip as u64,
        ));
        v.push(DecoderKernel::gemm_b(
            "moe.routed.down",
            moe.rows_per_expert,
            ei,
            d,
            moe.experts_on_chip as u64,
        ));
    }
    v.push(DecoderKernel::vec("residual.add", 2 * rows * d));
    v
}

/// Build the kernel flow of one MoE decoder layer for one *prefill chunk*
/// of `chunk_tokens` prompt rows at `context_tokens` total context (paper
/// §III-E; the serving layer's chunked-prefill cost model). Differences
/// from [`decode_layer_kernels`]:
///
/// - rows = the chunk itself (no speculative multiplier);
/// - attention runs un-absorbed: per-head K and V are up-projected from the
///   cached latent for the *whole context* (the MLA prefill recompute — its
///   cost grows with the chunk's offset, which is what makes late chunks of
///   a long prompt more expensive than early ones);
/// - the attention core is the causal chunked-prefill MHA shape.
pub fn prefill_layer_kernels(
    ds: &DeepSeekConfig,
    chunk_tokens: u32,
    context_tokens: u32,
    dtype: Dtype,
    moe: MoePlacement,
) -> Vec<DecoderKernel> {
    let rows = chunk_tokens.max(1) as u64;
    let context = context_tokens.max(chunk_tokens) as u64;
    let d = ds.d_model as u64;
    let h = ds.n_heads as u64;
    let qk = (ds.qk_nope_dim + ds.qk_rope_dim) as u64;
    let dc = ds.kv_lora_rank as u64;
    let mut v = Vec::new();

    v.push(DecoderKernel::vec("attn.rmsnorm", rows * d));
    if ds.q_lora_rank > 0 {
        let ql = ds.q_lora_rank as u64;
        v.push(DecoderKernel::gemm("attn.q_down (W^DQ)", rows, d, ql));
        v.push(DecoderKernel::vec("attn.q_norm", rows * ql));
        v.push(DecoderKernel::gemm("attn.q_up (W^UQ)", rows, ql, h * qk));
    } else {
        v.push(DecoderKernel::gemm("attn.q_proj (W^Q)", rows, d, h * qk));
    }
    v.push(DecoderKernel::gemm("attn.kv_down (W^DKV)", rows, d, dc + ds.qk_rope_dim as u64));
    v.push(DecoderKernel::vec("attn.kv_norm+rope", rows * (dc + 2 * ds.qk_rope_dim as u64)));
    // Un-absorbed prefill: reconstruct per-head K and V for the whole
    // context from the cached latent (Eq. 7/8 run forward, not absorbed).
    v.push(DecoderKernel::gemm_b("attn.k_up (W^UK)", context, dc, ds.qk_nope_dim as u64, h));
    v.push(DecoderKernel::gemm_b("attn.v_up (W^UV)", context, dc, ds.v_head_dim as u64, h));
    v.push(DecoderKernel {
        name: "attn.prefill_core".into(),
        class: KernelClass::Attention(ds.mla_prefill_shape(
            chunk_tokens.max(1),
            context as u32,
            dtype,
        )),
    });
    v.push(DecoderKernel::gemm("attn.o_proj (W^O)", rows, h * ds.v_head_dim as u64, d));
    v.push(DecoderKernel::vec("ffn.rmsnorm", rows * d));
    v.push(DecoderKernel::gemm("moe.gate", rows, d, ds.n_experts as u64));
    v.push(DecoderKernel::vec("moe.routing(top-k)", rows * ds.n_experts as u64));
    let ei = ds.expert_inter as u64;
    for s in 0..ds.shared_experts {
        v.push(DecoderKernel::gemm(&format!("moe.shared{s}.gate_up"), rows, d, 2 * ei));
        v.push(DecoderKernel::vec(&format!("moe.shared{s}.silu"), rows * ei));
        v.push(DecoderKernel::gemm(&format!("moe.shared{s}.down"), rows, ei, d));
    }
    if moe.experts_on_chip > 0 && moe.rows_per_expert > 0 {
        v.push(DecoderKernel::gemm_b(
            "moe.routed.gate_up",
            moe.rows_per_expert,
            d,
            2 * ei,
            moe.experts_on_chip as u64,
        ));
        v.push(DecoderKernel::vec(
            "moe.routed.silu",
            moe.rows_per_expert * ei * moe.experts_on_chip as u64,
        ));
        v.push(DecoderKernel::gemm_b(
            "moe.routed.down",
            moe.rows_per_expert,
            ei,
            d,
            moe.experts_on_chip as u64,
        ));
    }
    v.push(DecoderKernel::vec("residual.add", 2 * rows * d));
    v
}

/// FLOP breakdown of a whole model forward, per generated token:
/// (attention-core FLOPs, all other FLOPs). Fig. 1a.
pub fn flop_breakdown_per_token(ds: &DeepSeekConfig, phase: Phase, len: u32, dtype: Dtype) -> (f64, f64) {
    let kv_len = match phase {
        Phase::Prefill => len / 2, // causal average context
        _ => len,
    };
    let moe = MoePlacement {
        experts_on_chip: ds.n_experts,
        rows_per_expert: ((ds.mtp_spec_len as u64) * ds.experts_per_token as u64).div_ceil(ds.n_experts as u64).max(1),
    };
    // Per-iteration kernels for batch=1; normalize to per generated token.
    let kernels = decode_layer_kernels(ds, 1, kv_len, dtype, moe);
    let mut attn = 0.0;
    let mut other = 0.0;
    for k in &kernels {
        let f = k.class.flops() as f64;
        if k.is_attention() {
            attn += f;
        } else {
            other += f;
        }
    }
    // Routed expert flops: exactly top-k experts per token (the placement
    // above over-counts granularity; recompute exactly).
    let d = ds.d_model as u64 as f64;
    let ei = ds.expert_inter as f64;
    let rows = ds.mtp_spec_len as f64;
    let routed_exact = rows * ds.experts_per_token as f64 * 3.0 * 2.0 * d * ei;
    let routed_modeled = (2.0 * moe.rows_per_expert as f64 * d * 2.0 * ei + 2.0 * moe.rows_per_expert as f64 * ei * d)
        * moe.experts_on_chip as f64;
    other += routed_exact - routed_modeled;
    let per_tok = ds.tokens_per_iteration();
    (
        attn * ds.layers as f64 / per_tok,
        other * ds.layers as f64 / per_tok,
    )
}

/// A classic dense MHA+MLP model (Fig. 1a's Qwen-chat-7B).
#[derive(Debug, Clone)]
pub struct DenseModelConfig {
    pub name: String,
    pub layers: u32,
    pub d_model: u32,
    pub heads: u32,
    pub head_dim: u32,
    pub ffn_inter: u32,
}

impl DenseModelConfig {
    pub fn qwen7b() -> Self {
        DenseModelConfig { name: "Qwen-chat-7B".into(), layers: 32, d_model: 4096, heads: 32, head_dim: 128, ffn_inter: 11008 }
    }

    /// (attention-core FLOPs, other FLOPs) per token.
    pub fn flop_breakdown_per_token(&self, phase: Phase, len: u32) -> (f64, f64) {
        let d = self.d_model as f64;
        let hd = self.head_dim as f64;
        let h = self.heads as f64;
        let kv = match phase {
            Phase::Prefill => (len / 2) as f64,
            _ => len as f64,
        };
        let attn_core = 2.0 * h * kv * (hd + hd);
        let proj = 2.0 * d * (3.0 * h * hd) + 2.0 * (h * hd) * d;
        let ffn = 3.0 * 2.0 * d * self.ffn_inter as f64;
        (attn_core * self.layers as f64, (proj + ffn) * self.layers as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_count_matches_671b() {
        let ds = DeepSeekConfig::v3_671b();
        let p = ds.param_count() as f64 / 1e9;
        assert!((p - 671.0).abs() < 45.0, "params {p}B");
    }

    #[test]
    fn mtp_tokens_per_iteration() {
        let ds = DeepSeekConfig::v3_671b();
        assert!((ds.tokens_per_iteration() - 1.7).abs() < 1e-9);
    }

    #[test]
    fn decode_attention_dominates_at_long_context() {
        // Paper Fig. 1a: DS671B attention reaches ~71% of decode FLOPs at
        // long context.
        let ds = DeepSeekConfig::v3_671b();
        let (attn, other) = flop_breakdown_per_token(&ds, Phase::Decode, 10_000, Dtype::Fp8);
        let frac = attn / (attn + other);
        assert!(frac > 0.60 && frac < 0.80, "fraction {frac}");
        // And much lower for the dense Qwen-7B.
        let q = DenseModelConfig::qwen7b();
        let (qa, qo) = q.flop_breakdown_per_token(Phase::Decode, 10_000);
        let qfrac = qa / (qa + qo);
        assert!(qfrac < frac / 1.5, "qwen fraction {qfrac}");
    }

    #[test]
    fn kernel_flow_has_expected_structure() {
        let ds = DeepSeekConfig::v3_671b();
        let moe = MoePlacement { experts_on_chip: 8, rows_per_expert: 16 };
        let ks = decode_layer_kernels(&ds, 64, 4096, Dtype::Fp8, moe);
        assert_eq!(ks.iter().filter(|k| k.is_attention()).count(), 1);
        assert!(ks.iter().any(|k| k.name.contains("q_absorb")));
        assert!(ks.iter().any(|k| k.name.contains("moe.routed.gate_up")));
        // Attention core FLOPs must use the absorbed MQA shape.
        let a = ks.iter().find(|k| k.is_attention()).unwrap();
        if let KernelClass::Attention(s) = &a.class {
            assert_eq!(s.head_dim, 576);
            assert_eq!(s.kv_heads, 1);
        }
    }

    #[test]
    fn prefill_kernel_flow_is_chunk_shaped() {
        let ds = DeepSeekConfig::v3_671b();
        let moe = MoePlacement { experts_on_chip: 8, rows_per_expert: 256 };
        let ks = prefill_layer_kernels(&ds, 1024, 9216, Dtype::Fp8, moe);
        assert_eq!(ks.iter().filter(|k| k.is_attention()).count(), 1);
        // Prefill is un-absorbed: K/V up-projections over the full context,
        // and no decode-style q_absorb / v_unabsorb kernels.
        assert!(ks.iter().any(|k| k.name.contains("k_up")));
        assert!(ks.iter().any(|k| k.name.contains("v_up")));
        assert!(!ks.iter().any(|k| k.name.contains("q_absorb")));
        let a = ks.iter().find(|k| k.is_attention()).unwrap();
        if let KernelClass::Attention(s) = &a.class {
            assert_eq!(s.seq_q, 1024);
            assert_eq!(s.seq_kv, 9216);
            assert_eq!(s.head_dim, 192);
            assert_eq!(s.v_head_dim, 128);
            assert_eq!(s.kv_heads, s.heads);
            assert!(s.causal);
        }
        // Deeper offsets cost strictly more (K/V recompute + attention).
        let flops = |ctx: u32| -> u64 {
            prefill_layer_kernels(&ds, 1024, ctx, Dtype::Fp8, moe)
                .iter()
                .map(|k| k.class.flops())
                .sum()
        };
        assert!(flops(32_768) > flops(9216));
    }

    #[test]
    fn kv_cache_fits_wafer_hbm() {
        // §V-C: b=256 users, kv 4096, 61 layers + weights under 128 GiB.
        let ds = DeepSeekConfig::v3_671b();
        let kv = 256 * ds.kv_cache_bytes_per_user_layer(4096, Dtype::Fp8) * ds.layers as u64;
        let weights_ep32 = ds.param_count() / 32; // EP32 shards experts
        assert!(kv + weights_ep32 < 128 * (1 << 30), "kv {} GiB", kv >> 30);
    }

    #[test]
    fn expert_weights_dominate_params() {
        let ds = DeepSeekConfig::v3_671b();
        let moe_layers = (ds.layers - ds.dense_layers) as u64;
        let experts = ds.expert_weight_bytes_per_layer(Dtype::Fp8) * moe_layers;
        assert!(experts > ds.param_count() * 9 / 10 * 85 / 100);
    }
}
