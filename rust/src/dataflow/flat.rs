//! FlatAttention dataflow (paper Algorithm 2 + §III-C): groups of tiles
//! collaboratively process one attention block, with diagonal-tile HBM
//! fetches, row/column multicasts, and row-wise softmax/output reductions
//! over the fabric collectives.

use crate::arch::collective::{multicast, reduce, Axis, CollectiveImpl};
use crate::arch::config::ChipConfig;
use crate::arch::hbm;
use crate::arch::noc::{ChipResources, TileCoord};
use crate::arch::tile::{gemm_cycles, gemm_flops, vector_cycles, vector_flops, VectorOpKind};
use crate::dataflow::tiling::{choose_tiling, FlatTiling};
use crate::sim::{Category, Graph, Op, OpId};
use crate::workload::attention::AttentionShape;

/// FlatAttention variant knobs. The paper's named configurations:
/// - `FlatSC` = `collective: SwSeq`, no async, no double buffering
/// - `FlatTC` = `collective: SwTree`, no async, no double buffering
/// - `FlatHC` = `collective: Hw`, no async, with double buffering
/// - `FlatAsync` = `collective: Hw`, async two-head schedule + double buffer
#[derive(Debug, Clone, Copy)]
pub struct FlatParams {
    pub tiling: FlatTiling,
    pub collective: CollectiveImpl,
    /// §III-C: interleave two heads per group so DMA/vector work of one
    /// overlaps matrix work of the other.
    pub async_two_heads: bool,
    /// Prefetch next iteration's K/V slices into the second buffer.
    pub double_buffer: bool,
}

impl FlatParams {
    pub fn flat_sc(t: FlatTiling) -> Self {
        FlatParams { tiling: t, collective: CollectiveImpl::SwSeq, async_two_heads: false, double_buffer: false }
    }
    pub fn flat_tc(t: FlatTiling) -> Self {
        FlatParams { tiling: t, collective: CollectiveImpl::SwTree, async_two_heads: false, double_buffer: false }
    }
    pub fn flat_hc(t: FlatTiling) -> Self {
        FlatParams { tiling: t, collective: CollectiveImpl::Hw, async_two_heads: false, double_buffer: true }
    }
    pub fn flat_async(t: FlatTiling) -> Self {
        FlatParams { tiling: t, collective: CollectiveImpl::Hw, async_two_heads: true, double_buffer: true }
    }

    /// Default FlatAsync with the Fig. 10 tiling strategy.
    pub fn auto(cfg: &ChipConfig, shape: &AttentionShape) -> Self {
        Self::flat_async(choose_tiling(cfg, shape, true))
    }

    pub fn label(&self) -> String {
        if self.async_two_heads {
            "FlatAsync".into()
        } else {
            match self.collective {
                CollectiveImpl::Hw => "FlatHC".into(),
                CollectiveImpl::SwTree => "FlatTC".into(),
                CollectiveImpl::SwSeq => "FlatSC".into(),
            }
        }
    }
}

/// Per-lane frontier state threaded across the units a group processes.
struct GroupState {
    /// Compute frontier per tile (index y*gx+x within the group).
    frontier: Vec<OpId>,
    /// Dependency gating the *next* K/V load, per group column.
    kv_free: Vec<OpId>,
    /// Under double buffering: consumers of the most recent iteration.
    kv_free_prev: Vec<OpId>,
    /// Q buffer availability per group row.
    q_free: Vec<OpId>,
    q_free_prev: Vec<OpId>,
}

impl GroupState {
    fn new(g: &mut Graph, t: FlatTiling) -> Self {
        let start = g.join(&[]);
        let nt = (t.gx * t.gy) as usize;
        GroupState {
            frontier: vec![start; nt],
            kv_free: vec![start; t.gx as usize],
            kv_free_prev: vec![start; t.gx as usize],
            q_free: vec![start; t.gy as usize],
            q_free_prev: vec![start; t.gy as usize],
        }
    }
}

/// Build the full FlatAttention graph for `shape` on `cfg`.
///
/// Groups tile the mesh; independent units (batch × KV heads) are assigned
/// round-robin to groups. Within a group, units execute serially in the
/// naive schedule and pairwise-concurrently under `async_two_heads`.
pub fn build(cfg: &ChipConfig, res: &ChipResources, shape: &AttentionShape, p: &FlatParams) -> Graph {
    let t = p.tiling;
    assert!(t.gx <= cfg.mesh_x && t.gy <= cfg.mesh_y, "group exceeds mesh");
    let groups_x = cfg.mesh_x / t.gx;
    let groups_y = cfg.mesh_y / t.gy;
    let n_groups = (groups_x * groups_y) as u64;
    assert!(n_groups >= 1);

    let mut g = Graph::new(res.table.clone());
    let units = shape.independent_units();

    // Units per group, preserving unit order for determinism.
    let mut per_group: Vec<Vec<u64>> = vec![Vec::new(); n_groups as usize];
    for u in 0..units {
        per_group[(u % n_groups) as usize].push(u);
    }

    // Build units round-robin ACROSS groups (not group-by-group): shared
    // resources (HBM channels, NoC paths) break ties by op id, so a
    // group-major build order would systematically favour the first group
    // and starve the rest — the hardware arbitration is fair.
    let lanes = if p.async_two_heads { 2usize } else { 1 };
    // Each lane keeps persistent buffer-availability state, so the next
    // unit's loads begin as soon as buffers free — units pipeline within a
    // lane instead of serializing on full completion.
    let mut states: Vec<Vec<GroupState>> = (0..n_groups)
        .map(|_| (0..lanes).map(|_| GroupState::new(&mut g, t)).collect())
        .collect();
    let max_units = per_group.iter().map(|u| u.len()).max().unwrap_or(0);
    for k in 0..max_units {
        for (gi, group_units) in per_group.iter().enumerate() {
            if k >= group_units.len() {
                continue;
            }
            let ox = (gi as u32 % groups_x) * t.gx;
            let oy = (gi as u32 / groups_x) * t.gy;
            build_unit(&mut g, cfg, res, shape, p, ox, oy, &mut states[gi][k % lanes]);
        }
    }
    g
}

/// Build one unit (one batch × KV-head attention) on the group at
/// (ox, oy), threading the lane's buffer state; returns the unit's
/// completion op.
#[allow(clippy::too_many_arguments)]
fn build_unit(
    g: &mut Graph,
    cfg: &ChipConfig,
    res: &ChipResources,
    shape: &AttentionShape,
    p: &FlatParams,
    ox: u32,
    oy: u32,
    st: &mut GroupState,
) -> OpId {
    let t = p.tiling;
    let e = shape.dtype.bytes();
    let d = shape.head_dim as u64;
    let dv = shape.v_head_dim as u64;
    let br = t.slice_r as u64;
    let bc = t.slice_c as u64;
    let rows = shape.effective_q_rows();
    let kv = shape.seq_kv as u64;
    let t_r = rows.div_ceil(t.block_r());
    let t_c = kv.div_ceil(t.block_c());

    let tile_at = |x: u32, y: u32| TileCoord { x: ox + x, y: oy + y };
    // Diagonal tile of group row y (loads Q) / group column x (loads K/V).
    let q_diag = |y: u32| tile_at(y % t.gx, y);
    let kv_diag = |x: u32| tile_at(x, x % t.gy);

    let start = st.frontier[0];
    let nt = (t.gx * t.gy) as usize;
    let idx = |x: u32, y: u32| (y * t.gx + x) as usize;

    let mut unit_tail: Vec<OpId> = Vec::new();

    for _i in 0..t_r {
        // --- Q phase: diagonal loads + row-wise multicast (lines 5–7). ---
        let mut q_ready: Vec<OpId> = Vec::with_capacity(t.gy as usize);
        for y in 0..t.gy {
            let diag = q_diag(y);
            let dep = st.q_free[y as usize];
            let load = hbm::load(g, res, cfg, diag, br * d * e, &[dep]);
            let mc = multicast(g, res, cfg, p.collective, Axis::Row, oy + y, t.gx, br * d * e, &[load]);
            q_ready.push(mc);
        }

        // Per-tile O accumulator init is free (zero-fill under the GEMM).
        let mut row_frontier: Vec<Vec<OpId>> = vec![Vec::new(); t.gy as usize];

        for _j in 0..t_c {
            // --- K/V phase: diagonal loads + column-wise multicast (8–9). ---
            let kv_bytes = bc * shape.kv_row_bytes();
            let mut kv_ready: Vec<OpId> = Vec::with_capacity(t.gx as usize);
            for x in 0..t.gx {
                let diag = kv_diag(x);
                let dep = st.kv_free[x as usize];
                let load = hbm::load(g, res, cfg, diag, kv_bytes, &[dep]);
                let mc = multicast(g, res, cfg, p.collective, Axis::Col, ox + x, t.gy, kv_bytes, &[load]);
                kv_ready.push(mc);
            }

            // --- Compute S = Q·Kᵀ and local rowmax (10–13). ---
            let mut rowmax_ops: Vec<Vec<OpId>> = vec![Vec::new(); t.gy as usize];
            for y in 0..t.gy {
                for x in 0..t.gx {
                    let tc = tile_at(x, y);
                    let deps = [q_ready[y as usize], kv_ready[x as usize], st.frontier[idx(x, y)]];
                    let s_gemm = g.push(
                        Op::new(Some(res.matrix(tc)), gemm_cycles(&cfg.tile, br, d, bc), Category::Gemm)
                            .flops(gemm_flops(br, d, bc)),
                        &deps,
                    );
                    let rm = g.push(
                        Op::new(Some(res.vector(tc)), vector_cycles(&cfg.tile, VectorOpKind::RowMax, br, bc), Category::Vector)
                            .flops(vector_flops(VectorOpKind::RowMax, br, bc)),
                        &[s_gemm],
                    );
                    rowmax_ops[y as usize].push(rm);
                }
            }

            // --- Global rowmax: row-wise reduce + multicast (15–16). ---
            // Stats are fp32 per Q row: br × 4 bytes.
            let stat_bytes = br * 4;
            let mut max_ready: Vec<OpId> = Vec::with_capacity(t.gy as usize);
            for y in 0..t.gy {
                let dst = q_diag(y);
                let red = reduce(
                    g, res, cfg, p.collective, Axis::Row, oy + y, t.gx, dst, stat_bytes, shape.dtype,
                    &rowmax_ops[y as usize],
                );
                let mc = multicast(g, res, cfg, p.collective, Axis::Row, oy + y, t.gx, stat_bytes, &[red]);
                max_ready.push(mc);
            }

            // --- exp + rowsum (17–18). ---
            let mut rowsum_ops: Vec<Vec<OpId>> = vec![Vec::new(); t.gy as usize];
            let mut exp_done: Vec<OpId> = vec![start; nt];
            for y in 0..t.gy {
                for x in 0..t.gx {
                    let tc = tile_at(x, y);
                    let ex = g.push(
                        Op::new(Some(res.vector(tc)), vector_cycles(&cfg.tile, VectorOpKind::Exp, br, bc), Category::Vector)
                            .flops(vector_flops(VectorOpKind::Exp, br, bc)),
                        &[max_ready[y as usize]],
                    );
                    exp_done[idx(x, y)] = ex;
                    let rs = g.push(
                        Op::new(Some(res.vector(tc)), vector_cycles(&cfg.tile, VectorOpKind::RowSum, br, bc), Category::Vector)
                            .flops(vector_flops(VectorOpKind::RowSum, br, bc)),
                        &[ex],
                    );
                    rowsum_ops[y as usize].push(rs);
                }
            }

            // --- Global denominator: reduce + multicast (19–20). ---
            let mut sum_ready: Vec<OpId> = Vec::with_capacity(t.gy as usize);
            for y in 0..t.gy {
                let dst = q_diag(y);
                let red = reduce(
                    g, res, cfg, p.collective, Axis::Row, oy + y, t.gx, dst, stat_bytes, shape.dtype,
                    &rowsum_ops[y as usize],
                );
                let mc = multicast(g, res, cfg, p.collective, Axis::Row, oy + y, t.gx, stat_bytes, &[red]);
                sum_ready.push(mc);
            }

            // --- Stats update, O rescale, O += P·V (22–26). ---
            for y in 0..t.gy {
                for x in 0..t.gx {
                    let tc = tile_at(x, y);
                    let upd = g.push(
                        Op::new(Some(res.vector(tc)), vector_cycles(&cfg.tile, VectorOpKind::StatsUpdate, br, 1), Category::Vector)
                            .flops(vector_flops(VectorOpKind::StatsUpdate, br, 1)),
                        &[sum_ready[y as usize]],
                    );
                    let rescale = g.push(
                        Op::new(Some(res.vector(tc)), vector_cycles(&cfg.tile, VectorOpKind::Rescale, br, dv), Category::Vector)
                            .flops(vector_flops(VectorOpKind::Rescale, br, dv)),
                        &[upd],
                    );
                    let pv = g.push(
                        Op::new(Some(res.matrix(tc)), gemm_cycles(&cfg.tile, br, bc, dv), Category::Gemm)
                            .flops(gemm_flops(br, bc, dv)),
                        &[rescale, exp_done[idx(x, y)]],
                    );
                    st.frontier[idx(x, y)] = pv;
                    row_frontier[y as usize].push(pv);
                }
            }

            // Buffer turnover. Single-buffered: the load for iteration j+1
            // waits for iteration j's consumers (strictly serial, Fig. 4c).
            // Double-buffered: two K/V buffers alternate, so the load for
            // j+1 is gated by the consumers of j−1 (prefetch overlaps
            // compute, Fig. 4d).
            for x in 0..t.gx {
                let consumers: Vec<OpId> = (0..t.gy).map(|y| st.frontier[idx(x, y)]).collect();
                let free_j = g.join(&consumers);
                let xi = x as usize;
                if p.double_buffer {
                    st.kv_free[xi] = st.kv_free_prev[xi];
                    st.kv_free_prev[xi] = free_j;
                } else {
                    st.kv_free[xi] = free_j;
                }
            }
        }

        // --- Epilogue: final rescale, row-wise O reduction, store (28–30). ---
        for y in 0..t.gy {
            let mut rescaled: Vec<OpId> = Vec::with_capacity(t.gx as usize);
            for x in 0..t.gx {
                let tc = tile_at(x, y);
                let fin = g.push(
                    Op::new(Some(res.vector(tc)), vector_cycles(&cfg.tile, VectorOpKind::Rescale, br, dv), Category::Vector)
                        .flops(vector_flops(VectorOpKind::Rescale, br, dv)),
                    &[st.frontier[idx(x, y)]],
                );
                rescaled.push(fin);
                st.frontier[idx(x, y)] = fin;
            }
            let o_bytes = br * dv * e;
            let dst = q_diag(y);
            let red = reduce(
                g, res, cfg, p.collective, Axis::Row, oy + y, t.gx, dst, o_bytes, shape.dtype, &rescaled,
            );
            let store = hbm::store(g, res, cfg, dst, o_bytes, &[red]);
            if p.double_buffer {
                st.q_free[y as usize] = st.q_free_prev[y as usize];
                st.q_free_prev[y as usize] = store;
            } else {
                st.q_free[y as usize] = store;
            }
            unit_tail.push(store);
        }
    }

    g.join(&unit_tail)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::config::Dtype;
    use crate::metrics::KernelMetrics;

    fn sim(cfg: &ChipConfig, shape: &AttentionShape, p: &FlatParams) -> KernelMetrics {
        let res = ChipResources::new(cfg);
        let g = build(cfg, &res, shape, p);
        let r = g.simulate();
        KernelMetrics::from_sim(cfg, &r)
    }

    #[test]
    fn small_flat_runs() {
        let cfg = ChipConfig::tiny(4);
        let shape = AttentionShape::mha_prefill(1, 2, 64, 256, Dtype::Fp16);
        let t = FlatTiling { gx: 4, gy: 4, slice_r: 64, slice_c: 64 };
        let m = sim(&cfg, &shape, &FlatParams::flat_hc(t));
        assert!(m.cycles > 0);
        assert!(m.hbm_bytes > 0);
    }

    #[test]
    fn hw_collectives_beat_sw_seq() {
        // Larger payloads (slice 128, D=128) so the tree's per-stage sync is
        // amortized — the regime of paper Fig. 8 where SC > TC > HC holds.
        let cfg = ChipConfig::tiny(8);
        let shape = AttentionShape::mha_prefill(1, 4, 128, 1024, Dtype::Fp16);
        let t = FlatTiling { gx: 8, gy: 8, slice_r: 128, slice_c: 128 };
        let hc = sim(&cfg, &shape, &FlatParams::flat_hc(t));
        let sc = sim(&cfg, &shape, &FlatParams::flat_sc(t));
        let tc = sim(&cfg, &shape, &FlatParams::flat_tc(t));
        assert!(sc.cycles > tc.cycles, "SC {} TC {}", sc.cycles, tc.cycles);
        assert!(tc.cycles > hc.cycles, "TC {} HC {}", tc.cycles, hc.cycles);
    }

    #[test]
    fn async_beats_naive_hc() {
        let cfg = ChipConfig::tiny(8);
        let shape = AttentionShape::mha_prefill(2, 8, 64, 1024, Dtype::Fp16);
        let t = FlatTiling { gx: 8, gy: 8, slice_r: 128, slice_c: 128 };
        let hc = sim(&cfg, &shape, &FlatParams::flat_hc(t));
        let asy = sim(&cfg, &shape, &FlatParams::flat_async(t));
        assert!(asy.cycles < hc.cycles, "async {} hc {}", asy.cycles, hc.cycles);
    }

    #[test]
    fn hbm_traffic_matches_io_model() {
        let cfg = ChipConfig::tiny(4);
        let shape = AttentionShape::mha_prefill(1, 2, 64, 512, Dtype::Fp16);
        let t = FlatTiling { gx: 4, gy: 4, slice_r: 128, slice_c: 128 };
        let m = sim(&cfg, &shape, &FlatParams::flat_hc(t));
        let model = shape.io_bytes_with_flattening(128, 4);
        let err = (m.hbm_bytes as f64 - model as f64).abs() / model as f64;
        assert!(err < 0.05, "sim {} model {model}", m.hbm_bytes);
    }

    #[test]
    fn flops_match_shape_model() {
        let cfg = ChipConfig::tiny(4);
        let shape = AttentionShape::mha_prefill(1, 2, 64, 256, Dtype::Fp16);
        let t = FlatTiling { gx: 4, gy: 4, slice_r: 64, slice_c: 64 };
        let res = ChipResources::new(&cfg);
        let g = build(&cfg, &res, &shape, &FlatParams::flat_hc(t));
        let r = g.simulate();
        // GEMM flops (non-causal model: builder does not skip masked blocks)
        let gemm_flops_sim: u64 = r.flops;
        let expect = 2 * shape.independent_units() * 256 * 256 * (64 + 64);
        // Vector flops add a few percent.
        let ratio = gemm_flops_sim as f64 / expect as f64;
        assert!(ratio > 1.0 && ratio < 1.2, "ratio {ratio}");
    }

    #[test]
    fn decode_single_row_group() {
        let cfg = ChipConfig::tiny(8);
        let shape = AttentionShape::mha_decode(4, 8, 64, 1024, 1, Dtype::Fp16);
        let t = FlatTiling { gx: 8, gy: 1, slice_r: 1, slice_c: 128 };
        let m = sim(&cfg, &shape, &FlatParams::flat_async(t));
        assert!(m.cycles > 0);
        // Memory-bound: decent HBM utilization expected.
        assert!(m.hbm_bw_utilization > 0.2, "bw {}", m.hbm_bw_utilization);
    }
}
