//! The general tiling and group-scaling strategy for FlatAttention
//! (paper Fig. 10): *prioritize per-tile RedMulE utilization before
//! aggressive flattening*.
//!
//! 1. Pick the per-tile slice (Br/Gy × Bc/Gx) that maximizes matrix-engine
//!    efficiency subject to the L1 budget (→ 128×128 on the Table I tile,
//!    Fig. 11).
//! 2. Grow the group (Gx, Gy) as far as the attention-score matrix shape and
//!    the mesh topology allow, dividing the KV re-read factor (§III-A)
//!    without shrinking slices below the compute-efficient size
//!    (over-flattening, Fig. 9).

use crate::arch::config::{ChipConfig, Dtype};
use crate::arch::tile::{gemm_utilization, L1Budget};
use crate::workload::attention::AttentionShape;

/// A chosen FlatAttention tiling: group shape and per-tile slice sizes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FlatTiling {
    /// Group width (KV axis).
    pub gx: u32,
    /// Group height (Q-row axis).
    pub gy: u32,
    /// Per-tile Q rows (Br / Gy).
    pub slice_r: u32,
    /// Per-tile KV rows (Bc / Gx).
    pub slice_c: u32,
}

impl FlatTiling {
    pub fn block_r(&self) -> u64 {
        self.gy as u64 * self.slice_r as u64
    }
    pub fn block_c(&self) -> u64 {
        self.gx as u64 * self.slice_c as u64
    }
    pub fn tiles(&self) -> u32 {
        self.gx * self.gy
    }
}

/// How many op streams a tile keeps resident concurrently (§III-C).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Concurrency {
    /// One block in flight (FA-2, FlatSC/TC/HC).
    Single,
    /// Two *heads* interleaved (FA-3 style): everything duplicated,
    /// including K/V (different heads attend to different KV).
    TwoHeads,
    /// Two *output row blocks* interleaved (the FlatAsync footnote variant):
    /// Q/O/score duplicated, K/V shared between the two blocks.
    TwoRowBlocks,
}

/// L1 working set of one tile under the flash/flat dataflows.
///
/// `double_buffer` doubles the K/V slices (prefetch of the next inner
/// iteration). The O accumulator is held at engine-native precision (RedMulE
/// accumulates in the input format; fp32 row statistics are carried
/// separately), and P overwrites S in place.
#[allow(clippy::too_many_arguments)]
pub fn l1_working_set(
    slice_r: u64,
    slice_c: u64,
    d: u64,
    dv: u64,
    dtype: Dtype,
    double_buffer: bool,
    conc: Concurrency,
) -> L1Budget {
    l1_working_set_kv(slice_r, slice_c, d, dv, d + dv, dtype, double_buffer, conc)
}

/// As [`l1_working_set`] but with an explicit stored-KV row width:
/// MLA's V is a subview of the cached latent, so its K/V buffer holds
/// `kv_cols = d` columns, not `d + dv`.
#[allow(clippy::too_many_arguments)]
pub fn l1_working_set_kv(
    slice_r: u64,
    slice_c: u64,
    d: u64,
    dv: u64,
    kv_cols: u64,
    dtype: Dtype,
    double_buffer: bool,
    conc: Concurrency,
) -> L1Budget {
    let e = dtype.bytes();
    let kv_db = if double_buffer { 2 } else { 1 };
    let (dup, kv_dup) = match conc {
        Concurrency::Single => (1u64, 1u64),
        Concurrency::TwoHeads => (2, 2),
        Concurrency::TwoRowBlocks => (2, 1),
    };
    let mut b = L1Budget::new();
    b.add("Q", dup * slice_r * d * e)
        .add("K/V", kv_dup * kv_db * slice_c * kv_cols * e)
        .add("O_acc", dup * slice_r * dv * e)
        .add("S/P", dup * slice_r * slice_c * e)
        .add("stats(m,l)", dup * 2 * slice_r * 4);
    b
}

/// Mean matrix-engine utilization of one inner iteration's two GEMMs
/// (score `r×D×c` and output `r×c×Dv`) — the per-tile efficiency the
/// strategy maximizes (Fig. 11a).
pub fn slice_utilization(cfg: &ChipConfig, slice_r: u64, slice_c: u64, d: u64, dv: u64) -> f64 {
    let score = gemm_utilization(&cfg.tile, slice_r, d, slice_c);
    let out = gemm_utilization(&cfg.tile, slice_r, slice_c, dv);
    // Weight by FLOPs of each GEMM.
    let fs = (d * slice_r * slice_c) as f64;
    let fo = (dv * slice_r * slice_c) as f64;
    (score * fs + out * fo) / (fs + fo)
}

/// Largest power of two ≤ `x` (≥ 1).
fn pow2_floor(x: u32) -> u32 {
    if x == 0 {
        1
    } else {
        1 << (31 - x.leading_zeros())
    }
}

/// Find the compute-optimal per-tile slice for head dims (D, Dv): the
/// smallest square slice reaching ≥95% of the best achievable utilization
/// within the L1 budget (Fig. 11's 128×128 operating point on Table I).
pub fn optimal_slice(cfg: &ChipConfig, d: u32, dv: u32, dtype: Dtype, async_two_blocks: bool) -> (u32, u32) {
    optimal_slice_kv(cfg, d, dv, d + dv, dtype, async_two_blocks)
}

/// As [`optimal_slice`] with an explicit stored-KV row width (see
/// [`l1_working_set_kv`]).
pub fn optimal_slice_kv(cfg: &ChipConfig, d: u32, dv: u32, kv_cols: u32, dtype: Dtype, async_two_blocks: bool) -> (u32, u32) {
    let candidates = [16u32, 32, 64, 128, 256, 512];
    let conc = if async_two_blocks { Concurrency::TwoRowBlocks } else { Concurrency::Single };
    let mut feasible: Vec<(u32, f64)> = Vec::new();
    for &s in &candidates {
        let ws = l1_working_set_kv(s as u64, s as u64, d as u64, dv as u64, kv_cols as u64, dtype, true, conc);
        if ws.fits(&cfg.tile) {
            feasible.push((s, slice_utilization(cfg, s as u64, s as u64, d as u64, dv as u64)));
        }
    }
    if feasible.is_empty() {
        return (16, 16);
    }
    let best = feasible.iter().map(|&(_, u)| u).fold(0.0f64, f64::max);
    for &(s, u) in &feasible {
        if u >= 0.95 * best {
            return (s, s);
        }
    }
    let last = feasible.last().unwrap();
    (last.0, last.0)
}

/// Apply the Fig. 10 strategy to an attention shape on a chip: slice first,
/// then flatten the group along each axis up to the score-matrix shape and
/// the mesh.
pub fn choose_tiling(cfg: &ChipConfig, shape: &AttentionShape, async_two_heads: bool) -> FlatTiling {
    let rows = shape.effective_q_rows().max(1);
    let kv = shape.seq_kv.max(1) as u64;
    let kv_cols = (shape.kv_row_bytes() / shape.dtype.bytes()) as u32;
    let (sr0, sc0) = optimal_slice_kv(cfg, shape.head_dim, shape.v_head_dim, kv_cols, shape.dtype, async_two_heads);

    // Per-tile slices never exceed the problem itself.
    let slice_r = (sr0 as u64).min(rows) as u32;
    let slice_c = (sc0 as u64).min(kv) as u32;

    // Flatten: as many tiles along each axis as there are slices, capped by
    // the mesh, in powers of two so groups tile the mesh.
    let want_gy = rows.div_ceil(slice_r as u64).min(cfg.mesh_y as u64) as u32;
    let want_gx = kv.div_ceil(slice_c as u64).min(cfg.mesh_x as u64) as u32;
    let mut gy = pow2_floor(want_gy);
    let mut gx = pow2_floor(want_gx);

    // Keep enough groups to cover independent units when the workload has
    // plenty of parallelism and flattening no longer reduces IO (Tc == 1
    // already): shrinking the group would only idle tiles, so prefer the
    // larger group. But if there are more units than groups *and* the group
    // is larger than needed to hold the whole KV, shrink along X.
    let units = shape.independent_units();
    loop {
        let groups = (cfg.mesh_x / gx) as u64 * (cfg.mesh_y / gy) as u64;
        if groups >= units || (gx == 1 && gy == 1) {
            break;
        }
        // Group already covers all KV columns with slack → shrink X first.
        let covered_kv = gx as u64 * slice_c as u64;
        let covered_r = gy as u64 * slice_r as u64;
        if gx > 1 && covered_kv >= 2 * kv {
            gx /= 2;
        } else if gy > 1 && covered_r >= 2 * rows {
            gy /= 2;
        } else {
            break;
        }
    }

    // Rectangular refinement: decode-style shapes (few Q rows) leave most
    // of L1 free, so grow the KV slice to amortize per-iteration fixed
    // costs (HBM latency, collective hops, GEMM setup) while inner
    // iterations remain (§V-B: "Bc can be increased to maximize reuse of
    // the KV cache by leveraging the aggregated L1 capacity").
    let conc = if async_two_heads { Concurrency::TwoRowBlocks } else { Concurrency::Single };
    let mut slice_c = slice_c;
    while (slice_c as u64) < kv.div_ceil(gx as u64) {
        let cand = slice_c * 2;
        let ws = l1_working_set_kv(
            slice_r as u64,
            cand as u64,
            shape.head_dim as u64,
            shape.v_head_dim as u64,
            kv_cols as u64,
            shape.dtype,
            true,
            conc,
        );
        if ws.fits(&cfg.tile) {
            slice_c = cand;
        } else {
            break;
        }
    }

    FlatTiling { gx, gy, slice_r, slice_c }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::attention::AttentionShape;

    #[test]
    fn optimal_slice_is_128_on_table1() {
        // Paper Fig. 11: 128×128 is the selected operating point at D=128.
        let cfg = ChipConfig::table1();
        let (r, c) = optimal_slice(&cfg, 128, 128, Dtype::Fp16, false);
        assert_eq!((r, c), (128, 128));
        // Still 128 with the async two-row-block working set (K/V shared).
        let (r, c) = optimal_slice(&cfg, 128, 128, Dtype::Fp16, true);
        assert_eq!((r, c), (128, 128));
    }

    #[test]
    fn slice_utilization_anchors() {
        let cfg = ChipConfig::table1();
        let u128 = slice_utilization(&cfg, 128, 128, 128, 128);
        assert!(u128 > 0.95, "{u128}");
        let u16 = slice_utilization(&cfg, 16, 16, 128, 128);
        assert!((u16 - 0.20).abs() < 0.05, "{u16}");
    }

    #[test]
    fn prefill_4096_flattens_to_full_mesh() {
        let cfg = ChipConfig::table1();
        let s = AttentionShape::mha_prefill(2, 32, 128, 4096, Dtype::Fp16);
        let t = choose_tiling(&cfg, &s, true);
        assert_eq!(t.slice_r, 128);
        assert_eq!(t.slice_c, 128);
        assert_eq!(t.gx, 32);
        assert_eq!(t.gy, 32);
    }

    #[test]
    fn short_prefill_avoids_overflattening() {
        let cfg = ChipConfig::table1();
        let s = AttentionShape::mha_prefill(4, 32, 128, 512, Dtype::Fp16);
        let t = choose_tiling(&cfg, &s, true);
        // 512 rows / 128 slice = 4 tiles per axis, not 32.
        assert_eq!(t.slice_r, 128);
        assert_eq!(t.gy, 4);
        assert_eq!(t.gx, 4);
    }

    #[test]
    fn mha_decode_group_spans_one_row() {
        // §III-D: decode (Br = 1) uses a single-row group.
        let cfg = ChipConfig::table1();
        let s = AttentionShape::mha_decode(4, 32, 128, 4096, 1, Dtype::Fp16);
        let t = choose_tiling(&cfg, &s, false);
        assert_eq!(t.gy, 1);
        assert_eq!(t.slice_r, 1);
        assert!(t.gx >= 16, "gx {}", t.gx);
    }

    #[test]
    fn mla_decode_uses_wide_group() {
        let cfg = ChipConfig::wafer_fp8();
        let s = AttentionShape::mla_absorbed_decode(256, 128, 512, 64, 4096, 2, Dtype::Fp8);
        let t = choose_tiling(&cfg, &s, true);
        // MLA's wide head dims (576/512) shrink the L1-feasible slice; the
        // 256 effective rows flatten over a few group rows and the KV axis
        // flattens to the full mesh width.
        assert!(t.gy >= 2 && t.gy <= 8, "gy {}", t.gy);
        assert_eq!(t.gx, 32);
        // Per-tile GEMMs stay compute-efficient (≥90%).
        let u = slice_utilization(&cfg, t.slice_r as u64, t.slice_c as u64, 576, 512);
        assert!(u > 0.90, "slice util {u}");
    }

    #[test]
    fn working_set_fits_is_monotone() {
        let cfg = ChipConfig::table1();
        let c = Concurrency::TwoRowBlocks;
        let ok = l1_working_set(128, 128, 128, 128, Dtype::Fp16, true, c).fits(&cfg.tile);
        let too_big = l1_working_set(256, 256, 128, 128, Dtype::Fp16, true, c).fits(&cfg.tile);
        assert!(ok);
        assert!(!too_big);
        // FA-3's two-head variant duplicates K/V and no longer fits at 128.
        let fa3 = l1_working_set(128, 128, 128, 128, Dtype::Fp16, true, Concurrency::TwoHeads).fits(&cfg.tile);
        assert!(!fa3);
    }

    #[test]
    fn pow2_floor_works() {
        assert_eq!(pow2_floor(0), 1);
        assert_eq!(pow2_floor(1), 1);
        assert_eq!(pow2_floor(3), 2);
        assert_eq!(pow2_floor(32), 32);
        assert_eq!(pow2_floor(33), 32);
    }
}
