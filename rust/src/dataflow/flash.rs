//! FlashAttention-2 / FlashAttention-3 dataflows mapped onto the tile-based
//! accelerator (paper §III-A, Fig. 8 baselines).
//!
//! The mapping is the paper's: each tile is the analogue of an SM and
//! processes whole (batch, head, row-block) tasks independently — no
//! inter-tile communication, no inter-tile reuse, each tile streams the
//! full KV of its task from HBM (Algorithm 1).
//!
//! - FA-2: strictly serial per-tile schedule, single-buffered loads.
//! - FA-3: warp-specialization analogue — two concurrent tasks per tile and
//!   double-buffered K/V loads, plus a per-iteration scheduling/control
//!   overhead (the paper notes FA-3's "more sophisticated scheduling
//!   introduces non-negligible control overhead").

use crate::arch::config::ChipConfig;
use crate::arch::hbm;
use crate::arch::noc::{ChipResources, TileCoord};
use crate::arch::tile::{gemm_cycles, gemm_flops, vector_cycles, vector_flops, VectorOpKind};
use crate::dataflow::tiling::{l1_working_set_kv, Concurrency};
use crate::sim::{Category, Graph, Op, OpId};
use crate::workload::attention::AttentionShape;

/// FlashAttention generation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlashVersion {
    Fa2,
    Fa3,
}

impl FlashVersion {
    pub fn label(self) -> &'static str {
        match self {
            FlashVersion::Fa2 => "FA-2",
            FlashVersion::Fa3 => "FA-3",
        }
    }
}

/// Per-tile block size `M = Br = Bc` (Algorithm 1): the largest of
/// {32, 64, 128, 256} whose working set fits L1 (double-buffered for FA-3,
/// two concurrent tasks).
pub fn flash_block_size(cfg: &ChipConfig, shape: &AttentionShape, v: FlashVersion) -> u32 {
    // FA-3 is warp-specialized producer/consumer on ONE task: the same
    // block size as FA-2 with double-buffered K/V (not two concurrent
    // tasks — that would halve the feasible block and double HBM traffic).
    let (db, conc) = match v {
        FlashVersion::Fa2 => (false, Concurrency::Single),
        FlashVersion::Fa3 => (true, Concurrency::Single),
    };
    let kv_cols = shape.kv_row_bytes() / shape.dtype.bytes();
    let mut best = 32u32;
    for m in [32u32, 64, 128, 256] {
        let ws = l1_working_set_kv(
            m as u64,
            m as u64,
            shape.head_dim as u64,
            shape.v_head_dim as u64,
            kv_cols,
            shape.dtype,
            db,
            conc,
        );
        if ws.fits(&cfg.tile) {
            best = m;
        }
    }
    best
}

/// FA-3 per-inner-iteration control overhead (cycles): producer/consumer
/// synchronization of the asynchronous schedule.
const FA3_CONTROL_CYCLES: u64 = 64;

/// Build the FlashAttention graph for `shape` on `cfg`.
pub fn build(cfg: &ChipConfig, res: &ChipResources, shape: &AttentionShape, v: FlashVersion) -> Graph {
    let m = flash_block_size(cfg, shape, v) as u64;
    let rows = shape.effective_q_rows();
    let t_r = rows.div_ceil(m);
    let tasks = shape.independent_units() * t_r;
    let tiles = cfg.tiles() as u64;

    let mut g = Graph::new(res.table.clone());

    // Round-robin tasks over tiles, serial per tile; FA-3's intra-task
    // overlap comes from the double-buffered loads below.
    let lanes = 1u64;
    let _ = v;
    let mut tails: Vec<Vec<Option<OpId>>> = vec![vec![None; lanes as usize]; tiles as usize];
    for task in 0..tasks {
        let tile_i = (task % tiles) as u32;
        let lane = ((task / tiles) % lanes) as usize;
        let tile = TileCoord { x: tile_i % cfg.mesh_x, y: tile_i / cfg.mesh_x };
        let after = tails[tile_i as usize][lane];
        let done = build_task(&mut g, cfg, res, shape, v, tile, m, rows, after);
        tails[tile_i as usize][lane] = Some(done);
    }
    g
}

/// One (batch, head, row-block) task on `tile`; returns its completion op.
#[allow(clippy::too_many_arguments)]
fn build_task(
    g: &mut Graph,
    cfg: &ChipConfig,
    res: &ChipResources,
    shape: &AttentionShape,
    v: FlashVersion,
    tile: TileCoord,
    m: u64,
    rows: u64,
    after: Option<OpId>,
) -> OpId {
    let e = shape.dtype.bytes();
    let d = shape.head_dim as u64;
    let dv = shape.v_head_dim as u64;
    let br = m.min(rows);
    let kv = shape.seq_kv as u64;
    let t_c = kv.div_ceil(m);
    let double_buffer = v == FlashVersion::Fa3;

    let start = match after {
        Some(a) => a,
        None => g.join(&[]),
    };

    // Load Q block (line 5).
    let q_load = hbm::load(g, res, cfg, tile, br * d * e, &[start]);

    let mut frontier = q_load;
    let mut kv_gate = start;
    let mut kv_gate_prev = start;
    for j in 0..t_c {
        let bc = m.min(kv - j * m);
        // Load K_j, V_j (line 7; MLA: the shared latent once).
        let kv_load = hbm::load(g, res, cfg, tile, bc * shape.kv_row_bytes(), &[kv_gate]);

        // S = Q·Kᵀ (line 9) + softmax pieces (10–18).
        let mut deps: Vec<OpId> = vec![q_load, kv_load, frontier];
        if v == FlashVersion::Fa3 {
            let ctl = g.push(Op::new(None, FA3_CONTROL_CYCLES, Category::Sync), &[frontier]);
            deps.push(ctl);
        }
        let s_gemm = g.push(
            Op::new(Some(res.matrix(tile)), gemm_cycles(&cfg.tile, br, d, bc), Category::Gemm)
                .flops(gemm_flops(br, d, bc)),
            &deps,
        );
        let soft = [
            (VectorOpKind::RowMax, br, bc),
            (VectorOpKind::Exp, br, bc),
            (VectorOpKind::RowSum, br, bc),
            (VectorOpKind::StatsUpdate, br, 1),
            (VectorOpKind::Rescale, br, dv),
        ];
        let mut prev = s_gemm;
        for (kind, a, b) in soft {
            prev = g.push(
                Op::new(Some(res.vector(tile)), vector_cycles(&cfg.tile, kind, a, b), Category::Vector)
                    .flops(vector_flops(kind, a, b)),
                &[prev],
            );
        }
        // O += P̃·V (line 19).
        let pv = g.push(
            Op::new(Some(res.matrix(tile)), gemm_cycles(&cfg.tile, br, bc, dv), Category::Gemm)
                .flops(gemm_flops(br, bc, dv)),
            &[prev],
        );
        frontier = pv;
        if double_buffer {
            kv_gate = kv_gate_prev;
            kv_gate_prev = pv;
        } else {
            kv_gate = pv;
        }
    }

    // Final rescale (line 21) + store O (line 22).
    let fin = g.push(
        Op::new(Some(res.vector(tile)), vector_cycles(&cfg.tile, VectorOpKind::Rescale, br, dv), Category::Vector)
            .flops(vector_flops(VectorOpKind::Rescale, br, dv)),
        &[frontier],
    );
    hbm::store(g, res, cfg, tile, br * dv * e, &[fin])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::config::Dtype;
    use crate::metrics::KernelMetrics;

    fn sim(cfg: &ChipConfig, shape: &AttentionShape, v: FlashVersion) -> KernelMetrics {
        let res = ChipResources::new(cfg);
        let g = build(cfg, &res, shape, v);
        let r = g.simulate();
        KernelMetrics::from_sim(cfg, &r)
    }

    #[test]
    fn block_size_is_128_for_d128_fp16() {
        let cfg = ChipConfig::table1();
        let s = AttentionShape::mha_prefill(2, 32, 128, 4096, Dtype::Fp16);
        assert_eq!(flash_block_size(&cfg, &s, FlashVersion::Fa2), 128);
        // FA-3 double-buffers K/V within the same block size.
        assert_eq!(flash_block_size(&cfg, &s, FlashVersion::Fa3), 128);
    }

    #[test]
    fn fa2_runs_and_counts_traffic() {
        let cfg = ChipConfig::tiny(4);
        let s = AttentionShape::mha_prefill(1, 8, 64, 512, Dtype::Fp16);
        let m = sim(&cfg, &s, FlashVersion::Fa2);
        assert!(m.cycles > 0);
        let blk = flash_block_size(&cfg, &s, FlashVersion::Fa2) as u64;
        // IO model: Q+O once per task, KV re-read per row-block.
        let expect = s.flash_io_bytes(blk as u32);
        let err = (m.hbm_bytes as f64 - expect as f64).abs() / expect as f64;
        assert!(err < 0.05, "sim {} model {expect}", m.hbm_bytes);
    }

    #[test]
    fn fa3_overlap_hides_hbm_but_gains_little() {
        // Paper Fig. 8: on the tile accelerator FA-3's async schedule hides
        // loads behind compute, but smaller L1-feasible blocks + control
        // overhead leave it within a few percent of FA-2 overall.
        let mut cfg = ChipConfig::tiny(4);
        cfg.hbm.total_bandwidth_bytes_per_s = 4.0e12;
        let s = AttentionShape::mha_prefill(2, 8, 128, 1024, Dtype::Fp16);
        let a2 = sim(&cfg, &s, FlashVersion::Fa2);
        let a3 = sim(&cfg, &s, FlashVersion::Fa3);
        // Exposed (non-overlapped) HBM time shrinks under FA-3.
        assert!(a3.exposed[2] <= a2.exposed[2], "fa3 hbm {} fa2 hbm {}", a3.exposed[2], a2.exposed[2]);
        // Total runtime within ±20% of FA-2.
        let ratio = a3.cycles as f64 / a2.cycles as f64;
        assert!(ratio < 1.2, "fa3/fa2 ratio {ratio}");
    }

    #[test]
    fn flash_has_no_noc_traffic() {
        let cfg = ChipConfig::tiny(4);
        let s = AttentionShape::mha_prefill(1, 4, 64, 256, Dtype::Fp16);
        let m = sim(&cfg, &s, FlashVersion::Fa2);
        assert_eq!(m.noc_bytes, 0);
    }

    #[test]
    fn flash_is_memory_bound_on_table1() {
        // Paper Fig. 8: FlashAttention on the tile accelerator is strongly
        // memory-bound with HBM BW utilization up to ~80%.
        let cfg = ChipConfig::table1();
        let s = AttentionShape::mha_prefill(2, 32, 128, 2048, Dtype::Fp16);
        let m = sim(&cfg, &s, FlashVersion::Fa2);
        assert!(m.hbm_bw_utilization > 0.5, "bw {}", m.hbm_bw_utilization);
        assert!(m.compute_utilization < 0.5, "compute {}", m.compute_utilization);
    }
}
