//! SUMMA GEMM dataflow on the tile mesh (paper §III-E, Fig. 5a): stationary
//! C blocks, K-loop with row-wise multicast of A panels and column-wise
//! multicast of B panels, both fetched from HBM by the *diagonal* tiles to
//! avoid memory-controller conflicts on shared NoC links.
//!
//! Decode-time projections are skinny (few activation rows against a large
//! weight): a plain 2D SUMMA would leave most CE rows idle. Following the
//! same utilization-first principle as the attention tiling strategy
//! (Fig. 10), the mesh is split into `pm` M-partitions × `k_split` K-
//! partitions along the Y axis (`pm·k_split = mesh_y`); each K-partition
//! computes a partial C, combined at the end with a column-wise fabric
//! reduction.

use crate::arch::collective::{multicast, reduce, Axis, CollectiveImpl};
use crate::arch::config::{ChipConfig, Dtype};
use crate::arch::hbm;
use crate::arch::noc::{ChipResources, TileCoord};
use crate::arch::tile::{gemm_cycles, gemm_flops};
use crate::sim::{Graph, OpId};

/// SUMMA schedule parameters.
#[derive(Debug, Clone, Copy)]
pub struct SummaParams {
    /// K-panel depth per iteration.
    pub kb: u32,
    /// M-partitions along the mesh Y axis (`mesh_y / pm` = K-split degree).
    pub pm: u32,
    pub collective: CollectiveImpl,
    pub double_buffer: bool,
}

impl SummaParams {
    pub fn auto(cfg: &ChipConfig, m: u64, k: u64, n: u64, dtype: Dtype) -> Self {
        // M-partitions: enough rows of tiles that each holds ≥ ce_rows rows
        // of C, the rest of the Y axis splits K. Power of two to tile the
        // mesh.
        let want_pm = m.div_ceil(cfg.tile.ce_rows as u64).min(cfg.mesh_y as u64) as u32;
        let mut pm = 1u32;
        while pm * 2 <= want_pm && pm * 2 <= cfg.mesh_y {
            pm *= 2;
        }
        let k_split = (cfg.mesh_y / pm).max(1);
        let m_t = m.div_ceil(pm as u64);
        let n_t = n.div_ceil(cfg.mesh_x as u64);
        let k_local = k.div_ceil(k_split as u64);
        // Largest kb (multiple of 64) with double-buffered A/B panels plus
        // the C accumulator within L1.
        let l1 = cfg.tile.l1_kib * 1024;
        let mut kb = 64u64.min(k_local.max(1));
        for cand in [64u64, 128, 256, 512, 1024] {
            if cand > k_local {
                break;
            }
            let ws = 2 * (m_t * cand + cand * n_t) * dtype.bytes() + m_t * n_t * 4;
            if ws <= l1 {
                kb = cand;
            }
        }
        SummaParams { kb: kb as u32, pm, collective: CollectiveImpl::Hw, double_buffer: true }
    }

    pub fn k_split(&self, cfg: &ChipConfig) -> u32 {
        (cfg.mesh_y / self.pm).max(1)
    }
}

/// Build a SUMMA GEMM (C[m×n] = A[m×k]·B[k×n]) over the whole mesh.
/// `batch` instances run back-to-back (per-head / per-expert GEMMs).
#[allow(clippy::too_many_arguments)]
pub fn build(
    cfg: &ChipConfig,
    res: &ChipResources,
    m: u64,
    k: u64,
    n: u64,
    batch: u64,
    dtype: Dtype,
    p: &SummaParams,
) -> Graph {
    let mut g = Graph::new(res.table.clone());
    let mut tail: Option<OpId> = None;
    for _ in 0..batch {
        tail = Some(build_one(&mut g, cfg, res, m, k, n, dtype, p, tail));
    }
    g
}

#[allow(clippy::too_many_arguments)]
fn build_one(
    g: &mut Graph,
    cfg: &ChipConfig,
    res: &ChipResources,
    m: u64,
    k: u64,
    n: u64,
    dtype: Dtype,
    p: &SummaParams,
    after: Option<OpId>,
) -> OpId {
    let e = dtype.bytes();
    let mx = cfg.mesh_x;
    let my = cfg.mesh_y;
    let pm = p.pm.min(my);
    let k_split = (my / pm).max(1);
    let m_t = m.div_ceil(pm as u64);
    let n_t = n.div_ceil(mx as u64);
    let k_local = k.div_ceil(k_split as u64);
    let kb = (p.kb as u64).min(k_local.max(1));
    let t_k = k_local.div_ceil(kb);

    let start = match after {
        Some(a) => a,
        None => g.join(&[]),
    };

    let nt = (mx * my) as usize;
    let mut frontier: Vec<OpId> = vec![start; nt];
    let mut a_gate: Vec<OpId> = vec![start; my as usize];
    let mut a_gate_prev: Vec<OpId> = vec![start; my as usize];
    let mut b_gate: Vec<OpId> = vec![start; mx as usize];
    let mut b_gate_prev: Vec<OpId> = vec![start; mx as usize];
    let idx = |x: u32, y: u32| (y * mx + x) as usize;

    for _kk in 0..t_k {
        // A panels: each mesh row holds a distinct (m_part, k_part); its
        // diagonal tile loads m_t×kb and multicasts row-wise.
        let mut a_ready: Vec<OpId> = Vec::with_capacity(my as usize);
        for y in 0..my {
            let diag = TileCoord { x: y % mx, y };
            let load = hbm::load(g, res, cfg, diag, m_t * kb * e, &[a_gate[y as usize]]);
            let mc = multicast(g, res, cfg, p.collective, Axis::Row, y, mx, m_t * kb * e, &[load]);
            a_ready.push(mc);
        }
        // B panels: per column and per K-partition, the partition's diagonal
        // tile loads kb×n_t and multicasts over the partition's pm rows.
        let mut b_ready: Vec<Vec<OpId>> = vec![Vec::with_capacity(k_split as usize); mx as usize];
        for x in 0..mx {
            let mut gate = b_gate[x as usize];
            for kp in 0..k_split {
                let diag = TileCoord { x, y: kp * pm + (x % pm) };
                let load = hbm::load(g, res, cfg, diag, kb * n_t * e, &[gate]);
                let mc = multicast(g, res, cfg, p.collective, Axis::Col, x, pm, kb * n_t * e, &[load]);
                b_ready[x as usize].push(mc);
                gate = load; // serialize this column's partition loads
            }
        }
        // Rank-kb update on every tile.
        for y in 0..my {
            let kp = (y / pm) as usize;
            for x in 0..mx {
                let tile = TileCoord { x, y };
                let gemm = g.push(
                    crate::sim::Op::new(
                        Some(res.matrix(tile)),
                        gemm_cycles(&cfg.tile, m_t, kb, n_t),
                        crate::sim::Category::Gemm,
                    )
                    .flops(gemm_flops(m_t, kb, n_t)),
                    &[a_ready[y as usize], b_ready[x as usize][kp], frontier[idx(x, y)]],
                );
                frontier[idx(x, y)] = gemm;
            }
        }
        // Panel buffer turnover.
        for y in 0..my {
            let consumers: Vec<OpId> = (0..mx).map(|x| frontier[idx(x, y)]).collect();
            let free = g.join(&consumers);
            if p.double_buffer {
                a_gate[y as usize] = a_gate_prev[y as usize];
                a_gate_prev[y as usize] = free;
            } else {
                a_gate[y as usize] = free;
            }
        }
        for x in 0..mx {
            let consumers: Vec<OpId> = (0..my).map(|y| frontier[idx(x, y)]).collect();
            let free = g.join(&consumers);
            if p.double_buffer {
                b_gate[x as usize] = b_gate_prev[x as usize];
                b_gate_prev[x as usize] = free;
            } else {
                b_gate[x as usize] = free;
            }
        }
    }

    // Combine K-partitions (column-wise fabric reduction of fp32 partials)
    // and store C from the owning (first-partition) tiles.
    let mut stores: Vec<OpId> = Vec::new();
    for x in 0..mx {
        for yp in 0..pm {
            let dst = TileCoord { x, y: yp };
            let red = if k_split > 1 {
                let deps: Vec<OpId> = (0..k_split).map(|kp| frontier[idx(x, kp * pm + yp)]).collect();
                let joined = g.join(&deps);
                reduce(
                    g,
                    res,
                    cfg,
                    p.collective,
                    Axis::Col,
                    x,
                    k_split,
                    dst,
                    m_t * n_t * 4,
                    Dtype::Fp32,
                    &[joined],
                )
            } else {
                frontier[idx(x, yp)]
            };
            let s = hbm::store(g, res, cfg, dst, m_t * n_t * e, &[red]);
            stores.push(s);
        }
    }
    g.join(&stores)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::KernelMetrics;

    fn sim(cfg: &ChipConfig, m: u64, k: u64, n: u64, dtype: Dtype) -> KernelMetrics {
        let res = ChipResources::new(cfg);
        let p = SummaParams::auto(cfg, m, k, n, dtype);
        let g = build(cfg, &res, m, k, n, 1, dtype, &p);
        let r = g.simulate();
        KernelMetrics::from_sim(cfg, &r)
    }

    #[test]
    fn square_gemm_high_utilization() {
        let cfg = ChipConfig::tiny(4);
        // 512×1024×512 over 4×4 mesh: 128×kb×128 per tile — efficient.
        let m = sim(&cfg, 512, 1024, 512, Dtype::Fp16);
        assert!(m.compute_utilization > 0.4, "util {}", m.compute_utilization);
    }

    #[test]
    fn flops_exact() {
        let cfg = ChipConfig::tiny(4);
        let res = ChipResources::new(&cfg);
        let p = SummaParams::auto(&cfg, 256, 512, 256, Dtype::Fp16);
        let g = build(&cfg, &res, 256, 512, 256, 1, Dtype::Fp16, &p);
        let r = g.simulate();
        // GEMM flops exact; SW reduction adds none (HW collective in-fabric).
        assert_eq!(
            r.flops,
            2 * 256u64.div_ceil(p.pm as u64) * p.pm as u64 * 512 * 256,
        );
    }

    #[test]
    fn skinny_gemm_splits_k() {
        // Decode projection: m = 64 rows against a 7168×2048 weight. The
        // K-split keeps the matrix engines fed instead of idling 30/32 CE
        // rows.
        let cfg = ChipConfig::table1();
        let p = SummaParams::auto(&cfg, 64, 7168, 2048, Dtype::Fp8);
        assert!(p.pm <= 2, "pm {}", p.pm);
        assert!(p.k_split(&cfg) >= 16);
        let m = sim(&cfg, 64, 7168, 2048, Dtype::Fp8);
        // Weight streaming dominates: memory-bound with decent BW use.
        assert!(m.hbm_bw_utilization > 0.3, "bw {}", m.hbm_bw_utilization);
        assert!(m.compute_utilization < 0.45, "util {}", m.compute_utilization);
    }

    #[test]
    fn k_split_beats_naive_for_skinny() {
        let cfg = ChipConfig::tiny(8);
        let res = ChipResources::new(&cfg);
        let auto = SummaParams::auto(&cfg, 32, 4096, 1024, Dtype::Fp16);
        assert!(auto.pm < cfg.mesh_y, "auto should split K");
        let naive = SummaParams { pm: cfg.mesh_y, ..auto };
        let fast = build(&cfg, &res, 32, 4096, 1024, 1, Dtype::Fp16, &auto).simulate().makespan;
        let slow = build(&cfg, &res, 32, 4096, 1024, 1, Dtype::Fp16, &naive).simulate().makespan;
        assert!(fast < slow, "k-split {fast} vs naive {slow}");
    }

    #[test]
    fn batch_serializes() {
        let cfg = ChipConfig::tiny(4);
        let res = ChipResources::new(&cfg);
        let p = SummaParams::auto(&cfg, 128, 256, 128, Dtype::Fp16);
        let g1 = build(&cfg, &res, 128, 256, 128, 1, Dtype::Fp16, &p);
        let c1 = g1.simulate().makespan;
        let g2 = build(&cfg, &res, 128, 256, 128, 4, Dtype::Fp16, &p);
        let c2 = g2.simulate().makespan;
        assert!(c2 > 3 * c1, "c1 {c1} c2 {c2}");
    }

    #[test]
    fn kb_fits_l1() {
        let cfg = ChipConfig::table1();
        let p = SummaParams::auto(&cfg, 512, 7168, 2048, Dtype::Fp8);
        let m_t = 512u64.div_ceil(p.pm as u64);
        let n_t = 2048u64 / 32;
        let ws = 2 * (m_t * p.kb as u64 + p.kb as u64 * n_t) + m_t * n_t * 4;
        assert!(ws <= cfg.tile.l1_kib * 1024);
        assert!(p.kb >= 128);
    }
}
