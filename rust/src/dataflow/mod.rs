//! Dataflow schedulers: lowering of attention / GEMM kernels onto the tile
//! architecture, in two fidelities:
//!
//! - [`SimFidelity::Full`] — build the op DAG and run the discrete-event
//!   simulator (used for all paper figures).
//! - [`SimFidelity::Analytic`] — closed-form composition of the same per-op
//!   cost models (chip-level overlap algebra), used for the large wafer-
//!   scale sweeps; pinned against the DES by tests.

pub mod flash;
pub mod flat;
pub mod summa;
pub mod tiling;

use crate::arch::collective::{multicast_latency_cycles, reduce_latency_cycles};
use crate::arch::config::{ChipConfig, Dtype, SimFidelity};
use crate::arch::noc::ChipResources;
use crate::arch::tile::{gemm_cycles, vector_cycles, VectorOpKind};
use crate::metrics::KernelMetrics;
use crate::workload::attention::AttentionShape;
use crate::workload::deepseek::KernelClass;

pub use flash::FlashVersion;
pub use flat::FlatParams;
pub use tiling::{choose_tiling, FlatTiling};

/// Which attention dataflow to run.
#[derive(Debug, Clone, Copy)]
pub enum AttentionDataflow {
    Fa2,
    Fa3,
    Flat(FlatParams),
}

impl AttentionDataflow {
    pub fn label(&self) -> String {
        match self {
            AttentionDataflow::Fa2 => "FA-2".into(),
            AttentionDataflow::Fa3 => "FA-3".into(),
            AttentionDataflow::Flat(p) => p.label(),
        }
    }

    /// The paper's best configuration for a shape (FlatAsync + Fig. 10).
    pub fn auto_flat(cfg: &ChipConfig, shape: &AttentionShape) -> Self {
        AttentionDataflow::Flat(FlatParams::auto(cfg, shape))
    }
}

/// Simulate one attention kernel on one chip.
pub fn simulate_attention(
    cfg: &ChipConfig,
    shape: &AttentionShape,
    df: AttentionDataflow,
    fidelity: SimFidelity,
) -> KernelMetrics {
    match fidelity {
        SimFidelity::Full => {
            let res = ChipResources::new(cfg);
            let g = match df {
                AttentionDataflow::Fa2 => flash::build(cfg, &res, shape, FlashVersion::Fa2),
                AttentionDataflow::Fa3 => flash::build(cfg, &res, shape, FlashVersion::Fa3),
                AttentionDataflow::Flat(p) => flat::build(cfg, &res, shape, &p),
            };
            let r = g.simulate();
            KernelMetrics::from_sim(cfg, &r)
        }
        SimFidelity::Analytic => match df {
            AttentionDataflow::Fa2 => analytic_flash(cfg, shape, FlashVersion::Fa2),
            AttentionDataflow::Fa3 => analytic_flash(cfg, shape, FlashVersion::Fa3),
            AttentionDataflow::Flat(p) => analytic_flat(cfg, shape, &p),
        },
    }
}

/// Simulate a (possibly batched) GEMM kernel via SUMMA.
#[allow(clippy::too_many_arguments)]
pub fn simulate_gemm(
    cfg: &ChipConfig,
    m: u64,
    k: u64,
    n: u64,
    batch: u64,
    dtype: Dtype,
    fidelity: SimFidelity,
) -> KernelMetrics {
    match fidelity {
        SimFidelity::Full => {
            let res = ChipResources::new(cfg);
            let p = summa::SummaParams::auto(cfg, m, k, n, dtype);
            let g = summa::build(cfg, &res, m, k, n, batch, dtype, &p);
            let r = g.simulate();
            KernelMetrics::from_sim(cfg, &r)
        }
        SimFidelity::Analytic => analytic_gemm(cfg, m, k, n, batch, dtype),
    }
}

/// Simulate a vector kernel (norms / rope / activations): row-parallel over
/// tiles, streaming from HBM.
pub fn simulate_vector(cfg: &ChipConfig, elems: u64) -> KernelMetrics {
    let tiles = cfg.tiles() as u64;
    let per_tile = elems.div_ceil(tiles);
    let compute = vector_cycles(&cfg.tile, VectorOpKind::Elementwise, 1, per_tile);
    // Stream in+out through HBM at 2 bytes/elem.
    let bytes = 2 * elems * 2;
    let hbm = (bytes as f64 / cfg.hbm_bytes_per_cycle()).ceil() as u64;
    let cycles = compute.max(hbm) + cfg.hbm.latency_cycles;
    synth_metrics(cfg, cycles, elems, bytes, 0, 0.0)
}

/// Simulate any [`KernelClass`] with the given attention dataflow choice.
pub fn simulate_kernel(
    cfg: &ChipConfig,
    class: &KernelClass,
    attn_df: impl Fn(&AttentionShape) -> AttentionDataflow,
    fidelity: SimFidelity,
) -> KernelMetrics {
    match class {
        KernelClass::Gemm { m, k, n, batch } => simulate_gemm(cfg, *m, *k, *n, *batch, Dtype::Fp8, fidelity),
        KernelClass::Attention(s) => simulate_attention(cfg, s, attn_df(s), fidelity),
        KernelClass::Vector { elems } => simulate_vector(cfg, *elems),
    }
}

// ---------------------------------------------------------------------------
// Analytic models (chip-level overlap algebra over the same cost models).
// ---------------------------------------------------------------------------

fn synth_metrics(cfg: &ChipConfig, cycles: u64, flops: u64, hbm_bytes: u64, noc_bytes: u64, matrix_util: f64) -> KernelMetrics {
    let seconds = cfg.cycles_to_seconds(cycles);
    let tflops = if seconds > 0.0 { flops as f64 / seconds / 1e12 } else { 0.0 };
    KernelMetrics {
        cycles,
        seconds,
        tflops,
        compute_utilization: if seconds > 0.0 { tflops * 1e12 / cfg.peak_flops() } else { 0.0 },
        hbm_bw_utilization: if cycles > 0 {
            (hbm_bytes as f64 / (cycles as f64 * cfg.hbm_bytes_per_cycle())).min(1.0)
        } else {
            0.0
        },
        hbm_bytes,
        noc_bytes,
        matrix_utilization_active: matrix_util,
        matrix_efficiency_active: matrix_util,
        exposed: [0; 5],
    }
}

/// Per-inner-iteration vector (softmax) cycles of the flash/flat kernels.
fn softmax_iter_cycles(cfg: &ChipConfig, br: u64, bc: u64, dv: u64) -> u64 {
    vector_cycles(&cfg.tile, VectorOpKind::RowMax, br, bc)
        + vector_cycles(&cfg.tile, VectorOpKind::Exp, br, bc)
        + vector_cycles(&cfg.tile, VectorOpKind::RowSum, br, bc)
        + vector_cycles(&cfg.tile, VectorOpKind::StatsUpdate, br, 1)
        + vector_cycles(&cfg.tile, VectorOpKind::Rescale, br, dv)
}

fn analytic_flash(cfg: &ChipConfig, shape: &AttentionShape, v: FlashVersion) -> KernelMetrics {
    let m = flash::flash_block_size(cfg, shape, v) as u64;
    let e = shape.dtype.bytes();
    let d = shape.head_dim as u64;
    let dv = shape.v_head_dim as u64;
    let rows = shape.effective_q_rows();
    let br = m.min(rows);
    let kv = shape.seq_kv as u64;
    let t_c = kv.div_ceil(m);
    let t_r = rows.div_ceil(m);
    let tasks = shape.independent_units() * t_r;
    let tiles = cfg.tiles() as u64;
    let rounds = tasks.div_ceil(tiles);

    let bc = m.min(kv);
    let gemm_iter = gemm_cycles(&cfg.tile, br, d, bc) + gemm_cycles(&cfg.tile, br, bc, dv);
    let t_matrix = rounds * t_c * gemm_iter;
    let t_vector = rounds * t_c * softmax_iter_cycles(cfg, br, m.min(kv), dv);
    let io = shape.flash_io_bytes(m as u32);
    let t_hbm = (io as f64 / cfg.hbm_bytes_per_cycle()).ceil() as u64;
    let fill = cfg.hbm.latency_cycles + cfg.tile.gemm_setup_cycles;

    let flops = 2 * shape.independent_units() * rows * kv * (d + dv);
    // A task's own K/V load serializes with its compute (single-buffered),
    // but other tasks keep the channels busy meanwhile — so the chip is
    // bound by the slower of (per-tile serial chain, aggregate HBM).
    let own_occ = ((bc * (d + dv) * e) as f64 / cfg.hbm_channel_bytes_per_cycle()).ceil() as u64
        + cfg.hbm.latency_cycles;
    let cycles = match v {
        FlashVersion::Fa2 => t_hbm.max(t_matrix + t_vector + rounds * t_c * own_occ) + fill,
        // FA-3: double-buffered loads hide behind the (still serial)
        // compute + softmax chain; control overhead per iteration.
        FlashVersion::Fa3 => {
            let ctl = rounds * t_c * 64;
            t_hbm.max(t_matrix + t_vector + ctl) + fill
        }
    };
    let util = if cycles > 0 { flops as f64 / (cycles as f64 * cfg.peak_flops_per_cycle() as f64) } else { 0.0 };
    synth_metrics(cfg, cycles, flops, io, 0, util.min(1.0))
}

fn analytic_flat(cfg: &ChipConfig, shape: &AttentionShape, p: &FlatParams) -> KernelMetrics {
    let t = p.tiling;
    let e = shape.dtype.bytes();
    let d = shape.head_dim as u64;
    let dv = shape.v_head_dim as u64;
    let br = t.slice_r as u64;
    let bc = t.slice_c as u64;
    let rows = shape.effective_q_rows();
    let kv = shape.seq_kv as u64;
    let t_r = rows.div_ceil(t.block_r());
    let t_c = kv.div_ceil(t.block_c());
    let groups = ((cfg.mesh_x / t.gx) * (cfg.mesh_y / t.gy)) as u64;
    let units = shape.independent_units();
    let units_per_group = units.div_ceil(groups);
    let iters = units_per_group * t_r * t_c;

    // Per-tile engine totals.
    let gemm_iter = gemm_cycles(&cfg.tile, br, d, bc) + gemm_cycles(&cfg.tile, br, bc, dv);
    let t_matrix = iters * gemm_iter;
    let t_vector = iters * softmax_iter_cycles(cfg, br, bc, dv)
        + units_per_group * t_r * vector_cycles(&cfg.tile, VectorOpKind::Rescale, br, dv);

    // NoC per row/column path.
    let stat_bytes = br * 4;
    let kv_mcast = multicast_latency_cycles(cfg, p.collective, t.gy, bc * shape.kv_row_bytes());
    let stats_coll = 2
        * (reduce_latency_cycles(cfg, p.collective, t.gx, stat_bytes, shape.dtype)
            + multicast_latency_cycles(cfg, p.collective, t.gx, stat_bytes));
    let epilogue = reduce_latency_cycles(cfg, p.collective, t.gx, br * dv * e, shape.dtype)
        + multicast_latency_cycles(cfg, p.collective, t.gx, br * d * e);
    let t_noc = iters * (kv_mcast + stats_coll) + units_per_group * t_r * epilogue;

    // Chip-level HBM.
    let io = shape.io_bytes_with_flattening(t.slice_c.max(t.slice_r), t.gx.min(t.gy).max(1));
    let io = io.max(shape.ideal_io_bytes());
    let t_hbm = (io as f64 / cfg.hbm_bytes_per_cycle()).ceil() as u64;

    let fill = cfg.hbm.latency_cycles + cfg.tile.gemm_setup_cycles + kv_mcast;
    let flops = 2 * shape.independent_units() * rows * kv * (d + dv);

    let cycles = if p.async_two_heads {
        // Everything overlaps; the slowest engine class dominates. Vector
        // work and collectives stay serialized with each other (the softmax
        // reductions depend on the vector partials).
        t_matrix.max(t_hbm).max(t_vector + t_noc) + fill
    } else if p.double_buffer {
        // Loads overlap compute; softmax + collectives serialize with GEMM.
        t_hbm.max(t_matrix + t_vector + t_noc) + fill
    } else {
        t_hbm + t_matrix + t_vector + t_noc + fill
    };
    let util = if cycles > 0 { flops as f64 / (cycles as f64 * cfg.peak_flops_per_cycle() as f64) } else { 0.0 };
    synth_metrics(cfg, cycles, flops, io, iters * bc * shape.kv_row_bytes() * t.gx as u64, util.min(1.0))
}

fn analytic_gemm(cfg: &ChipConfig, m: u64, k: u64, n: u64, batch: u64, dtype: Dtype) -> KernelMetrics {
    let p = summa::SummaParams::auto(cfg, m, k, n, dtype);
    let e = dtype.bytes();
    let pm = p.pm.min(cfg.mesh_y) as u64;
    let k_split = p.k_split(cfg) as u64;
    let m_t = m.div_ceil(pm);
    let n_t = n.div_ceil(cfg.mesh_x as u64);
    let k_local = k.div_ceil(k_split);
    let kb = (p.kb as u64).min(k_local.max(1));
    let t_k = k_local.div_ceil(kb);

    let t_matrix = t_k * gemm_cycles(&cfg.tile, m_t, kb, n_t);
    let io = (m * k + k * n + m * n) * e;
    let t_hbm = (io as f64 / cfg.hbm_bytes_per_cycle()).ceil() as u64;
    // Per-path collective occupancy: a row path carries the A multicast; a
    // column path carries k_split B multicasts per iteration, plus the final
    // pm partial-C reductions.
    let t_noc_iter = multicast_latency_cycles(cfg, p.collective, cfg.mesh_x, m_t * kb * e)
        .max(k_split * multicast_latency_cycles(cfg, p.collective, pm as u32, kb * n_t * e));
    let t_reduce = if k_split > 1 {
        pm * reduce_latency_cycles(cfg, p.collective, k_split as u32, m_t * n_t * 4, Dtype::Fp32)
    } else {
        0
    };
    let t_noc = t_k * t_noc_iter;
    let fill = cfg.hbm.latency_cycles + cfg.tile.gemm_setup_cycles;

    // The K-combine reduction is a serial tail after the last iteration.
    let per_instance = t_matrix.max(t_hbm).max(t_noc) + t_reduce + fill;
    let cycles = per_instance * batch;
    let flops = 2 * m * k * n * batch;
    let util = if cycles > 0 { flops as f64 / (cycles as f64 * cfg.peak_flops_per_cycle() as f64) } else { 0.0 };
    synth_metrics(cfg, cycles, flops, io * batch, 0, util.min(1.0))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn analytic_flat_tracks_des() {
        let cfg = ChipConfig::tiny(8);
        let shape = AttentionShape::mha_prefill(2, 8, 64, 1024, Dtype::Fp16);
        let t = FlatTiling { gx: 8, gy: 8, slice_r: 128, slice_c: 128 };
        for p in [FlatParams::flat_hc(t), FlatParams::flat_async(t)] {
            let full = simulate_attention(&cfg, &shape, AttentionDataflow::Flat(p), SimFidelity::Full);
            let ana = simulate_attention(&cfg, &shape, AttentionDataflow::Flat(p), SimFidelity::Analytic);
            let err = (full.cycles as f64 - ana.cycles as f64).abs() / full.cycles as f64;
            assert!(err < 0.35, "{}: full {} ana {}", p.label(), full.cycles, ana.cycles);
        }
    }

    #[test]
    fn analytic_flash_tracks_des() {
        let cfg = ChipConfig::tiny(8);
        let shape = AttentionShape::mha_prefill(2, 8, 64, 1024, Dtype::Fp16);
        for v in [AttentionDataflow::Fa2, AttentionDataflow::Fa3] {
            let full = simulate_attention(&cfg, &shape, v, SimFidelity::Full);
            let ana = simulate_attention(&cfg, &shape, v, SimFidelity::Analytic);
            let err = (full.cycles as f64 - ana.cycles as f64).abs() / full.cycles as f64;
            // At full HBM-channel saturation the DES exhibits convoy
            // (head-of-line) effects the closed form cannot capture; the
            // analytic path is a lower-bound-style estimate there.
            assert!(err < 0.45, "{}: full {} ana {}", v.label(), full.cycles, ana.cycles);
        }
    }

    #[test]
    fn analytic_gemm_tracks_des() {
        let cfg = ChipConfig::tiny(8);
        let full = simulate_gemm(&cfg, 1024, 2048, 1024, 1, Dtype::Fp16, SimFidelity::Full);
        let ana = simulate_gemm(&cfg, 1024, 2048, 1024, 1, Dtype::Fp16, SimFidelity::Analytic);
        let err = (full.cycles as f64 - ana.cycles as f64).abs() / full.cycles as f64;
        assert!(err < 0.35, "full {} ana {}", full.cycles, ana.cycles);
    }

    #[test]
    fn vector_kernel_scales_with_elems() {
        let cfg = ChipConfig::table1();
        let a = simulate_vector(&cfg, 1 << 16);
        let b = simulate_vector(&cfg, 1 << 22);
        assert!(b.cycles > a.cycles);
    }
}
