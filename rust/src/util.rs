//! Small shared utilities: deterministic RNG (SplitMix64) for synthetic
//! tensors, byte formatting, and the process-wide worker-thread budget.

use std::sync::OnceLock;

/// Process-wide worker-thread budget, shared by everything that fans work
/// out across `std::thread` (the sharded fleet engine, the parallel batch
/// sweeps). One knob, three sources in priority order: the `--threads` CLI
/// flag (via [`set_worker_threads`]), the `FLATATTENTION_THREADS`
/// environment variable, then `std::thread::available_parallelism()`.
static WORKER_THREADS: OnceLock<usize> = OnceLock::new();

/// Pin the worker-thread budget (the `--threads` CLI flag). First caller
/// wins; later calls are ignored — the budget is process-global and read
/// from many places, so it must not change mid-run. Clamped to ≥ 1.
pub fn set_worker_threads(n: usize) {
    let _ = WORKER_THREADS.set(n.max(1));
}

/// The worker-thread budget: the pinned value if [`set_worker_threads`]
/// ran, else `FLATATTENTION_THREADS` (parsed, ≥ 1), else the machine's
/// available parallelism (1 when unknown). Thread counts never affect
/// results — every parallel consumer is bit-identical at any budget — so
/// this is purely a wall-clock/footprint control.
pub fn worker_threads() -> usize {
    if let Some(&n) = WORKER_THREADS.get() {
        return n;
    }
    if let Ok(v) = std::env::var("FLATATTENTION_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// SplitMix64: deterministic, seedable, dependency-free.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [-1, 1).
    pub fn next_f32_signed(&mut self) -> f32 {
        (2.0 * self.next_f64() - 1.0) as f32
    }

    pub fn next_range(&mut self, n: u64) -> u64 {
        self.next_u64() % n.max(1)
    }
}

/// Format a byte count with binary units.
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.1} {}", UNITS[u])
    }
}

/// Format a cycle count with thousands separators.
pub fn fmt_cycles(c: u64) -> String {
    let s = c.to_string();
    let mut out = String::new();
    for (i, ch) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(ch);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn splitmix_f64_in_range() {
        let mut r = SplitMix64::new(7);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            let y = r.next_f32_signed();
            assert!((-1.0..1.0).contains(&y));
        }
    }

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.0 KiB");
        assert_eq!(fmt_bytes(5 << 20), "5.0 MiB");
    }

    #[test]
    fn cycles_formatting() {
        assert_eq!(fmt_cycles(1234567), "1,234,567");
        assert_eq!(fmt_cycles(12), "12");
    }
}
