//! Wafer-scale sweep drivers: the batch sweeps and EP-degree comparisons of
//! paper Fig. 13 and the Table II operating points.

use crate::arch::config::SimFidelity;
use crate::multichip::d2d::WaferSystem;
use crate::multichip::parallelism::{
    AttentionChoice, DecodeEvaluator, DecodeOutcome, KernelCache, ParallelismPlan,
};
use crate::workload::deepseek::DeepSeekConfig;

/// The batch-per-chip sweep of Fig. 13a/13c.
pub const BATCH_SWEEP: [u32; 7] = [8, 16, 32, 64, 128, 256, 512];

/// The EP-degree plans of Fig. 13c on a 64-chip wafer.
pub fn ep_plans() -> Vec<ParallelismPlan> {
    vec![
        ParallelismPlan::new(1, 64),
        ParallelismPlan::new(8, 8),
        ParallelismPlan::new(16, 4),
        ParallelismPlan::new(32, 2),
        ParallelismPlan::new(64, 1),
    ]
}

/// Sweep batch sizes for one plan/dataflow (Fig. 13a series).
pub fn batch_sweep(
    sys: &WaferSystem,
    ds: &DeepSeekConfig,
    plan: ParallelismPlan,
    kv_len: u32,
    choice: AttentionChoice,
    fidelity: SimFidelity,
) -> Vec<DecodeOutcome> {
    batch_sweep_cached(sys, ds, plan, kv_len, choice, fidelity, KernelCache::new())
}

/// [`batch_sweep`] backed by a caller-supplied kernel cache, so repeated or
/// overlapping sweeps (Fig. 13a's two series, Fig. 13c's five plans, the
/// serving simulator's stage-time probes) never re-simulate an identical
/// (plan, batch, kv_len) kernel.
#[allow(clippy::too_many_arguments)]
pub fn batch_sweep_cached(
    sys: &WaferSystem,
    ds: &DeepSeekConfig,
    plan: ParallelismPlan,
    kv_len: u32,
    choice: AttentionChoice,
    fidelity: SimFidelity,
    cache: KernelCache,
) -> Vec<DecodeOutcome> {
    let mut ev = DecodeEvaluator::with_cache(fidelity, cache);
    BATCH_SWEEP.iter().map(|&b| ev.evaluate(sys, ds, plan, b, kv_len, choice)).collect()
}

/// Run several independent sweep series concurrently on a pool of
/// `std::thread` workers sharing one kernel cache. The pool size follows
/// the process-wide [`crate::util::worker_threads`] budget (the
/// `--threads`/`FLATATTENTION_THREADS` knob, same as the sharded fleet
/// engine) capped at `specs.len()`. Results come back in `specs` order,
/// and each series is identical to what a sequential [`batch_sweep`]
/// produces (the cache stores deterministic simulation results, so worker
/// count and completion order cannot change any value).
pub fn parallel_batch_sweeps(
    sys: &WaferSystem,
    ds: &DeepSeekConfig,
    specs: &[(ParallelismPlan, AttentionChoice)],
    kv_len: u32,
    fidelity: SimFidelity,
    cache: &KernelCache,
) -> Vec<Vec<DecodeOutcome>> {
    if specs.is_empty() {
        return Vec::new();
    }
    let workers = crate::util::worker_threads().min(specs.len()).max(1);
    let next = std::sync::atomic::AtomicUsize::new(0);
    let slots: Vec<std::sync::Mutex<Option<Vec<DecodeOutcome>>>> =
        specs.iter().map(|_| std::sync::Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let next = &next;
            let slots = &slots;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= specs.len() {
                    break;
                }
                let (plan, choice) = specs[i];
                let out = batch_sweep_cached(sys, ds, plan, kv_len, choice, fidelity, cache.clone());
                *slots[i].lock().unwrap() = Some(out);
            });
        }
    });
    slots
        .into_iter()
        .map(|s| s.into_inner().unwrap().expect("sweep worker panicked"))
        .collect()
}

/// Best outcome under a TPOT constraint (the Table II operating point rule:
/// the highest-throughput point with TPOT ≤ limit).
pub fn best_under_tpot(outcomes: &[DecodeOutcome], tpot_limit_ms: f64) -> Option<&DecodeOutcome> {
    outcomes
        .iter()
        .filter(|o| o.tpot_ms <= tpot_limit_ms)
        .max_by(|a, b| a.system_tokens_per_s.partial_cmp(&b.system_tokens_per_s).unwrap())
}

/// The paper's Table II "Ours1" row: 1 TB/s D2D wafer, EP32-PP2.
pub fn ours1(fidelity: SimFidelity) -> Vec<DecodeOutcome> {
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    batch_sweep(&sys, &ds, ParallelismPlan::new(32, 2), 4096, AttentionChoice::Flat, fidelity)
}

/// Table II "Ours2": D2D reduced to NVLink-class 160 GB/s.
pub fn ours2(fidelity: SimFidelity) -> Vec<DecodeOutcome> {
    let sys = WaferSystem::paper_nvlink_class();
    let ds = DeepSeekConfig::v3_671b();
    batch_sweep(&sys, &ds, ParallelismPlan::new(32, 2), 4096, AttentionChoice::Flat, fidelity)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_is_monotone_in_tpot() {
        let out = ours1(SimFidelity::Analytic);
        for w in out.windows(2) {
            assert!(w[1].tpot_ms >= w[0].tpot_ms, "TPOT must grow with batch");
        }
    }

    #[test]
    fn ours1_beats_ds_prof_per_chip() {
        // Table II: Ours1 ≥ 2.9× DS-Prof per-chip throughput under 50 ms.
        let out = ours1(SimFidelity::Analytic);
        let best = best_under_tpot(&out, 50.0).expect("some point under 50ms");
        let ds_prof = crate::baseline::soa::SoaSystem::ds_prof();
        let ratio = best.per_chip_tokens_per_s / ds_prof.tokens_per_s_per_chip;
        assert!(ratio > 2.0, "per-chip speedup {ratio}");
        assert!(best.tpot_ms < 50.0);
    }

    #[test]
    fn ours2_still_beats_ds_prof() {
        // Table II: even at 160 GB/s D2D, ≥1.6× decoding throughput.
        let out = ours2(SimFidelity::Analytic);
        let best = best_under_tpot(&out, 50.0).expect("some point under 50ms");
        let ds_prof = crate::baseline::soa::SoaSystem::ds_prof();
        let ratio = best.per_chip_tokens_per_s / ds_prof.tokens_per_s_per_chip;
        assert!(ratio > 1.2, "per-chip speedup {ratio}");
    }

    #[test]
    fn parallel_sweeps_match_sequential() {
        let sys = WaferSystem::paper();
        let ds = DeepSeekConfig::v3_671b();
        let specs = [
            (ParallelismPlan::new(32, 2), AttentionChoice::Flat),
            (ParallelismPlan::new(32, 2), AttentionChoice::FlashMla),
        ];
        let cache = KernelCache::new();
        let par = parallel_batch_sweeps(&sys, &ds, &specs, 4096, SimFidelity::Analytic, &cache);
        assert!(!cache.is_empty(), "shared cache should be populated");
        for (i, &(plan, choice)) in specs.iter().enumerate() {
            let seq = batch_sweep(&sys, &ds, plan, 4096, choice, SimFidelity::Analytic);
            assert_eq!(par[i].len(), seq.len());
            for (a, b) in par[i].iter().zip(&seq) {
                assert_eq!(a.batch_per_chip, b.batch_per_chip);
                assert!((a.stage_seconds - b.stage_seconds).abs() < 1e-15, "thread workers must not perturb results");
                assert!((a.system_tokens_per_s - b.system_tokens_per_s).abs() < 1e-9);
            }
        }
        // Re-running over the same cache adds no new kernel entries.
        let n = cache.len();
        parallel_batch_sweeps(&sys, &ds, &specs, 4096, SimFidelity::Analytic, &cache);
        assert_eq!(cache.len(), n);
    }

    #[test]
    fn ep_plans_cover_wafer() {
        for p in ep_plans() {
            assert_eq!(p.chips(), 64);
        }
    }

    #[test]
    fn higher_ep_helps_mid_batch() {
        // Fig. 13c: EP32 beats PP-only at medium batch.
        let sys = WaferSystem::paper();
        let ds = DeepSeekConfig::v3_671b();
        let mut ev = DecodeEvaluator::new(SimFidelity::Analytic);
        let pp = ev.evaluate(&sys, &ds, ParallelismPlan::new(1, 64), 64, 4096, AttentionChoice::Flat);
        let ep = ev.evaluate(&sys, &ds, ParallelismPlan::new(32, 2), 64, 4096, AttentionChoice::Flat);
        assert!(ep.system_tokens_per_s > pp.system_tokens_per_s);
        assert!(ep.tpot_ms < pp.tpot_ms);
    }
}
