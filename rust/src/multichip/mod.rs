//! Wafer-scale multi-die system model.
pub mod d2d;
pub mod parallelism;
pub mod wafer;
