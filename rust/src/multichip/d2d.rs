//! Wafer-scale D2D interconnect model (paper Fig. 2c, §IV).
//!
//! The paper's C2C model abstracts each chip as a traffic generator over
//! explicit D2D links with credit-based flow control; ours does the same in
//! closed form: a 2D mesh of chips, XY routing, uniform-destination traffic
//! within a parallelism group, with per-link serialization as the capacity
//! limit and hop-proportional latency.

use crate::arch::config::ChipConfig;

/// D2D interconnect configuration.
#[derive(Debug, Clone, Copy)]
pub struct D2dConfig {
    /// Chip mesh dimensions.
    pub mesh_x: u32,
    pub mesh_y: u32,
    /// Per-direction link bandwidth, bytes/second (paper: 1 TB/s; the
    /// "NVLink-class" ablation uses 160 GB/s).
    pub link_bandwidth_bytes_per_s: f64,
    /// Per-hop latency, seconds (paper: 256 ns).
    pub hop_latency_s: f64,
}

impl D2dConfig {
    /// The paper's wafer-scale system: 8×8 mesh, 1 TB/s, 256 ns.
    pub fn wafer_8x8() -> Self {
        D2dConfig { mesh_x: 8, mesh_y: 8, link_bandwidth_bytes_per_s: 1.0e12, hop_latency_s: 256e-9 }
    }

    /// Table II "Ours2": D2D bandwidth reduced to NVLink-class 160 GB/s.
    pub fn wafer_8x8_nvlink_class() -> Self {
        let mut c = Self::wafer_8x8();
        c.link_bandwidth_bytes_per_s = 160.0e9;
        c
    }

    pub fn chips(&self) -> u32 {
        self.mesh_x * self.mesh_y
    }

    /// Sub-mesh dimensions of a parallelism group of `n` chips (groups are
    /// laid out as contiguous rectangles; `n` must divide the mesh).
    pub fn group_dims(&self, n: u32) -> (u32, u32) {
        assert!(n >= 1 && n <= self.chips(), "group size {n} out of range");
        // Most-square factorization that fits the mesh.
        let mut best = (n.min(self.mesh_x), n.div_ceil(self.mesh_x.min(n)));
        let mut best_ratio = f64::INFINITY;
        for gx in 1..=n.min(self.mesh_x) {
            if n % gx != 0 {
                continue;
            }
            let gy = n / gx;
            if gy > self.mesh_y {
                continue;
            }
            let ratio = (gx.max(gy) as f64) / (gx.min(gy) as f64);
            if ratio < best_ratio {
                best_ratio = ratio;
                best = (gx, gy);
            }
        }
        best
    }

    /// Mean XY hop count for uniform traffic within a gx×gy sub-mesh.
    pub fn mean_hops(gx: u32, gy: u32) -> f64 {
        // E|x1−x2| over uniform pairs on n points = (n²−1)/(3n).
        let ex = |n: u32| {
            let n = n as f64;
            (n * n - 1.0) / (3.0 * n)
        };
        ex(gx) + ex(gy)
    }

    /// Time for an all-to-all-style exchange within a group of `n` chips
    /// where every chip injects `bytes_per_chip` spread uniformly over the
    /// group (the EP dispatch/combine pattern).
    ///
    /// Capacity limit: total byte·hops divided over the group's directed
    /// links; injection limit: a chip's own links; plus hop latency.
    pub fn all_to_all_seconds(&self, n: u32, bytes_per_chip: f64) -> f64 {
        if n <= 1 || bytes_per_chip <= 0.0 {
            return 0.0;
        }
        let (gx, gy) = self.group_dims(n);
        let hbar = Self::mean_hops(gx, gy);
        // Directed internal links of a gx×gy mesh: 2·(gx−1)·gy + 2·gx·(gy−1).
        let links = (2 * (gx - 1) * gy + 2 * gx * (gy - 1)) as f64;
        let total_byte_hops = n as f64 * bytes_per_chip * hbar;
        let t_links = total_byte_hops / (links * self.link_bandwidth_bytes_per_s);
        // Injection: a chip has up to 4 outgoing links, but sustained
        // injection is bounded by its boundary capacity.
        let inj_links = 4.0f64.min((gx.max(gy)) as f64);
        let t_inject = bytes_per_chip / (inj_links * self.link_bandwidth_bytes_per_s);
        t_links.max(t_inject) + hbar * self.hop_latency_s
    }

    /// Time to forward `bytes` between adjacent pipeline stages (neighbor
    /// chips, one hop, all boundary links usable in parallel across the
    /// stage's chips — here per-chip bytes over one link).
    pub fn neighbor_transfer_seconds(&self, bytes_per_chip: f64) -> f64 {
        bytes_per_chip / self.link_bandwidth_bytes_per_s + self.hop_latency_s
    }
}

/// Aggregate compute+bandwidth description of the wafer system (Table II).
#[derive(Debug, Clone)]
pub struct WaferSystem {
    pub chip: ChipConfig,
    pub d2d: D2dConfig,
}

impl WaferSystem {
    pub fn paper() -> Self {
        WaferSystem { chip: ChipConfig::wafer_fp8(), d2d: D2dConfig::wafer_8x8() }
    }

    pub fn paper_nvlink_class() -> Self {
        WaferSystem { chip: ChipConfig::wafer_fp8(), d2d: D2dConfig::wafer_8x8_nvlink_class() }
    }

    pub fn chips(&self) -> u32 {
        self.d2d.chips()
    }

    pub fn system_peak_tflops(&self) -> f64 {
        self.chips() as f64 * self.chip.peak_flops() / 1e12
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn group_dims_rectangular() {
        let d = D2dConfig::wafer_8x8();
        assert_eq!(d.group_dims(64), (8, 8));
        assert_eq!(d.group_dims(32), (4, 8)); // most-square tie → 4×8
        assert_eq!(d.group_dims(16), (4, 4));
        assert_eq!(d.group_dims(8), (2, 4));
        assert_eq!(d.group_dims(1), (1, 1));
    }

    #[test]
    fn mean_hops_grows_with_group() {
        let h16 = D2dConfig::mean_hops(4, 4);
        let h32 = D2dConfig::mean_hops(8, 4);
        let h64 = D2dConfig::mean_hops(8, 8);
        assert!(h16 < h32 && h32 < h64);
        assert!((h64 - 5.25).abs() < 0.01, "h64 {h64}");
    }

    #[test]
    fn all_to_all_overhead_grows_with_ep_degree() {
        // Paper Fig. 13d: D2D overhead grows with expert parallelism.
        let d = D2dConfig::wafer_8x8();
        let bytes = 30.0e6;
        let t16 = d.all_to_all_seconds(16, bytes);
        let t32 = d.all_to_all_seconds(32, bytes);
        let t64 = d.all_to_all_seconds(64, bytes);
        assert!(t16 < t32 && t32 < t64, "{t16} {t32} {t64}");
    }

    #[test]
    fn nvlink_class_is_slower() {
        let fast = D2dConfig::wafer_8x8();
        let slow = D2dConfig::wafer_8x8_nvlink_class();
        let b = 10.0e6;
        assert!(slow.all_to_all_seconds(32, b) > 4.0 * fast.all_to_all_seconds(32, b));
    }

    #[test]
    fn wafer_peak_1976_tflops_per_chip() {
        let w = WaferSystem::paper();
        let per_chip = w.system_peak_tflops() / w.chips() as f64;
        assert!((per_chip - 1990.0).abs() < 25.0, "{per_chip}");
    }

    #[test]
    fn single_chip_no_traffic() {
        let d = D2dConfig::wafer_8x8();
        assert_eq!(d.all_to_all_seconds(1, 1e9), 0.0);
    }
}
