//! Parallelism planning and decode evaluation for the wafer-scale system
//! (paper §III-F, §V-C): pipeline parallelism (PP), expert parallelism (EP)
//! and EP-PP hybrids under the barrier-separated naive execution model of
//! Fig. 5e.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::arch::config::{ChipConfig, Dtype, SimFidelity};
use crate::dataflow::{simulate_kernel, AttentionDataflow};
use crate::metrics::KernelMetrics;
use crate::multichip::d2d::WaferSystem;
use crate::obs::attrib::{AttribClass, StageAttrib};
use crate::workload::deepseek::{decode_layer_kernels, DeepSeekConfig, KernelClass, MoePlacement};

/// An EP×PP plan over the wafer's chips.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ParallelismPlan {
    pub ep: u32,
    pub pp: u32,
}

impl ParallelismPlan {
    pub fn new(ep: u32, pp: u32) -> Self {
        ParallelismPlan { ep, pp }
    }
    pub fn chips(&self) -> u32 {
        self.ep * self.pp
    }
    pub fn label(&self) -> String {
        match (self.ep, self.pp) {
            (1, p) => format!("PP{p}"),
            (e, 1) => format!("EP{e}"),
            (e, p) => format!("EP{e}-PP{p}"),
        }
    }
}

/// Which attention dataflow the decoder uses (the Fig. 13a comparison).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AttentionChoice {
    /// FlatAttention with the Fig. 10 tiling strategy (this paper).
    Flat,
    /// FlashMLA-style per-tile dataflow (no grouping, no collectives).
    FlashMla,
}

impl AttentionChoice {
    pub fn label(self) -> &'static str {
        match self {
            AttentionChoice::Flat => "FlatAttention",
            AttentionChoice::FlashMla => "FlashMLA",
        }
    }
}

/// Runtime breakdown of one decoder layer (seconds).
#[derive(Debug, Clone, Copy, Default)]
pub struct LayerBreakdown {
    pub attention_s: f64,
    pub gemm_s: f64,
    pub vector_s: f64,
    pub c2c_s: f64,
}

impl LayerBreakdown {
    pub fn total(&self) -> f64 {
        self.attention_s + self.gemm_s + self.vector_s + self.c2c_s
    }
}

/// Decode operating-point outcome.
#[derive(Debug, Clone)]
pub struct DecodeOutcome {
    pub plan: ParallelismPlan,
    pub batch_per_chip: u32,
    /// Time one pipeline stage takes for one decoding iteration.
    pub stage_seconds: f64,
    /// Per-user time per output token (ms).
    pub tpot_ms: f64,
    pub system_tokens_per_s: f64,
    pub per_chip_tokens_per_s: f64,
    /// Average per-MoE-layer breakdown.
    pub layer: LayerBreakdown,
    /// Matrix utilization achieved by the attention kernel.
    pub attention_utilization: f64,
}

/// Thread-safe memoization of kernel simulations, shareable across
/// [`DecodeEvaluator`] instances and `std::thread` sweep workers: identical
/// (chip, fidelity, dataflow, kernel-shape) keys hit the cache no matter
/// which sweep point or serving iteration asked first.
#[derive(Clone, Default)]
pub struct KernelCache {
    inner: Arc<Mutex<HashMap<String, KernelMetrics>>>,
    /// Shared (hits, misses) lookup counters — all clones report into one
    /// pair, so the observability snapshot sees the whole process.
    stats: Arc<Mutex<(u64, u64)>>,
}

impl KernelCache {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Look up `key`, simulating outside the lock on a miss so concurrent
    /// workers overlap their kernel simulations instead of serializing.
    /// Crate-visible so the serving layer's prefill engine shares one kernel
    /// memo with the decode evaluator.
    ///
    /// Hit/miss counting is interleaving-independent: a lookup counts as a
    /// miss only if ITS insert created the entry. When n threads race on one
    /// absent key the totals are always 1 miss + (n-1) hits regardless of
    /// scheduling, so the counters (exported into obs metrics) stay
    /// bit-identical across worker counts. Serial totals are unchanged.
    pub(crate) fn get_or_insert_with(&self, key: String, f: impl FnOnce() -> KernelMetrics) -> KernelMetrics {
        if let Some(m) = self.inner.lock().unwrap().get(&key) {
            self.stats.lock().unwrap().0 += 1;
            return m.clone();
        }
        let m = f();
        match self.inner.lock().unwrap().entry(key) {
            std::collections::hash_map::Entry::Occupied(e) => {
                self.stats.lock().unwrap().0 += 1;
                e.get().clone()
            }
            std::collections::hash_map::Entry::Vacant(e) => {
                self.stats.lock().unwrap().1 += 1;
                e.insert(m).clone()
            }
        }
    }

    /// Lookups served from the memo (shared across clones).
    pub fn hits(&self) -> u64 {
        self.stats.lock().unwrap().0
    }

    /// Lookups that had to simulate (shared across clones).
    pub fn misses(&self) -> u64 {
        self.stats.lock().unwrap().1
    }

    /// Snapshot of every entry, sorted by key — the on-disk persistence
    /// format (`coordinator::cache`) wants deterministic output.
    pub fn entries(&self) -> Vec<(String, KernelMetrics)> {
        let mut v: Vec<(String, KernelMetrics)> =
            self.inner.lock().unwrap().iter().map(|(k, m)| (k.clone(), m.clone())).collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    /// Seed one entry (loading a persisted cache). Existing entries win —
    /// a live simulation result is never overwritten by a disk value.
    pub fn seed(&self, key: String, m: KernelMetrics) {
        self.inner.lock().unwrap().entry(key).or_insert(m);
    }
}

/// Decode evaluator with kernel-simulation memoization (identical kernel
/// shapes across layers/batches/sweep points hit the cache).
pub struct DecodeEvaluator {
    cache: KernelCache,
    pub fidelity: SimFidelity,
}

impl DecodeEvaluator {
    pub fn new(fidelity: SimFidelity) -> Self {
        Self::with_cache(fidelity, KernelCache::new())
    }

    /// Evaluator backed by a shared (possibly cross-thread) cache.
    pub fn with_cache(fidelity: SimFidelity, cache: KernelCache) -> Self {
        DecodeEvaluator { cache, fidelity }
    }

    fn kernel(&mut self, cfg: &ChipConfig, chip_fp: &str, class: &KernelClass, choice: AttentionChoice) -> KernelMetrics {
        // Keyed on the full chip fingerprint, not `cfg.name`: presets are
        // routinely mutated in place (bandwidth/capacity ablations), and a
        // name-keyed cache would silently serve the original's results.
        // The fingerprint is built once per `evaluate` and passed in — the
        // per-kernel format only appends the cheap varying parts.
        let key = format!("{chip_fp}|{:?}|{:?}|{:?}", self.fidelity, choice, class);
        let fidelity = self.fidelity;
        self.cache.get_or_insert_with(key, || {
            simulate_kernel(
                cfg,
                class,
                |s| match choice {
                    AttentionChoice::Flat => AttentionDataflow::auto_flat(cfg, s),
                    AttentionChoice::FlashMla => AttentionDataflow::Fa2,
                },
                fidelity,
            )
        })
    }

    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }

    /// Evaluate one decode operating point.
    #[allow(clippy::too_many_arguments)]
    pub fn evaluate(
        &mut self,
        sys: &WaferSystem,
        ds: &DeepSeekConfig,
        plan: ParallelismPlan,
        batch_per_chip: u32,
        kv_len: u32,
        choice: AttentionChoice,
    ) -> DecodeOutcome {
        assert_eq!(plan.chips(), sys.chips(), "plan must cover the wafer");
        let cfg = &sys.chip;
        let chip_fp = cfg.fingerprint();
        let dtype = Dtype::Fp8;
        let sp = ds.mtp_spec_len.max(1) as u64;
        let rows = batch_per_chip as u64 * sp;

        // MoE routing statistics across the EP group.
        let group_tokens = rows * plan.ep as u64;
        let total_pairs = group_tokens * ds.experts_per_token as u64;
        let active_total = total_pairs.min(ds.n_experts as u64).max(1);
        let rows_per_expert = total_pairs.div_ceil(active_total);
        let active_per_chip = (active_total.div_ceil(plan.ep as u64)).min((ds.n_experts / plan.ep).max(1) as u64);
        let moe = MoePlacement { experts_on_chip: active_per_chip as u32, rows_per_expert };

        // Per-layer kernel times.
        let kernels = decode_layer_kernels(ds, batch_per_chip, kv_len, dtype, moe);
        let mut br = LayerBreakdown::default();
        let mut attn_util = 0.0;
        for k in &kernels {
            let m = self.kernel(cfg, &chip_fp, &k.class, choice);
            match &k.class {
                KernelClass::Attention(_) => {
                    br.attention_s += m.seconds;
                    attn_util = m.matrix_utilization_active.max(m.compute_utilization);
                }
                KernelClass::Gemm { .. } => br.gemm_s += m.seconds,
                KernelClass::Vector { .. } => br.vector_s += m.seconds,
            }
        }

        // C2C dispatch + combine per MoE layer (within the EP group).
        let dispatch_bytes = rows as f64 * ds.experts_per_token as f64 * ds.d_model as f64 * dtype.bytes() as f64;
        br.c2c_s = 2.0 * sys.d2d.all_to_all_seconds(plan.ep, dispatch_bytes);

        // Dense leading layers: replace MoE kernels with the dense FFN.
        let dense_ffn_s = {
            let d = ds.d_model as u64;
            let di = ds.dense_inter as u64;
            let up = self.kernel(cfg, &chip_fp, &KernelClass::Gemm { m: rows, k: d, n: 2 * di, batch: 1 }, choice);
            let down = self.kernel(cfg, &chip_fp, &KernelClass::Gemm { m: rows, k: di, n: d, batch: 1 }, choice);
            up.seconds + down.seconds
        };
        let moe_s = {
            // MoE-specific kernel seconds (routed + shared + gate).
            let mut s = 0.0;
            for k in &kernels {
                if k.name.starts_with("moe.") {
                    s += self.kernel(cfg, &chip_fp, &k.class, choice).seconds;
                }
            }
            s
        };
        let moe_layer_s = br.total();
        let dense_layer_s = moe_layer_s - br.c2c_s - moe_s + dense_ffn_s;

        // Stage time: layers split over pipeline stages + PP boundary xfer.
        let moe_layers = (ds.layers - ds.dense_layers) as f64;
        let dense_layers = ds.dense_layers as f64;
        let per_stage_moe = moe_layers / plan.pp as f64;
        let per_stage_dense = dense_layers / plan.pp as f64;
        let boundary = if plan.pp > 1 {
            sys.d2d.neighbor_transfer_seconds(rows as f64 * ds.d_model as f64 * dtype.bytes() as f64)
        } else {
            0.0
        };
        let stage_seconds = per_stage_moe * moe_layer_s + per_stage_dense * dense_layer_s + boundary;

        // Throughput / latency under wave pipelining (see DESIGN.md):
        // a wave = the EP group's users at one stage; `pp` waves in flight.
        let tokens_per_iter = ds.tokens_per_iteration();
        let wave_users = batch_per_chip as f64 * plan.ep as f64;
        let system_tokens_per_s = wave_users * tokens_per_iter / stage_seconds;
        let tpot_ms = plan.pp as f64 * stage_seconds / tokens_per_iter * 1e3;

        DecodeOutcome {
            plan,
            batch_per_chip,
            stage_seconds,
            tpot_ms,
            system_tokens_per_s,
            per_chip_tokens_per_s: system_tokens_per_s / sys.chips() as f64,
            layer: br,
            attention_utilization: attn_util,
        }
    }

    /// Attribution re-walk of [`DecodeEvaluator::evaluate`]: the identical
    /// kernel walk and per-stage scaling, but billed per kernel class with
    /// the simulated FLOPs/HBM-bytes/utilizations attached. The returned
    /// split is unsettled — the caller pins it to the memoized stage time
    /// via [`StageAttrib::settle`], so any float-reassociation residual
    /// lands loudly in the `other` class. Every kernel lookup hits the
    /// shared [`KernelCache`], so the re-walk is pure arithmetic after the
    /// first evaluation of an operating point.
    #[allow(clippy::too_many_arguments)]
    pub fn evaluate_attrib(
        &mut self,
        sys: &WaferSystem,
        ds: &DeepSeekConfig,
        plan: ParallelismPlan,
        batch_per_chip: u32,
        kv_len: u32,
        choice: AttentionChoice,
    ) -> StageAttrib {
        let cfg = &sys.chip;
        let chip_fp = cfg.fingerprint();
        let dtype = Dtype::Fp8;
        let sp = ds.mtp_spec_len.max(1) as u64;
        let rows = batch_per_chip as u64 * sp;

        let group_tokens = rows * plan.ep as u64;
        let total_pairs = group_tokens * ds.experts_per_token as u64;
        let active_total = total_pairs.min(ds.n_experts as u64).max(1);
        let rows_per_expert = total_pairs.div_ceil(active_total);
        let active_per_chip = (active_total.div_ceil(plan.ep as u64)).min((ds.n_experts / plan.ep).max(1) as u64);
        let moe = MoePlacement { experts_on_chip: active_per_chip as u32, rows_per_expert };

        let moe_layers = (ds.layers - ds.dense_layers) as f64;
        let dense_layers = ds.dense_layers as f64;
        let per_stage_moe = moe_layers / plan.pp as f64;
        let per_stage_dense = dense_layers / plan.pp as f64;

        let mut a = StageAttrib::default();
        // MoE layers run every kernel `per_stage_moe` times per stage;
        // dense layers re-run everything except the `moe.*` kernels.
        for k in &decode_layer_kernels(ds, batch_per_chip, kv_len, dtype, moe) {
            let m = self.kernel(cfg, &chip_fp, &k.class, choice);
            let mult = if k.name.starts_with("moe.") { per_stage_moe } else { per_stage_moe + per_stage_dense };
            let class = match &k.class {
                KernelClass::Attention(_) => AttribClass::Attention,
                KernelClass::Gemm { .. } => AttribClass::Gemm,
                KernelClass::Vector { .. } => AttribClass::Vector,
            };
            a.add_kernel(class, mult, &m);
        }
        // Dense FFN replaces the MoE experts in the leading dense layers.
        let d = ds.d_model as u64;
        let di = ds.dense_inter as u64;
        let up = self.kernel(cfg, &chip_fp, &KernelClass::Gemm { m: rows, k: d, n: 2 * di, batch: 1 }, choice);
        a.add_kernel(AttribClass::Gemm, per_stage_dense, &up);
        let down = self.kernel(cfg, &chip_fp, &KernelClass::Gemm { m: rows, k: di, n: d, batch: 1 }, choice);
        a.add_kernel(AttribClass::Gemm, per_stage_dense, &down);
        // Fabric: MoE all-to-all dispatch+combine per MoE layer, plus the
        // PP boundary hop once per stage.
        let dispatch_bytes = rows as f64 * ds.experts_per_token as f64 * ds.d_model as f64 * dtype.bytes() as f64;
        a.add_seconds(AttribClass::Comm, per_stage_moe * 2.0 * sys.d2d.all_to_all_seconds(plan.ep, dispatch_bytes));
        if plan.pp > 1 {
            let boundary = sys.d2d.neighbor_transfer_seconds(rows as f64 * ds.d_model as f64 * dtype.bytes() as f64);
            a.add_seconds(AttribClass::Comm, boundary);
        }
        a
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn eval(choice: AttentionChoice, ep: u32, pp: u32, b: u32) -> DecodeOutcome {
        let sys = WaferSystem::paper();
        let ds = DeepSeekConfig::v3_671b();
        let mut ev = DecodeEvaluator::new(SimFidelity::Analytic);
        ev.evaluate(&sys, &ds, ParallelismPlan::new(ep, pp), b, 4096, choice)
    }

    #[test]
    fn flat_beats_flashmla_at_high_batch() {
        // Paper Fig. 13a: up to ~2.1× system throughput at high batch.
        let flat = eval(AttentionChoice::Flat, 32, 2, 256);
        let mla = eval(AttentionChoice::FlashMla, 32, 2, 256);
        let speedup = flat.system_tokens_per_s / mla.system_tokens_per_s;
        // Paper: up to 2.1×. Our FlashMLA baseline inherits the K-split
        // GEMM dataflow + fair channel interleaving, so the measured gap is
        // smaller but the ordering and regime match.
        assert!(speedup > 1.3 && speedup < 3.0, "speedup {speedup}");
        // And TPOT is simultaneously lower.
        assert!(flat.tpot_ms < mla.tpot_ms);
    }

    #[test]
    fn attention_dominates_flashmla_runtime() {
        // Paper Fig. 13b: attention ≈71% of runtime with FlashMLA, ≈42%
        // with FlatAttention.
        let mla = eval(AttentionChoice::FlashMla, 32, 2, 256);
        let frac_mla = mla.layer.attention_s / mla.layer.total();
        // Paper: ≈71%; ours ≈54% (see the note in flat_beats_flashmla).
        assert!(frac_mla > 0.50, "flashmla attention fraction {frac_mla}");
        let flat = eval(AttentionChoice::Flat, 32, 2, 256);
        let frac_flat = flat.layer.attention_s / flat.layer.total();
        assert!(frac_flat < frac_mla - 0.1, "flat fraction {frac_flat}");
    }

    #[test]
    fn throughput_grows_with_batch() {
        let a = eval(AttentionChoice::Flat, 32, 2, 64);
        let b = eval(AttentionChoice::Flat, 32, 2, 256);
        assert!(b.system_tokens_per_s > a.system_tokens_per_s);
        // But TPOT degrades.
        assert!(b.tpot_ms > a.tpot_ms);
    }

    #[test]
    fn tpot_meets_50ms_at_paper_operating_point() {
        // Table II Ours1: b=256, kv=4096, EP32-PP2, TPOT within 50 ms.
        let o = eval(AttentionChoice::Flat, 32, 2, 256);
        assert!(o.tpot_ms < 50.0, "tpot {}", o.tpot_ms);
        // Per-chip throughput in the thousands (paper: 6940 tok/s).
        assert!(o.per_chip_tokens_per_s > 3000.0, "{}", o.per_chip_tokens_per_s);
    }

    #[test]
    fn pp_only_low_batch_underactivates_experts() {
        // Fig. 13c: under PP-only, low batch leaves experts idle; raising
        // batch at first barely moves throughput (weight streaming bound).
        let a = eval(AttentionChoice::Flat, 1, 64, 2);
        let b = eval(AttentionChoice::Flat, 1, 64, 8);
        let gain = b.system_tokens_per_s / a.system_tokens_per_s;
        assert!(gain < 3.0, "gain {gain} should be sublinear (4× batch)");
    }

    #[test]
    fn attrib_rewalk_conserves_stage_seconds() {
        let sys = WaferSystem::paper();
        let ds = DeepSeekConfig::v3_671b();
        let mut ev = DecodeEvaluator::new(SimFidelity::Analytic);
        let plan = ParallelismPlan::new(32, 2);
        let o = ev.evaluate(&sys, &ds, plan, 128, 4096, AttentionChoice::Flat);
        let a = ev.evaluate_attrib(&sys, &ds, plan, 128, 4096, AttentionChoice::Flat);
        let rel = (a.billed_s() - o.stage_seconds).abs() / o.stage_seconds;
        assert!(rel < 1e-9, "re-walk drifted from evaluate: {} vs {}", a.billed_s(), o.stage_seconds);
        // The split is non-degenerate: attention, gemm and comm all billed.
        assert!(a.by_class.iter().filter(|b| b.seconds > 0.0).count() >= 3, "{a:?}");
        let mut settled = a.clone();
        settled.settle(o.stage_seconds);
        assert!((settled.billed_s() - o.stage_seconds).abs() < 1e-15 * o.stage_seconds.max(1.0));
    }

    #[test]
    fn cache_hits_across_layers() {
        let sys = WaferSystem::paper();
        let ds = DeepSeekConfig::v3_671b();
        let mut ev = DecodeEvaluator::new(SimFidelity::Analytic);
        ev.evaluate(&sys, &ds, ParallelismPlan::new(32, 2), 128, 4096, AttentionChoice::Flat);
        let n1 = ev.cache_len();
        ev.evaluate(&sys, &ds, ParallelismPlan::new(32, 2), 128, 4096, AttentionChoice::Flat);
        assert_eq!(ev.cache_len(), n1, "second evaluation should be fully cached");
    }

    #[test]
    fn shared_cache_across_evaluators() {
        let sys = WaferSystem::paper();
        let ds = DeepSeekConfig::v3_671b();
        let cache = KernelCache::new();
        let mut a = DecodeEvaluator::with_cache(SimFidelity::Analytic, cache.clone());
        a.evaluate(&sys, &ds, ParallelismPlan::new(32, 2), 128, 4096, AttentionChoice::Flat);
        let n1 = cache.len();
        assert!(n1 > 0);
        // A second evaluator over the same shared cache adds nothing new for
        // the identical operating point.
        let mut b = DecodeEvaluator::with_cache(SimFidelity::Analytic, cache.clone());
        b.evaluate(&sys, &ds, ParallelismPlan::new(32, 2), 128, 4096, AttentionChoice::Flat);
        assert_eq!(cache.len(), n1);
        assert_eq!(b.cache_len(), n1);
    }
}
