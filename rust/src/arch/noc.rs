//! 2D-mesh NoC geometry and per-chip resource allocation.
//!
//! The NoC is modeled at the granularity the dataflows exercise it: row-wise
//! and column-wise collective *paths*. Collectives of the same mesh row
//! contend (serialize) on that row's path server; different rows/columns
//! proceed in parallel — matching the link-disjointness of FlooNoC-style
//! XY-routed row/column collectives.

use crate::arch::config::ChipConfig;
use crate::sim::{ResourceId, ResourceKind, ResourceTable};

/// Tile coordinate in the mesh. `x` is the column, `y` the row; HBM sits on
/// the south edge (y = mesh_y − 1 side).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TileCoord {
    pub x: u32,
    pub y: u32,
}

impl TileCoord {
    pub fn flat(self, cfg: &ChipConfig) -> u32 {
        self.y * cfg.mesh_x + self.x
    }
    /// Manhattan distance (XY routing hop count).
    pub fn hops_to(self, other: TileCoord) -> u64 {
        (self.x.abs_diff(other.x) + self.y.abs_diff(other.y)) as u64
    }
    /// Hop count from this tile to the south-edge HBM controller serving
    /// its column.
    pub fn hops_to_hbm(self, cfg: &ChipConfig) -> u64 {
        (cfg.mesh_y - self.y) as u64
    }
}

/// All DES resources of one chip, pre-allocated so dataflow generators can
/// address engines by tile coordinate.
#[derive(Debug, Clone)]
pub struct ChipResources {
    pub table: ResourceTable,
    matrix: Vec<ResourceId>,
    vector: Vec<ResourceId>,
    dma: Vec<ResourceId>,
    hbm_ch: Vec<ResourceId>,
    row_path: Vec<ResourceId>,
    col_path: Vec<ResourceId>,
    mesh_x: u32,
    mesh_y: u32,
    channels: u32,
}

impl ChipResources {
    pub fn new(cfg: &ChipConfig) -> Self {
        let mut table = ResourceTable::new();
        let tiles = cfg.tiles();
        let matrix = (0..tiles).map(|i| table.add(ResourceKind::MatrixEngine(i))).collect();
        let vector = (0..tiles).map(|i| table.add(ResourceKind::VectorEngine(i))).collect();
        let dma = (0..tiles).map(|i| table.add(ResourceKind::Dma(i))).collect();
        let channels = cfg.hbm.channels();
        let hbm_ch = (0..channels).map(|i| table.add(ResourceKind::HbmChannel(i))).collect();
        let row_path = (0..cfg.mesh_y).map(|i| table.add(ResourceKind::NocRow(i))).collect();
        let col_path = (0..cfg.mesh_x).map(|i| table.add(ResourceKind::NocCol(i))).collect();
        ChipResources {
            table,
            matrix,
            vector,
            dma,
            hbm_ch,
            row_path,
            col_path,
            mesh_x: cfg.mesh_x,
            mesh_y: cfg.mesh_y,
            channels,
        }
    }

    pub fn matrix(&self, t: TileCoord) -> ResourceId {
        self.matrix[(t.y * self.mesh_x + t.x) as usize]
    }
    pub fn vector(&self, t: TileCoord) -> ResourceId {
        self.vector[(t.y * self.mesh_x + t.x) as usize]
    }
    pub fn dma(&self, t: TileCoord) -> ResourceId {
        self.dma[(t.y * self.mesh_x + t.x) as usize]
    }
    pub fn row_path(&self, row: u32) -> ResourceId {
        self.row_path[row as usize]
    }
    pub fn col_path(&self, col: u32) -> ResourceId {
        self.col_path[col as usize]
    }

    /// HBM channel serving tile `t` (channels striped across mesh columns;
    /// multiple channels per column are interleaved over rows with a
    /// multiplicative hash — a plain `y % per_col` aliases with the group
    /// stride of the dataflows' diagonal loaders, silently idling half the
    /// channels).
    pub fn hbm_channel(&self, t: TileCoord) -> ResourceId {
        let per_col = (self.channels / self.mesh_x).max(1);
        let base = (t.x * self.channels / self.mesh_x).min(self.channels - 1);
        let h = (t.y as u64).wrapping_mul(0x9E3779B1) >> 13;
        let ch = (base + (h % per_col as u64) as u32).min(self.channels - 1);
        self.hbm_ch[ch as usize]
    }

    pub fn mesh_x(&self) -> u32 {
        self.mesh_x
    }
    pub fn mesh_y(&self) -> u32 {
        self.mesh_y
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coords_and_hops() {
        let cfg = ChipConfig::tiny(4);
        let a = TileCoord { x: 0, y: 0 };
        let b = TileCoord { x: 3, y: 2 };
        assert_eq!(a.hops_to(b), 5);
        assert_eq!(b.hops_to(a), 5);
        assert_eq!(a.flat(&cfg), 0);
        assert_eq!(b.flat(&cfg), 11);
        assert_eq!(a.hops_to_hbm(&cfg), 4);
        assert_eq!(b.hops_to_hbm(&cfg), 2);
    }

    #[test]
    fn resources_unique_per_tile() {
        let cfg = ChipConfig::tiny(4);
        let r = ChipResources::new(&cfg);
        let a = r.matrix(TileCoord { x: 1, y: 2 });
        let b = r.matrix(TileCoord { x: 2, y: 1 });
        assert_ne!(a, b);
        assert_ne!(r.vector(TileCoord { x: 1, y: 2 }), a);
    }

    #[test]
    fn hbm_channels_spread_over_columns() {
        let cfg = ChipConfig::table1();
        let r = ChipResources::new(&cfg);
        // 32 channels over 32 columns: one channel per column.
        let c0 = r.hbm_channel(TileCoord { x: 0, y: 5 });
        let c1 = r.hbm_channel(TileCoord { x: 1, y: 5 });
        assert_ne!(c0, c1);
        // Same column, different row → same channel (1 per column here).
        let c0b = r.hbm_channel(TileCoord { x: 0, y: 9 });
        assert_eq!(c0, c0b);
    }

    #[test]
    fn hbm_two_stacks_spread_over_both_channels() {
        // With 2 channels per column, the rows of a column must use both —
        // including rows at stride 4 (the diagonal-loader pattern of a
        // gy = 4 group, which a naive y%2 map would alias onto one channel).
        let cfg = ChipConfig::table1_gh200_match();
        let r = ChipResources::new(&cfg);
        let all: std::collections::HashSet<_> =
            (0..cfg.mesh_y).map(|y| r.hbm_channel(TileCoord { x: 0, y })).collect();
        assert_eq!(all.len(), 2, "both channels of column 0 must be used");
        let strided: std::collections::HashSet<_> =
            (0..cfg.mesh_y).step_by(4).map(|y| r.hbm_channel(TileCoord { x: 0, y: y + 1 })).collect();
        assert_eq!(strided.len(), 2, "stride-4 rows must still hit both channels");
    }
}
