//! HBM access modeling: DMA-issued block loads/stores through FIFO channel
//! servers, with a fixed access latency (≈200 cycles, paper §V-B) plus the
//! mesh traversal from the south-edge controller to the destination tile.
//!
//! Channel occupancy is `bytes / per-channel-bandwidth`; latency is charged
//! *after* the channel frees, so back-to-back streaming transfers pipeline
//! their latencies — the behaviour DRAMSys exhibits for sequential bursts.

use crate::arch::config::ChipConfig;
use crate::arch::noc::{ChipResources, TileCoord};
use crate::sim::{Category, Graph, Op, OpId};

/// Cycles a channel is occupied transferring `bytes`.
pub fn channel_occupancy_cycles(cfg: &ChipConfig, bytes: u64) -> u64 {
    let bw = cfg.hbm_channel_bytes_per_cycle();
    (bytes as f64 / bw).ceil() as u64
}

/// Total unloaded latency of one access for tile `t` (controller + mesh).
pub fn access_latency_cycles(cfg: &ChipConfig, t: TileCoord) -> u64 {
    cfg.hbm.latency_cycles + t.hops_to_hbm(cfg) * cfg.noc.router_latency_cycles
}

/// Append an HBM→L1 block load for tile `t` to the graph.
///
/// Chain: DMA issue (tile DMA queue) → channel occupancy (FIFO channel
/// server) → latency delay (no resource). Returns the op after which the
/// data is resident in L1.
pub fn load(g: &mut Graph, res: &ChipResources, cfg: &ChipConfig, t: TileCoord, bytes: u64, deps: &[OpId]) -> OpId {
    let issue = g.push(
        Op::new(Some(res.dma(t)), cfg.tile.dma_issue_cycles, Category::DmaIssue),
        deps,
    );
    let occ = g.push(
        Op::new(Some(res.hbm_channel(t)), channel_occupancy_cycles(cfg, bytes), Category::HbmRead).bytes(bytes),
        &[issue],
    );
    g.push(Op::new(None, access_latency_cycles(cfg, t), Category::Sync), &[occ])
}

/// Append an L1→HBM block store for tile `t`.
///
/// Stores are posted: the tile is released after issue + channel occupancy;
/// the trailing latency is still modeled so the makespan includes drain.
pub fn store(g: &mut Graph, res: &ChipResources, cfg: &ChipConfig, t: TileCoord, bytes: u64, deps: &[OpId]) -> OpId {
    let issue = g.push(
        Op::new(Some(res.dma(t)), cfg.tile.dma_issue_cycles, Category::DmaIssue),
        deps,
    );
    g.push(
        Op::new(Some(res.hbm_channel(t)), channel_occupancy_cycles(cfg, bytes), Category::HbmWrite).bytes(bytes),
        &[issue],
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::ResourceKind;

    #[test]
    fn occupancy_matches_bandwidth() {
        let cfg = ChipConfig::table1();
        // 64.8 B/cyc/channel → 1 MiB takes ~16.2k cycles.
        let c = channel_occupancy_cycles(&cfg, 1 << 20);
        assert!((c as f64 - (1u64 << 20) as f64 / 64.77).abs() < 20.0, "{c}");
    }

    #[test]
    fn load_includes_latency_store_posted() {
        let cfg = ChipConfig::tiny(4);
        let res = ChipResources::new(&cfg);
        let t = TileCoord { x: 0, y: 0 };
        let mut g = Graph::new(res.table.clone());
        let done = load(&mut g, &res, &cfg, t, 4096, &[]);
        let _ = done;
        let r = g.simulate();
        let occ = channel_occupancy_cycles(&cfg, 4096);
        assert_eq!(r.makespan, cfg.tile.dma_issue_cycles + occ + access_latency_cycles(&cfg, t));
        assert_eq!(r.hbm_read_bytes, 4096);
    }

    #[test]
    fn streaming_loads_pipeline_latency() {
        // N independent loads on one channel: occupancies serialize, the
        // fixed latencies overlap → makespan ≈ N·occ + 1·latency.
        let cfg = ChipConfig::tiny(4);
        let res = ChipResources::new(&cfg);
        let t = TileCoord { x: 1, y: 1 };
        let mut g = Graph::new(res.table.clone());
        let n = 8u64;
        for _ in 0..n {
            load(&mut g, &res, &cfg, t, 65536, &[]);
        }
        let r = g.simulate();
        let occ = channel_occupancy_cycles(&cfg, 65536);
        let lat = access_latency_cycles(&cfg, t);
        let expect = cfg.tile.dma_issue_cycles + n * occ + lat;
        // DMA issues pipeline under the channel occupancy.
        assert!(
            (r.makespan as i64 - expect as i64).unsigned_abs() <= cfg.tile.dma_issue_cycles * n,
            "makespan {} vs expect {}",
            r.makespan,
            expect
        );
    }

    #[test]
    fn different_columns_use_parallel_channels() {
        let cfg = ChipConfig::tiny(4);
        let res = ChipResources::new(&cfg);
        let mut g = Graph::new(res.table.clone());
        let bytes = 1 << 18;
        load(&mut g, &res, &cfg, TileCoord { x: 0, y: 0 }, bytes, &[]);
        load(&mut g, &res, &cfg, TileCoord { x: 1, y: 0 }, bytes, &[]);
        let r = g.simulate();
        let occ = channel_occupancy_cycles(&cfg, bytes);
        // Parallel channels: makespan ≈ one occupancy, not two.
        assert!(r.makespan < occ + occ / 2, "makespan {} occ {occ}", r.makespan);
    }

    #[test]
    fn channel_resources_exist() {
        let cfg = ChipConfig::table1();
        let res = ChipResources::new(&cfg);
        let n = res
            .table
            .iter()
            .filter(|(_, k)| matches!(k, ResourceKind::HbmChannel(_)))
            .count();
        assert_eq!(n, 32);
    }
}
