//! Tile-based many-PE architecture template (paper Fig. 2a) and its cost
//! models: tile engines (RedMulE matrix engine, Spatz vector engine, DMA),
//! the 2D-mesh NoC with fabric collectives, and HBM.

pub mod config;
pub mod tile;
pub mod noc;
pub mod hbm;
pub mod collective;

pub use config::{ChipConfig, HbmConfig, NocConfig, TileConfig};
pub use collective::CollectiveImpl;
