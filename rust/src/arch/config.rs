//! Architecture configuration: the parameter space the paper co-explores,
//! plus the named presets used in the evaluation (Table I, the FP8 wafer
//! configuration of §V-C, and the RTL-calibration scale of Fig. 6).



/// Numeric precision of a kernel. RedMulE delivers the same FLOP/cycle at
/// FP8 and FP16 (paper §V-C), so precision affects bytes, not engine rate.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dtype {
    Fp8,
    Fp16,
    Bf16,
    Fp32,
}

impl Dtype {
    pub fn bytes(self) -> u64 {
        match self {
            Dtype::Fp8 => 1,
            Dtype::Fp16 | Dtype::Bf16 => 2,
            Dtype::Fp32 => 4,
        }
    }
}

/// Per-tile configuration (paper Table I, "Tile" rows).
#[derive(Debug, Clone, Copy)]
pub struct TileConfig {
    /// RedMulE CE array rows (M dimension of the output stationary tile).
    pub ce_rows: u32,
    /// RedMulE CE array columns (N dimension).
    pub ce_cols: u32,
    /// Fixed per-GEMM-invocation overhead: pipeline fill/drain, accumulator
    /// flush and configuration. Calibrated so that a 16×128×16 slice runs at
    /// 20% utilization and a 128×128×128 slice at ≥95% (paper Fig. 9/11).
    pub gemm_setup_cycles: u64,
    /// Vector-engine FLOP/cycle (4 Spatz × 32 FLOP/cyc @FP16 in Table I).
    pub vector_flops_per_cycle: u64,
    /// Fixed vector-op startup (instruction issue, VL config).
    pub vector_startup_cycles: u64,
    /// L1 scratchpad capacity in KiB.
    pub l1_kib: u64,
    /// L1 bandwidth in bytes/cycle (shared by engines and DMA).
    pub l1_bytes_per_cycle: u64,
    /// DMA descriptor issue cost (scalar-core + DMA frontend).
    pub dma_issue_cycles: u64,
}

impl TileConfig {
    /// Matrix-engine peak FLOP/cycle (2 FLOPs per CE per cycle: MAC).
    pub fn matrix_flops_per_cycle(&self) -> u64 {
        2 * self.ce_rows as u64 * self.ce_cols as u64
    }
}

/// NoC configuration.
#[derive(Debug, Clone, Copy)]
pub struct NocConfig {
    /// Link width in bytes/cycle (Table I: 1024-bit links = 128 B/cyc).
    pub link_bytes_per_cycle: u64,
    /// Per-hop router traversal latency in cycles.
    pub router_latency_cycles: u64,
    /// Synchronization cost charged per software-collective stage (barrier
    /// between tree stages; paper §V-A).
    pub sw_sync_cycles: u64,
}

/// HBM configuration.
#[derive(Debug, Clone, Copy)]
pub struct HbmConfig {
    /// Number of HBM stacks on the south edge.
    pub stacks: u32,
    /// Channels per stack (Table I: 32).
    pub channels_per_stack: u32,
    /// Aggregate peak bandwidth of all stacks, bytes/second.
    pub total_bandwidth_bytes_per_s: f64,
    /// Access latency in cycles (paper §V-B: ≈200 cycles).
    pub latency_cycles: u64,
    /// Capacity per stack in GiB (HBM4: 64 GiB → the §V-C chip's two
    /// stacks give the 128 GiB used for weights + KV cache).
    pub capacity_gib_per_stack: u64,
}

impl HbmConfig {
    pub fn channels(&self) -> u32 {
        self.stacks * self.channels_per_stack
    }

    /// Total HBM capacity in bytes across all stacks.
    pub fn capacity_bytes(&self) -> u64 {
        self.stacks as u64 * self.capacity_gib_per_stack * (1 << 30)
    }
}

/// Simulation fidelity for kernel evaluation.
///
/// `Full` runs the discrete-event simulator on the lowered op graph;
/// `Analytic` composes the same per-op cost models in closed form (validated
/// against `Full` by unit tests) and is used for the large multichip sweeps.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SimFidelity {
    Full,
    Analytic,
}

/// A complete single-chip configuration.
#[derive(Debug, Clone)]
pub struct ChipConfig {
    pub name: String,
    /// Mesh dimensions (tiles).
    pub mesh_x: u32,
    pub mesh_y: u32,
    /// Clock frequency in GHz.
    pub freq_ghz: f64,
    pub tile: TileConfig,
    pub noc: NocConfig,
    pub hbm: HbmConfig,
}

impl ChipConfig {
    pub fn tiles(&self) -> u32 {
        self.mesh_x * self.mesh_y
    }

    /// Chip peak FLOP/s at FP16/FP8 (matrix engines only, as in Table I).
    pub fn peak_flops(&self) -> f64 {
        self.tiles() as f64 * self.tile.matrix_flops_per_cycle() as f64 * self.freq_ghz * 1e9
    }

    /// Chip peak FLOP/cycle.
    pub fn peak_flops_per_cycle(&self) -> u64 {
        self.tiles() as u64 * self.tile.matrix_flops_per_cycle()
    }

    /// HBM bandwidth in bytes/cycle (aggregate).
    pub fn hbm_bytes_per_cycle(&self) -> f64 {
        self.hbm.total_bandwidth_bytes_per_s / (self.freq_ghz * 1e9)
    }

    /// HBM bandwidth per channel in bytes/cycle.
    pub fn hbm_channel_bytes_per_cycle(&self) -> f64 {
        self.hbm_bytes_per_cycle() / self.hbm.channels() as f64
    }

    /// Convert cycles to seconds.
    pub fn cycles_to_seconds(&self, cycles: u64) -> f64 {
        cycles as f64 / (self.freq_ghz * 1e9)
    }

    /// Identity string for memoization keys. Unlike `name`, this tracks the
    /// performance-relevant fields, so a preset mutated in place (e.g. a
    /// bandwidth ablation) cannot alias a cache entry of the original.
    pub fn fingerprint(&self) -> String {
        format!(
            "{}:{}x{}@{:.4}GHz:ce{}x{}+{}:v{}+{}:l1-{}x{}:dma{}:noc{}+{}+{}:hbm{:.4e}x{}ch+{}cyc+{}GiB",
            self.name,
            self.mesh_x,
            self.mesh_y,
            self.freq_ghz,
            self.tile.ce_rows,
            self.tile.ce_cols,
            self.tile.gemm_setup_cycles,
            self.tile.vector_flops_per_cycle,
            self.tile.vector_startup_cycles,
            self.tile.l1_kib,
            self.tile.l1_bytes_per_cycle,
            self.tile.dma_issue_cycles,
            self.noc.link_bytes_per_cycle,
            self.noc.router_latency_cycles,
            self.noc.sw_sync_cycles,
            self.hbm.total_bandwidth_bytes_per_s,
            self.hbm.channels(),
            self.hbm.latency_cycles,
            self.hbm.capacity_gib_per_stack * self.hbm.stacks as u64,
        )
    }

    /// Ridge point (FLOP/byte) of the chip roofline.
    pub fn ridge_flops_per_byte(&self) -> f64 {
        self.peak_flops() / self.hbm.total_bandwidth_bytes_per_s
    }

    /// The paper's Table I system: 32×32 tiles @ 965 MHz, 1024-bit links,
    /// one HBM4 stack (2 TB/s, 32 channels) on the south edge,
    /// RedMulE 32×16 CEs (1024 FLOP/cyc @FP16), 4×Spatz (128 FLOP/cyc),
    /// 384 KiB L1 @ 512 B/cyc. ≈988 TFLOPS FP16 peak.
    pub fn table1() -> Self {
        ChipConfig {
            name: "table1-32x32".into(),
            mesh_x: 32,
            mesh_y: 32,
            freq_ghz: 0.965,
            tile: TileConfig {
                ce_rows: 32,
                ce_cols: 16,
                gemm_setup_cycles: 192,
                vector_flops_per_cycle: 128,
                vector_startup_cycles: 32,
                l1_kib: 384,
                l1_bytes_per_cycle: 512,
                dma_issue_cycles: 8,
            },
            noc: NocConfig {
                link_bytes_per_cycle: 128,
                router_latency_cycles: 1,
                sw_sync_cycles: 96,
            },
            hbm: HbmConfig {
                stacks: 1,
                channels_per_stack: 32,
                total_bandwidth_bytes_per_s: 2.0e12,
                latency_cycles: 200,
                capacity_gib_per_stack: 64,
            },
        }
    }

    /// Table I chip with two HBM4 stacks (4 TB/s) — the §V-B configuration
    /// matching GH200's FP16 peak *and* off-chip bandwidth (Fig. 12).
    pub fn table1_gh200_match() -> Self {
        let mut c = Self::table1();
        c.name = "table1-4tbs".into();
        c.hbm.stacks = 2;
        c.hbm.total_bandwidth_bytes_per_s = 4.0e12;
        c
    }

    /// The §V-C wafer-scale compute chiplet: Table I tile array run at
    /// 1.9 GHz for 1976 TFLOPS @FP8 (RedMulE FP8 = FP16 rate), two HBM4
    /// stacks (4 TB/s, 128 GiB).
    pub fn wafer_fp8() -> Self {
        let mut c = Self::table1();
        c.name = "wafer-fp8-1.9ghz".into();
        c.freq_ghz = 1.9;
        c.hbm.stacks = 2;
        c.hbm.total_bandwidth_bytes_per_s = 4.0e12;
        c
    }

    /// The 4×4-mesh calibration scale used in Fig. 6 (GVSoC-vs-RTL in the
    /// paper; cost-model pinning tests here).
    pub fn calib_4x4() -> Self {
        let mut c = Self::table1();
        c.name = "calib-4x4".into();
        c.mesh_x = 4;
        c.mesh_y = 4;
        c.hbm.channels_per_stack = 8;
        c.hbm.total_bandwidth_bytes_per_s = 0.5e12;
        c
    }

    /// A small config for fast tests.
    pub fn tiny(mesh: u32) -> Self {
        let mut c = Self::table1();
        c.name = format!("tiny-{mesh}x{mesh}");
        c.mesh_x = mesh;
        c.mesh_y = mesh;
        c.hbm.channels_per_stack = 8;
        c.hbm.total_bandwidth_bytes_per_s = 0.25e12;
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_peak_matches_paper() {
        let c = ChipConfig::table1();
        // Paper: 988 TFLOPS @FP16. 1024 tiles × 1024 FLOP/cyc × 0.965 GHz
        // = 1011 TFLOPS nominal; the paper's 988 reflects effective peak.
        // We accept the nominal value within 3%.
        let tflops = c.peak_flops() / 1e12;
        assert!((tflops - 1011.0).abs() < 15.0, "peak {tflops} TFLOPS");
        assert_eq!(c.tiles(), 1024);
        assert_eq!(c.tile.matrix_flops_per_cycle(), 1024);
    }

    #[test]
    fn table1_hbm_bandwidth() {
        let c = ChipConfig::table1();
        // 2 TB/s at 965 MHz ≈ 2073 B/cyc, 64.8 B/cyc/channel.
        assert!((c.hbm_bytes_per_cycle() - 2072.5).abs() < 1.0);
        assert_eq!(c.hbm.channels(), 32);
    }

    #[test]
    fn wafer_fp8_peak() {
        let c = ChipConfig::wafer_fp8();
        // Paper: 1976 TFLOPS @FP8 per chip.
        let tflops = c.peak_flops() / 1e12;
        assert!((tflops - 1990.0).abs() < 25.0, "peak {tflops} TFLOPS");
    }

    #[test]
    fn ridge_point_sane() {
        let c = ChipConfig::table1();
        // ~1011 TFLOPS / 2 TB/s ≈ 506 FLOP/byte.
        let r = c.ridge_flops_per_byte();
        assert!(r > 400.0 && r < 600.0, "ridge {r}");
    }

    #[test]
    fn wafer_hbm_capacity_128_gib() {
        // §V-C: two HBM4 stacks per chip, 128 GiB total for weights + KV.
        let c = ChipConfig::wafer_fp8();
        assert_eq!(c.hbm.capacity_bytes(), 128 * (1u64 << 30));
        assert_eq!(ChipConfig::table1().hbm.capacity_bytes(), 64 * (1u64 << 30));
    }

    #[test]
    fn dtype_bytes() {
        assert_eq!(Dtype::Fp8.bytes(), 1);
        assert_eq!(Dtype::Fp16.bytes(), 2);
        assert_eq!(Dtype::Fp32.bytes(), 4);
    }

    #[test]
    fn presets_are_distinct() {
        let names: Vec<String> = [
            ChipConfig::table1(),
            ChipConfig::table1_gh200_match(),
            ChipConfig::wafer_fp8(),
            ChipConfig::calib_4x4(),
        ]
        .iter()
        .map(|c| c.name.clone())
        .collect();
        let mut dedup = names.clone();
        dedup.dedup();
        assert_eq!(names, dedup);
    }
}
