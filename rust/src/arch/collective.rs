//! Fabric collectives (paper §II-D, Fig. 2b): row/column multicast and
//! reduction in three flavours.
//!
//! - `HW` — fabric-supported: flit-level replication/reduction inside the
//!   routers along the path; one hop-pipelined transfer at link line rate.
//! - `SW.Tree` — ⌈log₂N⌉ stages; transfers within a stage use disjoint link
//!   segments (parallel), with a synchronization barrier between stages;
//!   tree *reductions* additionally pay the receiver's vector-engine add at
//!   every stage.
//! - `SW.Seq` — the naive implementation: the source (or destination, for
//!   reductions) handles every peer with a sequential unicast; sequential
//!   reductions serialize transfer→add per peer (non-pipelined, as in the
//!   paper's baseline).
//!
//! Geometry note: collectives run along one mesh row (or column); the row's
//! path server serializes concurrent collectives in the same row, while
//! different rows proceed in parallel.

use crate::arch::config::{ChipConfig, Dtype};
use crate::arch::noc::{ChipResources, TileCoord};
use crate::arch::tile::{vector_cycles, VectorOpKind};
use crate::sim::{Category, Graph, Op, OpId};

/// Collective implementation flavour.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CollectiveImpl {
    /// Fabric-supported hardware collectives.
    Hw,
    /// Software logarithmic tree.
    SwTree,
    /// Software sequential unicasts.
    SwSeq,
}

impl CollectiveImpl {
    pub fn label(self) -> &'static str {
        match self {
            CollectiveImpl::Hw => "HW",
            CollectiveImpl::SwTree => "SW.Tree",
            CollectiveImpl::SwSeq => "SW.Seq",
        }
    }
}

/// Direction of a collective.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Axis {
    Row,
    Col,
}

fn path_resource(res: &ChipResources, axis: Axis, index: u32) -> crate::sim::ResourceId {
    match axis {
        Axis::Row => res.row_path(index),
        Axis::Col => res.col_path(index),
    }
}

fn transfer_cycles(cfg: &ChipConfig, bytes: u64, hops: u64) -> u64 {
    bytes.div_ceil(cfg.noc.link_bytes_per_cycle) + hops * cfg.noc.router_latency_cycles
}

/// One-to-all multicast of `bytes` along `axis` at row/column `index`,
/// to `width` tiles (including the source). Returns the completion op.
#[allow(clippy::too_many_arguments)]
pub fn multicast(
    g: &mut Graph,
    res: &ChipResources,
    cfg: &ChipConfig,
    imp: CollectiveImpl,
    axis: Axis,
    index: u32,
    width: u32,
    bytes: u64,
    deps: &[OpId],
) -> OpId {
    debug_assert!(width >= 1);
    if width <= 1 || bytes == 0 {
        return g.join(deps);
    }
    let path = path_resource(res, axis, index);
    match imp {
        CollectiveImpl::Hw => {
            // Hop-pipelined flit replication: latency = path hops + payload.
            let dur = transfer_cycles(cfg, bytes, (width - 1) as u64);
            g.push(Op::new(Some(path), dur, Category::NocCollective).bytes(bytes), deps)
        }
        CollectiveImpl::SwSeq => {
            // Source sends width−1 sequential unicasts.
            let mut last = g.join(deps);
            for d in 1..width {
                let dur = transfer_cycles(cfg, bytes, d as u64);
                last = g.push(Op::new(Some(path), dur, Category::NocUnicast).bytes(bytes), &[last]);
            }
            last
        }
        CollectiveImpl::SwTree => {
            // ⌈log₂ width⌉ stages; within a stage, senders use disjoint
            // segments (one path op of payload duration + longest hop).
            let stages = (width as f64).log2().ceil() as u32;
            let mut covered = 1u32;
            let mut last = g.join(deps);
            for _ in 0..stages {
                let senders = covered.min(width - covered);
                if senders == 0 {
                    break;
                }
                let max_dist = (width.div_ceil(2 * covered)).max(1) as u64 * covered as u64;
                let dur = transfer_cycles(cfg, bytes, max_dist.min(width as u64));
                let xfer = g.push(
                    Op::new(Some(path), dur, Category::NocUnicast).bytes(bytes * senders as u64),
                    &[last],
                );
                // Inter-stage synchronization barrier.
                last = g.push(Op::new(None, cfg.noc.sw_sync_cycles, Category::Sync), &[xfer]);
                covered += senders;
            }
            last
        }
    }
}

/// All-to-one sum reduction of per-tile payloads of `bytes` along `axis`,
/// over `width` tiles, landing on tile `dst`. `deps` gate the whole
/// collective (callers join per-tile readiness first). Returns completion.
#[allow(clippy::too_many_arguments)]
pub fn reduce(
    g: &mut Graph,
    res: &ChipResources,
    cfg: &ChipConfig,
    imp: CollectiveImpl,
    axis: Axis,
    index: u32,
    width: u32,
    dst: TileCoord,
    bytes: u64,
    dtype: Dtype,
    deps: &[OpId],
) -> OpId {
    debug_assert!(width >= 1);
    if width <= 1 || bytes == 0 {
        return g.join(deps);
    }
    let path = path_resource(res, axis, index);
    let elems_mn = |b: u64| (b / dtype.bytes()).max(1);
    match imp {
        CollectiveImpl::Hw => {
            // In-fabric reduction at line rate along the path.
            let dur = transfer_cycles(cfg, bytes, (width - 1) as u64);
            g.push(Op::new(Some(path), dur, Category::NocCollective).bytes(bytes * (width - 1) as u64), deps)
        }
        CollectiveImpl::SwSeq => {
            // width−1 peers each: unicast to dst, then dst adds — strictly
            // serialized (naive baseline, non-pipelined).
            let vres = res.vector(dst);
            let mut last = g.join(deps);
            for d in 1..width {
                let dur = transfer_cycles(cfg, bytes, d as u64);
                let xfer = g.push(Op::new(Some(path), dur, Category::NocUnicast).bytes(bytes), &[last]);
                let add_cyc = vector_cycles(&cfg.tile, VectorOpKind::Add, 1, elems_mn(bytes));
                last = g.push(
                    Op::new(Some(vres), add_cyc, Category::Vector).flops(elems_mn(bytes)),
                    &[xfer],
                );
            }
            last
        }
        CollectiveImpl::SwTree => {
            let stages = (width as f64).log2().ceil() as u32;
            let mut remaining = width;
            let mut last = g.join(deps);
            let vres = res.vector(dst);
            for s in 0..stages {
                let pairs = remaining / 2;
                if pairs == 0 {
                    break;
                }
                let dist = (1u64 << s).min(width as u64);
                let dur = transfer_cycles(cfg, bytes, dist);
                let xfer = g.push(
                    Op::new(Some(path), dur, Category::NocUnicast).bytes(bytes * pairs as u64),
                    &[last],
                );
                // Receivers add in parallel; model the add on the dst tile's
                // vector engine as the stage's critical path.
                let add_cyc = vector_cycles(&cfg.tile, VectorOpKind::Add, 1, elems_mn(bytes));
                let add = g.push(
                    Op::new(Some(vres), add_cyc, Category::Vector).flops(elems_mn(bytes) * pairs as u64),
                    &[xfer],
                );
                last = g.push(Op::new(None, cfg.noc.sw_sync_cycles, Category::Sync), &[add]);
                remaining -= pairs;
            }
            last
        }
    }
}

/// Closed-form latency of a multicast (used by the analytic fidelity and the
/// Fig. 7 sweeps; must track the DES within queueing effects).
pub fn multicast_latency_cycles(cfg: &ChipConfig, imp: CollectiveImpl, width: u32, bytes: u64) -> u64 {
    if width <= 1 || bytes == 0 {
        return 0;
    }
    match imp {
        CollectiveImpl::Hw => transfer_cycles(cfg, bytes, (width - 1) as u64),
        CollectiveImpl::SwSeq => (1..width).map(|d| transfer_cycles(cfg, bytes, d as u64)).sum(),
        CollectiveImpl::SwTree => {
            let stages = (width as f64).log2().ceil() as u64;
            stages * (bytes.div_ceil(cfg.noc.link_bytes_per_cycle) + cfg.noc.sw_sync_cycles)
                + (width as u64 - 1) * cfg.noc.router_latency_cycles
        }
    }
}

/// Closed-form latency of a sum reduction.
pub fn reduce_latency_cycles(cfg: &ChipConfig, imp: CollectiveImpl, width: u32, bytes: u64, dtype: Dtype) -> u64 {
    if width <= 1 || bytes == 0 {
        return 0;
    }
    let add = vector_cycles(
        &ChipConfig::table1().tile,
        VectorOpKind::Add,
        1,
        (bytes / dtype.bytes()).max(1),
    );
    let add = add.max(vector_cycles(&cfg.tile, VectorOpKind::Add, 1, (bytes / dtype.bytes()).max(1)));
    match imp {
        CollectiveImpl::Hw => transfer_cycles(cfg, bytes, (width - 1) as u64),
        CollectiveImpl::SwSeq => (1..width).map(|d| transfer_cycles(cfg, bytes, d as u64) + add).sum(),
        CollectiveImpl::SwTree => {
            let stages = (width as f64).log2().ceil() as u64;
            stages * (bytes.div_ceil(cfg.noc.link_bytes_per_cycle) + add + cfg.noc.sw_sync_cycles)
                + (width as u64 - 1) * cfg.noc.router_latency_cycles
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (ChipConfig, ChipResources) {
        let cfg = ChipConfig::table1();
        let res = ChipResources::new(&cfg);
        (cfg, res)
    }

    fn run_multicast(imp: CollectiveImpl, width: u32, bytes: u64) -> u64 {
        let (cfg, res) = setup();
        let mut g = Graph::new(res.table.clone());
        multicast(&mut g, &res, &cfg, imp, Axis::Row, 0, width, bytes, &[]);
        g.simulate().makespan
    }

    fn run_reduce(imp: CollectiveImpl, width: u32, bytes: u64) -> u64 {
        let (cfg, res) = setup();
        let mut g = Graph::new(res.table.clone());
        let dst = TileCoord { x: 0, y: 0 };
        reduce(&mut g, &res, &cfg, imp, Axis::Row, 0, width, dst, bytes, Dtype::Fp16, &[]);
        g.simulate().makespan
    }

    #[test]
    fn width_one_is_free() {
        assert_eq!(run_multicast(CollectiveImpl::Hw, 1, 4096), 0);
        assert_eq!(run_reduce(CollectiveImpl::SwSeq, 1, 4096), 0);
    }

    #[test]
    fn hw_multicast_speedups_match_fig7() {
        // Paper Fig. 7a, 32×32 mesh, large transfers: HW is 5.1× over
        // SW.Tree and 30.7× over SW.Seq.
        let bytes = 1 << 22; // 4 MiB
        let hw = run_multicast(CollectiveImpl::Hw, 32, bytes) as f64;
        let tree = run_multicast(CollectiveImpl::SwTree, 32, bytes) as f64;
        let seq = run_multicast(CollectiveImpl::SwSeq, 32, bytes) as f64;
        let s_tree = tree / hw;
        let s_seq = seq / hw;
        assert!((s_tree - 5.1).abs() < 0.6, "tree speedup {s_tree}");
        assert!((s_seq - 30.7).abs() < 1.5, "seq speedup {s_seq}");
    }

    #[test]
    fn hw_reduce_speedups_match_fig7() {
        // Paper Fig. 7b: HW reductions are 10.9× over SW.Tree and 67.3×
        // over SW.Seq.
        let bytes = 1 << 22;
        let hw = run_reduce(CollectiveImpl::Hw, 32, bytes) as f64;
        let tree = run_reduce(CollectiveImpl::SwTree, 32, bytes) as f64;
        let seq = run_reduce(CollectiveImpl::SwSeq, 32, bytes) as f64;
        let s_tree = tree / hw;
        let s_seq = seq / hw;
        assert!((s_tree - 10.9).abs() < 1.5, "tree speedup {s_tree}");
        assert!((s_seq - 67.3).abs() < 7.0, "seq speedup {s_seq}");
    }

    #[test]
    fn small_transfers_dominated_by_latency() {
        // At small sizes the HW advantage shrinks (hop latency dominates).
        let hw = run_multicast(CollectiveImpl::Hw, 32, 256) as f64;
        let seq = run_multicast(CollectiveImpl::SwSeq, 32, 256) as f64;
        let big_hw = run_multicast(CollectiveImpl::Hw, 32, 1 << 22) as f64;
        let big_seq = run_multicast(CollectiveImpl::SwSeq, 32, 1 << 22) as f64;
        assert!(seq / hw < big_seq / big_hw);
    }

    #[test]
    fn same_row_collectives_serialize_different_rows_parallel() {
        let (cfg, res) = setup();
        let bytes = 1 << 16;
        // Two on row 0.
        let mut g = Graph::new(res.table.clone());
        multicast(&mut g, &res, &cfg, CollectiveImpl::Hw, Axis::Row, 0, 32, bytes, &[]);
        multicast(&mut g, &res, &cfg, CollectiveImpl::Hw, Axis::Row, 0, 32, bytes, &[]);
        let serial = g.simulate().makespan;
        // One on row 0, one on row 1.
        let mut g = Graph::new(res.table.clone());
        multicast(&mut g, &res, &cfg, CollectiveImpl::Hw, Axis::Row, 0, 32, bytes, &[]);
        multicast(&mut g, &res, &cfg, CollectiveImpl::Hw, Axis::Row, 1, 32, bytes, &[]);
        let parallel = g.simulate().makespan;
        assert!(serial > parallel, "serial {serial} parallel {parallel}");
        assert!((serial as f64 / parallel as f64 - 2.0).abs() < 0.1);
    }

    #[test]
    fn analytic_tracks_des_multicast() {
        let (cfg, _) = setup();
        for imp in [CollectiveImpl::Hw, CollectiveImpl::SwTree, CollectiveImpl::SwSeq] {
            for bytes in [4096u64, 1 << 18, 1 << 22] {
                let des = run_multicast(imp, 32, bytes) as f64;
                let ana = multicast_latency_cycles(&cfg, imp, 32, bytes) as f64;
                let err = (des - ana).abs() / des.max(1.0);
                assert!(err < 0.25, "{} {bytes}: des {des} ana {ana}", imp.label());
            }
        }
    }

    #[test]
    fn analytic_tracks_des_reduce() {
        let (cfg, _) = setup();
        for imp in [CollectiveImpl::Hw, CollectiveImpl::SwTree, CollectiveImpl::SwSeq] {
            for bytes in [4096u64, 1 << 18, 1 << 22] {
                let des = run_reduce(imp, 32, bytes) as f64;
                let ana = reduce_latency_cycles(&cfg, imp, 32, bytes, Dtype::Fp16) as f64;
                let err = (des - ana).abs() / des.max(1.0);
                assert!(err < 0.3, "{} {bytes}: des {des} ana {ana}", imp.label());
            }
        }
    }

    #[test]
    fn column_multicast_uses_col_path() {
        let (cfg, res) = setup();
        let mut g = Graph::new(res.table.clone());
        // Column collectives on different columns run in parallel.
        multicast(&mut g, &res, &cfg, CollectiveImpl::Hw, Axis::Col, 0, 32, 1 << 16, &[]);
        multicast(&mut g, &res, &cfg, CollectiveImpl::Hw, Axis::Col, 5, 32, 1 << 16, &[]);
        let parallel = g.simulate().makespan;
        let single = run_multicast(CollectiveImpl::Hw, 32, 1 << 16);
        // Col path has same cost model as row path.
        assert_eq!(parallel, single);
    }
}
