//! Per-tile engine cost models: RedMulE matrix engine, Spatz vector engine,
//! DMA, and L1 occupancy bookkeeping.
//!
//! Calibration (substitutes the paper's RTL calibration, Fig. 6a): the
//! RedMulE model is an output-stationary systolic array of
//! `ce_rows × ce_cols` CEs streaming over K, plus a fixed fill/drain/config
//! overhead. The overhead is pinned so the model reproduces the paper's
//! reported operating points: ≈20% utilization for a 16×128×16 attention
//! slice (Fig. 9, over-flattening) and ≥95% for 128×128×128 (Fig. 11a).

use super::config::TileConfig;
use crate::sim::Cycles;

/// Kinds of vector-engine work in the attention dataflows.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VectorOpKind {
    /// Row-wise max over an m×n tile (n ops per row).
    RowMax,
    /// Elementwise exp (PACE-style dedicated unit: 1 elem/lane/cycle).
    Exp,
    /// Row-wise sum.
    RowSum,
    /// Diagonal rescale of an m×n tile (mul per element).
    Rescale,
    /// Elementwise add of two tiles (used by software reductions).
    Add,
    /// Elementwise scale + max-merge of tracking statistics (O(m) work).
    StatsUpdate,
    /// Generic elementwise op (RoPE, activation, norm pieces).
    Elementwise,
}

/// RedMulE GEMM cycles for an m×k×n product (output m×n, reduction k).
///
/// The CE array computes a `ce_rows × ce_cols` output tile per pass,
/// streaming K at 1 MAC/CE/cycle; output tiles are processed sequentially.
pub fn gemm_cycles(t: &TileConfig, m: u64, k: u64, n: u64) -> Cycles {
    if m == 0 || k == 0 || n == 0 {
        return 0;
    }
    let tiles_m = m.div_ceil(t.ce_rows as u64);
    let tiles_n = n.div_ceil(t.ce_cols as u64);
    tiles_m * tiles_n * k + t.gemm_setup_cycles
}

/// FLOPs of an m×k×n GEMM.
pub fn gemm_flops(m: u64, k: u64, n: u64) -> u64 {
    2 * m * k * n
}

/// RedMulE utilization for a single GEMM invocation (FLOPs over peak).
pub fn gemm_utilization(t: &TileConfig, m: u64, k: u64, n: u64) -> f64 {
    let cyc = gemm_cycles(t, m, k, n);
    if cyc == 0 {
        return 0.0;
    }
    gemm_flops(m, k, n) as f64 / (cyc as f64 * t.matrix_flops_per_cycle() as f64)
}

/// Vector-engine cycles for `kind` over an m×n tile.
///
/// Spatz lanes sustain `vector_flops_per_cycle` elementwise FLOP/cycle; ops
/// that read two operands and write one (Add) are L1-bandwidth limited to
/// half rate. Each invocation pays a fixed startup (VL config + issue).
pub fn vector_cycles(t: &TileConfig, kind: VectorOpKind, m: u64, n: u64) -> Cycles {
    let elems = m * n;
    if elems == 0 {
        return 0;
    }
    let rate = t.vector_flops_per_cycle.max(1);
    let work = match kind {
        VectorOpKind::RowMax | VectorOpKind::RowSum | VectorOpKind::Exp => elems.div_ceil(rate),
        // 3 streams through L1 (two reads + one write): half throughput.
        VectorOpKind::Add => elems.div_ceil(rate / 2),
        VectorOpKind::Rescale => elems.div_ceil(rate),
        // O(m) stats work (max-merge, exp of scalars, scale of ℓ).
        VectorOpKind::StatsUpdate => (4 * m).div_ceil(rate),
        VectorOpKind::Elementwise => elems.div_ceil(rate),
    };
    work + t.vector_startup_cycles
}

/// FLOPs attributed to a vector op (for accounting).
pub fn vector_flops(kind: VectorOpKind, m: u64, n: u64) -> u64 {
    match kind {
        VectorOpKind::StatsUpdate => 4 * m,
        _ => m * n,
    }
}

/// Track L1 scratchpad occupancy for a tile-resident working set.
#[derive(Debug, Clone, Default)]
pub struct L1Budget {
    items: Vec<(String, u64)>,
}

impl L1Budget {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn add(&mut self, name: &str, bytes: u64) -> &mut Self {
        self.items.push((name.to_string(), bytes));
        self
    }
    pub fn total_bytes(&self) -> u64 {
        self.items.iter().map(|(_, b)| b).sum()
    }
    pub fn total_kib(&self) -> f64 {
        self.total_bytes() as f64 / 1024.0
    }
    pub fn fits(&self, t: &TileConfig) -> bool {
        self.total_bytes() <= t.l1_kib * 1024
    }
    pub fn items(&self) -> &[(String, u64)] {
        &self.items
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::config::ChipConfig;

    fn tile() -> TileConfig {
        ChipConfig::table1().tile
    }

    #[test]
    fn gemm_cycles_zero_dims() {
        assert_eq!(gemm_cycles(&tile(), 0, 128, 128), 0);
        assert_eq!(gemm_cycles(&tile(), 128, 0, 128), 0);
    }

    #[test]
    fn gemm_128_cube_hits_paper_utilization() {
        // Paper Fig. 11a: ~95–98% RedMulE utilization at 128×128 slices.
        let u = gemm_utilization(&tile(), 128, 128, 128);
        assert!(u > 0.95 && u <= 0.98, "util {u}");
    }

    #[test]
    fn gemm_16_slice_overflattened_utilization() {
        // Paper Fig. 9 (S=512, 32×32 group): ≈20% utilization at slice 16.
        // Attention score GEMM per tile: 16×128×16.
        let u = gemm_utilization(&tile(), 16, 128, 16);
        assert!((u - 0.20).abs() < 0.03, "util {u}");
        // PV GEMM 16×16×128 matches too.
        let u2 = gemm_utilization(&tile(), 16, 16, 128);
        assert!((u2 - 0.20).abs() < 0.03, "util {u2}");
    }

    #[test]
    fn gemm_util_increases_with_slice() {
        let t = tile();
        let sizes = [16u64, 32, 64, 128, 256];
        let mut last = 0.0;
        for s in sizes {
            let u = gemm_utilization(&t, s, 128, s);
            assert!(u > last, "non-monotone at {s}: {u} <= {last}");
            last = u;
        }
        // 256 approaches 98%+ (Fig. 11a).
        assert!(last > 0.975, "util {last}");
    }

    #[test]
    fn gemv_degenerate_row() {
        // Decode GEMV m=1: dominated by the CE-column sweep over n.
        let t = tile();
        let c = gemm_cycles(&t, 1, 512, 64);
        assert_eq!(c, 4 * 512 + t.gemm_setup_cycles);
    }

    #[test]
    fn vector_add_is_half_rate() {
        let t = tile();
        let fast = vector_cycles(&t, VectorOpKind::Exp, 128, 128);
        let slow = vector_cycles(&t, VectorOpKind::Add, 128, 128);
        assert!(slow > fast);
        assert_eq!(slow - t.vector_startup_cycles, (128 * 128u64).div_ceil(64));
    }

    #[test]
    fn l1_budget_128_slice_fits_384kib() {
        // Single-stream working set at slice 128, D=128, FP16, double-
        // buffered K/V: the Fig. 11b operating point must fit in 384 KiB.
        let t = tile();
        let d = 128u64;
        let s = 128u64;
        let e = 2u64; // fp16
        let mut b = L1Budget::new();
        b.add("Q", s * d * e)
            .add("O_acc", s * d * e)
            .add("K.db", 2 * s * d * e)
            .add("V.db", 2 * s * d * e)
            .add("S/P", s * s * e)
            .add("stats", 2 * s * 4);
        assert!(b.fits(&t), "occupancy {} KiB", b.total_kib());
    }

    #[test]
    fn l1_budget_256_slice_overflows() {
        let t = tile();
        let d = 128u64;
        let s = 256u64;
        let e = 2u64;
        let mut b = L1Budget::new();
        b.add("Q", s * d * e)
            .add("O_acc", s * d * e)
            .add("K.db", 2 * s * d * e)
            .add("V.db", 2 * s * d * e)
            .add("S/P", s * s * e)
            .add("stats", 2 * s * 4);
        assert!(!b.fits(&t), "occupancy {} KiB should exceed 384", b.total_kib());
    }
}
