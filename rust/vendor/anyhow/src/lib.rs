//! Minimal offline shim of the `anyhow` API surface this workspace uses:
//! [`Error`], [`Result`], [`Context`], and the `anyhow!` / `bail!` /
//! `ensure!` macros. The build environment has no crates.io access, so the
//! real crate cannot be fetched; this shim keeps call sites source-compatible.
//!
//! Like the real crate, [`Error`] deliberately does **not** implement
//! `std::error::Error`, which is what makes the blanket
//! `From<E: std::error::Error>` conversion (and therefore `?`) possible.

use std::fmt;

/// A context-carrying error: a message plus the chain of contexts added via
/// [`Context::context`] (outermost first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    pub fn msg(m: impl fmt::Display) -> Self {
        Error { chain: vec![m.to_string()] }
    }

    fn wrap(mut self, ctx: String) -> Self {
        self.chain.insert(0, ctx);
        self
    }

    /// The innermost (root-cause) message — the opposite end of the chain
    /// from what `Display`/`to_string` shows.
    pub fn root_cause_message(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` prints the whole context chain, like anyhow.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        // Preserve the source chain as context lines.
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Add context to errors (and convert `Option` to `Result`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: Into<Error>,
{
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(f().to_string()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        bail!("boom {}", 7)
    }

    #[test]
    fn bail_formats() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "boom 7");
    }

    #[test]
    fn ensure_both_arities() {
        fn f(x: u32) -> Result<()> {
            ensure!(x > 1);
            ensure!(x > 2, "x was {x}");
            Ok(())
        }
        assert!(f(3).is_ok());
        assert!(f(2).unwrap_err().to_string().contains("x was 2"));
        assert!(f(1).unwrap_err().to_string().contains("condition failed"));
    }

    #[test]
    fn context_chains_and_alternate_display() {
        let r: Result<()> = "x".parse::<u32>().map_err(Error::from).context("parsing config");
        let e = r.unwrap_err();
        assert_eq!(e.to_string(), "parsing config");
        let full = format!("{e:#}");
        assert!(full.starts_with("parsing config: "), "{full}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<u32> {
            let v: u32 = "nope".parse()?;
            Ok(v)
        }
        assert!(f().is_err());
    }
}
